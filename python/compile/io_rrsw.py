"""`.rrsw` tensor container - the python<->rust weight/golden interchange.

Binary layout (little-endian):

    magic   b"RRSW1\\n"                      6 bytes
    u32     n_tensors
    per tensor:
        u16  name_len,  name (utf-8)
        u8   dtype      0=f32  1=i8  2=i32  3=u8
        u8   ndim
        u32  dims[ndim]
        raw  data (C order, LE)

Mirrored by rust/src/util/io.rs; both sides are round-trip tested against
the golden files written by compile/aot.py.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"RRSW1\n"
_DTYPES = {0: np.float32, 1: np.int8, 2: np.int32, 3: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1,
          np.dtype(np.int32): 2, np.dtype(np.uint8): 3}


def write_rrsw(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in _CODES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_rrsw(path: str) -> Dict[str, np.ndarray]:
    out = {}
    with open(path, "rb") as f:
        assert f.read(6) == MAGIC, f"{path}: bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = np.dtype(_DTYPES[code])
            count = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(
                f.read(count * dt.itemsize), dtype=dt
            ).reshape(dims).copy()
    return out
