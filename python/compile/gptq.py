"""GPTQ weight quantization (Frantar et al., 2022) - build-time substrate.

The paper quantizes weights with per-channel symmetric GPTQ using 128
calibration sequences; activations are RTN.  This module implements the
standard GPTQ column sweep with Cholesky-factored inverse Hessian and
error feedback, in numpy (build-time only; the rust engine has its own
implementation in rust/src/quant/gptq.rs tested against this one through
the golden vectors).

For variant spaces: pass ``x_calib`` already transformed the way the
activation reaches the GEMM (rotated for quarot/rrs, smoothed for sq), and
``w`` in the same space - GPTQ then compensates in that space.
"""

from __future__ import annotations

import numpy as np

QMAX = 7.0


def quantize_rtn_col(col: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return np.clip(np.round(col / scale), -QMAX, QMAX)


def gptq_quantize(
    w: np.ndarray,
    x_calib: np.ndarray,
    damp: float = 0.01,
    block: int = 64,
):
    """Quantize W [M,K] given calibration activations X [N,K].

    Returns (wq int8 [M,K], scale f32 [M,1]).  Per-output-channel symmetric
    scales are fixed from absmax upfront (the paper's per-channel scheme);
    GPTQ redistributes rounding error along K using H = 2 X^T X.
    """
    m, k = w.shape
    w = w.astype(np.float64).copy()
    h = 2.0 * (x_calib.astype(np.float64).T @ x_calib.astype(np.float64))
    # dampen: mean of diag keeps conditioning scale-free
    dmean = float(np.mean(np.diag(h)))
    if dmean <= 0:
        dmean = 1.0
    h[np.diag_indices(k)] += damp * dmean
    # dead channels: no calib signal -> freeze via large diagonal
    dead = np.diag(h) <= 0
    h[dead, dead] = dmean

    scale = np.maximum(np.abs(w).max(axis=1, keepdims=True), 1e-8) / QMAX

    # Upper Cholesky factor U of H^{-1}: Hinv = L L^T with L lower, so
    # U = L^T satisfies Hinv = U^T U (the factor GPTQ's sweep consumes).
    linv = np.linalg.inv(np.linalg.cholesky(h))
    hinv = linv.T @ linv  # H^{-1}
    hinv_u = np.linalg.cholesky(hinv).T

    q = np.zeros_like(w)
    for b0 in range(0, k, block):
        b1 = min(b0 + block, k)
        werr = np.zeros((m, b1 - b0))
        for j in range(b0, b1):
            d = hinv_u[j, j]
            col = w[:, j]
            qcol = quantize_rtn_col(col, scale[:, 0])
            q[:, j] = qcol
            err = (col - qcol * scale[:, 0]) / d
            # update remaining columns inside the block
            if j + 1 < b1:
                w[:, j + 1 : b1] -= np.outer(err, hinv_u[j, j + 1 : b1])
            werr[:, j - b0] = err
        # propagate block error to the tail
        if b1 < k:
            w[:, b1:] -= werr @ hinv_u[b0:b1, b1:]
    return q.astype(np.int8), scale.astype(np.float32)


def gptq_layer_error(w, wq, scale, x_calib) -> float:
    """Relative output MSE of the quantized layer on the calib batch."""
    y = x_calib @ w.T
    yq = x_calib @ (wq.astype(np.float32) * scale).T
    denom = float(np.mean(y * y)) + 1e-12
    return float(np.mean((y - yq) ** 2)) / denom
