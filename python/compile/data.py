"""Synthetic corpus + QA-task generator (build-time substrate).

The paper evaluates on WikiText-2 perplexity and Common Sense QA
(OBQA/BoolQ/ARC-e/ARC-c).  Neither dataset nor the LLaMA/Qwen checkpoints
are available in this environment (repro band 0/5), so we substitute a
deterministic synthetic corpus with enough latent structure for a small
transformer to learn:

  * an entity/attribute/relation knowledge base rendered through sentence
    templates (gives the model "facts" it can be quizzed on),
  * arithmetic and sequence patterns (gives sharply-peaked next-token
    distributions so quantization damage is visible in perplexity),
  * a held-out split used for teacher-forced perplexity (WikiText-2 stand-in).

Four zero-shot QA tasks mirror the paper's benchmark protocol (score each
candidate continuation by log-likelihood, pick the argmax):

  * ``boolq``  - yes/no fact verification            (BoolQ stand-in)
  * ``obqa``   - 4-way attribute completion          (OBQA stand-in)
  * ``arc_e``  - 4-way easy pattern completion       (ARC-e stand-in)
  * ``arc_c``  - 4-way hard relational inference     (ARC-c stand-in)

Everything is generated from a seeded PRNG; the same generator is mirrored
in rust/src/eval/qa.rs via the exported JSON task files, so python and rust
score identical task instances.
"""

from __future__ import annotations

import json
import random
from dataclasses import dataclass, field

ENTITIES = [
    "arlo", "brin", "ceda", "dorn", "elba", "fenn", "gilo", "hesta",
    "irin", "jova", "kels", "lumo", "mira", "nollo", "opal", "pryn",
    "quill", "rava", "senna", "tovo", "ursa", "velt", "wren", "xilo",
    "yara", "zemo",
]
COLORS = ["red", "blue", "green", "gold", "gray", "pink", "teal", "black"]
ANIMALS = ["fox", "owl", "cat", "elk", "bee", "yak", "hen", "ram"]
PLACES = ["hill", "lake", "cave", "reef", "dune", "glen", "moor", "peak"]
SIZES = ["tiny", "small", "big", "huge"]


@dataclass
class KnowledgeBase:
    """Entity -> attribute assignments plus a cyclic 'likes' relation."""

    color: dict = field(default_factory=dict)
    animal: dict = field(default_factory=dict)
    place: dict = field(default_factory=dict)
    size: dict = field(default_factory=dict)
    likes: dict = field(default_factory=dict)


def build_kb(rng: random.Random) -> KnowledgeBase:
    kb = KnowledgeBase()
    for e in ENTITIES:
        kb.color[e] = rng.choice(COLORS)
        kb.animal[e] = rng.choice(ANIMALS)
        kb.place[e] = rng.choice(PLACES)
        kb.size[e] = rng.choice(SIZES)
    shuffled = ENTITIES[:]
    rng.shuffle(shuffled)
    for a, b in zip(shuffled, shuffled[1:] + shuffled[:1]):
        kb.likes[a] = b
    return kb


def fact_sentences(kb: KnowledgeBase, rng: random.Random, n: int) -> list:
    """Render KB facts through a small set of templates."""
    out = []
    for _ in range(n):
        e = rng.choice(ENTITIES)
        t = rng.randrange(6)
        if t == 0:
            out.append(f"{e} is {kb.color[e]}.")
        elif t == 1:
            out.append(f"{e} the {kb.animal[e]} lives at the {kb.place[e]}.")
        elif t == 2:
            out.append(f"{e} is a {kb.size[e]} {kb.animal[e]}.")
        elif t == 3:
            out.append(f"{e} likes {kb.likes[e]}.")
        elif t == 4:
            out.append(
                f"the {kb.animal[e]} named {e} is {kb.color[e]} and {kb.size[e]}."
            )
        else:
            out.append(f"at the {kb.place[e]} you can find {e}.")
    return out


def pattern_sentences(rng: random.Random, n: int) -> list:
    """Low-entropy sequences: counting, alphabet runs, doubling."""
    out = []
    for _ in range(n):
        t = rng.randrange(4)
        if t == 0:
            a = rng.randrange(1, 6)
            seq = " ".join(str(a + i) for i in range(5))
            out.append(f"count: {seq}.")
        elif t == 1:
            a = rng.randrange(0, 20)
            out.append(f"sum: {a} plus {a + 1} is {2 * a + 1}.")
        elif t == 2:
            start = rng.randrange(0, 22)
            run = "".join(chr(ord("a") + (start + i) % 26) for i in range(6))
            out.append(f"abc: {' '.join(run)}.")
        else:
            a = rng.randrange(1, 9)
            out.append(f"double: {a} {2 * a} {4 * a}.")
    return out


def build_corpus(seed: int = 1234, n_facts: int = 24000, n_patterns: int = 8000):
    """Return (train_text, val_text, kb). Deterministic in ``seed``."""
    rng = random.Random(seed)
    kb = build_kb(rng)
    sents = fact_sentences(kb, rng, n_facts) + pattern_sentences(rng, n_patterns)
    rng.shuffle(sents)
    n_val = max(1, len(sents) // 20)
    val = " ".join(sents[:n_val])
    train = " ".join(sents[n_val:])
    return train, val, kb


# ---------------------------------------------------------------- QA tasks


def qa_boolq(kb: KnowledgeBase, rng: random.Random, n: int) -> list:
    """Yes/no verification. candidates = [' yes', ' no']."""
    items = []
    for _ in range(n):
        e = rng.choice(ENTITIES)
        truth = rng.random() < 0.5
        color = kb.color[e] if truth else rng.choice(
            [c for c in COLORS if c != kb.color[e]]
        )
        items.append(
            {
                "prompt": f"{e} is {color}. true?",
                "candidates": [" yes", " no"],
                "answer": 0 if truth else 1,
            }
        )
    return items


def qa_obqa(kb: KnowledgeBase, rng: random.Random, n: int) -> list:
    """4-way attribute completion: 'X is a <size> <animal>' -> animal."""
    items = []
    for _ in range(n):
        e = rng.choice(ENTITIES)
        gold = kb.animal[e]
        distract = rng.sample([a for a in ANIMALS if a != gold], 3)
        cands = distract + [gold]
        rng.shuffle(cands)
        items.append(
            {
                "prompt": f"{e} is a {kb.size[e]}",
                "candidates": [f" {c}." for c in cands],
                "answer": cands.index(gold),
            }
        )
    return items


def qa_arc_e(rng: random.Random, n: int) -> list:
    """Easy pattern completion: next number in a counting run."""
    items = []
    for _ in range(n):
        a = rng.randrange(1, 6)
        prompt = "count: " + " ".join(str(a + i) for i in range(4))
        gold = str(a + 4)
        pool = {str(a + 4 + d) for d in (1, 2, 3)}
        cands = sorted(pool) + [gold]
        rng.shuffle(cands)
        items.append(
            {
                "prompt": prompt,
                "candidates": [f" {c}." for c in cands],
                "answer": cands.index(gold),
            }
        )
    return items


def qa_arc_c(kb: KnowledgeBase, rng: random.Random, n: int) -> list:
    """Hard relational hop: who does X like -> that entity's color."""
    items = []
    for _ in range(n):
        e = rng.choice(ENTITIES)
        target = kb.likes[e]
        gold = kb.color[target]
        distract = rng.sample([c for c in COLORS if c != gold], 3)
        cands = distract + [gold]
        rng.shuffle(cands)
        items.append(
            {
                "prompt": f"{e} likes {target}. {target} is",
                "candidates": [f" {c}." for c in cands],
                "answer": cands.index(gold),
            }
        )
    return items


def build_qa_tasks(kb: KnowledgeBase, seed: int = 99, n_per_task: int = 200) -> dict:
    rng = random.Random(seed)
    return {
        "boolq": qa_boolq(kb, rng, n_per_task),
        "obqa": qa_obqa(kb, rng, n_per_task),
        "arc_e": qa_arc_e(rng, n_per_task),
        "arc_c": qa_arc_c(kb, rng, n_per_task),
    }


def export_qa(tasks: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(tasks, f)


if __name__ == "__main__":
    train, val, kb = build_corpus()
    print(f"train={len(train)} chars val={len(val)} chars")
    tasks = build_qa_tasks(kb)
    for k, v in tasks.items():
        print(k, len(v), v[0])
