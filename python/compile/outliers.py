"""Outlier-profile injection: make the tiny model exhibit LLM-like outliers.

The paper's phenomena depend on two activation pathologies that large
models develop naturally but a 0.6M-parameter model does not:

  * **channel-wise outliers** - a few hidden channels carry systematically
    large magnitudes into every linear layer.  In real LLMs these are
    amplified by RMSNorm gain channels; we reproduce the mechanism directly
    by scaling a handful of ``*_norm`` gain channels (x20..x200), which
    creates *genuine, data-dependent* channel outliers in the activations
    feeding wq/wk/wv/w_gate/w_up (and, through the residual stream, wo).
  * **spike outliers** - rare, huge, token-local values at the down-proj
    input produced by SwiGLU (paper Fig. 7: up to 1000x the token median).
    We scale a few w_gate rows so silu(gate)*up occasionally explodes for
    specific token patterns - spikes that move with the token, not the
    channel, exactly the class rotation is needed for.

Profiles map to the paper's model columns (Table 1): larger models show
stronger spikes (LLaMA3-70B being the pathological case where QuaRot alone
scores 57.33).  FP quality is re-measured after injection so every method
is compared against the same (slightly perturbed) reference model.  The
same profiles are implemented in rust/src/eval/profiles.rs; aot.py exports
the profile table so both sides stay in sync.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OutlierProfile:
    """Injection strengths; zeros = untouched model."""

    name: str
    n_channel: int = 0          # norm-gain channels to amplify
    channel_gain: float = 1.0   # amplification factor
    n_spike_rows: int = 0       # w_up rows to amplify (spikes at down-proj)
    spike_gain: float = 1.0
    n_const: int = 0            # embed channels given a constant offset
    const_gain: float = 0.0     # ("massive activations": sign-consistent
                                #  channel outliers, rank-1 after rotation)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


# Paper-column stand-ins, calibrated so that (see harness/table1):
#   base          : clean tiny model (sanity row)
#   llama2-like   : moderate channel outliers, mild spikes
#   llama3-like   : strong channel outliers + spikes (8B-ish sensitivity)
#   llama3-70b-like: extreme spikes -> rotation-only becomes unstable,
#                    reproducing the 57.33 -> 6.66 headline behaviour
#   qwen-like     : many medium channel outliers
PROFILES = {
    "base": OutlierProfile("base"),
    "llama2-like": OutlierProfile("llama2-like", n_channel=4, channel_gain=30.0,
                                  n_spike_rows=1, spike_gain=8.0,
                                  n_const=2, const_gain=15.0),
    "llama3-like": OutlierProfile("llama3-like", n_channel=4, channel_gain=40.0,
                                  n_spike_rows=2, spike_gain=25.0,
                                  n_const=4, const_gain=30.0),
    "llama3-70b-like": OutlierProfile("llama3-70b-like", n_channel=4,
                                      channel_gain=40.0, n_spike_rows=6,
                                      spike_gain=200.0,
                                      n_const=4, const_gain=30.0),
    "qwen-like": OutlierProfile("qwen-like", n_channel=8, channel_gain=40.0,
                                n_spike_rows=1, spike_gain=12.0,
                                n_const=6, const_gain=15.0),
}


def inject_uncompensated(params: dict, profile: OutlierProfile, seed: int = 17):
    """Inject raw outlier structure WITHOUT compensation.

    Used by the per-profile finetuning pipeline in aot.py: amplify norm
    gain channels (-> channel-wise activation outliers) and w_up rows
    (-> SwiGLU spike outliers at the down projector), then *finetune the
    rest of the network around them* with these tensors frozen.  The
    result is a healthy fp model that genuinely carries outliers - unlike
    the invertible diagonal rescaling of :func:`inject`, which SmoothQuant
    can undo exactly.

    Returns (params, frozen_names).
    """
    rng = np.random.default_rng(seed)
    out = {k: np.asarray(v).copy() for k, v in params.items()}
    layer_ids = sorted(
        {int(k.split(".")[1]) for k in params if k.startswith("layers.")}
    )
    dim = params["final_norm"].shape[0]
    ch = rng.choice(dim, size=min(profile.n_channel, dim), replace=False)
    frozen = set()
    if profile.n_const > 0:
        # "massive activations": a few frequent token ids get large
        # constant offsets in a few embedding channels — the attention-
        # sink/delimiter-token phenomenon.  These massive tokens stretch
        # RS's runtime channel maxima (victims, paper 2.2) and per-token
        # RTN scales; rotation spreads them (paper 3.3).
        massive_tokens = [ord(c) for c in " e.as"]  # frequent corpus bytes
        const_ch = rng.choice(dim, size=min(profile.n_const, dim), replace=False)
        signs = rng.choice([-1.0, 1.0], size=len(const_ch))
        for c, s in zip(const_ch, signs):
            out["embed"][massive_tokens, c] += s * profile.const_gain
        frozen.add("embed")
    for i in layer_ids:
        p = f"layers.{i}."
        if profile.n_channel > 0:
            for norm in ("attn_norm", "mlp_norm"):
                out[p + norm][ch] *= profile.channel_gain
                frozen.add(p + norm)
        if profile.n_spike_rows > 0:
            rows = rng.choice(
                out[p + "w_up"].shape[0], size=profile.n_spike_rows, replace=False
            )
            for r in rows:
                out[p + "w_up"][r] *= profile.spike_gain
            frozen.add(p + "w_up")
    return {k: jnp.asarray(v) for k, v in out.items()}, sorted(frozen)


def inject(params: dict, profile: OutlierProfile, seed: int = 17) -> dict:
    """Return a copy of ``params`` with the profile's outliers injected.

    **Function-preserving** (mirror of rust/src/model/weights.rs): the
    fp32 model computes the same function; only the activations that the
    quantizers see change.  Channel outliers: norm gain channel x g and
    the consuming linears' input columns / g.  Spike outliers: w_up row
    x s and the w_down input column / s (exactly linear through SwiGLU).
    """
    if profile.n_channel == 0 and profile.n_spike_rows == 0:
        return dict(params)
    rng = np.random.default_rng(seed)
    out = {k: np.asarray(v).copy() for k, v in params.items()}
    layer_ids = sorted(
        {int(k.split(".")[1]) for k in params if k.startswith("layers.")}
    )
    dim = params["final_norm"].shape[0]
    ch = rng.choice(dim, size=min(profile.n_channel, dim), replace=False)
    for i in layer_ids:
        p = f"layers.{i}."
        for c in ch:
            out[p + "attn_norm"][c] *= profile.channel_gain
            out[p + "mlp_norm"][c] *= profile.channel_gain
            for w in ("wq", "wk", "wv", "w_gate", "w_up"):
                out[p + w][:, c] /= profile.channel_gain
        if profile.n_spike_rows > 0:
            rows = rng.choice(
                out[p + "w_up"].shape[0], size=profile.n_spike_rows, replace=False
            )
            for r in rows:
                out[p + "w_up"][r] *= profile.spike_gain
                out[p + "w_down"][:, r] /= profile.spike_gain
    return {k: jnp.asarray(v) for k, v in out.items()}
