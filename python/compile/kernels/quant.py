"""Pallas INT4 RTN quantization kernels (L1, interpret=True).

Per-token symmetric INT4 quantization as a row-parallel Pallas kernel.
The kernel computes the row scale (absmax/7) and the int8-contained INT4
codes in a single VMEM-resident pass, the way a fused CUDA prologue would.
On a real TPU each grid step holds one (block_rows, K) tile in VMEM; here
``interpret=True`` lowers it to plain HLO so the CPU PJRT client can run it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 7.0


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / QMAX
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_rows",))
def quant_per_token(x, block_rows: int = 8):
    """[N,K] f32 -> (q [N,K] int8, scale [N,1] f32), per-token symmetric.

    Matches ref.quant_per_token bit-exactly (same round/clip order).
    """
    n, k = x.shape
    br = min(block_rows, n)
    assert n % br == 0, f"N={n} not divisible by block_rows={br}"
    return pl.pallas_call(
        _quant_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=True,
    )(x)


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def dequant_per_token(q, s, block_rows: int = 8):
    """Inverse of quant_per_token: (q [N,K] int8, s [N,1]) -> f32 [N,K]."""
    n, k = q.shape
    br = min(block_rows, n)
    assert n % br == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(q, s)
