"""Pure-jnp reference oracles for every quantization primitive.

These are the single source of truth for the numerics: the Pallas kernels
(python/compile/kernels/*.py), the L2 model variants (compile/model.py) and
the rust engine (rust/src/quant/*) are all tested against this module
(directly via pytest, and indirectly via the golden vectors exported by
compile/aot.py).

Conventions (match the paper, Section 2.1):
  * symmetric round-to-nearest INT4: q = clip(round(x/s), -7, 7),
    s = absmax/7  (2^{N-1}-1 levels; -8 is unused, as in the paper).
  * activations are quantized **per-token** (each row of the [N,K] matrix),
    which the paper calls "per-channel" for activations;
    weights are quantized **per-output-channel** (each row of [M,K]).
  * sub-channel = groups of ``group`` along K, one scale per group.
  * Runtime Smooth: s_j = max_i |X_ij| per input channel j, X/s quantized,
    and the channel (group) scale re-applied on the de-quantized output:
        Y = sum_j  Xq_j Wq_j^T * s_j          (paper eq. 1-3)
  * RRS: Hadamard-rotate X and W along K first, then Runtime Smooth.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

QMAX = 7.0  # 2^{4-1} - 1


# --------------------------------------------------------------- RTN INT4


def quant_scale(absmax):
    """Symmetric INT4 scale with a floor to avoid div-by-zero."""
    return jnp.maximum(absmax, 1e-8) / QMAX


def rtn_quant(x, scale):
    """q = clip(round(x / scale), -7, 7) as int8 container."""
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX)
    return q.astype(jnp.int8)


def quant_per_token(x):
    """[N,K] -> (q[N,K] int8, scale[N,1])."""
    s = quant_scale(jnp.max(jnp.abs(x), axis=-1, keepdims=True))
    return rtn_quant(x, s), s


def quant_per_channel_w(w):
    """[M,K] -> (q[M,K] int8, scale[M,1]) - per-output-channel."""
    return quant_per_token(w)


def quant_sub_channel(x, group: int):
    """[N,K] -> (q[N,K] int8, scale[N,K//group]). K % group == 0."""
    n, k = x.shape
    xg = x.reshape(n, k // group, group)
    s = quant_scale(jnp.max(jnp.abs(xg), axis=-1))  # [N, K//group]
    q = rtn_quant(xg, s[..., None]).reshape(n, k)
    return q, s


def dequant(q, scale):
    return q.astype(jnp.float32) * scale


# ------------------------------------------------------ fake-quant GEMMs


def igemm(xq, wq):
    """int8 x int8 -> int32 exact integer GEMM, [N,K]x[M,K] -> [N,M]."""
    return jnp.dot(
        xq.astype(jnp.int32), wq.astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )


def gemm_fp(x, w):
    return x @ w.T


def gemm_a4w4_per_channel(x, w, wq_pre=None):
    """Per-token activation / per-channel weight INT4 GEMM (RTN baseline)."""
    xq, sx = quant_per_token(x)
    wq, sw = wq_pre if wq_pre is not None else quant_per_channel_w(w)
    acc = igemm(xq, wq).astype(jnp.float32)
    return acc * sx * sw.T


def gemm_a4w4_sub_channel(x, w, group: int = 128):
    """Sub-channel INT4 GEMM: per-group scales for both operands."""
    n, k = x.shape
    m, _ = w.shape
    xq, sx = quant_sub_channel(x, group)  # [N,K],[N,G]
    wq, sw = quant_sub_channel(w, group)  # [M,K],[M,G]
    xg = xq.reshape(n, k // group, group).astype(jnp.int32)
    wg = wq.reshape(m, k // group, group).astype(jnp.int32)
    # per-group integer partials, scaled per group: sum_g sx[:,g] sw[:,g] P_g
    acc = jnp.einsum("ngk,mgk->gnm", xg, wg).astype(jnp.float32)
    acc = acc * sx.T[:, :, None] * sw.T[:, None, :]
    return acc.sum(axis=0)


# -------------------------------------------------------- Runtime Smooth


def rs_channel_scale(x):
    """Runtime smoothing scale: per-input-channel absmax (paper eq. 1)."""
    return jnp.maximum(jnp.max(jnp.abs(x), axis=0), 1e-8)  # [K]


def rs_reorder_perm(s):
    """Descending-magnitude channel permutation (paper pipeline step 1)."""
    return jnp.argsort(-s)


def rs_group_scales(s_perm, group: int):
    """Group-wise max over the reordered scales (pipeline step 2)."""
    k = s_perm.shape[0]
    return jnp.max(s_perm.reshape(k // group, group), axis=-1)  # [K//group]


def gemm_rs(x, w, group: int = 1, wq_pre=None):
    """Runtime Smooth INT4 GEMM (paper eq. 1-3 + kernel-fusion grouping).

    group=1 reproduces the exact per-channel runtime scale (Table 1 'RS');
    group=128 is the fused-kernel configuration (Table 4 ablation).
    ``wq_pre`` optionally supplies offline-quantized weights (q, scale) so
    GPTQ weights can be used instead of RTN.
    """
    n, k = x.shape
    s = rs_channel_scale(x)  # [K]
    perm = rs_reorder_perm(s)
    xp = x[:, perm]
    sg = rs_group_scales(s[perm], group)  # [K//group]
    # smooth: divide each channel group by its group scale
    x_sm = xp / jnp.repeat(sg, group)[None, :]
    xq, sx = quant_per_token(x_sm)
    wq, sw = wq_pre if wq_pre is not None else quant_per_channel_w(w)
    wqp = wq[:, perm]
    # block-wise integer partials; re-apply group scale on dequant (eq. 3)
    g = k // group
    m = wqp.shape[0]
    xg = xq.reshape(n, g, group).astype(jnp.int32)
    wg = wqp.reshape(m, g, group).astype(jnp.int32)
    acc = jnp.einsum("ngk,mgk->gnm", xg, wg).astype(jnp.float32)
    acc = acc * sg[:, None, None]
    return acc.sum(axis=0) * sx * sw.T


def gemm_rtn_a4w16(x, w):
    """Activation-only INT4 (A4W16): isolates activation quant error."""
    xq, sx = quant_per_token(x)
    return dequant(xq, sx) @ w.T


def gemm_rs_a4w16(x, w, group: int = 1):
    """Runtime Smooth with fp weights (paper Fig. 3 A4W16 ablation)."""
    s = rs_channel_scale(x)
    perm = rs_reorder_perm(s)
    sg = rs_group_scales(s[perm], group)
    sg_full = jnp.repeat(sg, group)
    x_sm = x[:, perm] / sg_full[None, :]
    xq, sx = quant_per_token(x_sm)
    xdq = dequant(xq, sx) * sg_full[None, :]
    return xdq @ w[:, perm].T


# --------------------------------------------------------------- Rotation


def hadamard(k: int) -> np.ndarray:
    """Normalized Sylvester-Hadamard matrix, k a power of two."""
    assert k & (k - 1) == 0, f"hadamard dim {k} not a power of two"
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < k:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(k)).astype(np.float32)


def fwht(x):
    """Fast Walsh-Hadamard transform along the last axis, normalized.

    Equivalent to x @ hadamard(K) but O(K log K).
    """
    k = x.shape[-1]
    assert k & (k - 1) == 0
    orig = x.shape
    y = x.reshape(-1, k)
    h = 1
    while h < k:
        y = y.reshape(-1, k // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.concatenate([a + b, a - b], axis=-1)
        h *= 2
    y = y.reshape(-1, k)
    return (y.reshape(orig) / jnp.sqrt(k)).astype(x.dtype)


def rotate(x):
    """x @ H with H the normalized Hadamard (output-equivalent pairing)."""
    return fwht(x)


def gemm_quarot(x, w, wq_pre=None):
    """QuaRot baseline: rotate both operands, per-channel INT4 GEMM."""
    xr = rotate(x)
    wq, sw = wq_pre if wq_pre is not None else quant_per_channel_w(rotate(w))
    xq, sx = quant_per_token(xr)
    return igemm(xq, wq).astype(jnp.float32) * sx * sw.T


def gemm_rrs_a4w16(x, w, group: int = 1):
    """Rotated Runtime Smooth with fp weights (activation-only ablation)."""
    return gemm_rs_a4w16(rotate(x), rotate(w), group=group)


def gemm_rrs(x, w, group: int = 128, wq_pre=None):
    """Rotated Runtime Smooth: rotate, then Runtime Smooth (paper 3.3).

    ``w`` is the *unrotated* weight when wq_pre is None; with wq_pre the
    caller passes offline-quantized **rotated** weights.
    """
    xr = rotate(x)
    if wq_pre is None:
        wq_pre = quant_per_channel_w(rotate(w))
    return gemm_rs(xr, None, group=group, wq_pre=wq_pre)


# ----------------------------------------------------------- SmoothQuant


def smoothquant_scales(calib_absmax_x, w, alpha: float = 0.5):
    """s_j = max|X_j|^a / max|W_j|^(1-a) (paper 2.2), from *calibration*."""
    wmax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)
    s = jnp.power(jnp.maximum(calib_absmax_x, 1e-8), alpha) / jnp.power(
        wmax, 1.0 - alpha
    )
    return jnp.maximum(s, 1e-8)


def gemm_smoothquant(x, w, s, wq_pre=None):
    """SmoothQuant INT4 GEMM with offline scales merged into the weight."""
    x_sm = x / s[None, :]
    xq, sx = quant_per_token(x_sm)
    if wq_pre is None:
        wq_pre = quant_per_channel_w(w * s[None, :])
    wq, sw = wq_pre
    return igemm(xq, wq).astype(jnp.float32) * sx * sw.T


# ------------------------------------------------------------- KV quant


def kv_quant(x, group: int = 128):
    """Sub-channel symmetric INT4 KV-cache quantization (paper 4.1)."""
    g = min(group, x.shape[-1])
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    q, s = quant_sub_channel(x2, g)
    return q.reshape(orig), s.reshape(orig[:-1] + (orig[-1] // g,))


def kv_dequant(q, s):
    g = q.shape[-1] // s.shape[-1]
    return (
        q.astype(jnp.float32).reshape(q.shape[:-1] + (s.shape[-1], g))
        * s[..., None]
    ).reshape(q.shape)


def kv_fake_quant(x, group: int = 128):
    q, s = kv_quant(x, group)
    return kv_dequant(q, s)


# ---------------------------------------------------------- smoothness u


def smoothness_mu(t):
    """mu = absmax(t)/RMS(t) per token (paper Fig. 2b); [N,K] -> [N]."""
    absmax = jnp.max(jnp.abs(t), axis=-1)
    rms = jnp.sqrt(jnp.mean(t * t, axis=-1) + 1e-12)
    return absmax / rms
