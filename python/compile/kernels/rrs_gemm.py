"""Fused Runtime-Smooth INT4 GEMM - the paper's compute hot-spot (L1).

Implements the Figure-4 pipeline as a Pallas kernel:

  1. (wrapper, "runtime" stage) channel-wise absmax -> reorder permutation
     -> group-wise smoothing scales -> smooth + per-token INT4 quantize.
     On CUDA the paper fuses this prologue into the GEMM; under XLA it
     stages into the same lowered module, so rust still sees ONE artifact.
  2. (kernel) blocked integer GEMM: each (bn x bm) output tile accumulates
     over K-blocks; the *group* smoothing scale is one scalar per K-block
     (group size == block size, exactly the paper's fusion constraint), so
     de-quantization is `acc += sg[g] * (Xq_blk @ Wq_blkT)` - a single
     scalar multiply per tile, the reason RS adds negligible overhead over
     plain per-channel A4W4 (paper 3.2, Fig. 6).
  3. (kernel epilogue) per-token activation scale and per-output-channel
     weight scale applied once on the final K-block.

TPU mapping (DESIGN.md section 7): block sizes default to MXU-friendly
(8,128)x(128,128) tiles; Xq/Wq tiles live in VMEM as int8, the f32
accumulator in VMEM scratch; `interpret=True` makes the same kernel run
on the CPU PJRT client for this reproduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _rs_gemm_kernel(xq_ref, wq_ref, sg_ref, sx_ref, sw_ref, o_ref, *, sub: int):
    """One (bn,bm) output tile x one K-block step.

    sub = number of smoothing groups inside this K-block (1 when
    group == block_k, the fused-kernel configuration).
    """
    kblk = pl.program_id(2)

    @pl.when(kblk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = xq_ref[...].astype(jnp.int32)  # (bn, bk)
    wq = wq_ref[...].astype(jnp.int32)  # (bm, bk)
    sg = sg_ref[...]  # (sub,)
    bn, bk = xq.shape
    bm = wq.shape[0]
    g = bk // sub
    if sub == 1:
        # group == block: one integer GEMM + one scalar multiply (hot path)
        part = jnp.dot(xq, wq.T, preferred_element_type=jnp.int32)
        o_ref[...] += part.astype(jnp.float32) * sg[0]
    else:
        # fine-grained groups inside the block (group-size ablation path)
        xs = xq.reshape(bn, sub, g)
        ws = wq.reshape(bm, sub, g)
        part = jnp.einsum(
            "nsg,msg->snm", xs, ws, preferred_element_type=jnp.int32
        ).astype(jnp.float32)
        o_ref[...] += jnp.sum(part * sg[:, None, None], axis=0)

    @pl.when(kblk == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[...] *= sx_ref[...] * sw_ref[...].T


@functools.partial(
    jax.jit, static_argnames=("group", "block_n", "block_m", "block_k")
)
def rs_gemm_prequant(
    xq, sx, wq, sw, sg,
    group: int = 128,
    block_n: int = 8,
    block_m: int = 128,
    block_k: int = 128,
):
    """Blocked INT4 GEMM over pre-quantized operands.

    xq [N,K] int8, sx [N,1] f32, wq [M,K] int8, sw [M,1] f32,
    sg [K//group] f32 (group smoothing scales, reordered layout).
    Returns [N,M] f32 = (sum_g sg_g Xq_g Wq_g^T) * sx * sw^T.
    """
    n, k = xq.shape
    m = wq.shape[0]
    bn = min(block_n, n)
    bm = min(block_m, m)
    bk = min(block_k, k)
    assert n % bn == 0 and m % bm == 0 and k % bk == 0, (n, m, k, bn, bm, bk)
    assert bk % group == 0 or group % bk == 0
    if group > bk:
        bk = group
    sub = bk // group
    kernel = functools.partial(_rs_gemm_kernel, sub=sub)
    return pl.pallas_call(
        kernel,
        grid=(n // bn, m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, g_: (i, g_)),
            pl.BlockSpec((bm, bk), lambda i, j, g_: (j, g_)),
            pl.BlockSpec((sub,), lambda i, j, g_: (g_,)),
            pl.BlockSpec((bn, 1), lambda i, j, g_: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, g_: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, g_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(xq, wq, sg, sx, sw)


def rs_prepare(x, group: int):
    """Runtime stage: perm, group scales, smoothed+quantized activation.

    Returns (xq [N,K] int8, sx [N,1], perm [K] int32, sg [K//group]).
    """
    s = ref.rs_channel_scale(x)
    perm = ref.rs_reorder_perm(s)
    xp = x[:, perm]
    sg = ref.rs_group_scales(s[perm], group)
    x_sm = xp / jnp.repeat(sg, group)[None, :]
    xq, sx = ref.quant_per_token(x_sm)
    return xq, sx, perm, sg


def rs_gemm(x, wq, sw, group: int = 128, **blocks):
    """Runtime Smooth INT4 GEMM: f32 activation x offline-quantized weight.

    wq/sw are the offline per-output-channel INT4 weight (RTN or GPTQ).
    """
    xq, sx, perm, sg = rs_prepare(x, group)
    return rs_gemm_prequant(xq, sx, wq[:, perm], sw, sg, group=group, **blocks)


def rrs_gemm(x, wq_rot, sw_rot, group: int = 128, **blocks):
    """Rotated Runtime Smooth GEMM: Hadamard-rotate x, then rs_gemm.

    wq_rot/sw_rot quantize the *offline-rotated* weight (W @ H), so the
    product equals X W^T up to quantization error (paper Fig. 2a).
    """
    xr = ref.rotate(x)
    return rs_gemm(xr, wq_rot, sw_rot, group=group, **blocks)
