"""Pallas Hadamard-rotation kernel (L1, interpret=True).

The QuaRot/RRS online rotation is ``x @ H_K`` with H the normalized
Sylvester-Hadamard matrix.  On TPU the natural formulation is a dense
matmul against the +-1/sqrt(K) matrix: the MXU executes a (bn,K)x(K,K)
tile at full systolic utilization and H lives in VMEM once (K<=512 here,
so H is at most 1MB in f32 - far under the ~16MB VMEM budget).  This is
the Hardware-Adaptation of the paper's CUDA "online Hadamard" (which uses
warp-level butterflies): on TPU, log-depth butterflies would be
VPU-serial, while the dense form is MXU-parallel.

A butterfly (FWHT) variant is included for cross-checking and for the
K > VMEM regime; it performs log2(K) in-VMEM passes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref


def _rotate_kernel(x_ref, h_ref, o_ref):
    # One (bn, K) tile times the (K, K) Hadamard, f32 accumulate.
    o_ref[...] = jnp.dot(
        x_ref[...], h_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rotate(x, block_rows: int = 8):
    """x @ hadamard(K) via a row-blocked Pallas matmul kernel. [N,K]->[N,K]."""
    n, k = x.shape
    h = jnp.asarray(ref.hadamard(k))
    br = min(block_rows, n)
    assert n % br == 0
    return pl.pallas_call(
        _rotate_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(x, h)


def _fwht_kernel(x_ref, o_ref):
    # Full FWHT on a (br, K) tile: log2(K) butterfly stages in VMEM.
    x = x_ref[...]
    br, k = x.shape
    h = 1
    while h < k:
        x = x.reshape(br, k // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1)
        h *= 2
    o_ref[...] = x.reshape(br, k) * (1.0 / np.sqrt(k))


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rotate_fwht(x, block_rows: int = 8):
    """FWHT butterfly variant of ``rotate`` (O(K log K) per row)."""
    n, k = x.shape
    assert k & (k - 1) == 0
    br = min(block_rows, n)
    assert n % br == 0
    return pl.pallas_call(
        _fwht_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(x)
