"""SpinQuant-style trained rotation (Liu et al., 2024) - Table 3 baseline.

SpinQuant replaces the fixed Hadamard with a learned rotation, optimized so
the rotated network quantizes well.  We reproduce the essential mechanism:
parametrize R = cayley(A) = (I - A)(I + A)^{-1} with A skew-symmetric
(guaranteed orthogonal, det +1), and minimize the INT4 fake-quantization
output error of rotated (activation, weight) pairs over a calibration set
with Adam.  This is the per-GEMM analogue of SpinQuant's R1/R2 training;
the paper's observation we reproduce is that the *trained* rotation does
not necessarily beat the fixed Hadamard (their Table 3).

Training uses a straight-through estimator for the rounding op.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


def cayley(a):
    """Skew-symmetrize then Cayley transform -> orthogonal [K,K]."""
    skew = a - a.T
    k = a.shape[0]
    eye = jnp.eye(k, dtype=a.dtype)
    return jnp.linalg.solve((eye + skew).T, (eye - skew).T).T


def _ste_round(x):
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _fake_quant_pt(x):
    """Differentiable per-token INT4 fake quant (STE)."""
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8) / 7.0
    q = jnp.clip(_ste_round(x / s), -7.0, 7.0)
    return q * s


def quant_loss(a, xs: List[jnp.ndarray], ws: List[jnp.ndarray]):
    """Sum of relative output MSEs of A4W4 GEMMs under rotation cayley(A)."""
    r = cayley(a)
    total = 0.0
    for x, w in zip(xs, ws):
        xr = x @ r
        wr = w @ r
        y_ref = x @ w.T
        y_q = _fake_quant_pt(xr) @ _fake_quant_pt(wr).T
        total = total + jnp.mean((y_ref - y_q) ** 2) / (jnp.mean(y_ref**2) + 1e-8)
    return total / len(xs)


@functools.partial(jax.jit, static_argnames=("lr",))
def _adam_step(a, m, v, t, xs, ws, lr: float):
    loss, g = jax.value_and_grad(quant_loss)(a, xs, ws)
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    a = a - lr * mh / (jnp.sqrt(vh) + eps)
    return a, m, v, loss


def train_rotation(
    xs: List[np.ndarray],
    ws: List[np.ndarray],
    k: int,
    steps: int = 150,
    lr: float = 1e-3,
    seed: int = 0,
    init_hadamard: bool = False,
) -> Tuple[np.ndarray, List[float]]:
    """Learn a KxK rotation minimizing INT4 GEMM error on (xs, ws) pairs.

    Returns (R [K,K] f32, loss_log).  ``init_hadamard=False`` matches
    SpinQuant's random init (their reported setting we compare against).
    """
    key = jax.random.PRNGKey(seed)
    a = 0.01 * jax.random.normal(key, (k, k), jnp.float32)
    xs_j = [jnp.asarray(x[: min(len(x), 512)]) for x in xs]
    ws_j = [jnp.asarray(w) for w in ws]
    m = jnp.zeros_like(a)
    v = jnp.zeros_like(a)
    log = []
    for t in range(1, steps + 1):
        a, m, v, loss = _adam_step(a, m, v, t, xs_j, ws_j, lr)
        if t % 25 == 0 or t == 1:
            log.append(float(loss))
    r = np.asarray(cayley(a), dtype=np.float32)
    return r, log


def rotation_orthogonality_error(r: np.ndarray) -> float:
    k = r.shape[0]
    return float(np.abs(r @ r.T - np.eye(k)).max())
