"""AOT driver: train -> quantize -> lower -> export artifacts/ (build time).

Run once via ``make artifacts``.  Produces, under artifacts/:

  weights.rrsw       fp32 trained parameters (rust engine input)
  spinquant_r.rrsw   learned rotation matrices (Table 3)
  goldens.rrsw       golden inputs/outputs for rust unit+integration tests
  qa_tasks.json      zero-shot QA task instances (Table 2)
  profiles.json      outlier-injection profile table (Table 1 columns)
  val.txt            held-out corpus split (perplexity stand-in)
  train_log.csv      loss curve of the build-time training run
  manifest.json      artifact index: graphs, shapes, configs
  *.hlo.txt          lowered HLO text (prefill/decode per variant + demo)

HLO **text** is the interchange format (not serialized protos): jax>=0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Weights are baked into the graphs as constants, so the rust request path
feeds only (tokens | token,kv,pos) - no parameter marshalling.  The
outlier-profile sweep for Table 1 runs in the rust engine from
weights.rrsw; the PJRT artifacts cover the "base" profile and serve as the
L1/L2 numerics oracle plus the serving FP reference.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, gptq, io_rrsw, outliers, spinquant, train
from .kernels import ref, rrs_gemm
from .model import (
    ModelConfig, QuantConfig, calib_absmax, capture_activations, decode_step,
    forward, init_params, layer_names, prepare_weights,
)

CFG = ModelConfig()
PREFILL_B, PREFILL_T = 1, 96
DECODE_B, MAX_T = 4, 160


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: weights are baked into the graphs; the
    # default elides them as `constant({...})`, which the rust-side text
    # parser would reject (or silently zero).
    return comp.as_hlo_text(True)


def lower_and_write(fn, args, path: str) -> dict:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {"bytes": len(text)}


def np_params(params):
    return {k: np.asarray(v) for k, v in params.items()}


def gptq_weights_for(params, cfg, qcfg, acts_by_layer):
    """Offline GPTQ in the correct variant space for each linear layer."""
    out = {}
    for name in layer_names(cfg):
        w = np.asarray(params[name])
        x = acts_by_layer[name]
        if qcfg.variant in ("quarot", "rrs"):
            w = np.asarray(ref.rotate(jnp.asarray(w)))
            x = np.asarray(ref.rotate(jnp.asarray(x)))
        wq, sw = gptq.gptq_quantize(w, x[:256])
        out[name] = (jnp.asarray(wq), jnp.asarray(sw))
    return out


def acts_per_layer(params, cfg, tokens):
    """Map linear-name -> calibration activations (inputs to that linear)."""
    acts = capture_activations(params, cfg, tokens)
    out = {}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        out[p + "wq"] = np.asarray(acts["qkv"][i])
        out[p + "wk"] = out[p + "wq"]
        out[p + "wv"] = out[p + "wq"]
        out[p + "wo"] = np.asarray(acts["o"][i])
        out[p + "w_gate"] = np.asarray(acts["gate_up"][i])
        out[p + "w_up"] = out[p + "w_gate"]
        out[p + "w_down"] = np.asarray(acts["down"][i])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--spin-steps", type=int, default=120)
    ap.add_argument("--finetune-steps", type=int, default=200)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    wpath = os.path.join(out, "weights.rrsw")
    if os.path.exists(wpath) and not args.force:
        print("loading cached weights", flush=True)
        raw = io_rrsw.read_rrsw(wpath)
        params = {k: jnp.asarray(v) for k, v in raw.items()}
        _, val_text, kb = data.build_corpus()
        log = []
    else:
        params, log, _, val_text = train.train(CFG, steps=args.steps)
        _, _, kb = data.build_corpus()
        io_rrsw.write_rrsw(wpath, np_params(params))
        with open(os.path.join(out, "train_log.csv"), "w") as f:
            f.write("step,loss,seconds\n")
            for s, l, sec in log:
                f.write(f"{s},{l:.6f},{sec:.2f}\n")
    print(f"[{time.time()-t0:.0f}s] weights ready "
          f"({CFG.param_count(params)} params)", flush=True)

    with open(os.path.join(out, "val.txt"), "w") as f:
        f.write(val_text)
    train_text, _, _ = data.build_corpus()

    # ---------------- per-profile outlier model variants (Table 1 columns)
    # inject uncompensated outlier structure, then finetune the rest of
    # the network around the frozen outlier tensors -> healthy fp models
    # that genuinely carry channel-wise + spike activation outliers.
    profile_fp = {}
    for name, prof in outliers.PROFILES.items():
        if name == "base":
            continue
        ppath = os.path.join(out, f"weights_{name}.rrsw")
        if os.path.exists(ppath) and not args.force:
            print(f"[{time.time()-t0:.0f}s] cached profile '{name}'", flush=True)
            continue
        pparams, frozen = outliers.inject_uncompensated(params, prof)
        pparams, last = train.finetune(
            pparams, CFG, train_text, frozen, steps=args.finetune_steps
        )
        nll = train.eval_nll(pparams, CFG, val_text)
        profile_fp[name] = float(np.exp(nll))
        io_rrsw.write_rrsw(ppath, np_params(pparams))
        print(f"[{time.time()-t0:.0f}s] profile '{name}': final loss "
              f"{last:.3f}, val ppl {np.exp(nll):.3f}", flush=True)
    with open(os.path.join(out, "qa_tasks.json"), "w") as f:
        json.dump(data.build_qa_tasks(kb), f)
    with open(os.path.join(out, "profiles.json"), "w") as f:
        json.dump({k: v.to_dict() for k, v in outliers.PROFILES.items()}, f,
                  indent=1)

    # ---------------- calibration + offline weight quant (base profile)
    val_toks = train.encode(val_text)
    calib = np.stack([val_toks[i * 64 : i * 64 + 64] for i in range(8)])
    calib_j = jnp.asarray(calib)
    acts_map = acts_per_layer(params, CFG, calib_j)
    print(f"[{time.time()-t0:.0f}s] calibration captured", flush=True)

    manifest = {
        "model": {
            "vocab": CFG.vocab, "dim": CFG.dim, "n_layers": CFG.n_layers,
            "n_heads": CFG.n_heads, "n_kv_heads": CFG.n_kv_heads,
            "ffn": CFG.ffn, "max_seq": CFG.max_seq,
            "rope_theta": CFG.rope_theta,
            "params": int(CFG.param_count(params)),
        },
        "prefill": {"batch": PREFILL_B, "seq": PREFILL_T},
        # pos_per_lane: decode graphs take one position input per lane, so
        # unequal-length sequences batch into a single graph call (the
        # rust runtime sniffs the pos input width; this flag is for humans)
        "decode": {"batch": DECODE_B, "max_t": MAX_T, "pos_per_lane": True},
        "graphs": {},
    }

    # ---------------- lower prefill + decode graphs per variant
    variants = {
        "fp": QuantConfig("fp"),
        "rtn": QuantConfig("rtn", w_bits=4, kv_bits=4),
        "rrs": QuantConfig("rrs", w_bits=4, kv_bits=4, group=128),
    }
    kd = CFG.n_kv_heads * CFG.head_dim
    for vname, qcfg in variants.items():
        gq = (gptq_weights_for(params, CFG, qcfg, acts_map)
              if qcfg.w_bits == 4 else None)
        prep = prepare_weights(params, CFG, qcfg, gptq_weights=gq)

        def prefill_fn(tokens, _prep=prep, _q=qcfg):
            return (forward(params, _prep, CFG, _q, tokens),)

        toks_spec = jax.ShapeDtypeStruct((PREFILL_B, PREFILL_T), jnp.int32)
        path = os.path.join(out, f"prefill_{vname}.hlo.txt")
        info = lower_and_write(prefill_fn, (toks_spec,), path)
        manifest["graphs"][f"prefill_{vname}"] = {
            "file": os.path.basename(path),
            "inputs": [["tokens", "i32", [PREFILL_B, PREFILL_T]]],
            "outputs": [["logits", "f32", [PREFILL_B, PREFILL_T, CFG.vocab]]],
            "quant": vars(qcfg) | {"variant": qcfg.variant},
            **info,
        }
        print(f"[{time.time()-t0:.0f}s] lowered prefill_{vname} "
              f"({info['bytes']} bytes)", flush=True)

        def decode_fn(token, kc, vc, pos, _prep=prep, _q=qcfg):
            return decode_step(params, _prep, CFG, _q, token, kc, vc, pos)

        tok_spec = jax.ShapeDtypeStruct((DECODE_B, 1), jnp.int32)
        kv_spec = jax.ShapeDtypeStruct(
            (CFG.n_layers, DECODE_B, MAX_T, CFG.n_kv_heads, CFG.head_dim),
            jnp.float32)
        # one position per lane: resident-lane decode batches sequences at
        # unequal positions into a single call
        pos_spec = jax.ShapeDtypeStruct((DECODE_B,), jnp.int32)
        path = os.path.join(out, f"decode_{vname}.hlo.txt")
        info = lower_and_write(
            decode_fn, (tok_spec, kv_spec, kv_spec, pos_spec), path)
        manifest["graphs"][f"decode_{vname}"] = {
            "file": os.path.basename(path),
            "inputs": [
                ["token", "i32", [DECODE_B, 1]],
                ["kcache", "f32", list(kv_spec.shape)],
                ["vcache", "f32", list(kv_spec.shape)],
                ["pos", "i32", [DECODE_B]],
            ],
            "outputs": [
                ["logits", "f32", [DECODE_B, 1, CFG.vocab]],
                ["kcache", "f32", list(kv_spec.shape)],
                ["vcache", "f32", list(kv_spec.shape)],
            ],
            "quant": vars(qcfg) | {"variant": qcfg.variant},
            **info,
        }
        print(f"[{time.time()-t0:.0f}s] lowered decode_{vname}", flush=True)

    # ---------------- standalone fused-kernel demo artifact (quickstart)
    rngd = np.random.default_rng(3)
    demo_w = rngd.normal(size=(128, 128)).astype(np.float32)
    demo_wq, demo_sw = ref.quant_per_channel_w(ref.rotate(jnp.asarray(demo_w)))

    def demo_fn(x):
        return (rrs_gemm.rrs_gemm(x, demo_wq, demo_sw, group=64),)

    demo_spec = jax.ShapeDtypeStruct((16, 128), jnp.float32)
    path = os.path.join(out, "demo_rrs_gemm.hlo.txt")
    info = lower_and_write(demo_fn, (demo_spec,), path)
    manifest["graphs"]["demo_rrs_gemm"] = {
        "file": "demo_rrs_gemm.hlo.txt",
        "inputs": [["x", "f32", [16, 128]]],
        "outputs": [["y", "f32", [16, 128]]],
        **info,
    }

    # ---------------- SpinQuant trained rotation (Table 3)
    xs = [acts_map[f"layers.{i}.wq"] for i in range(CFG.n_layers)]
    ws = [np.asarray(params[f"layers.{i}.wq"]) for i in range(CFG.n_layers)]
    r, spin_log = spinquant.train_rotation(
        xs, ws, CFG.dim, steps=args.spin_steps)
    xs_d = [acts_map[f"layers.{i}.w_down"] for i in range(CFG.n_layers)]
    ws_d = [np.asarray(params[f"layers.{i}.w_down"]) for i in range(CFG.n_layers)]
    r_ffn, spin_log_ffn = spinquant.train_rotation(
        xs_d, ws_d, CFG.ffn, steps=args.spin_steps)
    io_rrsw.write_rrsw(os.path.join(out, "spinquant_r.rrsw"),
                       {"r_dim": r, "r_ffn": r_ffn})
    manifest["spinquant"] = {"loss_log_dim": spin_log,
                             "loss_log_ffn": spin_log_ffn}
    print(f"[{time.time()-t0:.0f}s] spinquant rotations trained", flush=True)

    # ---------------- golden vectors for rust tests
    rng = np.random.default_rng(0)
    gx = rng.normal(size=(16, 128)).astype(np.float32)
    gx[:, 3] *= 40.0  # channel outlier
    gx[5, 77] = 90.0  # spike outlier
    gw = rng.normal(size=(64, 128)).astype(np.float32)
    gxj, gwj = jnp.asarray(gx), jnp.asarray(gw)
    q, s = ref.quant_per_token(gxj)
    wq, sw = ref.quant_per_channel_w(gwj)
    wqr, swr = ref.quant_per_channel_w(ref.rotate(gwj))
    goldens = {
        "x": gx, "w": gw,
        "quant_q": np.asarray(q), "quant_s": np.asarray(s),
        "rotate": np.asarray(ref.rotate(gxj)),
        "gemm_fp": np.asarray(ref.gemm_fp(gxj, gwj)),
        "gemm_rtn": np.asarray(ref.gemm_a4w4_per_channel(gxj, gwj)),
        "gemm_sub": np.asarray(ref.gemm_a4w4_sub_channel(gxj, gwj, 32)),
        "gemm_rs_g1": np.asarray(ref.gemm_rs(gxj, gwj, group=1)),
        "gemm_rs_g32": np.asarray(ref.gemm_rs(gxj, gwj, group=32)),
        "gemm_quarot": np.asarray(ref.gemm_quarot(gxj, gwj)),
        "gemm_rrs_g32": np.asarray(ref.gemm_rrs(gxj, gwj, group=32)),
        "kv_fq_g32": np.asarray(ref.kv_fake_quant(gxj, 32)),
        "smooth_mu": np.asarray(ref.smoothness_mu(gxj)),
        "wq": np.asarray(wq), "sw": np.asarray(sw),
        "wq_rot": np.asarray(wqr), "sw_rot": np.asarray(swr),
    }
    # GPTQ golden (small, deterministic)
    gq, gsc = gptq.gptq_quantize(gw, gx)
    goldens["gptq_wq"] = gq
    goldens["gptq_sw"] = gsc
    # smoothquant golden
    am = np.abs(gx).max(axis=0)
    sq_s = np.asarray(ref.smoothquant_scales(jnp.asarray(am), gwj))
    goldens["sq_scales"] = sq_s
    goldens["gemm_sq"] = np.asarray(ref.gemm_smoothquant(gxj, gwj, jnp.asarray(sq_s)))
    # model-level goldens (base profile): fp + rrs prefill logits
    gt = np.asarray(val_toks[: PREFILL_B * PREFILL_T], dtype=np.int32).reshape(
        PREFILL_B, PREFILL_T
    )
    goldens["prefill_tokens"] = gt
    for vname, qcfg in variants.items():
        gq_w = (gptq_weights_for(params, CFG, qcfg, acts_map)
                if qcfg.w_bits == 4 else None)
        prep = prepare_weights(params, CFG, qcfg, gptq_weights=gq_w)
        lg = forward(params, prep, CFG, qcfg, jnp.asarray(gt))
        goldens[f"prefill_logits_{vname}"] = np.asarray(lg)
    # demo kernel golden
    demo_x = rng.normal(size=(16, 128)).astype(np.float32)
    goldens["demo_x"] = demo_x
    goldens["demo_y"] = np.asarray(demo_fn(jnp.asarray(demo_x))[0])
    goldens["demo_w"] = demo_w
    io_rrsw.write_rrsw(os.path.join(out, "goldens.rrsw"), goldens)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[{time.time()-t0:.0f}s] artifacts complete -> {out}", flush=True)


if __name__ == "__main__":
    main()
