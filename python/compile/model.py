"""L2: LLaMA-architecture transformer in JAX with quantized-linear variants.

Build-time only.  The forward graph (prefill and single-step decode) is
lowered by compile/aot.py to HLO text and executed from rust via PJRT;
python never runs on the request path.

Architecture (matches the rust engine in rust/src/model/):
  byte-level embedding -> n_layers x [RMSNorm -> GQA attention (RoPE)
  -> RMSNorm -> SwiGLU MLP] -> RMSNorm -> LM head.
All weights are stored [out_features, in_features] so every linear is
``y = x @ W.T`` and quantization conventions follow kernels/ref.py.

Quantized-linear variants (``QuantVariant``):
  fp      - f32 matmul (FP16-reference stand-in)
  rtn     - per-token/per-channel INT4 RTN on X and W        (Table 1 'RTN')
  sq      - SmoothQuant: offline calib scales merged into W  ('SmoothQuant')
  rs      - Runtime Smooth, Pallas fused kernel              ('RS')
  quarot  - Hadamard-rotate X and W, per-channel INT4        ('QuaRot')
  rrs     - rotate + Runtime Smooth, Pallas fused kernel     ('RRS')

Weight quantization is applied offline by ``prepare_weights`` (RTN here;
GPTQ in compile/gptq.py), mirroring the paper's setup where weights are
quantized with GPTQ before inference.  The KV cache is optionally
INT4-fake-quantized (sub-channel, group<=128) to model A4W4KV4.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref, rrs_gemm

VARIANTS = ("fp", "rtn", "sq", "rs", "quarot", "rrs")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    dim: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    ffn: int = 256
    max_seq: int = 256
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    def param_count(self, params=None) -> int:
        if params is None:
            params = init_params(self, jax.random.PRNGKey(0))
        return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """One cell of the paper's scheme matrix, e.g. A4W4KV4 + method."""

    variant: str = "fp"  # activation-smoothing method
    w_bits: int = 16     # 4 -> offline INT4 weights (RTN or GPTQ)
    kv_bits: int = 16    # 4 -> sub-channel INT4 KV cache
    group: int = 128     # runtime-smooth group size (Table 4 ablation)
    kv_group: int = 128
    use_pallas: bool = True  # rs/rrs via the fused Pallas kernel


def layer_names(cfg: ModelConfig):
    for i in range(cfg.n_layers):
        for n in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
            yield f"layers.{i}.{n}"


def init_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """He-style init; flat dict name -> array (stable, sorted order)."""
    kd = cfg.n_kv_heads * cfg.head_dim
    shapes = {"embed": (cfg.vocab, cfg.dim), "head": (cfg.vocab, cfg.dim),
              "final_norm": (cfg.dim,)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        shapes[p + "attn_norm"] = (cfg.dim,)
        shapes[p + "mlp_norm"] = (cfg.dim,)
        shapes[p + "wq"] = (cfg.dim, cfg.dim)
        shapes[p + "wk"] = (kd, cfg.dim)
        shapes[p + "wv"] = (kd, cfg.dim)
        shapes[p + "wo"] = (cfg.dim, cfg.dim)
        shapes[p + "w_gate"] = (cfg.ffn, cfg.dim)
        shapes[p + "w_up"] = (cfg.ffn, cfg.dim)
        shapes[p + "w_down"] = (cfg.dim, cfg.ffn)
    params = {}
    for name in sorted(shapes):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 1.0 / np.sqrt(shape[-1])
            params[name] = jax.random.normal(sub, shape, jnp.float32) * std
    return params


# ------------------------------------------------------------ components


def rmsnorm(x, g, eps: float = 1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope_cos_sin(cfg: ModelConfig, positions):
    """positions [T] -> (cos, sin) each [T, head_dim/2]."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2) / hd))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, axis=1):
    """x [B,T,H,hd]; rotate-half convention (matches rust engine).

    cos/sin are [x.shape[axis], hd/2]: axis=1 is the prefill form (one
    angle per time step, shared across lanes); axis=0 is the per-lane
    decode form (one angle per lane, T==1).
    """
    h = x.shape[-1] // 2
    x1, x2 = x[..., :h], x[..., h:]
    shape = [1, 1, 1, cos.shape[-1]]
    shape[axis] = cos.shape[0]
    c = cos.reshape(shape)
    s = sin.reshape(shape)
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# ------------------------------------------------------- quantized linear


def prepare_weights(params, cfg: ModelConfig, qcfg: QuantConfig,
                    calib_absmax: Optional[Dict[str, jnp.ndarray]] = None,
                    gptq_weights: Optional[Dict[str, Any]] = None):
    """Offline weight preparation per variant.

    Returns dict name -> dict with keys among {w, wq, sw, smooth}:
      fp/rtn/rs: quantize W as-is.   sq: merge calib smooth scales into W.
      quarot/rrs: quantize W @ H (offline rotation).
    When ``gptq_weights`` provides (wq, sw) for a layer (from compile/gptq),
    they take precedence over RTN (already in the correct variant space).
    """
    out = {}
    for name in layer_names(cfg):
        w = params[name]
        entry: Dict[str, Any] = {}
        if qcfg.variant == "sq":
            am = (calib_absmax or {}).get(name)
            if am is None:
                am = jnp.ones((w.shape[1],), jnp.float32)
            s = ref.smoothquant_scales(am, w)
            entry["smooth"] = s
            w_eff = w * s[None, :]
        elif qcfg.variant in ("quarot", "rrs"):
            w_eff = ref.rotate(w)
        else:
            w_eff = w
        if qcfg.w_bits == 4:
            if gptq_weights and name in gptq_weights:
                entry["wq"], entry["sw"] = gptq_weights[name]
            else:
                entry["wq"], entry["sw"] = ref.quant_per_channel_w(w_eff)
        else:
            entry["w"] = w_eff
        out[name] = entry
    return out


def qlinear(x2d, prep: Dict[str, Any], qcfg: QuantConfig):
    """Dispatch one [N,K] x [M,K]^T linear through the variant path."""
    v = qcfg.variant
    if qcfg.w_bits == 4:
        wq, sw = prep["wq"], prep["sw"]
        w_for_act = ref.dequant(wq, sw)  # only used by fp-act paths
    else:
        w_for_act = prep["w"]
        wq = sw = None

    def _act_quant_gemm(xs):
        """per-token INT4 x (INT4|f32) weight."""
        xq, sx = ref.quant_per_token(xs)
        if wq is not None:
            return ref.igemm(xq, wq).astype(jnp.float32) * sx * sw.T
        return ref.dequant(xq, sx) @ w_for_act.T

    if v == "fp":
        if wq is not None:
            return x2d @ w_for_act.T
        return x2d @ w_for_act.T
    if v == "rtn":
        return _act_quant_gemm(x2d)
    if v == "sq":
        return _act_quant_gemm(x2d / prep["smooth"][None, :])
    if v == "quarot":
        return _act_quant_gemm(ref.rotate(x2d))
    if v in ("rs", "rrs"):
        xs = ref.rotate(x2d) if v == "rrs" else x2d
        if wq is not None and qcfg.use_pallas:
            return rrs_gemm.rs_gemm(xs, wq, sw, group=qcfg.group)
        # A4W16 / no-pallas path via the jnp oracle
        if wq is not None:
            return ref.gemm_rs(xs, None, group=qcfg.group, wq_pre=(wq, sw))
        # activation-only quantization (A4W16): smooth, quantize, fp gemm
        s = ref.rs_channel_scale(xs)
        perm = ref.rs_reorder_perm(s)
        sg = ref.rs_group_scales(s[perm], qcfg.group)
        x_sm = xs[:, perm] / jnp.repeat(sg, qcfg.group)[None, :]
        xq, sx = ref.quant_per_token(x_sm)
        xdq = ref.dequant(xq, sx) * jnp.repeat(sg, qcfg.group)[None, :]
        return xdq @ w_for_act[:, perm].T
    raise ValueError(f"unknown variant {v}")


# ----------------------------------------------------------- forward pass


def _attention(q, k, v, causal_from: int = 0):
    """q [B,Tq,H,hd], k/v [B,Tk,Hkv,hd] -> [B,Tq,H,hd] with GQA + causal."""
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qpos = causal_from + jnp.arange(tq)
    kpos = jnp.arange(tk)
    mask = kpos[None, :] <= qpos[:, None]
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", att, v)


def forward(params, prep, cfg: ModelConfig, qcfg: QuantConfig, tokens,
            kv_cache=None, pos: int = 0, return_kv: bool = False):
    """Forward pass.

    tokens [B,T] int32.  With ``kv_cache`` (list of (k,v) [B,Tpast,Hkv,hd])
    this is a decode step continuing at ``pos``; otherwise a prefill from 0.
    Returns logits [B,T,V] (+ per-layer new (k,v) when return_kv).
    """
    b, t = tokens.shape
    x = params["embed"][tokens]
    cos, sin = rope_cos_sin(cfg, pos + jnp.arange(t))
    new_kv = []
    kd = cfg.n_kv_heads * cfg.head_dim

    def lin(name, h2d):
        if qcfg.variant == "fp" and qcfg.w_bits != 4:
            return h2d @ params[name].T
        return qlinear(h2d, prep[name], qcfg)

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rmsnorm(x, params[p + "attn_norm"])
        h2 = h.reshape(b * t, cfg.dim)
        q = lin(p + "wq", h2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = lin(p + "wk", h2).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        v = lin(p + "wv", h2).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if qcfg.kv_bits == 4:
            k = ref.kv_fake_quant(k, qcfg.kv_group)
            v = ref.kv_fake_quant(v, qcfg.kv_group)
        new_kv.append((k, v))
        if kv_cache is not None:
            k = jnp.concatenate([kv_cache[i][0], k], axis=1)
            v = jnp.concatenate([kv_cache[i][1], v], axis=1)
        att = _attention(q, k, v, causal_from=pos)
        x = x + lin(p + "wo", att.reshape(b * t, cfg.dim)).reshape(b, t, cfg.dim)

        h = rmsnorm(x, params[p + "mlp_norm"])
        h2 = h.reshape(b * t, cfg.dim)
        gate = lin(p + "w_gate", h2)
        up = lin(p + "w_up", h2)
        act = jax.nn.silu(gate) * up
        x = x + lin(p + "w_down", act).reshape(b, t, cfg.dim)

    x = rmsnorm(x, params["final_norm"])
    logits = (x.reshape(b * t, cfg.dim) @ params["head"].T).reshape(b, t, cfg.vocab)
    if return_kv:
        return logits, new_kv
    return logits


def decode_step(params, prep, cfg: ModelConfig, qcfg: QuantConfig,
                token, kcache, vcache, pos):
    """Single-token decode over padded KV caches (the PJRT decode artifact).

    token  [B,1] i32;  kcache/vcache [L,B,maxT,Hkv,hd] f32;  pos [B] i32
    per-lane positions (tokens already cached in that lane) — a legacy
    length-1 ``pos`` broadcasts to every lane, the old scalar form.
    Returns (logits [B,1,V], updated kcache, updated vcache).  Cache
    updates happen inside the graph via per-lane dynamic_update_slice so
    rust only swaps buffers; lanes at unequal positions share one call.
    """
    b = token.shape[0]
    x = params["embed"][token]  # [B,1,D]
    lane_pos = pos if pos.shape[0] == b else jnp.broadcast_to(pos, (b,))
    cos, sin = rope_cos_sin(cfg, lane_pos)  # [B, hd/2]
    maxt = kcache.shape[2]

    def lin(name, h2d):
        if qcfg.variant == "fp" and qcfg.w_bits != 4:
            return h2d @ params[name].T
        return qlinear(h2d, prep[name], qcfg)

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rmsnorm(x, params[p + "attn_norm"])
        h2 = h.reshape(b, cfg.dim)
        q = lin(p + "wq", h2).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = lin(p + "wk", h2).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = lin(p + "wv", h2).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin, axis=0)
        k = apply_rope(k, cos, sin, axis=0)
        if qcfg.kv_bits == 4:
            k = ref.kv_fake_quant(k, qcfg.kv_group)
            v = ref.kv_fake_quant(v, qcfg.kv_group)
        # one batched scatter per cache: lane j's row lands at its own
        # position (constant op count in B, unlike per-lane update_slice)
        lanes = jnp.arange(b)
        kcache = kcache.at[i, lanes, lane_pos].set(k[:, 0])
        vcache = vcache.at[i, lanes, lane_pos].set(v[:, 0])
        kf = kcache[i]  # [B,maxT,Hkv,hd]
        vf = vcache[i]
        rep = cfg.n_heads // cfg.n_kv_heads
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(cfg.head_dim)
        valid = (jnp.arange(maxt)[None, :] <= lane_pos[:, None])
        att = jnp.where(valid[:, None, None, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, vf)
        x = x + lin(p + "wo", o.reshape(b, cfg.dim)).reshape(b, 1, cfg.dim)

        h = rmsnorm(x, params[p + "mlp_norm"])
        h2 = h.reshape(b, cfg.dim)
        act = jax.nn.silu(lin(p + "w_gate", h2)) * lin(p + "w_up", h2)
        x = x + lin(p + "w_down", act).reshape(b, 1, cfg.dim)

    x = rmsnorm(x, params["final_norm"])
    logits = (x.reshape(b, cfg.dim) @ params["head"].T).reshape(b, 1, cfg.vocab)
    return logits, kcache, vcache


def loss_fn(params, cfg: ModelConfig, tokens):
    """Next-token cross entropy over [B,T+1] token windows (fp path)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, None, cfg, QuantConfig("fp"), inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


# --------------------------------------------- activation capture (Fig. 7/9)


PROJ_KINDS = ("qkv", "o", "gate_up", "down")


def capture_activations(params, cfg: ModelConfig, tokens):
    """fp32 forward that records the input activation of every linear.

    Returns {proj_kind: [per-layer 2-D activations]} for Figures 7 and 9
    and for SmoothQuant/GPTQ calibration.
    """
    b, t = tokens.shape
    x = params["embed"][tokens]
    cos, sin = rope_cos_sin(cfg, jnp.arange(t))
    acts = {k: [] for k in PROJ_KINDS}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rmsnorm(x, params[p + "attn_norm"])
        h2 = h.reshape(b * t, cfg.dim)
        acts["qkv"].append(h2)
        q = (h2 @ params[p + "wq"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h2 @ params[p + "wk"].T).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        v = (h2 @ params[p + "wv"].T).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = _attention(q, k, v)
        a2 = att.reshape(b * t, cfg.dim)
        acts["o"].append(a2)
        x = x + (a2 @ params[p + "wo"].T).reshape(b, t, cfg.dim)
        h = rmsnorm(x, params[p + "mlp_norm"])
        h2 = h.reshape(b * t, cfg.dim)
        acts["gate_up"].append(h2)
        act = jax.nn.silu(h2 @ params[p + "w_gate"].T) * (h2 @ params[p + "w_up"].T)
        acts["down"].append(act)
        x = x + (act @ params[p + "w_down"].T).reshape(b, t, cfg.dim)
    return acts


def calib_absmax(params, cfg: ModelConfig, tokens) -> Dict[str, jnp.ndarray]:
    """Per-linear input-channel absmax from a calibration batch (SmoothQuant)."""
    acts = capture_activations(params, cfg, tokens)
    out = {}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        qkv = jnp.max(jnp.abs(acts["qkv"][i]), axis=0)
        out[p + "wq"] = qkv
        out[p + "wk"] = qkv
        out[p + "wv"] = qkv
        out[p + "wo"] = jnp.max(jnp.abs(acts["o"][i]), axis=0)
        gu = jnp.max(jnp.abs(acts["gate_up"][i]), axis=0)
        out[p + "w_gate"] = gu
        out[p + "w_up"] = gu
        out[p + "w_down"] = jnp.max(jnp.abs(acts["down"][i]), axis=0)
    return out
