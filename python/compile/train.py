"""Build-time training of the tiny LLaMA-style model on the synthetic corpus.

Hand-rolled AdamW (optax is not available in this environment).  Runs once
under ``make artifacts``; the trained weights are exported to
artifacts/weights.rrsw and re-used by both the PJRT artifacts and the rust
engine.  The loss curve is logged to artifacts/train_log.csv (end-to-end
validation evidence, see EXPERIMENTS.md).
"""

from __future__ import annotations

import functools
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import ModelConfig, init_params, loss_fn


def encode(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)


def batches(tokens: np.ndarray, bs: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=bs)
        yield np.stack([tokens[i : i + seq + 1] for i in idx])


def adamw_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


@functools.partial(jax.jit, static_argnames=("cfg", "wd"))
def train_step(params, opt, tokens, cfg: ModelConfig, lr, wd: float):
    """One AdamW step.  ``lr`` must be a traced scalar (NOT static): the
    cosine schedule changes it every step, and a static lr would force a
    fresh XLA compilation per step, exhausting the LLVM JIT allocator."""
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens)
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.95, 1e-8
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mh = jax.tree_util.tree_map(lambda x: x / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda x: x / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / (jnp.sqrt(vv) + eps) + wd * p),
        params, mh, vh,
    )
    return params, {"m": m, "v": v, "t": t}, loss


def train(
    cfg: ModelConfig,
    steps: int = 400,
    bs: int = 16,
    seq: int = 96,
    lr: float = 3e-3,
    wd: float = 0.01,
    seed: int = 1234,
    log_every: int = 20,
) -> Tuple[dict, list, str, str]:
    """Returns (params, loss_log, train_text, val_text)."""
    train_text, val_text, kb = data.build_corpus(seed=seed)
    toks = encode(train_text)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    log = []
    t0 = time.time()
    for step, batch in enumerate(batches(toks, bs, seq, steps, seed)):
        # cosine decay with short warmup
        warm = min(1.0, (step + 1) / 20)
        cos = 0.5 * (1 + np.cos(np.pi * step / steps))
        cur_lr = lr * warm * (0.1 + 0.9 * cos)
        params, opt, loss = train_step(params, opt, jnp.asarray(batch), cfg,
                                       jnp.float32(cur_lr), wd)
        if step % log_every == 0 or step == steps - 1:
            log.append((step, float(loss), time.time() - t0))
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    return params, log, train_text, val_text


def finetune(
    params,
    cfg: ModelConfig,
    train_text: str,
    frozen: list,
    steps: int = 150,
    bs: int = 16,
    seq: int = 96,
    lr: float = 1e-3,
    seed: int = 4321,
):
    """Finetune around frozen (outlier-carrying) tensors.

    Used to build the per-profile model variants: after
    outliers.inject_uncompensated, the network re-learns to use the
    amplified channels/rows, producing a healthy fp model with genuine
    activation outliers (see DESIGN.md section 2).
    """
    toks = encode(train_text)
    frozen_vals = {k: params[k] for k in frozen}
    opt = adamw_init(params)
    last = None
    for step, batch in enumerate(batches(toks, bs, seq, steps, seed)):
        warm = min(1.0, (step + 1) / 10)
        params, opt, loss = train_step(
            params, opt, jnp.asarray(batch), cfg, jnp.float32(lr * warm), 0.01
        )
        params = dict(params)
        params.update(frozen_vals)  # re-pin the outlier tensors
        last = float(loss)
        if step % 50 == 0:
            print(f"  finetune step {step} loss {last:.4f}", flush=True)
    return params, last


def eval_nll(params, cfg: ModelConfig, text: str, seq: int = 96,
             max_windows: int = 32, seed: int = 7) -> float:
    """Teacher-forced mean NLL (nats/byte) on held-out text."""
    toks = encode(text)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(toks) - seq - 1, size=max_windows)
    batch = np.stack([toks[i : i + seq + 1] for i in idx])
    return float(loss_fn(params, cfg, jnp.asarray(batch)))
