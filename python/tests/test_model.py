"""L2 model tests: shapes, variants, decode consistency, KV quantization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig, QuantConfig, decode_step, forward, init_params,
    prepare_weights, calib_absmax, capture_activations, loss_fn,
)

CFG = ModelConfig(n_layers=2)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, 255, size=(2, 16), dtype=np.int32))


ALL_VARIANTS = [
    ("fp", 16, 16), ("rtn", 4, 4), ("sq", 4, 16),
    ("rs", 4, 16), ("quarot", 4, 4), ("rrs", 4, 4),
]


@pytest.mark.parametrize("variant,wb,kb", ALL_VARIANTS)
def test_forward_shapes(params, tokens, variant, wb, kb):
    q = QuantConfig(variant, w_bits=wb, kv_bits=kb, group=32)
    prep = prepare_weights(params, CFG, q)
    lg = forward(params, prep, CFG, q, tokens)
    assert lg.shape == (2, 16, CFG.vocab)
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("variant,wb,kb", [("fp", 16, 16), ("rtn", 4, 4),
                                           ("quarot", 4, 4)])
def test_decode_matches_prefill_rowlocal(params, tokens, variant, wb, kb):
    """Row-local quant variants must produce identical prefill/decode."""
    q = QuantConfig(variant, w_bits=wb, kv_bits=kb, group=32)
    prep = prepare_weights(params, CFG, q) if variant != "fp" else None
    lg = forward(params, prep, CFG, q, tokens)
    b, t = tokens.shape
    kc = jnp.zeros((CFG.n_layers, b, 32, CFG.n_kv_heads, CFG.head_dim))
    vc = jnp.zeros_like(kc)
    outs = []
    for i in range(t):
        lgt, kc, vc = decode_step(params, prep, CFG, q, tokens[:, i:i+1],
                                  kc, vc, jnp.asarray([i], jnp.int32))
        outs.append(lgt)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(lg),
                               rtol=1e-3, atol=1e-4)


def test_decode_per_lane_positions(params, tokens):
    """Lanes at unequal positions in ONE decode call must reproduce the
    position-aligned runs exactly (the resident-lane serving contract:
    idle lanes write a garbage row at their next append slot, which the
    per-lane causal mask keeps invisible)."""
    q = QuantConfig("fp")
    b, t = tokens.shape
    lag = 3  # lane 1 trails lane 0 by `lag` decode steps
    kc0 = jnp.zeros((CFG.n_layers, b, 32, CFG.n_kv_heads, CFG.head_dim))
    vc0 = jnp.zeros_like(kc0)

    def aligned_run(row):
        """Both lanes decode the same row with a uniform position."""
        kc, vc = kc0, vc0
        outs = []
        toks = jnp.stack([row, row])
        for i in range(t):
            lgt, kc, vc = decode_step(params, None, CFG, q, toks[:, i:i+1],
                                      kc, vc, jnp.full((b,), i, jnp.int32))
            outs.append(np.asarray(lgt[0, 0]))
        return outs

    ref0 = aligned_run(tokens[0])
    ref1 = aligned_run(tokens[1])

    # staggered run: lane 1 idles (token 0 written at its next append
    # position 0, masked out) while lane 0 consumes its first `lag`
    # tokens, then both lanes decode their own streams at unequal pos
    kc, vc = kc0, vc0
    out0, out1 = [], []
    for i in range(t + lag):
        # idle convention (rust resident lanes): token 0 written at the
        # lane's next append position, invisible behind the causal mask
        p0, p1 = min(i, t), max(i - lag, 0)
        tok0 = tokens[0, i] if i < t else jnp.int32(0)
        tok1 = tokens[1, i - lag] if i >= lag else jnp.int32(0)
        step_t = jnp.asarray([[tok0], [tok1]], jnp.int32)
        step_p = jnp.asarray([p0, p1], jnp.int32)
        lgt, kc, vc = decode_step(params, None, CFG, q, step_t, kc, vc, step_p)
        if i < t:
            out0.append(np.asarray(lgt[0, 0]))
        if i >= lag:
            out1.append(np.asarray(lgt[1, 0]))

    for i in range(t):
        np.testing.assert_array_equal(out0[i], ref0[i])
        np.testing.assert_array_equal(out1[i], ref1[i])


def test_quant_degrades_gracefully(params, tokens):
    """INT4 logits stay correlated with fp logits (not garbage)."""
    fp = np.asarray(forward(params, None, CFG, QuantConfig("fp"), tokens))
    for v, wb in [("rrs", 4), ("quarot", 4)]:
        # group=1 (exact runtime scale); random untrained weights are the
        # worst case for INT4, so the bar is correlation, not match
        q = QuantConfig(v, w_bits=wb, kv_bits=16, group=1)
        prep = prepare_weights(params, CFG, q)
        lg = np.asarray(forward(params, prep, CFG, q, tokens))
        corr = np.corrcoef(fp.ravel(), lg.ravel())[0, 1]
        assert corr > 0.85, f"{v}: corr={corr}"


def test_kv4_close_to_kv16(params, tokens):
    q16 = QuantConfig("rtn", w_bits=4, kv_bits=16)
    q4 = QuantConfig("rtn", w_bits=4, kv_bits=4, kv_group=16)
    prep = prepare_weights(params, CFG, q16)
    a = np.asarray(forward(params, prep, CFG, q16, tokens))
    b = np.asarray(forward(params, prep, CFG, q4, tokens))
    # KV4 perturbs but does not destroy
    assert np.abs(a - b).max() < 0.5 * np.abs(a).max()


def test_capture_activations_shapes(params, tokens):
    acts = capture_activations(params, CFG, tokens)
    n = tokens.shape[0] * tokens.shape[1]
    assert len(acts["qkv"]) == CFG.n_layers
    assert acts["qkv"][0].shape == (n, CFG.dim)
    assert acts["down"][0].shape == (n, CFG.ffn)


def test_calib_absmax_covers_all_linears(params, tokens):
    am = calib_absmax(params, CFG, tokens)
    assert len(am) == 7 * CFG.n_layers
    for k, v in am.items():
        assert (np.asarray(v) > 0).all(), k


def test_loss_finite_and_learns(params):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 255, size=(2, 17), dtype=np.int32))
    l = float(loss_fn(params, CFG, toks))
    assert np.isfinite(l) and l < 12.0


def test_sq_uses_calibration(params, tokens):
    """SmoothQuant with real calib != SmoothQuant with unit scales."""
    am = calib_absmax(params, CFG, tokens)
    q = QuantConfig("sq", w_bits=4)
    prep_cal = prepare_weights(params, CFG, q, calib_absmax=am)
    prep_unit = prepare_weights(params, CFG, q)
    a = np.asarray(forward(params, prep_cal, CFG, q, tokens))
    b = np.asarray(forward(params, prep_unit, CFG, q, tokens))
    assert np.abs(a - b).max() > 1e-6
