"""Corpus/QA generator determinism + .rrsw container round-trip + AOT lowering."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, io_rrsw
from compile.model import ModelConfig, QuantConfig, forward, init_params
from compile import outliers


class TestData:
    def test_corpus_deterministic(self):
        a = data.build_corpus(seed=7)
        b = data.build_corpus(seed=7)
        assert a[0] == b[0] and a[1] == b[1]

    def test_corpus_split_disjoint_lengths(self):
        train, val, _ = data.build_corpus()
        assert len(train) > 10 * len(val) > 0

    def test_corpus_is_ascii(self):
        train, val, _ = data.build_corpus()
        assert max(train.encode()) < 128 and max(val.encode()) < 128

    def test_qa_tasks_valid(self):
        _, _, kb = data.build_corpus()
        tasks = data.build_qa_tasks(kb, n_per_task=50)
        assert set(tasks) == {"boolq", "obqa", "arc_e", "arc_c"}
        for name, items in tasks.items():
            assert len(items) == 50
            for it in items:
                assert 0 <= it["answer"] < len(it["candidates"])
                assert len(set(it["candidates"])) == len(it["candidates"])

    def test_qa_answers_consistent_with_kb(self):
        _, _, kb = data.build_corpus()
        tasks = data.build_qa_tasks(kb, n_per_task=20)
        for it in tasks["obqa"]:
            ent = it["prompt"].split()[0]
            gold = it["candidates"][it["answer"]].strip(" .")
            assert gold == kb.animal[ent]


class TestRrsw:
    def test_roundtrip(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "a": rng.normal(size=(3, 4)).astype(np.float32),
            "b": rng.integers(-7, 8, size=(2, 5)).astype(np.int8),
            "c": np.arange(6, dtype=np.int32).reshape(2, 3),
            "scalarish": np.array([1.5], dtype=np.float32),
        }
        p = str(tmp_path / "t.rrsw")
        io_rrsw.write_rrsw(p, tensors)
        back = io_rrsw.read_rrsw(p)
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    def test_rejects_bad_magic(self, tmp_path):
        p = str(tmp_path / "bad.rrsw")
        with open(p, "wb") as f:
            f.write(b"NOTRRSW")
        with pytest.raises(AssertionError):
            io_rrsw.read_rrsw(p)


class TestOutlierInjection:
    def test_base_profile_identity(self):
        cfg = ModelConfig(n_layers=1)
        params = init_params(cfg, jax.random.PRNGKey(0))
        out = outliers.inject(params, outliers.PROFILES["base"])
        for k in params:
            np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(params[k]))

    def test_injection_creates_channel_outliers(self):
        cfg = ModelConfig(n_layers=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prof = outliers.PROFILES["llama3-like"]
        inj = outliers.inject(params, prof)
        g0 = np.asarray(params["layers.0.attn_norm"])
        g1 = np.asarray(inj["layers.0.attn_norm"])
        assert abs((g1 / g0).max() - prof.channel_gain) < 1e-3

    def test_injection_profiles_ordered_by_severity(self):
        """Stronger profiles produce higher kurtosis activations."""
        cfg = ModelConfig(n_layers=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, 255, size=(2, 32), dtype=np.int32))
        mus = {}
        from compile.kernels import ref as R
        from compile.model import capture_activations
        for name in ("base", "llama2-like", "llama3-70b-like"):
            inj = outliers.inject(params, outliers.PROFILES[name])
            acts = capture_activations(inj, cfg, toks)
            mus[name] = float(np.mean(np.asarray(
                R.smoothness_mu(jnp.asarray(acts["qkv"][1])))))
        assert mus["base"] < mus["llama2-like"] < mus["llama3-70b-like"]


class TestAotLowering:
    def test_hlo_text_contains_constants(self):
        """Lowered text must NOT elide weights as `constant({...})`."""
        from compile.aot import to_hlo_text
        w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                        dtype=jnp.float32)

        def fn(x):
            return (x @ w.T,)

        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((4, 64), jnp.float32))
        text = to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "constant({...})" not in text

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(os.path.dirname(__file__),
                                        "../../artifacts/manifest.json")),
        reason="artifacts not built")
    def test_manifest_graphs_exist(self):
        import json
        root = os.path.join(os.path.dirname(__file__), "../../artifacts")
        with open(os.path.join(root, "manifest.json")) as f:
            man = json.load(f)
        for g, info in man["graphs"].items():
            assert os.path.exists(os.path.join(root, info["file"])), g
