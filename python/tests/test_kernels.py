"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes and asserts allclose against ref - this is
the core correctness signal for the compute hot-spot.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hadamard, quant, ref, rrs_gemm

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).normal(size=shape) * scale).astype(
        np.float32
    )


pow2 = st.sampled_from([32, 64, 128, 256])


class TestQuantKernel:
    @given(n=st.sampled_from([1, 2, 8, 16, 24]), k=pow2,
           seed=st.integers(0, 10_000), scale=st.sampled_from([0.01, 1.0, 50.0]))
    def test_matches_ref(self, n, k, seed, scale):
        x = rand((n, k), seed, scale)
        q1, s1 = quant.quant_per_token(jnp.asarray(x))
        q2, s2 = ref.quant_per_token(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)

    @given(n=st.sampled_from([4, 8]), k=pow2, seed=st.integers(0, 100))
    def test_roundtrip_error_bound(self, n, k, seed):
        """|x - dq(q(x))| <= scale/2 + eps, elementwise (RTN property)."""
        x = rand((n, k), seed)
        q, s = quant.quant_per_token(jnp.asarray(x))
        xr = np.asarray(quant.dequant_per_token(q, s))
        bound = np.asarray(s) / 2 + 1e-6
        assert (np.abs(xr - x) <= bound).all()

    def test_codes_in_range(self):
        x = rand((8, 64), 1, 100.0)
        q, _ = quant.quant_per_token(jnp.asarray(x))
        q = np.asarray(q)
        assert q.min() >= -7 and q.max() <= 7
        # absmax element hits +-7 exactly
        assert (np.abs(q).max(axis=1) == 7).all()


class TestHadamardKernel:
    @given(n=st.sampled_from([1, 8, 16]), k=pow2, seed=st.integers(0, 1000))
    def test_matches_dense(self, n, k, seed):
        x = rand((n, k), seed)
        want = x @ ref.hadamard(k)
        got = np.asarray(hadamard.rotate(jnp.asarray(x)))
        np.testing.assert_allclose(got, want, atol=1e-4)

    @given(n=st.sampled_from([8, 16]), k=pow2, seed=st.integers(0, 1000))
    def test_fwht_variant_matches(self, n, k, seed):
        x = rand((n, k), seed)
        a = np.asarray(hadamard.rotate(jnp.asarray(x)))
        b = np.asarray(hadamard.rotate_fwht(jnp.asarray(x)))
        np.testing.assert_allclose(a, b, atol=1e-4)

    @given(k=pow2)
    def test_involution(self, k):
        """Sylvester Hadamard is symmetric: rotate twice == identity."""
        x = rand((8, k), 3)
        y = np.asarray(hadamard.rotate(hadamard.rotate(jnp.asarray(x))))
        np.testing.assert_allclose(y, x, atol=1e-4)

    @given(k=pow2, seed=st.integers(0, 50))
    def test_norm_preserved(self, k, seed):
        x = rand((4, k), seed)
        y = np.asarray(ref.rotate(jnp.asarray(x)))
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=1), np.linalg.norm(x, axis=1), rtol=1e-4
        )


class TestRsGemmKernel:
    @given(
        n=st.sampled_from([8, 16]),
        k=st.sampled_from([64, 128, 256]),
        m=st.sampled_from([32, 64, 128]),
        group=st.sampled_from([1, 16, 32, 64]),
        seed=st.integers(0, 1000),
    )
    def test_matches_ref(self, n, k, m, group, seed):
        x = rand((n, k), seed)
        w = rand((m, k), seed + 1)
        wq, sw = ref.quant_per_channel_w(jnp.asarray(w))
        got = np.asarray(rrs_gemm.rs_gemm(jnp.asarray(x), wq, sw, group=group))
        want = np.asarray(
            ref.gemm_rs(jnp.asarray(x), jnp.asarray(w), group=group,
                        wq_pre=(wq, sw))
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    @given(seed=st.integers(0, 500), group=st.sampled_from([32, 128]))
    def test_rrs_matches_ref(self, seed, group):
        x = rand((16, 128), seed)
        w = rand((64, 128), seed + 7)
        wr = ref.rotate(jnp.asarray(w))
        wq, sw = ref.quant_per_channel_w(wr)
        got = np.asarray(rrs_gemm.rrs_gemm(jnp.asarray(x), wq, sw, group=group))
        want = np.asarray(ref.gemm_rrs(jnp.asarray(x), jnp.asarray(w), group=group))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    def test_channel_outliers_smoothed(self):
        """RS beats plain RTN on activations with channel-wise outliers.

        Compared under A4W16 (the paper's Fig. 3 setting) so the shared
        weight-quantization error does not mask the activation effect.
        """
        rng = np.random.default_rng(0)
        x = rng.normal(size=(32, 128)).astype(np.float32)
        # channel-wise outliers are *consistent* across tokens (paper Fig 2c:
        # "a collection of vectors with the same direction"); that is what
        # makes channel-wise smoothing exact.
        x[:, 5] = 100.0 * np.sign(rng.normal(size=32)) * (1 + 0.05 * rng.normal(size=32))
        x[:, 60] = -50.0 * (1 + 0.05 * rng.normal(size=32))
        w = rand((64, 128), 1)
        y_fp = x @ w.T
        y_rtn = np.asarray(ref.gemm_rtn_a4w16(jnp.asarray(x), jnp.asarray(w)))
        y_rs = np.asarray(ref.gemm_rs_a4w16(jnp.asarray(x), jnp.asarray(w), group=1))
        err = lambda y: np.abs(y - y_fp).mean()
        assert err(y_rs) < 0.4 * err(y_rtn)

    def test_spike_outliers_need_rotation(self):
        """Victim effect: spikes hurt RS; RRS recovers (paper Fig. 1c/5).

        A4W16 so the (identical) weight-quant error does not mask the
        activation-side effect.
        """
        rng = np.random.default_rng(0)
        x = rng.normal(size=(64, 128)).astype(np.float32)
        n_spikes = 8
        chans = rng.choice(128, size=n_spikes, replace=False)
        for t, c in enumerate(chans):
            x[t, c] = 1000.0  # spike tokens stretch channel scales
        w = rand((64, 128), 1)
        y_fp = x @ w.T
        y_rs = np.asarray(ref.gemm_rs_a4w16(jnp.asarray(x), jnp.asarray(w), group=1))
        y_rrs = np.asarray(ref.gemm_rrs_a4w16(jnp.asarray(x), jnp.asarray(w), group=1))
        # victims = the NORMAL tokens (paper 2.2); their error under RS
        # grows with spike count while RRS stays flat
        err = lambda y: np.abs(y - y_fp)[n_spikes:].mean()
        assert err(y_rrs) < 0.7 * err(y_rs)

    @given(group=st.sampled_from([1, 32, 128]))
    def test_perm_is_lossless_reordering(self, group):
        """The reorder permutation never changes the exact product, only
        the grouping quality: summing over permuted channels is exact."""
        x = rand((8, 128), 2)
        w = rand((32, 128), 3)
        # with float32 weights (no weight quant), RS at group=1 equals
        # quantizing X/s then rescaling - independent of permutation order
        y1 = np.asarray(ref.gemm_rs(jnp.asarray(x), jnp.asarray(w), group=group))
        assert np.isfinite(y1).all()


class TestSubChannel:
    @given(seed=st.integers(0, 200), group=st.sampled_from([16, 32, 64]))
    def test_subchannel_beats_perchannel_with_outliers(self, seed, group):
        x = rand((16, 128), seed)
        x[:, 3] *= 80.0
        w = rand((32, 128), seed + 1)
        y_fp = x @ w.T
        y_pc = np.asarray(ref.gemm_a4w4_per_channel(jnp.asarray(x), jnp.asarray(w)))
        y_sc = np.asarray(ref.gemm_a4w4_sub_channel(jnp.asarray(x), jnp.asarray(w), group))
        assert np.abs(y_sc - y_fp).mean() <= np.abs(y_pc - y_fp).mean()


class TestKvQuant:
    @given(seed=st.integers(0, 100), group=st.sampled_from([16, 32, 64]))
    def test_roundtrip_bound(self, seed, group):
        x = rand((4, 8, 2, 64), seed)
        y = np.asarray(ref.kv_fake_quant(jnp.asarray(x), group))
        # groupwise absmax/7/2 bound
        assert np.abs(y - x).max() <= np.abs(x).max() / 7 / 2 + 1e-5
