"""Method-level properties: GPTQ, SpinQuant, SmoothQuant, paper invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import gptq, spinquant
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def correlated_calib(n, k, seed=0):
    """Calibration activations with channel structure (like LM residuals)."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(n, k)).astype(np.float32)
    gains = np.exp(rng.normal(size=k)).astype(np.float32)
    return base * gains[None, :]


class TestGptq:
    def test_beats_rtn_on_calib(self):
        rng = np.random.default_rng(0)
        w = rng.normal(size=(32, 64)).astype(np.float32)
        x = correlated_calib(256, 64)
        wq_g, s_g = gptq.gptq_quantize(w, x)
        wq_r, s_r = (np.asarray(a) for a in ref.quant_per_channel_w(jnp.asarray(w)))
        e_g = gptq.gptq_layer_error(w, wq_g, s_g, x)
        e_r = gptq.gptq_layer_error(w, wq_r, s_r, x)
        assert e_g <= e_r * 1.001, (e_g, e_r)

    def test_codes_in_range(self):
        rng = np.random.default_rng(1)
        w = rng.normal(size=(16, 32)).astype(np.float32)
        x = correlated_calib(64, 32, 1)
        wq, s = gptq.gptq_quantize(w, x)
        assert wq.dtype == np.int8
        assert wq.min() >= -7 and wq.max() <= 7
        assert (s > 0).all()

    @given(seed=st.integers(0, 100))
    def test_deterministic(self, seed):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(8, 32)).astype(np.float32)
        x = correlated_calib(64, 32, seed)
        a = gptq.gptq_quantize(w, x)
        b = gptq.gptq_quantize(w, x)
        np.testing.assert_array_equal(a[0], b[0])


class TestSpinQuant:
    def test_cayley_orthogonal(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32) * 0.1)
        r = np.asarray(spinquant.cayley(a))
        assert spinquant.rotation_orthogonality_error(r) < 1e-4

    def test_training_reduces_loss(self):
        rng = np.random.default_rng(0)
        xs = [correlated_calib(128, 32, i) for i in range(2)]
        ws = [rng.normal(size=(16, 32)).astype(np.float32) for _ in range(2)]
        r, log = spinquant.train_rotation(xs, ws, 32, steps=60, lr=3e-3)
        assert log[-1] < log[0]
        assert spinquant.rotation_orthogonality_error(r) < 1e-3


class TestSmoothQuant:
    def test_scale_formula(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
        am = jnp.asarray(np.abs(rng.normal(size=32)).astype(np.float32) + 0.1)
        s = np.asarray(ref.smoothquant_scales(am, w, alpha=0.5))
        wmax = np.abs(np.asarray(w)).max(axis=0)
        want = np.sqrt(np.asarray(am)) / np.sqrt(wmax)
        np.testing.assert_allclose(s, np.maximum(want, 1e-8), rtol=1e-4)

    def test_unmatched_calibration_fails(self):
        """Paper Fig. 1a: calib scales from the wrong distribution do not
        smooth a shifted outlier pattern; runtime smooth does."""
        rng = np.random.default_rng(0)
        x_cal = rng.normal(size=(64, 128)).astype(np.float32)
        x_cal[:, 10] *= 100.0  # calib outlier at channel 10
        x_run = rng.normal(size=(64, 128)).astype(np.float32)
        x_run[:, 90] *= 100.0  # runtime outlier moved to channel 90
        w = rng.normal(size=(64, 128)).astype(np.float32)
        s = ref.smoothquant_scales(
            jnp.max(jnp.abs(jnp.asarray(x_cal)), axis=0), jnp.asarray(w))
        y_fp = x_run @ w.T
        y_sq = np.asarray(ref.gemm_smoothquant(jnp.asarray(x_run), jnp.asarray(w), s))
        y_rs = np.asarray(ref.gemm_rs(jnp.asarray(x_run), jnp.asarray(w), group=1))
        err = lambda y: np.abs(y - y_fp).mean()
        assert err(y_rs) < 0.5 * err(y_sq)


class TestPaperInvariants:
    """Quantified claims from Sections 2-3 of the paper."""

    def test_rotation_spreads_spikes(self):
        """Eq. 4: a token with one spike becomes near-constant magnitude."""
        k = 128
        t = np.full((1, k), 0.01, dtype=np.float32)
        t[0, 17] = 100.0
        tr = np.asarray(ref.rotate(jnp.asarray(t)))
        # all rotated entries ~ |O|/sqrt(K)
        expect = 100.0 / np.sqrt(k)
        assert np.abs(np.abs(tr) - expect).max() < 1.0

    def test_rotation_keeps_channelwise_consistency(self):
        """Fig. 2c: rank-1-ish channel-outlier activations stay channel-
        consistent after rotation (rotation maps columns together)."""
        rng = np.random.default_rng(0)
        token_gain = np.abs(rng.normal(size=(64, 1))).astype(np.float32) + 0.5
        direction = rng.normal(size=(1, 128)).astype(np.float32)
        x = token_gain * direction  # rank-1: same direction every token
        xr = np.asarray(ref.rotate(jnp.asarray(x)))
        # still rank-1 => channel-wise consistent after rotation
        s = np.linalg.svd(xr, compute_uv=False)
        assert s[1] < 1e-3 * s[0]

    def test_victim_effect(self):
        """Appendix A.1 protocol (eq. 8-10): normal tokens = all-ones; spike
        tokens stretch per-channel smoothing scales; u = max/RMS of the
        smoothed normal token.  Many spikes -> many RS victims -> u grows;
        rotation spreads the spikes into a consistent scale -> u stays ~1.
        """
        rng = np.random.default_rng(1)
        k, n_spikes = 128, 16
        x = rng.normal(size=(64, k)).astype(np.float32)
        chans = rng.choice(k, size=n_spikes, replace=False)
        for t, c in enumerate(chans):
            x[t, c] = 1000.0  # spike tokens
        # u = mu(1 / scale): smoothness of an all-ones normal token after
        # division by the smoothing scales (eq. 9-10)
        s = np.asarray(ref.rs_channel_scale(jnp.asarray(x)))
        u_rs = float(np.asarray(
            ref.smoothness_mu(jnp.asarray(1.0 / s[None, :])))[0])
        sr = np.asarray(ref.rs_channel_scale(ref.rotate(jnp.asarray(x))))
        u_rrs = float(np.asarray(
            ref.smoothness_mu(jnp.asarray(1.0 / sr[None, :])))[0])
        assert u_rrs < u_rs

    @given(seed=st.integers(0, 50))
    def test_rotation_lowers_mu_for_llm_like(self, seed):
        """Fig. 2b: activations with structure get smoother under rotation
        (in expectation over tokens)."""
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(64, 128)).astype(np.float32)
        x[:, rng.integers(0, 128, 4)] *= 50.0  # channel outliers
        mu_x = np.asarray(ref.smoothness_mu(jnp.asarray(x))).mean()
        mu_r = np.asarray(ref.smoothness_mu(ref.rotate(jnp.asarray(x)))).mean()
        assert mu_r < mu_x
