//! End-to-end serving driver (the repo's headline validation run):
//! start the coordinator on the trained model under A4W4KV4 RRS, fire a
//! batch of concurrent generation requests through the real TCP front-end
//! (every third client streams token frames; odd clients exercise the
//! sampler: temperature + top-p with a fixed seed) and report per-request
//! latency + aggregate throughput; then rerun a shared-prefix workload
//! over the paged KV pool and report the prefix-cache hit rate + peak
//! pool occupancy.
//!
//!     make artifacts && cargo run --release --example serve_batch
//!
//! Results are recorded in EXPERIMENTS.md ("End-to-end serving run").

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use rrs::coordinator::{server, Coordinator, RustServeEngine, SchedulerConfig};
use rrs::kvpool::PagedEngine;
use rrs::model::sampler::Sampling;
use rrs::model::{tokenizer, EngineConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::runtime::Artifacts;
use rrs::util::json::Json;

fn main() -> anyhow::Result<()> {
    // demo the quant-health probes unless the caller set a rate already
    // (RRS_OBS_SAMPLE=0 disables; see README "Observability")
    if std::env::var("RRS_OBS_SAMPLE").is_err() {
        rrs::obs::set_sample_every(16);
    }
    let artifacts = Artifacts::load("artifacts")?;
    let weights = Weights::load(artifacts.weights_path(), &artifacts.model)?;
    let val = artifacts.val_text()?;
    let toks = tokenizer::encode(&val);
    let calib: Vec<u32> =
        (0..8).flat_map(|i| toks[i * 64..i * 64 + 64].to_vec()).collect();

    let ecfg = EngineConfig {
        method: Method::Rrs,
        scheme: Scheme::A4W4KV4,
        group: 128,
        ..Default::default()
    };
    let model = QuantModel::prepare(
        &weights, &artifacts.model, &ecfg, Some(&calib), None)?;
    println!("engine: {} (rust INT4 path, fused RS GEMM)", ecfg.label());

    let coord = Arc::new(Coordinator::start(
        RustServeEngine::new(model),
        SchedulerConfig { max_batch: 8, queue_capacity: 128, ..Default::default() },
    )?);

    // bind the TCP server on an ephemeral port in a background thread
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let port = listener.local_addr()?.port();
    drop(listener); // server re-binds; tiny race acceptable for the demo
    let c2 = coord.clone();
    std::thread::spawn(move || {
        let _ = server::serve(c2, &format!("127.0.0.1:{port}"));
    });
    std::thread::sleep(std::time::Duration::from_millis(150));
    println!("server on 127.0.0.1:{port}");

    // 24 concurrent clients over the wire
    let prompts = [
        "arlo is", "brin the", "count: 2 3 4", "abc: a b c",
        "senna likes", "at the lake", "double: 3 6", "mira is a",
    ];
    let n_clients = 24;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..n_clients {
        let prompt = prompts[i % prompts.len()].to_string();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(String, Json)> {
            let stream = TcpStream::connect(("127.0.0.1", port))?;
            let mut w = stream.try_clone()?;
            let mut r = BufReader::new(stream);
            // every third client streams token frames; odd clients run
            // seeded temperature + nucleus sampling instead of greedy
            let stream_on = i % 3 == 0;
            let sampled = if i % 2 == 1 {
                format!(r#", "temperature": 0.8, "top_p": 0.95, "seed": {i}"#)
            } else {
                String::new()
            };
            let req = format!(
                r#"{{"prompt": "{prompt}", "max_tokens": 24, "stop": ".", "stream": {stream_on}{sampled}}}"#
            );
            w.write_all(req.as_bytes())?;
            w.write_all(b"\n")?;
            let mut line = String::new();
            loop {
                line.clear();
                if r.read_line(&mut line)? == 0 {
                    anyhow::bail!("server closed the connection");
                }
                let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!(e))?;
                // streamed clients drain token frames to the terminal
                // response; blocking clients get it in one line
                if !stream_on
                    || j.get("done").and_then(Json::as_bool) == Some(true)
                    || j.get("error").is_some()
                {
                    return Ok((prompt, j));
                }
            }
        }));
    }
    let mut total_tokens = 0usize;
    let mut lats = Vec::new();
    for h in handles {
        let (prompt, resp) = h.join().unwrap()?;
        let text = resp.get("text").and_then(Json::as_str).unwrap_or("<err>");
        let tokens = resp.get("tokens").and_then(Json::as_usize).unwrap_or(0);
        let ms = resp.get("total_ms").and_then(Json::as_f64).unwrap_or(0.0);
        total_tokens += tokens;
        lats.push(ms as f32);
        println!("  {:<14} -> {:<28} {:>3} tok {:>8.1} ms",
                 format!("{prompt:?}"), format!("{text:?}"), tokens, ms);
    }
    let wall = t0.elapsed().as_secs_f32();
    let s = rrs::util::stats::Summary::of(&lats);
    println!("\n== serve_batch summary ==");
    println!("requests:        {n_clients}");
    println!("wall time:       {wall:.2} s");
    println!("throughput:      {:.1} tokens/s", total_tokens as f32 / wall);
    println!("latency p50/p90: {:.1} / {:.1} ms", s.p50, s.p90);
    let m = coord.metrics.snapshot_json();
    println!("coordinator:     {}", m.dump());

    // sampled per-layer quantization health (the paper's runtime
    // statistics, measured during the serve run above)
    let health = rrs::obs::health::snapshot();
    if !health.is_empty() {
        let period = rrs::obs::sample_period();
        println!("\n== quant health (sampled, period {period}) ==");
        println!(
            "{:<12} {:>7} {:>12} {:>7} {:>9} {:>10}",
            "layer", "probes", "channel_max", "spike", "kurtosis", "clip_rate"
        );
        for (layer, h) in &health {
            println!(
                "{layer:<12} {:>7} {:>12.2} {:>7.2} {:>9.2} {:>10.4}",
                h.probes, h.channel_max, h.spike_ratio, h.kurtosis, h.clip_rate
            );
        }
    }

    // shut the server down over the wire
    let stream = TcpStream::connect(("127.0.0.1", port))?;
    let mut w = stream.try_clone()?;
    w.write_all(b"{\"cmd\": \"shutdown\"}\n")?;

    // ── Phase 2: shared-prefix workload over the paged KV pool ──────────
    // N requests over M distinct "system prompts": each request repeats
    // one of M long prefixes + a short unique user suffix, so the pool
    // should prefill each prefix once and serve the rest from the
    // prefix cache.
    let model2 = QuantModel::prepare(
        &weights, &artifacts.model, &ecfg, Some(&calib), None)?;
    let paged = Coordinator::start(
        PagedEngine::new(model2, 256, 16),
        SchedulerConfig { max_batch: 8, queue_capacity: 128, ..Default::default() },
    )?;
    let paged = Arc::new(paged);
    let systems = [
        "rules for the lake house: be kind to arlo and senna. ",
        "counting drills today: 1 2 3 4 5 6 7 8. ",
        "brin the fox guards the door while mira sleeps. ",
        "doubles practice: 1 2, 2 4, 3 6, 4 8. ",
    ];
    let users = ["arlo is", "senna likes", "count: 2 3", "mira is a",
                 "at the lake", "double: 5"];
    let n_requests = 24;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..n_requests {
        let c = paged.clone();
        let prompt = format!(
            "{}{}", systems[i % systems.len()], users[i % users.len()]
        );
        handles.push(std::thread::spawn(move || {
            c.generate(tokenizer::encode(&prompt), 16, Sampling::Greedy, None)
        }));
    }
    let mut ok = 0usize;
    let mut tokens = 0usize;
    for h in handles {
        if let Ok(resp) = h.join().unwrap() {
            ok += 1;
            tokens += resp.tokens.len();
        }
    }
    let wall2 = t0.elapsed().as_secs_f32();
    let m2 = paged.metrics.snapshot_json();
    let pool = m2.get("kv_pool").expect("paged backend exports kv_pool");
    println!("\n== shared-prefix (paged kvpool) summary ==");
    println!("requests:              {ok}/{n_requests} over {} system prompts",
             systems.len());
    println!("throughput:            {:.1} tokens/s", tokens as f32 / wall2);
    println!(
        "prefix-cache hit rate: {:.1}%  ({} tokens reused)",
        100.0 * paged.metrics.prefix_hit_rate(),
        pool.get("prefix_hit_tokens").and_then(Json::as_usize).unwrap_or(0)
    );
    println!(
        "peak pool occupancy:   {}/{} blocks  ({} preemptions, {} evictions)",
        pool.get("blocks_peak").and_then(Json::as_usize).unwrap_or(0),
        pool.get("blocks_total").and_then(Json::as_usize).unwrap_or(0),
        m2.get("preemptions").and_then(Json::as_usize).unwrap_or(0),
        pool.get("evictions").and_then(Json::as_usize).unwrap_or(0),
    );
    Ok(())
}
