//! Reproduce one Table-1 row interactively: perplexity of every method on
//! a chosen outlier profile and scheme.
//!
//!     cargo run --release --example quant_eval -- [profile] [scheme]
//!     (defaults: llama3-like a4w4kv16)

use rrs::eval::perplexity::format_ppl;
use rrs::harness::{table1, Ctx};
use rrs::model::weights::OutlierProfile;
use rrs::model::EngineConfig;
use rrs::quant::{Method, Scheme};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let profile_name = args.get(1).map(|s| s.as_str()).unwrap_or("llama3-like");
    let scheme = match args.get(2).map(|s| s.as_str()).unwrap_or("a4w4kv16") {
        "a4w4kv4" => Scheme::A4W4KV4,
        "a4w16kv16" => Scheme::A4W16KV16,
        _ => Scheme::A4W4KV16,
    };
    let ctx = Ctx::load("artifacts", "reports", false)?;
    let profile = OutlierProfile::builtin(profile_name)
        .ok_or_else(|| anyhow::anyhow!("unknown profile {profile_name}"))?;

    println!("profile: {profile_name}, scheme: {}", scheme.label());
    let fp = ctx.ppl(&profile, &EngineConfig {
        method: Method::Fp,
        scheme: Scheme::FP,
        gptq: false,
        ..Default::default()
    })?;
    println!("  {:<14} ppl {}", "FP16", format_ppl(fp));
    for method in table1::METHODS {
        let ppl = ctx.ppl(&profile, &table1::ecfg_like_table1(method, scheme))?;
        println!("  {:<14} ppl {}", method.name(), format_ppl(ppl));
    }
    Ok(())
}
