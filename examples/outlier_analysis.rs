//! Walk the paper's outlier analysis (Figures 2b, 7, 8, 9) on the trained
//! model: collect real activations, classify outliers, measure smoothness
//! under X / R / RS / RRS, and run the victim-effect Monte Carlo.
//!
//!     cargo run --release --example outlier_analysis

use rrs::eval::smoothness::{
    collect_mu, outlier_histogram, prob_less_smooth_after_rotation, victim_u,
    SmoothMode,
};
use rrs::harness::Ctx;
use rrs::model::engine::capture_activations;
use rrs::model::tokenizer;
use rrs::model::weights::OutlierProfile;
use rrs::util::rng::Pcg;
use rrs::util::stats;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::load("artifacts", "reports", true)?;
    let profile = OutlierProfile::builtin("llama3-70b-like").unwrap();
    let w = ctx.weights_for(&profile)?;
    let toks = tokenizer::encode(&ctx.val_text);
    let acts = capture_activations(&w, &ctx.mcfg, &toks[..192]);

    println!("== outlier analysis on profile '{}' ==\n", profile.name);

    println!("-- Fig 2b: P(token less smooth after rotation)");
    for (name, list) in [("qkv", &acts.qkv), ("down", &acts.down)] {
        let p: Vec<f32> =
            list.iter().map(prob_less_smooth_after_rotation).collect();
        println!("  {name:<6} {:.4}", stats::mean(&p));
    }
    let mut rng = Pcg::new(3);
    let g = rrs::linalg::gemm::Mat::from_vec(
        96, ctx.mcfg.dim, rng.normal_vec(96 * ctx.mcfg.dim));
    println!("  random {:.4}\n", prob_less_smooth_after_rotation(&g));

    println!("-- Fig 7: down-projector magnitude histogram (x token median)");
    let edges = [10.0, 50.0, 100.0, 500.0, 1000.0];
    let mut counts = vec![0usize; edges.len() + 1];
    for a in &acts.down {
        for (c, n) in counts.iter_mut().zip(outlier_histogram(a, &edges)) {
            *c += n;
        }
    }
    println!("  <10x: {}  10-50x: {}  50-100x: {}  100-500x: {}  \
              500-1000x: {}  >=1000x: {}\n",
             counts[0], counts[1], counts[2], counts[3], counts[4], counts[5]);

    println!("-- Fig 8: victim effect u vs #spike tokens (Monte Carlo)");
    for l in [1usize, 2, 8, 32] {
        let mut rs = Vec::new();
        let mut rrs_ = Vec::new();
        for t in 0..32 {
            let mut r1 = Pcg::new(900 + t);
            rs.push(victim_u(ctx.mcfg.dim, 64, l, 1000.0, false, &mut r1));
            let mut r2 = Pcg::new(900 + t);
            rrs_.push(victim_u(ctx.mcfg.dim, 64, l, 1000.0, true, &mut r2));
        }
        println!("  l={l:<3} u_RS={:.3}  u_RRS={:.3}",
                 stats::mean(&rs), stats::mean(&rrs_));
    }
    println!();

    println!("-- Fig 9: mean token mu per projector (X / R / RS / RRS)");
    for (kind, list) in [
        ("QKV ", &acts.qkv), ("O   ", &acts.o),
        ("GtUp", &acts.gate_up), ("Down", &acts.down),
    ] {
        print!("  {kind}");
        for mode in SmoothMode::ALL {
            let mut mus = Vec::new();
            for a in list {
                mus.extend(collect_mu(a, mode));
            }
            print!("  {}={:.2}", mode.name(), stats::mean(&mus));
        }
        println!();
    }
    Ok(())
}
