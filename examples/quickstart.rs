//! Quickstart: load the trained model, prepare it for INT4 inference with
//! Rotated Runtime Smooth, and generate text through the coordinator.
//!
//!     make artifacts && cargo run --release --example quickstart

use rrs::coordinator::{Coordinator, RustServeEngine, SchedulerConfig};
use rrs::model::sampler::Sampling;
use rrs::model::{tokenizer, EngineConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    // 1. load the AOT artifacts (trained weights + manifest)
    let artifacts = Artifacts::load("artifacts")?;
    let weights = Weights::load(artifacts.weights_path(), &artifacts.model)?;

    // 2. offline preparation: GPTQ INT4 weights in the rotated space,
    //    INT4 KV cache, Runtime Smooth group = 128 (the fused-kernel cfg)
    let val = artifacts.val_text()?;
    let calib = tokenizer::encode(&val[..512.min(val.len())]);
    let ecfg = EngineConfig {
        method: Method::Rrs,
        scheme: Scheme::A4W4KV4,
        group: 128,
        ..Default::default()
    };
    let model = QuantModel::prepare(
        &weights, &artifacts.model, &ecfg, Some(&calib), None)?;
    println!("prepared {} for inference", ecfg.label());

    // 3. serve a request through the coordinator
    let coord = Coordinator::start(
        RustServeEngine::new(model), SchedulerConfig::default())?;
    for prompt in ["arlo is", "count: 1 2 3 4", "senna likes"] {
        let resp = coord
            .generate(tokenizer::encode(prompt), 24, Sampling::Greedy,
                      Some(b'.' as u32))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "  {:<20} -> {:?}  ({} tok, {:.1} ms)",
            format!("{prompt:?}"),
            tokenizer::decode(&resp.tokens),
            resp.tokens.len(),
            resp.total_ms
        );
    }
    coord.shutdown();
    Ok(())
}
