#!/usr/bin/env python3
"""Diff freshly-written BENCH_*.json results against the committed
baselines (``git show HEAD:<file>``).

The committed files at the repo root are the perf trajectory. CI runner
throughput is noisy, so numeric drift is *reported*, never failed; hard
failures are structural only:

* a fresh results file is missing entirely (the bench did not run), or
* a numeric key present in the (non-pending) baseline vanished from the
  fresh results (a metric silently stopped being measured).

Baselines carrying ``"pending": true`` are placeholders committed before
any provisioned run recorded real numbers; they auto-accept the fresh
results, which should then be committed to replace them — loudly, so a
placeholder cannot linger unnoticed.  ``--forbid-pending`` upgrades a
pending baseline whose bench *did* run from a warning to a hard failure
(CI uses it: once a runner produced real numbers there is no excuse for
keeping the placeholder).
"""

import json
import subprocess
import sys

DEFAULT_FILES = [
    "BENCH_decode.json",
    "BENCH_gemm.json",
    "BENCH_obs.json",
    "BENCH_serving.json",
    "BENCH_matrix.json",
]


def committed(path):
    p = subprocess.run(
        ["git", "show", f"HEAD:{path}"], capture_output=True, text=True
    )
    if p.returncode != 0:
        return None
    try:
        return json.loads(p.stdout)
    except ValueError:
        return None


def numeric_leaves(prefix, obj, out):
    if isinstance(obj, dict):
        for k, v in obj.items():
            numeric_leaves(f"{prefix}.{k}" if prefix else k, v, out)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            numeric_leaves(f"{prefix}[{i}]", v, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def diff_one(path, failures, forbid_pending=False):
    try:
        with open(path) as f:
            fresh = json.load(f)
    except OSError:
        failures.append(f"{path}: fresh results missing (bench did not write it)")
        return
    except ValueError as e:
        failures.append(f"{path}: fresh results unparseable: {e}")
        return
    base = committed(path)
    if base is None:
        print(f"{path}: no committed baseline; accepting fresh results")
        return
    if base.get("pending"):
        msg = (
            f"{path}: baseline is a PENDING placeholder but the bench ran — "
            "commit the fresh results to replace it"
        )
        if forbid_pending:
            failures.append(msg)
        else:
            print(f"::warning::{msg}")
            print(f"{path}: baseline pending; accepting fresh results as the first real run")
        return
    b_nums, f_nums = {}, {}
    numeric_leaves("", base, b_nums)
    numeric_leaves("", fresh, f_nums)
    missing = sorted(set(b_nums) - set(f_nums))
    if missing:
        failures.append(
            f"{path}: metrics vanished vs baseline: {', '.join(missing[:10])}"
        )
        return
    drifts = []
    for k in sorted(set(b_nums) & set(f_nums)):
        if b_nums[k] == 0:
            continue
        delta = 100.0 * (f_nums[k] - b_nums[k]) / abs(b_nums[k])
        if abs(delta) >= 5.0:
            drifts.append(f"{k}: {b_nums[k]:g} -> {f_nums[k]:g} ({delta:+.1f}%)")
    tag = f"{len(drifts)} metrics drifted >= 5%" if drifts else "within 5% everywhere"
    print(f"{path}: ok vs baseline ({tag})")
    for d in drifts[:20]:
        print(f"    {d}")


def main(argv):
    forbid_pending = "--forbid-pending" in argv
    paths = [a for a in argv if not a.startswith("--")] or DEFAULT_FILES
    failures = []
    for path in paths:
        diff_one(path, failures, forbid_pending=forbid_pending)
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
