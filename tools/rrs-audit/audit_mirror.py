#!/usr/bin/env python3
"""Dependency-free mirror of the `rrs-audit` lint pass.

CI runs the Rust binary (`tools/rrs-audit`); this mirror implements the
*same rules over the same lexer model* so environments without a Rust
toolchain (hermetic containers, pre-commit hooks on minimal images) can
still run the gate.  Rule numbers, messages, and exit codes match the
Rust implementation — `tools/rrs-audit/tests/audit_fixtures.rs` pins the
two against the shared fixture corpus.

Rules (error level, exit 1 on any hit):
  R1 safety-comment      every `unsafe` fn/impl/block carries a
                         `// SAFETY:` justification (same line, or in
                         the comment/attribute block directly above).
  R2 panic-free-serving  no `.unwrap()` / `.expect(` / `panic!` /
                         `unreachable!` / `todo!` / `unimplemented!` in
                         the serving-path allowlist (coordinator/,
                         kvpool/, runtime/, obs/), outside test code.
  R3 ordering-note       every `Ordering::Relaxed` is either a pure
                         counter RMW (fetch_add/sub/max/min) or covered
                         by an `// ORDERING:` note in the enclosing
                         brace scope.
  R4 lock-order          the Mutex acquisition graph (guard held while
                         taking another lock) is acyclic.

Warnings (reported, non-fatal):
  W1 untrusted-indexing  `x[...]` indexing inside protocol-boundary
                         functions (*parse* / *from_json*) in the
                         allowlist without a `// BOUNDS:` note.

Usage: audit_mirror.py [ROOT] [--json]
ROOT defaults to the repo root found by walking up from this file.
"""

import json
import os
import re
import sys

ALLOWLIST = ("coordinator/", "kvpool/", "runtime/", "obs/")

PANIC_PATTERNS = [
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
]

COUNTER_RMW = ("fetch_add", "fetch_sub", "fetch_max", "fetch_min")


class Line:
    __slots__ = ("code", "comment", "open_delta")

    def __init__(self):
        self.code = ""
        self.comment = ""
        self.open_delta = 0


def lex(text):
    """Split each line into code and comment text, stripping string and
    char literals (replaced by `\"\"`) so tokens inside literals never
    match rules.  Tracks block comments and raw strings across lines."""
    lines = []
    state = "code"  # code | block_comment | string | raw_string
    raw_hashes = 0
    for raw in text.split("\n"):
        ln = Line()
        i, n = 0, len(raw)
        while i < n:
            c = raw[i]
            nxt = raw[i + 1] if i + 1 < n else ""
            if state == "block_comment":
                j = raw.find("*/", i)
                if j < 0:
                    ln.comment += raw[i:]
                    i = n
                else:
                    ln.comment += raw[i:j]
                    i = j + 2
                    state = "code"
                continue
            if state == "string":
                if c == "\\":
                    i += 2
                    continue
                if c == '"':
                    state = "code"
                    ln.code += '""'
                i += 1
                continue
            if state == "raw_string":
                if c == '"' and raw[i + 1 : i + 1 + raw_hashes] == "#" * raw_hashes:
                    state = "code"
                    ln.code += '""'
                    i += 1 + raw_hashes
                else:
                    i += 1
                continue
            # state == code
            if c == "/" and nxt == "/":
                ln.comment += raw[i + 2 :]
                i = n
                continue
            if c == "/" and nxt == "*":
                state = "block_comment"
                i += 2
                continue
            if c == "r" and (nxt == '"' or nxt == "#"):
                j = i + 1
                h = 0
                while j < n and raw[j] == "#":
                    h += 1
                    j += 1
                if j < n and raw[j] == '"':
                    state = "raw_string"
                    raw_hashes = h
                    i = j + 1
                    continue
            if c == "b" and nxt == '"':
                state = "string"
                i += 2
                continue
            if c == '"':
                state = "string"
                i += 1
                continue
            if c == "'":
                # char literal vs lifetime: 'a' is a char, 'a (no closing
                # quote right after one item) is a lifetime
                if nxt == "\\":
                    j = raw.find("'", i + 2)
                    i = (j + 1) if j >= 0 else n
                    ln.code += '""'
                    continue
                if i + 2 < n and raw[i + 2] == "'":
                    i += 3
                    ln.code += '""'
                    continue
                ln.code += c
                i += 1
                continue
            ln.code += c
            if c == "{":
                ln.open_delta += 1
            elif c == "}":
                ln.open_delta -= 1
            i += 1
        if state == "string":
            state = "code"  # unterminated; tolerate
        lines.append(ln)
    return lines


def test_regions(lines):
    """Line-index set covered by #[cfg(test)] / #[cfg(loom)]-style items
    (the attribute plus the brace range of the item that follows)."""
    covered = set()
    depth = 0
    depths = []
    for ln in lines:
        depths.append(depth)
        depth += ln.open_delta
    i = 0
    cfg = re.compile(r"#\s*\[\s*cfg\s*\(\s*(all\s*\(\s*)?(test|loom|any\s*\(\s*(test|loom))")
    while i < len(lines):
        if cfg.search(lines[i].code):
            covered.add(i)
            d0 = depths[i]
            j = i
            opened = False
            while j < len(lines):
                covered.add(j)
                if lines[j].open_delta > 0:
                    opened = True
                if opened and depths[j] + lines[j].open_delta <= d0:
                    break
                if not opened and lines[j].code.strip().endswith(";"):
                    break
                j += 1
            i = j + 1
        else:
            i += 1
    return covered


def check_file(relpath, text, graph):
    lines = lex(text)
    tests = test_regions(lines)
    in_allow = any(s in relpath for s in ALLOWLIST)
    errors, warnings = [], []

    depths = []
    d = 0
    for ln in lines:
        depths.append(d)
        d += ln.open_delta

    # R1: unsafe sites need SAFETY:
    unsafe_re = re.compile(r"\bunsafe\b\s*(fn|impl|trait|\{|extern)")
    attr_or_pass = re.compile(
        r"^\s*(#\[|#!\[|$|\}?\s*$|unsafe impl|pub unsafe|pub\(crate\) unsafe)"
    )
    for i, ln in enumerate(lines):
        if i in tests or not unsafe_re.search(ln.code):
            continue
        ok = "SAFETY:" in ln.comment
        j = i - 1
        hops = 0
        while not ok and j >= 0 and hops < 10:
            cj = lines[j]
            if "SAFETY:" in cj.comment:
                ok = True
                break
            stripped = cj.code.strip()
            # allowed pass-through lines: blank/comment-only, attributes,
            # sibling unsafe items (one note may cover a Send+Sync pair),
            # multi-line fn signatures
            if stripped and not attr_or_pass.match(cj.code) and not unsafe_re.search(cj.code):
                break
            j -= 1
            hops += 1
        if not ok:
            errors.append((relpath, i + 1, "R1", "unsafe site without a `// SAFETY:` justification"))

    # R2: no panicking APIs in the serving allowlist
    if in_allow:
        for i, ln in enumerate(lines):
            if i in tests:
                continue
            for pat in PANIC_PATTERNS:
                for m in re.finditer(re.escape(pat), ln.code):
                    if pat == ".expect(" and ln.code[m.start():m.start() + 12] == ".expect_err(":
                        continue
                    errors.append(
                        (relpath, i + 1, "R2", f"panicking `{pat.strip('.')}` on the serving path")
                    )

    # R3: Ordering::Relaxed requires counter RMW or an ORDERING: note.
    # A `// ORDERING:` comment covers the remainder of its brace scope.
    note_stack = []  # depths at which a note is active
    for i, ln in enumerate(lines):
        note_stack = [nd for nd in note_stack if nd <= depths[i]]
        if "ORDERING:" in ln.comment:
            note_stack.append(depths[i])
        if i in tests or "Ordering::Relaxed" not in ln.code:
            continue
        if any(k in ln.code for k in COUNTER_RMW):
            continue
        if "ORDERING:" in ln.comment or note_stack:
            continue
        errors.append(
            (relpath, i + 1, "R3",
             "`Ordering::Relaxed` load/store without an `// ORDERING:` note "
             "(or use a counter RMW)")
        )

    # R4 extraction: lock acquisitions with a guard still held
    lock_re = re.compile(
        r"(?:lock_recover\s*\(\s*&?(?P<a>[A-Za-z_][\w\.]*(?:\(\))?)\s*\)"
        r"|(?P<b>[A-Za-z_][\w\.]*?)\.lock\s*\(\))"
    )
    stem = os.path.basename(relpath).rsplit(".", 1)[0]
    held = []  # (depth, lockname, is_stmt_guard)
    for i, ln in enumerate(lines):
        if i in tests:
            continue
        held = [h for h in held if h[0] <= depths[i]]
        for m in lock_re.finditer(ln.code):
            name = m.group("a") or m.group("b")
            if name.endswith(".lock"):
                name = name[: -len(".lock")]
            canon = f"{stem}.{name}"
            code = ln.code
            stmt_guard = bool(re.search(r"\blet\s+(mut\s+)?\w+\s*=", code))
            for (_, src, sg) in held:
                if sg and src != canon:
                    graph.setdefault(src, set()).add((canon, relpath, i + 1))
            if stmt_guard:
                held.append((depths[i], canon, True))
            # temporaries (`x.lock()...` in one expression) drop at the
            # end of the statement — they never hold across another lock
        # end-of-statement: temporaries die; statement guards persist to
        # end of scope (approximation: `drop(g)` also releases)
        if "drop(" in ln.code:
            dropped = re.findall(r"drop\s*\(\s*(\w+)\s*\)", ln.code)
            if dropped:
                held = [h for h in held if not h[2]] or []
    # W1: indexing in protocol-boundary fns
    if in_allow:
        fn_re = re.compile(r"\bfn\s+(\w*(?:parse|from_json)\w*)")
        idx_re = re.compile(r"\b[a-z_][\w\.]*\[")
        cur_fn_depth = None
        for i, ln in enumerate(lines):
            if i in tests:
                continue
            if cur_fn_depth is not None and depths[i] <= cur_fn_depth and i > 0 and lines[i].code.strip().startswith("}"):
                cur_fn_depth = None
            m = fn_re.search(ln.code)
            if m:
                cur_fn_depth = depths[i]
                continue
            if cur_fn_depth is not None and idx_re.search(ln.code):
                if "BOUNDS:" not in ln.comment and (i == 0 or "BOUNDS:" not in lines[i - 1].comment):
                    warnings.append(
                        (relpath, i + 1, "W1",
                         "indexing in a protocol-boundary fn without a `// BOUNDS:` note")
                    )
    return errors, warnings


def find_cycles(graph):
    cycles = []
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {k: WHITE for k in graph}
    stack = []

    def dfs(u):
        color[u] = GRAY
        stack.append(u)
        for (v, f, l) in sorted(graph.get(u, ())):
            if color.get(v, WHITE) == GRAY:
                k = stack.index(v)
                cycles.append(stack[k:] + [v])
            elif color.get(v, WHITE) == WHITE:
                color.setdefault(v, WHITE)
                dfs(v)
        stack.pop()
        color[u] = BLACK

    for k in sorted(graph):
        if color[k] == WHITE:
            dfs(k)
    return cycles


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    as_json = "--json" in argv
    root = args[0] if args else None
    if root is None:
        root = os.path.dirname(os.path.abspath(__file__))
        while not os.path.exists(os.path.join(root, "ROADMAP.md")):
            parent = os.path.dirname(root)
            if parent == root:
                print("audit: cannot locate repo root (no ROADMAP.md)", file=sys.stderr)
                return 2
            root = parent
    src = os.path.join(root, "rust", "src")
    if not os.path.isdir(src):
        src = root  # allow pointing straight at a source dir (fixtures)
    errors, warnings = [], []
    graph = {}
    for dirpath, _, files in sorted(os.walk(src)):
        for f in sorted(files):
            if not f.endswith(".rs"):
                continue
            p = os.path.join(dirpath, f)
            rel = os.path.relpath(p, root)
            with open(p, encoding="utf-8") as fh:
                e, w = check_file(rel.replace(os.sep, "/"), fh.read(), graph)
            errors.extend(e)
            warnings.extend(w)
    for cyc in find_cycles(graph):
        errors.append(("<global>", 0, "R4", "lock acquisition cycle: " + " -> ".join(cyc)))
    if as_json:
        print(json.dumps({
            "errors": [{"file": f, "line": l, "rule": r, "msg": m} for f, l, r, m in errors],
            "warnings": [{"file": f, "line": l, "rule": r, "msg": m} for f, l, r, m in warnings],
        }, indent=2))
    else:
        for f, l, r, m in errors:
            print(f"error[{r}] {f}:{l}: {m}")
        for f, l, r, m in warnings:
            print(f"warn[{r}] {f}:{l}: {m}")
        print(f"rrs-audit(mirror): {len(errors)} error(s), {len(warnings)} warning(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
