use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let as_json = argv.iter().any(|a| a == "--json");
    let root = match argv.iter().find(|a| !a.starts_with("--")) {
        Some(p) => PathBuf::from(p),
        None => {
            let mut d = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            loop {
                if d.join("ROADMAP.md").exists() {
                    break d;
                }
                if !d.pop() {
                    eprintln!("audit: cannot locate repo root (no ROADMAP.md)");
                    return ExitCode::from(2);
                }
            }
        }
    };
    let (errors, warnings) = rrs_audit::run(&root);
    if as_json {
        println!("{}", rrs_audit::to_json(&errors, &warnings));
    } else {
        for line in rrs_audit::render_text(&errors, &warnings) {
            println!("{line}");
        }
    }
    if errors.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
