//! Line lexer: splits Rust source into per-line (code, comment) halves
//! with string/char literals stripped (replaced by `""`) so tokens inside
//! literals never match lint rules.  Tracks block comments and raw
//! strings across lines, and per-line brace deltas for scope depth.
//!
//! This is the same lexical model as `audit_mirror.py::lex` — the two
//! implementations are pinned against shared fixtures.

/// One source line after lexing.
pub struct Line {
    /// Code text with literals replaced by `""` and comments removed.
    pub code: String,
    /// Concatenated comment text (line + block comment bodies).
    pub comment: String,
    /// Net `{`/`}` delta contributed by code on this line.
    pub open_delta: i32,
}

pub(crate) fn is_word(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

pub(crate) fn is_word_or_dot(b: u8) -> bool {
    is_word(b) || b == b'.'
}

pub(crate) fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

pub(crate) fn skip_ws(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Byte-substring search starting at `from`.
pub(crate) fn find_from(hay: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    if from > hay.len() || needle.is_empty() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

enum State {
    Code,
    BlockComment,
    Str,
    RawStr,
}

pub fn lex(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    let mut raw_hashes = 0usize;
    for raw_line in text.split('\n') {
        let raw = raw_line.as_bytes();
        let n = raw.len();
        let mut code: Vec<u8> = Vec::new();
        let mut comment: Vec<u8> = Vec::new();
        let mut open_delta = 0i32;
        let mut i = 0usize;
        while i < n {
            let c = raw[i];
            let nxt = if i + 1 < n { raw[i + 1] } else { 0 };
            match state {
                State::BlockComment => {
                    match find_from(raw, i, b"*/") {
                        None => {
                            comment.extend_from_slice(&raw[i..]);
                            i = n;
                        }
                        Some(j) => {
                            comment.extend_from_slice(&raw[i..j]);
                            i = j + 2;
                            state = State::Code;
                        }
                    }
                    continue;
                }
                State::Str => {
                    if c == b'\\' {
                        i += 2;
                        continue;
                    }
                    if c == b'"' {
                        state = State::Code;
                        code.extend_from_slice(b"\"\"");
                    }
                    i += 1;
                    continue;
                }
                State::RawStr => {
                    let close = i + 1 + raw_hashes <= n
                        && raw[i + 1..i + 1 + raw_hashes].iter().all(|&h| h == b'#');
                    if c == b'"' && close {
                        state = State::Code;
                        code.extend_from_slice(b"\"\"");
                        i += 1 + raw_hashes;
                    } else {
                        i += 1;
                    }
                    continue;
                }
                State::Code => {}
            }
            // state == Code
            if c == b'/' && nxt == b'/' {
                comment.extend_from_slice(&raw[i + 2..]);
                i = n;
                continue;
            }
            if c == b'/' && nxt == b'*' {
                state = State::BlockComment;
                i += 2;
                continue;
            }
            if c == b'r' && (nxt == b'"' || nxt == b'#') {
                let mut j = i + 1;
                let mut h = 0usize;
                while j < n && raw[j] == b'#' {
                    h += 1;
                    j += 1;
                }
                if j < n && raw[j] == b'"' {
                    state = State::RawStr;
                    raw_hashes = h;
                    i = j + 1;
                    continue;
                }
            }
            if c == b'b' && nxt == b'"' {
                state = State::Str;
                i += 2;
                continue;
            }
            if c == b'"' {
                state = State::Str;
                i += 1;
                continue;
            }
            if c == b'\'' {
                // char literal vs lifetime: 'a' is a char, 'a (no closing
                // quote right after one item) is a lifetime
                if nxt == b'\\' {
                    i = match find_from(raw, i + 2, b"'") {
                        Some(j) => j + 1,
                        None => n,
                    };
                    code.extend_from_slice(b"\"\"");
                    continue;
                }
                if i + 2 < n && raw[i + 2] == b'\'' {
                    i += 3;
                    code.extend_from_slice(b"\"\"");
                    continue;
                }
                code.push(c);
                i += 1;
                continue;
            }
            code.push(c);
            if c == b'{' {
                open_delta += 1;
            } else if c == b'}' {
                open_delta -= 1;
            }
            i += 1;
        }
        if matches!(state, State::Str) {
            state = State::Code; // unterminated; tolerate
        }
        out.push(Line {
            code: String::from_utf8_lossy(&code).into_owned(),
            comment: String::from_utf8_lossy(&comment).into_owned(),
            open_delta,
        });
    }
    out
}

/// Match one literal token at `i` after optional whitespace.
fn tok(b: &[u8], i: usize, t: &[u8]) -> Option<usize> {
    let i = skip_ws(b, i);
    if b[i..].starts_with(t) {
        Some(i + t.len())
    } else {
        None
    }
}

/// True when the code text carries a `#[cfg(test)]`/`#[cfg(loom)]`-style
/// attribute (including `all(...)` / `any(...)` combinations).
pub(crate) fn cfg_test_attr(code: &str) -> bool {
    let b = code.as_bytes();
    for start in 0..b.len() {
        if b[start] != b'#' {
            continue;
        }
        let Some(j) = tok(b, start + 1, b"[") else { continue };
        let Some(j) = tok(b, j, b"cfg") else { continue };
        let Some(j) = tok(b, j, b"(") else { continue };
        let j = tok(b, j, b"all")
            .and_then(|k| tok(b, k, b"("))
            .unwrap_or(j);
        if tok(b, j, b"test").is_some() || tok(b, j, b"loom").is_some() {
            return true;
        }
        if let Some(k) = tok(b, j, b"any").and_then(|k| tok(b, k, b"(")) {
            if tok(b, k, b"test").is_some() || tok(b, k, b"loom").is_some() {
                return true;
            }
        }
    }
    false
}

/// Line-index set covered by `#[cfg(test)]` / `#[cfg(loom)]`-style items
/// (the attribute plus the brace range of the item that follows).
pub fn test_regions(lines: &[Line]) -> std::collections::HashSet<usize> {
    let mut covered = std::collections::HashSet::new();
    let mut depths = Vec::with_capacity(lines.len());
    let mut depth = 0i32;
    for ln in lines {
        depths.push(depth);
        depth += ln.open_delta;
    }
    let mut i = 0usize;
    while i < lines.len() {
        if cfg_test_attr(&lines[i].code) {
            covered.insert(i);
            let d0 = depths[i];
            let mut j = i;
            let mut opened = false;
            while j < lines.len() {
                covered.insert(j);
                if lines[j].open_delta > 0 {
                    opened = true;
                }
                if opened && depths[j] + lines[j].open_delta <= d0 {
                    break;
                }
                if !opened && lines[j].code.trim().ends_with(';') {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    covered
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_are_stripped_from_code() {
        let lines = lex("let s = \"unsafe { panic!() }\";");
        assert_eq!(lines.len(), 1);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(!lines[0].code.contains("panic!"));
        assert!(lines[0].code.contains("\"\""));
    }

    #[test]
    fn raw_strings_and_byte_strings_are_stripped() {
        let lines = lex("let a = r#\"panic!(\"x\")\"#; let b = b\".unwrap()\";");
        assert!(!lines[0].code.contains("panic!"));
        assert!(!lines[0].code.contains(".unwrap()"));
    }

    #[test]
    fn lifetimes_survive_char_literals_do_not() {
        let lines = lex("fn f<'a>(x: &'a u32) -> char { 'x' }");
        assert!(lines[0].code.contains("'a"));
        assert!(!lines[0].code.contains("'x'"));
    }

    #[test]
    fn line_and_block_comments_split_out() {
        let lines = lex("let x = 1; // SAFETY: tail\n/* ORDERING:\nspans */ let y = 2;");
        assert!(lines[0].comment.contains("SAFETY:"));
        assert!(!lines[0].code.contains("SAFETY"));
        assert!(lines[1].comment.contains("ORDERING:"));
        assert!(lines[2].code.contains("let y"));
    }

    #[test]
    fn open_delta_counts_code_braces_only() {
        let lines = lex("fn f() { // {{{\n    let s = \"}}\";\n}");
        assert_eq!(lines[0].open_delta, 1);
        assert_eq!(lines[1].open_delta, 0);
        assert_eq!(lines[2].open_delta, -1);
    }

    #[test]
    fn test_regions_cover_cfg_test_and_loom_items() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\n\
                   #[cfg(all(loom, test))]\nmod loom_tests {\n    fn m() {}\n}\nfn live2() {}";
        let lines = lex(src);
        let covered = test_regions(&lines);
        assert!(!covered.contains(&0));
        for i in 1..=4 {
            assert!(covered.contains(&i), "line {i} should be covered");
        }
        for i in 5..=8 {
            assert!(covered.contains(&i), "line {i} should be covered");
        }
        assert!(!covered.contains(&9));
    }
}
