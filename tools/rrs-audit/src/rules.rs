//! Lint rules over the lexed line model.  Rule numbers, messages, and
//! semantics are pinned 1:1 against `audit_mirror.py` by the shared
//! fixture corpus (`tests/audit_fixtures.rs`).
//!
//!   R1 safety-comment      every `unsafe` fn/impl/block carries a
//!                          `// SAFETY:` justification.
//!   R2 panic-free-serving  no panicking APIs in the serving allowlist.
//!   R3 ordering-note       every `Ordering::Relaxed` is a counter RMW
//!                          or covered by an `// ORDERING:` note.
//!   R4 lock-order          the Mutex acquisition graph is acyclic.
//!   W1 untrusted-indexing  indexing in protocol-boundary fns without a
//!                          `// BOUNDS:` note (warning only).

use std::collections::{BTreeMap, BTreeSet};

use crate::lexer::{
    find_from, is_ident_start, is_word, is_word_or_dot, lex, skip_ws, test_regions,
};

/// Serving-path allowlist: R2/W1 apply to files whose repo-relative path
/// contains one of these segments.
pub const ALLOWLIST: [&str; 4] = ["coordinator/", "kvpool/", "runtime/", "obs/"];

const PANIC_PATTERNS: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

const COUNTER_RMW: [&str; 4] = ["fetch_add", "fetch_sub", "fetch_max", "fetch_min"];

/// One reported violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    /// 1-based line number (0 for whole-repo findings like lock cycles).
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// Lock acquisition graph: `src lock -> {(dst lock, file, line)}`.
/// BTree containers give the same sorted iteration the mirror gets from
/// Python's `sorted()`, so cycle reports are byte-identical.
pub type LockGraph = BTreeMap<String, BTreeSet<(String, String, usize)>>;

/// `\bunsafe\b\s*(fn|impl|trait|\{|extern)` — an unsafe site needing R1.
pub(crate) fn unsafe_site(code: &str) -> bool {
    let b = code.as_bytes();
    let mut i = 0usize;
    while let Some(p) = find_from(b, i, b"unsafe") {
        let before_ok = p == 0 || !is_word(b[p - 1]);
        let after = p + 6;
        let after_ok = after >= b.len() || !is_word(b[after]);
        if before_ok && after_ok {
            let k = skip_ws(b, after);
            if k < b.len()
                && (b[k] == b'{'
                    || b[k..].starts_with(b"fn")
                    || b[k..].starts_with(b"impl")
                    || b[k..].starts_with(b"trait")
                    || b[k..].starts_with(b"extern"))
            {
                return true;
            }
        }
        i = p + 1;
    }
    false
}

/// Lines the upward SAFETY scan may pass through: blank/comment-only,
/// attributes, a lone `}`, and sibling unsafe item heads (one note may
/// cover a `Send`+`Sync` pair).
fn attr_or_pass(code: &str) -> bool {
    let t = code.trim_start();
    if t.starts_with("#[") || t.starts_with("#![") {
        return true;
    }
    let u = t.strip_prefix('}').unwrap_or(t);
    if u.trim().is_empty() {
        return true;
    }
    t.starts_with("unsafe impl")
        || t.starts_with("pub unsafe")
        || t.starts_with("pub(crate) unsafe")
}

/// `\blet\s+(mut\s+)?\w+\s*=` — the line binds a named guard.
fn has_stmt_guard(code: &str) -> bool {
    let b = code.as_bytes();
    let mut i = 0usize;
    while let Some(p) = find_from(b, i, b"let") {
        let before_ok = p == 0 || !is_word(b[p - 1]);
        let mut j = p + 3;
        if before_ok && j < b.len() && b[j].is_ascii_whitespace() {
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            // try with the optional `mut ` consumed, then without
            for with_mut in [true, false] {
                let mut k = j;
                if with_mut {
                    let ok = b[k..].starts_with(b"mut")
                        && k + 3 < b.len()
                        && b[k + 3].is_ascii_whitespace();
                    if !ok {
                        continue;
                    }
                    k += 3;
                    while k < b.len() && b[k].is_ascii_whitespace() {
                        k += 1;
                    }
                }
                let s = k;
                while k < b.len() && is_word(b[k]) {
                    k += 1;
                }
                if k == s {
                    continue;
                }
                k = skip_ws(b, k);
                if k < b.len() && b[k] == b'=' {
                    return true;
                }
            }
        }
        i = p + 1;
    }
    false
}

/// `drop\s*\(\s*\w+\s*\)` present (with a literal `drop(` on the line).
fn drop_releases(code: &str) -> bool {
    if !code.contains("drop(") {
        return false;
    }
    let b = code.as_bytes();
    let mut i = 0usize;
    while let Some(p) = find_from(b, i, b"drop") {
        let mut j = skip_ws(b, p + 4);
        if j < b.len() && b[j] == b'(' {
            j = skip_ws(b, j + 1);
            let s = j;
            while j < b.len() && is_word(b[j]) {
                j += 1;
            }
            if j > s {
                j = skip_ws(b, j);
                if j < b.len() && b[j] == b')' {
                    return true;
                }
            }
        }
        i = p + 1;
    }
    false
}

/// `lock_recover\s*\(\s*&?NAME\s*\)` starting at `p`.
fn match_recover(code: &str, p: usize) -> Option<(String, usize)> {
    let b = code.as_bytes();
    let mut i = skip_ws(b, p + 12);
    if i >= b.len() || b[i] != b'(' {
        return None;
    }
    i = skip_ws(b, i + 1);
    if i < b.len() && b[i] == b'&' {
        i += 1;
    }
    if i >= b.len() || !is_ident_start(b[i]) {
        return None;
    }
    let s = i;
    i += 1;
    while i < b.len() && is_word_or_dot(b[i]) {
        i += 1;
    }
    let mut e = i;
    if b[i..].starts_with(b"()") {
        i += 2;
        e = i;
    }
    i = skip_ws(b, i);
    if i < b.len() && b[i] == b')' {
        Some((code[s..e].to_string(), i + 1))
    } else {
        None
    }
}

/// Non-greedy `NAME\.lock\s*\(\)` starting at `start`.
fn match_dot_lock(code: &str, start: usize) -> Option<(String, usize)> {
    let b = code.as_bytes();
    let mut j = start + 1;
    loop {
        if b[j..].starts_with(b".lock") {
            let k = skip_ws(b, j + 5);
            if b[k..].starts_with(b"()") {
                return Some((code[start..j].to_string(), k + 2));
            }
        }
        if j < b.len() && is_word_or_dot(b[j]) {
            j += 1;
        } else {
            return None;
        }
    }
}

/// All lock acquisitions on a code line, left to right.
fn lock_matches(code: &str) -> Vec<String> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        if b[i..].starts_with(b"lock_recover") {
            if let Some((name, end)) = match_recover(code, i) {
                out.push(name);
                i = end;
                continue;
            }
        }
        if is_ident_start(b[i]) {
            if let Some((name, end)) = match_dot_lock(code, i) {
                out.push(name);
                i = end;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// `\bfn\s+NAME` where NAME contains `parse` or `from_json`.
fn protocol_fn(code: &str) -> bool {
    let b = code.as_bytes();
    let mut i = 0usize;
    while let Some(p) = find_from(b, i, b"fn") {
        let before_ok = p == 0 || !is_word(b[p - 1]);
        let mut j = p + 2;
        if before_ok && j < b.len() && b[j].is_ascii_whitespace() {
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            let s = j;
            while j < b.len() && is_word(b[j]) {
                j += 1;
            }
            let name = &code[s..j];
            if name.contains("parse") || name.contains("from_json") {
                return true;
            }
        }
        i = p + 1;
    }
    false
}

/// `\b[a-z_][\w\.]*\[` — indexing through a lowercase (dotted) path.
fn has_lower_index(code: &str) -> bool {
    let b = code.as_bytes();
    for p in 0..b.len() {
        if b[p] != b'[' {
            continue;
        }
        let mut s = p;
        while s > 0 && is_word_or_dot(b[s - 1]) {
            s -= 1;
        }
        if s == p {
            continue;
        }
        // candidate starts: the run head, or any char right after a `.`
        // (both are `\b` positions because `.` is a non-word char)
        for q in s..p {
            let boundary = q == s || b[q - 1] == b'.';
            if boundary && (b[q].is_ascii_lowercase() || b[q] == b'_') {
                return true;
            }
        }
    }
    false
}

/// Run all per-file rules; lock edges accumulate into `graph` for the
/// whole-repo R4 cycle check.
pub fn check_file(
    relpath: &str,
    text: &str,
    graph: &mut LockGraph,
) -> (Vec<Finding>, Vec<Finding>) {
    let lines = lex(text);
    let tests = test_regions(&lines);
    let in_allow = ALLOWLIST.iter().any(|s| relpath.contains(s));
    let mut errors: Vec<Finding> = Vec::new();
    let mut warnings: Vec<Finding> = Vec::new();

    let mut depths = Vec::with_capacity(lines.len());
    let mut d = 0i32;
    for ln in &lines {
        depths.push(d);
        d += ln.open_delta;
    }

    // R1: unsafe sites need SAFETY:
    for i in 0..lines.len() {
        if tests.contains(&i) || !unsafe_site(&lines[i].code) {
            continue;
        }
        let mut ok = lines[i].comment.contains("SAFETY:");
        let mut j = i as isize - 1;
        let mut hops = 0;
        while !ok && j >= 0 && hops < 10 {
            let cj = &lines[j as usize];
            if cj.comment.contains("SAFETY:") {
                ok = true;
                break;
            }
            let nonblank = !cj.code.trim().is_empty();
            if nonblank && !attr_or_pass(&cj.code) && !unsafe_site(&cj.code) {
                break;
            }
            j -= 1;
            hops += 1;
        }
        if !ok {
            errors.push(Finding {
                file: relpath.to_string(),
                line: i + 1,
                rule: "R1",
                msg: "unsafe site without a `// SAFETY:` justification".to_string(),
            });
        }
    }

    // R2: no panicking APIs in the serving allowlist
    if in_allow {
        for i in 0..lines.len() {
            if tests.contains(&i) {
                continue;
            }
            let code = lines[i].code.as_bytes();
            for pat in PANIC_PATTERNS {
                let mut s = 0usize;
                while let Some(p) = find_from(code, s, pat.as_bytes()) {
                    // (`.expect_err(` cannot collide: the byte after
                    // `.expect` is `_`, never `(`)
                    errors.push(Finding {
                        file: relpath.to_string(),
                        line: i + 1,
                        rule: "R2",
                        msg: format!(
                            "panicking `{}` on the serving path",
                            pat.trim_matches('.')
                        ),
                    });
                    s = p + pat.len();
                }
            }
        }
    }

    // R3: Ordering::Relaxed requires counter RMW or an ORDERING: note.
    // A `// ORDERING:` comment covers the remainder of its brace scope.
    let mut note_stack: Vec<i32> = Vec::new();
    for i in 0..lines.len() {
        note_stack.retain(|&nd| nd <= depths[i]);
        if lines[i].comment.contains("ORDERING:") {
            note_stack.push(depths[i]);
        }
        if tests.contains(&i) || !lines[i].code.contains("Ordering::Relaxed") {
            continue;
        }
        if COUNTER_RMW.iter().any(|k| lines[i].code.contains(k)) {
            continue;
        }
        if lines[i].comment.contains("ORDERING:") || !note_stack.is_empty() {
            continue;
        }
        errors.push(Finding {
            file: relpath.to_string(),
            line: i + 1,
            rule: "R3",
            msg: "`Ordering::Relaxed` load/store without an `// ORDERING:` note \
                  (or use a counter RMW)"
                .to_string(),
        });
    }

    // R4 extraction: lock acquisitions with a guard still held
    let stem = relpath.rsplit('/').next().unwrap_or(relpath);
    let stem = stem.rsplit_once('.').map(|(s, _)| s).unwrap_or(stem);
    let mut held: Vec<(i32, String, bool)> = Vec::new();
    for i in 0..lines.len() {
        if tests.contains(&i) {
            continue;
        }
        held.retain(|h| h.0 <= depths[i]);
        let code = &lines[i].code;
        let stmt_guard = has_stmt_guard(code);
        for name in lock_matches(code) {
            let name = name.strip_suffix(".lock").unwrap_or(&name);
            let canon = format!("{stem}.{name}");
            for (_, src, sg) in &held {
                if *sg && src != &canon {
                    graph
                        .entry(src.clone())
                        .or_default()
                        .insert((canon.clone(), relpath.to_string(), i + 1));
                }
            }
            if stmt_guard {
                held.push((depths[i], canon, true));
            }
            // temporaries (`x.lock()...` in one expression) drop at the
            // end of the statement — they never hold across another lock
        }
        // end-of-statement: temporaries die; statement guards persist to
        // end of scope (approximation: `drop(g)` also releases)
        if drop_releases(code) {
            held.retain(|h| !h.2);
        }
    }

    // W1: indexing in protocol-boundary fns
    if in_allow {
        let mut cur_fn_depth: Option<i32> = None;
        for i in 0..lines.len() {
            if tests.contains(&i) {
                continue;
            }
            if let Some(fd) = cur_fn_depth {
                if depths[i] <= fd && i > 0 && lines[i].code.trim().starts_with('}') {
                    cur_fn_depth = None;
                }
            }
            if protocol_fn(&lines[i].code) {
                cur_fn_depth = Some(depths[i]);
                continue;
            }
            if cur_fn_depth.is_some() && has_lower_index(&lines[i].code) {
                let prev_ok = i > 0 && lines[i - 1].comment.contains("BOUNDS:");
                if !lines[i].comment.contains("BOUNDS:") && !prev_ok {
                    warnings.push(Finding {
                        file: relpath.to_string(),
                        line: i + 1,
                        rule: "W1",
                        msg: "indexing in a protocol-boundary fn without a \
                              `// BOUNDS:` note"
                            .to_string(),
                    });
                }
            }
        }
    }

    (errors, warnings)
}

/// White/gray/black DFS over the lock graph; every gray back-edge emits
/// the cycle path (deterministic order via sorted containers).
pub fn find_cycles(graph: &LockGraph) -> Vec<Vec<String>> {
    const WHITE: u8 = 0;
    const GRAY: u8 = 1;

    fn dfs<'a>(
        u: &'a str,
        graph: &'a LockGraph,
        color: &mut BTreeMap<&'a str, u8>,
        stack: &mut Vec<&'a str>,
        cycles: &mut Vec<Vec<String>>,
    ) {
        color.insert(u, GRAY);
        stack.push(u);
        if let Some(edges) = graph.get(u) {
            for (v, _file, _line) in edges {
                match color.get(v.as_str()).copied().unwrap_or(WHITE) {
                    GRAY => {
                        let k =
                            stack.iter().position(|x| *x == v.as_str()).unwrap_or(0);
                        let mut cyc: Vec<String> =
                            stack[k..].iter().map(|s| s.to_string()).collect();
                        cyc.push(v.clone());
                        cycles.push(cyc);
                    }
                    WHITE => dfs(v, graph, color, stack, cycles),
                    _ => {}
                }
            }
        }
        stack.pop();
        color.insert(u, 2);
    }

    let mut color: BTreeMap<&str, u8> =
        graph.keys().map(|k| (k.as_str(), WHITE)).collect();
    let mut stack: Vec<&str> = Vec::new();
    let mut cycles = Vec::new();
    let keys: Vec<&str> = graph.keys().map(|k| k.as_str()).collect();
    for k in keys {
        if color.get(k).copied().unwrap_or(WHITE) == WHITE {
            dfs(k, graph, &mut color, &mut stack, &mut cycles);
        }
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(rel: &str, src: &str) -> (Vec<Finding>, Vec<Finding>) {
        let mut g = LockGraph::new();
        check_file(rel, src, &mut g)
    }

    #[test]
    fn r1_flags_bare_unsafe_and_accepts_noted() {
        let (e, _) = check("x.rs", "fn f() {\n    unsafe { g() }\n}");
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].rule, "R1");
        assert_eq!(e[0].line, 2);
        let (e, _) = check(
            "x.rs",
            "fn f() {\n    // SAFETY: g has no preconditions here.\n    unsafe { g() }\n}",
        );
        assert!(e.is_empty(), "{e:?}");
    }

    #[test]
    fn r1_note_reaches_through_attributes_and_siblings() {
        let src = "// SAFETY: detection gates both impls.\n\
                   #[allow(dead_code)]\nunsafe impl Send for X {}\nunsafe impl Sync for X {}";
        let (e, _) = check("x.rs", src);
        assert!(e.is_empty(), "{e:?}");
    }

    #[test]
    fn r2_only_fires_inside_allowlist_and_outside_tests() {
        let src = "fn f(v: &[u32]) {\n    v.first().unwrap();\n}\n\
                   #[cfg(test)]\nmod t {\n    fn g() { None::<u32>.unwrap(); }\n}";
        let (e, _) = check("coordinator/x.rs", src);
        assert_eq!(e.len(), 1);
        assert_eq!((e[0].rule, e[0].line), ("R2", 2));
        let (e, _) = check("model/x.rs", src);
        assert!(e.is_empty());
    }

    #[test]
    fn r3_counter_rmw_and_scoped_note_are_exempt() {
        let (e, _) = check("x.rs", "c.fetch_add(1, Ordering::Relaxed);");
        assert!(e.is_empty());
        let (e, _) = check("x.rs", "c.load(Ordering::Relaxed);");
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].rule, "R3");
        let src = "fn f() {\n    // ORDERING: monotone counter, staleness ok.\n\
                   \n    let a = c.load(Ordering::Relaxed);\n    let b = d.load(Ordering::Relaxed);\n}";
        let (e, _) = check("x.rs", src);
        assert!(e.is_empty(), "{e:?}");
    }

    #[test]
    fn r4_builds_edges_and_detects_cycles() {
        // lock names are file-stem-qualified, so the inversion must sit
        // in the same file to close the cycle
        let mut g = LockGraph::new();
        let ab = "fn ab(t: &T) {\n    let ga = t.a.lock();\n    let gb = t.b.lock();\n}";
        check_file("m/ab.rs", ab, &mut g);
        assert_eq!(g.len(), 1, "{g:?}");
        assert!(find_cycles(&g).is_empty());

        let both = "fn ab(t: &T) {\n    let ga = t.a.lock();\n    let gb = t.b.lock();\n}\n\
                    fn ba(t: &T) {\n    let gb = t.b.lock();\n    let ga = t.a.lock();\n}";
        let mut g2 = LockGraph::new();
        check_file("m/ab.rs", both, &mut g2);
        let cycles = find_cycles(&g2);
        assert_eq!(cycles.len(), 1, "{cycles:?}");
        assert!(cycles[0].contains(&"ab.t.a".to_string()), "{cycles:?}");
        assert!(cycles[0].contains(&"ab.t.b".to_string()), "{cycles:?}");
    }

    #[test]
    fn r4_drop_releases_the_guard() {
        let mut g = LockGraph::new();
        let src = "fn f(t: &T) {\n    let ga = t.a.lock();\n    drop(ga);\n    let gb = t.b.lock();\n}";
        check_file("m/f.rs", src, &mut g);
        assert!(g.is_empty(), "{g:?}");
    }

    #[test]
    fn r4_lock_recover_and_temporaries() {
        let mut g = LockGraph::new();
        let src = "fn f(t: &T) {\n    let ga = lock_recover(&t.a);\n    *lock_recover(&t.b) += 1;\n}";
        check_file("m/f.rs", src, &mut g);
        // guard ga held while t.b is taken -> one edge, no cycle
        assert_eq!(g.len(), 1);
        assert!(g.contains_key("f.t.a"), "{g:?}");
        // the temporary t.b guard was never held, so no reverse edge
        assert!(find_cycles(&g).is_empty());
    }

    #[test]
    fn w1_wants_bounds_note_on_same_or_previous_line() {
        let bad = "fn parse_header(b: &[u8]) -> u8 {\n    b[0]\n}";
        let (_, w) = check("obs/x.rs", bad);
        assert_eq!(w.len(), 1);
        assert_eq!((w[0].rule, w[0].line), ("W1", 2));
        let good = "fn parse_header(b: &[u8]) -> u8 {\n    // BOUNDS: framing check above.\n    b[0]\n}";
        let (_, w) = check("obs/x.rs", good);
        assert!(w.is_empty(), "{w:?}");
        // outside a protocol fn, indexing is fine
        let (_, w) = check("obs/x.rs", "fn sum(b: &[u8]) -> u8 {\n    b[0]\n}");
        assert!(w.is_empty());
    }
}
