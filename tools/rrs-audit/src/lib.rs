//! `rrs-audit` — workspace lint pass for the serving crate's unsafe and
//! lock-free core.
//!
//! CI runs this binary as a required gate; `audit_mirror.py` (same
//! directory) implements the same rules over the same lexer model for
//! environments without a Rust toolchain.  The two are pinned against
//! the shared fixture corpus by `tests/audit_fixtures.rs` — rule
//! numbers, messages, and exit codes must stay identical.
//!
//! Usage: `rrs-audit [ROOT] [--json]`.  ROOT defaults to the repo root
//! found by walking up from the current directory to `ROADMAP.md`; it
//! scans `ROOT/rust/src`, or ROOT itself when that directory is absent
//! (fixture mode).  Exit 1 on any error-level finding, 2 when the root
//! cannot be located.

pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

pub use rules::{check_file, find_cycles, Finding, LockGraph, ALLOWLIST};

/// Depth-first directory collection; the caller sorts the flat list by
/// path string to match Python's `sorted(os.walk(...))` scan order.
fn collect_dirs(d: &Path, out: &mut Vec<PathBuf>) {
    out.push(d.to_path_buf());
    let Ok(rd) = fs::read_dir(d) else { return };
    let mut subs: Vec<PathBuf> = rd
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.is_dir())
        .collect();
    subs.sort();
    for s in subs {
        collect_dirs(&s, out);
    }
}

/// Every `.rs` file under `src`, in deterministic scan order.
pub fn walk_rs_files(src: &Path) -> Vec<PathBuf> {
    let mut dirs = Vec::new();
    collect_dirs(src, &mut dirs);
    dirs.sort_by(|a, b| a.to_string_lossy().cmp(&b.to_string_lossy()));
    let mut files = Vec::new();
    for d in dirs {
        let Ok(rd) = fs::read_dir(&d) else { continue };
        let mut names: Vec<String> = rd
            .flatten()
            .filter(|e| e.path().is_file())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.ends_with(".rs"))
            .collect();
        names.sort();
        for nm in names {
            files.push(d.join(nm));
        }
    }
    files
}

/// Run the full audit rooted at `root`: per-file rules plus the
/// whole-repo lock-order cycle check.  Returns (errors, warnings).
pub fn run(root: &Path) -> (Vec<Finding>, Vec<Finding>) {
    let candidate = root.join("rust").join("src");
    let src = if candidate.is_dir() {
        candidate
    } else {
        // allow pointing straight at a source dir (fixtures)
        root.to_path_buf()
    };
    let mut graph = LockGraph::new();
    let mut errors: Vec<Finding> = Vec::new();
    let mut warnings: Vec<Finding> = Vec::new();
    for p in walk_rs_files(&src) {
        let rel = p
            .strip_prefix(root)
            .map(|r| r.to_string_lossy().replace('\\', "/"))
            .unwrap_or_else(|_| p.to_string_lossy().replace('\\', "/"));
        let Ok(text) = fs::read_to_string(&p) else { continue };
        let (e, w) = check_file(&rel, &text, &mut graph);
        errors.extend(e);
        warnings.extend(w);
    }
    for cyc in find_cycles(&graph) {
        errors.push(Finding {
            file: "<global>".to_string(),
            line: 0,
            rule: "R4",
            msg: format!("lock acquisition cycle: {}", cyc.join(" -> ")),
        });
    }
    (errors, warnings)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// `--json` report: same keys as the mirror's JSON mode.
pub fn to_json(errors: &[Finding], warnings: &[Finding]) -> String {
    fn arr(items: &[Finding]) -> String {
        let rows: Vec<String> = items
            .iter()
            .map(|f| {
                format!(
                    "    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"msg\": \"{}\"}}",
                    json_escape(&f.file),
                    f.line,
                    f.rule,
                    json_escape(&f.msg)
                )
            })
            .collect();
        if rows.is_empty() {
            "[]".to_string()
        } else {
            format!("[\n{}\n  ]", rows.join(",\n"))
        }
    }
    format!(
        "{{\n  \"errors\": {},\n  \"warnings\": {}\n}}",
        arr(errors),
        arr(warnings)
    )
}

/// Human-readable report lines (errors, then warnings, then the summary
/// line).  The binary prints these verbatim; fixtures compare them
/// against the mirror's output.
pub fn render_text(errors: &[Finding], warnings: &[Finding]) -> Vec<String> {
    let mut out = Vec::with_capacity(errors.len() + warnings.len() + 1);
    for f in errors {
        out.push(format!("error[{}] {}:{}: {}", f.rule, f.file, f.line, f.msg));
    }
    for f in warnings {
        out.push(format!("warn[{}] {}:{}: {}", f.rule, f.file, f.line, f.msg));
    }
    out.push(format!(
        "rrs-audit: {} error(s), {} warning(s)",
        errors.len(),
        warnings.len()
    ));
    out
}
