//! Pins the Rust audit pass and `audit_mirror.py` to each other over
//! the shared fixture corpus: same findings, same message strings, same
//! report lines.  Any rule change must update both implementations and
//! these expectations together.

use std::path::{Path, PathBuf};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures")
}

fn run_fixtures() -> (Vec<rrs_audit::Finding>, Vec<rrs_audit::Finding>) {
    rrs_audit::run(&fixture_root())
}

#[test]
fn fixture_corpus_produces_the_pinned_findings() {
    let (errors, warnings) = run_fixtures();
    let got: Vec<(String, usize, &str)> = errors
        .iter()
        .map(|f| (f.file.clone(), f.line, f.rule))
        .collect();
    let mut sorted = got.clone();
    sorted.sort();
    let want: Vec<(String, usize, &str)> = vec![
        ("<global>".into(), 0, "R4"),
        ("missing_safety.rs".into(), 4, "R1"),
        ("panics/coordinator/bad.rs".into(), 5, "R2"),
        ("panics/coordinator/bad.rs".into(), 7, "R2"),
        ("relaxed_no_note.rs".into(), 11, "R3"),
    ];
    assert_eq!(sorted, want, "full error list: {errors:?}");
    assert_eq!(warnings.len(), 1, "{warnings:?}");
    assert_eq!(warnings[0].file, "idx/obs/parse_bad.rs");
    assert_eq!(warnings[0].line, 12);
    assert_eq!(warnings[0].rule, "W1");
}

#[test]
fn fixture_messages_match_the_published_wording() {
    let (errors, warnings) = run_fixtures();
    let msg = |rule: &str| {
        errors
            .iter()
            .find(|f| f.rule == rule)
            .map(|f| f.msg.clone())
            .unwrap_or_default()
    };
    assert_eq!(msg("R1"), "unsafe site without a `// SAFETY:` justification");
    assert_eq!(
        msg("R3"),
        "`Ordering::Relaxed` load/store without an `// ORDERING:` note \
         (or use a counter RMW)"
    );
    assert_eq!(
        msg("R4"),
        "lock acquisition cycle: ab.t.a -> ab.t.b -> ab.t.a"
    );
    assert!(errors
        .iter()
        .any(|f| f.msg == "panicking `unwrap()` on the serving path"));
    assert!(errors
        .iter()
        .any(|f| f.msg == "panicking `panic!` on the serving path"));
    assert_eq!(
        warnings[0].msg,
        "indexing in a protocol-boundary fn without a `// BOUNDS:` note"
    );
}

#[test]
fn clean_fixture_contributes_nothing() {
    let (errors, warnings) = run_fixtures();
    assert!(errors.iter().all(|f| f.file != "clean.rs"), "{errors:?}");
    assert!(warnings.iter().all(|f| f.file != "clean.rs"), "{warnings:?}");
}

/// The binary's report lines must match the Python mirror byte for byte
/// (modulo the summary line, which names the implementation).  Skips
/// quietly when `python3` is unavailable.
#[test]
fn report_lines_match_python_mirror() {
    let mirror = Path::new(env!("CARGO_MANIFEST_DIR")).join("audit_mirror.py");
    let out = std::process::Command::new("python3")
        .arg(&mirror)
        .arg(fixture_root())
        .output();
    let out = match out {
        Ok(o) => o,
        Err(_) => {
            eprintln!("python3 unavailable; skipping mirror comparison");
            return;
        }
    };
    assert_eq!(out.status.code(), Some(1), "mirror should exit 1 on fixtures");
    let text = String::from_utf8_lossy(&out.stdout);
    let mut mirror_lines: Vec<&str> = text
        .lines()
        .filter(|l| !l.starts_with("rrs-audit"))
        .collect();
    mirror_lines.sort_unstable();

    let (errors, warnings) = run_fixtures();
    let rendered = rrs_audit::render_text(&errors, &warnings);
    let mut ours: Vec<&str> = rendered
        .iter()
        .map(String::as_str)
        .filter(|l| !l.starts_with("rrs-audit"))
        .collect();
    ours.sort_unstable();
    assert_eq!(ours, mirror_lines);

    // and the summary counts agree
    assert!(text.contains("rrs-audit(mirror): 5 error(s), 1 warning(s)"), "{text}");
    assert_eq!(
        rendered.last().map(String::as_str),
        Some("rrs-audit: 5 error(s), 1 warning(s)")
    );
}

/// The audited tree itself must stay clean — the same invariant CI
/// enforces with `cargo run -p rrs-audit` at the repo root.  Skips when
/// the checkout layout is unexpected (e.g. the package is vendored
/// elsewhere).
#[test]
fn repo_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .map(Path::to_path_buf)
        .unwrap_or_default();
    if !root.join("rust").join("src").is_dir() {
        eprintln!("no rust/src above the tool; skipping repo sweep");
        return;
    }
    let (errors, _warnings) = rrs_audit::run(&root);
    assert!(errors.is_empty(), "repo audit regressions: {errors:?}");
}
