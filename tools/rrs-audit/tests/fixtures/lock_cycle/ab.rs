// Fixture: R4 — the two functions acquire a and b in opposite orders
// while holding a guard, producing the cycle ab.t.a -> ab.t.b -> ab.t.a.

use std::sync::Mutex;

pub struct Two {
    pub a: Mutex<u32>,
    pub b: Mutex<u32>,
}

pub fn ab(t: &Two) -> u32 {
    let ga = lock_recover(&t.a);
    let gb = lock_recover(&t.b);
    *ga + *gb
}

pub fn ba(t: &Two) -> u32 {
    let gb = t.b.lock().unwrap_or_else(|e| e.into_inner());
    let ga = t.a.lock().unwrap_or_else(|e| e.into_inner());
    *ga + *gb
}

pub fn ab_released(t: &Two) -> u32 {
    // dropping the first guard before the second acquisition adds no
    // edge, so this function must not widen the cycle
    let ga = lock_recover(&t.a);
    let x = *ga;
    drop(ga);
    let gb = lock_recover(&t.b);
    x + *gb
}

fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
