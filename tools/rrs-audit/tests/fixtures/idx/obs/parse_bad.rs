// Fixture: W1 — indexing inside a protocol-boundary fn ("obs/" is in
// the allowlist) without a BOUNDS note.  Expect exactly one warning.

pub fn sum(b: &[u8]) -> u8 {
    // not a protocol-boundary fn name: indexing here is unchecked by W1
    // (this fn sits before any parse fn — the scanner's fn region only
    // opens at a *parse*/*from_json* name)
    b[0]
}

pub fn parse_header(b: &[u8]) -> u8 {
    b[0]
}

pub fn parse_checked(b: &[u8]) -> u8 {
    // BOUNDS: caller guarantees at least one byte (framing check).
    b[0]
}
