// Fixture: R2 — panicking APIs on the serving path ("coordinator/" is
// in the allowlist).  Expect two hits: .unwrap() and panic!.

pub fn serve(v: &[u32]) -> u32 {
    let first = *v.first().unwrap();
    if first > 10 {
        panic!("too big");
    }
    first
}

pub fn serve_quietly(v: &[u32]) -> u32 {
    // the string literal below must NOT count: it is stripped by the
    // lexer before rule matching
    let _label = "call .unwrap() at your peril";
    v.first().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_are_fine_in_tests() {
        assert_eq!(super::serve(&[1]), 1);
        let _ = "7".parse::<u32>().unwrap();
    }
}
