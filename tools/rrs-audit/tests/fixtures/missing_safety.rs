// Fixture: R1 — one bare unsafe block, one properly justified.

pub fn bad(x: &[u8]) -> u8 {
    unsafe { *x.as_ptr() }
}

pub fn good(x: &[u8]) -> u8 {
    // SAFETY: the slice is non-empty by the caller's framing contract,
    // so reading its first byte through the raw pointer is in bounds.
    unsafe { *x.as_ptr() }
}

// SAFETY: detection gates both marker impls; one note covers the pair.
unsafe impl Send for Marker {}
unsafe impl Sync for Marker {}

pub struct Marker;

#[cfg(test)]
mod tests {
    // unsafe in test code is exempt from R1
    pub fn probe(x: &[u8]) -> u8 {
        unsafe { *x.as_ptr() }
    }
}
