// Fixture: zero findings.  Exercises the lexer's literal stripping so
// rule tokens inside strings, raw strings, and chars never match.

pub fn add(a: u32, b: u32) -> u32 {
    a.wrapping_add(b)
}

pub fn labels() -> (&'static str, &'static str, char) {
    ("unsafe { }", r#"x.load(Ordering::Relaxed) // panic!"#, '{')
}
