// Fixture: R3 — one bare Relaxed load, one counter RMW (exempt), one
// covered by a scoped ORDERING note.  Expect exactly one hit.

use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed)
}

pub fn read_bad(c: &AtomicU64) -> u64 {
    c.load(Ordering::Relaxed)
}

pub fn read_ok(c: &AtomicU64) -> u64 {
    // ORDERING: monotone counter; readers tolerate staleness.
    c.load(Ordering::Relaxed)
}
