//! Serving load harness: ≥1024 concurrent streaming TCP connections
//! against the live coordinator + paged INT4 engine, with a mixed
//! sampling-parameter population (greedy, temperature, top-k, top-p,
//! penalties, logit bias, stop conditions, priorities, deadlines) and a
//! dropper cohort that disconnects mid-stream.  Measures client-side
//! TTFT and inter-token latency percentiles, then audits the
//! no-hung-lanes ledger: every submission reaches a terminal state and
//! every KV block is reclaimed.  Writes `BENCH_serving.json` (CI uploads
//! `BENCH_*.json` and asserts the ledger + connection count).
//!
//! Run: `cargo bench --bench serving_load`
//! Scale: `RRS_LOAD_CONNS=128 cargo bench --bench serving_load`

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use rrs::coordinator::{server, Coordinator, SchedulerConfig};
use rrs::kvpool::PagedEngine;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::util::json::{obj, Json};
use rrs::util::stats::Summary;

const MAX_BATCH: usize = 16;
const TOKENS_PER_CONN: usize = 8;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn tiny_model() -> QuantModel {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, 42);
    let calib: Vec<u32> = (0..128u32).map(|i| (i * 53 + 7) % 256).collect();
    let ecfg = EngineConfig {
        method: Method::Rrs,
        scheme: Scheme::A4W4KV4,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    QuantModel::prepare(&w, &cfg, &ecfg, Some(&calib), None).unwrap()
}

/// The i-th connection's request line: eight parameter presets cycle
/// through the sampling suite so every feature is live under load.
fn request_line(i: usize) -> String {
    let prompts = ["arlo is", "count: 1 2 3", "the fox named", "senna likes"];
    let prompt = prompts[i % prompts.len()];
    let base = format!(
        r#""prompt": "{prompt}", "max_tokens": {TOKENS_PER_CONN}, "stream": true"#
    );
    let extra = match i % 8 {
        0 => String::new(), // greedy
        1 => format!(r#", "temperature": 0.8, "seed": {}"#, 100 + i),
        2 => r#", "temperature": 1.0, "top_k": 40"#.into(),
        3 => r#", "temperature": 1.0, "top_p": 0.9"#.into(),
        // NOTE: each preset must stay a single line — the protocol is
        // newline-delimited
        4 => concat!(
            r#", "temperature": 0.8, "repetition_penalty": 1.2"#,
            r#", "presence_penalty": 0.2, "frequency_penalty": 0.1"#
        )
        .into(),
        5 => r#", "temperature": 0.9, "logit_bias": {"10": -1e9, "65": 2.0}"#.into(),
        6 => r#", "temperature": 0.7, "stop": ["zzz"], "stop_token_ids": [255]"#
            .into(),
        _ => r#", "priority": 5, "deadline_ms": 60000"#.into(),
    };
    format!("{{{base}{extra}}}\n")
}

struct ConnStats {
    ttft_ms: f32,
    itl_ms: Vec<f32>,
    tokens: usize,
    finish: String,
}

/// Drive one connection; `dropper` connections vanish after two frames.
fn run_conn(port: u16, i: usize, dropper: bool) -> Option<ConnStats> {
    // staggered connects: the kernel backlog is far smaller than the
    // connection count, so spread arrivals and retry refused attempts
    std::thread::sleep(Duration::from_micros((i as u64 % 64) * 300));
    let mut stream = None;
    for attempt in 0..50 {
        match TcpStream::connect(("127.0.0.1", port)) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10 * (attempt + 1))),
        }
    }
    let mut s = stream?;
    let mut reader = BufReader::new(s.try_clone().ok()?);
    let t0 = Instant::now();
    s.write_all(request_line(i).as_bytes()).ok()?;
    s.flush().ok()?;
    let mut ttft_ms = 0.0f32;
    let mut itl_ms = Vec::new();
    let mut tokens = 0usize;
    let mut last = t0;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).ok()? == 0 {
            return None; // server closed on us
        }
        let frame = Json::parse(line.trim()).ok()?;
        if frame.get("error").is_some() {
            return Some(ConnStats {
                ttft_ms: 0.0,
                itl_ms,
                tokens: 0,
                finish: "error".into(),
            });
        }
        if frame.get("done").and_then(Json::as_bool) == Some(true) {
            let finish = frame
                .get("finish")
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string();
            return Some(ConnStats { ttft_ms, itl_ms, tokens, finish });
        }
        let now = Instant::now();
        if tokens == 0 {
            ttft_ms = now.duration_since(t0).as_secs_f32() * 1e3;
        } else {
            itl_ms.push(now.duration_since(last).as_secs_f32() * 1e3);
        }
        last = now;
        tokens += 1;
        if dropper && tokens == 2 {
            let _ = s.shutdown(Shutdown::Both);
            return Some(ConnStats {
                ttft_ms,
                itl_ms,
                tokens,
                finish: "dropped".into(),
            });
        }
    }
}

fn main() {
    let conns = env_usize("RRS_LOAD_CONNS", 1024);
    let pool_blocks = env_usize("RRS_LOAD_BLOCKS", 96);
    println!(
        "serving load harness: {conns} streaming connections x \
         {TOKENS_PER_CONN} tokens (max_batch {MAX_BATCH})"
    );
    let coord = Arc::new(Coordinator::start(
        PagedEngine::new(tiny_model(), pool_blocks, 8),
        SchedulerConfig {
            max_batch: MAX_BATCH,
            queue_capacity: conns.max(64) * 2,
            ..Default::default()
        },
    ).expect("start coordinator"));
    let (port, accept_handle) = server::spawn(coord.clone(), "127.0.0.1:0").unwrap();
    // continuous profiler on for the whole load run: the `profile`
    // command below must return real folded stacks under traffic
    rrs::obs::profile::reset();
    rrs::obs::profile::start_at(99.0);

    let stats: Arc<Mutex<Vec<ConnStats>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for i in 0..conns {
        let stats = stats.clone();
        let dropper = i % 32 == 9;
        joins.push(
            std::thread::Builder::new()
                .stack_size(256 * 1024)
                .name(format!("load-{i}"))
                .spawn(move || {
                    if let Some(cs) = run_conn(port, i, dropper) {
                        stats.lock().unwrap().push(cs);
                    }
                })
                .unwrap(),
        );
    }
    for j in joins {
        let _ = j.join();
    }
    let wall_s = t0.elapsed().as_secs_f32();

    // no-hung-lanes ledger: poll until every submission is terminal and
    // the pool has reclaimed every block
    let m: &rrs::coordinator::Metrics = &coord.metrics;
    let ledger = |m: &rrs::coordinator::Metrics| {
        let sub = m.submitted.load(Ordering::Relaxed);
        let term = m.completed.load(Ordering::Relaxed)
            + m.cancelled.load(Ordering::Relaxed)
            + m.aborted.load(Ordering::Relaxed)
            + m.deadline_missed.load(Ordering::Relaxed)
            + m.rejected.load(Ordering::Relaxed);
        (sub, term, m.pool_blocks_used.load(Ordering::Relaxed))
    };
    let drain_t0 = Instant::now();
    let balanced = loop {
        let (sub, term, used) = ledger(m);
        if sub == term && used == 0 {
            break true;
        }
        if drain_t0.elapsed() > Duration::from_secs(60) {
            eprintln!(
                "LEDGER IMBALANCE: submitted {sub} != terminal {term} \
                 or blocks_used {used} != 0"
            );
            break false;
        }
        std::thread::sleep(Duration::from_millis(25));
    };

    // active-observability surfaces under load: `attrib` and `profile`
    // must both answer with non-empty, schema-valid bodies
    let query = |cmd: &str| -> Json {
        let mut c = TcpStream::connect(("127.0.0.1", port)).expect("query connect");
        c.write_all(format!("{{\"cmd\": \"{cmd}\"}}\n").as_bytes())
            .expect("query write");
        let mut line = String::new();
        BufReader::new(c).read_line(&mut line).expect("query read");
        Json::parse(line.trim()).expect("query parse")
    };
    let attrib = query("attrib");
    let attrib_rows = attrib
        .get("requests")
        .and_then(Json::as_arr)
        .map(|a| a.len())
        .unwrap_or(0);
    assert!(attrib_rows > 0, "attrib returned no requests: {}", attrib.dump());
    let slowest = &attrib.get("requests").unwrap().as_arr().unwrap()[0];
    for key in ["id", "total_ms", "tokens", "finish", "attributed_ms", "phases_ms"] {
        assert!(slowest.get(key).is_some(), "attrib row missing {key}");
    }
    let profile = query("profile");
    let prof_samples = profile
        .get("samples")
        .and_then(Json::as_usize)
        .unwrap_or(0);
    assert!(prof_samples > 0, "profiler took no samples: {}", profile.dump());
    assert!(
        profile.get("folded").and_then(Json::as_str).map(str::len).unwrap_or(0) > 0,
        "profile returned no folded stacks"
    );
    rrs::obs::profile::pause();
    println!("  attrib: {attrib_rows} slowest rows; profiler: {prof_samples} samples");

    let all = stats.lock().unwrap();
    let ttfts: Vec<f32> = all.iter().filter(|c| c.tokens > 0).map(|c| c.ttft_ms).collect();
    let itls: Vec<f32> = all.iter().flat_map(|c| c.itl_ms.iter().copied()).collect();
    let ttft = Summary::of(&ttfts);
    let itl = Summary::of(&itls);
    let client_tokens: usize = all.iter().map(|c| c.tokens).sum();
    let errors = all.iter().filter(|c| c.finish == "error").count();
    let dropped = all.iter().filter(|c| c.finish == "dropped").count();
    let completed = m.completed.load(Ordering::Relaxed);
    let cancelled = m.cancelled.load(Ordering::Relaxed);
    let deadline_missed = m.deadline_missed.load(Ordering::Relaxed);

    println!(
        "  {completed} completed, {cancelled} cancelled, {deadline_missed} \
         deadline-missed, {dropped} dropped, {errors} errors in {wall_s:.1}s \
         ({:.0} tok/s streamed)",
        client_tokens as f32 / wall_s
    );
    println!(
        "  TTFT p50 {:>8.1}ms  p99 {:>8.1}ms   (n={})",
        ttft.p50, ttft.p99, ttft.n
    );
    println!(
        "  ITL  p50 {:>8.1}ms  p99 {:>8.1}ms   (n={})",
        itl.p50, itl.p99, itl.n
    );

    let j = obj(vec![
        ("bench", "serving_load".into()),
        ("conns", conns.into()),
        ("max_batch", MAX_BATCH.into()),
        ("tokens_per_conn", TOKENS_PER_CONN.into()),
        ("pool_blocks", pool_blocks.into()),
        ("wall_s", (wall_s as f64).into()),
        ("submitted", (m.submitted.load(Ordering::Relaxed) as usize).into()),
        ("completed", (completed as usize).into()),
        ("cancelled", (cancelled as usize).into()),
        ("deadline_missed", (deadline_missed as usize).into()),
        ("aborted", (m.aborted.load(Ordering::Relaxed) as usize).into()),
        ("rejected", (m.rejected.load(Ordering::Relaxed) as usize).into()),
        (
            "tokens_streamed",
            (m.tokens_streamed.load(Ordering::Relaxed) as usize).into(),
        ),
        ("client_tokens", client_tokens.into()),
        ("client_errors", errors.into()),
        ("droppers", dropped.into()),
        ("tokens_per_s", (client_tokens as f64 / wall_s as f64).into()),
        (
            "ttft_ms",
            obj(vec![
                ("n", ttft.n.into()),
                ("p50", (ttft.p50 as f64).into()),
                ("p99", (ttft.p99 as f64).into()),
                ("mean", (ttft.mean as f64).into()),
            ]),
        ),
        (
            "itl_ms",
            obj(vec![
                ("n", itl.n.into()),
                ("p50", (itl.p50 as f64).into()),
                ("p99", (itl.p99 as f64).into()),
                ("mean", (itl.mean as f64).into()),
            ]),
        ),
        ("no_hung_lanes", balanced.into()),
    ]);
    let path = rrs::util::bench::bench_output_path("BENCH_serving.json");
    match std::fs::write(&path, j.dump()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }

    // shut the server down before the final verdict so the process exits
    if let Ok(mut c) = TcpStream::connect(("127.0.0.1", port)) {
        let _ = c.write_all(b"{\"cmd\": \"shutdown\"}\n");
        let mut line = String::new();
        let _ = BufReader::new(c).read_line(&mut line);
    }
    let _ = TcpStream::connect(("127.0.0.1", port));
    let _ = accept_handle.join();

    assert!(balanced, "no-hung-lanes ledger failed (see BENCH_serving.json)");
    assert!(
        ttft.n + dropped + errors >= conns * 9 / 10,
        "too few connections produced tokens: {} of {conns}",
        ttft.n
    );
}
