//! Prefill throughput + admitted concurrency over the paged KV pool:
//! 0% vs 90% shared-prefix workloads.
//!
//! Phase 1 (throughput): the shared workload prefills each distinct
//! prefix once and serves the rest from the prefix cache, so tokens/s
//! should rise sharply with the share ratio.
//!
//! Phase 2 (admitted concurrency): over a small fixed pool, keep
//! admitting live sequences until the prefix-aware gate refuses — the
//! count is deterministic block accounting, so the numbers are
//! machine-independent (recorded in README.md).
//!
//! Run: `cargo bench --bench kvpool_prefill` (add `--full` for the
//! larger workload)

use std::time::Instant;

use rrs::kvpool::PagedEngine;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};

const BLOCK_SIZE: usize = 8;
/// Pool size for the admitted-concurrency phase (small on purpose).
const ADMIT_BLOCKS: usize = 128;

fn engine_with(n_blocks: usize) -> PagedEngine {
    let mcfg = ModelConfig { n_layers: 2, max_seq: 256, ..Default::default() };
    let w = Weights::random(&mcfg, 9);
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&w, &mcfg, &ecfg, None, None).unwrap();
    PagedEngine::new(model, n_blocks, BLOCK_SIZE)
}

fn engine() -> PagedEngine {
    engine_with(1024)
}

/// Build `n` prompts of `len` tokens where the leading `shared` tokens
/// are identical across every prompt (0 => fully distinct workload).
fn prompts(n: usize, len: usize, shared: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| {
            (0..len)
                .map(|j| {
                    if j < shared {
                        (j as u32 * 13 + 7) % 256
                    } else {
                        ((i * 1009 + j * 31 + 11) % 256) as u32
                    }
                })
                .collect()
        })
        .collect()
}

fn bench_workload(label: &str, prompts: &[Vec<u32>]) -> f32 {
    let eng = engine();
    let total_tokens: usize = prompts.iter().map(Vec::len).sum();
    let t0 = Instant::now();
    for p in prompts {
        let mut seq = eng.new_seq();
        let _ = eng.prefill(&mut seq, p);
        // release immediately: sealed blocks stay in the prefix cache
        // (this is how retired requests feed later arrivals), and the
        // pool can never exhaust on the fully-distinct workload
        eng.release(&mut seq);
    }
    let dt = t0.elapsed().as_secs_f32();
    let s = eng.stats();
    let tps = total_tokens as f32 / dt;
    println!(
        "{label:<26} {:>4} prompts  {:>8.0} tok/s  hit {:>5.1}%  \
         occupancy {:>4}/{} blocks ({} evictions)",
        prompts.len(),
        tps,
        100.0 * s.prefix_hit_tokens as f32 / s.prefix_query_tokens.max(1) as f32,
        s.blocks_total - s.blocks_free,
        s.blocks_total,
        s.evictions,
    );
    tps
}

/// Admit live sequences until the prefix-aware gate refuses; every
/// admitted sequence stays resident, so the count is the concurrency the
/// pool sustains for this workload.  Pure block accounting: an 80-token
/// prompt costs ceil(81/8) = 11 blocks cold, but only its unshared
/// suffix (2 blocks) once the prefix is resident.
fn admitted_concurrency(label: &str, prompts: &[Vec<u32>]) -> usize {
    let eng = engine_with(ADMIT_BLOCKS);
    let mut seqs = Vec::new();
    for p in prompts {
        if !eng.can_admit(p) {
            break;
        }
        let mut seq = eng.new_seq();
        match eng.try_prefill(&mut seq, p) {
            Some(_) => seqs.push(seq),
            None => break,
        }
    }
    let s = eng.stats();
    println!(
        "{label:<26} {:>4} concurrent seqs  (pool {} x {} positions, \
         {} blocks pinned)",
        seqs.len(),
        s.blocks_total,
        BLOCK_SIZE,
        s.blocks_active,
    );
    seqs.len()
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, len) = if full { (64, 160) } else { (24, 80) };
    // 90% of each prompt is the shared prefix (block-aligned)
    let shared = (len * 9 / 10) / BLOCK_SIZE * BLOCK_SIZE;
    println!(
        "kvpool prefill bench: {n} prompts x {len} tokens (shared prefix \
         {shared} tokens)"
    );
    let cold = bench_workload("0% shared prefix", &prompts(n, len, 0));
    let warm = bench_workload("90% shared prefix", &prompts(n, len, shared));
    println!("shared-prefix speedup: {:.2}x", warm / cold.max(1e-9));

    // ── admitted concurrency under prefix-aware admission ──────────────
    let alen = 80usize;
    let ashared = (alen * 9 / 10) / BLOCK_SIZE * BLOCK_SIZE; // 72 tokens
    println!(
        "\nadmitted concurrency: {alen}-token prompts over {ADMIT_BLOCKS} \
         blocks (shared prefix {ashared} tokens)"
    );
    let c0 = admitted_concurrency("0% shared prefix", &prompts(96, alen, 0));
    let c90 = admitted_concurrency("90% shared prefix", &prompts(96, alen, ashared));
    println!(
        "prefix-aware admission concurrency gain: {:.2}x ({c0} -> {c90})",
        c90 as f32 / c0.max(1) as f32
    );
}
