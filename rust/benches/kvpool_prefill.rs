//! Prefill throughput over the paged KV pool: 0% vs 90% shared-prefix
//! workloads.  The shared workload prefills each distinct prefix once and
//! serves the rest from the prefix cache, so tokens/s should rise
//! sharply with the share ratio.
//!
//! Run: `cargo bench --bench kvpool_prefill` (add `--full` for the
//! larger workload)

use std::time::Instant;

use rrs::kvpool::PagedEngine;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};

const BLOCK_SIZE: usize = 8;

fn engine() -> PagedEngine {
    let mcfg = ModelConfig { n_layers: 2, max_seq: 256, ..Default::default() };
    let w = Weights::random(&mcfg, 9);
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&w, &mcfg, &ecfg, None, None).unwrap();
    PagedEngine::new(model, 1024, BLOCK_SIZE)
}

/// Build `n` prompts of `len` tokens where the leading `shared` tokens
/// are identical across every prompt (0 => fully distinct workload).
fn prompts(n: usize, len: usize, shared: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| {
            (0..len)
                .map(|j| {
                    if j < shared {
                        (j as u32 * 13 + 7) % 256
                    } else {
                        ((i * 1009 + j * 31 + 11) % 256) as u32
                    }
                })
                .collect()
        })
        .collect()
}

fn bench_workload(label: &str, prompts: &[Vec<u32>]) -> f32 {
    let eng = engine();
    let total_tokens: usize = prompts.iter().map(Vec::len).sum();
    let t0 = Instant::now();
    for p in prompts {
        let mut seq = eng.new_seq();
        let _ = eng.prefill(&mut seq, p);
        // release immediately: sealed blocks stay in the prefix cache
        // (this is how retired requests feed later arrivals), and the
        // pool can never exhaust on the fully-distinct workload
        eng.release(&mut seq);
    }
    let dt = t0.elapsed().as_secs_f32();
    let s = eng.stats();
    let tps = total_tokens as f32 / dt;
    println!(
        "{label:<26} {:>4} prompts  {:>8.0} tok/s  hit {:>5.1}%  \
         occupancy {:>4}/{} blocks ({} evictions)",
        prompts.len(),
        tps,
        100.0 * s.prefix_hit_tokens as f32 / s.prefix_query_tokens.max(1) as f32,
        s.blocks_total - s.blocks_free,
        s.blocks_total,
        s.evictions,
    );
    tps
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, len) = if full { (64, 160) } else { (24, 80) };
    // 90% of each prompt is the shared prefix (block-aligned)
    let shared = (len * 9 / 10) / BLOCK_SIZE * BLOCK_SIZE;
    println!(
        "kvpool prefill bench: {n} prompts x {len} tokens (shared prefix \
         {shared} tokens)"
    );
    let cold = bench_workload("0% shared prefix", &prompts(n, len, 0));
    let warm = bench_workload("90% shared prefix", &prompts(n, len, shared));
    println!("shared-prefix speedup: {:.2}x", warm / cold.max(1e-9));
}
