//! Prefill throughput + admitted concurrency + decode throughput over
//! the paged KV pool: 0% vs 90% shared-prefix workloads.
//!
//! Phase 1 (throughput): the shared workload prefills each distinct
//! prefix once and serves the rest from the prefix cache, so tokens/s
//! should rise sharply with the share ratio.
//!
//! Phase 2 (admitted concurrency): over a small fixed pool, keep
//! admitting live sequences until the prefix-aware gate refuses — the
//! count is deterministic block accounting, so the numbers are
//! machine-independent (recorded in README.md).
//!
//! Phase 3 (decode): batched steady-state decode tokens/s at 0% vs 90%
//! shared prefix on the interpreted engine, plus — when AOT artifacts
//! are present — the PJRT resident-lane fast path against its per-step
//! re-gather baseline at batch >= 8.  Results land in
//! `BENCH_decode.json` so the perf trajectory is recorded (CI uploads
//! `BENCH_*.json` as artifacts).
//!
//! Run: `cargo bench --bench kvpool_prefill` (add `--full` for the
//! larger workload)

use std::time::Instant;

use rrs::kvpool::{PagedEngine, PagedSeq};
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::runtime::PagedPjrtEngine;
use rrs::util::json::{obj, Json};

const BLOCK_SIZE: usize = 8;
/// Pool size for the admitted-concurrency phase (small on purpose).
const ADMIT_BLOCKS: usize = 128;

fn engine_with(n_blocks: usize) -> PagedEngine {
    let mcfg = ModelConfig { n_layers: 2, max_seq: 256, ..Default::default() };
    let w = Weights::random(&mcfg, 9);
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&w, &mcfg, &ecfg, None, None).unwrap();
    PagedEngine::new(model, n_blocks, BLOCK_SIZE)
}

fn engine() -> PagedEngine {
    engine_with(1024)
}

/// Build `n` prompts of `len` tokens where the leading `shared` tokens
/// are identical across every prompt (0 => fully distinct workload).
fn prompts(n: usize, len: usize, shared: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| {
            (0..len)
                .map(|j| {
                    if j < shared {
                        (j as u32 * 13 + 7) % 256
                    } else {
                        ((i * 1009 + j * 31 + 11) % 256) as u32
                    }
                })
                .collect()
        })
        .collect()
}

fn bench_workload(label: &str, prompts: &[Vec<u32>]) -> f32 {
    let eng = engine();
    let total_tokens: usize = prompts.iter().map(Vec::len).sum();
    let t0 = Instant::now();
    for p in prompts {
        let mut seq = eng.new_seq();
        let _ = eng.try_prefill(&mut seq, p).expect("prefill");
        // release immediately: sealed blocks stay in the prefix cache
        // (this is how retired requests feed later arrivals), and the
        // pool can never exhaust on the fully-distinct workload
        eng.release(&mut seq);
    }
    let dt = t0.elapsed().as_secs_f32();
    let s = eng.stats();
    let tps = total_tokens as f32 / dt;
    println!(
        "{label:<26} {:>4} prompts  {:>8.0} tok/s  hit {:>5.1}%  \
         occupancy {:>4}/{} blocks ({} evictions)",
        prompts.len(),
        tps,
        100.0 * s.prefix_hit_tokens as f32 / s.prefix_query_tokens.max(1) as f32,
        s.blocks_total - s.blocks_free,
        s.blocks_total,
        s.evictions,
    );
    tps
}

/// Admit live sequences until the prefix-aware gate refuses; every
/// admitted sequence stays resident, so the count is the concurrency the
/// pool sustains for this workload.  Pure block accounting: an 80-token
/// prompt costs ceil(81/8) = 11 blocks cold, but only its unshared
/// suffix (2 blocks) once the prefix is resident.
fn admitted_concurrency(label: &str, prompts: &[Vec<u32>]) -> usize {
    let eng = engine_with(ADMIT_BLOCKS);
    let mut seqs = Vec::new();
    for p in prompts {
        if !eng.can_admit(p) {
            break;
        }
        let mut seq = eng.new_seq();
        match eng.try_prefill(&mut seq, p) {
            Some(_) => seqs.push(seq),
            None => break,
        }
    }
    let s = eng.stats();
    println!(
        "{label:<26} {:>4} concurrent seqs  (pool {} x {} positions, \
         {} blocks pinned)",
        seqs.len(),
        s.blocks_total,
        BLOCK_SIZE,
        s.blocks_active,
    );
    seqs.len()
}

/// Phase 3a: admit `n_seqs` sequences, then measure batched decode
/// throughput (tokens/s) over `steps` steady-state steps.
fn bench_decode(label: &str, n_seqs: usize, len: usize, shared: usize, steps: usize) -> f32 {
    let eng = engine();
    let ps = prompts(n_seqs, len, shared);
    let mut seqs: Vec<PagedSeq> = ps
        .iter()
        .map(|p| {
            let mut s = eng.new_seq();
            let _ = eng.try_prefill(&mut s, p).expect("prefill");
            s
        })
        .collect();
    let t0 = Instant::now();
    for step in 0..steps {
        let mut batch: Vec<(&mut PagedSeq, u32)> = seqs
            .iter_mut()
            .map(|s| (s, (step % 250) as u32))
            .collect();
        let _ = eng.decode(&mut batch);
    }
    let dt = t0.elapsed().as_secs_f32();
    let tps = (steps * n_seqs) as f32 / dt;
    println!(
        "{label:<26} {n_seqs:>4} seqs x {steps} steps  {tps:>8.0} tok/s (decode)"
    );
    for s in seqs.iter_mut() {
        eng.release(s);
    }
    tps
}

/// Phase 3b (artifacts-gated): PJRT decode at batch >= 8 with lanes at
/// staggered positions — resident fast path vs the per-step re-gather
/// baseline.  Returns `(tps_resident, tps_regather)`.
fn bench_pjrt_decode(n_seqs: usize, steps: usize) -> Option<(f32, f32)> {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(root).join("manifest.json").exists() {
        println!("pjrt decode phase skipped: artifacts missing");
        return None;
    }
    // size the pool for the full workload: every sequence ends at
    // prompt (4 + 3i) + 1 warm + `steps` decoded positions
    let n_blocks = (0..n_seqs)
        .map(|i| (4 + 3 * i + 1 + steps).div_ceil(4) + 1)
        .sum::<usize>()
        + 16;
    let run = |resident: bool| -> f32 {
        let mut eng = PagedPjrtEngine::new(root, "fp", n_blocks, 4).unwrap();
        eng.set_residency(resident);
        // staggered prompt lengths -> unequal lane positions
        let mut seqs: Vec<PagedSeq> = (0..n_seqs)
            .map(|i| {
                let p: Vec<u32> = (0..4 + 3 * i as u32).map(|j| 30 + j % 90).collect();
                let mut s = eng.new_seq();
                eng.try_prefill(&mut s, &p).unwrap().unwrap();
                s
            })
            .collect();
        // warm the resident lanes (and the compiled graph) outside the clock
        let mut warm: Vec<(&mut PagedSeq, u32)> =
            seqs.iter_mut().map(|s| (s, 40u32)).collect();
        eng.decode(&mut warm).unwrap();
        drop(warm);
        let t0 = Instant::now();
        for step in 0..steps {
            let mut batch: Vec<(&mut PagedSeq, u32)> = seqs
                .iter_mut()
                .map(|s| (s, (40 + step % 50) as u32))
                .collect();
            eng.decode(&mut batch).unwrap();
        }
        let dt = t0.elapsed().as_secs_f32();
        let rs = eng.residency_stats();
        let mode = if eng.residency_enabled() { "resident" } else { "re-gather" };
        let tps = (steps * n_seqs) as f32 / dt;
        println!(
            "pjrt decode ({mode:<9})      {n_seqs:>4} seqs x {steps} steps  \
             {tps:>8.0} tok/s  ({} gathers, {} graph calls)",
            rs.kv_gather_total, rs.decode_graph_calls
        );
        for s in seqs.iter_mut() {
            eng.release(s);
        }
        tps
    };
    let regather = run(false);
    let resident = run(true);
    println!(
        "resident-lane decode speedup: {:.2}x",
        resident / regather.max(1e-9)
    );
    Some((resident, regather))
}

fn write_bench_decode_json(
    batch: usize,
    steps: usize,
    tps0: f32,
    tps90: f32,
    pjrt: Option<(f32, f32)>,
) {
    let pjrt_json = match pjrt {
        Some((resident, regather)) => obj(vec![
            ("tokens_per_s_resident", (resident as f64).into()),
            ("tokens_per_s_regather", (regather as f64).into()),
            (
                "resident_speedup",
                ((resident / regather.max(1e-9)) as f64).into(),
            ),
        ]),
        None => Json::Null,
    };
    let j = obj(vec![
        ("bench", "kvpool_decode".into()),
        ("batch", batch.into()),
        ("steps", steps.into()),
        (
            "interpreted",
            obj(vec![
                ("tokens_per_s_shared0", (tps0 as f64).into()),
                ("tokens_per_s_shared90", (tps90 as f64).into()),
            ]),
        ),
        ("pjrt", pjrt_json),
    ]);
    let path = rrs::util::bench::bench_output_path("BENCH_decode.json");
    match std::fs::write(&path, j.dump()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, len) = if full { (64, 160) } else { (24, 80) };
    // 90% of each prompt is the shared prefix (block-aligned)
    let shared = (len * 9 / 10) / BLOCK_SIZE * BLOCK_SIZE;
    println!(
        "kvpool prefill bench: {n} prompts x {len} tokens (shared prefix \
         {shared} tokens)"
    );
    let cold = bench_workload("0% shared prefix", &prompts(n, len, 0));
    let warm = bench_workload("90% shared prefix", &prompts(n, len, shared));
    println!("shared-prefix speedup: {:.2}x", warm / cold.max(1e-9));

    // ── admitted concurrency under prefix-aware admission ──────────────
    let alen = 80usize;
    let ashared = (alen * 9 / 10) / BLOCK_SIZE * BLOCK_SIZE; // 72 tokens
    println!(
        "\nadmitted concurrency: {alen}-token prompts over {ADMIT_BLOCKS} \
         blocks (shared prefix {ashared} tokens)"
    );
    let c0 = admitted_concurrency("0% shared prefix", &prompts(96, alen, 0));
    let c90 = admitted_concurrency("90% shared prefix", &prompts(96, alen, ashared));
    println!(
        "prefix-aware admission concurrency gain: {:.2}x ({c0} -> {c90})",
        c90 as f32 / c0.max(1) as f32
    );

    // ── batched decode throughput (steady state) ───────────────────────
    let (dbatch, dsteps) = if full { (16, 96) } else { (8, 48) };
    let dlen = 48usize;
    let dshared = (dlen * 9 / 10) / BLOCK_SIZE * BLOCK_SIZE;
    println!(
        "\ndecode: batch {dbatch} x {dlen}-token prompts (shared prefix \
         {dshared} tokens)"
    );
    let d0 = bench_decode("0% shared prefix", dbatch, dlen, 0, dsteps);
    let d90 = bench_decode("90% shared prefix", dbatch, dlen, dshared, dsteps);
    let pjrt = bench_pjrt_decode(dbatch, dsteps);
    write_bench_decode_json(dbatch, dsteps, d0, d90, pjrt);
}
