//! Coordinator-overhead bench: queue throughput and scheduler cost over a
//! no-op engine — isolates L3 so it provably is not the bottleneck
//! (DESIGN.md section 8: L3 target).
//!
//! Run: `cargo bench --bench coordinator`

use std::sync::Arc;
use std::time::Instant;

use rrs::coordinator::{Coordinator, EngineError, SchedulerConfig, ServeEngine};
use rrs::linalg::gemm::Mat;
use rrs::model::sampler::Sampling;
use rrs::util::bench::{black_box, Bencher};

/// Engine that does no math: measures pure coordination cost.
struct NullEngine {
    vocab: usize,
}

struct NullSeq {
    len: usize,
}

impl ServeEngine for NullEngine {
    type Seq = NullSeq;

    fn max_seq(&self) -> usize {
        4096
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn new_seq(&self) -> NullSeq {
        NullSeq { len: 0 }
    }

    fn try_prefill(&self, seq: &mut NullSeq, tokens: &[u32]) -> Option<Vec<f32>> {
        seq.len += tokens.len();
        Some(vec![0.0; self.vocab])
    }

    fn decode(&self, batch: &mut [(&mut NullSeq, u32)]) -> Result<Mat, EngineError> {
        for (seq, _) in batch.iter_mut() {
            seq.len += 1;
        }
        Ok(Mat::zeros(batch.len(), self.vocab))
    }

    fn seq_len(&self, seq: &NullSeq) -> usize {
        seq.len
    }

    fn seq_bytes(&self, _seq: &NullSeq) -> usize {
        0
    }
}

fn main() {
    // queue micro-bench
    let b = Bencher::default();
    let q = rrs::coordinator::RequestQueue::new(1_000_000);
    let (tx, _rx) = std::sync::mpsc::channel();
    let mut i = 0u64;
    let r = b.run("queue submit+drain", || {
        let req = rrs::coordinator::Request::new(
            i,
            vec![1, 2, 3],
            rrs::coordinator::RequestOptions {
                max_new_tokens: 4,
                ..Default::default()
            },
            tx.clone(),
        );
        i += 1;
        q.submit(req).unwrap();
        black_box(q.drain_now(1));
    });
    println!("{}", r.report_line());

    // end-to-end coordination cost per generated token (no model math)
    for max_batch in [1usize, 4, 16] {
        let coord = Arc::new(Coordinator::start(
            NullEngine { vocab: 256 },
            SchedulerConfig { max_batch, queue_capacity: 4096, ..Default::default() },
        ).expect("start coordinator"));
        let n_req = 256;
        let toks_per = 16;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for j in 0..n_req {
            let c = coord.clone();
            handles.push(std::thread::spawn(move || {
                c.generate(vec![j as u32 % 250 + 1, 2, 3], toks_per,
                           Sampling::Greedy, None)
                    .unwrap()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let dt = t0.elapsed().as_secs_f32();
        let tokens = (n_req * toks_per) as f32;
        println!(
            "null-engine serving: max_batch={max_batch:>2} {} reqs x {} toks \
             -> {:.0} tokens/s ({:.1} us/token coordination overhead)",
            n_req, toks_per, tokens / dt, 1e6 * dt / tokens
        );
    }
}
