//! Rotation-cost bench: FWHT (O(K log K)) vs dense orthogonal matmul
//! (O(K^2)) per token, across K — quantifies the online-rotation overhead
//! QuaRot/RRS pay and why Hadamard (not a learned dense rotation) is the
//! deployable choice (paper 4.2 note on SpinQuant's cost).
//!
//! Run: `cargo bench --bench hadamard`

use rrs::linalg::fwht::hadamard_dense;
use rrs::linalg::gemm::{gemm_f32, Mat};
use rrs::quant::rotation::Rotation;
use rrs::util::bench::{black_box, Bencher};
use rrs::util::rng::Pcg;

fn main() {
    let b = Bencher::default();
    let rows = 64;
    for k in [128usize, 256, 512, 1024] {
        let mut rng = Pcg::new(k as u64);
        let x = Mat::from_vec(rows, k, rng.normal_vec(rows * k));
        let rot = Rotation::Hadamard;
        let r_fwht = b.run(&format!("fwht {rows}x{k}"), || {
            black_box(rot.apply(&x));
        });
        let h = Mat::from_vec(k, k, hadamard_dense(k));
        let r_dense = b.run(&format!("dense {rows}x{k}"), || {
            black_box(gemm_f32(&x, &h));
        });
        println!("{}", r_fwht.report_line());
        println!(
            "{}  (dense/fwht = {:.1}x)",
            r_dense.report_line(),
            r_dense.ns_per_iter() / r_fwht.ns_per_iter()
        );
    }
}
