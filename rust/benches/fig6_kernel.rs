//! Figure 6 bench: kernel latency of FP32 / per-channel A4W4 /
//! sub-channel A4W4 / RS-fused A4W4 across batch sizes.
//!
//! The paper's NVBench RTX-4070-Ti comparison maps to our CPU INT4
//! kernels; dims scaled from LLaMA-7B to single-core wallclock.  The
//! claim under test is *relative*: RS-fusion ~ per-channel A4W4 cost,
//! sub-channel visibly slower (scale matrices in the epilogue).
//!
//! Run: `cargo bench --bench fig6_kernel [-- --full]`

use rrs::harness::fig6::measure;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (k, m) = if full { (2048, 2048) } else { (1024, 1024) };
    let batches: &[usize] =
        if full { &[1, 16, 64, 128, 256, 512] } else { &[1, 16, 64, 128] };
    println!("fig6 kernel bench, K=M={k} (quick={})", !full);
    println!(
        "{:>6} {:>12} {:>16} {:>16} {:>14} {:>10} {:>10}",
        "batch", "fp32(us)", "per-chan(us)", "sub-chan(us)", "rs-fused(us)",
        "rs-ovhd", "sub-ovhd"
    );
    for &b in batches {
        let r = measure(b, k, m, !full);
        println!(
            "{:>6} {:>12.1} {:>16.1} {:>16.1} {:>14.1} {:>9.1}% {:>9.1}%",
            r.batch,
            r.fp32_us,
            r.per_channel_us,
            r.sub_channel_us,
            r.rs_fused_us,
            100.0 * (r.rs_fused_us / r.per_channel_us - 1.0),
            100.0 * (r.sub_channel_us / r.per_channel_us - 1.0),
        );
    }
}
