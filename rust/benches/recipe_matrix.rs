//! Recipe-matrix ablation bench: sweep every composable quantization
//! recipe in [`QuantRecipe::matrix`] and record perplexity + decode
//! throughput per cell, writing `BENCH_matrix.json` at the repository
//! root (same payload the `rrs harness matrix` command emits on trained
//! artifacts; here the model is the small random stand-in, so the file
//! is tagged `smoke`).
//!
//! Run: `cargo bench --bench recipe_matrix`

use std::time::Instant;

use rrs::harness::matrix::{to_json, MatrixCell};
use rrs::model::{EngineConfig, KvCache, ModelConfig, QuantModel, Weights};
use rrs::quant::QuantRecipe;

const STEPS: usize = 100;

fn main() {
    let mcfg = ModelConfig { n_layers: 2, max_seq: 256, ..Default::default() };
    let w = Weights::random(&mcfg, 42);
    let calib: Vec<u32> = (0..512u32).map(|i| (i * 53 + 7) % 256).collect();
    let text = "the quick brown fox jumps over the lazy dog. ".repeat(64);
    println!("recipe matrix bench: {} cells x {STEPS} decode steps", QuantRecipe::matrix().len());

    let mut cells = Vec::new();
    for recipe in QuantRecipe::matrix() {
        let ecfg = EngineConfig::from_recipe(recipe);
        let model = QuantModel::prepare(&w, &mcfg, &ecfg, Some(&calib), None).unwrap();
        let ppl = rrs::eval::perplexity(&model, &text, 64, 4);
        let prompt: Vec<u32> = (1u32..17).collect();
        let mut cache = KvCache::new(&mcfg, &ecfg);
        model.forward_full(&prompt, Some(&mut cache));
        let mut tok = 3u32;
        let mut step = |cache: &mut KvCache, tok: &mut u32| {
            let mut batch = [(&mut *cache, *tok)];
            let logits = model.decode_batch(&mut batch);
            *tok = (logits.row(0)[0].abs() as u32 % 250) + 1;
        };
        for _ in 0..10 {
            step(&mut cache, &mut tok);
        }
        let t0 = Instant::now();
        for _ in 0..STEPS {
            step(&mut cache, &mut tok);
        }
        let tps = STEPS as f32 / t0.elapsed().as_secs_f32().max(1e-9);
        println!("  {:<24} ppl {:>10.2}  {:>8.0} tok/s", recipe.label(), ppl, tps);
        cells.push(MatrixCell { recipe, ppl, qa_avg: 0.0, decode_tps: tps });
    }

    let path = rrs::util::bench::bench_output_path("BENCH_matrix.json");
    match std::fs::write(&path, to_json(&cells, true).dump()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}
