//! GEMM kernel-layer bench: the staged scalar reference path vs the
//! runtime-dispatched packed microkernel, on decode- and prefill-shaped
//! problems.
//!
//! * decode shape — a handful of token rows against a wide weight
//!   (memory-bound over the weight: this is where consuming the
//!   nibble-packed weight directly halves the traffic);
//! * prefill shape — many rows, square-ish weight (compute-bound).
//!
//! Three measurements per shape: the raw INT4 igemm (unpacked reference
//! `igemm_i8_bt` vs dispatched packed), the fused RRS GEMM (staged
//! `forward_rs_fused_prepermuted` vs dispatched), and the RRS prologue
//! (staged vs fused).  Results land in `BENCH_gemm.json` (CI uploads all
//! `BENCH_*.json`), tagged with the live backend + autotuned tile so the
//! perf trajectory is attributable.  Set `RRS_KERNEL=scalar` for an A/B
//! of the dispatch itself.
//!
//! Run: `cargo bench --bench gemm_kernels` (add `--full` for bigger
//! shapes)

use rrs::kernels;
use rrs::linalg::igemm::{igemm_i8_bt, MatI8};
use rrs::quant::pack4::PackedI4;
use rrs::quant::qlinear::forward_rs_fused_prepermuted;
use rrs::quant::{rtn, runtime_smooth};
use rrs::util::bench::{black_box, Bencher};
use rrs::util::json::{obj, Json};
use rrs::util::rng::Pcg;

struct ShapeResult {
    name: &'static str,
    n: usize,
    k: usize,
    m: usize,
    igemm_ref_ns: f32,
    igemm_disp_ns: f32,
    rs_ref_ns: f32,
    rs_disp_ns: f32,
    prologue_ref_ns: f32,
    prologue_disp_ns: f32,
}

fn rand_codes(rng: &mut Pcg, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.below(15) as i8 - 7).collect()
}

fn measure(name: &'static str, n: usize, k: usize, m: usize, quick: bool) -> ShapeResult {
    let mut rng = Pcg::new(0xBE7C);
    let x = rrs::linalg::gemm::Mat::from_vec(n, k, rng.normal_vec(n * k));
    let a = MatI8::from_vec(n, k, rand_codes(&mut rng, n * k));
    let wq = MatI8::from_vec(m, k, rand_codes(&mut rng, m * k));
    let sw: Vec<f32> = (0..m).map(|j| 0.01 + (j % 13) as f32 * 1e-3).collect();
    let group = 128.min(k);
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };

    // raw INT4 igemm: unpacked i8 reference vs packed dispatched
    let r_ref = bencher.run("igemm ref", || {
        black_box(igemm_i8_bt(&a, &wq));
    });
    let bp = PackedI4::pack(&wq);
    let r_disp = bencher.run("igemm dispatched", || {
        black_box(kernels::igemm_packed(&a, &bp));
    });

    // fused RRS GEMM over a pre-permuted weight (the sticky-perm hot
    // loop): staged reference vs dispatched packed kernel
    let sa = runtime_smooth::prepare_staged(&x, group);
    let wqp = wq.permute_cols(&sa.perm);
    let bpp = PackedI4::pack(&wqp);
    let f_ref = bencher.run("rs fused ref", || {
        black_box(forward_rs_fused_prepermuted(&sa, &wqp, &sw));
    });
    let f_disp = bencher.run("rs fused dispatched", || {
        black_box(kernels::gemm_rs_fused_packed(
            &sa.q,
            &sa.token_scales,
            sa.group,
            &sa.group_scales,
            &bpp,
            &sw,
        ));
    });

    // activation prologue: staged passes vs fused kernel
    let p_ref = bencher.run("prologue ref", || {
        black_box(runtime_smooth::prepare_staged(&x, group));
    });
    let p_disp = bencher.run("prologue dispatched", || {
        black_box(runtime_smooth::prepare(&x, group));
    });

    let r = ShapeResult {
        name,
        n,
        k,
        m,
        igemm_ref_ns: r_ref.ns_per_iter(),
        igemm_disp_ns: r_disp.ns_per_iter(),
        rs_ref_ns: f_ref.ns_per_iter(),
        rs_disp_ns: f_disp.ns_per_iter(),
        prologue_ref_ns: p_ref.ns_per_iter(),
        prologue_disp_ns: p_disp.ns_per_iter(),
    };
    println!(
        "{name:<8} [{n}x{k}x{m}]  igemm {:>10.0} -> {:>10.0} ns ({:.2}x)  \
         rs-fused {:>10.0} -> {:>10.0} ns ({:.2}x)  \
         prologue {:>9.0} -> {:>9.0} ns ({:.2}x)",
        r.igemm_ref_ns,
        r.igemm_disp_ns,
        r.igemm_ref_ns / r.igemm_disp_ns.max(1.0),
        r.rs_ref_ns,
        r.rs_disp_ns,
        r.rs_ref_ns / r.rs_disp_ns.max(1.0),
        r.prologue_ref_ns,
        r.prologue_disp_ns,
        r.prologue_ref_ns / r.prologue_disp_ns.max(1.0),
    );
    r
}

fn shape_json(r: &ShapeResult) -> Json {
    obj(vec![
        ("shape", r.name.into()),
        ("n", r.n.into()),
        ("k", r.k.into()),
        ("m", r.m.into()),
        ("igemm_ref_ns", (r.igemm_ref_ns as f64).into()),
        ("igemm_dispatched_ns", (r.igemm_disp_ns as f64).into()),
        (
            "igemm_speedup",
            ((r.igemm_ref_ns / r.igemm_disp_ns.max(1.0)) as f64).into(),
        ),
        ("rs_fused_ref_ns", (r.rs_ref_ns as f64).into()),
        ("rs_fused_dispatched_ns", (r.rs_disp_ns as f64).into()),
        (
            "rs_fused_speedup",
            ((r.rs_ref_ns / r.rs_disp_ns.max(1.0)) as f64).into(),
        ),
        ("prologue_ref_ns", (r.prologue_ref_ns as f64).into()),
        ("prologue_dispatched_ns", (r.prologue_disp_ns as f64).into()),
    ])
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let ks = kernels::stats();
    println!(
        "gemm_kernels bench: backend {} (tile {}, autotuned {}, {} us)",
        ks.backend,
        ks.tiles.label(),
        ks.autotuned,
        ks.autotune_us
    );
    // decode: small batch, wide weight (weight streaming dominates);
    // prefill: many rows, moderate weight
    let (dk, dm) = if full { (2048, 4096) } else { (1024, 2048) };
    let (pn, pk, pm) = if full { (256, 1024, 1024) } else { (96, 512, 512) };
    let decode = measure("decode", 8, dk, dm, !full);
    let prefill = measure("prefill", pn, pk, pm, !full);

    let j = obj(vec![
        ("bench", "gemm_kernels".into()),
        ("backend", ks.backend.into()),
        ("tile", Json::Str(ks.tiles.label())),
        ("autotuned", ks.autotuned.into()),
        ("shapes", Json::Arr(vec![shape_json(&decode), shape_json(&prefill)])),
    ]);
    let path = rrs::util::bench::bench_output_path("BENCH_gemm.json");
    match std::fs::write(&path, j.dump()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}
