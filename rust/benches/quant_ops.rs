//! Micro-bench of the quantization primitives on the serving hot path:
//! per-token RTN, runtime-smooth prepare (scales+perm+quant), nibble
//! packing, KV quant/dequant and the integer dot kernel.
//!
//! Run: `cargo bench --bench quant_ops`

use rrs::linalg::gemm::Mat;
use rrs::linalg::igemm::idot;
use rrs::quant::{kv::QuantVec, pack4, rtn, runtime_smooth};
use rrs::util::bench::{black_box, Bencher};
use rrs::util::rng::Pcg;

fn main() {
    let b = Bencher::default();
    let mut rng = Pcg::new(1);
    let x = Mat::from_vec(64, 1024, rng.normal_vec(64 * 1024));

    let r = b.run("quant_per_token 64x1024", || {
        black_box(rtn::quant_per_token(&x));
    });
    println!("{}", r.report_line());

    for group in [1usize, 128] {
        let r = b.run(&format!("rs_prepare 64x1024 g={group}"), || {
            black_box(runtime_smooth::prepare(&x, group));
        });
        println!("{}", r.report_line());
    }

    let codes: Vec<i8> = (0..4096).map(|i| ((i % 15) as i8) - 7).collect();
    let r = b.run("pack_i4 4096", || {
        black_box(pack4::pack_i4(&codes));
    });
    println!("{}", r.report_line());
    let packed = pack4::pack_i4(&codes);
    let r = b.run("unpack_i4 4096", || {
        black_box(pack4::unpack_i4(&packed, 4096));
    });
    println!("{}", r.report_line());

    let row = rng.normal_vec(128);
    let r = b.run("kv quantize 128 (g=32)", || {
        black_box(QuantVec::quantize(&row, 32));
    });
    println!("{}", r.report_line());
    let q = QuantVec::quantize(&row, 32);
    let mut out = vec![0.0f32; 128];
    let r = b.run("kv dequantize 128", || {
        q.dequantize_into(black_box(&mut out));
    });
    println!("{}", r.report_line());

    let a: Vec<i8> = (0..4096).map(|i| ((i % 13) as i8) - 6).collect();
    let c: Vec<i8> = (0..4096).map(|i| ((i % 11) as i8) - 5).collect();
    let r = b.run("idot 4096", || {
        black_box(idot(&a, &c));
    });
    println!(
        "{}  ({:.2} GMAC/s)",
        r.report_line(),
        4096.0 / r.ns_per_iter()
    );
}
