//! Observability overhead bench: batched INT4 RRS decode with the
//! quant-health sampler off vs on, locking in the "obs-off is within
//! run-to-run noise" budget from `docs/ARCHITECTURE.md`.
//!
//! Measures decode tokens/s five ways — sampler off twice (the noise
//! baseline), then period 16 (the recommended production rate), then
//! period 1 (every call, the worst case), then with the continuous
//! sampling profiler running at 99 Hz — and writes `BENCH_obs.json`
//! plus a sample folded-stack artifact `BENCH_obs_folded.txt` (CI
//! uploads both and asserts the off/off ratio, the period-16 overhead,
//! and < 3% profiler overhead).
//!
//! Run: `cargo bench --bench obs_overhead`

use std::time::Instant;

use rrs::model::{EngineConfig, KvCache, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::util::json::obj;

const BATCH: usize = 4;
const WARMUP: usize = 20;
const STEPS: usize = 200;

fn decode_tps(model: &QuantModel, mcfg: &ModelConfig, ecfg: &EngineConfig) -> f32 {
    let prompt: Vec<u32> = (1u32..9).collect();
    let mut caches: Vec<KvCache> = (0..BATCH)
        .map(|_| {
            let mut c = KvCache::new(mcfg, ecfg);
            model.forward_full(&prompt, Some(&mut c));
            c
        })
        .collect();
    let mut next = vec![1u32; BATCH];
    let mut step = |next: &mut [u32]| {
        let mut batch: Vec<(&mut KvCache, u32)> = caches
            .iter_mut()
            .zip(next.iter())
            .map(|(c, &t)| (c, t))
            .collect();
        let logits = model.decode_batch(&mut batch);
        for (i, t) in next.iter_mut().enumerate() {
            // cheap argmax-free "sampling": keep tokens in vocab range
            *t = (logits.row(i)[0].abs() as u32 % 250) + 1;
        }
    };
    for _ in 0..WARMUP {
        step(&mut next);
    }
    let t0 = Instant::now();
    for _ in 0..STEPS {
        step(&mut next);
    }
    (STEPS * BATCH) as f32 / t0.elapsed().as_secs_f32()
}

fn main() {
    let mcfg = ModelConfig { n_layers: 2, max_seq: 512, ..Default::default() };
    let w = Weights::random(&mcfg, 42);
    let ecfg = EngineConfig {
        method: Method::Rrs,
        scheme: Scheme::A4W4KV16,
        group: 32,
        kv_group: 32,
        alpha: 0.5,
        gptq: false,
        recipe: None,
    };
    let model = QuantModel::prepare(&w, &mcfg, &ecfg, None, None).unwrap();
    println!("obs overhead bench: {BATCH} seqs x {STEPS} decode steps (RRS A4W4)");

    rrs::obs::health::reset();
    rrs::obs::set_sample_every(0);
    let off_a = decode_tps(&model, &mcfg, &ecfg);
    let off_b = decode_tps(&model, &mcfg, &ecfg);
    rrs::obs::set_sample_every(16);
    let sampled16 = decode_tps(&model, &mcfg, &ecfg);
    rrs::obs::set_sample_every(1);
    let sampled1 = decode_tps(&model, &mcfg, &ecfg);
    rrs::obs::set_sample_every(0);
    // continuous profiler at 99 Hz, quant sampler off: isolates the
    // sweep-thread + live-stack cost from the probe cost above
    rrs::obs::profile::reset();
    rrs::obs::profile::start_at(99.0);
    let prof_tps = decode_tps(&model, &mcfg, &ecfg);
    rrs::obs::profile::pause();
    let prof_samples = rrs::obs::profile::samples_total();
    let folded = rrs::obs::profile::folded();

    let probes: u64 = rrs::obs::health::snapshot()
        .iter()
        .map(|(_, h)| h.probes)
        .sum();
    let off_mean = 0.5 * (off_a + off_b);
    let noise_ratio = off_a / off_b.max(1e-9);
    let pct = |on: f32| 100.0 * (off_mean - on) / off_mean.max(1e-9);
    println!("  obs off   : {off_a:>8.0} / {off_b:>8.0} tok/s (ratio {noise_ratio:.3})");
    println!(
        "  period 16 : {sampled16:>8.0} tok/s ({:+.1}% vs off)",
        pct(sampled16)
    );
    println!(
        "  period 1  : {sampled1:>8.0} tok/s ({:+.1}% vs off, {probes} probes)",
        pct(sampled1)
    );
    println!(
        "  prof 99Hz : {prof_tps:>8.0} tok/s ({:+.1}% vs off, {prof_samples} samples)",
        pct(prof_tps)
    );

    let j = obj(vec![
        ("bench", "obs_overhead".into()),
        ("batch", BATCH.into()),
        ("steps", STEPS.into()),
        ("off_tps_a", (off_a as f64).into()),
        ("off_tps_b", (off_b as f64).into()),
        ("off_noise_ratio", (noise_ratio as f64).into()),
        ("sampled16_tps", (sampled16 as f64).into()),
        ("sampled16_overhead_pct", (pct(sampled16) as f64).into()),
        ("sampled1_tps", (sampled1 as f64).into()),
        ("sampled1_overhead_pct", (pct(sampled1) as f64).into()),
        ("prof_hz", 99.0f64.into()),
        ("prof_tps", (prof_tps as f64).into()),
        ("prof_overhead_pct", (pct(prof_tps) as f64).into()),
        ("prof_samples", (prof_samples as usize).into()),
        ("probes_recorded", (probes as usize).into()),
    ]);
    let path = rrs::util::bench::bench_output_path("BENCH_obs.json");
    match std::fs::write(&path, j.dump()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
    // a ready-to-render flamegraph collapse sample per run (CI artifact)
    let fpath = rrs::util::bench::bench_output_path("BENCH_obs_folded.txt");
    match std::fs::write(&fpath, &folded) {
        Ok(()) => println!("wrote {} ({} stacks)", fpath.display(), folded.lines().count()),
        Err(e) => println!("could not write {}: {e}", fpath.display()),
    }
}
