//! End-to-end serving bench: the full coordinator + rust INT4 engine on
//! the trained model (artifacts) or a random model (fallback), reporting
//! the paper-relevant serving metrics: token throughput + latency
//! percentiles per (method, scheme).
//!
//! Run: `cargo bench --bench e2e_serving`

use std::sync::Arc;
use std::time::Instant;

use rrs::coordinator::{Coordinator, RustServeEngine, SchedulerConfig};
use rrs::model::sampler::Sampling;
use rrs::model::{tokenizer, EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};

fn load_weights() -> (Weights, ModelConfig, Vec<u32>) {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if let Ok(artifacts) = rrs::runtime::Artifacts::load(root) {
        let mcfg = artifacts.model;
        if let Ok(w) = Weights::load(artifacts.weights_path(), &mcfg) {
            let val = artifacts.val_text().unwrap_or_default();
            let toks = tokenizer::encode(&val);
            let calib: Vec<u32> =
                (0..8).flat_map(|i| toks[i * 64..i * 64 + 64].to_vec()).collect();
            return (w, mcfg, calib);
        }
    }
    eprintln!("artifacts missing; benching a random model");
    let mcfg = ModelConfig::default();
    let w = Weights::random(&mcfg, 9);
    let calib: Vec<u32> = (0..512u32).map(|i| (i * 53 + 7) % 256).collect();
    (w, mcfg, calib)
}

fn bench_config(
    w: &Weights,
    mcfg: &ModelConfig,
    calib: &[u32],
    method: Method,
    scheme: Scheme,
    n_req: usize,
    max_new: usize,
) {
    let ecfg = EngineConfig {
        method,
        scheme,
        group: 128,
        kv_group: 128,
        alpha: 0.5,
        gptq: method != Method::Rtn && method != Method::Fp,
        recipe: None,
    };
    let model = QuantModel::prepare(w, mcfg, &ecfg, Some(calib), None).unwrap();
    let label = ecfg.label();
    let coord = Arc::new(Coordinator::start(
        RustServeEngine::new(model),
        SchedulerConfig { max_batch: 8, queue_capacity: 256, ..Default::default() },
    ).expect("start coordinator"));
    let prompts = ["arlo is", "count: 1 2 3", "the fox named", "senna likes"];
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for j in 0..n_req {
        let c = coord.clone();
        let prompt = tokenizer::encode(prompts[j % prompts.len()]);
        handles.push(std::thread::spawn(move || {
            c.generate(prompt, max_new, Sampling::Greedy, None).unwrap()
        }));
    }
    let mut total_tokens = 0usize;
    for h in handles {
        total_tokens += h.join().unwrap().tokens.len();
    }
    let dt = t0.elapsed().as_secs_f32();
    let lat = coord.metrics.total_summary();
    println!(
        "{label:<22} {n_req:>3} reqs  {:>7.1} tok/s  p50 {:>7.1}ms  p90 {:>7.1}ms",
        total_tokens as f32 / dt,
        lat.p50,
        lat.p90
    );
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (w, mcfg, calib) = load_weights();
    let (n_req, max_new) = if full { (64, 24) } else { (16, 12) };
    println!("e2e serving bench ({} reqs x {} new tokens)", n_req, max_new);
    for (method, scheme) in [
        (Method::Fp, Scheme::FP),
        (Method::Rtn, Scheme::A4W4KV4),
        (Method::QuaRot, Scheme::A4W4KV4),
        (Method::Rrs, Scheme::A4W4KV4),
        (Method::Rrs, Scheme::A4W4KV16),
    ] {
        bench_config(&w, &mcfg, &calib, method, scheme, n_req, max_new);
    }
}
