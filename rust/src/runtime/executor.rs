//! Graph execution on the PJRT CPU client + the PJRT-backed engine
//! (prefill for evaluation, stateful decode for serving).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::artifacts::{Artifacts, GraphInfo};

/// Typed host tensor crossing the PJRT boundary.
///
/// # Examples
///
/// ```
/// use rrs::runtime::executor::HostTensor;
///
/// let t = HostTensor::f32(vec![2, 3], vec![0.5; 6]);
/// assert_eq!(t.shape(), &[2, 3]);
/// assert_eq!(t.as_f32().unwrap().len(), 6);
/// ```
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } => shape,
            HostTensor::I32 { shape, .. } => shape,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            HostTensor::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// One compiled HLO graph, ready to execute.
pub struct GraphRunner {
    pub info: GraphInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl GraphRunner {
    /// Load HLO text, compile on the client.
    pub fn load(client: &xla::PjRtClient, info: &GraphInfo) -> Result<GraphRunner> {
        let path = info
            .file
            .to_str()
            .context("artifact path not utf-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compile {}", info.name))?;
        Ok(GraphRunner { info: info.clone(), exe })
    }

    /// Execute with host tensors; returns the tuple elements as host
    /// tensors (graphs are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "graph {} expects {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        for (spec, t) in self.info.inputs.iter().zip(inputs) {
            if spec.shape != t.shape() {
                bail!(
                    "graph {} input '{}' shape {:?} != {:?}",
                    self.info.name,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// PJRT-backed model engine over the AOT artifacts: the L2/L1 numerics
/// oracle and the FP serving reference.  One compiled executable per
/// (graph, variant); graphs are lazily loaded and cached.
pub struct PjrtEngine {
    pub artifacts: Artifacts,
    client: xla::PjRtClient,
    runners: crate::util::sync::Mutex<HashMap<String, std::sync::Arc<GraphRunner>>>,
}

/// Decode-side session state held by rust (caches live in host memory and
/// are round-tripped through the graph each step — the graph updates them
/// in place via dynamic_update_slice).
pub struct PjrtKvState {
    pub kcache: Vec<f32>,
    pub vcache: Vec<f32>,
    pub shape: Vec<usize>,
    pub pos: usize,
}

impl PjrtEngine {
    pub fn new(root: impl AsRef<std::path::Path>) -> Result<PjrtEngine> {
        let artifacts = Artifacts::load(root)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine {
            artifacts,
            client,
            runners: crate::util::sync::Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (or load+compile) a graph by name.
    pub fn runner(&self, name: &str) -> Result<std::sync::Arc<GraphRunner>> {
        {
            let map = crate::util::sync::lock_recover(&self.runners);
            if let Some(r) = map.get(name) {
                return Ok(r.clone());
            }
        }
        let info = self.artifacts.graph(name)?.clone();
        let runner = std::sync::Arc::new(GraphRunner::load(&self.client, &info)?);
        crate::util::sync::lock_recover(&self.runners)
            .insert(name.to_string(), runner.clone());
        Ok(runner)
    }

    /// Run a prefill graph (`prefill_{variant}`): tokens [B,T] -> logits
    /// flattened [B*T*vocab].
    pub fn prefill(&self, variant: &str, tokens: &[i32]) -> Result<HostTensor> {
        let name = format!("prefill_{variant}");
        let runner = self.runner(&name)?;
        let spec = &runner.info.inputs[0];
        if tokens.len() != spec.numel() {
            bail!(
                "prefill_{variant} wants {} tokens, got {}",
                spec.numel(),
                tokens.len()
            );
        }
        let input = HostTensor::i32(spec.shape.clone(), tokens.to_vec());
        let mut out = runner.run(&[input])?;
        Ok(out.remove(0))
    }

    /// Dense KV-cache tensor shape of the `decode_{variant}` graphs:
    /// `[n_layers, decode_batch, decode_max_t, n_kv_heads, head_dim]`.
    pub fn kv_cache_shape(&self) -> Vec<usize> {
        let cfg = &self.artifacts.model;
        vec![
            cfg.n_layers,
            self.artifacts.decode_batch,
            self.artifacts.decode_max_t,
            cfg.n_kv_heads,
            cfg.head_dim(),
        ]
    }

    /// Fresh decode KV state sized for `decode_{variant}` graphs.
    pub fn new_kv_state(&self) -> PjrtKvState {
        let shape = self.kv_cache_shape();
        let n: usize = shape.iter().product();
        PjrtKvState { kcache: vec![0.0; n], vcache: vec![0.0; n], shape, pos: 0 }
    }

    /// One decode step for a batch of B tokens (B = manifest decode batch).
    /// Returns logits `[B, vocab]` flattened; the KV state advances by one.
    pub fn decode_step(
        &self,
        variant: &str,
        tokens: &[i32],
        state: &mut PjrtKvState,
    ) -> Result<Vec<f32>> {
        if state.pos >= self.artifacts.decode_max_t {
            bail!("KV state full ({} positions)", state.pos);
        }
        let (logits, kc, vc) = self.decode_step_raw(
            variant,
            tokens,
            std::mem::take(&mut state.kcache),
            std::mem::take(&mut state.vcache),
            state.pos,
        )?;
        state.kcache = kc;
        state.vcache = vc;
        state.pos += 1;
        Ok(logits)
    }

    /// The stateless core of [`decode_step`](PjrtEngine::decode_step):
    /// run `decode_{variant}` over caller-owned dense caches (shape
    /// [`kv_cache_shape`](PjrtEngine::kv_cache_shape), flattened) with
    /// every lane at the same position `pos`, returning
    /// `(logits, kcache, vcache)` with the new row written at `pos`.
    /// Thin uniform-position wrapper over
    /// [`decode_step_lanes`](PjrtEngine::decode_step_lanes).
    pub fn decode_step_raw(
        &self,
        variant: &str,
        tokens: &[i32],
        kcache: Vec<f32>,
        vcache: Vec<f32>,
        pos: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let pos_lanes = vec![pos; self.artifacts.decode_batch];
        self.decode_step_lanes(variant, tokens, kcache, vcache, &pos_lanes)
    }

    /// Run `decode_{variant}` with one position per lane: lane `i`'s new
    /// row is written at `pos[i]` and its attention masks positions
    /// beyond `pos[i]`, so unequal-length sequences share one graph
    /// call.  On per-lane-position artifacts
    /// ([`Artifacts::decode_pos_width`] == batch) the positions pass
    /// straight through; legacy scalar-position artifacts accept only
    /// position-aligned lanes (an error otherwise).  This is the hot
    /// path the resident-lane paged backend
    /// ([`super::paged::PagedPjrtEngine`]) drives.
    pub fn decode_step_lanes(
        &self,
        variant: &str,
        tokens: &[i32],
        kcache: Vec<f32>,
        vcache: Vec<f32>,
        pos: &[usize],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let b = self.artifacts.decode_batch;
        if tokens.len() != b {
            bail!("decode batch is {b}, got {} tokens", tokens.len());
        }
        if pos.len() != b {
            bail!("decode batch is {b}, got {} lane positions", pos.len());
        }
        for &p in pos {
            if p >= self.artifacts.decode_max_t {
                bail!("decode position {p} out of range");
            }
        }
        let pos_input = if self.artifacts.decode_pos_width() == b {
            HostTensor::i32(vec![b], pos.iter().map(|&p| p as i32).collect())
        } else {
            let p0 = pos[0];
            if pos.iter().any(|&p| p != p0) {
                bail!(
                    "decode_{variant} takes a scalar position (legacy \
                     artifacts); lanes must be position-aligned"
                );
            }
            HostTensor::i32(vec![1], vec![p0 as i32])
        };
        let shape = self.kv_cache_shape();
        let runner = self.runner(&format!("decode_{variant}"))?;
        let inputs = vec![
            HostTensor::i32(vec![b, 1], tokens.to_vec()),
            HostTensor::f32(shape.clone(), kcache),
            HostTensor::f32(shape, vcache),
            pos_input,
        ];
        let out = runner.run(&inputs)?;
        let mut it = out.into_iter();
        let logits = it.next().context("decode output 0")?;
        let kc = it.next().context("decode output 1")?;
        let vc = it.next().context("decode output 2")?;
        let logits = match logits {
            HostTensor::F32 { data, .. } => data,
            _ => bail!("logits not f32"),
        };
        let kc = match kc {
            HostTensor::F32 { data, .. } => data,
            _ => bail!("kcache not f32"),
        };
        let vc = match vc {
            HostTensor::F32 { data, .. } => data,
            _ => bail!("vcache not f32"),
        };
        Ok((logits, kc, vc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checked() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn host_tensor_bad_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }
}
