//! Paged serving backend for the PJRT runtime: AOT-compiled decode
//! graphs whose KV memory lives in the same [`KvPool`] as the
//! interpreted engine's, served from **resident decode lanes**.
//!
//! The decode graph is stateless over dense host tensors (caches of
//! shape `[L, B, maxT, H, D]` round-tripped through every call, see
//! [`PjrtEngine::decode_step_lanes`]).  The *authoritative* KV rows
//! live in pool blocks — blocks store f32 rows for the PJRT path, so
//! every copy is bit-exact — while a [`LaneResidency`] keeps per-lane
//! dense copies alive between steps.  Steady-state decode is O(1) per
//! token: a lane whose `(id, epoch, rows)` tag still matches its
//! sequence skips the gather entirely, the graph appends the new row in
//! place (per-lane positions, so unequal-length sequences share one
//! call), and only that row is scattered back into the pool.  Lanes are
//! re-gathered only on admission, preemption/re-admission, or CoW
//! adoption (epoch/id changes — see [`crate::runtime::residency`]).
//!
//! Allocation, prefix sharing (full-block and partial-tail),
//! copy-on-write, and prefix-aware admission are *identical* to the
//! interpreted [`crate::kvpool::PagedEngine`] path: one pool-governed
//! scheduler serves every backend.  `rust/tests/runtime_paged.rs` and
//! `rust/tests/paged_churn.rs` assert the paged path is bit-identical
//! to the flat [`PjrtKvState`] path, across admission/preemption churn.
//!
//! [`PjrtKvState`]: super::executor::PjrtKvState

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::engine_iface::{EngineError, ServeEngine};
use crate::kvpool::engine::{begin_paged_prefill, seal_paged_seq};
use crate::kvpool::{BlockId, KvPool, KvPoolConfig, PagedSeq, PoolStats};
use crate::linalg::gemm::Mat;
use crate::util::sync::{lock_recover, Mutex};

use super::executor::PjrtEngine;
use super::residency::{LaneResidency, ResidencyStats};

/// Pool-governed serving engine over AOT-compiled `decode_{variant}`
/// graphs with resident decode lanes.  Implements [`ServeEngine`], so
/// the coordinator's scheduler drives it exactly like the interpreted
/// paged backend: block-gated admission, prompt-prefix reuse, and
/// preemption to the queue.
pub struct PagedPjrtEngine {
    rt: PjrtEngine,
    variant: String,
    pool: Mutex<KvPool>,
    resident: Mutex<LaneResidency>,
    n_layers: usize,
    /// K/V row width: `n_kv_heads * head_dim`.
    kv_dim: usize,
    /// Graph decode lanes (the manifest's fixed decode batch).
    lanes: usize,
    /// Positions per lane in the dense cache tensors.
    max_t: usize,
    vocab: usize,
    /// The decode graphs take one position input per lane (new
    /// artifacts); legacy scalar-position graphs force the re-gather
    /// path with equal-position lane grouping.
    per_lane_pos: bool,
    /// Resident fast path enabled (requires `per_lane_pos`; see
    /// [`set_residency`](PagedPjrtEngine::set_residency)).
    use_residency: bool,
}

// SAFETY: the xla handles (PJRT client + compiled executables) are only
// reached through `&self` methods of `PjrtEngine`, whose runner cache is
// internally locked, and the PJRT CPU client's execute path is
// thread-safe; the pool and the resident lanes sit behind their own
// mutexes (lock order: pool, then resident).  `Send + Sync` is what
// lets the coordinator move the engine onto its single worker thread
// (the `ServeEngine` bound).
unsafe impl Send for PagedPjrtEngine {}
unsafe impl Sync for PagedPjrtEngine {}

impl PagedPjrtEngine {
    /// Load the AOT artifacts under `root` and serve `decode_{variant}`
    /// over a pool of `n_blocks` blocks of `block_size` positions each.
    pub fn new(
        root: impl AsRef<std::path::Path>,
        variant: &str,
        n_blocks: usize,
        block_size: usize,
    ) -> Result<PagedPjrtEngine> {
        let rt = PjrtEngine::new(root)?;
        let m = rt.artifacts.model;
        let cfg = KvPoolConfig {
            n_blocks,
            block_size,
            n_layers: m.n_layers,
            // f32 rows: the graph round-trips f32 caches, so pool storage
            // must be bit-exact (quantized variants apply the paper's KV
            // fake-quant inside the graph itself)
            kv_bits: 32,
            kv_group: 1,
        };
        let lanes = rt.artifacts.decode_batch;
        let max_t = rt.artifacts.decode_max_t;
        let per_lane_pos = rt.artifacts.decode_pos_width() == lanes;
        let dense_len = m.n_layers * lanes * max_t * m.kv_dim();
        Ok(PagedPjrtEngine {
            variant: variant.to_string(),
            pool: Mutex::new(KvPool::new(cfg)),
            resident: Mutex::new(LaneResidency::new(lanes, dense_len)),
            n_layers: m.n_layers,
            kv_dim: m.kv_dim(),
            lanes,
            max_t,
            vocab: m.vocab,
            per_lane_pos,
            use_residency: per_lane_pos,
            rt,
        })
    }

    /// The graph variant served (`fp` / `rtn` / `rrs`).
    pub fn variant(&self) -> &str {
        &self.variant
    }

    /// `true` when the loaded artifacts lower a per-lane position input
    /// (unequal-length sequences share one decode call).
    pub fn per_lane_pos(&self) -> bool {
        self.per_lane_pos
    }

    /// `true` when decode runs on resident lanes (the O(1) fast path).
    pub fn residency_enabled(&self) -> bool {
        self.use_residency
    }

    /// Toggle the resident fast path — `false` forces the per-step
    /// re-gather baseline (what `cargo bench --bench kvpool_prefill`
    /// measures against).  Residency cannot be enabled on legacy
    /// scalar-position artifacts: a resident bank would have idle lanes
    /// clobbered at the shared position, so the request is ignored.
    pub fn set_residency(&mut self, on: bool) {
        self.use_residency = on && self.per_lane_pos;
    }

    /// Cumulative gather/scatter/refresh counters of the resident-lane
    /// subsystem (both paths count their gathers).
    pub fn residency_stats(&self) -> ResidencyStats {
        lock_recover(&self.resident).stats()
    }

    /// Create an empty paged sequence (same state type as the
    /// interpreted paged backend).
    pub fn new_seq(&self) -> PagedSeq {
        PagedSeq::new()
    }

    fn dense_len(&self) -> usize {
        self.n_layers * self.lanes * self.max_t * self.kv_dim
    }

    /// Flat offset of the row (layer, lane, pos) in the dense caches.
    fn row_off(&self, layer: usize, lane: usize, pos: usize) -> usize {
        ((layer * self.lanes + lane) * self.max_t + pos) * self.kv_dim
    }

    /// Gather a sequence's pooled rows into lane `lane` of the dense
    /// cache tensors (positions `[0, len)` from the pool).  `zero_tail`
    /// scrubs `[len, max_t)` so a *refreshed resident* lane is
    /// indistinguishable from a fresh flat state; callers packing into
    /// freshly zero-allocated buffers skip the redundant memset.
    fn pack_lane(
        &self,
        pool: &KvPool,
        table: &[BlockId],
        len: usize,
        lane: usize,
        kc: &mut [f32],
        vc: &mut [f32],
        zero_tail: bool,
    ) {
        let _phase =
            crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::KvGather);
        let mut ks: Vec<Vec<f32>> = Vec::new();
        let mut vs: Vec<Vec<f32>> = Vec::new();
        for layer in 0..self.n_layers {
            let (keys, vals) = pool.gather_rows(table, layer, &mut ks, &mut vs);
            for pos in 0..len {
                let off = self.row_off(layer, lane, pos);
                kc[off..off + self.kv_dim].copy_from_slice(&keys[pos]);
                vc[off..off + self.kv_dim].copy_from_slice(&vals[pos]);
            }
            if zero_tail {
                let tail = self.row_off(layer, lane, len);
                let end = self.row_off(layer, lane, self.max_t);
                kc[tail..end].fill(0.0);
                vc[tail..end].fill(0.0);
            }
        }
    }

    /// Scatter the step's new row (lane `lane`, position `pos`) for
    /// every layer from the returned dense caches into the pool.
    fn harvest_row(
        &self,
        pool: &mut KvPool,
        table: &mut Vec<BlockId>,
        kc: &[f32],
        vc: &[f32],
        lane: usize,
        pos: usize,
    ) {
        let _phase =
            crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::KvScatter);
        for layer in 0..self.n_layers {
            let off = self.row_off(layer, lane, pos);
            pool.append_row(
                table,
                layer,
                pos,
                &kc[off..off + self.kv_dim],
                &vc[off..off + self.kv_dim],
            );
        }
    }

    /// Fallible pool-governed prefill, the PJRT analog of
    /// [`PagedEngine::try_prefill`](crate::kvpool::PagedEngine::try_prefill):
    /// pin the cached prompt prefix, reserve the unshared suffix plus
    /// one decode-headroom block (`None` — sequence released — on
    /// exhaustion), then feed the suffix through the decode graph
    /// token-by-token, harvesting each new row into the pool.  Returns
    /// the last position's logits.
    pub fn try_prefill(
        &self,
        seq: &mut PagedSeq,
        tokens: &[u32],
    ) -> Result<Option<Vec<f32>>> {
        let mut pool = lock_recover(&self.pool);
        let Some(matched) = begin_paged_prefill(&mut pool, seq, tokens) else {
            return Ok(None);
        };
        let mut kc = vec![0.0f32; self.dense_len()];
        let mut vc = vec![0.0f32; self.dense_len()];
        self.pack_lane(&pool, &seq.table, matched, 0, &mut kc, &mut vc, false);
        {
            let mut res = lock_recover(&self.resident);
            res.note_gather();
        }
        let mut logits = Vec::new();
        for (i, &tok) in tokens[matched..].iter().enumerate() {
            let pos = matched + i;
            let step_toks = vec![tok as i32; self.lanes];
            let step = self.rt.decode_step_raw(&self.variant, &step_toks, kc, vc, pos);
            let (lg, kc2, vc2) = match step {
                Ok(out) => out,
                Err(e) => {
                    // graph failure: unpin everything so a Result-handling
                    // caller does not leak refcounted blocks
                    pool.release_seq(&mut seq.table);
                    *seq = PagedSeq::new();
                    return Err(e);
                }
            };
            logits = lg;
            kc = kc2;
            vc = vc2;
            self.harvest_row(&mut pool, &mut seq.table, &kc, &vc, 0, pos);
            seq.len += 1;
            let mut res = lock_recover(&self.resident);
            res.note_graph_call();
            res.note_scatter(self.n_layers as u64);
        }
        seal_paged_seq(&mut pool, seq);
        logits.truncate(self.vocab);
        Ok(Some(logits))
    }

    /// One pool-governed decode step for a batch of sequences.  With
    /// per-lane-position artifacts the batch runs on resident lanes:
    /// unequal-length sequences share one graph call per bank of
    /// `lanes` lanes, resident lanes skip the gather, and only the
    /// appended row is scattered back.  Legacy scalar-position artifacts
    /// (or [`set_residency`](PagedPjrtEngine::set_residency)`(false)`)
    /// fall back to grouping equal-position sequences and re-gathering
    /// each group.  Returns logits `[batch, vocab]`.  On a graph error
    /// the already-stepped sequences keep their advanced (valid) pool
    /// state, un-stepped sequences are rolled back to their pre-call
    /// state; the caller still owns every sequence and releases as
    /// usual.
    pub fn decode(&self, batch: &mut [(&mut PagedSeq, u32)]) -> Result<Mat> {
        let mut pool = lock_recover(&self.pool);
        let mut out = Mat::zeros(batch.len(), self.vocab);
        for (seq, tok) in batch.iter_mut() {
            seq.tokens.push(*tok);
            assert!(
                pool.reserve(&mut seq.table, seq.len + 1),
                "kvpool exhausted during decode (reserve_decode must gate)"
            );
        }
        let mut res = lock_recover(&self.resident);
        let stepped = if self.use_residency {
            self.decode_resident(&mut pool, &mut res, batch, &mut out)
        } else {
            self.decode_regather(&mut pool, &mut res, batch, &mut out)
        };
        if let Err(e) = stepped {
            // un-stepped sequences still carry the token pushed above
            // (tokens.len() == len + 1) with no KV row behind it: pop it
            // so the tokens/len invariant — and future prefix sealing —
            // stays sound
            for (seq, _) in batch.iter_mut() {
                if seq.tokens.len() == seq.len + 1 {
                    seq.tokens.pop();
                }
            }
            return Err(e);
        }
        Ok(out)
    }

    /// The O(1) fast path: resident banks, per-lane positions.
    fn decode_resident(
        &self,
        pool: &mut KvPool,
        res: &mut LaneResidency,
        batch: &mut [(&mut PagedSeq, u32)],
        out: &mut Mat,
    ) -> Result<()> {
        let occ: Vec<(u64, u64, usize)> =
            batch.iter().map(|(s, _)| (s.id, s.epoch, s.len)).collect();
        let plan = res.assign(&occ);
        for (i, a) in plan.iter().enumerate() {
            if a.refresh {
                let (kc, vc) = res.bank_buffers_mut(a.bank);
                let seq = &batch[i].0;
                self.pack_lane(pool, &seq.table, seq.len, a.lane, kc, vc, true);
            }
        }
        let mut by_bank: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, a) in plan.iter().enumerate() {
            by_bank.entry(a.bank).or_default().push(i);
        }
        for (&bank, items) in &by_bank {
            let mut toks = vec![0i32; self.lanes];
            let mut pos: Vec<usize> = (0..self.lanes)
                .map(|l| res.idle_pos(bank, l, self.max_t))
                .collect();
            for &i in items {
                let a = plan[i];
                toks[a.lane] = batch[i].1 as i32;
                pos[a.lane] = batch[i].0.len;
            }
            let (kc, vc) = res.take_bank_buffers(bank);
            let step = self.rt.decode_step_lanes(&self.variant, &toks, kc, vc, &pos);
            let (lg, kc2, vc2) = match step {
                Ok(x) => x,
                Err(e) => {
                    // the in-flight buffers are gone: restore a zeroed
                    // bank so no lane trusts stale data
                    res.reset_bank(bank);
                    return Err(e);
                }
            };
            res.note_graph_call();
            for &i in items {
                let a = plan[i];
                let seq = &mut *batch[i].0;
                let p = seq.len;
                self.harvest_row(pool, &mut seq.table, &kc2, &vc2, a.lane, p);
                res.note_scatter(self.n_layers as u64);
                seq.len += 1;
                seal_paged_seq(pool, seq);
                res.committed(a.bank, a.lane, seq.len);
                out.row_mut(i)
                    .copy_from_slice(&lg[a.lane * self.vocab..(a.lane + 1) * self.vocab]);
            }
            res.put_bank_buffers(bank, kc2, vc2);
        }
        Ok(())
    }

    /// The re-gather baseline (legacy scalar-position artifacts, and the
    /// benchmark comparison point): group sequences by equal position,
    /// pack every group's lanes from pool blocks, one graph call per
    /// group.
    fn decode_regather(
        &self,
        pool: &mut KvPool,
        res: &mut LaneResidency,
        batch: &mut [(&mut PagedSeq, u32)],
        out: &mut Mat,
    ) -> Result<()> {
        let mut order: Vec<usize> = (0..batch.len()).collect();
        order.sort_by_key(|&i| batch[i].0.len);
        let mut g0 = 0usize;
        while g0 < order.len() {
            let pos = batch[order[g0]].0.len;
            let mut g1 = g0 + 1;
            while g1 < order.len()
                && batch[order[g1]].0.len == pos
                && g1 - g0 < self.lanes
            {
                g1 += 1;
            }
            let group = &order[g0..g1];
            let mut kc = vec![0.0f32; self.dense_len()];
            let mut vc = vec![0.0f32; self.dense_len()];
            let mut toks = vec![batch[group[0]].1 as i32; self.lanes];
            for (lane, &i) in group.iter().enumerate() {
                self.pack_lane(pool, &batch[i].0.table, pos, lane, &mut kc, &mut vc, false);
                res.note_gather();
                toks[lane] = batch[i].1 as i32;
            }
            let (lg, kc2, vc2) =
                self.rt.decode_step_raw(&self.variant, &toks, kc, vc, pos)?;
            res.note_graph_call();
            for (lane, &i) in group.iter().enumerate() {
                self.harvest_row(pool, &mut batch[i].0.table, &kc2, &vc2, lane, pos);
                res.note_scatter(self.n_layers as u64);
                let seq = &mut *batch[i].0;
                seq.len += 1;
                seal_paged_seq(pool, seq);
                out.row_mut(i)
                    .copy_from_slice(&lg[lane * self.vocab..(lane + 1) * self.vocab]);
            }
            g0 = g1;
        }
        Ok(())
    }

    /// Release the sequence's blocks back to the pool (retire or
    /// preemption); sealed blocks stay cached for prefix reuse.  The
    /// sequence's resident lane is dropped eagerly (and trailing empty
    /// banks freed), and the fresh state carries a new identity, so a
    /// stale tag can never alias it.
    pub fn release(&self, seq: &mut PagedSeq) {
        let mut pool = lock_recover(&self.pool);
        pool.release_seq(&mut seq.table);
        lock_recover(&self.resident).invalidate_seq(seq.id);
        *seq = PagedSeq::new();
    }

    /// Prefix-aware admission gate — same accounting as the interpreted
    /// paged backend ([`KvPool::can_fit_prompt`]).
    pub fn can_admit(&self, prompt: &[u32]) -> bool {
        lock_recover(&self.pool).can_fit_prompt(prompt)
    }

    /// Ensure `seq` can grow by one token; `false` = preempt first.
    pub fn reserve_decode(&self, seq: &mut PagedSeq) -> bool {
        lock_recover(&self.pool).reserve(&mut seq.table, seq.len + 1)
    }

    /// Longest prompt prefix currently resident in the prefix cache.
    pub fn prefix_match_len(&self, prompt: &[u32]) -> usize {
        lock_recover(&self.pool).probe_prefix(prompt)
    }

    /// Pool occupancy / prefix-cache counters.
    pub fn stats(&self) -> PoolStats {
        lock_recover(&self.pool).stats()
    }

    /// KV bytes held by one sequence's blocks.
    pub fn seq_bytes(&self, seq: &PagedSeq) -> usize {
        lock_recover(&self.pool).table_bytes(&seq.table)
    }
}

impl ServeEngine for PagedPjrtEngine {
    type Seq = PagedSeq;

    fn max_seq(&self) -> usize {
        self.max_t
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn new_seq(&self) -> PagedSeq {
        PagedSeq::new()
    }

    fn try_prefill(&self, seq: &mut PagedSeq, tokens: &[u32]) -> Option<Vec<f32>> {
        match PagedPjrtEngine::try_prefill(self, seq, tokens) {
            Ok(r) => r,
            Err(e) => {
                // a graph failure is not a capacity refusal, but the
                // trait's `None` keeps the request queued; the inherent
                // try_prefill already released the sequence, and the
                // scheduler's empty-refusal counter aborts the request
                // if the failure persists
                eprintln!("rrs-runtime: pjrt prefill graph failed: {e:#}");
                None
            }
        }
    }

    fn decode(
        &self,
        batch: &mut [(&mut PagedSeq, u32)],
    ) -> Result<Mat, EngineError> {
        PagedPjrtEngine::decode(self, batch)
            .map_err(|e| EngineError(format!("pjrt decode graph failed: {e:#}")))
    }

    fn seq_len(&self, seq: &PagedSeq) -> usize {
        seq.len
    }

    fn seq_bytes(&self, seq: &PagedSeq) -> usize {
        PagedPjrtEngine::seq_bytes(self, seq)
    }

    fn can_admit(&self, prompt: &[u32]) -> bool {
        PagedPjrtEngine::can_admit(self, prompt)
    }

    fn prefix_match_len(&self, prompt: &[u32]) -> usize {
        PagedPjrtEngine::prefix_match_len(self, prompt)
    }

    fn reserve_decode(&self, seq: &mut PagedSeq) -> bool {
        PagedPjrtEngine::reserve_decode(self, seq)
    }

    fn release_seq(&self, seq: &mut PagedSeq) {
        PagedPjrtEngine::release(self, seq)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.stats())
    }

    fn residency_stats(&self) -> Option<ResidencyStats> {
        Some(PagedPjrtEngine::residency_stats(self))
    }
}
