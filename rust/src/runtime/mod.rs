//! PJRT runtime: load the AOT-lowered HLO-text artifacts and execute them
//! on the CPU PJRT client (`xla` crate).  This is the bridge between the
//! build-time python (L1 Pallas kernels + L2 JAX model) and the rust
//! request path — python never runs at serving time.
//!
//! Interchange is HLO **text**: jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! Serving runs through [`paged::PagedPjrtEngine`], which keeps the
//! decode graphs' KV rows in the shared paged pool
//! ([`crate::kvpool`]) — the AOT path and the interpreted path are
//! governed by the same allocator, prefix cache, and admission gates —
//! and serves steady-state decode from resident lanes
//! ([`residency::LaneResidency`]): O(1) per token, refreshed from the
//! pool only when a sequence's identity or epoch changes.

pub mod artifacts;
pub mod executor;
pub mod paged;
pub mod residency;

pub use artifacts::Artifacts;
pub use executor::{GraphRunner, PjrtEngine, PjrtKvState};
pub use paged::PagedPjrtEngine;
pub use residency::{LaneResidency, ResidencyStats};
