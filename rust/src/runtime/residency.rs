//! Resident decode lanes for the paged PJRT backend: the bookkeeping
//! that makes steady-state decode O(1) per token.
//!
//! The decode graphs round-trip dense cache tensors of shape
//! `[layers, lanes, max_t, kv_heads, head_dim]`.  Before this subsystem
//! the paged backend re-gathered every active sequence's pool blocks
//! into fresh dense tensors on **every** step — O(len) per token.  A
//! [`LaneResidency`] instead keeps the dense tensors alive between
//! steps, in *banks* of `lanes` lanes, and tags each lane with the
//! occupying sequence's `(id, epoch, rows)`.  A lane whose tag still
//! matches its sequence decodes straight from the resident copy; the
//! pool stays authoritative, and only the appended row is scattered
//! back per step.
//!
//! Lifecycle of a lane (see `docs/ARCHITECTURE.md`):
//!
//! ```text
//!               admission / preemption / CoW adoption (epoch or id change)
//!      ┌────────────────────────────────────────────────────────┐
//!      ▼                                                        │
//!   DIRTY ──gather [0,len) + zero tail──► RESIDENT ──decode──► RESIDENT
//!                                             │   (scatter appended row,
//!                                             │    rows += 1)
//!                                             └── LRU eviction when the
//!                                                 slot is reassigned
//! ```
//!
//! Invalidation rules — a resident copy is trusted only when **all** of
//! these hold, otherwise the lane refreshes from the pool:
//!
//! * the lane's `seq_id` equals the sequence's [`PagedSeq::id`]
//!   (release mints a fresh id, so recycled sequences never alias);
//! * the lane's `epoch` equals the sequence's [`PagedSeq::epoch`]
//!   (admission bumps it: prefix pins and partial-tail adoption change
//!   pool rows behind the engine's back);
//! * the lane's `rows` equals the sequence's length (every row the
//!   dense copy holds was mirrored by the engine's own scatter path).
//!
//! Pool-side LRU eviction never invalidates a lane: it only reclaims
//! refcount-0 blocks, which no live sequence references.
//!
//! [`PagedSeq::id`]: crate::kvpool::PagedSeq::id
//! [`PagedSeq::epoch`]: crate::kvpool::PagedSeq::epoch

/// Cumulative residency counters, exported through
/// [`crate::coordinator::Metrics`] and the TCP stats endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Full-cache lane gathers from pool blocks (prefill packs + lane
    /// refreshes).  Flat across steady-state decode — the O(1) claim.
    pub kv_gather_total: u64,
    /// K/V row pairs scattered back into the pool (one per layer per
    /// decoded token): the O(1)-per-token write path.
    pub kv_scatter_rows_total: u64,
    /// Lane (re)assignments that required a refresh from the pool.
    pub lane_refresh_total: u64,
    /// Decode steps served entirely from resident lanes (no gather).
    pub resident_hits: u64,
    /// Decode graph invocations (one per bank touched per step).
    pub decode_graph_calls: u64,
}

/// One lane's occupancy tag.
#[derive(Clone, Copy, Debug)]
struct LaneSlot {
    seq_id: u64,
    epoch: u64,
    /// Valid dense rows `[0, rows)` mirrored for this sequence.
    rows: usize,
    /// LRU stamp for slot reassignment.
    last_use: u64,
}

/// One dense cache tensor pair plus its lane tags.  A bank maps onto a
/// single decode-graph call; `kc`/`vc` are the flattened
/// `[layers, lanes, max_t, kv_heads, head_dim]` host tensors the graph
/// round-trips.
pub struct LaneBank {
    /// Flattened dense key cache (graph input/output).
    pub kc: Vec<f32>,
    /// Flattened dense value cache (graph input/output).
    pub vc: Vec<f32>,
    slots: Vec<Option<LaneSlot>>,
}

impl LaneBank {
    fn new(lanes: usize, dense_len: usize) -> LaneBank {
        LaneBank {
            kc: vec![0.0; dense_len],
            vc: vec![0.0; dense_len],
            slots: vec![None; lanes],
        }
    }
}

/// Where [`LaneResidency::assign`] placed one sequence for this step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneAssignment {
    /// Bank index (one graph call per bank).
    pub bank: usize,
    /// Lane within the bank.
    pub lane: usize,
    /// `true` = the dense copy is stale or new: gather `[0, len)` from
    /// the pool (and zero the tail) before the graph call.
    pub refresh: bool,
}

/// Lane-residency manager: banks of dense decode caches, lane
/// assignment with LRU reuse, and the staleness protocol described in
/// the module docs.  Pure bookkeeping — no PJRT types — so the
/// invalidation logic is unit-testable without artifacts.
pub struct LaneResidency {
    banks: Vec<LaneBank>,
    lanes: usize,
    dense_len: usize,
    tick: u64,
    stats: ResidencyStats,
}

impl LaneResidency {
    /// `lanes` = the decode graph's batch dimension; `dense_len` = the
    /// flattened length of one dense cache tensor.
    pub fn new(lanes: usize, dense_len: usize) -> LaneResidency {
        assert!(lanes > 0);
        LaneResidency {
            banks: Vec::new(),
            lanes,
            dense_len,
            tick: 0,
            stats: ResidencyStats::default(),
        }
    }

    /// Cumulative counters snapshot.
    pub fn stats(&self) -> ResidencyStats {
        self.stats
    }

    /// Banks currently allocated.
    pub fn bank_count(&self) -> usize {
        self.banks.len()
    }

    /// Place every `(id, epoch, len)` occupant on a lane for this step.
    /// Occupants already resident with a matching tag keep their lane
    /// with `refresh: false`; everyone else lands on a free or
    /// least-recently-used lane (never one claimed this step) with
    /// `refresh: true`.  New banks are grown when the batch outnumbers
    /// the existing lanes.  A batch that fits a single bank is always
    /// consolidated into one: strays left in other banks by an earlier
    /// burst are re-homed (one refresh each), because paying one gather
    /// now beats paying one extra graph call on *every* later step.
    /// Counter effects: one `kv_gather_total` + `lane_refresh_total`
    /// per refresh, one `resident_hits` per kept lane.
    ///
    /// The heuristic assumes the scheduler's usage — every live
    /// sequence decodes in one batch per step (see
    /// `coordinator::scheduler::run_loop`).  A caller that instead
    /// alternates disjoint sub-batches over a live set larger than the
    /// total lane count will evict each other's residents and re-gather
    /// every step, like any bounded cache whose working set exceeds it;
    /// batch the whole active set (or grow `lanes`) to stay O(1).
    pub fn assign(&mut self, occupants: &[(u64, u64, usize)]) -> Vec<LaneAssignment> {
        self.tick += 1;
        // consolidation: when the whole batch fits one bank, constrain
        // every placement to one — preferring the bank already holding
        // the most of the batch, then the one with the most free lanes
        // (so an emptied bank is reused instead of evicting another
        // bank's live residents), then the lowest index (so higher
        // banks drain and their buffers are freed by the trailing pop)
        let home = if occupants.len() <= self.lanes && !self.banks.is_empty() {
            let mut per_bank = vec![0usize; self.banks.len()];
            for &(id, _, _) in occupants {
                if let Some((b, _)) = self.find_seq(id) {
                    per_bank[b] += 1;
                }
            }
            let frees: Vec<usize> = self
                .banks
                .iter()
                .map(|bk| bk.slots.iter().filter(|s| s.is_none()).count())
                .collect();
            let best = (0..self.banks.len())
                .max_by_key(|&b| (per_bank[b], frees[b], std::cmp::Reverse(b)))
                .unwrap_or(0);
            for &(id, _, _) in occupants {
                if let Some((b, l)) = self.find_seq(id) {
                    if b != best {
                        self.banks[b].slots[l] = None; // stray: re-home below
                    }
                }
            }
            Some(best)
        } else {
            None
        };
        let mut out: Vec<Option<LaneAssignment>> = vec![None; occupants.len()];
        let mut claimed: Vec<(usize, usize)> = Vec::with_capacity(occupants.len());
        // pass 1: occupants already holding a lane
        for (i, &(id, epoch, len)) in occupants.iter().enumerate() {
            if let Some((b, l)) = self.find_seq(id) {
                // find_seq only returns occupied lanes; if the slot were
                // somehow vacated the occupant simply falls through to
                // pass 2 and re-gathers (correct, just slower)
                let Some(slot) = self.banks[b].slots[l].as_mut() else {
                    debug_assert!(false, "find_seq returned a vacant lane");
                    continue;
                };
                let fresh = slot.epoch != epoch || slot.rows != len;
                slot.epoch = epoch;
                slot.rows = len;
                slot.last_use = self.tick;
                if fresh {
                    self.stats.kv_gather_total += 1;
                    self.stats.lane_refresh_total += 1;
                } else {
                    self.stats.resident_hits += 1;
                }
                out[i] = Some(LaneAssignment { bank: b, lane: l, refresh: fresh });
                claimed.push((b, l));
            }
        }
        // pass 2: everyone else takes an empty lane, then evicts LRU,
        // then grows a bank
        for (i, &(id, epoch, len)) in occupants.iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            let (b, l) = self
                .free_lane(&claimed, home)
                .unwrap_or_else(|| self.grow_bank());
            self.banks[b].slots[l] = Some(LaneSlot {
                seq_id: id,
                epoch,
                rows: len,
                last_use: self.tick,
            });
            self.stats.kv_gather_total += 1;
            self.stats.lane_refresh_total += 1;
            out[i] = Some(LaneAssignment { bank: b, lane: l, refresh: true });
            claimed.push((b, l));
        }
        self.reclaim_trailing_banks();
        // pass 2 places every unassigned occupant (grow_bank cannot
        // fail), so the fallback lane is unreachable; refresh=true keeps
        // even that impossible case correct (a full re-gather never
        // serves stale rows, it is only slower)
        out.into_iter()
            .map(|a| {
                a.unwrap_or_else(|| {
                    debug_assert!(false, "occupant left unplaced");
                    LaneAssignment { bank: 0, lane: 0, refresh: true }
                })
            })
            .collect()
    }

    /// Burst memory does not outlive the burst: trailing banks left
    /// fully empty (strays re-homed, occupants retired) release their
    /// dense buffers.
    fn reclaim_trailing_banks(&mut self) {
        while self
            .banks
            .last()
            .is_some_and(|b| b.slots.iter().all(Option::is_none))
        {
            self.banks.pop();
        }
    }

    fn find_seq(&self, id: u64) -> Option<(usize, usize)> {
        for (b, bank) in self.banks.iter().enumerate() {
            for (l, slot) in bank.slots.iter().enumerate() {
                if slot.map(|s| s.seq_id) == Some(id) {
                    return Some((b, l));
                }
            }
        }
        None
    }

    /// First empty lane, else the least-recently-used lane not claimed
    /// this step; `only_bank` restricts the search (batch consolidation).
    fn free_lane(
        &self,
        claimed: &[(usize, usize)],
        only_bank: Option<usize>,
    ) -> Option<(usize, usize)> {
        let mut lru: Option<(u64, usize, usize)> = None;
        for (b, bank) in self.banks.iter().enumerate() {
            if only_bank.is_some_and(|h| h != b) {
                continue;
            }
            for (l, slot) in bank.slots.iter().enumerate() {
                if claimed.contains(&(b, l)) {
                    continue;
                }
                match slot {
                    None => return Some((b, l)),
                    Some(s) => {
                        if lru.map_or(true, |(t, ..)| s.last_use < t) {
                            lru = Some((s.last_use, b, l));
                        }
                    }
                }
            }
        }
        lru.map(|(_, b, l)| (b, l))
    }

    fn grow_bank(&mut self) -> (usize, usize) {
        self.banks.push(LaneBank::new(self.lanes, self.dense_len));
        (self.banks.len() - 1, 0)
    }

    /// The position an **idle** lane should pass to the graph: its next
    /// append slot, so the garbage row the graph writes there stays
    /// behind the causal mask and is overwritten by the occupant's next
    /// real decode.  A lane whose dense copy is full would have its last
    /// valid row clobbered instead, so it is invalidated and parks at
    /// `max_t - 1`.  Empty lanes park at 0.
    pub fn idle_pos(&mut self, bank: usize, lane: usize, max_t: usize) -> usize {
        match &self.banks[bank].slots[lane] {
            Some(s) if s.rows >= max_t => {
                self.banks[bank].slots[lane] = None;
                max_t - 1
            }
            Some(s) => s.rows,
            None => 0,
        }
    }

    /// Mutable dense buffers of one bank (lane refresh target).
    pub fn bank_buffers_mut(&mut self, bank: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
        let b = &mut self.banks[bank];
        (&mut b.kc, &mut b.vc)
    }

    /// Move a bank's dense buffers out for a graph call (the graph
    /// consumes owned `Vec`s); pair with
    /// [`put_bank_buffers`](LaneResidency::put_bank_buffers) or
    /// [`reset_bank`](LaneResidency::reset_bank).
    pub fn take_bank_buffers(&mut self, bank: usize) -> (Vec<f32>, Vec<f32>) {
        let b = &mut self.banks[bank];
        (std::mem::take(&mut b.kc), std::mem::take(&mut b.vc))
    }

    /// Install the graph's returned caches as the bank's resident copy.
    pub fn put_bank_buffers(&mut self, bank: usize, kc: Vec<f32>, vc: Vec<f32>) {
        debug_assert_eq!(kc.len(), self.dense_len);
        debug_assert_eq!(vc.len(), self.dense_len);
        let b = &mut self.banks[bank];
        b.kc = kc;
        b.vc = vc;
    }

    /// Zero a bank and drop every lane tag (graph-failure recovery: the
    /// in-flight buffers were consumed, so nothing resident survives).
    pub fn reset_bank(&mut self, bank: usize) {
        self.banks[bank] = LaneBank::new(self.lanes, self.dense_len);
    }

    /// Record the post-step row count of a decoded lane (the engine
    /// mirrored the appended row itself, so the copy stays trusted).
    pub fn committed(&mut self, bank: usize, lane: usize, rows: usize) {
        if let Some(s) = self.banks[bank].slots[lane].as_mut() {
            s.rows = rows;
        }
    }

    /// Drop a released sequence's lane tag immediately (retire /
    /// preemption), then free any trailing banks that emptied out — so
    /// an idle engine holds zero dense banks and burst memory is
    /// reclaimed as the burst's occupants retire, not merely recycled.
    pub fn invalidate_seq(&mut self, id: u64) {
        if let Some((b, l)) = self.find_seq(id) {
            self.banks[b].slots[l] = None;
        }
        self.reclaim_trailing_banks();
    }

    /// Count a full-cache gather performed outside lane assignment
    /// (prefill packs, the legacy re-gather path).
    pub fn note_gather(&mut self) {
        self.stats.kv_gather_total += 1;
    }

    /// Count `rows` K/V row pairs scattered back into the pool.
    pub fn note_scatter(&mut self, rows: u64) {
        self.stats.kv_scatter_rows_total += rows;
    }

    /// Count one decode-graph invocation.
    pub fn note_graph_call(&mut self) {
        self.stats.decode_graph_calls += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_decode_needs_zero_gathers() {
        // 3 sequences on a 4-lane bank: after the admission refresh,
        // 200 decode rounds never gather again
        let mut res = LaneResidency::new(4, 64);
        let mut seqs = [(1u64, 1u64, 5usize), (2, 1, 9), (3, 1, 2)];
        let plan = res.assign(&seqs);
        assert!(plan.iter().all(|a| a.refresh));
        assert_eq!(res.stats().kv_gather_total, 3);
        for round in 0..200 {
            // mirror the engine: rows advance with len after each step
            for (i, s) in seqs.iter_mut().enumerate() {
                s.2 += 1;
                let a = plan[i];
                res.committed(a.bank, a.lane, s.2);
            }
            let again = res.assign(&seqs);
            for (a, b) in plan.iter().zip(&again) {
                assert_eq!((a.bank, a.lane), (b.bank, b.lane), "round {round}");
            }
            assert!(
                again.iter().all(|a| !a.refresh),
                "round {round}: steady-state lane refreshed"
            );
        }
        assert_eq!(res.stats().kv_gather_total, 3, "gathers grew in steady state");
        assert_eq!(res.stats().resident_hits, 3 * 200);
    }

    #[test]
    fn epoch_bump_forces_refresh() {
        let mut res = LaneResidency::new(2, 16);
        let a = res.assign(&[(7, 1, 4)])[0];
        assert!(a.refresh);
        res.committed(a.bank, a.lane, 5);
        assert!(!res.assign(&[(7, 1, 5)])[0].refresh);
        // re-admission after preemption bumps the epoch -> dirty
        let b = res.assign(&[(7, 2, 5)])[0];
        assert!(b.refresh);
        assert_eq!((b.bank, b.lane), (a.bank, a.lane), "same lane, refreshed");
    }

    #[test]
    fn rows_mismatch_forces_refresh() {
        // rows advanced outside the engine's own scatter (e.g. a missed
        // commit) must not be trusted
        let mut res = LaneResidency::new(2, 16);
        let a = res.assign(&[(9, 1, 4)])[0];
        res.committed(a.bank, a.lane, 5);
        assert!(res.assign(&[(9, 1, 7)])[0].refresh);
    }

    #[test]
    fn lru_lane_is_evicted_for_new_sequences() {
        let mut res = LaneResidency::new(2, 16);
        let p1 = res.assign(&[(1, 1, 3), (2, 1, 3)]);
        assert_eq!(res.bank_count(), 1);
        // seq 2 keeps decoding; seq 1 goes cold
        for len in 4..8 {
            let a = res.assign(&[(2, 1, len - 1)])[0];
            res.committed(a.bank, a.lane, len);
        }
        // a new sequence takes seq 1's lane (the LRU), not seq 2's
        let b = res.assign(&[(3, 1, 2)])[0];
        assert_eq!((b.bank, b.lane), (p1[0].bank, p1[0].lane));
        // seq 1 returning is a refresh (its lane was reassigned)
        assert!(res.assign(&[(1, 1, 3)])[0].refresh);
    }

    #[test]
    fn batch_larger_than_bank_grows_banks() {
        let mut res = LaneResidency::new(2, 16);
        let occ: Vec<(u64, u64, usize)> = (1..=5).map(|i| (i, 1, 4)).collect();
        let plan = res.assign(&occ);
        assert_eq!(res.bank_count(), 3);
        // no two occupants share a lane
        for (i, a) in plan.iter().enumerate() {
            for b in &plan[i + 1..] {
                assert!((a.bank, a.lane) != (b.bank, b.lane));
            }
        }
        // steady state across multiple banks
        let again = res.assign(&occ);
        assert!(again.iter().all(|a| !a.refresh));
    }

    #[test]
    fn small_batch_consolidates_into_one_bank() {
        // a burst splits residents across two banks; once the batch fits
        // one bank again, strays re-home (one refresh) so every later
        // step is a single graph call
        let mut res = LaneResidency::new(2, 16);
        res.assign(&[(1, 1, 2), (2, 1, 2)]); // fills bank 0
        let burst = res.assign(&[(3, 1, 2), (4, 1, 2), (5, 1, 2)]);
        assert_eq!(res.bank_count(), 2);
        let b5 = burst[2];
        assert_eq!(b5.bank, 1, "the burst overflow grew a second bank");
        // seqs 3 and 4 retired; the surviving pair {5, 3'} fits one bank
        let plan = res.assign(&[(5, 1, 3), (6, 1, 2)]);
        assert_eq!(plan[0].bank, plan[1].bank, "small batch split across banks");
        // steady state afterwards: same bank, no refresh
        let again = res.assign(&[(5, 1, 3), (6, 1, 2)]);
        assert!(again.iter().all(|a| !a.refresh));
        assert_eq!(again[0].bank, again[1].bank);
    }

    #[test]
    fn idle_pos_parks_at_next_append_slot() {
        let mut res = LaneResidency::new(2, 16);
        let a = res.assign(&[(1, 1, 6)])[0];
        assert_eq!(res.idle_pos(a.bank, a.lane, 10), 6);
        assert_eq!(res.idle_pos(a.bank, 1, 10), 0, "empty lane parks at 0");
        // a full lane is invalidated rather than clobbered silently
        res.committed(a.bank, a.lane, 10);
        assert_eq!(res.idle_pos(a.bank, a.lane, 10), 9);
        assert!(res.assign(&[(1, 1, 10)])[0].refresh);
    }

    #[test]
    fn invalidate_seq_frees_trailing_banks() {
        let mut res = LaneResidency::new(2, 16);
        res.assign(&[(1, 1, 2), (2, 1, 2), (3, 1, 2)]); // overflows into bank 1
        assert_eq!(res.bank_count(), 2);
        res.invalidate_seq(3);
        assert_eq!(res.bank_count(), 1, "trailing bank freed on retire");
        res.invalidate_seq(1);
        assert_eq!(res.bank_count(), 1, "bank 0 still hosts seq 2");
        res.invalidate_seq(2);
        assert_eq!(res.bank_count(), 0, "idle engine holds no dense banks");
    }

    #[test]
    fn reset_bank_drops_residency() {
        let mut res = LaneResidency::new(2, 8);
        let a = res.assign(&[(1, 1, 3)])[0];
        let (kc, vc) = res.take_bank_buffers(a.bank);
        assert_eq!(kc.len(), 8);
        drop((kc, vc));
        res.reset_bank(a.bank);
        let (kc2, _) = res.take_bank_buffers(a.bank);
        assert_eq!(kc2.len(), 8, "reset restores zeroed buffers");
        res.put_bank_buffers(a.bank, vec![0.0; 8], vec![0.0; 8]);
        assert!(res.assign(&[(1, 1, 3)])[0].refresh);
    }
}
