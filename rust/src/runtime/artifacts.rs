//! Artifact manifest: what `make artifacts` produced and how to feed it.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::config::ModelConfig;
use crate::util::json::Json;

/// Tensor spec of one graph input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct GraphInfo {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed artifacts/manifest.json + paths.
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub root: PathBuf,
    pub model: ModelConfig,
    pub graphs: Vec<GraphInfo>,
    pub decode_batch: usize,
    pub decode_max_t: usize,
    pub prefill_batch: usize,
    pub prefill_seq: usize,
}

fn parse_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().context("spec list")?;
    arr.iter()
        .map(|spec| {
            let name = spec.idx(0).and_then(Json::as_str).context("spec name")?;
            let dtype = spec.idx(1).and_then(Json::as_str).context("spec dtype")?;
            let shape = spec
                .idx(2)
                .and_then(Json::as_arr)
                .context("spec shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { name: name.into(), dtype: dtype.into(), shape })
        })
        .collect()
}

impl Artifacts {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Artifacts> {
        let root = root.as_ref().to_path_buf();
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json", root.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let model = ModelConfig::from_manifest(&j)?;
        let graphs_obj = j.get("graphs").context("manifest missing graphs")?;
        let mut graphs = Vec::new();
        for (name, g) in graphs_obj.as_obj().context("graphs object")? {
            graphs.push(GraphInfo {
                name: name.clone(),
                file: root.join(
                    g.get("file").and_then(Json::as_str).context("graph file")?,
                ),
                inputs: parse_specs(g.get("inputs").context("inputs")?)?,
                outputs: parse_specs(g.get("outputs").context("outputs")?)?,
            });
        }
        let dec = j.get("decode").context("decode info")?;
        let pre = j.get("prefill").context("prefill info")?;
        Ok(Artifacts {
            root,
            model,
            graphs,
            decode_batch: dec.get("batch").and_then(Json::as_usize).unwrap_or(4),
            decode_max_t: dec.get("max_t").and_then(Json::as_usize).unwrap_or(160),
            prefill_batch: pre.get("batch").and_then(Json::as_usize).unwrap_or(1),
            prefill_seq: pre.get("seq").and_then(Json::as_usize).unwrap_or(96),
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphInfo> {
        self.graphs
            .iter()
            .find(|g| g.name == name)
            .with_context(|| format!("artifact graph '{name}' not in manifest"))
    }

    /// Width of the decode graphs' `pos` input (their fourth input):
    /// `decode_batch` on per-lane-position artifacts, where every lane
    /// carries its own position and unequal-length sequences share one
    /// graph call; `1` on legacy scalar-position artifacts (and when no
    /// decode graph is present).  Sniffed from the manifest specs so
    /// both artifact generations keep working.
    pub fn decode_pos_width(&self) -> usize {
        self.graphs
            .iter()
            .find(|g| g.name.starts_with("decode_"))
            .and_then(|g| g.inputs.get(3))
            .map(|s| s.numel())
            .unwrap_or(1)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.root.join("weights.rrsw")
    }

    pub fn goldens_path(&self) -> PathBuf {
        self.root.join("goldens.rrsw")
    }

    pub fn spinquant_path(&self) -> PathBuf {
        self.root.join("spinquant_r.rrsw")
    }

    pub fn val_text(&self) -> Result<String> {
        Ok(std::fs::read_to_string(self.root.join("val.txt"))?)
    }

    pub fn qa_tasks_json(&self) -> Result<Json> {
        let text = std::fs::read_to_string(self.root.join("qa_tasks.json"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("qa_tasks.json: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("rrs_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model":{"vocab":256,"dim":128,"n_layers":4,"n_heads":4,
                "n_kv_heads":2,"ffn":256,"max_seq":256,"rope_theta":10000.0},
               "prefill":{"batch":1,"seq":96},
               "decode":{"batch":4,"max_t":160},
               "graphs":{"prefill_fp":{"file":"prefill_fp.hlo.txt",
                 "inputs":[["tokens","i32",[1,96]]],
                 "outputs":[["logits","f32",[1,96,256]]]}}}"#,
        )
        .unwrap();
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.model.dim, 128);
        let g = a.graph("prefill_fp").unwrap();
        assert_eq!(g.inputs[0].shape, vec![1, 96]);
        assert_eq!(g.outputs[0].numel(), 96 * 256);
        assert!(a.graph("nope").is_err());
        // no decode graph in the manifest: legacy scalar-pos default
        assert_eq!(a.decode_pos_width(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn decode_pos_width_sniffs_per_lane_artifacts() {
        let dir = std::env::temp_dir().join("rrs_artifacts_poswidth_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model":{"vocab":256,"dim":128,"n_layers":4,"n_heads":4,
                "n_kv_heads":2,"ffn":256,"max_seq":256,"rope_theta":10000.0},
               "prefill":{"batch":1,"seq":96},
               "decode":{"batch":4,"max_t":160,"pos_per_lane":true},
               "graphs":{"decode_fp":{"file":"decode_fp.hlo.txt",
                 "inputs":[["token","i32",[4,1]],
                           ["kcache","f32",[4,4,160,2,32]],
                           ["vcache","f32",[4,4,160,2,32]],
                           ["pos","i32",[4]]],
                 "outputs":[["logits","f32",[4,1,256]],
                            ["kcache","f32",[4,4,160,2,32]],
                            ["vcache","f32",[4,4,160,2,32]]]}}}"#,
        )
        .unwrap();
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.decode_pos_width(), 4);
        assert_eq!(a.decode_batch, 4);
        std::fs::remove_dir_all(&dir).ok();
    }
}
