//! Table 3: training-based rotation (SpinQuant, Cayley-SGD learned at
//! build time) vs fixed-Hadamard QuaRot vs RRS.  The paper's finding we
//! reproduce: the *trained* rotation does not necessarily beat the fixed
//! Hadamard, and RRS leads.

use anyhow::Result;

use crate::eval::perplexity::format_ppl;
use crate::model::weights::OutlierProfile;
use crate::model::EngineConfig;
use crate::quant::{Method, Scheme};

use super::{Ctx, MdTable};

pub fn run(ctx: &Ctx) -> Result<()> {
    if ctx.spin.is_none() {
        eprintln!("table3: spinquant_r.rrsw missing; skipping");
        return Ok(());
    }
    let profiles = ["base", "llama2-like", "llama3-like", "qwen-like"];
    let mut header = vec!["Method".to_string()];
    header.extend(profiles.iter().map(|s| s.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = MdTable::new(&hdr);

    for method in [Method::SpinQuant, Method::QuaRot, Method::Rrs] {
        let mut row = vec![method.name().to_string()];
        for pname in profiles {
            let profile = OutlierProfile::builtin(pname).unwrap();
            let ecfg = EngineConfig {
                method,
                scheme: Scheme::A4W4KV4,
                group: 16,
                kv_group: 128,
                alpha: 0.5,
                gptq: true,
                recipe: None,
            };
            let ppl = ctx.ppl(&profile, &ecfg)?;
            eprintln!("table3: {} {} -> {}", method.name(), pname, format_ppl(ppl));
            row.push(format_ppl(ppl));
        }
        table.row(row);
    }

    println!("\n## Table 3 — trained vs fixed rotation, A4W4KV4 perplexity\n");
    table.print();
    ctx.write_report("table3.md", &table.to_markdown())?;
    ctx.write_report("table3.csv", &table.to_csv())?;
    Ok(())
}
