//! Table 2: zero-shot QA accuracy (Common Sense QA stand-ins) under
//! 4-4-16 and 4-4-4, per method and model profile.  Expected shape:
//! GPTQ/SmoothQuant near chance, RS recovers most accuracy, RRS >= QuaRot.

use anyhow::Result;

use crate::eval::qa::{load_tasks, score_tasks};
use crate::model::weights::OutlierProfile;
use crate::quant::Scheme;

use super::table1::{ecfg_like_table1, METHODS};
use super::{Ctx, MdTable};

pub fn run(ctx: &Ctx) -> Result<()> {
    let tasks = load_tasks(&ctx.artifacts.qa_tasks_json()?)?;
    let limit = if ctx.fast { 12 } else { 50 };
    // the paper's Table 2 models map to our injected profiles; use the
    // llama3-like profile as the headline column plus base for sanity
    let profiles = ["base", "llama3-like"];
    let mut header = vec!["#Bits".to_string(), "Profile".to_string(), "Method".to_string()];
    header.extend(tasks.iter().map(|(n, _)| n.to_uppercase()));
    header.push("Avg.".to_string());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = MdTable::new(&hdr);

    for (label, scheme) in [("4-4-16", Scheme::A4W4KV16), ("4-4-4", Scheme::A4W4KV4)] {
        for pname in profiles {
            let profile = OutlierProfile::builtin(pname).unwrap();
            for method in METHODS {
                let ecfg = ecfg_like_table1(method, scheme);
                let model = ctx.prepare_model(&profile, &ecfg)?;
                let (per, avg) = score_tasks(&model, &tasks, limit);
                let mut row = vec![
                    label.to_string(),
                    pname.to_string(),
                    method.name().to_string(),
                ];
                row.extend(per.iter().map(|(_, a)| format!("{a:.1}")));
                row.push(format!("{avg:.1}"));
                eprintln!("table2: {label} {pname} {} -> avg {avg:.1}", method.name());
                table.row(row);
            }
        }
    }

    println!("\n## Table 2 — zero-shot QA accuracy % (higher is better)\n");
    table.print();
    ctx.write_report("table2.md", &table.to_markdown())?;
    ctx.write_report("table2.csv", &table.to_csv())?;
    Ok(())
}
