//! Experiment harness: regenerates every table and figure of the paper
//! (see DESIGN.md section 5 for the experiment index).  Each submodule
//! prints the paper-style rows and writes markdown + CSV into an output
//! directory; `run_all` drives the full evaluation suite.

pub mod fig6;
pub mod figures;
pub mod matrix;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::linalg::gemm::Mat;
use crate::model::weights::OutlierProfile;
use crate::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use crate::model::tokenizer;
use crate::runtime::Artifacts;
use crate::util::io::read_rrsw;

/// Shared inputs for all experiments.
pub struct Ctx {
    pub artifacts: Artifacts,
    pub mcfg: ModelConfig,
    pub weights: Weights,
    pub val_text: String,
    pub calib: Vec<u32>,
    /// Learned rotations (R_dim, R_ffn) for the SpinQuant baseline.
    pub spin: Option<(Mat, Mat)>,
    pub out_dir: PathBuf,
    /// Fast mode: fewer eval windows / items (CI-speed smoke runs).
    pub fast: bool,
}

impl Ctx {
    pub fn load(artifacts_root: &str, out_dir: &str, fast: bool) -> Result<Ctx> {
        let artifacts = Artifacts::load(artifacts_root)?;
        let mcfg = artifacts.model;
        let weights = Weights::load(artifacts.weights_path(), &mcfg)
            .context("load weights.rrsw (run `make artifacts`)")?;
        let val_text = artifacts.val_text()?;
        let val_toks = tokenizer::encode(&val_text);
        // calibration protocol shared with python aot.py: 8 windows of 64
        let calib: Vec<u32> = (0..8)
            .flat_map(|i| val_toks[i * 64..i * 64 + 64].to_vec())
            .collect();
        let spin = read_rrsw(artifacts.spinquant_path()).ok().and_then(|m| {
            let r_dim = m.get("r_dim")?;
            let r_ffn = m.get("r_ffn")?;
            let (dr, dc) = r_dim.dims2().ok()?;
            let (fr, fc) = r_ffn.dims2().ok()?;
            Some((
                Mat::from_vec(dr, dc, r_dim.as_f32().ok()?.to_vec()),
                Mat::from_vec(fr, fc, r_ffn.as_f32().ok()?.to_vec()),
            ))
        });
        std::fs::create_dir_all(out_dir)?;
        Ok(Ctx {
            artifacts,
            mcfg,
            weights,
            val_text,
            calib,
            spin,
            out_dir: PathBuf::from(out_dir),
            fast,
        })
    }

    /// Windows used for perplexity (fast mode trims for smoke tests).
    pub fn ppl_windows(&self) -> usize {
        if self.fast {
            2
        } else {
            8
        }
    }

    /// Weights for a profile: prefer the finetuned per-profile checkpoint
    /// (weights_<name>.rrsw, built by aot.py by finetuning around frozen
    /// outlier tensors); fall back to direct injection for ad-hoc
    /// profiles.
    pub fn weights_for(&self, profile: &OutlierProfile) -> Result<Weights> {
        if profile.name == "base" {
            return Ok(self.weights.clone());
        }
        let path = self
            .artifacts
            .root
            .join(format!("weights_{}.rrsw", profile.name));
        if path.exists() {
            Weights::load(&path, &self.mcfg)
        } else {
            eprintln!(
                "note: {} missing; falling back to compensated injection",
                path.display()
            );
            Ok(profile.inject(&self.weights, 17))
        }
    }

    /// Prepare an engine over a profile's weights.
    pub fn prepare_model(
        &self,
        profile: &OutlierProfile,
        ecfg: &EngineConfig,
    ) -> Result<QuantModel> {
        let w = self.weights_for(profile)?;
        let spin = self.spin.clone();
        QuantModel::prepare(&w, &self.mcfg, ecfg, Some(&self.calib), spin)
    }

    /// Perplexity of a (profile, engine-config) cell.
    pub fn ppl(&self, profile: &OutlierProfile, ecfg: &EngineConfig) -> Result<f32> {
        let m = self.prepare_model(profile, ecfg)?;
        Ok(crate::eval::perplexity(&m, &self.val_text, 96, self.ppl_windows()))
    }

    pub fn write_report(&self, name: &str, content: &str) -> Result<()> {
        let path = self.out_dir.join(name);
        std::fs::write(&path, content)?;
        eprintln!("wrote {}", path.display());
        Ok(())
    }
}

/// Markdown table builder shared by the experiment writers.
pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> MdTable {
        MdTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }
}

/// Run every experiment (used by `rrs harness all` / `make tables`).
pub fn run_all(ctx: &Ctx) -> Result<()> {
    table1::run(ctx)?;
    table2::run(ctx)?;
    table3::run(ctx)?;
    table4::run(ctx)?;
    figures::fig2b(ctx)?;
    figures::fig3(ctx)?;
    fig6::run(ctx)?;
    figures::fig7(ctx)?;
    figures::fig8(ctx)?;
    figures::fig9(ctx)?;
    matrix::run(ctx)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mdtable_renders() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }
}
