//! Table 4: group-size ablation of the runtime smoothing scale.
//! Expected shape: RRS is flat in group size (rotation pre-equalizes the
//! channels, so coarse groups cost nothing — what enables the fused
//! kernel); RS deteriorates as groups grow, sharply in the presence of
//! spikes.

use anyhow::Result;

use crate::eval::perplexity::format_ppl;
use crate::model::weights::OutlierProfile;
use crate::model::EngineConfig;
use crate::quant::{Method, Scheme};

use super::{Ctx, MdTable};

pub const GROUPS: [usize; 6] = [1, 16, 32, 64, 128, 256];

pub fn run(ctx: &Ctx) -> Result<()> {
    let profiles = ["llama3-like", "qwen-like"];
    let mut header = vec!["Method".to_string(), "Profile".to_string()];
    header.extend(GROUPS.iter().map(|g| g.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = MdTable::new(&hdr);

    for method in [Method::Rrs, Method::Rs] {
        for pname in profiles {
            let profile = OutlierProfile::builtin(pname).unwrap();
            let mut row = vec![method.name().to_string(), pname.to_string()];
            for g in GROUPS {
                // groups larger than a layer's K clamp to K (the paper
                // marks unsupported sizes "-"; our dims clamp instead)
                let ecfg = EngineConfig {
                    method,
                    scheme: Scheme::A4W4KV16,
                    group: g,
                    kv_group: 128,
                    alpha: 0.5,
                    gptq: true,
                    recipe: None,
                };
                let ppl = ctx.ppl(&profile, &ecfg)?;
                eprintln!("table4: {} {} g={} -> {}", method.name(), pname, g,
                          format_ppl(ppl));
                row.push(format_ppl(ppl));
            }
            table.row(row);
        }
    }

    println!("\n## Table 4 — runtime-smooth group-size ablation (ppl)\n");
    table.print();
    ctx.write_report("table4.md", &table.to_markdown())?;
    ctx.write_report("table4.csv", &table.to_csv())?;
    Ok(())
}
