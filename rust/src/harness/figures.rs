//! Figures 2b, 3, 7, 8, 9 — the analysis plots, emitted as data tables
//! (series the paper plots; CSV for external plotting).

use anyhow::Result;

use crate::eval::perplexity::format_ppl;
use crate::eval::smoothness::{
    collect_mu, outlier_histogram, prob_less_smooth_after_rotation, victim_u,
    SmoothMode,
};
use crate::linalg::gemm::Mat;
use crate::model::engine::capture_activations;
use crate::model::weights::OutlierProfile;
use crate::model::{tokenizer, EngineConfig};
use crate::quant::{Method, Scheme};
use crate::util::rng::Pcg;
use crate::util::stats;

use super::{Ctx, MdTable};

/// Captured per-projector activations for a profile.
fn capture_for(ctx: &Ctx, profile: &str) -> Result<crate::model::engine::CapturedActs> {
    let p = OutlierProfile::builtin(profile).unwrap();
    let w = p.inject(&ctx.weights, 17);
    let toks = tokenizer::encode(&ctx.val_text);
    let n = 192.min(toks.len());
    Ok(capture_activations(&w, &ctx.mcfg, &toks[..n]))
}

/// Fig. 2b: probability a token is LESS smooth after rotation — model
/// activations vs a random Gaussian matrix.
pub fn fig2b(ctx: &Ctx) -> Result<()> {
    let mut table = MdTable::new(&["source", "P(less smooth after rotation)"]);
    for profile in ["base", "llama2-like", "llama3-like", "qwen-like"] {
        let acts = capture_for(ctx, profile)?;
        // pool qkv activations over layers (the paper plots per model)
        let mut probs = Vec::new();
        for layer_act in acts.qkv.iter().chain(acts.down.iter()) {
            probs.push(prob_less_smooth_after_rotation(layer_act));
        }
        table.row(vec![
            format!("model:{profile}"),
            format!("{:.4}", stats::mean(&probs)),
        ]);
    }
    // random-matrix baseline
    let mut rng = Pcg::new(42);
    let mut probs = Vec::new();
    for _ in 0..8 {
        let g = Mat::from_vec(96, ctx.mcfg.dim, rng.normal_vec(96 * ctx.mcfg.dim));
        probs.push(prob_less_smooth_after_rotation(&g));
    }
    table.row(vec!["random-matrix".into(), format!("{:.4}", stats::mean(&probs))]);

    println!("\n## Figure 2b — P(less smooth after rotation)\n");
    table.print();
    ctx.write_report("fig2b.md", &table.to_markdown())?;
    ctx.write_report("fig2b.csv", &table.to_csv())?;
    Ok(())
}

/// Fig. 3: Runtime-Smooth ablation — SmoothQuant (offline calib, merged)
/// vs runtime-scale-merged vs Runtime Smooth (no migration), under A4W4
/// and A4W16 (ppl bars).
pub fn fig3(ctx: &Ctx) -> Result<()> {
    let profile = OutlierProfile::builtin("llama3-like").unwrap();
    let mut table = MdTable::new(&["variant", "A4W4", "A4W16"]);
    let variants: [(&str, Method); 3] = [
        ("SmoothQuant (offline scale, migrated)", Method::SmoothQuant),
        ("runtime scale, migrated", Method::RsMigrated),
        ("Runtime Smooth (no migration)", Method::Rs),
    ];
    for (label, method) in variants {
        let mut row = vec![label.to_string()];
        for scheme in [Scheme::A4W4KV16, Scheme::A4W16KV16] {
            let ecfg = EngineConfig {
                method,
                scheme,
                group: 1,
                kv_group: 128,
                alpha: 0.5,
                gptq: method == Method::SmoothQuant,
                recipe: None,
            };
            let ppl = ctx.ppl(&profile, &ecfg)?;
            eprintln!("fig3: {label} {} -> {}", scheme.label(), format_ppl(ppl));
            row.push(format_ppl(ppl));
        }
        table.row(row);
    }
    println!("\n## Figure 3 — Runtime Smooth ablation (ppl)\n");
    table.print();
    ctx.write_report("fig3.md", &table.to_markdown())?;
    ctx.write_report("fig3.csv", &table.to_csv())?;
    Ok(())
}

/// Fig. 7: spike-outlier magnitude histogram at the Down-projector input
/// (ratios to the token median, per magnitude interval).
pub fn fig7(ctx: &Ctx) -> Result<()> {
    let edges = [10.0, 50.0, 100.0, 500.0, 1000.0];
    let mut header = vec!["profile".to_string(), "projector".to_string()];
    header.push("<10x".into());
    for w in edges.windows(2) {
        header.push(format!("{}x-{}x", w[0] as i64, w[1] as i64));
    }
    header.push(format!(">={}x", *edges.last().unwrap() as i64));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = MdTable::new(&hdr);

    for profile in ["base", "llama3-like", "llama3-70b-like"] {
        let acts = capture_for(ctx, profile)?;
        for (kind, list) in [("down", &acts.down), ("qkv", &acts.qkv)] {
            let mut counts = vec![0usize; edges.len() + 1];
            for a in list {
                for (c, n) in counts.iter_mut().zip(outlier_histogram(a, &edges)) {
                    *c += n;
                }
            }
            let mut row = vec![profile.to_string(), kind.to_string()];
            // bucket 0 = <10x is "normal"; report counts beyond it raw
            row.extend(counts.iter().map(|c| c.to_string()));
            table.row(row);
        }
    }
    println!("\n## Figure 7 — spike-outlier magnitude counts (x median)\n");
    table.print();
    ctx.write_report("fig7.md", &table.to_markdown())?;
    ctx.write_report("fig7.csv", &table.to_csv())?;
    Ok(())
}

/// Fig. 8: Monte-Carlo victim effect — u of a normal token after division
/// by the smoothing scales, vs the number of spike tokens, RS vs RRS.
pub fn fig8(ctx: &Ctx) -> Result<()> {
    let spikes = [1usize, 2, 4, 8, 16, 32];
    let trials = if ctx.fast { 8 } else { 64 };
    let mut table = MdTable::new(&["#spike tokens", "u (RS)", "u (RRS)"]);
    for &l in &spikes {
        let mut u_rs = Vec::new();
        let mut u_rrs = Vec::new();
        for t in 0..trials {
            let mut rng = Pcg::new(1000 + t as u64);
            u_rs.push(victim_u(ctx.mcfg.dim, 64, l, 1000.0, false, &mut rng));
            let mut rng = Pcg::new(1000 + t as u64);
            u_rrs.push(victim_u(ctx.mcfg.dim, 64, l, 1000.0, true, &mut rng));
        }
        table.row(vec![
            l.to_string(),
            format!("{:.3}", stats::mean(&u_rs)),
            format!("{:.3}", stats::mean(&u_rrs)),
        ]);
    }
    println!("\n## Figure 8 — victim effect u vs #spike tokens\n");
    table.print();
    ctx.write_report("fig8.md", &table.to_markdown())?;
    ctx.write_report("fig8.csv", &table.to_csv())?;
    Ok(())
}

/// Fig. 9: smoothness mu per projector under X / R / RS / RRS.
pub fn fig9(ctx: &Ctx) -> Result<()> {
    let mut table =
        MdTable::new(&["profile", "projector", "X", "R", "RS", "RRS"]);
    for profile in ["llama3-like", "llama3-70b-like"] {
        let acts = capture_for(ctx, profile)?;
        for (kind, list) in [
            ("QKV_Proj", &acts.qkv),
            ("O_Proj", &acts.o),
            ("GateUp_Proj", &acts.gate_up),
            ("Down_Proj", &acts.down),
        ] {
            let mut row = vec![profile.to_string(), kind.to_string()];
            for mode in SmoothMode::ALL {
                let mut mus = Vec::new();
                for a in list {
                    mus.extend(collect_mu(a, mode));
                }
                row.push(format!("{:.2}", stats::mean(&mus)));
            }
            table.row(row);
        }
    }
    println!("\n## Figure 9 — mean token mu per projector and smoother\n");
    table.print();
    ctx.write_report("fig9.md", &table.to_markdown())?;
    ctx.write_report("fig9.csv", &table.to_csv())?;
    Ok(())
}
