//! Figure 6: kernel-efficiency comparison — FP32 reference GEMM vs
//! per-channel A4W4 vs sub-channel A4W4 vs the Runtime-Smooth fused
//! kernel, across batch sizes.
//!
//! The paper measures CUDA kernels on an RTX 4070 Ti via NVBench; our
//! testbed is the rust CPU INT4 path, so absolute numbers differ but the
//! *relative* claim transfers: RS-fusion adds one [1,K] scale vector and
//! a scalar multiply per K-block over per-channel A4W4 (negligible),
//! while sub-channel A4W4 moves whole scale matrices through the epilogue
//! (noticeable).  Dims are scaled from LLaMA-7B (4096) to fit single-core
//! CPU wallclock; see EXPERIMENTS.md.

use anyhow::Result;

use crate::linalg::gemm::Mat;
use crate::quant::qlinear::{
    forward_per_channel_a4w4, forward_rs_fused_prepermuted,
    forward_sub_channel_prequant,
};
use crate::quant::{rtn, runtime_smooth};
use crate::util::bench::{black_box, Bencher};
use crate::util::rng::Pcg;

use super::{Ctx, MdTable};

pub struct Fig6Row {
    pub batch: usize,
    pub fp32_us: f32,
    pub per_channel_us: f32,
    pub sub_channel_us: f32,
    pub rs_fused_us: f32,
}

/// Measure the kernel trio at one (batch, k, m) point.
pub fn measure(batch: usize, k: usize, m: usize, quick: bool) -> Fig6Row {
    let mut rng = Pcg::new(7);
    let x = Mat::from_vec(batch, k, rng.normal_vec(batch * k));
    let w = Mat::from_vec(m, k, rng.normal_vec(m * k));
    let group = 128.min(k);

    // offline-prepared operands (weights quantize offline in all schemes)
    let (wq, sw) = rtn::quant_per_channel_w(&w);
    let (wq_sub, sw_sub) = rtn::quant_sub_channel(&w, group);
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };

    let r_fp = bencher.run("fp32", || {
        black_box(crate::linalg::gemm::gemm_f32_bt(&x, &w));
    });
    // per-channel A4W4: runtime act quant + igemm + scalar epilogue
    let r_pc = bencher.run("per-channel", || {
        black_box(forward_per_channel_a4w4(&x, &wq, &sw));
    });
    // sub-channel A4W4: runtime act quant (grouped) + per-group epilogue
    let r_sc = bencher.run("sub-channel", || {
        let (xq, sx) = rtn::quant_sub_channel(&x, group);
        black_box(forward_sub_channel_prequant(&xq, &sx, &wq_sub, &sw_sub, group));
    });
    // RS fused: runtime smooth (scales+perm+quant) + fused igemm.  The
    // weight gather by the runtime permutation is hoisted the way the
    // CUDA kernel's gather is fused: measure with pre-permuted weight and
    // include the activation-side runtime stage.
    let sa0 = runtime_smooth::prepare(&x, group);
    let wqp = wq.permute_cols(&sa0.perm);
    let r_rs = bencher.run("rs-fused", || {
        let sa = runtime_smooth::prepare(&x, group);
        black_box(forward_rs_fused_prepermuted(&sa, &wqp, &sw));
    });

    Fig6Row {
        batch,
        fp32_us: r_fp.ns_per_iter() / 1e3,
        per_channel_us: r_pc.ns_per_iter() / 1e3,
        sub_channel_us: r_sc.ns_per_iter() / 1e3,
        rs_fused_us: r_rs.ns_per_iter() / 1e3,
    }
}

pub fn run(ctx: &Ctx) -> Result<()> {
    // LLaMA-7B-like aspect (K = M), scaled to CPU wallclock
    let (k, m) = if ctx.fast { (256, 256) } else { (1024, 1024) };
    let batches: &[usize] = if ctx.fast {
        &[1, 16, 64]
    } else {
        &[1, 16, 64, 128, 256]
    };
    let mut table = MdTable::new(&[
        "batch",
        "fp32 (us)",
        "per-channel A4W4 (us)",
        "sub-channel A4W4 (us)",
        "RS-fused A4W4 (us)",
        "RS overhead vs per-channel",
        "sub-channel overhead",
    ]);
    for &b in batches {
        let r = measure(b, k, m, ctx.fast);
        eprintln!(
            "fig6: b={b} fp {:.0}us pc {:.0}us sc {:.0}us rs {:.0}us",
            r.fp32_us, r.per_channel_us, r.sub_channel_us, r.rs_fused_us
        );
        table.row(vec![
            b.to_string(),
            format!("{:.1}", r.fp32_us),
            format!("{:.1}", r.per_channel_us),
            format!("{:.1}", r.sub_channel_us),
            format!("{:.1}", r.rs_fused_us),
            format!("{:+.1}%", 100.0 * (r.rs_fused_us / r.per_channel_us - 1.0)),
            format!("{:+.1}%", 100.0 * (r.sub_channel_us / r.per_channel_us - 1.0)),
        ]);
    }
    println!("\n## Figure 6 — kernel latency, K=M={k} (CPU INT4 analog)\n");
    table.print();
    ctx.write_report("fig6.md", &table.to_markdown())?;
    ctx.write_report("fig6.csv", &table.to_csv())?;
    Ok(())
}
