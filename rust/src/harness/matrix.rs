//! Strategy-matrix ablation: sweep the composable-recipe grid
//! ([`QuantRecipe::matrix`]) and emit one comparable accuracy-vs-speed
//! report per recipe — perplexity, zero-shot QA accuracy, and decode
//! throughput — as `reports/matrix.{md,csv}` plus a machine-readable
//! `BENCH_matrix.json` at the repository root (CI diffs it against the
//! committed baseline and uploads it as the ablation artifact).

use anyhow::Result;

use crate::eval::perplexity::format_ppl;
use crate::eval::qa::load_tasks;
use crate::model::weights::OutlierProfile;
use crate::model::{EngineConfig, KvCache, QuantModel};
use crate::quant::{QuantRecipe, RotationKind, Smoothing};
use crate::util::bench::bench_output_path;
use crate::util::json::{obj, Json};

use super::{Ctx, MdTable};

/// One measured cell of the strategy matrix.
pub struct MatrixCell {
    pub recipe: QuantRecipe,
    pub ppl: f32,
    pub qa_avg: f32,
    pub decode_tps: f32,
}

fn smoothing_name(s: Smoothing) -> &'static str {
    match s {
        Smoothing::None => "none",
        Smoothing::Runtime => "runtime",
        Smoothing::Calibrated => "calibrated",
    }
}

fn rotation_name(r: RotationKind) -> &'static str {
    match r {
        RotationKind::None => "none",
        RotationKind::Hadamard => "hadamard",
        RotationKind::Dense => "dense",
    }
}

/// Greedy-ish single-sequence decode throughput (tokens/s) after a short
/// prefill; enough steps to amortize cache effects without turning the
/// ablation into a benchmark suite.
fn decode_tps(model: &QuantModel, ctx: &Ctx, ecfg: &EngineConfig, steps: usize) -> f32 {
    let prompt: Vec<u32> = (1u32..17).collect();
    let mut cache = KvCache::new(&ctx.mcfg, ecfg);
    model.forward_full(&prompt, Some(&mut cache));
    let mut tok = 1u32;
    let mut step = |cache: &mut KvCache, tok: &mut u32| {
        let mut batch = [(&mut *cache, *tok)];
        let logits = model.decode_batch(&mut batch);
        *tok = (logits.row(0)[0].abs() as u32 % 250) + 1;
    };
    for _ in 0..4 {
        step(&mut cache, &mut tok);
    }
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        step(&mut cache, &mut tok);
    }
    steps as f32 / t0.elapsed().as_secs_f32().max(1e-9)
}

/// Run every recipe in the ablation grid over the headline outlier
/// profile and collect (ppl, QA accuracy, decode tok/s) per cell.
pub fn measure(ctx: &Ctx) -> Result<Vec<MatrixCell>> {
    let profile = OutlierProfile::builtin("llama3-like").unwrap();
    let tasks = load_tasks(&ctx.artifacts.qa_tasks_json()?)?;
    let qa_limit = if ctx.fast { 8 } else { 50 };
    let steps = if ctx.fast { 16 } else { 64 };
    let mut cells = Vec::new();
    for recipe in QuantRecipe::matrix() {
        let ecfg = EngineConfig::from_recipe(recipe);
        let model = ctx.prepare_model(&profile, &ecfg)?;
        let ppl =
            crate::eval::perplexity(&model, &ctx.val_text, 96, ctx.ppl_windows());
        let (_, qa_avg) = crate::eval::qa::score_tasks(&model, &tasks, qa_limit);
        let tps = decode_tps(&model, ctx, &ecfg, steps);
        eprintln!(
            "matrix: {} -> ppl {} qa {:.1}% {:.0} tok/s",
            recipe.label(),
            format_ppl(ppl),
            qa_avg,
            tps
        );
        cells.push(MatrixCell { recipe, ppl, qa_avg, decode_tps: tps });
    }
    Ok(cells)
}

/// Serialize measured cells as the `BENCH_matrix.json` payload.
/// `smoke` marks runs on tiny random models (the CI scenario-matrix
/// job) as opposed to the trained-artifact harness sweep.
pub fn to_json(cells: &[MatrixCell], smoke: bool) -> Json {
    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            obj(vec![
                ("recipe", c.recipe.label().as_str().into()),
                ("smoothing", smoothing_name(c.recipe.smoothing).into()),
                ("rotation", rotation_name(c.recipe.rotation).into()),
                ("a_bits", (c.recipe.a_bits as usize).into()),
                ("w_bits", (c.recipe.w_bits as usize).into()),
                ("kv_bits", (c.recipe.kv_bits as usize).into()),
                ("group", c.recipe.group.into()),
                ("gptq", c.recipe.gptq.into()),
                ("ppl", (c.ppl as f64).into()),
                ("qa_avg_pct", (c.qa_avg as f64).into()),
                ("decode_tps", (c.decode_tps as f64).into()),
            ])
        })
        .collect();
    obj(vec![
        ("bench", "recipe_matrix".into()),
        ("pending", false.into()),
        ("smoke", smoke.into()),
        ("cells", Json::Arr(rows)),
    ])
}

pub fn run(ctx: &Ctx) -> Result<()> {
    let cells = measure(ctx)?;
    let mut table = MdTable::new(&[
        "Recipe",
        "Smooth",
        "Rotation",
        "A-W-KV",
        "PPL",
        "QA avg %",
        "decode tok/s",
    ]);
    for c in &cells {
        table.row(vec![
            c.recipe.label(),
            smoothing_name(c.recipe.smoothing).to_string(),
            rotation_name(c.recipe.rotation).to_string(),
            format!("{}-{}-{}", c.recipe.a_bits, c.recipe.w_bits, c.recipe.kv_bits),
            format_ppl(c.ppl),
            format!("{:.1}", c.qa_avg),
            format!("{:.0}", c.decode_tps),
        ]);
    }
    println!("\n## Strategy matrix — accuracy vs speed per quant recipe\n");
    table.print();
    ctx.write_report("matrix.md", &table.to_markdown())?;
    ctx.write_report("matrix.csv", &table.to_csv())?;
    let path = bench_output_path("BENCH_matrix.json");
    std::fs::write(&path, to_json(&cells, false).dump())?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
