//! Table 1: WikiText-2-stand-in perplexity for every method x scheme x
//! model profile.  The paper's claim shapes this must reproduce:
//!   * RTN / GPTQ-alone diverge (1e2..1e5-style ppl),
//!   * SmoothQuant fails at INT4 (big but finite),
//!   * RS recovers channel-wise-outlier profiles but breaks on heavy
//!     spikes (the llama3-70b-like column),
//!   * QuaRot is strong but degrades on the heavy-spike profile,
//!   * RRS is best or tied everywhere (the 57.33 -> 6.66 headline).

use anyhow::Result;

use crate::eval::perplexity::format_ppl;
use crate::model::weights::OutlierProfile;
use crate::model::EngineConfig;
use crate::quant::{Method, Scheme};

use super::{Ctx, MdTable};

pub const METHODS: [Method; 6] = [
    Method::Rtn,
    Method::SmoothQuant,
    Method::GptqOnly,
    Method::Rs,
    Method::QuaRot,
    Method::Rrs,
];

pub fn schemes() -> Vec<(&'static str, Scheme)> {
    vec![
        ("16-4-16 (A4W16KV16)", Scheme::A4W16KV16),
        ("4-4-16 (A4W4KV16)", Scheme::A4W4KV16),
        ("4-4-4 (A4W4KV4)", Scheme::A4W4KV4),
    ]
}

/// The Table-1 engine settings, shared by Table 2.
pub fn ecfg_like_table1(method: Method, scheme: Scheme) -> EngineConfig {
    ecfg_for(method, scheme)
}

fn ecfg_for(method: Method, scheme: Scheme) -> EngineConfig {
    EngineConfig {
        method,
        scheme,
        // Table 1 settings: RS evaluated at group 1 (upper bound, as in
        // the paper).  RRS uses the fused-kernel group scaled to this
        // model: the paper pairs group 128 with K = 4096..11008 (32-86
        // groups per GEMM); at dim 128 the equivalent granularity is
        // group 16 (8-16 groups). group == K would degenerate RS to a
        // single per-tensor scale.
        group: if method == Method::Rs { 1 } else { 16 },
        kv_group: 128,
        alpha: 0.5,
        // paper: GPTQ weights everywhere except the RTN row
        gptq: method != Method::Rtn,
        recipe: None,
    }
}

pub fn run(ctx: &Ctx) -> Result<()> {
    let profiles: Vec<OutlierProfile> = OutlierProfile::NAMES
        .iter()
        .map(|n| OutlierProfile::builtin(n).unwrap())
        .collect();

    let mut header = vec!["#Bits".to_string(), "Method".to_string()];
    header.extend(profiles.iter().map(|p| p.name.clone()));
    let hdr_ref: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = MdTable::new(&hdr_ref);

    // FP16 reference row
    let mut fp_row = vec!["16-16-16".to_string(), "FP16".to_string()];
    for p in &profiles {
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        let ppl = ctx.ppl(p, &ecfg)?;
        eprintln!("table1: FP16 {} -> {}", p.name, format_ppl(ppl));
        fp_row.push(format_ppl(ppl));
    }
    table.row(fp_row);

    for (scheme_label, scheme) in schemes() {
        for method in METHODS {
            let mut row = vec![scheme_label.to_string(), method.name().to_string()];
            for p in &profiles {
                let ppl = ctx.ppl(p, &ecfg_for(method, scheme))?;
                eprintln!(
                    "table1: {} {} {} -> {}",
                    scheme.label(),
                    method.name(),
                    p.name,
                    format_ppl(ppl)
                );
                row.push(format_ppl(ppl));
            }
            table.row(row);
        }
    }

    println!("\n## Table 1 — perplexity (lower is better)\n");
    table.print();
    ctx.write_report("table1.md", &table.to_markdown())?;
    ctx.write_report("table1.csv", &table.to_csv())?;
    Ok(())
}
