//! # RRS — Rotated Runtime Smooth for accurate INT4 inference
//!
//! Production-shaped reproduction of *"Rotated Runtime Smooth:
//! Training-Free Activation Smoother for accurate INT4 inference"*
//! (ICLR 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — serving coordinator: request router, dynamic
//!   batcher, prefill/decode scheduler, metrics, and a paged INT4
//!   KV-cache pool ([`kvpool`]: block-table attention, content-hash
//!   prefix sharing with partial-block tails, prefix-aware admission,
//!   LRU eviction, scheduler preemption) — plus a pure-rust INT4
//!   inference engine whose quantized GEMMs implement every smoothing
//!   method in the paper (RTN / SmoothQuant / RS / QuaRot / RRS / GPTQ),
//!   running over a runtime-dispatched SIMD microkernel layer
//!   ([`kernels`]: packed-weight INT4 GEMM, fused RRS prologue, FWHT —
//!   scalar / portable / AVX2 backends selected at startup), and a PJRT
//!   runtime that loads the AOT-lowered JAX graphs and serves them
//!   through the same pool ([`runtime::PagedPjrtEngine`]).  A unified
//!   observability layer ([`obs`]: lock-free log-scale latency
//!   histograms, per-request span tracing with Chrome `trace_event`
//!   export, Prometheus text exposition, sampled per-layer
//!   quant-health probes) instruments the whole stack.
//!
//! See `README.md` for the repo map and `docs/ARCHITECTURE.md` for the
//! full data-flow diagram.
//! * **L2 (python/compile/model.py)** — the JAX transformer, lowered once
//!   to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — the fused Runtime-Smooth INT4 GEMM
//!   as a Pallas kernel (interpret mode), numerically cross-checked against
//!   this crate through golden vectors.
//!
//! The environment vendors only the `xla` crate and its dependencies, so
//! the usual ecosystem crates (tokio/serde/clap/criterion/rand/proptest)
//! are re-implemented as small substrates under [`util`].

pub mod coordinator;
pub mod eval;
pub mod harness;
pub mod kernels;
pub mod kvpool;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod util;
