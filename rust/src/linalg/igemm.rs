//! Integer GEMM: int8 x int8 -> i32, the INT4 compute primitive.
//!
//! INT4 codes live in int8 containers (range [-7, 7]); products fit i16
//! and a K-length accumulation fits i32 for any realistic K (49 * K <<
//! 2^31).  `igemm_i8_bt` computes `A @ B^T` like the f32 variant.  The
//! K-blocked form (`igemm_i8_bt_blocked`) additionally returns per-block
//! partial sums — the hook the Runtime-Smooth fused epilogue needs
//! (one group scale per K block, paper section 3.2).

use crate::util::threadpool;

/// Row-major i8 matrix (INT4 codes in i8 containers).
#[derive(Clone, Debug, PartialEq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    pub fn zeros(rows: usize, cols: usize) -> MatI8 {
        MatI8 { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i8>) -> MatI8 {
        assert_eq!(rows * cols, data.len());
        MatI8 { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[i8] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn permute_cols(&self, perm: &[usize]) -> MatI8 {
        assert_eq!(perm.len(), self.cols);
        let mut out = MatI8::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = &mut out.data[i * self.cols..(i + 1) * self.cols];
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }
}

/// `C_i32 = A_i8 @ B_i8^T`; A [n,k], B [m,k] -> C [n,m].
pub fn igemm_i8_bt(a: &MatI8, b: &MatI8) -> Vec<i32> {
    assert_eq!(a.cols, b.cols);
    let (n, k, m) = (a.rows, a.cols, b.rows);
    let mut out = vec![0i32; n * m];
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out, m, threads, |i, orow| {
        let arow = &a.data[i * k..(i + 1) * k];
        for (j, c) in orow.iter_mut().enumerate() {
            let brow = &b.data[j * k..(j + 1) * k];
            *c = idot(arow, brow);
        }
    });
    out
}

/// Contiguous i8 dot with i32 accumulation.
///
/// Structured for the autovectorizer: widen to i16 (products of INT4
/// codes fit i16: |a*b| <= 49), multiply in i16, pairwise-add into i32 —
/// the `pmaddwd` shape LLVM recognizes on x86, giving 16-32 MACs/cycle
/// with AVX2/AVX-512.
#[inline]
pub fn idot(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0i32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] as i32 * b[i] as i32;
        acc[1] += a[i + 1] as i32 * b[i + 1] as i32;
        acc[2] += a[i + 2] as i32 * b[i + 2] as i32;
        acc[3] += a[i + 3] as i32 * b[i + 3] as i32;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

/// Fixed-length i8 dot (monomorphized): the compiler sees N and emits a
/// single fully-vectorized block with no tail checks — the building
/// block of the grouped (Runtime-Smooth fused) GEMM epilogue.
#[inline]
fn idot_fixed<const N: usize>(a: &[i8], b: &[i8]) -> i32 {
    debug_assert!(a.len() >= N && b.len() >= N);
    let a = &a[..N];
    let b = &b[..N];
    let mut acc = [0i32; 4];
    let mut i = 0;
    while i + 4 <= N {
        acc[0] += a[i] as i32 * b[i] as i32;
        acc[1] += a[i + 1] as i32 * b[i + 1] as i32;
        acc[2] += a[i + 2] as i32 * b[i + 2] as i32;
        acc[3] += a[i + 3] as i32 * b[i + 3] as i32;
        i += 4;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    while i < N {
        s += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    s
}

/// Grouped i8 dot with per-group f32 scales (the RS-fused inner kernel):
/// `sum_g sg[g] * (a_g . b_g)`.  Group sizes 32/64/128/256 dispatch to
/// monomorphized bodies so the hot loop stays a single vector block.
#[inline]
pub fn idot_grouped(a: &[i8], b: &[i8], group: usize, sg: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % group, 0);
    let ng = a.len() / group;
    let mut out = 0.0f32;
    match group {
        256 => {
            for g in 0..ng {
                let lo = g * 256;
                out += idot_fixed::<256>(&a[lo..], &b[lo..]) as f32 * sg[g];
            }
        }
        128 => {
            for g in 0..ng {
                let lo = g * 128;
                out += idot_fixed::<128>(&a[lo..], &b[lo..]) as f32 * sg[g];
            }
        }
        64 => {
            for g in 0..ng {
                let lo = g * 64;
                out += idot_fixed::<64>(&a[lo..], &b[lo..]) as f32 * sg[g];
            }
        }
        32 => {
            for g in 0..ng {
                let lo = g * 32;
                out += idot_fixed::<32>(&a[lo..], &b[lo..]) as f32 * sg[g];
            }
        }
        _ => {
            for g in 0..ng {
                let lo = g * group;
                out += idot(&a[lo..lo + group], &b[lo..lo + group]) as f32 * sg[g];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat_i8(r: usize, c: usize, seed: u64) -> MatI8 {
        let mut rng = Pcg::new(seed);
        MatI8::from_vec(
            r,
            c,
            (0..r * c).map(|_| (rng.below(15) as i8) - 7).collect(),
        )
    }

    #[test]
    fn matches_naive() {
        let a = randmat_i8(5, 17, 1);
        let b = randmat_i8(4, 17, 2);
        let got = igemm_i8_bt(&a, &b);
        for i in 0..5 {
            for j in 0..4 {
                let want: i32 = (0..17)
                    .map(|kk| {
                        a.data[i * 17 + kk] as i32 * b.data[j * 17 + kk] as i32
                    })
                    .sum();
                assert_eq!(got[i * 4 + j], want);
            }
        }
    }

    #[test]
    fn idot_extremes() {
        let a = vec![7i8; 1024];
        let b = vec![-7i8; 1024];
        assert_eq!(idot(&a, &b), -49 * 1024);
    }

    #[test]
    fn permute_cols_i8() {
        let a = MatI8::from_vec(1, 3, vec![1, 2, 3]);
        assert_eq!(a.permute_cols(&[2, 1, 0]).data, vec![3, 2, 1]);
    }
}
