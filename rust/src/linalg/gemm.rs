//! Row-major f32 matrix + blocked GEMM.
//!
//! `gemm_f32_bt(a, b)` computes `A @ B^T` — the natural layout for linear
//! layers whose weights are stored `[out, in]` (every GEMM in the engine).

use crate::util::threadpool;

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Gather columns: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Mat {
        assert_eq!(perm.len(), self.cols);
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

/// `C = A @ B^T`; A is [n,k], B is [m,k], C is [n,m].  Rows of C are
/// computed in parallel; the inner kernel is a k-contiguous dot product
/// (autovectorizes well since both operands stride 1).
pub fn gemm_f32_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "gemm_bt: inner dims");
    let (n, k, m) = (a.rows, a.cols, b.rows);
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, crow| {
        let arow = &a.data[i * k..(i + 1) * k];
        for (j, c) in crow.iter_mut().enumerate() {
            let brow = &b.data[j * k..(j + 1) * k];
            *c = dot(arow, brow);
        }
    });
    out
}

/// `C = A @ B`; A is [n,k], B is [k,m].
pub fn gemm_f32(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm: inner dims");
    let (n, k, m) = (a.rows, a.cols, b.cols);
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, crow| {
        let arow = &a.data[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * m..(kk + 1) * m];
            for (c, &bv) in crow.iter_mut().zip(brow) {
                *c += av * bv;
            }
        }
    });
    out
}

/// Contiguous dot product, unrolled x4 for the autovectorizer.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn naive_bt(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                let mut s = 0.0;
                for kk in 0..a.cols {
                    s += a.at(i, kk) * b.at(j, kk);
                }
                out.data[i * b.rows + j] = s;
            }
        }
        out
    }

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(r, c, rng.normal_vec(r * c))
    }

    #[test]
    fn bt_matches_naive() {
        for (n, k, m, seed) in [(3, 5, 4, 1), (8, 16, 8, 2), (1, 33, 7, 3)] {
            let a = randmat(n, k, seed);
            let b = randmat(m, k, seed + 10);
            let got = gemm_f32_bt(&a, &b);
            let want = naive_bt(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-4);
        }
    }

    #[test]
    fn gemm_matches_bt_via_transpose() {
        let a = randmat(4, 6, 5);
        let b = randmat(6, 3, 6);
        let got = gemm_f32(&a, &b);
        let want = gemm_f32_bt(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn permute_cols_gathers() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let p = a.permute_cols(&[2, 0, 1]);
        assert_eq!(p.data, vec![3., 1., 2., 6., 4., 5.]);
    }

    #[test]
    fn transpose_involution() {
        let a = randmat(5, 7, 9);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn dot_matches_scalar() {
        let mut rng = Pcg::new(1);
        let a = rng.normal_vec(37);
        let b = rng.normal_vec(37);
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - want).abs() < 1e-4);
    }
}
