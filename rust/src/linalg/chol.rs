//! Cholesky factorization + SPD inverse — the numerics GPTQ needs for its
//! inverse-Hessian error feedback.

use anyhow::{bail, Result};

/// Lower Cholesky factor L of a symmetric positive-definite matrix
/// (row-major [n,n]): `A = L L^T`.
pub fn cholesky_lower(a: &[f32], n: usize) -> Result<Vec<f32>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    let a64: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    for i in 0..n {
        for j in 0..=i {
            let mut s = a64[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (s={s})");
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l.into_iter().map(|x| x as f32).collect())
}

/// Inverse of an SPD matrix via Cholesky: `A^{-1} = L^{-T} L^{-1}`.
pub fn invert_spd(a: &[f32], n: usize) -> Result<Vec<f32>> {
    let l = cholesky_lower(a, n)?;
    let l64: Vec<f64> = l.iter().map(|&x| x as f64).collect();
    // forward-solve L X = I  -> X = L^{-1} (lower triangular)
    let mut linv = vec![0.0f64; n * n];
    for col in 0..n {
        linv[col * n + col] = 1.0 / l64[col * n + col];
        for i in col + 1..n {
            let mut s = 0.0;
            for k in col..i {
                s -= l64[i * n + k] * linv[k * n + col];
            }
            linv[i * n + col] = s / l64[i * n + i];
        }
    }
    // A^{-1} = L^{-T} L^{-1}
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in i.max(j)..n {
                s += linv[k * n + i] * linv[k * n + j];
            }
            out[i * n + j] = s;
        }
    }
    Ok(out.into_iter().map(|x| x as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Random SPD matrix A = B B^T + eps I.
    fn random_spd(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        let b: Vec<f32> = rng.normal_vec(n * n);
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { 0.5 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn llt_reconstructs() {
        let n = 16;
        let a = random_spd(n, 1);
        let l = cholesky_lower(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-2, "({i},{j})");
            }
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let n = 12;
        let a = random_spd(n, 2);
        let ainv = invert_spd(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * ainv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-2, "({i},{j}) -> {s}");
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_lower(&a, 2).is_err());
    }
}
