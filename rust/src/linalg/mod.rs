//! Dense linear algebra substrate: row-major f32 matrices, blocked GEMMs
//! (f32 and int8->int32), the fast Walsh-Hadamard transform used by the
//! rotation methods, and the Cholesky solver GPTQ needs.

pub mod chol;
pub mod fwht;
pub mod gemm;
pub mod igemm;

pub use chol::{cholesky_lower, invert_spd};
pub use fwht::{fwht_inplace, fwht_rows};
pub use gemm::{gemm_f32, gemm_f32_bt, Mat};
pub use igemm::igemm_i8_bt;

/// Softmax over a mutable row, numerically stable.
pub fn softmax_inplace(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// argmax index of a row (first on ties).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut r = vec![1.0, 2.0, 3.0, -1e30];
        softmax_inplace(&mut r);
        assert!((r.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(r[3] < 1e-12);
        assert!(r[2] > r[1] && r[1] > r[0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[0.0, 5.0, 5.0, 1.0]), 1);
    }
}
