//! Fast Walsh-Hadamard transform — the rotation primitive of QuaRot/RRS.
//!
//! `fwht_inplace` applies the *normalized* Sylvester-Hadamard matrix
//! (`x @ H_K / sqrt(K)`-equivalent) in O(K log K).  Since Sylvester H is
//! symmetric and orthogonal, the transform is an involution — applied
//! twice it returns the input, which the tests exploit.
//!
//! The public entry points dispatch through the [`crate::kernels`]
//! registry (SIMD butterflies on AVX2 hosts); every backend is
//! bit-identical to [`fwht_inplace_scalar`], the reference kept here.

/// In-place normalized FWHT along a power-of-two-length slice, on the
/// dispatched kernel backend.
pub fn fwht_inplace(x: &mut [f32]) {
    let k = x.len();
    assert!(k.is_power_of_two(), "fwht length {k} not a power of two");
    crate::kernels::fwht_dispatch(x);
}

/// The scalar reference butterfly network (the `RRS_KERNEL=scalar`
/// backend and the oracle the SIMD backends are diffed against).
pub fn fwht_inplace_scalar(x: &mut [f32]) {
    let k = x.len();
    assert!(k.is_power_of_two(), "fwht length {k} not a power of two");
    let mut h = 1;
    while h < k {
        let step = h * 2;
        let mut base = 0;
        while base < k {
            for i in base..base + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = a + b;
                x[i + h] = a - b;
            }
            base += step;
        }
        h = step;
    }
    let norm = 1.0 / (k as f32).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

/// Apply the normalized FWHT to every `k`-length row of a flat buffer
/// (rows in parallel on the dispatched backend).
pub fn fwht_rows(data: &mut [f32], k: usize) {
    assert_eq!(data.len() % k, 0);
    crate::kernels::fwht_rows_par(data, k);
}

/// Dense normalized Hadamard matrix (for tests / cross-checks).
pub fn hadamard_dense(k: usize) -> Vec<f32> {
    assert!(k.is_power_of_two());
    let mut h = vec![0.0f32; k * k];
    h[0] = 1.0;
    let mut n = 1;
    while n < k {
        for i in 0..n {
            for j in 0..n {
                let v = h[i * k + j];
                h[i * k + (j + n)] = v;
                h[(i + n) * k + j] = v;
                h[(i + n) * k + (j + n)] = -v;
            }
        }
        n *= 2;
    }
    let norm = 1.0 / (k as f32).sqrt();
    for v in h.iter_mut() {
        *v *= norm;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn involution() {
        let mut rng = Pcg::new(1);
        let orig = rng.normal_vec(256);
        let mut x = orig.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn matches_dense() {
        let k = 64;
        let mut rng = Pcg::new(2);
        let x = rng.normal_vec(k);
        let h = hadamard_dense(k);
        let mut want = vec![0.0f32; k];
        for j in 0..k {
            for (i, &xi) in x.iter().enumerate() {
                want[j] += xi * h[i * k + j];
            }
        }
        let mut got = x.clone();
        fwht_inplace(&mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn preserves_norm() {
        let mut rng = Pcg::new(3);
        let x = rng.normal_vec(128);
        let n0: f32 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y);
        let n1: f32 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
    }

    #[test]
    fn spreads_spike() {
        // paper eq. 4: a single spike becomes constant magnitude |O|/sqrt(K)
        let k = 128;
        let mut x = vec![0.0f32; k];
        x[17] = 100.0;
        fwht_inplace(&mut x);
        let expect = 100.0 / (k as f32).sqrt();
        for v in &x {
            assert!((v.abs() - expect).abs() < 1e-3);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_pow2() {
        let mut x = vec![0.0f32; 12];
        fwht_inplace(&mut x);
    }
}
