//! The paged serving engine: [`QuantModel`] forward paths running over a
//! shared [`KvPool`], with prompt-prefix reuse at prefill time.
//!
//! Sequences hold a block table instead of owning rows; a batch of
//! sequences plus the pool adapts to the engine's [`KvSeqBatch`]
//! interface, so prefill/decode run through the *same* generic forwards
//! as the flat [`crate::model::engine::KvCache`] path — the paged path
//! is bit-identical by construction (asserted in
//! rust/tests/kvpool_paged.rs).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::sync::{lock_recover, Mutex};

use crate::linalg::gemm::Mat;
use crate::model::engine::{KvSeqBatch, QuantModel};

use super::block::BlockId;
use super::pool::{KvPool, KvPoolConfig, PoolStats, HASH_SEED};

/// Process-wide sequence identity source ([`PagedSeq::id`]).
static NEXT_SEQ_ID: AtomicU64 = AtomicU64::new(1);

/// Per-sequence state on the paged backend: a block table plus the token
/// history needed to seal full blocks into the prefix cache.  Shared by
/// every pool-governed engine — the interpreted [`PagedEngine`] here and
/// the AOT [`crate::runtime::PagedPjrtEngine`].
pub struct PagedSeq {
    /// Pool blocks covering positions `[0, len)`, in order.
    pub table: Vec<BlockId>,
    /// Cached positions.
    pub len: usize,
    /// Tokens whose K/V rows are cached (`tokens.len() == len`).
    pub tokens: Vec<u32>,
    /// Process-unique identity, minted by [`PagedSeq::new`].  Release
    /// replaces the state with a fresh one, so a recycled slot never
    /// aliases an old identity — this is what lets resident-lane caches
    /// ([`crate::runtime::residency`]) trust a `(id, epoch)` match.
    pub id: u64,
    /// Bumped whenever pool-side rows for this sequence change outside a
    /// decode append the owning engine performed itself (today: prompt
    /// admission, which pins prefix hits and adopts partial tails).  A
    /// resident dense copy tagged with a stale epoch must be re-gathered.
    /// In the current lifecycle every admission also starts from a
    /// freshly-minted `id` (release replaces the state), so the id check
    /// already subsumes this one — the epoch is belt-and-braces for any
    /// future in-place re-admission path.
    pub epoch: u64,
    /// Blocks already sealed into the prefix map.
    pub(crate) sealed_blocks: usize,
    /// Chain hash up to `sealed_blocks`.
    pub(crate) chain: u64,
}

impl PagedSeq {
    pub fn new() -> PagedSeq {
        PagedSeq {
            table: Vec::new(),
            len: 0,
            tokens: Vec::new(),
            id: NEXT_SEQ_ID.fetch_add(1, Ordering::Relaxed),
            epoch: 0,
            sealed_blocks: 0,
            chain: HASH_SEED,
        }
    }
}

impl Default for PagedSeq {
    fn default() -> Self {
        PagedSeq::new()
    }
}

/// The prefill skeleton every pool-governed backend shares (interpreted
/// [`PagedEngine`] and the AOT `runtime::PagedPjrtEngine`): pin the
/// cached prompt prefix, then reserve the unshared suffix *plus one
/// decode-headroom block* — the exact charge
/// [`KvPool::can_fit_prompt`](crate::kvpool::KvPool::can_fit_prompt)
/// accounts for.  Returns the matched token count, or `None` with the
/// sequence fully released when the reservation fails.
pub(crate) fn begin_paged_prefill(
    pool: &mut KvPool,
    seq: &mut PagedSeq,
    tokens: &[u32],
) -> Option<usize> {
    debug_assert!(seq.len == 0 && seq.table.is_empty(), "prefill on a live seq");
    // admission mutates pool-side rows (prefix pins, partial-tail
    // adoption): any resident dense copy of this sequence goes stale
    seq.epoch = seq.epoch.wrapping_add(1);
    let matched = pool.match_prefix(tokens, &mut seq.table);
    seq.len = matched;
    seq.tokens.extend_from_slice(tokens);
    // a match ending mid-block shared its tail block read-only; the
    // first append materializes the deferred CoW copy from a fresh
    // block, so the reservation-time re-check must also see one
    // allocatable block beyond the table itself
    let pending_cow = matched % pool.block_size() != 0;
    if !pool.reserve(&mut seq.table, tokens.len() + 1)
        || (pending_cow && pool.available() == 0)
    {
        pool.release_seq(&mut seq.table);
        *seq = PagedSeq::new();
        return None;
    }
    Some(matched)
}

/// Seal the sequence's newly-filled full blocks into the prefix cache
/// (the closing half of the shared prefill/decode skeleton).
pub(crate) fn seal_paged_seq(pool: &mut KvPool, seq: &mut PagedSeq) {
    let (sealed, chain) =
        pool.seal_full_blocks(&seq.table, &seq.tokens, seq.sealed_blocks, seq.chain);
    seq.sealed_blocks = sealed;
    seq.chain = chain;
}

/// [`KvSeqBatch`] adapter: a batch of paged sequences sharing one pool.
struct PagedKvBatch<'a, 'b> {
    pool: &'a mut KvPool,
    seqs: &'a mut [&'b mut PagedSeq],
}

impl KvSeqBatch for PagedKvBatch<'_, '_> {
    fn batch_len(&self) -> usize {
        self.seqs.len()
    }

    fn pos(&self, i: usize) -> usize {
        self.seqs[i].len
    }

    fn push_row(&mut self, i: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.pool.append_row(&mut self.seqs[i].table, layer, pos, k, v);
    }

    fn view_rows<'s>(
        &'s self,
        i: usize,
        layer: usize,
        k_scratch: &'s mut Vec<Vec<f32>>,
        v_scratch: &'s mut Vec<Vec<f32>>,
    ) -> (&'s [Vec<f32>], &'s [Vec<f32>]) {
        self.pool.gather_rows(&self.seqs[i].table, layer, k_scratch, v_scratch)
    }

    fn advance(&mut self, i: usize, n: usize) {
        self.seqs[i].len += n;
    }
}

/// Paged-attention engine over a [`QuantModel`]: the serving backend
/// whose KV memory is a fixed slab of shared, refcounted INT4 blocks.
pub struct PagedEngine {
    pub model: QuantModel,
    pool: Mutex<KvPool>,
}

impl PagedEngine {
    /// `n_blocks` fixed-size blocks of `block_size` token positions each.
    pub fn new(model: QuantModel, n_blocks: usize, block_size: usize) -> PagedEngine {
        let cfg = KvPoolConfig {
            n_blocks,
            block_size,
            n_layers: model.mcfg.n_layers,
            kv_bits: model.recipe.kv_bits,
            kv_group: model.kv_group(),
        };
        PagedEngine { model, pool: Mutex::new(KvPool::new(cfg)) }
    }

    pub fn new_seq(&self) -> PagedSeq {
        PagedSeq::new()
    }

    /// Fallible prefill: under one pool lock, pin the cached prompt
    /// prefix (full blocks zero-copy, a mid-block tail by copy), reserve
    /// the unshared suffix plus one decode-headroom block, and forward
    /// the suffix.  Returns `None` — with the sequence fully released —
    /// when the reservation fails, which is the race-safe re-check
    /// behind [`can_admit`](PagedEngine::can_admit): a request admitted
    /// by the gate can still lose its blocks to an earlier admission in
    /// the same scheduler round.
    pub fn try_prefill(&self, seq: &mut PagedSeq, tokens: &[u32]) -> Option<Vec<f32>> {
        let mut pool = lock_recover(&self.pool);
        let matched = begin_paged_prefill(&mut pool, seq, tokens)?;
        let suffix = &tokens[matched..];
        let logits = {
            let mut seqs = [&mut *seq];
            let mut batch = PagedKvBatch { pool: &mut *pool, seqs: &mut seqs };
            self.model.forward_seq(suffix, &mut batch, 0)
        };
        seal_paged_seq(&mut pool, seq);
        Some(logits.row(logits.rows - 1).to_vec())
    }

    /// One batched decode step; mirrors
    /// [`QuantModel::decode_batch`] over block tables.
    pub fn decode(&self, batch: &mut [(&mut PagedSeq, u32)]) -> Mat {
        let mut pool = lock_recover(&self.pool);
        let tokens: Vec<u32> = batch.iter().map(|(_, t)| *t).collect();
        for (seq, tok) in batch.iter_mut() {
            seq.tokens.push(*tok);
            assert!(
                pool.reserve(&mut seq.table, seq.len + 1),
                "kvpool exhausted during decode (reserve_decode must gate)"
            );
        }
        let logits = {
            let mut seqs: Vec<&mut PagedSeq> =
                batch.iter_mut().map(|(s, _)| &mut **s).collect();
            let mut pb = PagedKvBatch { pool: &mut *pool, seqs: &mut seqs };
            self.model.decode_step(&mut pb, &tokens)
        };
        for (seq, _) in batch.iter_mut() {
            seal_paged_seq(&mut pool, seq);
        }
        logits
    }

    /// Release the sequence's blocks back to the pool (retire or
    /// preemption); sealed blocks stay cached for prefix reuse.
    pub fn release(&self, seq: &mut PagedSeq) {
        let mut pool = lock_recover(&self.pool);
        pool.release_seq(&mut seq.table);
        *seq = PagedSeq::new();
    }

    /// Can a prompt of this shape be admitted right now?  Prefix-aware:
    /// the prompt is charged only for its *unshared* suffix blocks (plus
    /// one decode-headroom block) — cached prefix blocks arrive
    /// pre-filled, so a 90%-shared prompt fits into a pool with room for
    /// just its tail.  [`try_prefill`](PagedEngine::try_prefill) re-checks
    /// at reservation time, keeping same-round admission races safe.
    pub fn can_admit(&self, prompt: &[u32]) -> bool {
        lock_recover(&self.pool).can_fit_prompt(prompt)
    }

    /// Ensure `seq` can grow by one token; `false` = preempt first.
    pub fn reserve_decode(&self, seq: &mut PagedSeq) -> bool {
        lock_recover(&self.pool).reserve(&mut seq.table, seq.len + 1)
    }

    /// Longest prompt prefix currently resident in the prefix cache.
    pub fn prefix_match_len(&self, prompt: &[u32]) -> usize {
        lock_recover(&self.pool).probe_prefix(prompt)
    }

    pub fn stats(&self) -> PoolStats {
        lock_recover(&self.pool).stats()
    }

    pub fn seq_bytes(&self, seq: &PagedSeq) -> usize {
        lock_recover(&self.pool).table_bytes(&seq.table)
    }
}
