//! The paged KV-block pool: free-list allocation, refcounted sharing,
//! content-hash prefix cache, and LRU eviction of released blocks.
//!
//! Blocks are sealed into the prefix map only when full, so shared
//! blocks are immutable by construction; copy-on-write in
//! [`KvPool::append_row`] guards the invariant anyway.
//!
//! Two prefix-reuse mechanisms feed [`KvPool::match_prefix`]:
//!
//! * **full-block hits** — the chain-hash walk pins sealed blocks
//!   directly into the new sequence's table (zero-copy sharing);
//! * **partial-block tail hits** — when the shared prefix ends
//!   mid-block, the sealed sibling that extends the chain is found via
//!   the parent-hash index and pinned into the table *read-only*; the
//!   shared leading rows are copied into a fresh block only on the
//!   first append into that block (lazy copy-on-write — a sequence that
//!   is released before it ever appends, e.g. on a failed reservation,
//!   never pays the copy; `lazy_tail_shares` vs `lazy_tail_copies`
//!   proves the deferral).
//!
//! [`KvPool::can_fit_prompt`] is the admission-side mirror: it charges a
//! prompt only for the blocks `match_prefix` + [`KvPool::reserve`] would
//! actually allocate, which is what lets the scheduler admit many more
//! concurrent sequences under shared-prefix traffic.

use std::collections::HashMap;

use super::block::{BlockId, KvBlock};

/// FNV-1a offset basis: the start of every sequence's chain hash.
pub(crate) const HASH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Extend a chain hash over one block's worth of token ids (FNV-1a).
/// Chaining makes a block's hash depend on its whole prompt prefix, so
/// equal blocks at different prefixes never collide by construction.
pub(crate) fn chain_hash(mut h: u64, tokens: &[u32]) -> u64 {
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Pool geometry + storage format.
#[derive(Clone, Copy, Debug)]
pub struct KvPoolConfig {
    /// Total blocks in the slab.
    pub n_blocks: usize,
    /// Token positions per block.
    pub block_size: usize,
    pub n_layers: usize,
    pub kv_bits: u8,
    pub kv_group: usize,
}

/// Aggregate pool counters surfaced through [`crate::coordinator`]'s
/// metrics and the TCP stats endpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub blocks_total: usize,
    /// On the free list (never used or fully reclaimed).
    pub blocks_free: usize,
    /// Refcount 0 but retained in the prefix cache (evictable).
    pub blocks_cached: usize,
    /// Pinned by at least one live sequence.
    pub blocks_active: usize,
    pub bytes_used: usize,
    /// match_prefix calls / tokens probed / tokens + blocks served from
    /// the prefix cache (cumulative).
    pub prefix_queries: u64,
    pub prefix_query_tokens: u64,
    pub prefix_hit_tokens: u64,
    pub prefix_hit_blocks: u64,
    /// Prefix hits that ended mid-block and were served by sharing the
    /// sealed tail block (partial-block tail sharing).
    pub prefix_partial_hits: u64,
    pub evictions: u64,
    pub cow_copies: u64,
    /// Sealed tail blocks shared read-only at match time (the lazy
    /// partial-tail path: no rows copied yet).
    pub lazy_tail_shares: u64,
    /// Broken internal invariants survived at runtime (a KV row dropped
    /// because a reserve-gated alloc failed anyway).  Always 0 in a
    /// healthy pool; nonzero means the affected sequences' caches are
    /// incomplete and their outputs untrustworthy — surfaced so the
    /// watchdog/stats layers can scream instead of the process dying.
    pub integrity_errors: u64,
    /// Lazily-shared tails actually materialized by a first append.
    /// `lazy_tail_shares - lazy_tail_copies` = copies the lazy scheme
    /// avoided outright (sequences released before ever appending).
    pub lazy_tail_copies: u64,
}

struct Slot {
    block: KvBlock,
    refcount: u32,
    /// Chain hash once sealed + registered in the prefix map.
    hash: Option<u64>,
    /// Chain hash of the prefix *before* this block (children-index key;
    /// meaningful only while `hash` is set).
    parent: u64,
    /// Token ids this sealed block covers (verifies map hits).
    tokens: Vec<u32>,
    /// LRU stamp, updated when the refcount drops to 0.
    last_use: u64,
}

/// Result of one prefix-cache walk over a prompt.
struct PrefixWalk {
    /// Tokens covered by full-block hits.
    matched: usize,
    /// The sealed blocks serving those tokens, in chain order.
    hits: Vec<BlockId>,
    /// Mid-block tail candidate: a sealed sibling of the first
    /// non-matching block and how many of its leading rows the prompt
    /// shares (always leaves at least one prompt token to forward).
    partial: Option<(BlockId, usize)>,
}

/// The paged KV pool.
///
/// # Examples
///
/// Reserve a block table for a sequence, then release it; unsealed
/// blocks return straight to the free list:
///
/// ```
/// use rrs::kvpool::{KvPool, KvPoolConfig};
///
/// let mut pool = KvPool::new(KvPoolConfig {
///     n_blocks: 4,
///     block_size: 8,
///     n_layers: 1,
///     kv_bits: 4,
///     kv_group: 8,
/// });
/// let mut table = Vec::new();
/// assert!(pool.reserve(&mut table, 20)); // ceil(20/8) = 3 of 4 blocks
/// assert_eq!(table.len(), 3);
/// assert_eq!(pool.available(), 1);
/// assert!(!pool.can_fit_prompt(&[1, 2, 3, 4, 5, 6, 7, 8, 9])); // needs 2
/// pool.release_seq(&mut table);
/// assert_eq!(pool.available(), 4);
/// ```
pub struct KvPool {
    cfg: KvPoolConfig,
    slots: Vec<Slot>,
    free: Vec<BlockId>,
    /// chain hash of a sealed full block -> its slot.
    prefix_map: HashMap<u64, BlockId>,
    /// chain hash of a prefix -> sealed blocks extending it (partial
    /// tail-sharing candidates).
    children: HashMap<u64, Vec<BlockId>>,
    tick: u64,
    prefix_queries: u64,
    prefix_query_tokens: u64,
    prefix_hit_tokens: u64,
    prefix_hit_blocks: u64,
    prefix_partial_hits: u64,
    evictions: u64,
    cow_copies: u64,
    lazy_tail_shares: u64,
    lazy_tail_copies: u64,
    integrity_errors: u64,
}

impl KvPool {
    pub fn new(cfg: KvPoolConfig) -> KvPool {
        assert!(cfg.n_blocks > 0 && cfg.block_size > 0 && cfg.n_layers > 0);
        let slots = (0..cfg.n_blocks)
            .map(|_| Slot {
                block: KvBlock::new(cfg.n_layers, cfg.kv_bits, cfg.kv_group),
                refcount: 0,
                hash: None,
                parent: HASH_SEED,
                tokens: Vec::new(),
                last_use: 0,
            })
            .collect();
        // pop order: block 0 first
        let free = (0..cfg.n_blocks as BlockId).rev().collect();
        KvPool {
            cfg,
            slots,
            free,
            prefix_map: HashMap::new(),
            children: HashMap::new(),
            tick: 0,
            prefix_queries: 0,
            prefix_query_tokens: 0,
            prefix_hit_tokens: 0,
            prefix_hit_blocks: 0,
            prefix_partial_hits: 0,
            evictions: 0,
            cow_copies: 0,
            lazy_tail_shares: 0,
            lazy_tail_copies: 0,
            integrity_errors: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    pub fn blocks_total(&self) -> usize {
        self.cfg.n_blocks
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_size)
    }

    /// Blocks obtainable right now: the free list plus evictable cached
    /// blocks (refcount 0, retained only for prefix reuse).
    pub fn available(&self) -> usize {
        self.free.len() + self.cached_count()
    }

    fn cached_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.refcount == 0 && s.hash.is_some())
            .count()
    }

    /// Grab a block: free list first, then evict the least-recently-used
    /// cached block.  Returned slot has refcount 1 and an empty block.
    fn alloc(&mut self) -> Option<BlockId> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => self.evict_lru()?,
        };
        let slot = &mut self.slots[id as usize];
        debug_assert!(slot.refcount == 0 && slot.hash.is_none());
        slot.refcount = 1;
        Some(id)
    }

    fn evict_lru(&mut self) -> Option<BlockId> {
        let id = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.refcount == 0 && s.hash.is_some())
            .min_by_key(|(_, s)| s.last_use)
            .map(|(i, _)| i as BlockId)?;
        let slot = &mut self.slots[id as usize];
        // the filter above admits only hash-carrying slots; a slot that
        // lost its hash between filter and take would mean map/slot
        // desync, so fail loudly in debug and report no evictable block
        // in release rather than panicking the serving thread
        let Some(h) = slot.hash.take() else {
            debug_assert!(false, "cached block lost its hash");
            self.integrity_errors += 1;
            return None;
        };
        let parent = slot.parent;
        self.prefix_map.remove(&h);
        if let Some(kids) = self.children.get_mut(&parent) {
            kids.retain(|&k| k != id);
            if kids.is_empty() {
                self.children.remove(&parent);
            }
        }
        slot.tokens.clear();
        slot.block.reset(self.cfg.kv_bits, self.cfg.kv_group);
        self.evictions += 1;
        Some(id)
    }

    /// Ensure `table` covers `upto_tokens` positions, allocating tail
    /// blocks as needed.  `false` = pool exhausted (the scheduler must
    /// preempt); partially-reserved blocks stay in the table and are
    /// reclaimed by [`release_seq`](KvPool::release_seq).
    pub fn reserve(&mut self, table: &mut Vec<BlockId>, upto_tokens: usize) -> bool {
        let need = self.blocks_for(upto_tokens);
        while table.len() < need {
            match self.alloc() {
                Some(id) => table.push(id),
                None => return false,
            }
        }
        true
    }

    /// The one prefix-cache walk every entry point shares: chain-hash the
    /// prompt's full blocks through the map, verifying each hit's tokens
    /// (hash-collision guard), then look for a partial-tail sibling of
    /// the first non-matching block via the children index.  At least one
    /// prompt token is always left for the forward pass.
    fn walk_prefix(&self, tokens: &[u32]) -> PrefixWalk {
        let bs = self.cfg.block_size;
        let mut h = HASH_SEED;
        let mut matched = 0usize;
        let mut hits = Vec::new();
        while matched + bs < tokens.len() {
            let seg = &tokens[matched..matched + bs];
            let hn = chain_hash(h, seg);
            let Some(id) = self.prefix_map.get(&hn).copied() else { break };
            if self.slots[id as usize].tokens.as_slice() != seg {
                break; // hash collision: do not serve foreign rows
            }
            hits.push(id);
            matched += bs;
            h = hn;
        }
        // partial tail: among the sealed blocks extending the matched
        // chain, share the longest run of leading rows the prompt agrees
        // with (capped so one token is always left to forward)
        let mut partial = None;
        if matched < tokens.len() {
            let rest = &tokens[matched..tokens.len() - 1];
            let mut best = 0usize;
            if let Some(kids) = self.children.get(&h) {
                for &id in kids {
                    let ts = &self.slots[id as usize].tokens;
                    let n = ts.iter().zip(rest).take_while(|(a, b)| a == b).count();
                    if n > best {
                        best = n;
                        partial = Some((id, n));
                    }
                }
            }
        }
        PrefixWalk { matched, hits, partial }
    }

    /// Walk the prompt through the prefix cache, pinning every full-block
    /// hit into `table`; when the prefix ends mid-block, the sealed tail
    /// sibling is pinned **read-only** (lazy partial-tail adoption — no
    /// rows move).  The first [`append_row`](KvPool::append_row) into
    /// that block copies just the shared leading rows (CoW on write
    /// instead of at match time), so a sequence released before it ever
    /// appends never pays the copy.  Returns the number of matched
    /// tokens; at least one prompt token is always left for the forward
    /// pass.  Callers must budget one allocatable block for the deferred
    /// copy when the match ends mid-block (admission does: see
    /// [`can_fit_prompt`](KvPool::can_fit_prompt)).
    pub fn match_prefix(&mut self, tokens: &[u32], table: &mut Vec<BlockId>) -> usize {
        self.prefix_queries += 1;
        self.prefix_query_tokens += tokens.len() as u64;
        let walk = self.walk_prefix(tokens);
        for &id in &walk.hits {
            self.slots[id as usize].refcount += 1;
            table.push(id);
        }
        let mut matched = walk.matched;
        if let Some((src, rows)) = walk.partial {
            if rows > 0 {
                self.slots[src as usize].refcount += 1;
                table.push(src);
                matched += rows;
                self.prefix_partial_hits += 1;
                self.lazy_tail_shares += 1;
            }
        }
        self.prefix_hit_blocks += walk.hits.len() as u64;
        self.prefix_hit_tokens += matched as u64;
        matched
    }

    /// Read-only prefix probe: matched token count (full-block plus
    /// partial-tail), with no refcounting and no counter updates.
    pub fn probe_prefix(&self, tokens: &[u32]) -> usize {
        let walk = self.walk_prefix(tokens);
        walk.matched + walk.partial.map_or(0, |(_, n)| n)
    }

    /// Exact admission accounting: can a prompt of this shape be matched
    /// + reserved right now (including one decode-headroom block)?  The
    /// prompt is charged only for its *unshared* suffix blocks — full
    /// prefix hits arrive pre-filled and are excluded — while hit blocks
    /// that are currently evictable are excluded from the supply side
    /// (pinning them removes them from the eviction pool).  This mirrors
    /// [`match_prefix`](KvPool::match_prefix) +
    /// [`reserve`](KvPool::reserve) exactly, so a prompt admitted with no
    /// concurrent pool mutation is guaranteed to reserve.
    pub fn can_fit_prompt(&self, tokens: &[u32]) -> bool {
        let walk = self.walk_prefix(tokens);
        let mut pinned_supply = walk
            .hits
            .iter()
            .filter(|&&id| self.slots[id as usize].refcount == 0)
            .count();
        // a lazily-shared tail stays pinned until its deferred CoW copy
        // lands, so a currently-evictable tail also leaves the supply
        // (the copy target itself is already charged: the tail's block
        // position is not subtracted from `needed`)
        if let Some((id, rows)) = walk.partial {
            if rows > 0 && self.slots[id as usize].refcount == 0 {
                pinned_supply += 1;
            }
        }
        let needed = self.blocks_for(tokens.len() + 1) - walk.hits.len();
        needed <= self.free.len() + self.cached_count() - pinned_supply
    }

    /// Append one K/V row pair at absolute position `pos` of the sequence
    /// owning `table`.  Allocates the tail block on a boundary (callers
    /// gate capacity via [`reserve`](KvPool::reserve) / admission) and
    /// copies-on-write if the target block is shared.
    pub fn append_row(
        &mut self,
        table: &mut Vec<BlockId>,
        layer: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let _phase =
            crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::KvScatter);
        let bs = self.cfg.block_size;
        let bi = pos / bs;
        debug_assert!(bi <= table.len(), "non-sequential KV append");
        if bi == table.len() {
            // reserve()/can_fit_prompt gate capacity before any forward
            // touches the pool, so an empty allocator here is a protocol
            // violation upstream.  Dropping the row (and counting it)
            // keeps the server alive: this sequence's cache is now
            // incomplete, which integrity_errors surfaces loudly, while
            // a panic here would take every lane down with it.
            let Some(id) = self.alloc() else {
                debug_assert!(false, "kvpool exhausted: reserve must gate capacity");
                self.integrity_errors += 1;
                return;
            };
            table.push(id);
        }
        let id = table[bi];
        // copy before mutating when the block is shared with another
        // live sequence (refcount) OR sealed into the prefix cache
        // (hash): a sealed tail lazily adopted from a *released* owner
        // has refcount 1, but mutating it in place would corrupt the
        // registered prefix block every future hit verifies against
        let shared = self.slots[id as usize].refcount > 1;
        let sealed = self.slots[id as usize].hash.is_some();
        if shared || sealed {
            // only the rows this sequence actually owns move (positions
            // `[bi*bs, pos)`), which for a lazily-shared sealed tail
            // trims the foreign rows past the shared prefix and
            // materializes the deferred copy
            let owned = pos - bi * bs;
            // same protocol contract as above: can_fit_prompt charges
            // one headroom block for a pending CoW, so exhaustion here
            // is an upstream accounting bug — skip the write (dropping
            // the row) instead of killing the serving thread
            let Some(copy) = self.alloc() else {
                debug_assert!(false, "kvpool exhausted during copy-on-write");
                self.integrity_errors += 1;
                return;
            };
            let data = self.slots[id as usize].block.clone_prefix(owned);
            self.slots[copy as usize].block = data;
            if sealed {
                self.lazy_tail_copies += 1;
            }
            self.release_block(id);
            table[bi] = copy;
            self.cow_copies += 1;
        }
        self.slots[table[bi] as usize].block.push(layer, k, v);
    }

    /// Dequantize every cached row of `table` for `layer` into the
    /// scratch buffers, returning (keys, values) views in position order.
    pub fn gather_rows<'a>(
        &self,
        table: &[BlockId],
        layer: usize,
        k_scratch: &'a mut Vec<Vec<f32>>,
        v_scratch: &'a mut Vec<Vec<f32>>,
    ) -> (&'a [Vec<f32>], &'a [Vec<f32>]) {
        let _phase =
            crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::KvGather);
        let mut n = 0usize;
        for &id in table {
            let (ks, vs) = &self.slots[id as usize].block.layers[layer];
            let rows = ks.len();
            while k_scratch.len() < n + rows {
                k_scratch.push(Vec::new());
            }
            while v_scratch.len() < n + rows {
                v_scratch.push(Vec::new());
            }
            for r in 0..rows {
                ks.row_into(r, &mut k_scratch[n + r]);
                vs.row_into(r, &mut v_scratch[n + r]);
            }
            n += rows;
        }
        (&k_scratch[..n], &v_scratch[..n])
    }

    /// Seal every full block of `tokens` into the prefix map, resuming
    /// from `(sealed, chain)`; returns the updated pair.  Already-sealed
    /// (matched) blocks just advance the chain.
    pub fn seal_full_blocks(
        &mut self,
        table: &[BlockId],
        tokens: &[u32],
        mut sealed: usize,
        mut chain: u64,
    ) -> (usize, u64) {
        let bs = self.cfg.block_size;
        while (sealed + 1) * bs <= tokens.len() {
            let seg = &tokens[sealed * bs..(sealed + 1) * bs];
            let parent = chain;
            chain = chain_hash(chain, seg);
            let id = table[sealed];
            if self.slots[id as usize].block.fill() < bs {
                break; // not yet full for every position
            }
            self.register_sealed(id, parent, chain, seg);
            sealed += 1;
        }
        (sealed, chain)
    }

    fn register_sealed(&mut self, id: BlockId, parent: u64, hash: u64, tokens: &[u32]) {
        if self.prefix_map.contains_key(&hash) {
            return; // an equivalent block is already registered
        }
        let slot = &mut self.slots[id as usize];
        slot.hash = Some(hash);
        slot.parent = parent;
        slot.tokens = tokens.to_vec();
        self.prefix_map.insert(hash, id);
        self.children.entry(parent).or_default().push(id);
    }

    /// Release every block of a retiring / preempted sequence.  Sealed
    /// blocks stay cached for prefix reuse (LRU-stamped leaf-first, so
    /// eviction trims chains from the tail); unsealed blocks are reset
    /// and freed.
    pub fn release_seq(&mut self, table: &mut Vec<BlockId>) {
        for id in table.drain(..).rev() {
            self.release_block(id);
        }
    }

    fn release_block(&mut self, id: BlockId) {
        let slot = &mut self.slots[id as usize];
        debug_assert!(slot.refcount > 0, "double release of KV block {id}");
        slot.refcount -= 1;
        if slot.refcount > 0 {
            return;
        }
        if slot.hash.is_some() {
            self.tick += 1;
            self.slots[id as usize].last_use = self.tick;
        } else {
            slot.block.reset(self.cfg.kv_bits, self.cfg.kv_group);
            self.free.push(id);
        }
    }

    /// Bytes held by the blocks of one sequence (scaled down for shared
    /// blocks would be fancier; this reports the plain sum).
    pub fn table_bytes(&self, table: &[BlockId]) -> usize {
        table.iter().map(|&id| self.slots[id as usize].block.bytes).sum()
    }

    pub fn stats(&self) -> PoolStats {
        let cached = self.cached_count();
        PoolStats {
            blocks_total: self.cfg.n_blocks,
            blocks_free: self.free.len(),
            blocks_cached: cached,
            blocks_active: self.cfg.n_blocks - self.free.len() - cached,
            bytes_used: self.slots.iter().map(|s| s.block.bytes).sum(),
            prefix_queries: self.prefix_queries,
            prefix_query_tokens: self.prefix_query_tokens,
            prefix_hit_tokens: self.prefix_hit_tokens,
            prefix_hit_blocks: self.prefix_hit_blocks,
            prefix_partial_hits: self.prefix_partial_hits,
            evictions: self.evictions,
            cow_copies: self.cow_copies,
            lazy_tail_shares: self.lazy_tail_shares,
            lazy_tail_copies: self.lazy_tail_copies,
            integrity_errors: self.integrity_errors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n_blocks: usize, block_size: usize) -> KvPoolConfig {
        KvPoolConfig { n_blocks, block_size, n_layers: 2, kv_bits: 4, kv_group: 8 }
    }

    fn fill_seq(pool: &mut KvPool, table: &mut Vec<BlockId>, tokens: &[u32]) {
        // push one 16-wide K/V row per token per layer, like a forward
        for layer in 0..2 {
            for (pos, &t) in tokens.iter().enumerate() {
                let row: Vec<f32> = (0..16).map(|j| (t as f32) + j as f32 * 0.1).collect();
                pool.append_row(table, layer, pos, &row, &row);
            }
        }
    }

    #[test]
    fn alloc_exhaustion_and_release() {
        let mut pool = KvPool::new(cfg(3, 4));
        let mut t1 = Vec::new();
        assert!(pool.reserve(&mut t1, 12)); // 3 blocks
        assert_eq!(t1.len(), 3);
        let mut t2 = Vec::new();
        assert!(!pool.reserve(&mut t2, 4)); // exhausted
        assert_eq!(pool.available(), 0);
        pool.release_seq(&mut t1);
        assert!(t1.is_empty());
        assert_eq!(pool.available(), 3); // unsealed blocks go straight to free
        assert!(pool.reserve(&mut t2, 4));
        pool.release_seq(&mut t2);
    }

    #[test]
    fn prefix_match_pins_and_verifies_tokens() {
        let mut pool = KvPool::new(cfg(8, 4));
        let tokens: Vec<u32> = (0..9).collect(); // 2 full blocks + 1 tail
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        let (sealed, chain) = pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);
        assert_eq!(sealed, 2);
        assert_ne!(chain, HASH_SEED);

        // a second sequence with the same prompt reuses both full blocks
        let mut t2 = Vec::new();
        let matched = pool.match_prefix(&tokens, &mut t2);
        assert_eq!(matched, 8);
        assert_eq!(t2.len(), 2);
        assert_eq!(t2[0], t1[0]);
        let s = pool.stats();
        assert_eq!(s.prefix_hit_blocks, 2);
        assert_eq!(s.prefix_hit_tokens, 8);

        // a different prompt matches nothing
        let other: Vec<u32> = (100..109).collect();
        let mut t3 = Vec::new();
        assert_eq!(pool.match_prefix(&other, &mut t3), 0);
        assert!(t3.is_empty());

        // an exactly-block-aligned prompt full-matches the first block and
        // partial-matches 3 rows of the second (one token is always left
        // for the forward pass, so the last position is never served);
        // the tail block is shared READ-ONLY — no rows copied at match
        let aligned: Vec<u32> = (0..8).collect();
        let mut t4 = Vec::new();
        assert_eq!(pool.match_prefix(&aligned, &mut t4), 7);
        assert_eq!(t4.len(), 2);
        assert_eq!(t4[1], t1[1], "tail shared read-only until first append");
        // pinned by t1 (owner), t2 (full hit), and t4 (lazy tail share)
        assert_eq!(pool.slots[t4[1] as usize].refcount, 3);
        let s = pool.stats();
        assert_eq!(s.prefix_partial_hits, 1);
        assert_eq!(s.lazy_tail_shares, 1);
        assert_eq!(s.cow_copies, 0, "lazy adoption copies nothing at match");
        pool.release_seq(&mut t2);
        pool.release_seq(&mut t4);
        pool.release_seq(&mut t1);
    }

    #[test]
    fn sealed_blocks_cache_then_evict_lru_leaf_first() {
        let mut pool = KvPool::new(cfg(3, 4));
        let tokens: Vec<u32> = (0..9).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);
        pool.release_seq(&mut t1);
        let s = pool.stats();
        assert_eq!(s.blocks_cached, 2); // two sealed blocks retained
        assert_eq!(s.blocks_free, 1); // the unsealed tail was freed
        assert_eq!(pool.available(), 3);

        // exhaust: allocations evict the cached chain leaf-first, so the
        // root block survives longest and still serves a 4-token match
        let mut t2 = Vec::new();
        assert!(pool.reserve(&mut t2, 8)); // free 1 + evict 1
        assert_eq!(pool.stats().evictions, 1);
        let mut t3 = Vec::new();
        assert_eq!(pool.match_prefix(&tokens, &mut t3), 4, "root block survives");
        pool.release_seq(&mut t3);
        pool.release_seq(&mut t2);
    }

    #[test]
    fn copy_on_write_unshares_a_block() {
        // a partially-filled block shared by two tables: appending through
        // one table must copy, leaving the other table's rows untouched
        let mut pool = KvPool::new(cfg(4, 4));
        let row = vec![0.5f32; 16];
        let mut ta = Vec::new();
        for layer in 0..2 {
            for pos in 0..3 {
                pool.append_row(&mut ta, layer, pos, &row, &row);
            }
        }
        let mut tb = vec![ta[0]];
        pool.slots[ta[0] as usize].refcount += 1;
        pool.append_row(&mut tb, 0, 3, &row, &row);
        assert_ne!(tb[0], ta[0], "append into a shared block must copy");
        assert_eq!(pool.stats().cow_copies, 1);
        assert_eq!(pool.slots[ta[0] as usize].refcount, 1);
        assert_eq!(pool.slots[ta[0] as usize].block.fill(), 3);
        assert_eq!(pool.slots[tb[0] as usize].block.fill(), 4);
        pool.release_seq(&mut tb);
        pool.release_seq(&mut ta);
    }

    #[test]
    fn partial_tail_shares_lazily_then_copies_on_first_append() {
        let mut pool = KvPool::new(cfg(8, 4));
        let tokens: Vec<u32> = (0..9).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);

        // shares 6 tokens: block 0 fully, 2 rows into block 1 — the
        // sealed tail is pinned read-only, nothing copied yet
        let probe: Vec<u32> = vec![0, 1, 2, 3, 4, 5, 99, 98];
        assert_eq!(pool.probe_prefix(&probe), 6);
        let mut t2 = Vec::new();
        assert_eq!(pool.match_prefix(&probe, &mut t2), 6);
        assert_eq!(t2.len(), 2);
        assert_eq!(t2[0], t1[0], "full block shared zero-copy");
        assert_eq!(t2[1], t1[1], "tail block shared read-only");
        let s = pool.stats();
        assert_eq!(s.prefix_partial_hits, 1);
        assert_eq!(s.lazy_tail_shares, 1);
        assert_eq!(s.lazy_tail_copies, 0);
        assert_eq!(s.cow_copies, 0, "copy deferred to first append");
        assert_eq!(s.prefix_hit_tokens, 6);

        // the shared rows decode to block 1's leading rows straight from
        // the shared sealed block (readers slice by sequence length)
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let (keys, _) = pool.gather_rows(&t2, 0, &mut ks, &mut vs);
        assert!((keys[4][0] - 4.0).abs() < 0.5);

        // first append (position 6 = 2 rows into the tail block)
        // materializes the deferred copy: only the 2 shared rows move,
        // the source keeps its 4 rows and stays sealed
        let row = vec![0.25f32; 16];
        for layer in 0..2 {
            pool.append_row(&mut t2, layer, 6, &row, &row);
        }
        assert_ne!(t2[1], t1[1], "first append must unshare the tail");
        assert_eq!(pool.slots[t2[1] as usize].block.fill(), 3);
        assert_eq!(pool.slots[t2[1] as usize].refcount, 1);
        assert_eq!(pool.slots[t1[1] as usize].block.fill(), 4);
        assert_eq!(pool.slots[t1[1] as usize].refcount, 1);
        let s = pool.stats();
        assert_eq!(s.lazy_tail_copies, 1);
        assert_eq!(s.cow_copies, 1);
        pool.release_seq(&mut t2);
        pool.release_seq(&mut t1);
    }

    #[test]
    fn lazy_tail_share_released_unused_never_copies() {
        // the deferral payoff: a sequence that matches a mid-block tail
        // but is released before appending (failed reservation, abort)
        // pays zero row copies — the eager scheme always copied here
        let mut pool = KvPool::new(cfg(8, 4));
        let tokens: Vec<u32> = (0..9).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);
        let free_before = pool.stats().blocks_free;

        let probe: Vec<u32> = vec![0, 1, 2, 3, 4, 5, 99, 98];
        let mut t2 = Vec::new();
        assert_eq!(pool.match_prefix(&probe, &mut t2), 6);
        pool.release_seq(&mut t2);

        let s = pool.stats();
        assert_eq!(s.lazy_tail_shares, 1);
        assert_eq!(s.lazy_tail_copies, 0, "copy avoided entirely");
        assert_eq!(s.cow_copies, 0);
        assert_eq!(s.blocks_free, free_before, "no block consumed");
        // the sealed tail survives for the next arrival
        assert_eq!(pool.probe_prefix(&probe), 6);
        pool.release_seq(&mut t1);
    }

    #[test]
    fn can_fit_prompt_charges_only_the_unshared_suffix() {
        // 6 blocks of 4: seq A pins 5 (16-token prompt + headroom via
        // reserve), leaving 1 free; a 90%-shared prompt needs only its
        // suffix
        let mut pool = KvPool::new(cfg(6, 4));
        let tokens: Vec<u32> = (0..16).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);
        assert!(pool.reserve(&mut t1, 17)); // headroom block: 5 pinned
        assert_eq!(pool.available(), 1);

        // shares 12 of 15 tokens (3 full blocks) -> charged
        // blocks_for(16) - 3 = 1 block, which fits the single free block
        let mut shared: Vec<u32> = (0..12).collect();
        shared.extend([70, 71, 72]);
        assert!(pool.can_fit_prompt(&shared));
        let mut t2 = Vec::new();
        let matched = pool.match_prefix(&shared, &mut t2);
        assert_eq!(matched, 12);
        assert!(pool.reserve(&mut t2, shared.len() + 1));

        // a fully distinct prompt of the same length cannot fit
        let distinct: Vec<u32> = (100..115).collect();
        assert!(!pool.can_fit_prompt(&distinct));
        pool.release_seq(&mut t2);
        pool.release_seq(&mut t1);
    }

    #[test]
    fn can_fit_prompt_excludes_evictable_hits_from_supply() {
        // all 4 blocks cached after release: a prompt hitting 3 of them
        // must not count those 3 as *both* reusable and evictable
        let mut pool = KvPool::new(cfg(4, 4));
        let tokens: Vec<u32> = (0..16).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);
        pool.release_seq(&mut t1);
        assert_eq!(pool.stats().blocks_cached, 4);

        // 12 shared + 8 distinct = 20 tokens: 3 full hits, charged
        // blocks_for(21) - 3 = 3 fresh blocks, but pinning the hits
        // leaves only 1 evictable block
        let mut prompt: Vec<u32> = (0..12).collect();
        prompt.extend(200..208);
        assert!(!pool.can_fit_prompt(&prompt));

        // trimming the suffix to one block's worth fits
        let mut short: Vec<u32> = (0..12).collect();
        short.extend([200, 201, 202]);
        assert!(pool.can_fit_prompt(&short));
    }

    #[test]
    fn prop_chain_hash_invariant_under_any_split() {
        // hashing a token stream in one shot must equal hashing it in
        // arbitrary chunks — block-aligned and mid-block alike — since
        // the prefix cache seals per block while admission probes whole
        // prompts
        use crate::util::proptest::{check, Config};
        check("chain-hash-split-invariant", Config::default(), |rng, _| {
            let n = 1 + rng.below(96);
            let stream: Vec<u32> = (0..n).map(|_| rng.next_u32() % 512).collect();
            let whole = chain_hash(HASH_SEED, &stream);
            let mut h = HASH_SEED;
            let mut at = 0usize;
            while at < n {
                let step = 1 + rng.below(n - at);
                h = chain_hash(h, &stream[at..at + step]);
                at += step;
            }
            if h != whole {
                return Err(format!("split hash {h:#x} != whole {whole:#x}"));
            }
            // a stream differing in any single token must diverge
            let flip = rng.below(n);
            let mut other = stream.clone();
            other[flip] ^= 1 + rng.next_u32() % 255;
            if chain_hash(HASH_SEED, &other) == whole {
                return Err(format!("flip at {flip} did not change the hash"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_distinct_streams_never_adopt_foreign_tails() {
        // seal a random stream, then probe random relatives: the match
        // must cover exactly the shared prefix (capped one short of the
        // probe, which always forwards its last token) and never serve
        // rows past the divergence point — for block-aligned and
        // mid-block divergences alike
        use crate::util::proptest::{check, Config};
        check("no-foreign-tail-adoption", Config { cases: 48, ..Config::default() },
            |rng, _| {
                let bs = 4usize;
                let mut pool = KvPool::new(cfg(32, bs));
                let n = bs + 1 + rng.below(20);
                let stream: Vec<u32> = (0..n).map(|_| rng.next_u32() % 64).collect();
                let mut t1 = Vec::new();
                fill_seq(&mut pool, &mut t1, &stream);
                pool.seal_full_blocks(&t1, &stream, 0, HASH_SEED);

                // relative: shares `share` tokens then diverges hard
                // (probe values never collide with stream values)
                let share = rng.below(n + 1);
                let mut probe: Vec<u32> = stream[..share].to_vec();
                let tail = 1 + rng.below(8);
                probe.extend((0..tail).map(|_| 1000 + rng.next_u32() % 64));
                // only sealed (full) blocks are servable: the stream's
                // trailing partial block never enters the prefix cache
                let expect = share.min(n / bs * bs);
                let got = pool.probe_prefix(&probe);
                if got != expect {
                    return Err(format!(
                        "probe over {share}-shared prefix matched {got}, \
                         want {expect} (stream {n} tokens)"
                    ));
                }
                // the pinning walk agrees with the read-only probe
                let mut t2 = Vec::new();
                let matched = pool.match_prefix(&probe, &mut t2);
                if matched != expect {
                    return Err(format!("match {matched} != probe {expect}"));
                }
                pool.release_seq(&mut t2);
                pool.release_seq(&mut t1);
                Ok(())
            });
    }

    #[test]
    fn gather_rows_roundtrips_block_table() {
        let mut pool = KvPool::new(cfg(4, 4));
        let tokens: Vec<u32> = (0..6).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let (keys, vals) = pool.gather_rows(&t1, 1, &mut ks, &mut vs);
        assert_eq!(keys.len(), 6);
        assert_eq!(vals.len(), 6);
        for (pos, row) in keys.iter().enumerate() {
            assert_eq!(row.len(), 16);
            // INT4 roundtrip keeps values close to the source row
            let want = pos as f32; // first element of the source row
            assert!((row[0] - want).abs() < 0.5, "pos {pos}: {} vs {want}", row[0]);
        }
        pool.release_seq(&mut t1);
    }
}
