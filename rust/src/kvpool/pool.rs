//! The paged KV-block pool: free-list allocation, refcounted sharing,
//! content-hash prefix cache, and LRU eviction of released blocks.
//!
//! Blocks are sealed into the prefix map only when full, so shared
//! blocks are immutable by construction; copy-on-write in
//! [`KvPool::append_row`] guards the invariant anyway.

use std::collections::HashMap;

use super::block::{BlockId, KvBlock};

/// FNV-1a offset basis: the start of every sequence's chain hash.
pub(crate) const HASH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Extend a chain hash over one block's worth of token ids (FNV-1a).
/// Chaining makes a block's hash depend on its whole prompt prefix, so
/// equal blocks at different prefixes never collide by construction.
pub(crate) fn chain_hash(mut h: u64, tokens: &[u32]) -> u64 {
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Pool geometry + storage format.
#[derive(Clone, Copy, Debug)]
pub struct KvPoolConfig {
    /// Total blocks in the slab.
    pub n_blocks: usize,
    /// Token positions per block.
    pub block_size: usize,
    pub n_layers: usize,
    pub kv_bits: u8,
    pub kv_group: usize,
}

/// Aggregate pool counters surfaced through [`crate::coordinator`]'s
/// metrics and the TCP stats endpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub blocks_total: usize,
    /// On the free list (never used or fully reclaimed).
    pub blocks_free: usize,
    /// Refcount 0 but retained in the prefix cache (evictable).
    pub blocks_cached: usize,
    /// Pinned by at least one live sequence.
    pub blocks_active: usize,
    pub bytes_used: usize,
    /// match_prefix calls / tokens probed / tokens + blocks served from
    /// the prefix cache (cumulative).
    pub prefix_queries: u64,
    pub prefix_query_tokens: u64,
    pub prefix_hit_tokens: u64,
    pub prefix_hit_blocks: u64,
    pub evictions: u64,
    pub cow_copies: u64,
}

struct Slot {
    block: KvBlock,
    refcount: u32,
    /// Chain hash once sealed + registered in the prefix map.
    hash: Option<u64>,
    /// Token ids this sealed block covers (verifies map hits).
    tokens: Vec<u32>,
    /// LRU stamp, updated when the refcount drops to 0.
    last_use: u64,
}

/// The paged KV pool.
pub struct KvPool {
    cfg: KvPoolConfig,
    slots: Vec<Slot>,
    free: Vec<BlockId>,
    /// chain hash of a sealed full block -> its slot.
    prefix_map: HashMap<u64, BlockId>,
    tick: u64,
    prefix_queries: u64,
    prefix_query_tokens: u64,
    prefix_hit_tokens: u64,
    prefix_hit_blocks: u64,
    evictions: u64,
    cow_copies: u64,
}

impl KvPool {
    pub fn new(cfg: KvPoolConfig) -> KvPool {
        assert!(cfg.n_blocks > 0 && cfg.block_size > 0 && cfg.n_layers > 0);
        let slots = (0..cfg.n_blocks)
            .map(|_| Slot {
                block: KvBlock::new(cfg.n_layers, cfg.kv_bits, cfg.kv_group),
                refcount: 0,
                hash: None,
                tokens: Vec::new(),
                last_use: 0,
            })
            .collect();
        // pop order: block 0 first
        let free = (0..cfg.n_blocks as BlockId).rev().collect();
        KvPool {
            cfg,
            slots,
            free,
            prefix_map: HashMap::new(),
            tick: 0,
            prefix_queries: 0,
            prefix_query_tokens: 0,
            prefix_hit_tokens: 0,
            prefix_hit_blocks: 0,
            evictions: 0,
            cow_copies: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    pub fn blocks_total(&self) -> usize {
        self.cfg.n_blocks
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_size)
    }

    /// Blocks obtainable right now: the free list plus evictable cached
    /// blocks (refcount 0, retained only for prefix reuse).
    pub fn available(&self) -> usize {
        self.free.len() + self.cached_count()
    }

    fn cached_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.refcount == 0 && s.hash.is_some())
            .count()
    }

    /// Grab a block: free list first, then evict the least-recently-used
    /// cached block.  Returned slot has refcount 1 and an empty block.
    fn alloc(&mut self) -> Option<BlockId> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => self.evict_lru()?,
        };
        let slot = &mut self.slots[id as usize];
        debug_assert!(slot.refcount == 0 && slot.hash.is_none());
        slot.refcount = 1;
        Some(id)
    }

    fn evict_lru(&mut self) -> Option<BlockId> {
        let id = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.refcount == 0 && s.hash.is_some())
            .min_by_key(|(_, s)| s.last_use)
            .map(|(i, _)| i as BlockId)?;
        let slot = &mut self.slots[id as usize];
        let h = slot.hash.take().expect("cached block has a hash");
        self.prefix_map.remove(&h);
        slot.tokens.clear();
        slot.block.reset(self.cfg.kv_bits, self.cfg.kv_group);
        self.evictions += 1;
        Some(id)
    }

    /// Ensure `table` covers `upto_tokens` positions, allocating tail
    /// blocks as needed.  `false` = pool exhausted (the scheduler must
    /// preempt); partially-reserved blocks stay in the table and are
    /// reclaimed by [`release_seq`](KvPool::release_seq).
    pub fn reserve(&mut self, table: &mut Vec<BlockId>, upto_tokens: usize) -> bool {
        let need = self.blocks_for(upto_tokens);
        while table.len() < need {
            match self.alloc() {
                Some(id) => table.push(id),
                None => return false,
            }
        }
        true
    }

    /// The one prefix-cache walk both entry points share: chain-hash the
    /// prompt's full blocks through the map, verifying each hit's tokens
    /// (hash-collision guard) and always leaving at least one prompt
    /// token for the forward pass.  Returns (matched tokens, hit blocks).
    fn walk_prefix(&self, tokens: &[u32]) -> (usize, Vec<BlockId>) {
        let bs = self.cfg.block_size;
        let mut h = HASH_SEED;
        let mut matched = 0usize;
        let mut hits = Vec::new();
        while matched + bs < tokens.len() {
            let seg = &tokens[matched..matched + bs];
            h = chain_hash(h, seg);
            let Some(id) = self.prefix_map.get(&h).copied() else { break };
            if self.slots[id as usize].tokens.as_slice() != seg {
                break; // hash collision: do not serve foreign rows
            }
            hits.push(id);
            matched += bs;
        }
        (matched, hits)
    }

    /// Walk the prompt's full blocks through the prefix map, pinning every
    /// hit into `table`.  Returns the number of matched tokens; at least
    /// one prompt token is always left for the forward pass.
    pub fn match_prefix(&mut self, tokens: &[u32], table: &mut Vec<BlockId>) -> usize {
        self.prefix_queries += 1;
        self.prefix_query_tokens += tokens.len() as u64;
        let (matched, hits) = self.walk_prefix(tokens);
        for &id in &hits {
            self.slots[id as usize].refcount += 1;
            table.push(id);
        }
        self.prefix_hit_blocks += hits.len() as u64;
        self.prefix_hit_tokens += matched as u64;
        matched
    }

    /// Read-only prefix probe (admission gating): matched token count,
    /// with no refcounting and no counter updates.
    pub fn probe_prefix(&self, tokens: &[u32]) -> usize {
        self.walk_prefix(tokens).0
    }

    /// Append one K/V row pair at absolute position `pos` of the sequence
    /// owning `table`.  Allocates the tail block on a boundary (callers
    /// gate capacity via [`reserve`](KvPool::reserve) / admission) and
    /// copies-on-write if the target block is shared.
    pub fn append_row(
        &mut self,
        table: &mut Vec<BlockId>,
        layer: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let bs = self.cfg.block_size;
        let bi = pos / bs;
        debug_assert!(bi <= table.len(), "non-sequential KV append");
        if bi == table.len() {
            let id = self
                .alloc()
                .expect("kvpool exhausted: admission/reserve must gate capacity");
            table.push(id);
        }
        let id = table[bi];
        if self.slots[id as usize].refcount > 1 {
            // shared block: copy before mutating
            let copy = self
                .alloc()
                .expect("kvpool exhausted during copy-on-write");
            let data = self.slots[id as usize].block.clone_data();
            let dst = &mut self.slots[copy as usize];
            dst.block = data;
            self.release_block(id);
            table[bi] = copy;
            self.cow_copies += 1;
        }
        self.slots[table[bi] as usize].block.push(layer, k, v);
    }

    /// Dequantize every cached row of `table` for `layer` into the
    /// scratch buffers, returning (keys, values) views in position order.
    pub fn gather_rows<'a>(
        &self,
        table: &[BlockId],
        layer: usize,
        k_scratch: &'a mut Vec<Vec<f32>>,
        v_scratch: &'a mut Vec<Vec<f32>>,
    ) -> (&'a [Vec<f32>], &'a [Vec<f32>]) {
        let mut n = 0usize;
        for &id in table {
            let (ks, vs) = &self.slots[id as usize].block.layers[layer];
            let rows = ks.len();
            while k_scratch.len() < n + rows {
                k_scratch.push(Vec::new());
            }
            while v_scratch.len() < n + rows {
                v_scratch.push(Vec::new());
            }
            for r in 0..rows {
                ks.row_into(r, &mut k_scratch[n + r]);
                vs.row_into(r, &mut v_scratch[n + r]);
            }
            n += rows;
        }
        (&k_scratch[..n], &v_scratch[..n])
    }

    /// Seal every full block of `tokens` into the prefix map, resuming
    /// from `(sealed, chain)`; returns the updated pair.  Already-sealed
    /// (matched) blocks just advance the chain.
    pub fn seal_full_blocks(
        &mut self,
        table: &[BlockId],
        tokens: &[u32],
        mut sealed: usize,
        mut chain: u64,
    ) -> (usize, u64) {
        let bs = self.cfg.block_size;
        while (sealed + 1) * bs <= tokens.len() {
            let seg = &tokens[sealed * bs..(sealed + 1) * bs];
            chain = chain_hash(chain, seg);
            let id = table[sealed];
            if self.slots[id as usize].block.fill() < bs {
                break; // not yet full for every position
            }
            self.register_sealed(id, chain, seg);
            sealed += 1;
        }
        (sealed, chain)
    }

    fn register_sealed(&mut self, id: BlockId, hash: u64, tokens: &[u32]) {
        if self.prefix_map.contains_key(&hash) {
            return; // an equivalent block is already registered
        }
        let slot = &mut self.slots[id as usize];
        slot.hash = Some(hash);
        slot.tokens = tokens.to_vec();
        self.prefix_map.insert(hash, id);
    }

    /// Release every block of a retiring / preempted sequence.  Sealed
    /// blocks stay cached for prefix reuse (LRU-stamped leaf-first, so
    /// eviction trims chains from the tail); unsealed blocks are reset
    /// and freed.
    pub fn release_seq(&mut self, table: &mut Vec<BlockId>) {
        for id in table.drain(..).rev() {
            self.release_block(id);
        }
    }

    fn release_block(&mut self, id: BlockId) {
        let slot = &mut self.slots[id as usize];
        debug_assert!(slot.refcount > 0, "double release of KV block {id}");
        slot.refcount -= 1;
        if slot.refcount > 0 {
            return;
        }
        if slot.hash.is_some() {
            self.tick += 1;
            self.slots[id as usize].last_use = self.tick;
        } else {
            slot.block.reset(self.cfg.kv_bits, self.cfg.kv_group);
            self.free.push(id);
        }
    }

    /// Bytes held by the blocks of one sequence (scaled down for shared
    /// blocks would be fancier; this reports the plain sum).
    pub fn table_bytes(&self, table: &[BlockId]) -> usize {
        table.iter().map(|&id| self.slots[id as usize].block.bytes).sum()
    }

    pub fn stats(&self) -> PoolStats {
        let cached = self.cached_count();
        PoolStats {
            blocks_total: self.cfg.n_blocks,
            blocks_free: self.free.len(),
            blocks_cached: cached,
            blocks_active: self.cfg.n_blocks - self.free.len() - cached,
            bytes_used: self.slots.iter().map(|s| s.block.bytes).sum(),
            prefix_queries: self.prefix_queries,
            prefix_query_tokens: self.prefix_query_tokens,
            prefix_hit_tokens: self.prefix_hit_tokens,
            prefix_hit_blocks: self.prefix_hit_blocks,
            evictions: self.evictions,
            cow_copies: self.cow_copies,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n_blocks: usize, block_size: usize) -> KvPoolConfig {
        KvPoolConfig { n_blocks, block_size, n_layers: 2, kv_bits: 4, kv_group: 8 }
    }

    fn fill_seq(pool: &mut KvPool, table: &mut Vec<BlockId>, tokens: &[u32]) {
        // push one 16-wide K/V row per token per layer, like a forward
        for layer in 0..2 {
            for (pos, &t) in tokens.iter().enumerate() {
                let row: Vec<f32> = (0..16).map(|j| (t as f32) + j as f32 * 0.1).collect();
                pool.append_row(table, layer, pos, &row, &row);
            }
        }
    }

    #[test]
    fn alloc_exhaustion_and_release() {
        let mut pool = KvPool::new(cfg(3, 4));
        let mut t1 = Vec::new();
        assert!(pool.reserve(&mut t1, 12)); // 3 blocks
        assert_eq!(t1.len(), 3);
        let mut t2 = Vec::new();
        assert!(!pool.reserve(&mut t2, 4)); // exhausted
        assert_eq!(pool.available(), 0);
        pool.release_seq(&mut t1);
        assert!(t1.is_empty());
        assert_eq!(pool.available(), 3); // unsealed blocks go straight to free
        assert!(pool.reserve(&mut t2, 4));
        pool.release_seq(&mut t2);
    }

    #[test]
    fn prefix_match_pins_and_verifies_tokens() {
        let mut pool = KvPool::new(cfg(8, 4));
        let tokens: Vec<u32> = (0..9).collect(); // 2 full blocks + 1 tail
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        let (sealed, chain) = pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);
        assert_eq!(sealed, 2);
        assert_ne!(chain, HASH_SEED);

        // a second sequence with the same prompt reuses both full blocks
        let mut t2 = Vec::new();
        let matched = pool.match_prefix(&tokens, &mut t2);
        assert_eq!(matched, 8);
        assert_eq!(t2.len(), 2);
        assert_eq!(t2[0], t1[0]);
        let s = pool.stats();
        assert_eq!(s.prefix_hit_blocks, 2);
        assert_eq!(s.prefix_hit_tokens, 8);

        // a different prompt matches nothing
        let other: Vec<u32> = (100..109).collect();
        let mut t3 = Vec::new();
        assert_eq!(pool.match_prefix(&other, &mut t3), 0);
        assert!(t3.is_empty());

        // an exactly-block-aligned prompt leaves the last block unmatched
        // so prefill always has at least one token to forward
        let aligned: Vec<u32> = (0..8).collect();
        let mut t4 = Vec::new();
        assert_eq!(pool.match_prefix(&aligned, &mut t4), 4);
        pool.release_seq(&mut t2);
        pool.release_seq(&mut t4);
        pool.release_seq(&mut t1);
    }

    #[test]
    fn sealed_blocks_cache_then_evict_lru_leaf_first() {
        let mut pool = KvPool::new(cfg(3, 4));
        let tokens: Vec<u32> = (0..9).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        pool.seal_full_blocks(&t1, &tokens, 0, HASH_SEED);
        pool.release_seq(&mut t1);
        let s = pool.stats();
        assert_eq!(s.blocks_cached, 2); // two sealed blocks retained
        assert_eq!(s.blocks_free, 1); // the unsealed tail was freed
        assert_eq!(pool.available(), 3);

        // exhaust: allocations evict the cached chain leaf-first, so the
        // root block survives longest and still serves a 4-token match
        let mut t2 = Vec::new();
        assert!(pool.reserve(&mut t2, 8)); // free 1 + evict 1
        assert_eq!(pool.stats().evictions, 1);
        let mut t3 = Vec::new();
        assert_eq!(pool.match_prefix(&tokens, &mut t3), 4, "root block survives");
        pool.release_seq(&mut t3);
        pool.release_seq(&mut t2);
    }

    #[test]
    fn copy_on_write_unshares_a_block() {
        // a partially-filled block shared by two tables: appending through
        // one table must copy, leaving the other table's rows untouched
        let mut pool = KvPool::new(cfg(4, 4));
        let row = vec![0.5f32; 16];
        let mut ta = Vec::new();
        for layer in 0..2 {
            for pos in 0..3 {
                pool.append_row(&mut ta, layer, pos, &row, &row);
            }
        }
        let mut tb = vec![ta[0]];
        pool.slots[ta[0] as usize].refcount += 1;
        pool.append_row(&mut tb, 0, 3, &row, &row);
        assert_ne!(tb[0], ta[0], "append into a shared block must copy");
        assert_eq!(pool.stats().cow_copies, 1);
        assert_eq!(pool.slots[ta[0] as usize].refcount, 1);
        assert_eq!(pool.slots[ta[0] as usize].block.fill(), 3);
        assert_eq!(pool.slots[tb[0] as usize].block.fill(), 4);
        pool.release_seq(&mut tb);
        pool.release_seq(&mut ta);
    }

    #[test]
    fn gather_rows_roundtrips_block_table() {
        let mut pool = KvPool::new(cfg(4, 4));
        let tokens: Vec<u32> = (0..6).collect();
        let mut t1 = Vec::new();
        fill_seq(&mut pool, &mut t1, &tokens);
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let (keys, vals) = pool.gather_rows(&t1, 1, &mut ks, &mut vs);
        assert_eq!(keys.len(), 6);
        assert_eq!(vals.len(), 6);
        for (pos, row) in keys.iter().enumerate() {
            assert_eq!(row.len(), 16);
            // INT4 roundtrip keeps values close to the source row
            let want = pos as f32; // first element of the source row
            assert!((row[0] - want).abs() < 0.5, "pos {pos}: {} vs {want}", row[0]);
        }
        pool.release_seq(&mut t1);
    }
}
