//! Fixed-size KV blocks: the allocation unit of the paged pool.
//!
//! One block holds `block_size` consecutive token positions for *every*
//! layer's K and V rows, in the same storage format as the flat cache
//! ([`KvStore`]: nibble-packed INT4 with per-group scales, or fp32) —
//! so the paged path dequantizes to exactly the same values as the flat
//! path and stays bit-identical.

use crate::model::engine::KvStore;

/// Index into the pool's slot array.
pub type BlockId = u32;

/// One fixed-size KV block across all layers.
pub struct KvBlock {
    /// (K rows, V rows) per layer; each store holds up to `block_size`
    /// rows, appended in position order.
    pub layers: Vec<(KvStore, KvStore)>,
    /// Running byte counter (payload + scales), updated on push/reset.
    pub bytes: usize,
}

impl KvBlock {
    pub fn new(n_layers: usize, kv_bits: u8, group: usize) -> KvBlock {
        KvBlock {
            layers: (0..n_layers)
                .map(|_| (KvStore::new(kv_bits, group), KvStore::new(kv_bits, group)))
                .collect(),
            bytes: 0,
        }
    }

    /// Positions fully or partially filled: layer 0 is pushed first, so
    /// its K-row count is the block's fill level.
    pub fn fill(&self) -> usize {
        self.layers[0].0.len()
    }

    /// Append one K/V row pair for `layer`.
    pub fn push(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let (ks, vs) = &mut self.layers[layer];
        self.bytes += ks.push(k) + vs.push(v);
    }

    /// Drop all rows, re-initializing the stores (block returns to the
    /// free list).
    pub fn reset(&mut self, kv_bits: u8, group: usize) {
        for l in self.layers.iter_mut() {
            l.0 = KvStore::new(kv_bits, group);
            l.1 = KvStore::new(kv_bits, group);
        }
        self.bytes = 0;
    }

    /// Deep copy of the row data (copy-on-write support).
    pub fn clone_data(&self) -> KvBlock {
        KvBlock {
            layers: self.layers.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            bytes: self.bytes,
        }
    }

    /// Deep copy of the first `rows` positions of every layer — the
    /// partial-block tail-sharing flavour of copy-on-write (rows are
    /// independently quantized, so a row-boundary cut is exact).
    pub fn clone_prefix(&self, rows: usize) -> KvBlock {
        let mut bytes = 0usize;
        let layers = self
            .layers
            .iter()
            .map(|(k, v)| {
                let (kt, vt) = (k.truncated(rows), v.truncated(rows));
                bytes += kt.bytes() + vt.bytes();
                (kt, vt)
            })
            .collect();
        KvBlock { layers, bytes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_tracks_layer0_and_bytes_accumulate() {
        let mut b = KvBlock::new(2, 4, 8);
        assert_eq!(b.fill(), 0);
        let row = vec![1.0f32; 16];
        b.push(0, &row, &row);
        b.push(0, &row, &row);
        b.push(1, &row, &row);
        assert_eq!(b.fill(), 2);
        assert!(b.bytes > 0);
        let before = b.bytes;
        let copy = b.clone_data();
        assert_eq!(copy.bytes, before);
        b.reset(4, 8);
        assert_eq!(b.fill(), 0);
        assert_eq!(b.bytes, 0);
        assert_eq!(copy.fill(), 2);
    }

    #[test]
    fn clone_prefix_cuts_at_row_boundary() {
        let mut b = KvBlock::new(2, 4, 8);
        for pos in 0..4 {
            let row = vec![pos as f32; 16];
            b.push(0, &row, &row);
            b.push(1, &row, &row);
        }
        let head = b.clone_prefix(3);
        assert_eq!(head.fill(), 3);
        assert!(head.bytes > 0 && head.bytes < b.bytes);
        // the copied rows decode to the source's leading rows
        let mut out = Vec::new();
        head.layers[1].0.row_into(2, &mut out);
        assert!((out[0] - 2.0).abs() < 0.5);
        // clamped when asked for more rows than stored
        assert_eq!(b.clone_prefix(9).fill(), 4);
    }
}
