//! Paged INT4 KV-cache pool (vLLM-style) for the serving coordinator.
//!
//! The paper's sub-channel INT4 KV quantization (§4.1) makes every cached
//! position a fixed-size nibble-packed record, which is exactly what a
//! paged allocator wants.  This module provides:
//!
//! * [`block::KvBlock`] — a fixed-size slab unit: `block_size` token
//!   positions × every layer's K/V rows, in the same [`KvStore`] format
//!   as the flat cache (so paged attention is bit-identical);
//! * [`pool::KvPool`] — free-list allocation over a bounded slab,
//!   refcounted block sharing, a chain-hashed prefix cache with verified
//!   hits, copy-on-write (including *lazy* partial-block tail adoption
//!   for prefixes that end mid-block: the sealed tail is shared
//!   read-only at match time and its rows are copied only on the first
//!   append), LRU eviction of released sealed blocks, and exact
//!   prefix-aware admission accounting
//!   ([`pool::KvPool::can_fit_prompt`]);
//! * [`engine::PagedEngine`] — the serving backend: prefill with prompt
//!   prefix reuse + batched decode over block tables, implementing the
//!   coordinator's `ServeEngine` trait (see
//!   `crate::coordinator::engine_iface`), which charges admission only
//!   for a prompt's unshared suffix and preempts to the queue when the
//!   pool runs dry.
//!
//! The AOT PJRT path ([`crate::runtime::PagedPjrtEngine`]) runs over the
//! same pool, so every backend shares one allocator, prefix cache, and
//! admission gate.
//!
//! [`KvStore`]: crate::model::engine::KvStore

pub mod block;
pub mod engine;
pub mod pool;

pub use block::{BlockId, KvBlock};
pub use engine::{PagedEngine, PagedSeq};
pub use pool::{KvPool, KvPoolConfig, PoolStats};
