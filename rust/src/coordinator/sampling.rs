//! Per-request sampling: the full parameter suite (top-k, top-p,
//! repetition / presence / frequency penalties, stop sequences and stop
//! token ids, logit bias, per-request seeds) applied in one vectorized
//! pass over the batch's logit rows.
//!
//! The scheduler owns one [`SamplerState`] per in-flight request.  Each
//! decode step it builds a [`Lane`] per active row and calls
//! [`sample_lanes`], which fans the rows out over
//! [`crate::util::threadpool::parallel_rows`] — sampling is pure
//! per-row CPU work (penalty application + partial top-k selection +
//! softmax), so it threads the same way the GEMM tile driver does.
//!
//! Determinism contract: a state carries its own [`Pcg`] stream seeded
//! from the request (`seed` param, falling back to the request id), so
//! the sampled token stream for a request is a pure function of
//! (logits, params, seed) — independent of batch composition, admission
//! order, or preemption.  The scheduler preserves the state across
//! preemption, and both engines produce bit-identical logits for a
//! given token history, so a preempted-and-resumed request continues
//! the exact stream it would have produced uninterrupted.

use std::collections::HashMap;

use crate::model::sampler::Sampling;
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::threadpool;

use super::request::FinishReason;

/// A bias at or below this value bans the token outright (−inf logit).
pub const BAN_BIAS: f32 = -1e9;

/// Most stop sequences accepted per request (and max tokens per one).
const MAX_STOP_SEQS: usize = 8;
const MAX_STOP_SEQ_LEN: usize = 64;

/// Full per-request sampling parameter suite.
///
/// Defaults are the identity: greedy argmax with every modifier off.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// `<= 0` means greedy argmax (after bias/penalties).
    pub temperature: f32,
    /// Keep only the `k` highest logits; `0` disables.
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability-descending prefix
    /// with mass `>= top_p`; `1.0` disables.
    pub top_p: f32,
    /// HF-style repetition penalty over prompt + generated tokens:
    /// positive logits are divided by it, negative multiplied.  `1.0`
    /// disables; values `> 1` discourage repeats.
    pub repetition_penalty: f32,
    /// Flat subtraction from every token generated at least once.
    pub presence_penalty: f32,
    /// Subtraction proportional to a token's generated-count.
    pub frequency_penalty: f32,
    /// Additive per-token logit adjustments; a bias `<= BAN_BIAS` bans
    /// the token outright.
    pub logit_bias: Vec<(u32, f32)>,
    /// Finish with [`FinishReason::StopToken`] when one is produced.
    pub stop_token_ids: Vec<u32>,
    /// Finish with [`FinishReason::StopSequence`] when the generated
    /// token tail matches one (spans token boundaries by construction).
    pub stop_sequences: Vec<Vec<u32>>,
    /// RNG seed; `None` derives one from the request id.
    pub seed: Option<u64>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            presence_penalty: 0.0,
            frequency_penalty: 0.0,
            logit_bias: Vec::new(),
            stop_token_ids: Vec::new(),
            stop_sequences: Vec::new(),
            seed: None,
        }
    }
}

/// The legacy three-mode enum maps onto the full suite.
impl From<Sampling> for SamplingParams {
    fn from(s: Sampling) -> SamplingParams {
        match s {
            Sampling::Greedy => SamplingParams::default(),
            Sampling::Temperature(t) => {
                SamplingParams { temperature: t, ..Default::default() }
            }
            Sampling::TopK { k, temperature } => SamplingParams {
                temperature,
                top_k: k,
                ..Default::default()
            },
        }
    }
}

/// Read an optional numeric field; present-but-not-a-number is an error
/// (never a silent fallback).
pub(crate) fn num_field(req: &Json, key: &str) -> Result<Option<f64>, String> {
    match req.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Num(x)) => Ok(Some(*x)),
        Some(_) => Err(format!("'{key}' must be a number")),
    }
}

/// Read an optional non-negative integer field (rejects fractions).
pub(crate) fn usize_field(req: &Json, key: &str) -> Result<Option<usize>, String> {
    match num_field(req, key)? {
        None => Ok(None),
        Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => {
            Ok(Some(x as usize))
        }
        Some(_) => Err(format!("'{key}' must be a non-negative integer")),
    }
}

/// Read an optional (possibly negative) integer field.
pub(crate) fn int_field(req: &Json, key: &str) -> Result<Option<i64>, String> {
    match num_field(req, key)? {
        None => Ok(None),
        Some(x) if x.is_finite() && x.fract() == 0.0 => Ok(Some(x as i64)),
        Some(_) => Err(format!("'{key}' must be an integer")),
    }
}

impl SamplingParams {
    /// Parse the sampling fields of a protocol request.  Strict: a field
    /// that is present with the wrong type or an out-of-range value is a
    /// protocol error, not a silent greedy fallback.  `stop` (string
    /// matching) is layered on by the server, which owns the tokenizer.
    pub fn from_json(req: &Json) -> Result<SamplingParams, String> {
        let mut p = SamplingParams::default();
        if let Some(t) = num_field(req, "temperature")? {
            p.temperature = t as f32;
        }
        if let Some(k) = usize_field(req, "top_k")? {
            p.top_k = k;
        }
        if let Some(tp) = num_field(req, "top_p")? {
            p.top_p = tp as f32;
        }
        if let Some(r) = num_field(req, "repetition_penalty")? {
            p.repetition_penalty = r as f32;
        }
        if let Some(x) = num_field(req, "presence_penalty")? {
            p.presence_penalty = x as f32;
        }
        if let Some(x) = num_field(req, "frequency_penalty")? {
            p.frequency_penalty = x as f32;
        }
        if let Some(s) = usize_field(req, "seed")? {
            p.seed = Some(s as u64);
        }
        match req.get("logit_bias") {
            None | Some(Json::Null) => {}
            // {"65": -5.0, "66": 1e9} — keys are token-id strings
            Some(Json::Obj(kvs)) => {
                for (k, v) in kvs {
                    let tok: u32 = k
                        .parse()
                        .map_err(|_| format!("logit_bias key '{k}' is not a token id"))?;
                    let b = v
                        .as_f64()
                        .ok_or_else(|| format!("logit_bias['{k}'] must be a number"))?;
                    p.logit_bias.push((tok, b as f32));
                }
            }
            Some(_) => {
                return Err("'logit_bias' must be an object of token-id: bias".into())
            }
        }
        match req.get("stop_token_ids") {
            None | Some(Json::Null) => {}
            Some(Json::Arr(xs)) => {
                for x in xs {
                    match x.as_usize() {
                        Some(t) => p.stop_token_ids.push(t as u32),
                        None => {
                            return Err(
                                "'stop_token_ids' entries must be token ids".into()
                            )
                        }
                    }
                }
            }
            Some(_) => return Err("'stop_token_ids' must be an array".into()),
        }
        Ok(p)
    }

    /// Range-check every knob; called at submission so a bad request is
    /// rejected before it ever reaches the scheduler.
    pub fn validate(&self) -> Result<(), String> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(format!("temperature {} out of range", self.temperature));
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 || self.top_p > 1.0 {
            return Err(format!("top_p {} must be in (0, 1]", self.top_p));
        }
        if !self.repetition_penalty.is_finite() || self.repetition_penalty <= 0.0 {
            return Err(format!(
                "repetition_penalty {} must be positive",
                self.repetition_penalty
            ));
        }
        for (name, x) in [
            ("presence_penalty", self.presence_penalty),
            ("frequency_penalty", self.frequency_penalty),
        ] {
            if !x.is_finite() || x.abs() > 1e4 {
                return Err(format!("{name} {x} out of range"));
            }
        }
        for &(_, b) in &self.logit_bias {
            if b.is_nan() {
                return Err("logit_bias must not be NaN".into());
            }
        }
        if self.stop_sequences.len() > MAX_STOP_SEQS {
            return Err(format!("at most {MAX_STOP_SEQS} stop sequences"));
        }
        for s in &self.stop_sequences {
            if s.is_empty() || s.len() > MAX_STOP_SEQ_LEN {
                return Err(format!(
                    "stop sequences must be 1..={MAX_STOP_SEQ_LEN} tokens"
                ));
            }
        }
        Ok(())
    }
}

/// First index of the maximum finite value (`0` when everything is
/// `-inf`/NaN — callers ban at most V−1 tokens in practice).
fn argmax_finite(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if !x.is_nan() && x > best_v {
            best = i;
            best_v = x;
        }
    }
    best
}

/// Per-request sampler: params + private RNG stream + the token history
/// the penalties and stop matching need.  Cheap to keep across
/// preemption (a few hash maps), which is what makes resumed requests
/// continue their exact token stream.
#[derive(Clone, Debug)]
pub struct SamplerState {
    params: SamplingParams,
    rng: Pcg,
    /// Occurrences in prompt + generated (repetition penalty domain).
    seen: HashMap<u32, u32>,
    /// Occurrences in generated only (presence/frequency domain).
    gen_counts: HashMap<u32, u32>,
    /// Trailing generated tokens, as long as the longest stop sequence.
    tail: Vec<u32>,
    tail_cap: usize,
    stop_hit: Option<FinishReason>,
}

impl SamplerState {
    /// `fallback_seed` (the request id) keeps unseeded requests
    /// deterministic per-request yet decorrelated across a batch.
    pub fn new(params: SamplingParams, fallback_seed: u64, prompt: &[u32]) -> Self {
        let seed = params.seed.unwrap_or(0x5eed_0000_0000 ^ fallback_seed);
        let tail_cap =
            params.stop_sequences.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut seen = HashMap::new();
        for &t in prompt {
            *seen.entry(t).or_insert(0) += 1;
        }
        SamplerState {
            params,
            rng: Pcg::new(seed),
            seen,
            gen_counts: HashMap::new(),
            tail: Vec::with_capacity(tail_cap),
            tail_cap,
            stop_hit: None,
        }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Set when a recorded token completed a stop id / stop sequence.
    pub fn stop_hit(&self) -> Option<FinishReason> {
        self.stop_hit
    }

    /// Logits after NaN scrubbing, bias, and the three penalties — the
    /// distribution every downstream step (and the property tests) work
    /// from.  NaN logits are treated as banned, never sampled.
    pub fn adjusted_logits(&self, logits: &[f32]) -> Vec<f32> {
        let p = &self.params;
        let mut adj: Vec<f32> = logits
            .iter()
            .map(|&l| if l.is_nan() { f32::NEG_INFINITY } else { l })
            .collect();
        for &(tok, bias) in &p.logit_bias {
            if let Some(x) = adj.get_mut(tok as usize) {
                *x = if bias <= BAN_BIAS { f32::NEG_INFINITY } else { *x + bias };
            }
        }
        if p.repetition_penalty != 1.0 {
            for &tok in self.seen.keys() {
                if let Some(x) = adj.get_mut(tok as usize) {
                    if x.is_finite() {
                        *x = if *x > 0.0 {
                            *x / p.repetition_penalty
                        } else {
                            *x * p.repetition_penalty
                        };
                    }
                }
            }
        }
        if p.presence_penalty != 0.0 || p.frequency_penalty != 0.0 {
            for (&tok, &n) in &self.gen_counts {
                if let Some(x) = adj.get_mut(tok as usize) {
                    if x.is_finite() {
                        *x -= p.presence_penalty + p.frequency_penalty * n as f32;
                    }
                }
            }
        }
        adj
    }

    /// The final categorical distribution as `(token, probability)`
    /// pairs.  Greedy collapses to a single pair; when nucleus
    /// truncation is active the pairs come back probability-descending.
    /// Probabilities are renormalized to sum to 1 (up to rounding).
    pub fn distribution(&self, logits: &[f32]) -> Vec<(u32, f32)> {
        let adj = self.adjusted_logits(logits);
        let p = &self.params;
        if p.temperature <= 0.0 {
            return vec![(argmax_finite(&adj) as u32, 1.0)];
        }
        let mut idx: Vec<usize> =
            (0..adj.len()).filter(|&i| adj[i] > f32::NEG_INFINITY).collect();
        if idx.is_empty() {
            // every token banned: degenerate, pick token 0 by convention
            return vec![(0, 1.0)];
        }
        // partial selection, not a full sort: O(V) instead of O(V log V)
        let k = if p.top_k == 0 { idx.len() } else { p.top_k.min(idx.len()) };
        if k < idx.len() {
            idx.select_nth_unstable_by(k - 1, |&a, &b| adj[b].total_cmp(&adj[a]));
            idx.truncate(k);
        }
        let mut probs: Vec<f32> =
            idx.iter().map(|&i| adj[i] / p.temperature).collect();
        crate::linalg::softmax_inplace(&mut probs);
        let mut cand: Vec<(u32, f32)> =
            idx.iter().zip(&probs).map(|(&i, &pr)| (i as u32, pr)).collect();
        if p.top_p < 1.0 {
            cand.sort_by(|a, b| b.1.total_cmp(&a.1));
            let mut cum = 0.0f32;
            let mut keep = cand.len();
            for (i, &(_, pr)) in cand.iter().enumerate() {
                cum += pr;
                if cum >= p.top_p {
                    keep = i + 1;
                    break;
                }
            }
            cand.truncate(keep);
            let total: f32 = cand.iter().map(|c| c.1).sum();
            if total > 0.0 {
                for c in cand.iter_mut() {
                    c.1 /= total;
                }
            }
        }
        cand
    }

    /// Sample one token and record it (penalty counts + stop matching).
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        let cand = self.distribution(logits);
        let tok = pick(&cand, &mut self.rng);
        self.record(tok);
        tok
    }

    fn record(&mut self, tok: u32) {
        *self.seen.entry(tok).or_insert(0) += 1;
        *self.gen_counts.entry(tok).or_insert(0) += 1;
        if self.stop_hit.is_some() {
            return;
        }
        if self.params.stop_token_ids.contains(&tok) {
            self.stop_hit = Some(FinishReason::StopToken);
            return;
        }
        if self.tail_cap > 0 {
            self.tail.push(tok);
            if self.tail.len() > self.tail_cap {
                let excess = self.tail.len() - self.tail_cap;
                self.tail.drain(..excess);
            }
            if self.params.stop_sequences.iter().any(|s| self.tail.ends_with(s)) {
                self.stop_hit = Some(FinishReason::StopSequence);
            }
        }
    }
}

/// Weighted draw robust to probability mass summing below 1.0 (the draw
/// is scaled by the actual mass; a degenerate all-zero mass falls back
/// to the most probable candidate rather than silently picking the
/// last).
fn pick(cand: &[(u32, f32)], rng: &mut Pcg) -> u32 {
    let total: f32 = cand.iter().map(|c| c.1).sum();
    if !(total > 0.0) || !total.is_finite() {
        return cand
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map(|c| c.0)
            .unwrap_or(0);
    }
    let r = rng.uniform() * total;
    let mut acc = 0.0f32;
    for &(tok, pr) in cand {
        acc += pr;
        if r < acc {
            return tok;
        }
    }
    cand.last().map(|c| c.0).unwrap_or(0)
}

/// One batch row for [`sample_lanes`]: a request's sampler + its logit
/// row, filled with the sampled token.
pub struct Lane<'a> {
    state: &'a mut SamplerState,
    logits: &'a [f32],
    out: u32,
}

impl<'a> Lane<'a> {
    pub fn new(state: &'a mut SamplerState, logits: &'a [f32]) -> Lane<'a> {
        Lane { state, logits, out: 0 }
    }

    /// The sampled token (valid after [`sample_lanes`]).
    pub fn token(&self) -> u32 {
        self.out
    }
}

/// Sample every lane in one vectorized pass, threaded across the batch
/// via the crate's scoped pool.  Each lane's RNG stream is private, so
/// the result is identical to sampling the lanes serially — the
/// parallelism is free of ordering effects by construction.
pub fn sample_lanes(lanes: &mut [Lane<'_>]) {
    let _phase = crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::Sampling);
    let threads = threadpool::default_threads().min(lanes.len().max(1));
    threadpool::parallel_rows(lanes, 1, threads, |_, row| {
        let lane = &mut row[0];
        lane.out = lane.state.sample(lane.logits);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn logits_v(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal() * 2.0).collect()
    }

    #[test]
    fn default_params_are_greedy_identity() {
        let p = SamplingParams::default();
        assert!(p.validate().is_ok());
        let st = SamplerState::new(p, 7, &[]);
        let l = logits_v(32, 1);
        let d = st.distribution(&l);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0 as usize, argmax_finite(&l));
    }

    #[test]
    fn parse_rejects_malformed_fields() {
        for bad in [
            r#"{"temperature": "hot"}"#,
            r#"{"top_k": -3}"#,
            r#"{"top_k": 2.5}"#,
            r#"{"logit_bias": [1, 2]}"#,
            r#"{"logit_bias": {"x": 1}}"#,
            r#"{"stop_token_ids": 4}"#,
            r#"{"seed": -1}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(SamplingParams::from_json(&j).is_err(), "accepted {bad}");
        }
        for bad in [
            SamplingParams { top_p: 0.0, ..Default::default() },
            SamplingParams { top_p: 1.5, ..Default::default() },
            SamplingParams { temperature: f32::NAN, ..Default::default() },
            SamplingParams { repetition_penalty: 0.0, ..Default::default() },
            SamplingParams {
                stop_sequences: vec![vec![]],
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err(), "validated {bad:?}");
        }
    }

    #[test]
    fn parse_roundtrips_the_full_suite() {
        let j = Json::parse(
            r#"{"temperature": 0.8, "top_k": 40, "top_p": 0.9,
                "repetition_penalty": 1.3, "presence_penalty": 0.5,
                "frequency_penalty": 0.25, "seed": 42,
                "logit_bias": {"65": -1e9, "66": 2.0},
                "stop_token_ids": [10, 13]}"#,
        )
        .unwrap();
        let p = SamplingParams::from_json(&j).unwrap();
        assert_eq!(p.top_k, 40);
        assert_eq!(p.seed, Some(42));
        assert_eq!(p.stop_token_ids, vec![10, 13]);
        assert_eq!(p.logit_bias.len(), 2);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn ban_bias_excludes_token_entirely() {
        let l = {
            let mut l = logits_v(16, 3);
            l[5] = 100.0; // would dominate
            l
        };
        let p = SamplingParams {
            temperature: 1.0,
            logit_bias: vec![(5, BAN_BIAS)],
            ..Default::default()
        };
        let mut st = SamplerState::new(p, 1, &[]);
        assert!(st.distribution(&l).iter().all(|&(t, _)| t != 5));
        for _ in 0..50 {
            assert_ne!(st.sample(&l), 5);
        }
    }

    #[test]
    fn stop_sequence_matches_across_records() {
        let p = SamplingParams {
            stop_sequences: vec![vec![7, 8, 9]],
            ..Default::default()
        };
        let mut st = SamplerState::new(p, 1, &[]);
        for t in [1, 7, 8] {
            st.record(t);
            assert_eq!(st.stop_hit(), None);
        }
        st.record(9);
        assert_eq!(st.stop_hit(), Some(FinishReason::StopSequence));
    }

    #[test]
    fn stop_token_id_reported_as_stop_token() {
        let p = SamplingParams {
            stop_token_ids: vec![3],
            ..Default::default()
        };
        let mut st = SamplerState::new(p, 1, &[]);
        st.record(2);
        assert_eq!(st.stop_hit(), None);
        st.record(3);
        assert_eq!(st.stop_hit(), Some(FinishReason::StopToken));
    }

    #[test]
    fn seeded_states_replay_identically() {
        let p = SamplingParams {
            temperature: 0.9,
            top_k: 8,
            top_p: 0.95,
            seed: Some(99),
            ..Default::default()
        };
        let l = logits_v(64, 5);
        let mut a = SamplerState::new(p.clone(), 1, &[4, 5]);
        let mut b = SamplerState::new(p, 999, &[4, 5]); // id must not matter
        for _ in 0..32 {
            assert_eq!(a.sample(&l), b.sample(&l));
        }
    }

    #[test]
    fn lanes_match_serial_sampling() {
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 12,
            top_p: 0.9,
            ..Default::default()
        };
        let rows: Vec<Vec<f32>> = (0..9).map(|i| logits_v(48, 100 + i)).collect();
        let mut par: Vec<SamplerState> =
            (0..9).map(|i| SamplerState::new(p.clone(), i, &[])).collect();
        let mut ser = par.clone();
        let toks: Vec<u32> = {
            let mut lanes: Vec<Lane> = par
                .iter_mut()
                .zip(&rows)
                .map(|(s, l)| Lane::new(s, l))
                .collect();
            sample_lanes(&mut lanes);
            lanes.iter().map(|l| l.token()).collect()
        };
        for (i, s) in ser.iter_mut().enumerate() {
            assert_eq!(s.sample(&rows[i]), toks[i], "lane {i}");
        }
    }

    #[test]
    fn pick_is_robust_to_undermass() {
        let mut rng = Pcg::new(2);
        // mass sums to 0.5: scaled draw must stay within the candidates
        let cand = vec![(1u32, 0.2f32), (2, 0.2), (3, 0.1)];
        for _ in 0..200 {
            let t = pick(&cand, &mut rng);
            assert!(cand.iter().any(|&(c, _)| c == t));
        }
        // zero mass: fall back to the most probable candidate
        assert_eq!(pick(&[(4, 0.0), (9, 0.0)], &mut rng), 4);
    }
}
