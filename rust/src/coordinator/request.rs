//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc;
use std::time::Instant;

use crate::model::sampler::Sampling;

pub type RequestId = u64;

/// A generation request.
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// Stop generation at this token (e.g. b'.' for the demo corpus).
    pub stop_token: Option<u32>,
    pub submitted_at: Instant,
    /// Channel the scheduler answers on.
    pub reply: mpsc::Sender<Response>,
}

/// Completion + per-request timing breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub queue_ms: f32,
    pub prefill_ms: f32,
    pub decode_ms: f32,
    pub total_ms: f32,
    /// Sequence position where generation stopped.
    pub finish_reason: FinishReason,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    /// KV capacity exhausted.
    Truncated,
    /// Coordinator shutting down.
    Aborted,
}

/// Submission failures (backpressure surface).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity: shed load (HTTP 429 analog).
    QueueFull,
    /// Coordinator stopped.
    Closed,
    /// Prompt longer than the engine's max sequence.
    PromptTooLong { prompt: usize, max: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::Closed => write!(f, "coordinator closed"),
            SubmitError::PromptTooLong { prompt, max } => {
                write!(f, "prompt length {prompt} exceeds max {max}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}
