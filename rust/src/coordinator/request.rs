//! Request/response types crossing the coordinator boundary.
//!
//! Replies stream: the scheduler sends one [`Event::Token`] per sampled
//! token as it is produced, then exactly one [`Event::Done`] carrying
//! the full [`Response`].  Dropping the receiver (or setting the
//! [`StreamHandle`] cancel flag) tells the scheduler the client went
//! away; the lane is retired as [`FinishReason::Cancelled`] and its KV
//! blocks are freed.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::sampling::SamplingParams;

pub type RequestId = u64;

/// One frame of a streaming reply.
#[derive(Clone, Debug)]
pub enum Event {
    /// A freshly sampled token; `index` is its position in the
    /// generated sequence (0-based, gap-free).
    Token { id: RequestId, index: usize, token: u32 },
    /// Terminal frame: the complete response with timings.
    Done(Response),
}

/// Submission-time knobs beyond the prompt itself.
#[derive(Clone, Debug)]
pub struct RequestOptions {
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// Higher runs first; lower is preempted first.  Default 0.
    pub priority: i32,
    /// Relative deadline; a lane past it finishes as
    /// [`FinishReason::Deadline`] with whatever it produced.
    pub deadline: Option<Duration>,
}

impl Default for RequestOptions {
    fn default() -> Self {
        RequestOptions {
            max_new_tokens: 32,
            params: SamplingParams::default(),
            priority: 0,
            deadline: None,
        }
    }
}

/// A generation request.
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    pub priority: i32,
    /// Absolute deadline (resolved from [`RequestOptions::deadline`]).
    pub deadline: Option<Instant>,
    /// Client-side cancellation flag (shared with the [`StreamHandle`]).
    pub cancel: Arc<AtomicBool>,
    pub submitted_at: Instant,
    /// Channel the scheduler streams events on.
    pub reply: mpsc::Sender<Event>,
}

impl Request {
    pub fn new(
        id: RequestId,
        prompt: Vec<u32>,
        opts: RequestOptions,
        reply: mpsc::Sender<Event>,
    ) -> Request {
        let submitted_at = Instant::now();
        Request {
            id,
            prompt,
            max_new_tokens: opts.max_new_tokens,
            params: opts.params,
            priority: opts.priority,
            deadline: opts.deadline.map(|d| submitted_at + d),
            cancel: Arc::new(AtomicBool::new(false)),
            submitted_at,
            reply,
        }
    }
}

/// Client side of a streaming submission.
pub struct StreamHandle {
    pub id: RequestId,
    pub events: mpsc::Receiver<Event>,
    pub cancel: Arc<AtomicBool>,
}

impl StreamHandle {
    /// Ask the scheduler to stop this request at the next step; it
    /// finishes as [`FinishReason::Cancelled`] and frees its lane.
    pub fn abort(&self) {
        // ORDERING: the cancel flag is a lone latch with no payload
        // published alongside it; the scheduler polls it once per step,
        // so Relaxed only delays the stop by at most one step.
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Block until the terminal frame, discarding token frames.
    pub fn wait(self) -> Result<Response, SubmitError> {
        wait_done(&self.events)
    }
}

/// Drain token frames until the terminal [`Event::Done`].
pub fn wait_done(rx: &mpsc::Receiver<Event>) -> Result<Response, SubmitError> {
    loop {
        match rx.recv() {
            Ok(Event::Token { .. }) => continue,
            Ok(Event::Done(resp)) => return Ok(resp),
            Err(_) => return Err(SubmitError::Closed),
        }
    }
}

/// Completion + per-request timing breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub queue_ms: f32,
    pub prefill_ms: f32,
    pub decode_ms: f32,
    pub total_ms: f32,
    /// Sequence position where generation stopped.
    pub finish_reason: FinishReason,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    /// A token in `stop_token_ids` was produced.
    StopToken,
    /// The generated tail matched a stop sequence.
    StopSequence,
    /// KV capacity exhausted.
    Truncated,
    /// Coordinator shutting down or the request could never fit.
    Aborted,
    /// Deadline passed before completion.
    Deadline,
    /// Client went away (receiver dropped or cancel flag set).
    Cancelled,
}

impl FinishReason {
    /// Stable wire string used by the TCP protocol and metrics.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop",
            FinishReason::StopSequence => "stop_seq",
            FinishReason::Truncated => "truncated",
            FinishReason::Aborted => "aborted",
            FinishReason::Deadline => "deadline",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// Submission failures (backpressure surface).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity: shed load (HTTP 429 analog).
    QueueFull,
    /// Coordinator stopped.
    Closed,
    /// Prompt longer than the engine's max sequence.
    PromptTooLong { prompt: usize, max: usize },
    /// Sampling params failed validation (never silently coerced).
    InvalidParams(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::Closed => write!(f, "coordinator closed"),
            SubmitError::PromptTooLong { prompt, max } => {
                write!(f, "prompt length {prompt} exceeds max {max}")
            }
            SubmitError::InvalidParams(e) => {
                write!(f, "invalid sampling params: {e}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}
