//! Engine abstraction the scheduler drives: the pure-rust INT4 engine is
//! the default backend; the PJRT executor (runtime::PjrtEngine) can serve
//! the same trait for the AOT-graph path.

use crate::linalg::gemm::Mat;
use crate::model::engine::{KvCache, QuantModel};

/// Opaque per-sequence state owned by the backend.
pub trait ServeEngine: Send + Sync {
    type Seq: Send;

    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Create an empty sequence state.
    fn new_seq(&self) -> Self::Seq;

    /// Prefill `tokens` into the sequence; returns logits of the LAST
    /// position [vocab].
    fn prefill(&self, seq: &mut Self::Seq, tokens: &[u32]) -> Vec<f32>;

    /// Advance every sequence by one token; returns logits [B, vocab].
    fn decode(&self, batch: &mut [(&mut Self::Seq, u32)]) -> Mat;

    /// Current length of a sequence.
    fn seq_len(&self, seq: &Self::Seq) -> usize;

    /// KV memory footprint of a sequence (for metrics).
    fn seq_bytes(&self, seq: &Self::Seq) -> usize;
}

/// The pure-rust quantized engine backend.
pub struct RustServeEngine {
    pub model: QuantModel,
}

impl RustServeEngine {
    pub fn new(model: QuantModel) -> RustServeEngine {
        RustServeEngine { model }
    }
}

impl ServeEngine for RustServeEngine {
    type Seq = KvCache;

    fn max_seq(&self) -> usize {
        self.model.mcfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.model.mcfg.vocab
    }

    fn new_seq(&self) -> KvCache {
        KvCache::new(&self.model.mcfg, &self.model.ecfg)
    }

    fn prefill(&self, seq: &mut KvCache, tokens: &[u32]) -> Vec<f32> {
        let logits = self.model.forward_full(tokens, Some(seq));
        logits.row(logits.rows - 1).to_vec()
    }

    fn decode(&self, batch: &mut [(&mut KvCache, u32)]) -> Mat {
        self.model.decode_batch(batch)
    }

    fn seq_len(&self, seq: &KvCache) -> usize {
        seq.len()
    }

    fn seq_bytes(&self, seq: &KvCache) -> usize {
        seq.bytes()
    }
}
