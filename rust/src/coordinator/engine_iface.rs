//! Engine abstraction the scheduler drives.  Three backends implement
//! it: the pure-rust INT4 engine ([`RustServeEngine`], flat per-sequence
//! caches), the paged-pool backend ([`PagedEngine`], block-governed
//! memory with prefix sharing), and the AOT-graph backend
//! ([`crate::runtime::PagedPjrtEngine`]), which runs compiled PJRT
//! decode graphs over the *same* paged pool — so admission, prefix
//! sharing and preemption behave identically on every serving path.

use std::fmt;

use crate::kvpool::{PagedEngine, PagedSeq, PoolStats};
use crate::linalg::gemm::Mat;
use crate::model::engine::{KvCache, QuantModel};
use crate::runtime::residency::ResidencyStats;

/// Typed engine failure: a backend step that could not run (compiled
/// graph execution failed, device lost).  Distinct from a capacity
/// refusal, which is the `None` arm of
/// [`try_prefill`](ServeEngine::try_prefill) and is retryable; an
/// `EngineError` aborts the affected lanes with strict protocol replies
/// instead of panicking the scheduler thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineError(pub String);

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "engine error: {}", self.0)
    }
}

impl std::error::Error for EngineError {}

/// Opaque per-sequence state owned by the backend.
pub trait ServeEngine: Send + Sync {
    type Seq: Send;

    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Create an empty sequence state.
    fn new_seq(&self) -> Self::Seq;

    /// Prefill `tokens` into the sequence; returns logits of the LAST
    /// position, `[vocab]`-shaped.  `None` means the backend could not
    /// reserve KV memory for this prompt *right now* — a request that
    /// passed [`can_admit`](ServeEngine::can_admit) can still lose its
    /// blocks to an earlier admission in the same scheduler round, and
    /// paged backends re-check jointly at reservation time.  On `None`
    /// the sequence is left released and the scheduler re-queues the
    /// request.  Backends without a capacity gate never return `None`.
    ///
    /// This is the only prefill entry point: an infallible `prefill`
    /// used to be the required method, but every serving caller has to
    /// handle the capacity refusal anyway, and the infallible wrapper
    /// invited `.expect()` on the serving path (rrs-audit rule R2).
    fn try_prefill(&self, seq: &mut Self::Seq, tokens: &[u32]) -> Option<Vec<f32>>;

    /// Advance every sequence by one token; returns logits [B, vocab],
    /// or a typed error when the backend could not run the step at all
    /// (the scheduler aborts the affected lanes with terminal replies).
    fn decode(
        &self,
        batch: &mut [(&mut Self::Seq, u32)],
    ) -> Result<Mat, EngineError>;

    /// Current length of a sequence.
    fn seq_len(&self, seq: &Self::Seq) -> usize;

    /// KV memory footprint of a sequence (for metrics).
    fn seq_bytes(&self, seq: &Self::Seq) -> usize;

    /// Pool-capacity gate: can a prompt of this shape be admitted right
    /// now?  Flat backends always admit (memory is unbounded per seq);
    /// paged backends check block availability.
    fn can_admit(&self, _prompt: &[u32]) -> bool {
        true
    }

    /// Longest prompt prefix already resident in the backend's prefix
    /// cache, in tokens (0 for backends without one).
    fn prefix_match_len(&self, _prompt: &[u32]) -> usize {
        0
    }

    /// Ensure `seq` can grow by one token before the next decode step;
    /// `false` = the scheduler must preempt (or retire) first.
    fn reserve_decode(&self, _seq: &mut Self::Seq) -> bool {
        true
    }

    /// Release a sequence's cache resources (retire / preemption).
    fn release_seq(&self, _seq: &mut Self::Seq) {}

    /// KV-pool occupancy counters, when the backend is paged.
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }

    /// Resident-lane gather/scatter/refresh counters, when the backend
    /// serves decode from resident dense lanes
    /// ([`crate::runtime::PagedPjrtEngine`]).
    fn residency_stats(&self) -> Option<ResidencyStats> {
        None
    }
}

/// The pure-rust quantized engine backend (flat per-sequence caches).
pub struct RustServeEngine {
    pub model: QuantModel,
}

impl RustServeEngine {
    pub fn new(model: QuantModel) -> RustServeEngine {
        RustServeEngine { model }
    }
}

impl ServeEngine for RustServeEngine {
    type Seq = KvCache;

    fn max_seq(&self) -> usize {
        self.model.mcfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.model.mcfg.vocab
    }

    fn new_seq(&self) -> KvCache {
        KvCache::new(&self.model.mcfg, &self.model.ecfg)
    }

    fn try_prefill(&self, seq: &mut KvCache, tokens: &[u32]) -> Option<Vec<f32>> {
        // flat caches have no capacity gate: prefill always succeeds
        let logits = self.model.forward_full(tokens, Some(seq));
        Some(logits.row(logits.rows - 1).to_vec())
    }

    fn decode(
        &self,
        batch: &mut [(&mut KvCache, u32)],
    ) -> Result<Mat, EngineError> {
        Ok(self.model.decode_batch(batch))
    }

    fn seq_len(&self, seq: &KvCache) -> usize {
        seq.len()
    }

    fn seq_bytes(&self, seq: &KvCache) -> usize {
        seq.bytes()
    }
}

impl ServeEngine for PagedEngine {
    type Seq = PagedSeq;

    fn max_seq(&self) -> usize {
        self.model.mcfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.model.mcfg.vocab
    }

    fn new_seq(&self) -> PagedSeq {
        PagedEngine::new_seq(self)
    }

    fn try_prefill(&self, seq: &mut PagedSeq, tokens: &[u32]) -> Option<Vec<f32>> {
        PagedEngine::try_prefill(self, seq, tokens)
    }

    fn decode(
        &self,
        batch: &mut [(&mut PagedSeq, u32)],
    ) -> Result<Mat, EngineError> {
        Ok(PagedEngine::decode(self, batch))
    }

    fn seq_len(&self, seq: &PagedSeq) -> usize {
        seq.len
    }

    fn seq_bytes(&self, seq: &PagedSeq) -> usize {
        PagedEngine::seq_bytes(self, seq)
    }

    fn can_admit(&self, prompt: &[u32]) -> bool {
        PagedEngine::can_admit(self, prompt)
    }

    fn prefix_match_len(&self, prompt: &[u32]) -> usize {
        PagedEngine::prefix_match_len(self, prompt)
    }

    fn reserve_decode(&self, seq: &mut PagedSeq) -> bool {
        PagedEngine::reserve_decode(self, seq)
    }

    fn release_seq(&self, seq: &mut PagedSeq) {
        PagedEngine::release(self, seq)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.stats())
    }
}
