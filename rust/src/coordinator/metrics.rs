//! Serving metrics: counters + latency reservoirs, snapshotted as JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Coordinator-wide metrics (thread-safe).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    lat_total_ms: Mutex<Vec<f32>>,
    lat_queue_ms: Mutex<Vec<f32>>,
    lat_per_token_ms: Mutex<Vec<f32>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_completion(&self, total_ms: f32, queue_ms: f32, n_tokens: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(n_tokens as u64, Ordering::Relaxed);
        self.lat_total_ms.lock().unwrap().push(total_ms);
        self.lat_queue_ms.lock().unwrap().push(queue_ms);
        if n_tokens > 0 {
            self.lat_per_token_ms
                .lock()
                .unwrap()
                .push(total_ms / n_tokens as f32);
        }
    }

    pub fn total_summary(&self) -> Summary {
        Summary::of(&self.lat_total_ms.lock().unwrap())
    }

    pub fn queue_summary(&self) -> Summary {
        Summary::of(&self.lat_queue_ms.lock().unwrap())
    }

    pub fn per_token_summary(&self) -> Summary {
        Summary::of(&self.lat_per_token_ms.lock().unwrap())
    }

    pub fn snapshot_json(&self) -> Json {
        let s = self.total_summary();
        let q = self.queue_summary();
        let pt = self.per_token_summary();
        obj(vec![
            ("submitted", (self.submitted.load(Ordering::Relaxed) as usize).into()),
            ("rejected", (self.rejected.load(Ordering::Relaxed) as usize).into()),
            ("completed", (self.completed.load(Ordering::Relaxed) as usize).into()),
            (
                "tokens_generated",
                (self.tokens_generated.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "decode_steps",
                (self.decode_steps.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "latency_ms",
                obj(vec![
                    ("p50", (s.p50 as f64).into()),
                    ("p90", (s.p90 as f64).into()),
                    ("p99", (s.p99 as f64).into()),
                    ("mean", (s.mean as f64).into()),
                ]),
            ),
            (
                "queue_ms",
                obj(vec![("p50", (q.p50 as f64).into()), ("p90", (q.p90 as f64).into())]),
            ),
            (
                "per_token_ms",
                obj(vec![("p50", (pt.p50 as f64).into()), ("p90", (pt.p90 as f64).into())]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.observe_completion(100.0, 5.0, 10);
        m.observe_completion(200.0, 10.0, 20);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        let j = m.snapshot_json();
        assert_eq!(j.get("submitted").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("tokens_generated").unwrap().as_usize(), Some(30));
        assert!(j.get("latency_ms").unwrap().get("p50").is_some());
    }
}
