//! Serving metrics: counters, bounded log-scale latency histograms, and
//! the request-lifecycle trace ring, snapshotted as JSON (the `metrics`
//! / `stats` commands) or Prometheus text ([`crate::obs::prom`], the
//! `metrics_prom` command).
//!
//! Latencies live in fixed-memory lock-free
//! [`LogHistogram`](crate::obs::hist::LogHistogram)s — the old
//! unbounded `Mutex<Vec<f32>>` reservoirs grew forever on a long-running
//! server and their mutexes could poison the stats endpoint; the
//! histograms have neither failure mode while keeping the same
//! [`Summary`] output shape for existing callers.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernels;
use crate::kvpool::PoolStats;
use crate::obs::hist::LogHistogram;
use crate::obs::trace::TraceRing;
use crate::obs::{self, Sampler};
use crate::runtime::residency::ResidencyStats;
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Coordinator-wide metrics (thread-safe).
///
/// # Examples
///
/// ```
/// use rrs::coordinator::Metrics;
///
/// let m = Metrics::new();
/// m.observe_completion(12.0, 2.0, 6); // total_ms, queue_ms, tokens
/// let snap = m.snapshot_json();
/// assert_eq!(snap.get("completed").unwrap().as_usize(), Some(1));
/// assert_eq!(snap.get("tokens_generated").unwrap().as_usize(), Some(6));
/// ```
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub aborted: AtomicU64,
    /// Client went away (stream receiver dropped / cancel flag set).
    pub cancelled: AtomicU64,
    /// Requests finished by deadline-aware preemption past their deadline.
    pub deadline_missed: AtomicU64,
    pub tokens_generated: AtomicU64,
    /// Token frames actually delivered to live stream receivers.
    pub tokens_streamed: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    /// Sequences preempted back to the queue on pool exhaustion.
    pub preemptions: AtomicU64,
    // KV-pool gauges, refreshed by the scheduler loop on paged backends.
    pub pool_blocks_total: AtomicU64,
    pub pool_blocks_used: AtomicU64,
    pub pool_blocks_cached: AtomicU64,
    /// High-water mark of pool_blocks_used.
    pub pool_blocks_peak: AtomicU64,
    pub pool_evictions: AtomicU64,
    pub pool_cow_copies: AtomicU64,
    pub pool_lazy_tail_shares: AtomicU64,
    pub pool_lazy_tail_copies: AtomicU64,
    pub prefix_queries: AtomicU64,
    pub prefix_query_tokens: AtomicU64,
    pub prefix_hit_tokens: AtomicU64,
    pub prefix_hit_blocks: AtomicU64,
    pub prefix_partial_hits: AtomicU64,
    // Resident-lane gauges, refreshed by the scheduler loop on backends
    // that decode from resident dense lanes (runtime::PagedPjrtEngine).
    // kv_gather_total flat across steady-state decode is the O(1) claim.
    pub kv_gather_total: AtomicU64,
    pub kv_scatter_rows_total: AtomicU64,
    pub lane_refresh_total: AtomicU64,
    pub resident_hits: AtomicU64,
    pub decode_graph_calls: AtomicU64,
    /// Request-lifecycle span ring (`trace` command exports it).
    pub trace: TraceRing,
    /// Sampler gating per-decode-step trace spans (`RRS_OBS_SAMPLE`).
    pub step_trace: Sampler,
    lat_total: LogHistogram,
    lat_queue: LogHistogram,
    lat_per_token: LogHistogram,
    lat_prefill: LogHistogram,
    lat_ttft: LogHistogram,
    lat_itl: LogHistogram,
}

impl Metrics {
    // ORDERING: every atomic in this impl is an independent monotonic
    // counter or last-write-wins gauge; snapshot readers tolerate a
    // torn view across fields (the stats endpoint is advisory, not a
    // synchronization point), so all accesses are intentionally Relaxed.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_completion(&self, total_ms: f32, queue_ms: f32, n_tokens: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(n_tokens as u64, Ordering::Relaxed);
        self.lat_total.observe(total_ms);
        self.lat_queue.observe(queue_ms);
        if n_tokens > 0 {
            self.lat_per_token.observe(total_ms / n_tokens as f32);
        }
    }

    /// Record one prompt prefill (compute only, this admission round).
    pub fn observe_prefill(&self, prefill_ms: f32) {
        self.lat_prefill.observe(prefill_ms);
    }

    /// Record time-to-first-token: submission to the first sampled token.
    /// Every observation also feeds the SLO watchdog's burn-rate window.
    pub fn observe_ttft(&self, ttft_ms: f32) {
        self.lat_ttft.observe(ttft_ms);
        obs::watchdog::observe_ttft(ttft_ms);
    }

    /// Record one inter-token latency (gap between consecutive tokens of
    /// one request, measured across batched decode steps).  Every
    /// observation also feeds the SLO watchdog's burn-rate window.
    pub fn observe_itl(&self, itl_ms: f32) {
        self.lat_itl.observe(itl_ms);
        obs::watchdog::observe_itl(itl_ms);
    }

    pub fn total_summary(&self) -> Summary {
        self.lat_total.summary()
    }

    pub fn queue_summary(&self) -> Summary {
        self.lat_queue.summary()
    }

    pub fn per_token_summary(&self) -> Summary {
        self.lat_per_token.summary()
    }

    pub fn prefill_summary(&self) -> Summary {
        self.lat_prefill.summary()
    }

    pub fn ttft_summary(&self) -> Summary {
        self.lat_ttft.summary()
    }

    pub fn itl_summary(&self) -> Summary {
        self.lat_itl.summary()
    }

    /// Histogram families as `(prometheus_name, help, histogram)` — the
    /// [`crate::obs::prom`] renderer iterates this.
    pub fn histograms(&self) -> [(&'static str, &'static str, &LogHistogram); 6] {
        [
            (
                "rrs_request_latency_ms",
                "End-to-end request latency (queue + prefill + decode).",
                &self.lat_total,
            ),
            (
                "rrs_queue_wait_ms",
                "Queue wait before first admission.",
                &self.lat_queue,
            ),
            (
                "rrs_per_token_ms",
                "Total latency divided by generated tokens.",
                &self.lat_per_token,
            ),
            (
                "rrs_prefill_ms",
                "Prompt prefill compute per admission round.",
                &self.lat_prefill,
            ),
            (
                "rrs_ttft_ms",
                "Time to first token (submission to first sample).",
                &self.lat_ttft,
            ),
            (
                "rrs_itl_ms",
                "Inter-token latency across batched decode steps.",
                &self.lat_itl,
            ),
        ]
    }

    /// Refresh the KV-pool gauges from a pool snapshot (scheduler loop).
    pub fn update_pool(&self, s: &PoolStats) {
        self.pool_blocks_total.store(s.blocks_total as u64, Ordering::Relaxed);
        self.pool_blocks_used.store(s.blocks_active as u64, Ordering::Relaxed);
        self.pool_blocks_cached.store(s.blocks_cached as u64, Ordering::Relaxed);
        self.pool_blocks_peak.fetch_max(s.blocks_active as u64, Ordering::Relaxed);
        self.pool_evictions.store(s.evictions, Ordering::Relaxed);
        self.pool_cow_copies.store(s.cow_copies, Ordering::Relaxed);
        self.pool_lazy_tail_shares.store(s.lazy_tail_shares, Ordering::Relaxed);
        self.pool_lazy_tail_copies.store(s.lazy_tail_copies, Ordering::Relaxed);
        self.prefix_queries.store(s.prefix_queries, Ordering::Relaxed);
        self.prefix_query_tokens.store(s.prefix_query_tokens, Ordering::Relaxed);
        self.prefix_hit_tokens.store(s.prefix_hit_tokens, Ordering::Relaxed);
        self.prefix_hit_blocks.store(s.prefix_hit_blocks, Ordering::Relaxed);
        self.prefix_partial_hits.store(s.prefix_partial_hits, Ordering::Relaxed);
    }

    /// Refresh the resident-lane gauges from an engine snapshot
    /// (scheduler loop, paged PJRT backend).
    pub fn update_residency(&self, s: &ResidencyStats) {
        self.kv_gather_total.store(s.kv_gather_total, Ordering::Relaxed);
        self.kv_scatter_rows_total
            .store(s.kv_scatter_rows_total, Ordering::Relaxed);
        self.lane_refresh_total.store(s.lane_refresh_total, Ordering::Relaxed);
        self.resident_hits.store(s.resident_hits, Ordering::Relaxed);
        self.decode_graph_calls.store(s.decode_graph_calls, Ordering::Relaxed);
    }

    /// Fraction of probed prompt tokens served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        let hit = self.prefix_hit_tokens.load(Ordering::Relaxed) as f64;
        let probed = self.prefix_query_tokens.load(Ordering::Relaxed) as f64;
        if probed > 0.0 {
            hit / probed
        } else {
            0.0
        }
    }

    pub fn snapshot_json(&self) -> Json {
        let s = self.total_summary();
        let q = self.queue_summary();
        let pt = self.per_token_summary();
        let pf = self.prefill_summary();
        let tt = self.ttft_summary();
        let it = self.itl_summary();
        obj(vec![
            ("submitted", (self.submitted.load(Ordering::Relaxed) as usize).into()),
            ("rejected", (self.rejected.load(Ordering::Relaxed) as usize).into()),
            ("completed", (self.completed.load(Ordering::Relaxed) as usize).into()),
            (
                "tokens_generated",
                (self.tokens_generated.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "decode_steps",
                (self.decode_steps.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "preemptions",
                (self.preemptions.load(Ordering::Relaxed) as usize).into(),
            ),
            ("aborted", (self.aborted.load(Ordering::Relaxed) as usize).into()),
            ("cancelled", (self.cancelled.load(Ordering::Relaxed) as usize).into()),
            (
                "deadline_missed",
                (self.deadline_missed.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "tokens_streamed",
                (self.tokens_streamed.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "kv_pool",
                obj(vec![
                    (
                        "blocks_total",
                        (self.pool_blocks_total.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "blocks_used",
                        (self.pool_blocks_used.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "blocks_cached",
                        (self.pool_blocks_cached.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "blocks_peak",
                        (self.pool_blocks_peak.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "evictions",
                        (self.pool_evictions.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "cow_copies",
                        (self.pool_cow_copies.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "lazy_tail_shares",
                        (self.pool_lazy_tail_shares.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "lazy_tail_copies",
                        (self.pool_lazy_tail_copies.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "prefix_queries",
                        (self.prefix_queries.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "prefix_hit_tokens",
                        (self.prefix_hit_tokens.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "prefix_hit_blocks",
                        (self.prefix_hit_blocks.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "prefix_partial_hits",
                        (self.prefix_partial_hits.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    ("prefix_hit_rate", self.prefix_hit_rate().into()),
                ]),
            ),
            ("kernels", kernel_json()),
            (
                "lane_residency",
                obj(vec![
                    (
                        "kv_gather_total",
                        (self.kv_gather_total.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "kv_scatter_rows_total",
                        (self.kv_scatter_rows_total.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "lane_refresh_total",
                        (self.lane_refresh_total.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                    (
                        "resident_hits",
                        (self.resident_hits.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "decode_graph_calls",
                        (self.decode_graph_calls.load(Ordering::Relaxed) as usize)
                            .into(),
                    ),
                ]),
            ),
            (
                "latency_ms",
                obj(vec![
                    ("p50", (s.p50 as f64).into()),
                    ("p90", (s.p90 as f64).into()),
                    ("p99", (s.p99 as f64).into()),
                    ("mean", (s.mean as f64).into()),
                ]),
            ),
            (
                "queue_ms",
                obj(vec![("p50", (q.p50 as f64).into()), ("p90", (q.p90 as f64).into())]),
            ),
            (
                "per_token_ms",
                obj(vec![("p50", (pt.p50 as f64).into()), ("p90", (pt.p90 as f64).into())]),
            ),
            (
                "prefill_ms",
                obj(vec![("p50", (pf.p50 as f64).into()), ("p90", (pf.p90 as f64).into())]),
            ),
            (
                "ttft_ms",
                obj(vec![
                    ("n", tt.n.into()),
                    ("p50", (tt.p50 as f64).into()),
                    ("p90", (tt.p90 as f64).into()),
                    ("p99", (tt.p99 as f64).into()),
                    ("mean", (tt.mean as f64).into()),
                ]),
            ),
            (
                "itl_ms",
                obj(vec![
                    ("n", it.n.into()),
                    ("p50", (it.p50 as f64).into()),
                    ("p90", (it.p90 as f64).into()),
                    ("p99", (it.p99 as f64).into()),
                    ("mean", (it.mean as f64).into()),
                ]),
            ),
            ("quant_health", obs::health::snapshot_json()),
            ("alerts", obs::watchdog::alerts_json()),
            (
                "attrib",
                obj(vec![
                    ("window", obs::attrib::finished_len().into()),
                ]),
            ),
            (
                "trace",
                obj(vec![
                    ("events_total", (self.trace.total() as usize).into()),
                    ("dropped", (self.trace.dropped() as usize).into()),
                    ("capacity", self.trace.capacity().into()),
                ]),
            ),
        ])
    }
}

/// Kernel-layer snapshot for the `stats` endpoint: the live backend, the
/// autotuned tile shape, and cumulative dispatch counters.  Read straight
/// from the process-wide [`crate::kernels`] registry — all serving
/// backends share one kernel layer, so there is nothing per-engine to
/// poll.  Uses the non-forcing peek so a metrics poll never runs the
/// startup autotune sweep itself (a pure-PJRT server may never resolve
/// the interpreted kernel registry at all).
fn kernel_json() -> Json {
    let Some(ks) = kernels::stats_peek() else {
        return obj(vec![("backend", "uninitialized".into())]);
    };
    obj(vec![
        ("backend", ks.backend.into()),
        ("tile", Json::Str(ks.tiles.label())),
        ("autotuned", ks.autotuned.into()),
        ("autotune_us", (ks.autotune_us as usize).into()),
        ("fused_gemm_calls", (ks.fused_gemm_calls as usize).into()),
        ("fused_gemm_rows", (ks.fused_gemm_rows as usize).into()),
        ("per_channel_calls", (ks.per_channel_calls as usize).into()),
        ("igemm_calls", (ks.igemm_calls as usize).into()),
        ("prologue_rows", (ks.prologue_rows as usize).into()),
        ("fwht_rows", (ks.fwht_rows as usize).into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.observe_completion(100.0, 5.0, 10);
        m.observe_completion(200.0, 10.0, 20);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        let j = m.snapshot_json();
        assert_eq!(j.get("submitted").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("tokens_generated").unwrap().as_usize(), Some(30));
        assert!(j.get("latency_ms").unwrap().get("p50").is_some());
    }

    #[test]
    fn latency_reservoirs_are_bounded_histograms() {
        // the old Vec reservoirs grew without bound; the histograms must
        // absorb any number of observations at fixed memory while keeping
        // the Summary shape for callers
        let m = Metrics::new();
        for i in 0..10_000 {
            m.observe_completion(50.0 + (i % 100) as f32, 1.0, 10);
        }
        let s = m.total_summary();
        assert_eq!(s.n, 10_000);
        assert!(s.p50 >= 50.0 && s.p50 <= 170.0, "p50 {}", s.p50);
        assert!(s.min >= 50.0 && s.max <= 150.0);
        // per-token: 10k observations around 5-15 ms
        let pt = m.per_token_summary();
        assert_eq!(pt.n, 10_000);
        assert!(pt.p90 <= 16.0, "p90 {}", pt.p90);
    }

    #[test]
    fn ttft_itl_prefill_snapshot() {
        let m = Metrics::new();
        m.observe_ttft(25.0);
        m.observe_ttft(35.0);
        m.observe_itl(4.0);
        m.observe_prefill(18.0);
        let j = m.snapshot_json();
        let tt = j.get("ttft_ms").unwrap();
        assert_eq!(tt.get("n").unwrap().as_usize(), Some(2));
        let p50 = tt.get("p50").unwrap().as_f64().unwrap();
        assert!(p50 > 20.0 && p50 < 40.0, "ttft p50 {p50}");
        let it = j.get("itl_ms").unwrap();
        assert_eq!(it.get("n").unwrap().as_usize(), Some(1));
        let ip50 = it.get("p50").unwrap().as_f64().unwrap();
        assert!((ip50 - 4.0).abs() < 1e-3, "itl p50 {ip50}");
        assert!(j.get("prefill_ms").unwrap().get("p50").is_some());
        assert!(j.get("quant_health").is_some());
        let tr = j.get("trace").unwrap();
        assert_eq!(tr.get("events_total").unwrap().as_usize(), Some(0));
        assert!(tr.get("capacity").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn residency_gauges_snapshot() {
        let m = Metrics::new();
        m.update_residency(&ResidencyStats {
            kv_gather_total: 7,
            kv_scatter_rows_total: 640,
            lane_refresh_total: 5,
            resident_hits: 120,
            decode_graph_calls: 33,
        });
        let j = m.snapshot_json();
        let lr = j.get("lane_residency").unwrap();
        assert_eq!(lr.get("kv_gather_total").unwrap().as_usize(), Some(7));
        assert_eq!(lr.get("kv_scatter_rows_total").unwrap().as_usize(), Some(640));
        assert_eq!(lr.get("lane_refresh_total").unwrap().as_usize(), Some(5));
        assert_eq!(lr.get("resident_hits").unwrap().as_usize(), Some(120));
        assert_eq!(lr.get("decode_graph_calls").unwrap().as_usize(), Some(33));
    }

    #[test]
    fn kernel_gauges_snapshot() {
        // exercise one dispatched GEMM so the counters are live, then
        // check the stats snapshot carries the kernel section
        use crate::linalg::igemm::MatI8;
        use crate::quant::pack4::PackedI4;
        let xq = MatI8::from_vec(1, 16, vec![1i8; 16]);
        let wq = MatI8::from_vec(2, 16, vec![2i8; 32]);
        let _ = crate::kernels::gemm_per_channel_packed(
            &xq,
            &[0.5],
            &PackedI4::pack(&wq),
            &[0.25, 0.25],
        );
        let m = Metrics::new();
        let j = m.snapshot_json();
        let kj = j.get("kernels").unwrap();
        assert!(!kj.get("backend").unwrap().as_str().unwrap().is_empty());
        let tile = kj.get("tile").unwrap().as_str().unwrap().to_string();
        assert_eq!(tile.split('x').count(), 3, "tile label {tile}");
        assert!(kj.get("per_channel_calls").unwrap().as_usize().unwrap() >= 1);
        assert!(kj.get("fused_gemm_calls").is_some());
        assert!(kj.get("prologue_rows").is_some());
    }

    #[test]
    fn pool_gauges_snapshot() {
        let m = Metrics::new();
        let s = PoolStats {
            blocks_total: 64,
            blocks_free: 40,
            blocks_cached: 8,
            blocks_active: 16,
            prefix_query_tokens: 100,
            prefix_hit_tokens: 25,
            prefix_queries: 5,
            cow_copies: 3,
            lazy_tail_shares: 2,
            prefix_partial_hits: 1,
            ..Default::default()
        };
        m.update_pool(&s);
        // peak is a high-water mark: a lower reading must not clear it
        m.update_pool(&PoolStats { blocks_active: 4, ..s });
        let j = m.snapshot_json();
        let pool = j.get("kv_pool").unwrap();
        assert_eq!(pool.get("blocks_total").unwrap().as_usize(), Some(64));
        assert_eq!(pool.get("blocks_used").unwrap().as_usize(), Some(4));
        assert_eq!(pool.get("blocks_peak").unwrap().as_usize(), Some(16));
        assert_eq!(pool.get("cow_copies").unwrap().as_usize(), Some(3));
        assert_eq!(pool.get("lazy_tail_shares").unwrap().as_usize(), Some(2));
        assert_eq!(pool.get("prefix_partial_hits").unwrap().as_usize(), Some(1));
        let rate = pool.get("prefix_hit_rate").unwrap().as_f64().unwrap();
        assert!((rate - 0.25).abs() < 1e-9);
    }
}
