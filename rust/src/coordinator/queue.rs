//! Bounded request queue with admission control (the backpressure point).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use super::request::{Request, SubmitError};

/// Poison-recovering lock/wait helpers.  The queue pairs its Mutex with
/// a Condvar, so it stays on `std::sync` directly (loom does not model
/// `wait_timeout`) instead of the `util::sync` shim; recovery semantics
/// match [`crate::obs::lock_recover`]: a producer that panicked between
/// `insert` and `notify` leaves at worst one already-counted request,
/// which the scheduler's drain loop still retires — strictly better
/// than poisoning every subsequent submit.
fn lock_inner(m: &Mutex<Inner>) -> MutexGuard<'_, Inner> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn wait_on<'a>(
    cv: &Condvar,
    g: MutexGuard<'a, Inner>,
    wait: Duration,
) -> MutexGuard<'a, Inner> {
    let (g2, _timeout) = cv
        .wait_timeout(g, wait)
        .unwrap_or_else(PoisonError::into_inner);
    g2
}

/// MPMC bounded priority queue; producers fail fast when full (shed
/// load rather than queue unboundedly — the serving-side backpressure
/// policy).  Higher [`Request::priority`] pops first; within a priority
/// class order stays FIFO (stable insertion).
///
/// # Examples
///
/// ```
/// use rrs::coordinator::{Request, RequestOptions, RequestQueue};
/// use std::time::Duration;
///
/// let q = RequestQueue::new(2);
/// let (tx, _rx) = std::sync::mpsc::channel();
/// q.submit(Request::new(1, vec![1, 2], RequestOptions::default(), tx))
///     .unwrap();
/// let batch = q.pop_batch(8, Duration::ZERO);
/// assert_eq!(batch.len(), 1);
/// assert_eq!(batch[0].id, 1);
/// ```
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
}

struct Inner {
    items: VecDeque<Request>,
    closed: bool,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking submit; `Err(QueueFull)` = backpressure.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        let mut g = lock_inner(&self.inner);
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(SubmitError::QueueFull);
        }
        // stable priority insert: after the last request with priority
        // >= the new one, so equal priorities stay FIFO
        let pos = g
            .items
            .iter()
            .position(|r| r.priority < req.priority)
            .unwrap_or(g.items.len());
        g.items.insert(pos, req);
        self.cv.notify_one();
        Ok(())
    }

    /// Pop up to `max` requests; blocks up to `wait` for the first one.
    /// Returns an empty vec on timeout or closure-with-empty-queue.
    pub fn pop_batch(&self, max: usize, wait: Duration) -> Vec<Request> {
        let mut g = lock_inner(&self.inner);
        if g.items.is_empty() && !g.closed {
            g = wait_on(&self.cv, g, wait);
        }
        let take = g.items.len().min(max);
        g.items.drain(..take).collect()
    }

    /// Pop up to `max` requests from the front while `admit` approves
    /// them; blocks up to `wait` for the first item.  Stops at the first
    /// non-admissible request *leaving it queued*, so capacity gating
    /// (paged KV pools) preserves FIFO order instead of starving large
    /// prompts.
    pub fn pop_batch_if<F: FnMut(&Request) -> bool>(
        &self,
        max: usize,
        wait: Duration,
        mut admit: F,
    ) -> Vec<Request> {
        let mut g = lock_inner(&self.inner);
        if g.items.is_empty() && !g.closed && !wait.is_zero() {
            g = wait_on(&self.cv, g, wait);
        }
        let mut out = Vec::new();
        while out.len() < max {
            match g.items.front() {
                Some(r) if admit(r) => {}
                _ => break,
            }
            if let Some(r) = g.items.pop_front() {
                out.push(r);
            }
        }
        out
    }

    /// Pop everything available without blocking.
    pub fn drain_now(&self, max: usize) -> Vec<Request> {
        let mut g = lock_inner(&self.inner);
        let take = g.items.len().min(max);
        g.items.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        lock_inner(&self.inner).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        let mut g = lock_inner(&self.inner);
        g.closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_inner(&self.inner).closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::request::{Event, RequestOptions};
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> (Request, mpsc::Receiver<Event>) {
        req_prio(id, 0)
    }

    fn req_prio(id: u64, priority: i32) -> (Request, mpsc::Receiver<Event>) {
        let (tx, rx) = mpsc::channel();
        let opts = RequestOptions {
            max_new_tokens: 4,
            priority,
            ..Default::default()
        };
        (Request::new(id, vec![1, 2], opts, tx), rx)
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            rxs.push(rx);
        }
        let batch = q.pop_batch(10, Duration::from_millis(1));
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn priority_pops_first_fifo_within_class() {
        let q = RequestQueue::new(8);
        let mut keep = Vec::new();
        for (id, prio) in [(0, 0), (1, 0), (2, 5), (3, 5), (4, -1)] {
            let (r, rx) = req_prio(id, prio);
            q.submit(r).unwrap();
            keep.push(rx);
        }
        let got = q.pop_batch(10, Duration::from_millis(1));
        assert_eq!(
            got.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![2, 3, 0, 1, 4]
        );
    }

    #[test]
    fn backpressure_when_full() {
        let q = RequestQueue::new(2);
        let (r0, _k0) = req(0);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.submit(r0).unwrap();
        q.submit(r1).unwrap();
        assert_eq!(q.submit(r2).unwrap_err(), SubmitError::QueueFull);
    }

    #[test]
    fn closed_rejects() {
        let q = RequestQueue::new(2);
        q.close();
        let (r, _keep) = req(0);
        assert_eq!(q.submit(r).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn pop_batch_caps_at_max() {
        let q = RequestQueue::new(8);
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            keep.push(rx);
        }
        assert_eq!(q.pop_batch(2, Duration::from_millis(1)).len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn pop_batch_if_stops_at_first_rejection_preserving_fifo() {
        let q = RequestQueue::new(8);
        let mut keep = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i);
            q.submit(r).unwrap();
            keep.push(rx);
        }
        // admit ids < 2 only: pops 0 and 1, leaves 2 and 3 queued
        let got = q.pop_batch_if(10, Duration::from_millis(1), |r| r.id < 2);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(q.len(), 2);
        // head is still 2 (FIFO preserved)
        let rest = q.pop_batch(10, Duration::from_millis(1));
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn timeout_returns_empty() {
        let q = RequestQueue::new(2);
        let t0 = Instant::now();
        let got = q.pop_batch(4, Duration::from_millis(30));
        assert!(got.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }
}
