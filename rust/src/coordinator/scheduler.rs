//! Continuous-batching scheduler + the public [`Coordinator`] handle.
//!
//! One worker thread owns the engine.  Each loop iteration:
//!   1. **admit** — while the active set has room *and the backend's KV
//!      capacity gate passes*, pop waiting requests (preempted ones
//!      first), prefill their prompts into fresh sequences.  Paged
//!      backends gate prefix-aware: a prompt is charged only for its
//!      unshared suffix blocks, and the reservation inside
//!      `try_prefill` re-checks jointly so same-round admissions cannot
//!      oversubscribe the pool.  Requests whose client vanished or
//!      whose deadline already passed are dropped here, before any
//!      prefill compute is spent on them;
//!   2. **reserve** — every active sequence must be able to grow by one
//!      token; when the paged pool is exhausted the least-important
//!      lane is preempted back to the queue: lowest priority first,
//!      then (deadline-aware) the lane with the most slack, then the
//!      youngest (recompute-style: its blocks are released and its
//!      progress is re-prefilled on re-admission);
//!   3. **decode** — one batched step over all active sequences, then
//!      one vectorized sampling pass over the batch's logit rows
//!      ([`super::sampling::sample_lanes`], threaded).  Every sampled
//!      token is streamed to its client as an [`Event::Token`] frame
//!      immediately;
//!   4. **retire** — sequences hitting a stop id / stop sequence /
//!      max_new_tokens / KV capacity / their deadline — or whose client
//!      disconnected — get their terminal [`Event::Done`] sent and
//!      their cache released.
//!
//! Requests join and leave the running batch at *step* granularity:
//! admission happens every loop iteration (bounded by
//! `admit_per_step`), and retirement both before and after each decode
//! step, so a short request never waits for the batch to drain.
//!
//! Prefill happens inside the loop (chunked admission), so short decode
//! steps are never starved by long prompts beyond one admission slot —
//! the paper's serving context (prefill = compute-bound A4W4 GEMMs,
//! decode = memory-bound) maps onto exactly this split.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::model::sampler::Sampling;
use crate::obs::attrib::{self, Breakdown, Phase};
use crate::obs::trace::SpanKind;
use crate::obs::{profile, watchdog};

use super::engine_iface::{EngineError, ServeEngine};
use super::metrics::Metrics;
use super::queue::RequestQueue;
use super::request::{
    wait_done, Event, FinishReason, Request, RequestId, RequestOptions, Response,
    StreamHandle, SubmitError,
};
use super::sampling::{self, SamplerState, SamplingParams};

/// Scheduler policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently-active sequences (decode batch bound).
    pub max_batch: usize,
    /// Max waiting requests before submissions are rejected.
    pub queue_capacity: usize,
    /// How long the worker sleeps waiting for work when idle.
    pub idle_wait: Duration,
    /// Max new requests admitted (prefilled) per loop iteration.
    pub admit_per_step: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            queue_capacity: 64,
            idle_wait: Duration::from_millis(2),
            admit_per_step: 2,
        }
    }
}

struct Active<S> {
    id: RequestId,
    seq: S,
    /// Original prompt (kept for recompute-style preemption).
    prompt: Vec<u32>,
    generated: Vec<u32>,
    next_token: u32,
    max_new_tokens: usize,
    sampler: SamplerState,
    priority: i32,
    deadline: Option<Instant>,
    cancel: Arc<AtomicBool>,
    /// The reply receiver was dropped: stream no further, retire soon.
    disconnected: bool,
    submitted_at: Instant,
    /// When this request's latest token landed (inter-token latency).
    last_token_at: Instant,
    queue_ms: f32,
    prefill_ms: f32,
    /// Per-phase wall-time attribution accumulated across decode rounds
    /// (preserved over preemption; finalized at retire).
    attrib: Breakdown,
    reply: mpsc::Sender<Event>,
}

/// Milliseconds (f32) to whole microseconds for trace spans.
fn ms_us(ms: f32) -> u64 {
    (ms.max(0.0) * 1e3) as u64
}

/// A request waiting for (re-)admission: fresh from the public queue, or
/// preempted with the tokens it had already generated.
struct Pending {
    req: Request,
    generated: Vec<u32>,
    /// Prompt to prefill on (re-)admission: original + generated so far.
    /// Cached because the capacity gate consults it every scheduler loop.
    full_prompt: Vec<u32>,
    /// Queue latency measured at first admission (preserved on resume).
    queue_ms: Option<f32>,
    /// Prefill time spent before preemption (re-prefill adds to it).
    prior_prefill_ms: f32,
    /// Preserved sampler state: a resumed request continues the exact
    /// RNG stream and penalty counts it was preempted with.
    sampler: Option<SamplerState>,
    /// Attribution carried across preemption.
    attrib: Breakdown,
    /// `try_prefill` refusals observed while nothing else was resident.
    /// With an empty active set a refusal cannot be capacity pressure
    /// from other lanes, so repeated ones mean the engine can never
    /// prefill this request; the admission loop aborts it instead of
    /// spinning on it forever.
    empty_refusals: u32,
}

/// Empty-pool `try_prefill` refusals tolerated before aborting.
const MAX_EMPTY_REFUSALS: u32 = 3;

impl Pending {
    fn fresh(req: Request) -> Pending {
        let full_prompt = req.prompt.clone();
        Pending {
            req,
            generated: Vec::new(),
            full_prompt,
            queue_ms: None,
            prior_prefill_ms: 0.0,
            sampler: None,
            attrib: Breakdown::default(),
            empty_refusals: 0,
        }
    }

    fn resumed<S>(a: Active<S>) -> Pending {
        let mut full_prompt = a.prompt.clone();
        full_prompt.extend_from_slice(&a.generated);
        Pending {
            req: Request {
                id: a.id,
                prompt: a.prompt,
                max_new_tokens: a.max_new_tokens,
                params: a.sampler.params().clone(),
                priority: a.priority,
                deadline: a.deadline,
                cancel: a.cancel,
                submitted_at: a.submitted_at,
                reply: a.reply,
            },
            generated: a.generated,
            full_prompt,
            queue_ms: Some(a.queue_ms),
            prior_prefill_ms: a.prefill_ms,
            sampler: Some(a.sampler),
            attrib: a.attrib,
            // it prefilled successfully before, so refusal counting
            // restarts on resume
            empty_refusals: 0,
        }
    }

    /// `now` is the scheduler round's hoisted timestamp, so deadline
    /// drops, TTFT, and ITL stamps stay mutually consistent.
    fn dead_reason(&self, now: Instant) -> Option<FinishReason> {
        // ORDERING: cancel is a monotonic one-way flag; a stale Relaxed
        // read only delays the cancellation by one scheduler round
        if self.req.cancel.load(Ordering::Relaxed) {
            Some(FinishReason::Cancelled)
        } else if self.req.deadline.map(|d| now >= d).unwrap_or(false) {
            Some(FinishReason::Deadline)
        } else {
            None
        }
    }
}

/// Public handle: submit requests, read metrics, shut down.
pub struct Coordinator {
    queue: Arc<RequestQueue>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    worker: Option<JoinHandle<()>>,
    max_seq: usize,
}

impl Coordinator {
    /// Start the worker thread over an engine backend.  Thread-spawn
    /// failure (fd/thread exhaustion) surfaces as a typed error rather
    /// than a panic so callers embedding the coordinator can shed load.
    pub fn start<E: ServeEngine + 'static>(
        engine: E,
        cfg: SchedulerConfig,
    ) -> std::io::Result<Coordinator> {
        let queue = Arc::new(RequestQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        // continuous profiler: spawns its sweep thread iff RRS_PROF_HZ
        // is set to a positive rate (no-op otherwise)
        profile::ensure_env_started();
        let max_seq = engine.max_seq();
        let q2 = queue.clone();
        let m2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("rrs-scheduler".into())
            .spawn(move || run_loop(engine, cfg, q2, m2))?;
        Ok(Coordinator {
            queue,
            metrics,
            next_id: AtomicU64::new(1),
            worker: Some(worker),
            max_seq,
        })
    }

    /// Submit with the full option set; returns a streaming handle
    /// (token events as produced, then the terminal response).
    pub fn submit_opts(
        &self,
        prompt: Vec<u32>,
        opts: RequestOptions,
    ) -> Result<StreamHandle, SubmitError> {
        if prompt.is_empty() || prompt.len() + opts.max_new_tokens > self.max_seq {
            return Err(SubmitError::PromptTooLong {
                prompt: prompt.len() + opts.max_new_tokens,
                max: self.max_seq,
            });
        }
        if let Err(e) = opts.params.validate() {
            return Err(SubmitError::InvalidParams(e));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let prompt_len = prompt.len();
        let (tx, rx) = mpsc::channel();
        let req = Request::new(id, prompt, opts, tx);
        let cancel = req.cancel.clone();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match self.queue.submit(req) {
            Ok(()) => {
                self.metrics
                    .trace
                    .instant(id, SpanKind::Enqueue, prompt_len as u64);
                Ok(StreamHandle { id, events: rx, cancel })
            }
            Err(e) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit a generation request; returns (id, receiver) or
    /// backpressure.  Legacy three-mode surface over [`Self::submit_opts`].
    pub fn submit(
        &self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        sampling: Sampling,
        stop_token: Option<u32>,
    ) -> Result<(RequestId, mpsc::Receiver<Event>), SubmitError> {
        let mut params: SamplingParams = sampling.into();
        if let Some(s) = stop_token {
            params.stop_token_ids.push(s);
        }
        let h = self.submit_opts(
            prompt,
            RequestOptions { max_new_tokens, params, ..Default::default() },
        )?;
        Ok((h.id, h.events))
    }

    /// Convenience: submit and block until the response arrives.
    pub fn generate(
        &self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        sampling: Sampling,
        stop_token: Option<u32>,
    ) -> Result<Response, SubmitError> {
        let (_, rx) = self.submit(prompt, max_new_tokens, sampling, stop_token)?;
        wait_done(&rx)
    }

    /// Convenience: full-option submit and block until done.
    pub fn generate_opts(
        &self,
        prompt: Vec<u32>,
        opts: RequestOptions,
    ) -> Result<Response, SubmitError> {
        self.submit_opts(prompt, opts)?.wait()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Stop accepting work and join the worker (in-flight requests finish).
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn run_loop<E: ServeEngine>(
    engine: E,
    cfg: SchedulerConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
) {
    let mut active: Vec<Active<E::Seq>> = Vec::new();
    let mut preempted: VecDeque<Pending> = VecDeque::new();
    loop {
        // one timestamp per round: deadline checks, victim slack, and
        // the pre-step retire all read the same clock
        let round_now = Instant::now();
        // export watchdog raise/clear edges as instant trace events so
        // alerts land on the same timeline as the requests they affected
        for (tid, raised) in watchdog::drain_transitions() {
            let kind =
                if raised { SpanKind::AlertRaise } else { SpanKind::AlertClear };
            metrics.trace.instant(tid, kind, 0);
        }
        // drop dead work at the head of the resume queue (client gone or
        // deadline passed) before spending any capacity on it
        while let Some(r) =
            preempted.front().and_then(|p| p.dead_reason(round_now))
        {
            if let Some(p) = preempted.pop_front() {
                finish_waiting(p, r, &metrics);
            }
        }
        // 1. admit — preempted requests first (they hold progress), then
        // the public queue; both gated on the backend's capacity check
        let mut room = cfg.max_batch.saturating_sub(active.len());
        let mut incoming: Vec<Pending> = Vec::new();
        while room > 0 {
            let admissible = preempted
                .front()
                .is_some_and(|p| engine.can_admit(&p.full_prompt));
            if !admissible {
                break;
            }
            if let Some(p) = preempted.pop_front() {
                incoming.push(p);
                room -= 1;
            }
        }
        if room > 0 && preempted.is_empty() {
            let take = room.min(cfg.admit_per_step);
            let wait = if active.is_empty() && incoming.is_empty() {
                cfg.idle_wait
            } else {
                Duration::ZERO
            };
            incoming.extend(
                queue
                    .pop_batch_if(take, wait, |r| engine.can_admit(&r.prompt))
                    .into_iter()
                    .map(Pending::fresh),
            );
        }
        // fully stalled: with nothing active the pool is at its emptiest,
        // so a capacity refusal here means the request can never fit —
        // abort it rather than wedging the queue behind it
        if active.is_empty() && incoming.is_empty() {
            let head_stuck = preempted
                .front()
                .is_some_and(|p| !engine.can_admit(&p.full_prompt));
            if head_stuck {
                if let Some(p) = preempted.pop_front() {
                    finish_waiting(p, FinishReason::Aborted, &metrics);
                }
            } else if preempted.is_empty() {
                for req in queue.pop_batch(1, cfg.idle_wait) {
                    if engine.can_admit(&req.prompt) {
                        incoming.push(Pending::fresh(req));
                    } else {
                        finish_waiting(
                            Pending::fresh(req),
                            FinishReason::Aborted,
                            &metrics,
                        );
                    }
                }
            }
        }

        // prefill admitted requests
        for p in incoming {
            if let Some(r) = p.dead_reason(round_now) {
                finish_waiting(p, r, &metrics);
                continue;
            }
            let Pending {
                req,
                mut generated,
                full_prompt,
                queue_ms,
                prior_prefill_ms,
                sampler,
                attrib: carried_attrib,
                empty_refusals,
            } = p;
            let measured_queue_ms = queue_ms
                .unwrap_or_else(|| req.submitted_at.elapsed().as_secs_f32() * 1e3);
            let t0 = Instant::now();
            let mut seq = engine.new_seq();
            // joint-capacity re-check at reservation time: the admissions
            // ahead of this one in the same round consumed blocks the
            // can_admit gate did not see, so an individually-admissible
            // request may no longer fit — try_prefill reserves (or
            // refuses) atomically under the pool lock, and a refused
            // request is deferred instead of hitting an exhaustion panic
            let prefilled = {
                let _phase = attrib::phase_scope(Phase::Prefill);
                engine.try_prefill(&mut seq, &full_prompt)
            };
            let Some(logits) = prefilled else {
                let again = Pending {
                    req,
                    generated,
                    full_prompt,
                    queue_ms,
                    prior_prefill_ms,
                    sampler,
                    attrib: carried_attrib,
                    empty_refusals: empty_refusals
                        + u32::from(active.is_empty()),
                };
                // refusals with an empty active set mean the engine can
                // never take this request (a capacity refusal would have
                // failed can_admit instead): abort it after a few rounds
                // rather than bouncing it through admission forever
                if again.empty_refusals >= MAX_EMPTY_REFUSALS {
                    finish_waiting(again, FinishReason::Aborted, &metrics);
                } else {
                    preempted.push_back(again);
                }
                continue;
            };
            let queue_ms = measured_queue_ms;
            metrics
                .prefill_tokens
                .fetch_add(full_prompt.len() as u64, Ordering::Relaxed);
            // one post-prefill timestamp covers the prefill span, TTFT,
            // and this lane's ITL base, so the stamps agree exactly
            let t1 = Instant::now();
            let round_prefill_ms =
                t1.saturating_duration_since(t0).as_secs_f32() * 1e3;
            let prefill_ms = prior_prefill_ms + round_prefill_ms;
            metrics.observe_prefill(round_prefill_ms);
            metrics
                .trace
                .span(req.id, SpanKind::Admit, ms_us(measured_queue_ms), 0);
            metrics.trace.span(
                req.id,
                SpanKind::Prefill,
                ms_us(round_prefill_ms),
                full_prompt.len() as u64,
            );
            // fresh admissions build their sampler here (prompt counts
            // seeded); resumed ones continue their preserved state, so
            // the token stream is identical to the uninterrupted run
            let mut sampler = sampler.unwrap_or_else(|| {
                SamplerState::new(req.params.clone(), req.id, &req.prompt)
            });
            let next = sampler.sample(&logits);
            // TTFT only on first admission: a re-prefilled (preempted)
            // request already delivered its first token long ago
            if generated.is_empty() {
                let ttft_ms =
                    t1.saturating_duration_since(req.submitted_at).as_secs_f32() * 1e3;
                metrics.observe_ttft(ttft_ms);
            }
            let index = generated.len();
            generated.push(next);
            let disconnected = send_token(&metrics, &req.reply, req.id, index, next);
            active.push(Active {
                id: req.id,
                seq,
                prompt: req.prompt,
                generated,
                next_token: next,
                max_new_tokens: req.max_new_tokens,
                sampler,
                priority: req.priority,
                deadline: req.deadline,
                cancel: req.cancel,
                disconnected,
                submitted_at: req.submitted_at,
                last_token_at: t1,
                queue_ms,
                prefill_ms,
                attrib: carried_attrib,
                reply: req.reply,
            });
        }

        if active.is_empty() {
            // keep pool/residency gauges honest while idle, so a client
            // watching `stats` sees freed blocks without new traffic
            refresh_gauges(&engine, &metrics);
            if preempted.is_empty() && queue.is_closed() && queue.is_empty() {
                return;
            }
            continue;
        }

        // 2. retire finished BEFORE stepping (first token may already stop)
        retire(&engine, &mut active, &metrics, round_now);
        if active.is_empty() {
            refresh_gauges(&engine, &metrics);
            continue;
        }

        // 2b. reserve — every sequence must be able to take one more
        // token; on exhaustion preempt the least-important lane until
        // the step fits (KvPool::reserve only tops a table up to the
        // next block, so re-checking already-reserved lanes is free)
        loop {
            let mut short = false;
            for a in active.iter_mut() {
                if !engine.reserve_decode(&mut a.seq) {
                    short = true;
                    break;
                }
            }
            if !short || active.is_empty() {
                break;
            }
            let mut victim = active.remove(victim_index(&active, round_now));
            engine.release_seq(&mut victim.seq);
            metrics.preemptions.fetch_add(1, Ordering::Relaxed);
            metrics
                .trace
                .instant(victim.id, SpanKind::Preempt, victim.generated.len() as u64);
            preempted.push_front(Pending::resumed(victim));
        }
        if active.is_empty() {
            continue;
        }

        // 3. one batched decode step.  Drain the thread's phase
        // accumulator first: scopes fired during this round's prefills
        // are already counted per-request via prefill_ms and must not
        // leak into the decode-step attribution below.
        let _ = attrib::step_take();
        let mut pairs: Vec<(&mut E::Seq, u32)> = active
            .iter_mut()
            .map(|a| {
                let t = a.next_token;
                (&mut a.seq, t)
            })
            .collect();
        let logits = match engine.decode(&mut pairs) {
            Ok(l) => l,
            Err(e) => {
                // strict protocol reply on a failed batched step: every
                // lane is released and its client gets a terminal
                // `Aborted` response instead of a silently dead stream
                drop(pairs);
                abort_active(&engine, &mut active, &metrics, &e);
                refresh_gauges(&engine, &metrics);
                continue;
            }
        };
        drop(pairs);
        metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        let step_done = Instant::now();
        // one vectorized sampling pass over the batch's logit rows:
        // each lane applies its own penalties/top-k/top-p from its own
        // RNG stream, threaded across the batch
        let tokens: Vec<u32> = {
            let mut lanes: Vec<sampling::Lane> = active
                .iter_mut()
                .enumerate()
                .map(|(i, a)| sampling::Lane::new(&mut a.sampler, logits.row(i)))
                .collect();
            sampling::sample_lanes(&mut lanes);
            lanes.iter().map(|l| l.token()).collect()
        };
        // this round's instrumented step phases (kv gather/scatter,
        // gemm, sampling): each participating lane waited the whole
        // batched step, so each lane is attributed the full step totals
        let step_us = attrib::step_take();
        // sampled once per batched step, not per row: one step = one span
        // per participating request when the sampler fires
        let step_traced = metrics.step_trace.hit();
        for (i, a) in active.iter_mut().enumerate() {
            let tok = tokens[i];
            let index = a.generated.len();
            a.generated.push(tok);
            a.next_token = tok;
            if !a.disconnected {
                a.disconnected = send_token(&metrics, &a.reply, a.id, index, tok);
            }
            let itl_ms = step_done
                .saturating_duration_since(a.last_token_at)
                .as_secs_f32()
                * 1e3;
            a.last_token_at = step_done;
            metrics.observe_itl(itl_ms);
            let mut measured_us = 0u64;
            for p in [Phase::KvGather, Phase::KvScatter, Phase::Gemm, Phase::Sampling]
            {
                let us = step_us[p as usize];
                a.attrib.add(p, us);
                measured_us += us;
            }
            // the remainder of this lane's inter-token interval was
            // spent outside any instrumented phase (attention
            // bookkeeping, other lanes' admissions, loop overhead)
            a.attrib
                .add(Phase::DecodeOther, ms_us(itl_ms).saturating_sub(measured_us));
            if step_traced {
                metrics.trace.span(
                    a.id,
                    SpanKind::DecodeStep,
                    ms_us(itl_ms),
                    a.generated.len() as u64,
                );
            }
        }
        refresh_gauges(&engine, &metrics);
        retire(&engine, &mut active, &metrics, step_done);
    }
}

/// Stream one token frame; returns `true` when the client is gone.
fn send_token(
    metrics: &Metrics,
    reply: &mpsc::Sender<Event>,
    id: RequestId,
    index: usize,
    token: u32,
) -> bool {
    match reply.send(Event::Token { id, index, token }) {
        Ok(()) => {
            metrics.tokens_streamed.fetch_add(1, Ordering::Relaxed);
            false
        }
        Err(_) => true,
    }
}

fn refresh_gauges<E: ServeEngine>(engine: &E, metrics: &Metrics) {
    if let Some(ps) = engine.pool_stats() {
        metrics.update_pool(&ps);
    }
    if let Some(rs) = engine.residency_stats() {
        metrics.update_residency(&rs);
    }
}

/// Preemption victim: lowest priority loses first; within a priority
/// class the lane with the most deadline slack (deadline-less =
/// infinite) is safest to pause; ties fall to the youngest lane, which
/// has the least progress to recompute.
fn victim_index<S>(active: &[Active<S>], now: Instant) -> usize {
    let slack = |x: &Active<S>| {
        x.deadline
            .map(|d| d.saturating_duration_since(now).as_micros() as u64)
            .unwrap_or(u64::MAX)
    };
    let mut best = active.len() - 1; // youngest (admission order kept)
    for i in (0..active.len()).rev() {
        let (a, b) = (&active[i], &active[best]);
        if a.priority < b.priority
            || (a.priority == b.priority && slack(a) > slack(b))
        {
            best = i;
        }
    }
    best
}

/// Terminal accounting for a request that never (re-)entered the active
/// set: aborted while waiting, cancelled, or past its deadline.
fn finish_waiting(p: Pending, reason: FinishReason, metrics: &Metrics) {
    let ctr = match reason {
        FinishReason::Cancelled => &metrics.cancelled,
        FinishReason::Deadline => &metrics.deadline_missed,
        _ => &metrics.aborted,
    };
    ctr.fetch_add(1, Ordering::Relaxed);
    metrics
        .trace
        .instant(p.req.id, SpanKind::Abort, p.generated.len() as u64);
    let total_ms = p.req.submitted_at.elapsed().as_secs_f32() * 1e3;
    let queue_ms = p.queue_ms.unwrap_or(total_ms);
    // attribution for a request that died waiting: whatever it
    // accumulated before preemption, queue/prefill finalized here
    let mut b = p.attrib;
    b.set(Phase::Queue, ms_us(queue_ms));
    b.set(Phase::Prefill, ms_us(p.prior_prefill_ms));
    b.add(Phase::StreamWrite, attrib::take_stream_write(p.req.id));
    attrib::finish_request(attrib::RequestAttrib {
        id: p.req.id,
        total_us: ms_us(total_ms),
        tokens: p.generated.len() as u64,
        finish: reason.as_str(),
        breakdown: b,
    });
    let _ = p.req.reply.send(Event::Done(Response {
        id: p.req.id,
        tokens: p.generated,
        queue_ms,
        prefill_ms: p.prior_prefill_ms,
        decode_ms: 0.0,
        total_ms,
        finish_reason: reason,
    }));
}

/// Abort every active lane after the engine reported a typed decode
/// error (e.g. a PJRT graph failure): release the sequences, account
/// the aborts, and send each client its terminal response.
fn abort_active<E: ServeEngine>(
    engine: &E,
    active: &mut Vec<Active<E::Seq>>,
    metrics: &Metrics,
    err: &EngineError,
) {
    eprintln!(
        "rrs-scheduler: decode step failed ({err}); aborting {} lane(s)",
        active.len()
    );
    for mut a in active.drain(..) {
        engine.release_seq(&mut a.seq);
        metrics.aborted.fetch_add(1, Ordering::Relaxed);
        metrics
            .trace
            .instant(a.id, SpanKind::Abort, a.generated.len() as u64);
        let total_ms = a.submitted_at.elapsed().as_secs_f32() * 1e3;
        let decode_ms = (total_ms - a.queue_ms - a.prefill_ms).max(0.0);
        a.attrib.set(Phase::Queue, ms_us(a.queue_ms));
        a.attrib.set(Phase::Prefill, ms_us(a.prefill_ms));
        a.attrib
            .add(Phase::StreamWrite, attrib::take_stream_write(a.id));
        attrib::finish_request(attrib::RequestAttrib {
            id: a.id,
            total_us: ms_us(total_ms),
            tokens: a.generated.len() as u64,
            finish: FinishReason::Aborted.as_str(),
            breakdown: a.attrib,
        });
        let _ = a.reply.send(Event::Done(Response {
            id: a.id,
            tokens: a.generated,
            queue_ms: a.queue_ms,
            prefill_ms: a.prefill_ms,
            decode_ms,
            total_ms,
            finish_reason: FinishReason::Aborted,
        }));
    }
}

fn finishes<E: ServeEngine>(
    engine: &E,
    a: &Active<E::Seq>,
    now: Instant,
) -> Option<FinishReason> {
    // ORDERING: cancel is a monotonic one-way flag; a stale Relaxed
    // read only delays retirement by one decode step
    if a.disconnected || a.cancel.load(Ordering::Relaxed) {
        Some(FinishReason::Cancelled)
    } else if a.deadline.map(|d| now >= d).unwrap_or(false) {
        Some(FinishReason::Deadline)
    } else if let Some(r) = a.sampler.stop_hit() {
        // stop ids / stop sequences win the race against max_tokens:
        // the stop is checked first at the boundary step
        Some(r)
    } else if a.generated.len() >= a.max_new_tokens {
        Some(FinishReason::MaxTokens)
    } else if engine.seq_len(&a.seq) + 1 >= engine.max_seq() {
        Some(FinishReason::Truncated)
    } else {
        None
    }
}

fn retire<E: ServeEngine>(
    engine: &E,
    active: &mut Vec<Active<E::Seq>>,
    metrics: &Metrics,
    now: Instant,
) {
    let mut i = 0;
    while i < active.len() {
        let Some(reason) = finishes(engine, &active[i], now) else {
            i += 1;
            continue;
        };
        // plain remove keeps `active` in admission order, which the
        // preemption pass relies on for its youngest-lane tie-break
        let mut a = active.remove(i);
        engine.release_seq(&mut a.seq);
        let total_ms =
            now.saturating_duration_since(a.submitted_at).as_secs_f32() * 1e3;
        let decode_ms = (total_ms - a.queue_ms - a.prefill_ms).max(0.0);
        // finalize the attribution: queue/prefill are measured
        // per-request (overwrite), stream writes drain from the server's
        // ledger, decode phases accumulated across the rounds above
        a.attrib.set(Phase::Queue, ms_us(a.queue_ms));
        a.attrib.set(Phase::Prefill, ms_us(a.prefill_ms));
        a.attrib
            .add(Phase::StreamWrite, attrib::take_stream_write(a.id));
        for p in attrib::ALL_PHASES {
            let us = a.attrib.get(p);
            if us > 0 {
                metrics.trace.span(a.id, SpanKind::Phase(p), us, 0);
            }
        }
        attrib::finish_request(attrib::RequestAttrib {
            id: a.id,
            total_us: ms_us(total_ms),
            tokens: a.generated.len() as u64,
            finish: reason.as_str(),
            breakdown: a.attrib,
        });
        match reason {
            FinishReason::Cancelled => {
                metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                metrics
                    .trace
                    .instant(a.id, SpanKind::Abort, a.generated.len() as u64);
            }
            FinishReason::Deadline => {
                metrics.deadline_missed.fetch_add(1, Ordering::Relaxed);
                metrics
                    .trace
                    .instant(a.id, SpanKind::Abort, a.generated.len() as u64);
            }
            _ => {
                metrics.observe_completion(total_ms, a.queue_ms, a.generated.len());
                metrics
                    .trace
                    .instant(a.id, SpanKind::Finish, a.generated.len() as u64);
            }
        }
        let _ = a.reply.send(Event::Done(Response {
            id: a.id,
            tokens: a.generated,
            queue_ms: a.queue_ms,
            prefill_ms: a.prefill_ms,
            decode_ms,
            total_ms,
            finish_reason: reason,
        }));
    }
}
