//! L3 serving coordinator — the system layer that makes RRS deployable:
//! request queue with admission control, dynamic batcher, continuous
//! prefill/decode scheduler over INT4 KV caches, worker thread, TCP
//! front-end and metrics.
//!
//! Built on std threads + channels (tokio is not vendored in this
//! environment); the design mirrors a vLLM-style router: frontends submit
//! [`request::Request`]s into a bounded [`queue::RequestQueue`]; the
//! worker runs [`scheduler::Scheduler`], which admits waiting requests
//! into the active set (prefill) and steps all active sequences one token
//! per iteration (continuous batching), retiring finished sequences.
//!
//! Two engine backends serve the scheduler: the flat per-sequence cache
//! ([`RustServeEngine`]) and the paged INT4 KV pool
//! ([`crate::kvpool::PagedEngine`]) — the latter gates admission on block
//! availability, shares prompt-prefix blocks across requests, and is
//! preempted back to the queue when the pool runs dry.

pub mod engine_iface;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod server;

pub use crate::kvpool::{PagedEngine, PagedSeq, PoolStats};
pub use engine_iface::{RustServeEngine, ServeEngine};
pub use metrics::Metrics;
pub use queue::RequestQueue;
pub use request::{Request, RequestId, Response, SubmitError};
pub use scheduler::{Coordinator, SchedulerConfig};
