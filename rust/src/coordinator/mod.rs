//! L3 serving coordinator — the system layer that makes RRS deployable:
//! request queue with admission control, dynamic batcher, continuous
//! prefill/decode scheduler over INT4 KV caches, worker thread, TCP
//! front-end and metrics.
//!
//! Built on std threads + channels (tokio is not vendored in this
//! environment); the design mirrors a vLLM-style router: frontends submit
//! [`request::Request`]s into a bounded [`queue::RequestQueue`]; the
//! worker thread started by [`scheduler::Coordinator`] admits waiting
//! requests into the active set (prefill) and steps all active sequences
//! one token per iteration (continuous batching), retiring finished
//! sequences.  Requests join and leave the batch at step granularity;
//! every sampled token streams back immediately as a
//! [`request::Event::Token`] frame, and the per-request sampling suite
//! (top-k/top-p, penalties, stop sequences, logit bias, seeds —
//! [`sampling::SamplingParams`]) runs as one vectorized pass over the
//! batch's logit rows each step.
//!
//! Three engine backends serve the scheduler: the flat per-sequence
//! cache ([`RustServeEngine`]), the paged INT4 KV pool
//! ([`crate::kvpool::PagedEngine`]), and the AOT PJRT-graph backend
//! ([`crate::runtime::PagedPjrtEngine`]) running over the *same* pool.
//! Paged backends gate admission prefix-aware (a prompt is charged only
//! for its unshared suffix blocks), share prompt-prefix blocks across
//! requests — including partial-block tails via copy-on-write — and are
//! preempted back to the queue when the pool runs dry.

pub mod engine_iface;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod sampling;
pub mod scheduler;
pub mod server;

pub use crate::kvpool::{PagedEngine, PagedSeq, PoolStats};
pub use engine_iface::{EngineError, RustServeEngine, ServeEngine};
pub use metrics::Metrics;
pub use queue::RequestQueue;
pub use request::{
    Event, FinishReason, Request, RequestId, RequestOptions, Response,
    StreamHandle, SubmitError,
};
pub use sampling::{SamplerState, SamplingParams};
pub use scheduler::{Coordinator, SchedulerConfig};
