//! TCP front-end: newline-delimited JSON over a socket.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "arlo is", "max_tokens": 24, "temperature": 0.0}
//!   <- {"id": 1, "text": " red.", "tokens": 5, "total_ms": 12.3, ...}
//!   -> {"cmd": "metrics"}            <- metrics snapshot
//!   -> {"cmd": "metrics_prom"}       <- Prometheus text exposition 0.0.4
//!                                       (wrapped as {"content_type", "body"})
//!   -> {"cmd": "trace"}              <- Chrome trace_event document; add
//!                                       {"format": "jsonl"} for one event
//!                                       per line in "body"
//!   -> {"cmd": "shutdown"}           <- {"ok": true} and server exits
//!
//! Each connection gets a handler thread; generation responses block the
//! connection (clients pipeline by opening several connections — the
//! scheduler interleaves them via continuous batching).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::model::sampler::Sampling;
use crate::model::tokenizer;
use crate::util::json::{obj, Json};

use super::request::FinishReason;
use super::scheduler::Coordinator;

/// Serve until a `shutdown` command arrives.  Returns the bound port.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<u16> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    let stop = Arc::new(AtomicBool::new(false));
    eprintln!("rrs server listening on port {port}");
    for stream in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let coord = coordinator.clone();
        let stop2 = stop.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, coord, stop2);
        });
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(port)
}

fn handle_conn(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(&line, &coord, &stop);
        writer.write_all(reply.dump().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

/// One protocol line -> one JSON reply (exposed for tests).
pub fn handle_line(line: &str, coord: &Coordinator, stop: &AtomicBool) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return obj(vec![("error", format!("bad json: {e}").as_str().into())]),
    };
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        return match cmd {
            // "stats" is an alias: the snapshot includes the KV-pool
            // gauges (blocks used/cached/peak, prefix hit rate, ...)
            "metrics" | "stats" => coord.metrics.snapshot_json(),
            // Prometheus exposition rides the JSON protocol as a wrapped
            // body; an HTTP shim only needs to echo body with the given
            // content type
            "metrics_prom" => obj(vec![
                ("content_type", "text/plain; version=0.0.4".into()),
                (
                    "body",
                    Json::Str(crate::obs::prom::render(&coord.metrics)),
                ),
            ]),
            "trace" => {
                let jsonl = req.get("format").and_then(Json::as_str)
                    == Some("jsonl");
                if jsonl {
                    obj(vec![(
                        "body",
                        Json::Str(coord.metrics.trace.chrome_trace_jsonl()),
                    )])
                } else {
                    coord.metrics.trace.chrome_trace_json()
                }
            }
            "ping" => obj(vec![("ok", true.into())]),
            "shutdown" => {
                stop.store(true, Ordering::Relaxed);
                obj(vec![("ok", true.into())])
            }
            other => obj(vec![("error", format!("unknown cmd {other}").as_str().into())]),
        };
    }
    let Some(prompt) = req.get("prompt").and_then(Json::as_str) else {
        return obj(vec![("error", "missing 'prompt'".into())]);
    };
    let max_tokens = req
        .get("max_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(32);
    let temperature = req
        .get("temperature")
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as f32;
    let sampling = if temperature <= 0.0 {
        Sampling::Greedy
    } else {
        Sampling::Temperature(temperature)
    };
    let stop_token = req
        .get("stop")
        .and_then(Json::as_str)
        .and_then(|s| s.bytes().next())
        .map(|b| b as u32);
    match coord.generate(tokenizer::encode(prompt), max_tokens, sampling, stop_token) {
        Ok(resp) => obj(vec![
            ("id", (resp.id as usize).into()),
            ("text", tokenizer::decode(&resp.tokens).as_str().into()),
            ("tokens", resp.tokens.len().into()),
            ("queue_ms", (resp.queue_ms as f64).into()),
            ("prefill_ms", (resp.prefill_ms as f64).into()),
            ("decode_ms", (resp.decode_ms as f64).into()),
            ("total_ms", (resp.total_ms as f64).into()),
            (
                "finish",
                match resp.finish_reason {
                    FinishReason::MaxTokens => "max_tokens",
                    FinishReason::StopToken => "stop",
                    FinishReason::Truncated => "truncated",
                    FinishReason::Aborted => "aborted",
                }
                .into(),
            ),
        ]),
        Err(e) => obj(vec![("error", e.to_string().as_str().into())]),
    }
}
