//! TCP front-end: newline-delimited JSON over a socket.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "arlo is", "max_tokens": 24, "temperature": 0.8,
//!       "top_k": 40, "top_p": 0.9, "repetition_penalty": 1.1,
//!       "presence_penalty": 0.0, "frequency_penalty": 0.0,
//!       "logit_bias": {"46": -1e9}, "seed": 7, "n": 1,
//!       "stop": [" word"], "stop_token_ids": [10],
//!       "priority": 0, "deadline_ms": 5000}
//!   <- {"id": 1, "text": " red.", "tokens": 5, "total_ms": 12.3,
//!       "finish": "stop_seq", ...}
//!   -> same + {"stream": true}
//!   <- one frame per token as it is sampled:
//!      {"id": 1, "index": 0, "token": 32, "text": " "}
//!      ... then exactly one terminal frame:
//!      {"id": 1, "done": true, "text": " red.", "tokens": 5,
//!       "finish": "stop_seq", "queue_ms": ..., "total_ms": ...}
//!      (with `"n" > 1` every frame also carries `"choice"`)
//!   -> {"cmd": "metrics"}            <- metrics snapshot (includes the
//!                                       watchdog "alerts" section)
//!   -> {"cmd": "metrics_prom"}       <- Prometheus text exposition 0.0.4
//!                                       (wrapped as {"content_type", "body",
//!                                       "malformed_lines"})
//!   -> {"cmd": "trace"}              <- Chrome trace_event document; add
//!                                       {"format": "jsonl"} for one event
//!                                       per line in "body"
//!   -> {"cmd": "attrib", "n": 10}    <- top-n slowest finished requests
//!                                       with per-phase latency breakdowns
//!   -> {"cmd": "profile"}            <- continuous-profiler state + folded
//!                                       stacks (flamegraph collapse format;
//!                                       enable sampling with RRS_PROF_HZ)
//!   -> {"cmd": "shutdown"}           <- {"ok": true} and server exits
//!
//! Malformed sampling params (wrong type, out of range) get an
//! `{"error": ...}` reply — never a silent greedy fallback.  A client
//! that disconnects mid-stream has its in-flight requests cancelled:
//! the scheduler retires the lanes as `cancelled` and frees their KV
//! blocks.
//!
//! Each connection gets a handler thread; non-streaming generation
//! responses block the connection (clients pipeline by opening several
//! connections — the scheduler interleaves them via continuous
//! batching).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::model::tokenizer;
use crate::obs::attrib::{self, Phase};
use crate::obs::{profile, prom};
use crate::util::json::{obj, Json};

use super::request::{Event, RequestOptions, Response, StreamHandle, SubmitError};
use super::sampling::{int_field, usize_field, SamplingParams};
use super::scheduler::Coordinator;

/// Serve until a `shutdown` command arrives.  Returns the bound port.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<u16> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    eprintln!("rrs server listening on port {port}");
    accept_loop(listener, coordinator);
    Ok(port)
}

/// Bind, then run the accept loop on a background thread.  Returns the
/// bound port immediately (tests and load harnesses connect right
/// away).  Shut down with `{"cmd": "shutdown"}` followed by one extra
/// connection to unblock the accept loop, then join the handle.
pub fn spawn(
    coordinator: Arc<Coordinator>,
    addr: &str,
) -> Result<(u16, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    let handle = std::thread::Builder::new()
        .name("rrs-accept".into())
        .spawn(move || accept_loop(listener, coordinator))?;
    Ok((port, handle))
}

fn accept_loop(listener: TcpListener, coordinator: Arc<Coordinator>) {
    // ORDERING: the stop flag is a lone latch polled between
    // connections/requests; nothing is published with it, so Relaxed
    // costs at most one extra accepted connection before shutdown.
    let stop = Arc::new(AtomicBool::new(false));
    for stream in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let coord = coordinator.clone();
        let stop2 = stop.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, coord, stop2);
        });
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                write_line(
                    &mut writer,
                    &obj(vec![("error", format!("bad json: {e}").as_str().into())]),
                )?;
                continue;
            }
        };
        if req.get("cmd").is_some() {
            write_line(&mut writer, &handle_command(&req, &coord, &stop))?;
        } else if req.get("stream").and_then(Json::as_bool) == Some(true) {
            match parse_generation(&req) {
                Ok(spec) => stream_generation(&mut writer, &coord, spec)?,
                Err(e) => write_line(&mut writer, &obj(vec![("error", Json::Str(e))]))?,
            }
        } else {
            write_line(&mut writer, &handle_request(&req, &coord))?;
        }
        // ORDERING: lone shutdown latch; Relaxed poll per request.
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

fn write_line(w: &mut impl Write, j: &Json) -> std::io::Result<()> {
    w.write_all(j.dump().as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// One protocol line -> one JSON reply (exposed for tests).  Streaming
/// requests need a live socket; this non-streaming surface serves
/// commands and blocking generation.
pub fn handle_line(line: &str, coord: &Coordinator, stop: &AtomicBool) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return obj(vec![("error", format!("bad json: {e}").as_str().into())]),
    };
    if req.get("cmd").is_some() {
        return handle_command(&req, coord, stop);
    }
    handle_request(&req, coord)
}

fn handle_command(req: &Json, coord: &Coordinator, stop: &AtomicBool) -> Json {
    let Some(cmd) = req.get("cmd").and_then(Json::as_str) else {
        return obj(vec![("error", "'cmd' must be a string".into())]);
    };
    match cmd {
        // "stats" is an alias: the snapshot includes the KV-pool
        // gauges (blocks used/cached/peak, prefix hit rate, ...)
        "metrics" | "stats" => coord.metrics.snapshot_json(),
        // Prometheus exposition rides the JSON protocol as a wrapped
        // body; an HTTP shim only needs to echo body with the given
        // content type
        "metrics_prom" => {
            let body = prom::render(&coord.metrics);
            // self-check the exposition with the graceful parser: a
            // malformed line is counted in the reply, never a panic
            let (_, malformed) = prom::parse_exposition(&body);
            obj(vec![
                ("content_type", "text/plain; version=0.0.4".into()),
                ("malformed_lines", malformed.into()),
                ("body", Json::Str(body)),
            ])
        }
        "trace" => {
            let jsonl = req.get("format").and_then(Json::as_str) == Some("jsonl");
            if jsonl {
                obj(vec![("body", Json::Str(coord.metrics.trace.chrome_trace_jsonl()))])
            } else {
                coord.metrics.trace.chrome_trace_json()
            }
        }
        // top-n slowest finished requests with phase decompositions
        "attrib" => {
            let n = req
                .get("n")
                .and_then(Json::as_usize)
                .unwrap_or(10)
                .clamp(1, 256);
            attrib::slowest_json(n)
        }
        // continuous-profiler state + folded stacks
        "profile" => profile::profile_json(),
        "ping" => obj(vec![("ok", true.into())]),
        "shutdown" => {
            // ORDERING: lone shutdown latch (see accept_loop).
            stop.store(true, Ordering::Relaxed);
            obj(vec![("ok", true.into())])
        }
        other => obj(vec![("error", format!("unknown cmd {other}").as_str().into())]),
    }
}

/// A fully parsed generation request (prompt + options + choice count).
struct GenSpec {
    prompt: Vec<u32>,
    max_tokens: usize,
    params: SamplingParams,
    priority: i32,
    deadline: Option<Duration>,
    n: usize,
}

/// Strict protocol parse: any present-but-malformed field is an error
/// reply, never a silent fallback.
fn parse_generation(req: &Json) -> Result<GenSpec, String> {
    let Some(prompt) = req.get("prompt").and_then(Json::as_str) else {
        return Err("missing 'prompt'".into());
    };
    let max_tokens = usize_field(req, "max_tokens")?.unwrap_or(32);
    let mut params = SamplingParams::from_json(req)?;
    // "stop": one stop string or an array of them, matched against the
    // generated text (token-boundary-agnostic by construction: the
    // byte-level tokenizer makes any multi-byte stop string span tokens)
    match req.get("stop") {
        None | Some(Json::Null) => {}
        Some(Json::Str(s)) if !s.is_empty() => {
            params.stop_sequences.push(tokenizer::encode(s));
        }
        Some(Json::Arr(xs)) => {
            for x in xs {
                match x.as_str() {
                    Some(s) if !s.is_empty() => {
                        params.stop_sequences.push(tokenizer::encode(s));
                    }
                    _ => return Err("'stop' entries must be non-empty strings".into()),
                }
            }
        }
        Some(_) => return Err("'stop' must be a non-empty string or array".into()),
    }
    params.validate()?;
    let priority = int_field(req, "priority")?.unwrap_or(0);
    if !(-1_000_000..=1_000_000).contains(&priority) {
        return Err("'priority' out of range".into());
    }
    let deadline = match req.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => match v.as_f64() {
            Some(ms) if ms.is_finite() && ms > 0.0 => {
                Some(Duration::from_secs_f64(ms / 1e3))
            }
            _ => return Err("'deadline_ms' must be a positive number".into()),
        },
    };
    let n = usize_field(req, "n")?.unwrap_or(1);
    if n == 0 || n > 16 {
        return Err("'n' must be in 1..=16".into());
    }
    Ok(GenSpec {
        prompt: tokenizer::encode(prompt),
        max_tokens,
        params,
        priority: priority as i32,
        deadline,
        n,
    })
}

/// Submit choice `c` of a spec.  With an explicit seed, choice `c` uses
/// `seed + c` so the choices differ yet stay reproducible.
fn submit_choice(
    coord: &Coordinator,
    spec: &GenSpec,
    c: usize,
) -> Result<StreamHandle, SubmitError> {
    let mut params = spec.params.clone();
    if let Some(s) = params.seed {
        params.seed = Some(s.wrapping_add(c as u64));
    }
    coord.submit_opts(
        spec.prompt.clone(),
        RequestOptions {
            max_new_tokens: spec.max_tokens,
            params,
            priority: spec.priority,
            deadline: spec.deadline,
        },
    )
}

fn response_json(resp: &Response, choice: Option<usize>) -> Json {
    let mut kvs: Vec<(&str, Json)> = vec![("id", (resp.id as usize).into())];
    if let Some(c) = choice {
        kvs.push(("choice", c.into()));
    }
    kvs.extend([
        ("text", tokenizer::decode(&resp.tokens).as_str().into()),
        ("tokens", resp.tokens.len().into()),
        ("queue_ms", (resp.queue_ms as f64).into()),
        ("prefill_ms", (resp.prefill_ms as f64).into()),
        ("decode_ms", (resp.decode_ms as f64).into()),
        ("total_ms", (resp.total_ms as f64).into()),
        ("finish", resp.finish_reason.as_str().into()),
    ]);
    obj(kvs)
}

/// Blocking (non-streaming) generation, including `n > 1` choices.
fn handle_request(req: &Json, coord: &Coordinator) -> Json {
    let spec = match parse_generation(req) {
        Ok(s) => s,
        Err(e) => return obj(vec![("error", Json::Str(e))]),
    };
    let mut handles = Vec::new();
    for c in 0..spec.n {
        match submit_choice(coord, &spec, c) {
            Ok(h) => handles.push(h),
            Err(e) => return obj(vec![("error", e.to_string().as_str().into())]),
        }
    }
    let mut responses = Vec::new();
    for h in handles {
        match h.wait() {
            Ok(r) => responses.push(r),
            Err(e) => return obj(vec![("error", e.to_string().as_str().into())]),
        }
    }
    if responses.len() == 1 {
        // BOUNDS: guarded by the len() == 1 check above
        response_json(&responses[0], None)
    } else {
        obj(vec![
            // BOUNDS: non-empty — one response per choice, spec.n >= 1
            ("id", (responses[0].id as usize).into()),
            (
                "choices",
                Json::Arr(
                    responses
                        .iter()
                        .enumerate()
                        .map(|(c, r)| response_json(r, Some(c)))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Stream token frames for every choice as they are produced.  A write
/// failure means the client went away: cancel all in-flight choices so
/// the scheduler frees their lanes.
fn stream_generation(
    w: &mut impl Write,
    coord: &Coordinator,
    spec: GenSpec,
) -> Result<()> {
    struct Slot {
        choice: usize,
        handle: StreamHandle,
        done: bool,
    }
    let multi = spec.n > 1;
    let mut slots: Vec<Slot> = Vec::new();
    for c in 0..spec.n {
        match submit_choice(coord, &spec, c) {
            Ok(h) => slots.push(Slot { choice: c, handle: h, done: false }),
            Err(e) => {
                let mut kvs: Vec<(&str, Json)> = Vec::new();
                if multi {
                    kvs.push(("choice", c.into()));
                }
                kvs.push(("error", e.to_string().as_str().into()));
                write_line(w, &obj(kvs))?;
            }
        }
    }
    let mut open = slots.len();
    let mut write_err: Option<std::io::Error> = None;
    'serve: while open > 0 {
        let mut progressed = false;
        for s in slots.iter_mut() {
            if s.done {
                continue;
            }
            loop {
                match s.handle.events.try_recv() {
                    Ok(Event::Token { id, index, token }) => {
                        progressed = true;
                        let mut kvs: Vec<(&str, Json)> =
                            vec![("id", (id as usize).into())];
                        if multi {
                            kvs.push(("choice", s.choice.into()));
                        }
                        kvs.extend([
                            ("index", index.into()),
                            ("token", (token as usize).into()),
                            ("text", tokenizer::decode(&[token]).as_str().into()),
                        ]);
                        // socket write time is attributed to the request
                        // (drained by the scheduler at retire) and made
                        // visible to the profiler while in flight
                        let t0 = std::time::Instant::now();
                        let wrote = {
                            let _phase = attrib::phase_scope(Phase::StreamWrite);
                            write_line(w, &obj(kvs))
                        };
                        attrib::add_stream_write(
                            id,
                            t0.elapsed().as_micros() as u64,
                        );
                        if let Err(e) = wrote {
                            write_err = Some(e);
                            break 'serve;
                        }
                    }
                    Ok(Event::Done(resp)) => {
                        progressed = true;
                        s.done = true;
                        open -= 1;
                        let mut frame =
                            response_json(&resp, multi.then_some(s.choice));
                        if let Json::Obj(kvs) = &mut frame {
                            kvs.push(("done".to_string(), Json::Bool(true)));
                        }
                        if let Err(e) = write_line(w, &frame) {
                            write_err = Some(e);
                            break 'serve;
                        }
                        break;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        s.done = true;
                        open -= 1;
                        break;
                    }
                }
            }
        }
        if open > 0 && !progressed {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    if let Some(e) = write_err {
        // client disconnected mid-stream: tell the scheduler to retire
        // every in-flight choice (freeing its KV blocks) and also drop
        // the receivers so token sends fail fast
        for s in slots.iter() {
            if !s.done {
                s.handle.abort();
            }
        }
        return Err(e.into());
    }
    Ok(())
}
