//! Composable quantization recipes: the strategy matrix behind every
//! quantized path in the engine.
//!
//! The paper's argument is comparative — Runtime Smooth vs calibrated
//! migration vs rotation — so the quant layer must be able to compose
//! those strategies freely instead of hardcoding one recipe per
//! [`Method`].  A [`QuantRecipe`] picks each axis independently:
//!
//! * **smoothing** — none / Runtime Smooth (runtime channel maxima,
//!   never merged into weights) / SmoothQuant (calibrated, merged
//!   offline);
//! * **rotation** — none / Hadamard (FWHT, with an automatic
//!   block-diagonal fallback on non-power-of-two widths) / dense
//!   QuaRot-style closed-form (or learned SpinQuant matrices when
//!   provided);
//! * **activation precision** — INT4 / INT8 / f32;
//! * **weight precision** — INT4 (RTN or GPTQ) / f32;
//! * **KV-cache precision** — INT4 / INT8 / f32.
//!
//! Every legacy [`Method`] maps onto a recipe via
//! [`QuantRecipe::from_method`], and the recipe-driven
//! [`crate::quant::qlinear::QLinear`] pipeline takes the *same* code
//! routes the method dispatch did, so the presets stay bit-identical to
//! the pre-refactor paths (locked in by `rust/tests/golden.rs` and
//! `rust/tests/recipes.rs`).  New combinations — W4A8 SmoothQuant,
//! SmoothRot-style calibrated-smoothing-plus-rotation, INT8 KV — come
//! for free and are swept by `harness::matrix`.

use anyhow::{bail, Result};

use super::{Method, Scheme};

/// Activation-smoothing strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Smoothing {
    /// No smoothing (RTN / rotation-only recipes).
    None,
    /// Runtime Smooth: channel maxima from the live batch (paper 3.1).
    Runtime,
    /// SmoothQuant: calibrated scales merged into the weight offline.
    Calibrated,
}

/// Rotation strategy applied to (activation, weight) pairs along K.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RotationKind {
    /// No rotation.
    None,
    /// Sylvester-Hadamard via FWHT; non-power-of-two widths fall back
    /// to an orthogonal block-diagonal Hadamard at prepare time.
    Hadamard,
    /// Dense orthogonal rotation: learned SpinQuant matrices when
    /// supplied, otherwise a QuaRot-style closed-form sign-randomized
    /// Hadamard built per width.
    Dense,
}

impl Smoothing {
    pub fn tag(&self) -> &'static str {
        match self {
            Smoothing::None => "none",
            Smoothing::Runtime => "rs",
            Smoothing::Calibrated => "sq",
        }
    }
}

impl RotationKind {
    pub fn tag(&self) -> &'static str {
        match self {
            RotationKind::None => "",
            RotationKind::Hadamard => "+had",
            RotationKind::Dense => "+rot",
        }
    }
}

/// One point of the quantization strategy matrix.  `Copy` on purpose:
/// this is a plain descriptor, resolved once per engine and threaded by
/// value everywhere a method/scheme pair used to travel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantRecipe {
    pub smoothing: Smoothing,
    pub rotation: RotationKind,
    /// Activation precision: 4, 8, or 16 (=f32 passthrough).
    pub a_bits: u8,
    /// Weight precision: 4 or 16 (=f32).
    pub w_bits: u8,
    /// KV-cache precision: 4, 8, or 16 (=f32 rows).
    pub kv_bits: u8,
    /// Runtime-Smooth group size (Table 4 knob; 1 = exact per-channel).
    pub group: usize,
    /// KV-cache quant group (clamped to head_dim at use).
    pub kv_group: usize,
    /// SmoothQuant alpha.
    pub alpha: f32,
    /// GPTQ (vs RTN) for INT4 weights when calibration is available.
    pub gptq: bool,
    /// Fig. 3 ablation: migrate the runtime scale into the weight per
    /// call (requires `smoothing == Runtime`, no rotation).
    pub migrate: bool,
}

impl Default for QuantRecipe {
    fn default() -> Self {
        QuantRecipe::from_method(
            Method::Rrs,
            Scheme::A4W4KV4,
            128,
            128,
            0.5,
            true,
        )
    }
}

impl QuantRecipe {
    /// The recipe a legacy `(method, scheme, ...)` engine config denotes.
    /// The recipe-driven pipeline takes the same code routes as the
    /// method dispatch, so this mapping is bit-exact.
    pub fn from_method(
        method: Method,
        scheme: Scheme,
        group: usize,
        kv_group: usize,
        alpha: f32,
        gptq: bool,
    ) -> QuantRecipe {
        let (smoothing, rotation, migrate) = match method {
            Method::Fp | Method::Rtn | Method::GptqOnly => {
                (Smoothing::None, RotationKind::None, false)
            }
            Method::SmoothQuant => {
                (Smoothing::Calibrated, RotationKind::None, false)
            }
            Method::Rs => (Smoothing::Runtime, RotationKind::None, false),
            Method::QuaRot => (Smoothing::None, RotationKind::Hadamard, false),
            Method::Rrs => (Smoothing::Runtime, RotationKind::Hadamard, false),
            Method::SpinQuant => (Smoothing::None, RotationKind::Dense, false),
            Method::RsMigrated => {
                (Smoothing::Runtime, RotationKind::None, true)
            }
        };
        // legacy Fp dispatch bypasses activation/weight quantization
        // entirely whatever the scheme says (only kv_bits is honored),
        // so its recipe pins a/w to full precision
        let (a_bits, w_bits) = if method == Method::Fp {
            (16, 16)
        } else {
            (scheme.a_bits, scheme.w_bits)
        };
        QuantRecipe {
            smoothing,
            rotation,
            a_bits,
            w_bits,
            kv_bits: scheme.kv_bits,
            group: group.max(1),
            kv_group: kv_group.max(1),
            alpha,
            gptq,
            migrate,
        }
    }

    /// The precision triple as a legacy [`Scheme`].
    pub fn scheme(&self) -> Scheme {
        Scheme {
            a_bits: self.a_bits,
            w_bits: self.w_bits,
            kv_bits: self.kv_bits,
        }
    }

    /// Closest legacy [`Method`] preset (labels / back-compat only —
    /// dispatch runs off the recipe axes, not this).
    pub fn method(&self) -> Method {
        if self.migrate {
            return Method::RsMigrated;
        }
        match (self.smoothing, self.rotation) {
            (Smoothing::Runtime, RotationKind::None) => Method::Rs,
            (Smoothing::Runtime, _) => Method::Rrs,
            (Smoothing::Calibrated, _) => Method::SmoothQuant,
            (Smoothing::None, RotationKind::Hadamard) => Method::QuaRot,
            (Smoothing::None, RotationKind::Dense) => Method::SpinQuant,
            (Smoothing::None, RotationKind::None) => {
                if self.is_fp() {
                    Method::Fp
                } else if self.gptq {
                    Method::GptqOnly
                } else {
                    Method::Rtn
                }
            }
        }
    }

    /// Fully full-precision (no weight or activation quantization)?
    pub fn is_fp(&self) -> bool {
        self.a_bits >= 16 && self.w_bits >= 16
    }

    /// Does this recipe quantize activations at all?
    pub fn quantizes_acts(&self) -> bool {
        self.a_bits < 16
    }

    /// Symmetric max code for the activation precision (7 for INT4,
    /// 127 for INT8; INT4 for the degenerate a16-with-int4-weight path,
    /// matching the legacy dispatch).
    pub fn a_qmax(&self) -> f32 {
        if self.a_bits == 8 {
            super::QMAX8
        } else {
            super::QMAX
        }
    }

    /// Strategy tag, e.g. `rs+had`, `sq`, `none+rot`, `rs-mig`, `fp`.
    pub fn tag(&self) -> String {
        if self.is_fp()
            && self.smoothing == Smoothing::None
            && self.rotation == RotationKind::None
        {
            return "fp".to_string();
        }
        let s = if self.migrate { "rs-mig" } else { self.smoothing.tag() };
        format!("{}{}", s, self.rotation.tag())
    }

    /// Stable human/machine label, e.g. `rs+had-A4W4KV4-g128`.
    pub fn label(&self) -> String {
        format!("{}-{}-g{}", self.tag(), self.scheme().label(), self.group)
    }

    /// Reject descriptors no engine path supports, with a clear error
    /// (this is what turns would-be runtime panics into load-time
    /// failures).
    pub fn validate(&self) -> Result<()> {
        if !matches!(self.a_bits, 4 | 8 | 16) {
            bail!("unsupported activation bits {} (want 4, 8 or 16)", self.a_bits);
        }
        if !matches!(self.w_bits, 4 | 16) {
            bail!("unsupported weight bits {} (want 4 or 16)", self.w_bits);
        }
        if !matches!(self.kv_bits, 4 | 8 | 16) {
            bail!("unsupported KV bits {} (want 4, 8 or 16)", self.kv_bits);
        }
        if self.group == 0 || self.kv_group == 0 {
            bail!("group sizes must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.alpha) || !self.alpha.is_finite() {
            bail!("alpha {} outside [0, 1]", self.alpha);
        }
        if self.migrate {
            if self.smoothing != Smoothing::Runtime {
                bail!("migrate requires runtime smoothing");
            }
            if self.rotation != RotationKind::None {
                bail!("migrate composes with no rotation (Fig. 3 ablation)");
            }
        }
        Ok(())
    }

    /// Parse a recipe string: either a legacy method preset (`rrs`,
    /// `sq`, `quarot`, ...) or colon-separated tokens overriding
    /// individual axes, applied left to right over the default RRS
    /// recipe.  Examples:
    ///
    /// * `rrs` — the paper's RRS W4A4KV4 preset
    /// * `sq:a8w4kv8` — SmoothQuant W4A8 with INT8 KV
    /// * `rs:dense:a4w4kv4:g32` — runtime smoothing + dense rotation
    /// * `rtn:a4w4kv16:nogptq` — plain RTN, fp KV, RTN weights
    ///
    /// Token kinds: method names, `nosmooth|rs|sq`, `norot|had|dense`,
    /// `aXwYkvZ`, `gN`, `kvgN`, `alphaF`, `gptq|nogptq`, `migrate`.
    pub fn parse(s: &str) -> Result<QuantRecipe> {
        let mut r = QuantRecipe::default();
        for raw in s.split([':', ',']) {
            let tok = raw.trim().to_lowercase();
            if tok.is_empty() {
                continue;
            }
            if let Some(m) = Method::parse(&tok) {
                let scheme = if m == Method::Fp { Scheme::FP } else { r.scheme() };
                r = QuantRecipe::from_method(
                    m, scheme, r.group, r.kv_group, r.alpha, r.gptq,
                );
                continue;
            }
            if let Some(scheme) = parse_scheme_token(&tok) {
                r.a_bits = scheme.a_bits;
                r.w_bits = scheme.w_bits;
                r.kv_bits = scheme.kv_bits;
                continue;
            }
            match tok.as_str() {
                "nosmooth" => r.smoothing = Smoothing::None,
                "norot" => r.rotation = RotationKind::None,
                "had" | "hadamard" => r.rotation = RotationKind::Hadamard,
                "dense" | "rot" => r.rotation = RotationKind::Dense,
                "gptq" => r.gptq = true,
                "nogptq" | "rtn-w" => r.gptq = false,
                "migrate" => {
                    r.smoothing = Smoothing::Runtime;
                    r.rotation = RotationKind::None;
                    r.migrate = true;
                }
                _ => {
                    if let Some(g) = tok.strip_prefix("kvg") {
                        r.kv_group = g
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad kv group '{tok}'"))?;
                    } else if let Some(g) = tok.strip_prefix('g') {
                        r.group = g
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad group '{tok}'"))?;
                    } else if let Some(a) = tok.strip_prefix("alpha") {
                        r.alpha = a
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad alpha '{tok}'"))?;
                    } else {
                        bail!("unknown recipe token '{tok}' in '{s}'");
                    }
                }
            }
        }
        r.validate()?;
        Ok(r)
    }

    /// Recipe override from the `RRS_RECIPE` environment variable.
    pub fn from_env() -> Option<Result<QuantRecipe>> {
        match std::env::var("RRS_RECIPE") {
            Ok(s) if !s.trim().is_empty() => Some(QuantRecipe::parse(&s)),
            _ => None,
        }
    }

    /// The ablation matrix the harness sweeps (`rrs harness matrix`):
    /// every smoothing x rotation x precision point the paper's
    /// comparisons need, including the W4A8 hybrids and the KV ablation.
    pub fn matrix() -> Vec<QuantRecipe> {
        let base = QuantRecipe {
            smoothing: Smoothing::None,
            rotation: RotationKind::None,
            a_bits: 4,
            w_bits: 4,
            kv_bits: 4,
            group: 32,
            kv_group: 32,
            alpha: 0.5,
            gptq: false,
            migrate: false,
        };
        vec![
            // the paper's headline recipe: RRS W4A4 + INT4 KV
            QuantRecipe {
                smoothing: Smoothing::Runtime,
                rotation: RotationKind::Hadamard,
                ..base
            },
            // runtime smoothing alone (Table 1 "RS")
            QuantRecipe { smoothing: Smoothing::Runtime, ..base },
            // rotation alone (QuaRot-style, FWHT)
            QuantRecipe { rotation: RotationKind::Hadamard, ..base },
            // rotation alone, dense closed-form (QuaRot-style dense)
            QuantRecipe { rotation: RotationKind::Dense, ..base },
            // plain RTN floor
            base,
            // SmoothQuant W4A8 with INT8 KV (the hybrid SNIPPETS names)
            QuantRecipe {
                smoothing: Smoothing::Calibrated,
                a_bits: 8,
                kv_bits: 8,
                ..base
            },
            // RRS at W4A8 + INT8 KV: does extra activation headroom help?
            QuantRecipe {
                smoothing: Smoothing::Runtime,
                rotation: RotationKind::Hadamard,
                a_bits: 8,
                kv_bits: 8,
                ..base
            },
            // SmoothRot-style: calibrated smoothing composed with rotation
            QuantRecipe {
                smoothing: Smoothing::Calibrated,
                rotation: RotationKind::Hadamard,
                ..base
            },
        ]
    }
}

/// Parse `aXwYkvZ` (e.g. `a4w4kv4`, `a8w4kv16`) or `fp`.
fn parse_scheme_token(t: &str) -> Option<Scheme> {
    if t == "fp" || t == "fp16" {
        return Some(Scheme::FP);
    }
    let rest = t.strip_prefix('a')?;
    let wpos = rest.find('w')?;
    let a: u8 = rest[..wpos].parse().ok()?;
    let rest = &rest[wpos + 1..];
    let kpos = rest.find("kv")?;
    let w: u8 = rest[..kpos].parse().ok()?;
    let kv: u8 = rest[kpos + 2..].parse().ok()?;
    Some(Scheme { a_bits: a, w_bits: w, kv_bits: kv })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_round_trip_through_method() {
        for m in Method::ALL {
            let scheme =
                if m == Method::Fp { Scheme::FP } else { Scheme::A4W4KV4 };
            let r = QuantRecipe::from_method(m, scheme, 64, 64, 0.5, false);
            r.validate().unwrap();
            assert_eq!(r.scheme(), scheme, "{m:?}");
            // GptqOnly folds into the Rtn/GptqOnly pair by the gptq flag
            let back = r.method();
            match m {
                Method::GptqOnly => assert_eq!(back, Method::Rtn),
                other => assert_eq!(back, other),
            }
        }
        let mig = QuantRecipe::from_method(
            Method::RsMigrated,
            Scheme::A4W4KV16,
            128,
            128,
            0.5,
            false,
        );
        assert!(mig.migrate);
        assert_eq!(mig.method(), Method::RsMigrated);
    }

    #[test]
    fn parse_presets_and_tokens() {
        let rrs = QuantRecipe::parse("rrs").unwrap();
        assert_eq!(rrs.smoothing, Smoothing::Runtime);
        assert_eq!(rrs.rotation, RotationKind::Hadamard);
        assert_eq!(rrs.scheme(), Scheme::A4W4KV4);

        let sq8 = QuantRecipe::parse("sq:a8w4kv8:g64:alpha0.8").unwrap();
        assert_eq!(sq8.smoothing, Smoothing::Calibrated);
        assert_eq!(sq8.rotation, RotationKind::None);
        assert_eq!((sq8.a_bits, sq8.w_bits, sq8.kv_bits), (8, 4, 8));
        assert_eq!(sq8.group, 64);
        assert!((sq8.alpha - 0.8).abs() < 1e-6);

        let hyb = QuantRecipe::parse("rs:dense:a4w4kv16:kvg16").unwrap();
        assert_eq!(hyb.smoothing, Smoothing::Runtime);
        assert_eq!(hyb.rotation, RotationKind::Dense);
        assert_eq!(hyb.kv_group, 16);

        let fp = QuantRecipe::parse("fp").unwrap();
        assert!(fp.is_fp());
        assert_eq!(fp.tag(), "fp");

        assert!(QuantRecipe::parse("rrs:a3w4kv4").is_err());
        assert!(QuantRecipe::parse("bogus-token").is_err());
        assert!(QuantRecipe::parse("rrs:alpha2.0").is_err());
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(QuantRecipe::default().label(), "rs+had-A4W4KV4-g128");
        let q = QuantRecipe::parse("quarot:a4w4kv16:g32").unwrap();
        assert_eq!(q.label(), "none+had-A4W4KV16-g32");
    }

    #[test]
    fn matrix_covers_required_cells() {
        let m = QuantRecipe::matrix();
        assert!(m.len() >= 6, "matrix has {} cells", m.len());
        for r in &m {
            r.validate().unwrap();
        }
        // RRS W4A4
        assert!(m.iter().any(|r| r.smoothing == Smoothing::Runtime
            && r.rotation == RotationKind::Hadamard
            && r.a_bits == 4
            && r.w_bits == 4));
        // SmoothQuant W4A8
        assert!(m.iter().any(|r| r.smoothing == Smoothing::Calibrated
            && r.a_bits == 8
            && r.w_bits == 4));
        // rotation-only (QuaRot-style)
        assert!(m.iter().any(|r| r.smoothing == Smoothing::None
            && r.rotation != RotationKind::None));
        // labels are unique (the report keys on them)
        let mut labels: Vec<String> = m.iter().map(|r| r.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), m.len());
    }

    #[test]
    fn validate_rejects_bad_axes() {
        let r = QuantRecipe { a_bits: 3, ..QuantRecipe::default() };
        assert!(r.validate().is_err());
        let r = QuantRecipe { w_bits: 8, ..QuantRecipe::default() };
        assert!(r.validate().is_err());
        // rrs default has a rotation -> migrate is invalid on top of it
        let r = QuantRecipe { migrate: true, ..QuantRecipe::default() };
        assert!(r.validate().is_err());
    }
}
