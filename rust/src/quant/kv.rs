//! INT4/INT8 KV-cache quantization (paper 4.1: sub-channel symmetric,
//! group size 128, RTN).  INT4 values are stored nibble-packed with
//! per-group f32 scales — the format the coordinator's KV manager holds
//! per sequence slot, giving a true 4-bit-per-value cache (+ scale
//! overhead).  [`QuantVec8`] is the INT8 ablation point of the recipe
//! matrix: same grouping, one byte per value, no packing pass.

use super::{pack4, rtn, QMAX8};

/// One quantized vector (e.g. a K or V head row at one position).
#[derive(Clone, Debug)]
pub struct QuantVec {
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub len: usize,
    pub group: usize,
}

impl QuantVec {
    /// Quantize `x` with sub-channel groups of `group` (clamped to len).
    pub fn quantize(x: &[f32], group: usize) -> QuantVec {
        let g = group.min(x.len()).max(1);
        let mut codes = Vec::with_capacity(x.len());
        let mut scales = Vec::with_capacity(x.len().div_ceil(g));
        for seg in x.chunks(g) {
            let s = rtn::scale_for(seg.iter().fold(0.0f32, |a, &v| a.max(v.abs())));
            scales.push(s);
            for &v in seg {
                codes.push(rtn::quantize_one(v, s));
            }
        }
        QuantVec {
            packed: pack4::pack_i4(&codes),
            scales,
            len: x.len(),
            group: g,
        }
    }

    /// Dequantize into `out` (len must match).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        let codes = pack4::unpack_i4(&self.packed, self.len);
        for (i, (&c, o)) in codes.iter().zip(out.iter_mut()).enumerate() {
            *o = c as f32 * self.scales[i / self.group];
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.dequantize_into(&mut out);
        out
    }

    /// Bytes used (payload + scales), for memory accounting/metrics.
    pub fn bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }
}

/// One INT8-quantized vector: same sub-channel grouping as [`QuantVec`],
/// codes stored directly (one byte per value, no nibble packing).
#[derive(Clone, Debug)]
pub struct QuantVec8 {
    pub codes: Vec<i8>,
    pub scales: Vec<f32>,
    pub group: usize,
}

impl QuantVec8 {
    /// Quantize `x` with sub-channel groups of `group` (clamped to len).
    pub fn quantize(x: &[f32], group: usize) -> QuantVec8 {
        let g = group.min(x.len()).max(1);
        let mut codes = Vec::with_capacity(x.len());
        let mut scales = Vec::with_capacity(x.len().div_ceil(g));
        for seg in x.chunks(g) {
            let s = rtn::scale_for_q(
                seg.iter().fold(0.0f32, |a, &v| a.max(v.abs())),
                QMAX8,
            );
            scales.push(s);
            for &v in seg {
                codes.push(rtn::quantize_one_q(v, s, QMAX8));
            }
        }
        QuantVec8 { codes, scales, group: g }
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    /// Dequantize into `out` (len must match).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.codes.len());
        for (i, (&c, o)) in self.codes.iter().zip(out.iter_mut()).enumerate() {
            *o = c as f32 * self.scales[i / self.group];
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.codes.len()];
        self.dequantize_into(&mut out);
        out
    }

    /// Bytes used (payload + scales), for memory accounting/metrics.
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// Fake-quantize in place (quantize + dequantize), the model-graph analog.
pub fn fake_quant_inplace(x: &mut [f32], group: usize) {
    let q = QuantVec::quantize(x, group);
    q.dequantize_into(x);
}

/// INT8 fake-quantization (the KV ablation's model-graph analog).
pub fn fake_quant8_inplace(x: &mut [f32], group: usize) {
    let q = QuantVec8::quantize(x, group);
    q.dequantize_into(x);
}

/// Fake-quantize at the recipe's KV precision: 4 and 8 quantize, any
/// other width is full-precision passthrough.
pub fn fake_quant_bits_inplace(x: &mut [f32], group: usize, bits: u8) {
    match bits {
        4 => fake_quant_inplace(x, group),
        8 => fake_quant8_inplace(x, group),
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    #[test]
    fn roundtrip_bound() {
        check("kv-roundtrip", Config::default(), |rng, _| {
            let n = 8 * (1 + rng.below(16));
            let x = rng.normal_vec(n);
            let q = QuantVec::quantize(&x, 32);
            let y = q.dequantize();
            for (i, (&a, &b)) in x.iter().zip(&y).enumerate() {
                // error bounded by half the group step
                let s = q.scales[i / q.group];
                if (a - b).abs() > s / 2.0 + 1e-6 {
                    return Err(format!("at {i}: {a} vs {b} (s={s})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn memory_is_4bit_plus_scales() {
        let x = vec![1.0f32; 128];
        let q = QuantVec::quantize(&x, 128);
        assert_eq!(q.packed.len(), 64); // 128 codes -> 64 bytes
        assert_eq!(q.scales.len(), 1);
        assert_eq!(q.bytes(), 68); // vs 512 bytes fp32 => 7.5x smaller
    }

    #[test]
    fn group_clamps_to_len() {
        let x = vec![0.5f32; 8];
        let q = QuantVec::quantize(&x, 128);
        assert_eq!(q.group, 8);
        assert_eq!(q.scales.len(), 1);
    }

    #[test]
    fn roundtrip_group_larger_than_len() {
        // group clamps to len: one scale, error still half-step bounded
        check("kv-group-gt-len", Config::default(), |rng, _| {
            let n = 1 + rng.below(31);
            let group = n + 1 + rng.below(256);
            let x = rng.normal_vec(n);
            let q = QuantVec::quantize(&x, group);
            if q.group != n.max(1) || q.scales.len() != 1 {
                return Err(format!("group {} scales {}", q.group, q.scales.len()));
            }
            let y = q.dequantize();
            let s = q.scales[0];
            for (i, (&a, &b)) in x.iter().zip(&y).enumerate() {
                if (a - b).abs() > s / 2.0 + 1e-6 {
                    return Err(format!("at {i}: {a} vs {b} (s={s})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_len_not_divisible_by_group() {
        // ragged tail group: scale indexing i/group still lands on the
        // right (smaller) last group
        check("kv-ragged-tail", Config::default(), |rng, _| {
            let group = 2 + rng.below(15);
            let n = group * (1 + rng.below(4)) + 1 + rng.below(group - 1);
            let x = rng.normal_vec(n);
            let q = QuantVec::quantize(&x, group);
            if q.scales.len() != n.div_ceil(group) {
                return Err(format!(
                    "n={n} group={group}: {} scales",
                    q.scales.len()
                ));
            }
            let y = q.dequantize();
            for (i, (&a, &b)) in x.iter().zip(&y).enumerate() {
                let s = q.scales[i / q.group];
                if (a - b).abs() > s / 2.0 + 1e-6 {
                    return Err(format!("at {i}: {a} vs {b} (s={s})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn all_zero_segments_roundtrip_exactly() {
        // zero groups hit the scale floor (1e-8) and must decode to 0.0,
        // without poisoning neighbouring non-zero groups
        let mut x = vec![0.0f32; 48];
        for v in x.iter_mut().skip(32) {
            *v = 1.5;
        }
        let q = QuantVec::quantize(&x, 16);
        assert_eq!(q.scales.len(), 3);
        let y = q.dequantize();
        for (i, &v) in y.iter().enumerate().take(32) {
            assert_eq!(v, 0.0, "zero segment decoded to {v} at {i}");
        }
        for (i, &v) in y.iter().enumerate().skip(32) {
            assert!((v - 1.5).abs() < 0.2, "at {i}: {v}");
        }
        // fully-zero vector, group > len
        let z = QuantVec::quantize(&[0.0; 7], 64);
        assert!(z.dequantize().iter().all(|&v| v == 0.0));
        assert!(z.scales[0] > 0.0);
    }

    #[test]
    fn fake_quant_idempotent() {
        let mut rng = crate::util::rng::Pcg::new(1);
        let mut x = rng.normal_vec(64);
        fake_quant_inplace(&mut x, 16);
        let once = x.clone();
        fake_quant_inplace(&mut x, 16);
        // quantizing already-quantized values is exact
        for (a, b) in once.iter().zip(&x) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn int8_roundtrip_bound_and_edge_groups() {
        // mirrors the INT4 edge-case suite: half-step error bound, group
        // clamping, ragged tail, and a tighter step than INT4
        check("kv8-roundtrip", Config::default(), |rng, _| {
            let group = 2 + rng.below(31);
            let n = 1 + rng.below(200);
            let x = rng.normal_vec(n);
            let q = QuantVec8::quantize(&x, group);
            if q.group != group.min(n).max(1) {
                return Err(format!("group {} for n={n}", q.group));
            }
            if q.scales.len() != n.div_ceil(q.group) {
                return Err(format!("{} scales", q.scales.len()));
            }
            let y = q.dequantize();
            for (i, (&a, &b)) in x.iter().zip(&y).enumerate() {
                let s = q.scales[i / q.group];
                if (a - b).abs() > s / 2.0 + 1e-6 {
                    return Err(format!("at {i}: {a} vs {b} (s={s})"));
                }
            }
            // INT8 groups step ~18x finer than INT4 on the same data
            let q4 = QuantVec::quantize(&x, group);
            for (s8, s4) in q.scales.iter().zip(&q4.scales) {
                if *s8 > *s4 {
                    return Err(format!("int8 step {s8} > int4 step {s4}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int8_memory_is_1byte_plus_scales() {
        let x = vec![1.0f32; 128];
        let q = QuantVec8::quantize(&x, 128);
        assert_eq!(q.len(), 128);
        assert_eq!(q.scales.len(), 1);
        assert_eq!(q.bytes(), 132); // vs 512 bytes fp32 => ~3.9x smaller
    }

    #[test]
    fn int8_zero_segments_roundtrip_exactly() {
        let mut x = vec![0.0f32; 48];
        for v in x.iter_mut().skip(32) {
            *v = 1.5;
        }
        let q = QuantVec8::quantize(&x, 16);
        assert_eq!(q.scales.len(), 3);
        let y = q.dequantize();
        for (i, &v) in y.iter().enumerate().take(32) {
            assert_eq!(v, 0.0, "zero segment decoded to {v} at {i}");
        }
        for (i, &v) in y.iter().enumerate().skip(32) {
            assert!((v - 1.5).abs() < 0.01, "at {i}: {v}");
        }
        let z = QuantVec8::quantize(&[0.0; 7], 64);
        assert!(z.dequantize().iter().all(|&v| v == 0.0));
        assert!(z.scales[0] > 0.0);
    }

    #[test]
    fn fake_quant_bits_dispatch() {
        let mut rng = crate::util::rng::Pcg::new(7);
        let base = rng.normal_vec(64);

        let mut x4 = base.clone();
        fake_quant_bits_inplace(&mut x4, 16, 4);
        let mut want4 = base.clone();
        fake_quant_inplace(&mut want4, 16);
        assert_eq!(x4, want4);

        let mut x8 = base.clone();
        fake_quant_bits_inplace(&mut x8, 16, 8);
        let mut want8 = base.clone();
        fake_quant8_inplace(&mut want8, 16);
        assert_eq!(x8, want8);
        // int8 is strictly closer on this data than int4
        let e8: f32 =
            x8.iter().zip(&base).map(|(a, b)| (a - b).abs()).sum();
        let e4: f32 =
            x4.iter().zip(&base).map(|(a, b)| (a - b).abs()).sum();
        assert!(e8 < e4);

        let mut x16 = base.clone();
        fake_quant_bits_inplace(&mut x16, 16, 16);
        assert_eq!(x16, base);
    }
}
