//! Runtime Smooth (paper section 3.1-3.2): the training-free activation
//! smoother.  Channel-wise maxima are computed **at runtime** from the
//! activation batch that is actually being multiplied, never merged into
//! the weights:
//!
//! 1. `channel_scales`  — `s_j = max_i |X_ij|`                 (eq. 1)
//! 2. `reorder_perm`    — channels sorted by descending scale  (Fig. 4 (1))
//! 3. `group_scales`    — per-group maxima after reordering    (Fig. 4 (2))
//! 4. smooth + per-token quantize; the fused GEMM re-applies the group
//!    scale on the de-quantized partials                       (eq. 3)
//!
//! With `group == 1` this is the exact per-channel runtime scale (Table 1
//! "RS"); `group == 128` matches the GEMM block size so the scale hoists
//! out of the inner loop (Table 4 / Figure 6 fused kernel).

use crate::linalg::gemm::Mat;
use crate::linalg::igemm::MatI8;

use super::{rtn, QMAX};

/// Runtime channel-wise absolute maxima (eq. 1), floored at 1e-8.
pub fn channel_scales(x: &Mat) -> Vec<f32> {
    let mut s = vec![0.0f32; x.cols];
    for i in 0..x.rows {
        for (sj, &v) in s.iter_mut().zip(x.row(i)) {
            *sj = sj.max(v.abs());
        }
    }
    for sj in s.iter_mut() {
        *sj = sj.max(1e-8);
    }
    s
}

/// Descending-magnitude permutation of channels (stable on ties).
pub fn reorder_perm(scales: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scales.len()).collect();
    idx.sort_by(|&a, &b| {
        scales[b]
            .partial_cmp(&scales[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Group-wise maxima over reordered scales; `perm.len() % group == 0`.
pub fn group_scales(scales: &[f32], perm: &[usize], group: usize) -> Vec<f32> {
    assert_eq!(perm.len() % group, 0);
    perm.chunks(group)
        .map(|idxs| idxs.iter().fold(0.0f32, |a, &j| a.max(scales[j])))
        .collect()
}

/// Smoothed + per-token-quantized activation, ready for the fused GEMM.
pub struct SmoothedAct {
    /// INT4 codes of X[:, perm] / repeat(group_scales) (reordered layout).
    pub q: MatI8,
    /// Per-token quantization scales.
    pub token_scales: Vec<f32>,
    /// Channel permutation applied (weights must be gathered identically).
    pub perm: Vec<usize>,
    /// Per-group smoothing scales (reordered layout).
    pub group_scales: Vec<f32>,
    pub group: usize,
}

/// Full runtime stage of the fused pipeline (Fig. 4 steps 1-2 + quant),
/// on the dispatched [`crate::kernels`] backend: fused channel-max
/// reduction + smooth + per-token RTN quantize.  Bit-identical to
/// [`prepare_staged`] on every backend (asserted by
/// `rust/tests/kernel_diff.rs`).
pub fn prepare(x: &Mat, group: usize) -> SmoothedAct {
    crate::kernels::rrs_prologue(x, group)
}

/// [`prepare`] at an arbitrary symmetric max code (7 = INT4 — the
/// golden path — 127 = the W4A8 activation recipe).
pub fn prepare_q(x: &Mat, group: usize, qmax: f32) -> SmoothedAct {
    crate::kernels::rrs_prologue_q(x, group, qmax)
}

/// The staged reference pipeline: separate channel-max, gather/smooth,
/// absmax and quantize passes — the oracle the fused kernel prologue
/// (every backend of [`crate::kernels::rrs_prologue`]) is diffed
/// against.
pub fn prepare_staged(x: &Mat, group: usize) -> SmoothedAct {
    prepare_staged_q(x, group, QMAX)
}

/// [`prepare_staged`] at an arbitrary max code — the W4A8 oracle.
pub fn prepare_staged_q(x: &Mat, group: usize, qmax: f32) -> SmoothedAct {
    let s = channel_scales(x);
    let perm = reorder_perm(&s);
    let sg = group_scales(&s, &perm, group);
    let mut q = MatI8::zeros(x.rows, x.cols);
    let mut token_scales = vec![0.0f32; x.rows];
    let mut smooth_row = vec![0.0f32; x.cols];
    for i in 0..x.rows {
        let row = x.row(i);
        // gather + smooth in one pass
        for (j, &p) in perm.iter().enumerate() {
            smooth_row[j] = row[p] / sg[j / group];
        }
        let sx = rtn::scale_for_q(
            smooth_row.iter().fold(0.0f32, |a, &v| a.max(v.abs())),
            qmax,
        );
        token_scales[i] = sx;
        let qrow = &mut q.data[i * x.cols..(i + 1) * x.cols];
        rtn::quantize_row_q(&smooth_row, sx, qmax, qrow);
    }
    SmoothedAct { q, token_scales, perm, group_scales: sg, group }
}

/// A4W16 fake-quant path: smooth, quantize, de-quantize, un-permute.
/// Returns the effective activation the fp GEMM should consume.
pub fn fake_quant_a4w16(x: &Mat, group: usize) -> Mat {
    fake_quant_rs_q(x, group, QMAX)
}

/// [`fake_quant_a4w16`] at an arbitrary symmetric max code (127 = the
/// A8W16 runtime-smoothed recipe).
pub fn fake_quant_rs_q(x: &Mat, group: usize, qmax: f32) -> Mat {
    let sa = prepare_q(x, group, qmax);
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let sx = sa.token_scales[i];
        let qrow = sa.q.row(i);
        let dst = out.row_mut(i);
        for (j, &p) in sa.perm.iter().enumerate() {
            dst[p] = qrow[j] as f32 * sx * sa.group_scales[j / group];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, check, Config};
    use crate::util::rng::Pcg;

    fn randmat(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(n, k, rng.normal_vec(n * k))
    }

    #[test]
    fn channel_scales_are_maxima() {
        let x = Mat::from_vec(2, 3, vec![1.0, -5.0, 2.0, -3.0, 4.0, 0.5]);
        assert_eq!(channel_scales(&x), vec![3.0, 5.0, 2.0]);
    }

    #[test]
    fn perm_is_descending_permutation() {
        check("rs-perm", Config::default(), |rng, _| {
            let s: Vec<f32> = (0..64).map(|_| rng.uniform()).collect();
            let p = reorder_perm(&s);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            if sorted != (0..64).collect::<Vec<_>>() {
                return Err("not a permutation".into());
            }
            for w in p.windows(2) {
                if s[w[0]] < s[w[1]] {
                    return Err("not descending".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn group_scale_dominates_members() {
        let x = randmat(8, 64, 3);
        let s = channel_scales(&x);
        let p = reorder_perm(&s);
        let sg = group_scales(&s, &p, 16);
        for (g, idxs) in p.chunks(16).enumerate() {
            for &j in idxs {
                assert!(sg[g] >= s[j]);
            }
        }
    }

    #[test]
    fn smoothed_codes_bounded() {
        let mut x = randmat(8, 64, 4);
        for i in 0..8 {
            x.data[i * 64 + 7] *= 200.0; // channel outlier
        }
        let sa = prepare(&x, 16);
        assert!(sa.q.data.iter().all(|&c| c.abs() <= 7));
        assert!(sa.group_scales.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn fake_quant_close_at_group1() {
        // group=1: per-channel smoothing makes the roundtrip error tiny
        // even with consistent channel outliers
        let mut rng = Pcg::new(5);
        let mut x = Mat::from_vec(16, 64, rng.normal_vec(16 * 64));
        for i in 0..16 {
            x.data[i * 64 + 3] = 100.0 * (1.0 + 0.02 * rng.normal());
        }
        let y = fake_quant_a4w16(&x, 1);
        // outlier channel recovered within ~ (1/7)/2 relative
        for i in 0..16 {
            let rel = (y.at(i, 3) - x.at(i, 3)).abs() / x.at(i, 3).abs();
            assert!(rel < 0.08, "row {i} rel {rel}");
        }
        assert_close(&y.data, &x.data, 0.5, 0.12).unwrap();
    }

    #[test]
    fn int8_prepare_matches_staged_and_bounds_codes() {
        let x = randmat(6, 64, 9);
        let fused = prepare_q(&x, 16, crate::quant::QMAX8);
        let staged = prepare_staged_q(&x, 16, crate::quant::QMAX8);
        assert_eq!(fused.q.data, staged.q.data);
        assert_eq!(fused.token_scales, staged.token_scales);
        assert_eq!(fused.perm, staged.perm);
        assert_eq!(fused.group_scales, staged.group_scales);
        assert!(fused.q.data.iter().all(|&c| (c as i32).abs() <= 127));
        // qmax=7 variant is exactly the legacy pipeline
        let legacy = prepare_staged(&x, 16);
        let at7 = prepare_staged_q(&x, 16, QMAX);
        assert_eq!(legacy.q.data, at7.q.data);
        assert_eq!(legacy.token_scales, at7.token_scales);
    }

    #[test]
    fn grouping_monotone_in_quality() {
        // finer groups never increase the roundtrip error much; coarse
        // groups with a spike outlier hurt (Table 4 mechanism)
        let mut rng = Pcg::new(6);
        let mut x = Mat::from_vec(16, 128, rng.normal_vec(16 * 128));
        x.data[5 * 128 + 77] = 500.0; // spike
        let err = |g: usize| {
            let y = fake_quant_a4w16(&x, g);
            x.data
                .iter()
                .zip(&y.data)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
        };
        let e1 = err(1);
        let e128 = err(128);
        assert!(e1 <= e128 * 1.05, "e1={e1} e128={e128}");
    }
}
