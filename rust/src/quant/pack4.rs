//! INT4 nibble packing: two codes per byte.  Used by the KV-cache manager
//! so a 4-bit cache really occupies 4 bits (+ scales), and by weight
//! storage.  Codes are in [-8, 7] two's-complement nibbles (we only emit
//! [-7, 7], matching the paper's symmetric range).

/// Pack i8 codes (each in [-8, 7]) into nibbles; pairs `(2i, 2i+1)` share
/// byte `i` (low nibble first).  Odd lengths pad the final high nibble
/// with 0.
pub fn pack_i4(codes: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    let mut it = codes.chunks_exact(2);
    for pair in &mut it {
        out.push(((pair[0] as u8) & 0x0f) | (((pair[1] as u8) & 0x0f) << 4));
    }
    if let [last] = it.remainder() {
        out.push((*last as u8) & 0x0f);
    }
    out
}

/// Unpack nibbles back to i8 codes ([-8, 7] sign extension).
pub fn unpack_i4(packed: &[u8], n: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(n);
    for (i, &b) in packed.iter().enumerate() {
        let lo = sign_extend(b & 0x0f);
        out.push(lo);
        if 2 * i + 1 < n {
            out.push(sign_extend(b >> 4));
        }
    }
    out.truncate(n);
    out
}

#[inline]
fn sign_extend(nibble: u8) -> i8 {
    ((nibble << 4) as i8) >> 4
}

/// Bytes needed to pack `n` INT4 codes.
pub fn packed_len(n: usize) -> usize {
    n.div_ceil(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    #[test]
    fn roundtrip_all_codes() {
        let codes: Vec<i8> = (-8..=7).collect();
        let packed = pack_i4(&codes);
        assert_eq!(packed.len(), 8);
        assert_eq!(unpack_i4(&packed, codes.len()), codes);
    }

    #[test]
    fn roundtrip_random() {
        check("pack4-roundtrip", Config::default(), |rng, _| {
            let n = 1 + rng.below(100);
            let codes: Vec<i8> =
                (0..n).map(|_| rng.below(15) as i8 - 7).collect();
            let packed = pack_i4(&codes);
            if packed.len() != packed_len(n) {
                return Err("bad packed length".into());
            }
            if unpack_i4(&packed, n) != codes {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn odd_length() {
        let codes = vec![3i8, -2, 7];
        assert_eq!(unpack_i4(&pack_i4(&codes), 3), codes);
    }

    #[test]
    fn density_is_half() {
        assert_eq!(packed_len(128), 64);
        assert_eq!(packed_len(1), 1);
    }
}
