//! INT4 nibble packing: two codes per byte.  Used by the KV-cache manager
//! so a 4-bit cache really occupies 4 bits (+ scales), by weight storage,
//! and — as [`PackedI4`] — by the [`crate::kernels`] microkernels, which
//! consume nibble-packed weights *directly* (no unpack-to-i8
//! materialization, half the memory traffic of an i8 weight).  Codes are
//! in [-8, 7] two's-complement nibbles (we only emit [-7, 7], matching
//! the paper's symmetric range).

use crate::linalg::igemm::MatI8;

/// Pack i8 codes (each in [-8, 7]) into nibbles; pairs `(2i, 2i+1)` share
/// byte `i` (low nibble first).  Odd lengths pad the final high nibble
/// with 0.
pub fn pack_i4(codes: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    let mut it = codes.chunks_exact(2);
    for pair in &mut it {
        out.push(((pair[0] as u8) & 0x0f) | (((pair[1] as u8) & 0x0f) << 4));
    }
    if let [last] = it.remainder() {
        out.push((*last as u8) & 0x0f);
    }
    out
}

/// Unpack nibbles back to i8 codes ([-8, 7] sign extension).
pub fn unpack_i4(packed: &[u8], n: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(n);
    for (i, &b) in packed.iter().enumerate() {
        let lo = sign_extend(b & 0x0f);
        out.push(lo);
        if 2 * i + 1 < n {
            out.push(sign_extend(b >> 4));
        }
    }
    out.truncate(n);
    out
}

#[inline]
fn sign_extend(nibble: u8) -> i8 {
    ((nibble << 4) as i8) >> 4
}

/// Bytes needed to pack `n` INT4 codes.
pub fn packed_len(n: usize) -> usize {
    n.div_ceil(2)
}

/// A row-major matrix of INT4 codes stored two-per-byte, the weight
/// layout the [`crate::kernels`] GEMM microkernels read directly.
///
/// Byte `t` of a row holds codes `2t` (low nibble) and `2t + 1` (high
/// nibble), exactly the [`pack_i4`] convention.  Rows are padded with
/// zero bytes to a [`PackedI4::ROW_ALIGN`]-byte stride so a SIMD kernel
/// can always read whole 16-byte chunks: zero nibbles contribute zero to
/// any dot product, making the padding numerically inert.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedI4 {
    /// Output channels (GEMM `m`).
    pub rows: usize,
    /// Unpacked inner dimension (GEMM `k`).
    pub cols: usize,
    /// Bytes per row (`>= packed_len(cols)`, multiple of `ROW_ALIGN`).
    pub stride: usize,
    pub data: Vec<u8>,
}

impl PackedI4 {
    /// Row stride alignment in bytes (one 128-bit SIMD lane).
    pub const ROW_ALIGN: usize = 16;

    /// Pack an i8 code matrix (each value in [-8, 7]) row by row.
    pub fn pack(m: &MatI8) -> PackedI4 {
        let pl = packed_len(m.cols);
        let stride = pl.next_multiple_of(Self::ROW_ALIGN).max(Self::ROW_ALIGN);
        let mut data = vec![0u8; m.rows * stride];
        for i in 0..m.rows {
            let row = m.row(i);
            let dst = &mut data[i * stride..i * stride + pl];
            for (t, pair) in row.chunks(2).enumerate() {
                let lo = (pair[0] as u8) & 0x0f;
                let hi = if let Some(&second) = pair.get(1) {
                    ((second as u8) & 0x0f) << 4
                } else {
                    0
                };
                dst[t] = lo | hi;
            }
        }
        PackedI4 { rows: m.rows, cols: m.cols, stride, data }
    }

    /// One packed row, including the zero padding tail (`stride` bytes).
    #[inline]
    pub fn row(&self, j: usize) -> &[u8] {
        &self.data[j * self.stride..(j + 1) * self.stride]
    }

    /// Unpack back to an i8 matrix (test / cross-check path).
    pub fn unpack(&self) -> MatI8 {
        let pl = packed_len(self.cols);
        let mut out = MatI8::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let packed = &self.data[i * self.stride..i * self.stride + pl];
            let row = unpack_i4(packed, self.cols);
            out.data[i * self.cols..(i + 1) * self.cols].copy_from_slice(&row);
        }
        out
    }

    /// Payload bytes (padding included).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    #[test]
    fn roundtrip_all_codes() {
        let codes: Vec<i8> = (-8..=7).collect();
        let packed = pack_i4(&codes);
        assert_eq!(packed.len(), 8);
        assert_eq!(unpack_i4(&packed, codes.len()), codes);
    }

    #[test]
    fn roundtrip_random() {
        check("pack4-roundtrip", Config::default(), |rng, _| {
            let n = 1 + rng.below(100);
            let codes: Vec<i8> =
                (0..n).map(|_| rng.below(15) as i8 - 7).collect();
            let packed = pack_i4(&codes);
            if packed.len() != packed_len(n) {
                return Err("bad packed length".into());
            }
            if unpack_i4(&packed, n) != codes {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn odd_length() {
        let codes = vec![3i8, -2, 7];
        assert_eq!(unpack_i4(&pack_i4(&codes), 3), codes);
    }

    #[test]
    fn density_is_half() {
        assert_eq!(packed_len(128), 64);
        assert_eq!(packed_len(1), 1);
    }

    #[test]
    fn packed_mat_roundtrip_at_odd_widths() {
        // widths straddling every alignment edge: odd, one-under/over a
        // 16-byte stride boundary, and tiny
        check("packedi4-roundtrip", Config { cases: 96, ..Config::default() },
            |rng, case| {
                let rows = 1 + rng.below(7);
                let cols = match case % 4 {
                    0 => 1 + 2 * rng.below(40),      // odd
                    1 => 31 + rng.below(4),           // around the 32 edge
                    2 => 1 + rng.below(8),            // tiny
                    _ => 1 + rng.below(130),          // anything
                };
                let codes: Vec<i8> =
                    (0..rows * cols).map(|_| rng.below(16) as i8 - 8).collect();
                let m = MatI8::from_vec(rows, cols, codes);
                let p = PackedI4::pack(&m);
                if p.stride % PackedI4::ROW_ALIGN != 0
                    || p.stride < packed_len(cols)
                {
                    return Err(format!("bad stride {} for cols {cols}", p.stride));
                }
                // padding bytes beyond the payload must be zero (SIMD
                // kernels read them and rely on 0 * x == 0)
                for i in 0..rows {
                    let row = p.row(i);
                    if row[packed_len(cols)..].iter().any(|&b| b != 0) {
                        return Err("nonzero padding".into());
                    }
                    // odd cols: the final payload byte's high nibble pads 0
                    if cols % 2 == 1 && row[packed_len(cols) - 1] >> 4 != 0 {
                        return Err("nonzero odd-width pad nibble".into());
                    }
                }
                if p.unpack() != m {
                    return Err("packed matrix roundtrip mismatch".into());
                }
                Ok(())
            });
    }
}
