//! INT4 quantization library — every smoothing/quantization method the
//! paper evaluates, implemented natively so the serving hot path never
//! touches python:
//!
//! * [`rtn`] — symmetric round-to-nearest INT4 (per-tensor / per-token /
//!   per-output-channel / sub-channel), the base primitive (paper 2.1).
//! * [`pack4`] — nibble packing for INT4 storage (KV cache, weights).
//! * [`smoothquant`] — offline calibrated channel smoothing (paper 2.2).
//! * [`runtime_smooth`] — the paper's Runtime Smooth: runtime channel
//!   maxima, reorder permutation, group scales (section 3.1-3.2).
//! * [`rotation`] — Hadamard rotation utilities (QuaRot baseline + the
//!   rotated half of RRS, section 2.3/3.3).
//! * [`gptq`] — GPTQ weight quantization (offline, per-channel symmetric).
//! * [`kv`] — sub-channel INT4 KV-cache quantization.
//! * [`qlinear`] — fused quantized-linear ops assembled from the above:
//!   per-channel A4W4, sub-channel A4W4, RS-fused A4W4 (the Figure-6
//!   kernel trio), plus QuaRot and RRS paths; one enum dispatch per call.
//! * [`recipe`] — the composable strategy matrix: smoothing × rotation ×
//!   activation/weight/KV precision as one [`QuantRecipe`] descriptor
//!   that drives `qlinear`, the engine, and the KV pool.

pub mod gptq;
pub mod kv;
pub mod pack4;
pub mod qlinear;
pub mod recipe;
pub mod rotation;
pub mod rtn;
pub mod runtime_smooth;
pub mod smoothquant;

pub use recipe::{QuantRecipe, RotationKind, Smoothing};

/// INT4 symmetric max code: 2^{4-1} - 1 (the paper leaves -8 unused).
pub const QMAX: f32 = 7.0;

/// INT8 symmetric max code (W4A8 activations, INT8 KV).
pub const QMAX8: f32 = 127.0;

/// Methods evaluated in the paper's tables (plus fp reference).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Fp,
    Rtn,
    SmoothQuant,
    /// GPTQ weights + plain RTN activations (the paper's "GPTQ" row).
    GptqOnly,
    Rs,
    QuaRot,
    Rrs,
    /// QuaRot with a learned (SpinQuant) rotation instead of Hadamard.
    SpinQuant,
    /// Fig. 3 ablation: runtime smoothing scale but *migrated into the
    /// weight per call* (re-quantizing W·diag(s) at runtime) — shows why
    /// Runtime Smooth must NOT share outliers with the weight.
    RsMigrated,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "fp" | "fp16" => Method::Fp,
            "rtn" => Method::Rtn,
            "sq" | "smoothquant" => Method::SmoothQuant,
            "gptq" => Method::GptqOnly,
            "rs" => Method::Rs,
            "quarot" => Method::QuaRot,
            "rrs" => Method::Rrs,
            "spinquant" => Method::SpinQuant,
            "rs-migrated" => Method::RsMigrated,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp => "FP16",
            Method::Rtn => "RTN",
            Method::SmoothQuant => "SmoothQuant",
            Method::GptqOnly => "GPTQ",
            Method::Rs => "RS",
            Method::QuaRot => "QuaRot",
            Method::Rrs => "RRS",
            Method::SpinQuant => "SpinQuant",
            Method::RsMigrated => "RS-migrated",
        }
    }

    /// Does this method rotate activations/weights?
    pub fn rotated(&self) -> bool {
        matches!(self, Method::QuaRot | Method::Rrs | Method::SpinQuant)
    }

    /// Does this method apply Runtime Smooth?
    pub fn runtime_smoothed(&self) -> bool {
        matches!(self, Method::Rs | Method::Rrs)
    }

    pub const ALL: [Method; 8] = [
        Method::Fp,
        Method::Rtn,
        Method::SmoothQuant,
        Method::GptqOnly,
        Method::Rs,
        Method::QuaRot,
        Method::Rrs,
        Method::SpinQuant,
    ];
}

/// One cell of the paper's scheme matrix (e.g. `A4W4KV4`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scheme {
    pub a_bits: u8,
    pub w_bits: u8,
    pub kv_bits: u8,
}

impl Scheme {
    pub const A4W4KV4: Scheme = Scheme { a_bits: 4, w_bits: 4, kv_bits: 4 };
    pub const A4W4KV16: Scheme = Scheme { a_bits: 4, w_bits: 4, kv_bits: 16 };
    pub const A4W16KV16: Scheme = Scheme { a_bits: 4, w_bits: 16, kv_bits: 16 };
    pub const FP: Scheme = Scheme { a_bits: 16, w_bits: 16, kv_bits: 16 };

    pub fn label(&self) -> String {
        format!("A{}W{}KV{}", self.a_bits, self.w_bits, self.kv_bits)
    }
}
