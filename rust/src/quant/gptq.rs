//! GPTQ weight quantization (Frantar et al. 2022) — rust implementation,
//! mirrored against python/compile/gptq.py through the golden vectors.
//!
//! Per-output-channel symmetric INT4 scales fixed upfront; the column
//! sweep redistributes rounding error through the inverse Hessian
//! `H = 2 X^T X + damp*mean(diag)*I` using its upper Cholesky factor.

use anyhow::Result;

use crate::linalg::chol::{cholesky_lower, invert_spd};
use crate::linalg::gemm::{gemm_f32_bt, Mat};
use crate::linalg::igemm::MatI8;

use super::rtn;

/// GPTQ-quantize `w` [M,K] given calibration activations `x` [N,K].
/// Returns (codes, per-row scales).
pub fn gptq_quantize(w: &Mat, x: &Mat, damp: f32, block: usize) -> Result<(MatI8, Vec<f32>)> {
    let (m, k) = (w.rows, w.cols);
    assert_eq!(x.cols, k);

    // H = 2 X^T X (+ damping), accumulated in f64 to match python/numpy
    let mut h64 = vec![0.0f64; k * k];
    for r in 0..x.rows {
        let row = x.row(r);
        for i in 0..k {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut h64[i * k..(i + 1) * k];
            for (hv, &xj) in hrow.iter_mut().zip(row) {
                *hv += 2.0 * xi * (xj as f64);
            }
        }
    }
    let dmean = {
        let d: f64 = (0..k).map(|i| h64[i * k + i]).sum::<f64>() / k as f64;
        if d <= 0.0 {
            1.0
        } else {
            d
        }
    };
    for i in 0..k {
        if h64[i * k + i] <= 0.0 {
            h64[i * k + i] = dmean;
        }
        h64[i * k + i] += damp as f64 * dmean;
    }

    // upper Cholesky factor U of H^{-1}: Hinv = L L^T, U = L^T
    let h: Vec<f32> = h64.iter().map(|&v| v as f32).collect();
    let hinv = invert_spd(&h, k)?;
    let l = cholesky_lower(&hinv, k)?;
    // u[i][j] = l[j][i]  (upper)
    let u_at = |i: usize, j: usize| l[j * k + i] as f64;

    // fixed per-row scales from the *original* weights
    let mut scales = vec![0.0f32; m];
    for r in 0..m {
        scales[r] = rtn::scale_for(w.row(r).iter().fold(0.0f32, |a, &v| a.max(v.abs())));
    }

    // f64 working copy (python works in float64 end-to-end)
    let mut work: Vec<f64> = w.data.iter().map(|&v| v as f64).collect();
    let mut q = MatI8::zeros(m, k);
    let mut b0 = 0;
    while b0 < k {
        let b1 = (b0 + block).min(k);
        // per-column quantize + in-block error propagation
        let mut err_block = vec![0.0f64; m * (b1 - b0)];
        for j in b0..b1 {
            let d = u_at(j, j);
            for r in 0..m {
                let col = work[r * k + j];
                let qc = rtn::quantize_one(col as f32, scales[r]);
                q.data[r * k + j] = qc;
                let e = (col - qc as f64 * scales[r] as f64) / d;
                err_block[r * (b1 - b0) + (j - b0)] = e;
                // update the remainder of the block for this row
                for jj in j + 1..b1 {
                    work[r * k + jj] -= e * u_at(j, jj);
                }
            }
        }
        // propagate the block's error to the tail columns
        if b1 < k {
            for r in 0..m {
                for j in b0..b1 {
                    let e = err_block[r * (b1 - b0) + (j - b0)];
                    if e == 0.0 {
                        continue;
                    }
                    for jj in b1..k {
                        work[r * k + jj] -= e * u_at(j, jj);
                    }
                }
            }
        }
        b0 = b1;
    }
    Ok((q, scales))
}

/// Relative output MSE of a quantized layer on a calibration batch.
pub fn layer_error(w: &Mat, wq: &MatI8, scales: &[f32], x: &Mat) -> f32 {
    let y = gemm_f32_bt(x, w);
    let mut wdq = Mat::zeros(w.rows, w.cols);
    for r in 0..w.rows {
        for c in 0..w.cols {
            wdq.data[r * w.cols + c] = wq.data[r * w.cols + c] as f32 * scales[r];
        }
    }
    let yq = gemm_f32_bt(x, &wdq);
    let num: f32 = y
        .data
        .iter()
        .zip(&yq.data)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    let den: f32 = y.data.iter().map(|a| a * a).sum::<f32>() + 1e-12;
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn correlated_calib(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        let mut x = Mat::from_vec(n, k, rng.normal_vec(n * k));
        let gains: Vec<f32> = (0..k).map(|_| rng.normal().exp()).collect();
        for i in 0..n {
            for (v, g) in x.row_mut(i).iter_mut().zip(&gains) {
                *v *= g;
            }
        }
        x
    }

    #[test]
    fn beats_rtn_on_calibration() {
        let mut rng = Pcg::new(0);
        let w = Mat::from_vec(16, 48, rng.normal_vec(16 * 48));
        let x = correlated_calib(128, 48, 1);
        let (qg, sg) = gptq_quantize(&w, &x, 0.01, 16).unwrap();
        let (qr, sr) = rtn::quant_per_channel_w(&w);
        let eg = layer_error(&w, &qg, &sg, &x);
        let er = layer_error(&w, &qr, &sr, &x);
        assert!(eg <= er * 1.001, "gptq {eg} vs rtn {er}");
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Pcg::new(2);
        let w = Mat::from_vec(8, 32, rng.normal_vec(8 * 32));
        let x = correlated_calib(64, 32, 3);
        let (q, s) = gptq_quantize(&w, &x, 0.01, 8).unwrap();
        assert!(q.data.iter().all(|&c| c.abs() <= 7));
        assert!(s.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn deterministic() {
        let mut rng = Pcg::new(4);
        let w = Mat::from_vec(4, 16, rng.normal_vec(64));
        let x = correlated_calib(32, 16, 5);
        let a = gptq_quantize(&w, &x, 0.01, 4).unwrap();
        let b = gptq_quantize(&w, &x, 0.01, 4).unwrap();
        assert_eq!(a.0.data, b.0.data);
    }
}
