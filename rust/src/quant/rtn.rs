//! Symmetric round-to-nearest INT4 quantization (paper section 2.1).
//!
//! `q = clip(round(x / s), -7, 7)`, `s = absmax / 7`, matching
//! python/compile/kernels/ref.py bit-for-bit.  numpy/XLA round
//! half-to-even, while `f32::round` rounds half-away-from-zero, so we
//! implement banker's rounding explicitly — this keeps the rust engine
//! and the Pallas kernel code-exact on the golden vectors.

use crate::linalg::gemm::Mat;
use crate::linalg::igemm::MatI8;

use super::QMAX;

/// Round half-to-even (numpy/IEEE default), as f32.
///
/// Branch-free magic-number form: adding 1.5*2^23 forces the mantissa to
/// drop all fractional bits under the default (round-half-even) FP
/// rounding mode; subtracting recovers the integral value.  Valid for
/// |x| < 2^22, far beyond the [-7, 7] quantization range — and it
/// autovectorizes, which the branchy form does not.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    const MAGIC: f32 = 12_582_912.0; // 1.5 * 2^23
    (x + MAGIC) - MAGIC
}

/// Quantization scale for a group with absolute maximum `absmax`, at an
/// arbitrary symmetric max code (7 = INT4, 127 = INT8).
#[inline]
pub fn scale_for_q(absmax: f32, qmax: f32) -> f32 {
    absmax.max(1e-8) / qmax
}

/// Quantization scale for a group with absolute maximum `absmax`.
#[inline]
pub fn scale_for(absmax: f32) -> f32 {
    scale_for_q(absmax, QMAX)
}

/// Quantize one value against a scale at an arbitrary max code.
#[inline]
pub fn quantize_one_q(x: f32, scale: f32, qmax: f32) -> i8 {
    round_half_even(x / scale).clamp(-qmax, qmax) as i8
}

/// Quantize one value against a scale.
#[inline]
pub fn quantize_one(x: f32, scale: f32) -> i8 {
    quantize_one_q(x, scale, QMAX)
}

/// Quantize a row against one scale at an arbitrary max code (hot path;
/// true division keeps bit-parity with the python oracle, and still
/// autovectorizes).
#[inline]
pub fn quantize_row_q(src: &[f32], scale: f32, qmax: f32, dst: &mut [i8]) {
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = round_half_even(x / scale).clamp(-qmax, qmax) as i8;
    }
}

/// Quantize a row against one scale (INT4).
#[inline]
pub fn quantize_row(src: &[f32], scale: f32, dst: &mut [i8]) {
    quantize_row_q(src, scale, QMAX, dst);
}

/// Per-token (row) symmetric quantization at an arbitrary max code:
/// returns (codes, per-row scales).  `qmax = 7` is the INT4 path the
/// goldens lock; `qmax = 127` is the W4A8 activation path.
pub fn quant_per_token_q(x: &Mat, qmax: f32) -> (MatI8, Vec<f32>) {
    let mut q = MatI8::zeros(x.rows, x.cols);
    let mut scales = vec![0.0f32; x.rows];
    for i in 0..x.rows {
        let row = x.row(i);
        let s =
            scale_for_q(row.iter().fold(0.0f32, |a, &v| a.max(v.abs())), qmax);
        scales[i] = s;
        let qrow = &mut q.data[i * x.cols..(i + 1) * x.cols];
        quantize_row_q(row, s, qmax, qrow);
    }
    (q, scales)
}

/// Per-token (row) symmetric INT4: returns (codes, per-row scales).
pub fn quant_per_token(x: &Mat) -> (MatI8, Vec<f32>) {
    quant_per_token_q(x, QMAX)
}

/// Per-output-channel weight quantization = per-row on a [M,K] weight.
pub fn quant_per_channel_w(w: &Mat) -> (MatI8, Vec<f32>) {
    quant_per_token(w)
}

/// Sub-channel quantization: groups of `group` along K, scales [rows, K/group].
pub fn quant_sub_channel(x: &Mat, group: usize) -> (MatI8, Vec<f32>) {
    assert_eq!(x.cols % group, 0, "K={} % group={}", x.cols, group);
    let g = x.cols / group;
    let mut q = MatI8::zeros(x.rows, x.cols);
    let mut scales = vec![0.0f32; x.rows * g];
    for i in 0..x.rows {
        let row = x.row(i);
        for gi in 0..g {
            let seg = &row[gi * group..(gi + 1) * group];
            let s = scale_for(seg.iter().fold(0.0f32, |a, &v| a.max(v.abs())));
            scales[i * g + gi] = s;
            let qseg =
                &mut q.data[i * x.cols + gi * group..i * x.cols + (gi + 1) * group];
            for (qv, &v) in qseg.iter_mut().zip(seg) {
                *qv = quantize_one(v, s);
            }
        }
    }
    (q, scales)
}

/// Dequantize per-token codes back to f32.
pub fn dequant_per_token(q: &MatI8, scales: &[f32]) -> Mat {
    let mut out = Mat::zeros(q.rows, q.cols);
    for i in 0..q.rows {
        let s = scales[i];
        let src = q.row(i);
        let dst = out.row_mut(i);
        for (d, &c) in dst.iter_mut().zip(src) {
            *d = c as f32 * s;
        }
    }
    out
}

/// Fake-quantize (quantize+dequantize) per-token at an arbitrary max code.
pub fn fake_quant_per_token_q(x: &Mat, qmax: f32) -> Mat {
    let (q, s) = quant_per_token_q(x, qmax);
    dequant_per_token(&q, &s)
}

/// Fake-quantize (quantize+dequantize) per-token — used for A4W16 paths.
pub fn fake_quant_per_token(x: &Mat) -> Mat {
    fake_quant_per_token_q(x, QMAX)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    #[test]
    fn codes_bounded_and_absmax_hits_7() {
        check("rtn-bounds", Config::default(), |rng, _| {
            let n = 2 + rng.below(6);
            let k = 8 * (1 + rng.below(8));
            let data = rng.normal_vec(n * k);
            let x = Mat::from_vec(n, k, data);
            let (q, s) = quant_per_token(&x);
            for i in 0..n {
                let row = q.row(i);
                if row.iter().any(|&c| c.abs() > 7) {
                    return Err("code out of range".into());
                }
                if row.iter().map(|&c| c.abs()).max().unwrap() != 7 {
                    return Err("absmax code must be 7".into());
                }
                if s[i] <= 0.0 {
                    return Err("scale must be positive".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_error_bound() {
        check("rtn-roundtrip", Config::default(), |rng, _| {
            let x = Mat::from_vec(4, 32, rng.normal_vec(128));
            let (q, s) = quant_per_token(&x);
            let xr = dequant_per_token(&q, &s);
            for i in 0..4 {
                for j in 0..32 {
                    let err = (x.at(i, j) - xr.at(i, j)).abs();
                    if err > s[i] / 2.0 + 1e-6 {
                        return Err(format!("err {err} > half-step {}", s[i] / 2.0));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sub_channel_refines() {
        // with a channel outlier, sub-channel quantization has lower
        // roundtrip error than per-token
        check("subchannel-refines", Config { cases: 16, ..Default::default() },
            |rng, _| {
                let mut data = rng.normal_vec(4 * 64);
                for r in 0..4 {
                    data[r * 64 + 3] *= 100.0;
                }
                let x = Mat::from_vec(4, 64, data);
                let (qt, st) = quant_per_token(&x);
                let (qs, ss) = quant_sub_channel(&x, 16);
                let ert = err(&x, &dequant_per_token(&qt, &st));
                let mut xs = Mat::zeros(4, 64);
                for i in 0..4 {
                    for j in 0..64 {
                        xs.data[i * 64 + j] =
                            qs.data[i * 64 + j] as f32 * ss[i * 4 + j / 16];
                    }
                }
                let ers = err(&x, &xs);
                if ers <= ert {
                    Ok(())
                } else {
                    Err(format!("sub {ers} > per-token {ert}"))
                }
            });

        fn err(a: &Mat, b: &Mat) -> f32 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| (x - y).abs())
                .sum::<f32>()
        }
    }

    #[test]
    fn zero_row_safe() {
        let x = Mat::zeros(2, 8);
        let (q, s) = quant_per_token(&x);
        assert!(q.data.iter().all(|&c| c == 0));
        assert!(s.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn int8_codes_bounded_and_no_worse_than_int4() {
        check("rtn-int8", Config::default(), |rng, _| {
            let x = Mat::from_vec(4, 32, rng.normal_vec(128));
            let (q8, s8) = quant_per_token_q(&x, crate::quant::QMAX8);
            let (q4, s4) = quant_per_token(&x);
            for i in 0..4 {
                let row = q8.row(i);
                if row.iter().any(|&c| (c as i32).abs() > 127) {
                    return Err("int8 code out of range".into());
                }
                if row.iter().map(|&c| (c as i32).abs()).max().unwrap() != 127 {
                    return Err("absmax code must be 127".into());
                }
                let mut sum8 = 0.0f32;
                let mut sum4 = 0.0f32;
                for j in 0..32 {
                    let e8 = (x.at(i, j) - q8.row(i)[j] as f32 * s8[i]).abs();
                    let e4 = (x.at(i, j) - q4.row(i)[j] as f32 * s4[i]).abs();
                    if e8 > s8[i] / 2.0 + 1e-6 {
                        return Err(format!("int8 err {e8} > half-step"));
                    }
                    sum8 += e8;
                    sum4 += e4;
                }
                if sum8 > sum4 + 1e-6 {
                    return Err(format!("int8 row err {sum8} > int4 {sum4}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn qmax7_variants_are_the_legacy_functions() {
        let x = Mat::from_vec(2, 16, (0..32).map(|i| (i as f32).sin()).collect());
        let (qa, sa) = quant_per_token(&x);
        let (qb, sb) = quant_per_token_q(&x, QMAX);
        assert_eq!(qa.data, qb.data);
        assert_eq!(sa, sb);
        assert_eq!(scale_for(3.2), scale_for_q(3.2, QMAX));
        assert_eq!(quantize_one(1.7, 0.3), quantize_one_q(1.7, 0.3, QMAX));
    }
}
