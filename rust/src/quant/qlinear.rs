//! Quantized linear layers: offline weight preparation + the runtime GEMM
//! paths for every method in the paper.  This module is the rust analogue
//! of the fused CUDA kernel pipeline (Fig. 4) and the basis of the
//! Figure-6 efficiency comparison:
//!
//! * `forward_per_channel_q`     — plain per-token x per-channel INT4/INT8
//!   GEMM (the QuaRot/SpinQuant kernel setting; `qmax` selects A4 or A8).
//! * `forward_sub_channel_a4w4`  — group-wise scales on both operands
//!   (the paper's costly baseline: scale *matrices* move through the
//!   epilogue).
//! * `forward_rs_fused`          — Runtime-Smooth fused GEMM: one scalar
//!   group scale per K-block in the epilogue (negligible overhead claim).
//!
//! [`QLinear`] bundles a prepared weight with a [`QuantRecipe`] and
//! dispatches on the recipe's independent axes — smoothing (none /
//! runtime / calibrated), rotation (none / Hadamard / dense), activation
//! precision (INT4 / INT8 / fp) — instead of a closed method enum, so
//! combinations the named methods never paired (SmoothQuant + Hadamard,
//! runtime smooth at INT8, ...) run through the same code paths.  The
//! legacy [`Method`]-driven [`QLinear::prepare`] is a thin wrapper that
//! maps the method to its recipe; every legacy route stays bit-identical
//! (asserted by `rust/tests/golden.rs`).
//!
//! INT4/INT8 runtime paths go through the [`crate::kernels`] registry:
//! weights are nibble-packed offline ([`PackedI4`]) and the dispatched
//! microkernel consumes them directly.  The free `forward_*` functions
//! below are the *staged scalar references* those kernels are diffed
//! against (`rust/tests/kernel_diff.rs`) — they keep the original loops
//! on purpose.

use std::sync::Arc;

use anyhow::Result;

use crate::kernels;
use crate::linalg::gemm::{gemm_f32_bt, Mat};
use crate::linalg::igemm::{idot, MatI8};
use crate::quant::pack4::PackedI4;
use crate::util::threadpool;

use super::recipe::{QuantRecipe, RotationKind, Smoothing};
use super::rotation::Rotation;
use super::rtn;
use super::runtime_smooth::{self, SmoothedAct};
use super::{gptq, smoothquant, Method, Scheme, QMAX, QMAX8};

/// Offline-prepared weight.
#[derive(Clone, Debug)]
pub enum PreparedWeight {
    /// Full-precision (possibly rotated / smooth-merged) weight.
    Fp(Mat),
    /// Per-output-channel INT4 (RTN or GPTQ).  `packed` is the
    /// nibble-packed mirror of `q` the [`crate::kernels`] GEMMs consume
    /// directly (half the weight traffic of the i8 codes).  It is only
    /// materialized for recipes that serve the per-channel path; the
    /// runtime-smoothed recipes instead pack the *permuted* weight into
    /// the sticky perm cache, so a second copy here would be dead
    /// memory.
    Int4 { q: MatI8, packed: Option<PackedI4>, scales: Vec<f32> },
}

impl PreparedWeight {
    /// Quantized weight from i8 codes; `pack` materializes the
    /// nibble-packed mirror for the per-channel serving path.
    fn int4(q: MatI8, scales: Vec<f32>, pack: bool) -> PreparedWeight {
        let packed = pack.then(|| PackedI4::pack(&q));
        PreparedWeight::Int4 { q, packed, scales }
    }
}

impl PreparedWeight {
    pub fn out_features(&self) -> usize {
        match self {
            PreparedWeight::Fp(w) => w.rows,
            PreparedWeight::Int4 { q, .. } => q.rows,
        }
    }

    pub fn in_features(&self) -> usize {
        match self {
            PreparedWeight::Fp(w) => w.cols,
            PreparedWeight::Int4 { q, .. } => q.cols,
        }
    }
}

/// Options for offline preparation (legacy [`Method`]-keyed surface;
/// mapped onto a [`QuantRecipe`] internally).
pub struct PrepareOpts<'a> {
    pub method: Method,
    pub scheme: Scheme,
    /// Runtime-Smooth group size (1 = exact per-channel scale).
    pub group: usize,
    /// SmoothQuant alpha.
    pub alpha: f32,
    /// SmoothQuant calibration (required for Method::SmoothQuant).
    pub calib: Option<&'a smoothquant::Calibration>,
    /// GPTQ calibration activations in the *method's* space (already
    /// rotated for quarot/rrs/spinquant); None -> RTN weights.
    pub gptq_calib: Option<&'a Mat>,
    /// Rotation for quarot/rrs/spinquant (defaults to Hadamard).
    pub rotation: Option<Rotation>,
}

impl<'a> Default for PrepareOpts<'a> {
    fn default() -> Self {
        PrepareOpts {
            method: Method::Rrs,
            scheme: Scheme::A4W4KV16,
            group: 128,
            alpha: 0.5,
            calib: None,
            gptq_calib: None,
            rotation: None,
        }
    }
}

/// Calibration side-inputs for [`QLinear::prepare_recipe`] — everything
/// a recipe may need that is not derivable from the weight itself.
#[derive(Default)]
pub struct PrepareAux<'a> {
    /// Activation calibration for [`Smoothing::Calibrated`].
    pub calib: Option<&'a smoothquant::Calibration>,
    /// GPTQ calibration activations in the recipe's space (already
    /// rotated for rotated recipes); None -> RTN weights.
    pub gptq_calib: Option<&'a Mat>,
    /// Explicit rotation override; None synthesizes one from the
    /// recipe's [`RotationKind`] and the weight's K dimension.
    pub rotation: Option<Rotation>,
}

/// Deterministic seed for closed-form dense rotation synthesis (QuaRot
/// eq. 2 style: block Hadamard with random sign flips).  Keyed on K so
/// different widths get different sign patterns while every prepare of
/// the same width agrees.
fn dense_rotation_seed(k: usize) -> u64 {
    0xC0DE ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// A linear layer prepared for quantized inference.
pub struct QLinear {
    /// The composed strategy this layer was prepared under.
    pub recipe: QuantRecipe,
    pub weight: PreparedWeight,
    /// Calibrated (SmoothQuant-style) activation divisors.
    pub smooth: Option<Vec<f32>>,
    /// Activation-side rotation (weight was rotated offline).
    pub rotation: Option<Rotation>,
    /// Sticky reorder cache: channel maxima ordering is stable across
    /// decode steps, so the permuted + re-packed weight is reused until
    /// the runtime permutation actually changes (big win: the gather is
    /// comparable to the GEMM itself at decode batch sizes).
    perm_cache: std::sync::Mutex<Option<(Vec<usize>, Arc<PackedI4>)>>,
    /// Observability label (e.g. `l3.wq`): installed as the thread's
    /// layer scope for the duration of [`QLinear::forward`] so sampled
    /// quant-health probes ([`crate::obs::health`]) land on this layer's
    /// bucket.  `None` (the default) inherits the caller's scope.
    pub probe: Option<String>,
}

impl QLinear {
    /// Offline preparation from the legacy method surface: maps the
    /// method onto its [`QuantRecipe`] and delegates to
    /// [`QLinear::prepare_recipe`].  Bit-identical to the historical
    /// method-keyed preparation on every route.
    pub fn prepare(w: &Mat, opts: &PrepareOpts) -> Result<QLinear> {
        let recipe = QuantRecipe::from_method(
            opts.method,
            opts.scheme,
            opts.group.max(1),
            128,
            opts.alpha,
            opts.gptq_calib.is_some(),
        );
        Self::prepare_recipe(
            w,
            &recipe,
            PrepareAux {
                calib: opts.calib,
                gptq_calib: opts.gptq_calib,
                rotation: opts.rotation.clone(),
            },
        )
    }

    /// Offline preparation from a composed [`QuantRecipe`]: validate,
    /// resolve the rotation against the weight's K dimension (never a
    /// runtime panic — non-power-of-two K gets the block-Hadamard
    /// fallback or a prepare-time error), merge calibrated smoothing,
    /// rotate, quantize.
    pub fn prepare_recipe(
        w: &Mat,
        recipe: &QuantRecipe,
        aux: PrepareAux,
    ) -> Result<QLinear> {
        recipe.validate()?;
        let k = w.cols;
        let rotation = match recipe.rotation {
            RotationKind::None => None,
            RotationKind::Hadamard => Some(
                aux.rotation
                    .clone()
                    .unwrap_or_else(|| Rotation::hadamard_for(k)),
            ),
            RotationKind::Dense => Some(aux.rotation.clone().unwrap_or_else(
                || Rotation::closed_form_dense(k, dense_rotation_seed(k)),
            )),
        };
        if let Some(r) = &rotation {
            r.validate(k)?;
        }
        let mut smooth = None;
        // calibrated smoothing merges in the ORIGINAL channel basis; the
        // rotation is then applied to the merged weight (activations are
        // divided, then rotated, in the same order at runtime)
        let mut w_eff = match recipe.smoothing {
            Smoothing::Calibrated => {
                let calib = aux.calib.ok_or_else(|| {
                    anyhow::anyhow!("calibrated smoothing needs calibration")
                })?;
                let s = smoothquant::smoothing_scales(calib, w, recipe.alpha);
                let merged = smoothquant::merge_into_weight(w, &s);
                smooth = Some(s);
                merged
            }
            _ => w.clone(),
        };
        if let Some(r) = &rotation {
            w_eff = r.apply(&w_eff);
        }
        if recipe.migrate {
            // keep the fp weight: it is re-merged + re-quantized per call
            return Ok(QLinear {
                recipe: *recipe,
                weight: PreparedWeight::Fp(w_eff),
                smooth: None,
                rotation: None,
                perm_cache: std::sync::Mutex::new(None),
                probe: None,
            });
        }
        let weight = if recipe.w_bits == 4 {
            let (q, scales) = match aux.gptq_calib {
                Some(x) => gptq::gptq_quantize(&w_eff, x, 0.01, 64)?,
                None => rtn::quant_per_channel_w(&w_eff),
            };
            // runtime-smoothed recipes serve through the permuted
            // perm-cache packing
            PreparedWeight::int4(
                q,
                scales,
                recipe.smoothing != Smoothing::Runtime,
            )
        } else {
            PreparedWeight::Fp(w_eff)
        };
        Ok(QLinear {
            recipe: *recipe,
            weight,
            smooth,
            rotation,
            perm_cache: std::sync::Mutex::new(None),
            probe: None,
        })
    }

    /// Assemble a layer from already-prepared parts (golden tests /
    /// checkpoint loaders; `perm_cache` starts cold).
    pub fn from_parts(
        recipe: QuantRecipe,
        weight: PreparedWeight,
        smooth: Option<Vec<f32>>,
        rotation: Option<Rotation>,
    ) -> QLinear {
        QLinear {
            recipe,
            weight,
            smooth,
            rotation,
            perm_cache: std::sync::Mutex::new(None),
            probe: None,
        }
    }

    /// Closest legacy [`Method`] for this layer's recipe.
    pub fn method(&self) -> Method {
        self.recipe.method()
    }

    /// Runtime forward: `y = recipe(x) @ W^T` with the recipe's
    /// smoothing, rotation and activation quantization applied in
    /// pipeline order (divide by calibrated scales, rotate, then either
    /// the runtime-smooth fused path or the per-channel path).
    pub fn forward(&self, x: &Mat) -> Mat {
        let _layer = crate::obs::layer_scope(self.probe.as_deref());
        if self.recipe.migrate {
            return self.rs_migrated_forward(x);
        }
        let smoothed;
        let mut cur = x;
        if let Some(s) = &self.smooth {
            smoothed = smoothquant::smooth_activation(cur, s);
            cur = &smoothed;
        }
        let rotated;
        if let Some(r) = &self.rotation {
            rotated = r.apply(cur);
            cur = &rotated;
        }
        if self.recipe.smoothing == Smoothing::Runtime {
            self.rs_forward(cur)
        } else {
            self.act_quant_gemm(cur)
        }
    }

    /// Fig. 3 ablation: runtime channel scales *merged into the weight*
    /// each call — the migration scheme that breaks at INT4 (the shared
    /// outliers make W·diag(s) hard to quantize).
    fn rs_migrated_forward(&self, x: &Mat) -> Mat {
        let PreparedWeight::Fp(w) = &self.weight else {
            panic!("migrated recipes keep fp weights");
        };
        let s = runtime_smooth::channel_scales(x);
        let xs = smoothquant::smooth_activation(x, &s);
        let wm = smoothquant::merge_into_weight(w, &s);
        if self.recipe.w_bits == 4 {
            let (wq, sw) = rtn::quant_per_channel_w(&wm);
            forward_per_channel_q(&xs, &wq, &sw, self.recipe.a_qmax())
        } else {
            let xdq = rtn::fake_quant_per_token_q(&xs, self.recipe.a_qmax());
            gemm_f32_bt(&xdq, &wm)
        }
    }

    /// Runtime-Smooth path at the recipe's activation precision: fused
    /// prologue + fused GEMM on the dispatched kernel backend —
    /// bit-identical to the staged reference path (asserted by
    /// `rust/tests/kernel_diff.rs`).
    fn rs_forward(&self, x: &Mat) -> Mat {
        let group = effective_group(self.recipe.group, x.cols);
        let qmax = self.recipe.a_qmax();
        match &self.weight {
            PreparedWeight::Int4 { q, scales, .. } => {
                let sa = runtime_smooth::prepare_q(x, group, qmax);
                let wqp = {
                    let mut cache = crate::obs::lock_recover(&self.perm_cache);
                    match cache.as_ref() {
                        Some((perm, wqp)) if *perm == sa.perm => wqp.clone(),
                        _ => {
                            let permuted = q.permute_cols(&sa.perm);
                            let wqp = Arc::new(PackedI4::pack(&permuted));
                            *cache = Some((sa.perm.clone(), wqp.clone()));
                            wqp
                        }
                    }
                };
                kernels::gemm_rs_fused_packed(
                    &sa.q,
                    &sa.token_scales,
                    sa.group,
                    &sa.group_scales,
                    &wqp,
                    scales,
                )
            }
            PreparedWeight::Fp(w) => {
                // AxW16: activation-only quantization
                let xdq = runtime_smooth::fake_quant_rs_q(x, group, qmax);
                gemm_f32_bt(&xdq, w)
            }
        }
    }

    /// Per-channel path at the recipe's activation precision: INT8
    /// activations route to the W4A8 kernel entry, INT4 to the classic
    /// per-channel GEMM, full-precision recipes skip activation
    /// quantization entirely.
    fn act_quant_gemm(&self, x: &Mat) -> Mat {
        let qmax = self.recipe.a_qmax();
        match &self.weight {
            PreparedWeight::Int4 { q, packed, scales } => match packed {
                Some(p) => {
                    let (xq, sx) = rtn::quant_per_token_q(x, qmax);
                    if crate::obs::health::sampled() {
                        let layer = crate::obs::current_layer_or("act_quant");
                        crate::obs::health::probe_quant_q(&layer, x, &xq, qmax);
                    }
                    if self.recipe.a_bits == 8 {
                        kernels::gemm_w4a8_packed(&xq, &sx, p, scales)
                    } else {
                        kernels::gemm_per_channel_packed(&xq, &sx, p, scales)
                    }
                }
                // runtime-smoothed weights skip the packed mirror; this
                // path is unreachable from their dispatch but stays
                // correct
                None => forward_per_channel_q(x, q, scales, qmax),
            },
            PreparedWeight::Fp(w) => {
                if self.recipe.quantizes_acts() {
                    let xdq = rtn::fake_quant_per_token_q(x, qmax);
                    gemm_f32_bt(&xdq, w)
                } else {
                    gemm_f32_bt(x, w)
                }
            }
        }
    }

    pub fn out_features(&self) -> usize {
        self.weight.out_features()
    }
}

/// Clamp the RS group to the largest divisor of K that is <= `group`.
pub fn effective_group(group: usize, k: usize) -> usize {
    let mut g = group.min(k).max(1);
    while k % g != 0 {
        g -= 1;
    }
    g
}

/// Per-channel AxW4 at an explicit symmetric max activation code
/// (7 = A4, 127 = A8): per-token integer activation x per-channel INT4
/// weight.  Staged scalar reference — [`QLinear`] serves this path
/// through [`crate::kernels::gemm_per_channel_packed`] /
/// [`crate::kernels::gemm_w4a8_packed`], which must match this
/// bit-for-bit.
pub fn forward_per_channel_q(
    x: &Mat,
    wq: &MatI8,
    sw: &[f32],
    qmax: f32,
) -> Mat {
    let (xq, sx) = rtn::quant_per_token_q(x, qmax);
    let (n, k, m) = (xq.rows, xq.cols, wq.rows);
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, orow| {
        let arow = &xq.data[i * k..(i + 1) * k];
        let sxi = sx[i];
        for (j, o) in orow.iter_mut().enumerate() {
            let acc = idot(arow, &wq.data[j * k..(j + 1) * k]);
            *o = acc as f32 * sxi * sw[j];
        }
    });
    out
}

/// Per-channel A4W4 (the QuaRot/SpinQuant kernel setting).
pub fn forward_per_channel_a4w4(x: &Mat, wq: &MatI8, sw: &[f32]) -> Mat {
    forward_per_channel_q(x, wq, sw, QMAX)
}

/// Per-channel A8W4 — the staged oracle for the W4A8 microkernel entry
/// ([`crate::kernels::gemm_w4a8_packed`], diffed in
/// `rust/tests/kernel_diff.rs`).
pub fn forward_per_channel_a8w4(x: &Mat, wq: &MatI8, sw: &[f32]) -> Mat {
    forward_per_channel_q(x, wq, sw, QMAX8)
}

/// Sub-channel A4W4: per-group scales for both operands — the expensive
/// baseline of Figure 6 (scale *matrices* in the epilogue).
pub fn forward_sub_channel_a4w4(x: &Mat, w: &Mat, group: usize) -> Mat {
    let g = effective_group(group, x.cols);
    let (xq, sx) = rtn::quant_sub_channel(x, g);
    let (wq, sw) = rtn::quant_sub_channel(w, g);
    forward_sub_channel_prequant(&xq, &sx, &wq, &sw, g)
}

/// Sub-channel GEMM over pre-quantized operands (bench hot path).
pub fn forward_sub_channel_prequant(
    xq: &MatI8,
    sx: &[f32],
    wq: &MatI8,
    sw: &[f32],
    group: usize,
) -> Mat {
    let (n, k, m) = (xq.rows, xq.cols, wq.rows);
    let ng = k / group;
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, orow| {
        let arow = &xq.data[i * k..(i + 1) * k];
        let sxi = &sx[i * ng..(i + 1) * ng];
        // combined per-(i,j) group scales: this extra NG-vector build per
        // output element is exactly the "scale matrices move through the
        // epilogue" cost the paper charges sub-channel quantization with
        let mut combined = vec![0.0f32; ng];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &wq.data[j * k..(j + 1) * k];
            let swj = &sw[j * ng..(j + 1) * ng];
            for (c, (&a, &b)) in combined.iter_mut().zip(sxi.iter().zip(swj)) {
                *c = a * b;
            }
            *o = crate::linalg::igemm::idot_grouped(arow, brow, group, &combined);
        }
    });
    out
}

/// Runtime-Smooth fused GEMM (Fig. 4 step 3): per-K-block integer partial
/// times ONE scalar group scale, epilogue applies token x channel scales.
/// `wq` is the offline-quantized weight in ORIGINAL channel order; the
/// smoothed activation's permutation is applied to the weight columns here
/// (the CUDA kernel gathers; we gather once per call).
pub fn forward_rs_fused(sa: &SmoothedAct, wq: &MatI8, sw: &[f32]) -> Mat {
    let wqp = wq.permute_cols(&sa.perm);
    forward_rs_fused_prepermuted(sa, &wqp, sw)
}

/// Fused RS GEMM when the weight is already in the reordered layout
/// (staged scalar reference; [`QLinear`] serves this path through
/// [`crate::kernels::gemm_rs_fused_packed`], which must match this
/// bit-for-bit).
pub fn forward_rs_fused_prepermuted(
    sa: &SmoothedAct,
    wqp: &MatI8,
    sw: &[f32],
) -> Mat {
    let (n, k, m) = (sa.q.rows, sa.q.cols, wqp.rows);
    let group = sa.group;
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, orow| {
        let arow = &sa.q.data[i * k..(i + 1) * k];
        let sxi = sa.token_scales[i];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &wqp.data[j * k..(j + 1) * k];
            let acc = crate::linalg::igemm::idot_grouped(
                arow, brow, group, &sa.group_scales,
            );
            *o = acc * sxi * sw[j];
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, check, Config};
    use crate::util::rng::Pcg;

    fn randmat(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(n, k, rng.normal_vec(n * k))
    }

    /// Activations with consistent channel-wise outliers + one spike.
    fn llm_like_act(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        let mut x = Mat::from_vec(n, k, rng.normal_vec(n * k));
        for i in 0..n {
            x.data[i * k + 3] = 60.0 * (1.0 + 0.05 * rng.normal());
            x.data[i * k + k / 2] = -35.0 * (1.0 + 0.05 * rng.normal());
        }
        x.data[k + 7] = 400.0; // spike in token 1
        x
    }

    #[test]
    fn rs_fused_matches_unfused_math() {
        // the fused kernel computes sum_g sg (Xq_g . Wq_g) * sx * sw, which
        // must equal explicitly dequantizing and multiplying
        check("rs-fused-exact", Config { cases: 16, ..Default::default() },
            |rng, case| {
                let n = 2 + rng.below(6);
                let k = 64;
                let group = [1, 8, 16, 64][case % 4];
                let x = randmat(n, k, case as u64);
                let w = randmat(12, k, case as u64 + 99);
                let (wq, sw) = rtn::quant_per_channel_w(&w);
                let sa = runtime_smooth::prepare(&x, group);
                let got = forward_rs_fused(&sa, &wq, &sw);
                // reference: dequantize the smoothed activation fully
                let mut xdq = Mat::zeros(n, k);
                for i in 0..n {
                    for j in 0..k {
                        xdq.data[i * k + sa.perm[j]] = sa.q.data[i * k + j] as f32
                            * sa.token_scales[i]
                            * sa.group_scales[j / group];
                    }
                }
                let mut wdq = Mat::zeros(12, k);
                for r in 0..12 {
                    for c in 0..k {
                        wdq.data[r * k + c] = wq.data[r * k + c] as f32 * sw[r];
                    }
                }
                let want = gemm_f32_bt(&xdq, &wdq);
                assert_close(&got.data, &want.data, 1e-3, 1e-4)
            });
    }

    #[test]
    fn all_methods_finite_and_correlated() {
        let x = llm_like_act(16, 128, 1);
        let w = randmat(32, 128, 2);
        let y_fp = gemm_f32_bt(&x, &w);
        let calib = smoothquant::Calibration::from_batches([&x].into_iter(), 128);
        for method in Method::ALL {
            let opts = PrepareOpts {
                method,
                scheme: if method == Method::Fp {
                    Scheme::FP
                } else {
                    Scheme::A4W4KV16
                },
                group: 32,
                calib: Some(&calib),
                ..Default::default()
            };
            let lin = QLinear::prepare(&w, &opts).unwrap();
            let y = lin.forward(&x);
            assert!(y.data.iter().all(|v| v.is_finite()), "{method:?}");
            let corr = correlation(&y.data, &y_fp.data);
            assert!(corr > 0.85, "{method:?} corr={corr}");
        }
    }

    #[test]
    fn recipe_prepare_matches_method_prepare_bitwise() {
        // the method surface is a wrapper over prepare_recipe; every
        // legacy route must stay bit-identical through the recipe layer
        let x = llm_like_act(8, 128, 11);
        let w = randmat(16, 128, 12);
        let calib = smoothquant::Calibration::from_batches([&x].into_iter(), 128);
        for method in Method::ALL {
            let scheme = if method == Method::Fp {
                Scheme::FP
            } else {
                Scheme::A4W4KV16
            };
            let opts = PrepareOpts {
                method,
                scheme,
                group: 32,
                calib: Some(&calib),
                ..Default::default()
            };
            let via_method = QLinear::prepare(&w, &opts).unwrap();
            let recipe =
                QuantRecipe::from_method(method, scheme, 32, 128, 0.5, false);
            let via_recipe = QLinear::prepare_recipe(
                &w,
                &recipe,
                PrepareAux { calib: Some(&calib), ..Default::default() },
            )
            .unwrap();
            let ya = via_method.forward(&x);
            let yb = via_recipe.forward(&x);
            assert_eq!(ya.data, yb.data, "{method:?}");
        }
    }

    #[test]
    fn w4a8_recipe_cuts_activation_error() {
        // same INT4 weights, INT8 activations: the extra activation bits
        // must pay off on outlier-heavy inputs
        let x = llm_like_act(16, 128, 13);
        let w = randmat(32, 128, 14);
        let y_fp = gemm_f32_bt(&x, &w);
        let err = |spec: &str| {
            let r = QuantRecipe::parse(spec).unwrap();
            let lin =
                QLinear::prepare_recipe(&w, &r, PrepareAux::default()).unwrap();
            let y = lin.forward(&x);
            assert!(y.data.iter().all(|v| v.is_finite()), "{spec}");
            y.data
                .iter()
                .zip(&y_fp.data)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / y.data.len() as f32
        };
        let e4 = err("rtn:a4w4kv16");
        let e8 = err("rtn:a8w4kv16");
        assert!(e8 < e4, "a8 {e8} must beat a4 {e4}");
    }

    #[test]
    fn composed_recipes_run_finite_and_correlated() {
        // combinations the legacy method enum never paired
        let x = llm_like_act(8, 128, 15);
        let w = randmat(16, 128, 16);
        let y_fp = gemm_f32_bt(&x, &w);
        let calib = smoothquant::Calibration::from_batches([&x].into_iter(), 128);
        for spec in ["sq:had", "rs:a8w4kv8", "sq:a8w4kv8:had", "dense:g32"] {
            let r = QuantRecipe::parse(spec).unwrap();
            let lin = QLinear::prepare_recipe(
                &w,
                &r,
                PrepareAux { calib: Some(&calib), ..Default::default() },
            )
            .unwrap();
            let y = lin.forward(&x);
            assert!(y.data.iter().all(|v| v.is_finite()), "{spec}");
            let corr = correlation(&y.data, &y_fp.data);
            assert!(corr > 0.85, "{spec} corr={corr}");
        }
    }

    #[test]
    fn non_pow2_k_prepares_without_panicking() {
        // k=96 is not a power of two: legacy Hadamard asserted; the
        // recipe path must fall back to the block decomposition
        let x = llm_like_act(4, 96, 17);
        let w = randmat(8, 96, 18);
        for spec in ["rrs:g32", "quarot:g32", "dense:g32"] {
            let r = QuantRecipe::parse(spec).unwrap();
            let lin =
                QLinear::prepare_recipe(&w, &r, PrepareAux::default()).unwrap();
            let y = lin.forward(&x);
            assert!(y.data.iter().all(|v| v.is_finite()), "{spec}");
        }
    }

    #[test]
    fn rrs_beats_rtn_on_llm_like() {
        let x = llm_like_act(16, 128, 3);
        let w = randmat(32, 128, 4);
        let y_fp = gemm_f32_bt(&x, &w);
        let err = |m: Method, scheme: Scheme| {
            let opts = PrepareOpts { method: m, scheme, group: 32, ..Default::default() };
            let lin = QLinear::prepare(&w, &opts).unwrap();
            let y = lin.forward(&x);
            y.data
                .iter()
                .zip(&y_fp.data)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / y.data.len() as f32
        };
        // A4W4: shared weight-quant error narrows the gap but RRS still wins
        let e_rtn = err(Method::Rtn, Scheme::A4W4KV16);
        let e_rrs = err(Method::Rrs, Scheme::A4W4KV16);
        assert!(e_rrs < 0.9 * e_rtn, "A4W4: rrs {e_rrs} vs rtn {e_rtn}");
        // A4W16 isolates the activation side: the gap is decisive (Fig. 3)
        let e_rtn16 = err(Method::Rtn, Scheme::A4W16KV16);
        let e_rrs16 = err(Method::Rrs, Scheme::A4W16KV16);
        assert!(e_rrs16 < 0.7 * e_rtn16, "A4W16: rrs {e_rrs16} vs rtn {e_rtn16}");
    }

    #[test]
    fn a4w16_paths() {
        let x = llm_like_act(8, 64, 5);
        let w = randmat(16, 64, 6);
        for method in [Method::Rtn, Method::Rs, Method::Rrs, Method::QuaRot] {
            let opts = PrepareOpts {
                method,
                scheme: Scheme::A4W16KV16,
                group: 16,
                ..Default::default()
            };
            let lin = QLinear::prepare(&w, &opts).unwrap();
            assert!(matches!(lin.weight, PreparedWeight::Fp(_)));
            let y = lin.forward(&x);
            assert!(y.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn a8_staged_reference_bounds() {
        // forward_per_channel_a8w4 is the W4A8 oracle: its codes come
        // from the INT8 per-token quantizer and its output must sit
        // closer to fp than the A4 reference on outlier-heavy input
        let x = llm_like_act(6, 64, 19);
        let w = randmat(12, 64, 20);
        let (wq, sw) = rtn::quant_per_channel_w(&w);
        let y4 = forward_per_channel_a4w4(&x, &wq, &sw);
        let y8 = forward_per_channel_a8w4(&x, &wq, &sw);
        // both must agree with a dequantized-weight fp GEMM of their own
        // fake-quantized activation
        let mut wdq = Mat::zeros(12, 64);
        for r in 0..12 {
            for c in 0..64 {
                wdq.data[r * 64 + c] = wq.data[r * 64 + c] as f32 * sw[r];
            }
        }
        let want8 = gemm_f32_bt(&rtn::fake_quant_per_token_q(&x, QMAX8), &wdq);
        assert_close(&y8.data, &want8.data, 1e-3, 1e-4).unwrap();
        let want4 = gemm_f32_bt(&rtn::fake_quant_per_token(&x), &wdq);
        assert_close(&y4.data, &want4.data, 1e-3, 1e-4).unwrap();
    }

    #[test]
    fn sub_channel_matches_explicit_dequant() {
        let x = randmat(4, 64, 7);
        let w = randmat(8, 64, 8);
        let g = 16;
        let got = forward_sub_channel_a4w4(&x, &w, g);
        let (xq, sx) = rtn::quant_sub_channel(&x, g);
        let (wq, sw) = rtn::quant_sub_channel(&w, g);
        let ng = 64 / g;
        let mut want = Mat::zeros(4, 8);
        for i in 0..4 {
            for j in 0..8 {
                let mut acc = 0.0;
                for kk in 0..64 {
                    acc += xq.data[i * 64 + kk] as f32
                        * sx[i * ng + kk / g]
                        * wq.data[j * 64 + kk] as f32
                        * sw[j * ng + kk / g];
                }
                want.data[i * 8 + j] = acc;
            }
        }
        assert!(got.max_abs_diff(&want) < 1e-2);
    }

    #[test]
    fn effective_group_divides() {
        assert_eq!(effective_group(128, 64), 64);
        assert_eq!(effective_group(48, 64), 32);
        assert_eq!(effective_group(1, 64), 1);
        assert_eq!(effective_group(128, 96), 96);
    }

    fn correlation(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len() as f32;
        let ma = a.iter().sum::<f32>() / n;
        let mb = b.iter().sum::<f32>() / n;
        let mut num = 0.0;
        let mut da = 0.0;
        let mut db = 0.0;
        for (&x, &y) in a.iter().zip(b) {
            num += (x - ma) * (y - mb);
            da += (x - ma) * (x - ma);
            db += (y - mb) * (y - mb);
        }
        num / (da.sqrt() * db.sqrt() + 1e-12)
    }
}
