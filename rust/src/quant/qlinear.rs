//! Quantized linear layers: offline weight preparation + the runtime GEMM
//! paths for every method in the paper.  This module is the rust analogue
//! of the fused CUDA kernel pipeline (Fig. 4) and the basis of the
//! Figure-6 efficiency comparison:
//!
//! * `forward_per_channel_a4w4`  — plain per-token x per-channel INT4 GEMM
//!   (the QuaRot/SpinQuant kernel setting).
//! * `forward_sub_channel_a4w4`  — group-wise scales on both operands
//!   (the paper's costly baseline: scale *matrices* move through the
//!   epilogue).
//! * `forward_rs_fused`          — Runtime-Smooth fused GEMM: one scalar
//!   group scale per K-block in the epilogue (negligible overhead claim).
//!
//! [`QLinear`] bundles a prepared weight with a method and dispatches.
//! Its INT4 runtime paths go through the [`crate::kernels`] registry:
//! weights are nibble-packed offline ([`PackedI4`]) and the dispatched
//! microkernel consumes them directly.  The free `forward_*` functions
//! below are the *staged scalar references* those kernels are diffed
//! against (`rust/tests/kernel_diff.rs`) — they keep the original loops
//! on purpose.

use std::sync::Arc;

use anyhow::Result;

use crate::kernels;
use crate::linalg::gemm::{gemm_f32_bt, Mat};
use crate::linalg::igemm::{idot, MatI8};
use crate::quant::pack4::PackedI4;
use crate::util::threadpool;

use super::runtime_smooth::{self, SmoothedAct};
use super::rotation::Rotation;
use super::rtn;
use super::{gptq, smoothquant, Method, Scheme};

/// Offline-prepared weight.
#[derive(Clone, Debug)]
pub enum PreparedWeight {
    /// Full-precision (possibly rotated / smooth-merged) weight.
    Fp(Mat),
    /// Per-output-channel INT4 (RTN or GPTQ).  `packed` is the
    /// nibble-packed mirror of `q` the [`crate::kernels`] GEMMs consume
    /// directly (half the weight traffic of the i8 codes).  It is only
    /// materialized for methods that serve the per-channel path; the
    /// Runtime-Smooth methods instead pack the *permuted* weight into
    /// the sticky perm cache, so a second copy here would be dead
    /// memory.
    Int4 { q: MatI8, packed: Option<PackedI4>, scales: Vec<f32> },
}

impl PreparedWeight {
    /// Quantized weight from i8 codes; `pack` materializes the
    /// nibble-packed mirror for the per-channel serving path.
    fn int4(q: MatI8, scales: Vec<f32>, pack: bool) -> PreparedWeight {
        let packed = pack.then(|| PackedI4::pack(&q));
        PreparedWeight::Int4 { q, packed, scales }
    }
}

impl PreparedWeight {
    pub fn out_features(&self) -> usize {
        match self {
            PreparedWeight::Fp(w) => w.rows,
            PreparedWeight::Int4 { q, .. } => q.rows,
        }
    }

    pub fn in_features(&self) -> usize {
        match self {
            PreparedWeight::Fp(w) => w.cols,
            PreparedWeight::Int4 { q, .. } => q.cols,
        }
    }
}

/// Options for offline preparation.
pub struct PrepareOpts<'a> {
    pub method: Method,
    pub scheme: Scheme,
    /// Runtime-Smooth group size (1 = exact per-channel scale).
    pub group: usize,
    /// SmoothQuant alpha.
    pub alpha: f32,
    /// SmoothQuant calibration (required for Method::SmoothQuant).
    pub calib: Option<&'a smoothquant::Calibration>,
    /// GPTQ calibration activations in the *method's* space (already
    /// rotated for quarot/rrs/spinquant); None -> RTN weights.
    pub gptq_calib: Option<&'a Mat>,
    /// Rotation for quarot/rrs/spinquant (defaults to Hadamard).
    pub rotation: Option<Rotation>,
}

impl<'a> Default for PrepareOpts<'a> {
    fn default() -> Self {
        PrepareOpts {
            method: Method::Rrs,
            scheme: Scheme::A4W4KV16,
            group: 128,
            alpha: 0.5,
            calib: None,
            gptq_calib: None,
            rotation: None,
        }
    }
}

/// A linear layer prepared for quantized inference.
pub struct QLinear {
    pub method: Method,
    pub scheme: Scheme,
    pub group: usize,
    pub weight: PreparedWeight,
    /// SmoothQuant activation divisors.
    pub smooth: Option<Vec<f32>>,
    /// Activation-side rotation (weight was rotated offline).
    pub rotation: Option<Rotation>,
    /// Sticky reorder cache: channel maxima ordering is stable across
    /// decode steps, so the permuted + re-packed weight is reused until
    /// the runtime permutation actually changes (big win: the gather is
    /// comparable to the GEMM itself at decode batch sizes).
    perm_cache: std::sync::Mutex<Option<(Vec<usize>, Arc<PackedI4>)>>,
    /// Observability label (e.g. `l3.wq`): installed as the thread's
    /// layer scope for the duration of [`QLinear::forward`] so sampled
    /// quant-health probes ([`crate::obs::health`]) land on this layer's
    /// bucket.  `None` (the default) inherits the caller's scope.
    pub probe: Option<String>,
}

impl QLinear {
    /// Offline preparation: rotate / merge / quantize the weight per the
    /// method, matching python `prepare_weights` + GPTQ.
    pub fn prepare(w: &Mat, opts: &PrepareOpts) -> Result<QLinear> {
        let method = opts.method;
        let mut smooth = None;
        let rotation = if method.rotated() {
            Some(opts.rotation.clone().unwrap_or(Rotation::Hadamard))
        } else {
            None
        };
        let w_eff = match method {
            Method::SmoothQuant => {
                let calib = opts
                    .calib
                    .ok_or_else(|| anyhow::anyhow!("SmoothQuant needs calibration"))?;
                let s = smoothquant::smoothing_scales(calib, w, opts.alpha);
                let merged = smoothquant::merge_into_weight(w, &s);
                smooth = Some(s);
                merged
            }
            m if m.rotated() => rotation.as_ref().unwrap().apply(w),
            _ => w.clone(),
        };
        if method == Method::RsMigrated {
            // keep the fp weight: it is re-merged + re-quantized per call
            return Ok(QLinear {
                method,
                scheme: opts.scheme,
                group: opts.group.max(1),
                weight: PreparedWeight::Fp(w_eff),
                smooth: None,
                rotation: None,
                perm_cache: std::sync::Mutex::new(None),
                probe: None,
            });
        }
        let weight = if opts.scheme.w_bits == 4 && method != Method::Fp {
            let (q, scales) = match opts.gptq_calib {
                Some(x) => gptq::gptq_quantize(&w_eff, x, 0.01, 64)?,
                None => rtn::quant_per_channel_w(&w_eff),
            };
            // RS/RRS serve through the permuted perm-cache packing
            PreparedWeight::int4(q, scales, !method.runtime_smoothed())
        } else {
            PreparedWeight::Fp(w_eff)
        };
        Ok(QLinear {
            method,
            scheme: opts.scheme,
            group: opts.group.max(1),
            weight,
            smooth,
            rotation,
            perm_cache: std::sync::Mutex::new(None),
            probe: None,
        })
    }

    /// Runtime forward: `y = method(x) @ W^T` with the method's
    /// quantization pipeline applied.
    pub fn forward(&self, x: &Mat) -> Mat {
        let _layer = crate::obs::layer_scope(self.probe.as_deref());
        match self.method {
            Method::Fp => match &self.weight {
                PreparedWeight::Fp(w) => gemm_f32_bt(x, w),
                PreparedWeight::Int4 { .. } => self.act_quant_gemm(x),
            },
            Method::Rtn | Method::GptqOnly => self.act_quant_gemm(x),
            Method::SmoothQuant => {
                let s = self.smooth.as_ref().expect("sq scales");
                let xs = smoothquant::smooth_activation(x, s);
                self.act_quant_gemm(&xs)
            }
            Method::QuaRot | Method::SpinQuant => {
                let xr = self.rotation.as_ref().unwrap().apply(x);
                self.act_quant_gemm(&xr)
            }
            Method::Rs => self.rs_forward(x),
            Method::Rrs => {
                let xr = self.rotation.as_ref().unwrap().apply(x);
                self.rs_forward_rotated(&xr)
            }
            Method::RsMigrated => self.rs_migrated_forward(x),
        }
    }

    /// Fig. 3 ablation: runtime channel scales *merged into the weight*
    /// each call — the migration scheme that breaks at INT4 (the shared
    /// outliers make W·diag(s) hard to quantize).
    fn rs_migrated_forward(&self, x: &Mat) -> Mat {
        let PreparedWeight::Fp(w) = &self.weight else {
            panic!("RsMigrated keeps fp weights");
        };
        let s = runtime_smooth::channel_scales(x);
        let xs = smoothquant::smooth_activation(x, &s);
        let wm = smoothquant::merge_into_weight(w, &s);
        if self.scheme.w_bits == 4 {
            let (wq, sw) = rtn::quant_per_channel_w(&wm);
            forward_per_channel_a4w4(&xs, &wq, &sw)
        } else {
            let xdq = rtn::fake_quant_per_token(&xs);
            gemm_f32_bt(&xdq, &wm)
        }
    }

    fn rs_forward(&self, x: &Mat) -> Mat {
        self.rs_forward_rotated(x)
    }

    fn rs_forward_rotated(&self, x: &Mat) -> Mat {
        let group = effective_group(self.group, x.cols);
        match &self.weight {
            PreparedWeight::Int4 { q, scales, .. } => {
                // fused prologue + fused GEMM on the dispatched kernel
                // backend — bit-identical to the staged reference path
                let sa = runtime_smooth::prepare(x, group);
                let wqp = {
                    let mut cache = crate::obs::lock_recover(&self.perm_cache);
                    match cache.as_ref() {
                        Some((perm, wqp)) if *perm == sa.perm => wqp.clone(),
                        _ => {
                            let permuted = q.permute_cols(&sa.perm);
                            let wqp = Arc::new(PackedI4::pack(&permuted));
                            *cache = Some((sa.perm.clone(), wqp.clone()));
                            wqp
                        }
                    }
                };
                kernels::gemm_rs_fused_packed(
                    &sa.q,
                    &sa.token_scales,
                    sa.group,
                    &sa.group_scales,
                    &wqp,
                    scales,
                )
            }
            PreparedWeight::Fp(w) => {
                // A4W16: activation-only quantization
                let xdq = runtime_smooth::fake_quant_a4w16(x, group);
                gemm_f32_bt(&xdq, w)
            }
        }
    }

    fn act_quant_gemm(&self, x: &Mat) -> Mat {
        match &self.weight {
            PreparedWeight::Int4 { q, packed, scales } => match packed {
                Some(p) => {
                    let (xq, sx) = rtn::quant_per_token(x);
                    if crate::obs::health::sampled() {
                        let layer = crate::obs::current_layer_or("act_quant");
                        crate::obs::health::probe_quant(&layer, x, &xq);
                    }
                    kernels::gemm_per_channel_packed(&xq, &sx, p, scales)
                }
                // RS-method weights skip the packed mirror; this path is
                // unreachable from their dispatch but stays correct
                None => forward_per_channel_a4w4(x, q, scales),
            },
            PreparedWeight::Fp(w) => {
                let xdq = rtn::fake_quant_per_token(x);
                gemm_f32_bt(&xdq, w)
            }
        }
    }

    pub fn out_features(&self) -> usize {
        self.weight.out_features()
    }
}

/// Clamp the RS group to the largest divisor of K that is <= `group`.
pub fn effective_group(group: usize, k: usize) -> usize {
    let mut g = group.min(k).max(1);
    while k % g != 0 {
        g -= 1;
    }
    g
}

/// Per-channel A4W4: per-token INT4 activation x per-channel INT4 weight.
/// Staged scalar reference — [`QLinear`] serves this path through
/// [`crate::kernels::gemm_per_channel_packed`], which must match this
/// bit-for-bit.
pub fn forward_per_channel_a4w4(x: &Mat, wq: &MatI8, sw: &[f32]) -> Mat {
    let (xq, sx) = rtn::quant_per_token(x);
    let (n, k, m) = (xq.rows, xq.cols, wq.rows);
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, orow| {
        let arow = &xq.data[i * k..(i + 1) * k];
        let sxi = sx[i];
        for (j, o) in orow.iter_mut().enumerate() {
            let acc = idot(arow, &wq.data[j * k..(j + 1) * k]);
            *o = acc as f32 * sxi * sw[j];
        }
    });
    out
}

/// Sub-channel A4W4: per-group scales for both operands — the expensive
/// baseline of Figure 6 (scale *matrices* in the epilogue).
pub fn forward_sub_channel_a4w4(x: &Mat, w: &Mat, group: usize) -> Mat {
    let g = effective_group(group, x.cols);
    let (xq, sx) = rtn::quant_sub_channel(x, g);
    let (wq, sw) = rtn::quant_sub_channel(w, g);
    forward_sub_channel_prequant(&xq, &sx, &wq, &sw, g)
}

/// Sub-channel GEMM over pre-quantized operands (bench hot path).
pub fn forward_sub_channel_prequant(
    xq: &MatI8,
    sx: &[f32],
    wq: &MatI8,
    sw: &[f32],
    group: usize,
) -> Mat {
    let (n, k, m) = (xq.rows, xq.cols, wq.rows);
    let ng = k / group;
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, orow| {
        let arow = &xq.data[i * k..(i + 1) * k];
        let sxi = &sx[i * ng..(i + 1) * ng];
        // combined per-(i,j) group scales: this extra NG-vector build per
        // output element is exactly the "scale matrices move through the
        // epilogue" cost the paper charges sub-channel quantization with
        let mut combined = vec![0.0f32; ng];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &wq.data[j * k..(j + 1) * k];
            let swj = &sw[j * ng..(j + 1) * ng];
            for (c, (&a, &b)) in combined.iter_mut().zip(sxi.iter().zip(swj)) {
                *c = a * b;
            }
            *o = crate::linalg::igemm::idot_grouped(arow, brow, group, &combined);
        }
    });
    out
}

/// Runtime-Smooth fused GEMM (Fig. 4 step 3): per-K-block integer partial
/// times ONE scalar group scale, epilogue applies token x channel scales.
/// `wq` is the offline-quantized weight in ORIGINAL channel order; the
/// smoothed activation's permutation is applied to the weight columns here
/// (the CUDA kernel gathers; we gather once per call).
pub fn forward_rs_fused(sa: &SmoothedAct, wq: &MatI8, sw: &[f32]) -> Mat {
    let wqp = wq.permute_cols(&sa.perm);
    forward_rs_fused_prepermuted(sa, &wqp, sw)
}

/// Fused RS GEMM when the weight is already in the reordered layout
/// (staged scalar reference; [`QLinear`] serves this path through
/// [`crate::kernels::gemm_rs_fused_packed`], which must match this
/// bit-for-bit).
pub fn forward_rs_fused_prepermuted(
    sa: &SmoothedAct,
    wqp: &MatI8,
    sw: &[f32],
) -> Mat {
    let (n, k, m) = (sa.q.rows, sa.q.cols, wqp.rows);
    let group = sa.group;
    let mut out = Mat::zeros(n, m);
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(&mut out.data, m, threads, |i, orow| {
        let arow = &sa.q.data[i * k..(i + 1) * k];
        let sxi = sa.token_scales[i];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &wqp.data[j * k..(j + 1) * k];
            let acc = crate::linalg::igemm::idot_grouped(
                arow, brow, group, &sa.group_scales,
            );
            *o = acc * sxi * sw[j];
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, check, Config};
    use crate::util::rng::Pcg;

    fn randmat(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(n, k, rng.normal_vec(n * k))
    }

    /// Activations with consistent channel-wise outliers + one spike.
    fn llm_like_act(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        let mut x = Mat::from_vec(n, k, rng.normal_vec(n * k));
        for i in 0..n {
            x.data[i * k + 3] = 60.0 * (1.0 + 0.05 * rng.normal());
            x.data[i * k + k / 2] = -35.0 * (1.0 + 0.05 * rng.normal());
        }
        x.data[k + 7] = 400.0; // spike in token 1
        x
    }

    #[test]
    fn rs_fused_matches_unfused_math() {
        // the fused kernel computes sum_g sg (Xq_g . Wq_g) * sx * sw, which
        // must equal explicitly dequantizing and multiplying
        check("rs-fused-exact", Config { cases: 16, ..Default::default() },
            |rng, case| {
                let n = 2 + rng.below(6);
                let k = 64;
                let group = [1, 8, 16, 64][case % 4];
                let x = randmat(n, k, case as u64);
                let w = randmat(12, k, case as u64 + 99);
                let (wq, sw) = rtn::quant_per_channel_w(&w);
                let sa = runtime_smooth::prepare(&x, group);
                let got = forward_rs_fused(&sa, &wq, &sw);
                // reference: dequantize the smoothed activation fully
                let mut xdq = Mat::zeros(n, k);
                for i in 0..n {
                    for j in 0..k {
                        xdq.data[i * k + sa.perm[j]] = sa.q.data[i * k + j] as f32
                            * sa.token_scales[i]
                            * sa.group_scales[j / group];
                    }
                }
                let mut wdq = Mat::zeros(12, k);
                for r in 0..12 {
                    for c in 0..k {
                        wdq.data[r * k + c] = wq.data[r * k + c] as f32 * sw[r];
                    }
                }
                let want = gemm_f32_bt(&xdq, &wdq);
                assert_close(&got.data, &want.data, 1e-3, 1e-4)
            });
    }

    #[test]
    fn all_methods_finite_and_correlated() {
        let x = llm_like_act(16, 128, 1);
        let w = randmat(32, 128, 2);
        let y_fp = gemm_f32_bt(&x, &w);
        let calib = smoothquant::Calibration::from_batches([&x].into_iter(), 128);
        for method in Method::ALL {
            let opts = PrepareOpts {
                method,
                scheme: if method == Method::Fp {
                    Scheme::FP
                } else {
                    Scheme::A4W4KV16
                },
                group: 32,
                calib: Some(&calib),
                ..Default::default()
            };
            let lin = QLinear::prepare(&w, &opts).unwrap();
            let y = lin.forward(&x);
            assert!(y.data.iter().all(|v| v.is_finite()), "{method:?}");
            let corr = correlation(&y.data, &y_fp.data);
            assert!(corr > 0.85, "{method:?} corr={corr}");
        }
    }

    #[test]
    fn rrs_beats_rtn_on_llm_like() {
        let x = llm_like_act(16, 128, 3);
        let w = randmat(32, 128, 4);
        let y_fp = gemm_f32_bt(&x, &w);
        let err = |m: Method, scheme: Scheme| {
            let opts = PrepareOpts { method: m, scheme, group: 32, ..Default::default() };
            let lin = QLinear::prepare(&w, &opts).unwrap();
            let y = lin.forward(&x);
            y.data
                .iter()
                .zip(&y_fp.data)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / y.data.len() as f32
        };
        // A4W4: shared weight-quant error narrows the gap but RRS still wins
        let e_rtn = err(Method::Rtn, Scheme::A4W4KV16);
        let e_rrs = err(Method::Rrs, Scheme::A4W4KV16);
        assert!(e_rrs < 0.9 * e_rtn, "A4W4: rrs {e_rrs} vs rtn {e_rtn}");
        // A4W16 isolates the activation side: the gap is decisive (Fig. 3)
        let e_rtn16 = err(Method::Rtn, Scheme::A4W16KV16);
        let e_rrs16 = err(Method::Rrs, Scheme::A4W16KV16);
        assert!(e_rrs16 < 0.7 * e_rtn16, "A4W16: rrs {e_rrs16} vs rtn {e_rtn16}");
    }

    #[test]
    fn a4w16_paths() {
        let x = llm_like_act(8, 64, 5);
        let w = randmat(16, 64, 6);
        for method in [Method::Rtn, Method::Rs, Method::Rrs, Method::QuaRot] {
            let opts = PrepareOpts {
                method,
                scheme: Scheme::A4W16KV16,
                group: 16,
                ..Default::default()
            };
            let lin = QLinear::prepare(&w, &opts).unwrap();
            assert!(matches!(lin.weight, PreparedWeight::Fp(_)));
            let y = lin.forward(&x);
            assert!(y.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn sub_channel_matches_explicit_dequant() {
        let x = randmat(4, 64, 7);
        let w = randmat(8, 64, 8);
        let g = 16;
        let got = forward_sub_channel_a4w4(&x, &w, g);
        let (xq, sx) = rtn::quant_sub_channel(&x, g);
        let (wq, sw) = rtn::quant_sub_channel(&w, g);
        let ng = 64 / g;
        let mut want = Mat::zeros(4, 8);
        for i in 0..4 {
            for j in 0..8 {
                let mut acc = 0.0;
                for kk in 0..64 {
                    acc += xq.data[i * 64 + kk] as f32
                        * sx[i * ng + kk / g]
                        * wq.data[j * 64 + kk] as f32
                        * sw[j * ng + kk / g];
                }
                want.data[i * 8 + j] = acc;
            }
        }
        assert!(got.max_abs_diff(&want) < 1e-2);
    }

    #[test]
    fn effective_group_divides() {
        assert_eq!(effective_group(128, 64), 64);
        assert_eq!(effective_group(48, 64), 32);
        assert_eq!(effective_group(1, 64), 1);
        assert_eq!(effective_group(128, 96), 96);
    }

    fn correlation(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len() as f32;
        let ma = a.iter().sum::<f32>() / n;
        let mb = b.iter().sum::<f32>() / n;
        let mut num = 0.0;
        let mut da = 0.0;
        let mut db = 0.0;
        for (&x, &y) in a.iter().zip(b) {
            num += (x - ma) * (y - mb);
            da += (x - ma) * (x - ma);
            db += (y - mb) * (y - mb);
        }
        num / (da.sqrt() * db.sqrt() + 1e-12)
    }
}
