//! SmoothQuant baseline (paper 2.2): offline calibrated channel smoothing
//! with outlier *migration* into the weights —
//! `s_j = max|X_j|^alpha / max|W_j|^(1-alpha)`, `X' = X / s`, `W' = W * s`.
//!
//! The paper's analysis (and our Table 1) shows why this fails at INT4:
//! the calibration can mismatch runtime activations, and the migrated
//! outliers make W harder to quantize.

use crate::linalg::gemm::Mat;

/// Calibration record: per-input-channel absolute maxima of activations.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub act_absmax: Vec<f32>,
}

impl Calibration {
    /// Accumulate channel maxima over calibration batches.
    pub fn from_batches<'a>(batches: impl Iterator<Item = &'a Mat>, k: usize) -> Self {
        let mut am = vec![0.0f32; k];
        for x in batches {
            assert_eq!(x.cols, k);
            for i in 0..x.rows {
                for (a, &v) in am.iter_mut().zip(x.row(i)) {
                    *a = a.max(v.abs());
                }
            }
        }
        Calibration { act_absmax: am }
    }
}

/// Smoothing scales (paper 2.2), floored for numeric safety.
pub fn smoothing_scales(calib: &Calibration, w: &Mat, alpha: f32) -> Vec<f32> {
    let mut wmax = vec![0.0f32; w.cols];
    for i in 0..w.rows {
        for (m, &v) in wmax.iter_mut().zip(w.row(i)) {
            *m = m.max(v.abs());
        }
    }
    calib
        .act_absmax
        .iter()
        .zip(&wmax)
        .map(|(&a, &m)| {
            (a.max(1e-8).powf(alpha) / m.max(1e-8).powf(1.0 - alpha)).max(1e-8)
        })
        .collect()
}

/// Apply `X / s` (runtime side of SmoothQuant).
pub fn smooth_activation(x: &Mat, s: &[f32]) -> Mat {
    let mut out = x.clone();
    for i in 0..out.rows {
        for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
            *v /= sj;
        }
    }
    out
}

/// Apply `W * s` (offline merge into the weight).
pub fn merge_into_weight(w: &Mat, s: &[f32]) -> Mat {
    let mut out = w.clone();
    for i in 0..out.rows {
        for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
            *v *= sj;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_f32_bt;
    use crate::util::rng::Pcg;

    fn randmat(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(n, k, rng.normal_vec(n * k))
    }

    #[test]
    fn smoothing_preserves_output_in_fp() {
        let x = randmat(4, 32, 1);
        let w = randmat(8, 32, 2);
        let calib = Calibration::from_batches([&x].into_iter(), 32);
        let s = smoothing_scales(&calib, &w, 0.5);
        let y0 = gemm_f32_bt(&x, &w);
        let y1 = gemm_f32_bt(&smooth_activation(&x, &s), &merge_into_weight(&w, &s));
        assert!(y0.max_abs_diff(&y1) < 1e-3);
    }

    #[test]
    fn alpha_interpolates() {
        let x = randmat(4, 16, 3);
        let w = randmat(8, 16, 4);
        let calib = Calibration::from_batches([&x].into_iter(), 16);
        let s0 = smoothing_scales(&calib, &w, 0.0);
        let s1 = smoothing_scales(&calib, &w, 1.0);
        // alpha=1 -> scales equal activation maxima
        for (a, &sj) in calib.act_absmax.iter().zip(&s1) {
            assert!((a.max(1e-8) - sj).abs() < 1e-4);
        }
        // alpha=0 -> scales are 1/weight maxima
        let mut wmax = vec![0.0f32; 16];
        for i in 0..8 {
            for (m, &v) in wmax.iter_mut().zip(w.row(i)) {
                *m = m.max(v.abs());
            }
        }
        for (m, &sj) in wmax.iter().zip(&s0) {
            assert!((1.0 / m - sj).abs() / sj < 1e-3);
        }
    }

    #[test]
    fn calibration_accumulates_over_batches() {
        let a = Mat::from_vec(1, 2, vec![1.0, -3.0]);
        let b = Mat::from_vec(1, 2, vec![-2.0, 0.5]);
        let c = Calibration::from_batches([&a, &b].into_iter(), 2);
        assert_eq!(c.act_absmax, vec![2.0, 3.0]);
    }
}
