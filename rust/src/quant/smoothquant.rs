//! SmoothQuant baseline (paper 2.2): offline calibrated channel smoothing
//! with outlier *migration* into the weights —
//! `s_j = max|X_j|^alpha / max|W_j|^(1-alpha)`, `X' = X / s`, `W' = W * s`.
//!
//! The paper's analysis (and our Table 1) shows why this fails at INT4:
//! the calibration can mismatch runtime activations, and the migrated
//! outliers make W harder to quantize.

use crate::linalg::gemm::Mat;

/// Calibration record: per-input-channel absolute maxima of activations.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub act_absmax: Vec<f32>,
}

impl Calibration {
    /// Accumulate channel maxima over calibration batches.
    pub fn from_batches<'a>(batches: impl Iterator<Item = &'a Mat>, k: usize) -> Self {
        let mut am = vec![0.0f32; k];
        for x in batches {
            assert_eq!(x.cols, k);
            for i in 0..x.rows {
                for (a, &v) in am.iter_mut().zip(x.row(i)) {
                    *a = a.max(v.abs());
                }
            }
        }
        Calibration { act_absmax: am }
    }
}

/// Clamp range for smoothing scales.  At the 1e-8 calibration floor with
/// extreme alpha the raw ratio reaches 1e±8 — dividing activations by a
/// ~1e-8 scale amplifies them by 1e8 and the downstream quantizer
/// saturates (or the ratio degenerates to inf/inf = NaN).  Healthy
/// calibrations produce scales near 1, so the clamp is a no-op there.
pub const SCALE_MIN: f32 = 1e-4;
pub const SCALE_MAX: f32 = 1e4;

/// Smoothing scales (paper 2.2), floored for numeric safety and clamped
/// to `[SCALE_MIN, SCALE_MAX]`; non-finite ratios fall back to 1 (no
/// migration for that channel).
pub fn smoothing_scales(calib: &Calibration, w: &Mat, alpha: f32) -> Vec<f32> {
    let mut wmax = vec![0.0f32; w.cols];
    for i in 0..w.rows {
        for (m, &v) in wmax.iter_mut().zip(w.row(i)) {
            *m = m.max(v.abs());
        }
    }
    calib
        .act_absmax
        .iter()
        .zip(&wmax)
        .map(|(&a, &m)| {
            let raw = a.max(1e-8).powf(alpha) / m.max(1e-8).powf(1.0 - alpha);
            if raw.is_finite() {
                raw.clamp(SCALE_MIN, SCALE_MAX)
            } else {
                1.0
            }
        })
        .collect()
}

/// Apply `X / s` (runtime side of SmoothQuant).
pub fn smooth_activation(x: &Mat, s: &[f32]) -> Mat {
    let mut out = x.clone();
    for i in 0..out.rows {
        for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
            *v /= sj;
        }
    }
    out
}

/// Apply `W * s` (offline merge into the weight).
pub fn merge_into_weight(w: &Mat, s: &[f32]) -> Mat {
    let mut out = w.clone();
    for i in 0..out.rows {
        for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
            *v *= sj;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_f32_bt;
    use crate::util::rng::Pcg;

    fn randmat(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(n, k, rng.normal_vec(n * k))
    }

    #[test]
    fn smoothing_preserves_output_in_fp() {
        let x = randmat(4, 32, 1);
        let w = randmat(8, 32, 2);
        let calib = Calibration::from_batches([&x].into_iter(), 32);
        let s = smoothing_scales(&calib, &w, 0.5);
        let y0 = gemm_f32_bt(&x, &w);
        let y1 = gemm_f32_bt(&smooth_activation(&x, &s), &merge_into_weight(&w, &s));
        assert!(y0.max_abs_diff(&y1) < 1e-3);
    }

    #[test]
    fn alpha_interpolates() {
        let x = randmat(4, 16, 3);
        let w = randmat(8, 16, 4);
        let calib = Calibration::from_batches([&x].into_iter(), 16);
        let s0 = smoothing_scales(&calib, &w, 0.0);
        let s1 = smoothing_scales(&calib, &w, 1.0);
        // alpha=1 -> scales equal activation maxima
        for (a, &sj) in calib.act_absmax.iter().zip(&s1) {
            assert!((a.max(1e-8) - sj).abs() < 1e-4);
        }
        // alpha=0 -> scales are 1/weight maxima
        let mut wmax = vec![0.0f32; 16];
        for i in 0..8 {
            for (m, &v) in wmax.iter_mut().zip(w.row(i)) {
                *m = m.max(v.abs());
            }
        }
        for (m, &sj) in wmax.iter().zip(&s0) {
            assert!((1.0 / m - sj).abs() / sj < 1e-3);
        }
    }

    #[test]
    fn calibration_accumulates_over_batches() {
        let a = Mat::from_vec(1, 2, vec![1.0, -3.0]);
        let b = Mat::from_vec(1, 2, vec![-2.0, 0.5]);
        let c = Calibration::from_batches([&a, &b].into_iter(), 2);
        assert_eq!(c.act_absmax, vec![2.0, 3.0]);
    }

    #[test]
    fn scales_stay_finite_and_clamped_on_degenerate_calibration() {
        use crate::util::proptest::{check, Config};
        check("sq-scale-edges", Config::default(), |rng, _| {
            let k = 4 + rng.below(12);
            // hostile channel maxima: zeros (floor), huge outliers,
            // denormal-scale values
            let mut am = vec![0.0f32; k];
            let mut wdata = vec![0.0f32; 2 * k];
            for j in 0..k {
                am[j] = match rng.below(4) {
                    0 => 0.0,
                    1 => 1e30,
                    2 => 1e-30,
                    _ => rng.normal_vec(1)[0].abs(),
                };
                let wv = match rng.below(4) {
                    0 => 0.0,
                    1 => 1e30,
                    2 => 1e-30,
                    _ => rng.normal_vec(1)[0],
                };
                wdata[j] = wv;
                wdata[k + j] = -wv * 0.5;
            }
            let w = Mat::from_vec(2, k, wdata);
            let calib = Calibration { act_absmax: am };
            for &alpha in &[0.0f32, 0.25, 0.5, 0.85, 1.0] {
                let s = smoothing_scales(&calib, &w, alpha);
                for (j, &sj) in s.iter().enumerate() {
                    if !sj.is_finite() {
                        return Err(format!("non-finite scale {sj} at {j}"));
                    }
                    if !(SCALE_MIN..=SCALE_MAX).contains(&sj) {
                        return Err(format!("scale {sj} escapes clamp at {j}"));
                    }
                }
                // smoothing with these scales must never mint non-finite
                // activations from finite (if large) inputs
                let x = Mat::from_vec(
                    1,
                    k,
                    (0..k).map(|j| calib.act_absmax[j]).collect(),
                );
                let xs = smooth_activation(&x, &s);
                if xs.data.iter().any(|v| !v.is_finite()) {
                    return Err("smoothed activation went non-finite".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn clamp_is_noop_for_healthy_scales() {
        // the fix must not perturb in-range calibrations: same inputs as
        // the fp-preservation test, raw ratio recomputed by hand
        let x = randmat(4, 32, 11);
        let w = randmat(8, 32, 12);
        let calib = Calibration::from_batches([&x].into_iter(), 32);
        let s = smoothing_scales(&calib, &w, 0.5);
        let mut wmax = vec![0.0f32; 32];
        for i in 0..8 {
            for (m, &v) in wmax.iter_mut().zip(w.row(i)) {
                *m = m.max(v.abs());
            }
        }
        for j in 0..32 {
            let raw = calib.act_absmax[j].max(1e-8).powf(0.5)
                / wmax[j].max(1e-8).powf(0.5);
            assert_eq!(s[j], raw, "clamp changed an in-range scale");
        }
    }
}
