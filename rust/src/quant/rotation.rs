//! Rotation utilities (paper 2.3): Hadamard rotation for QuaRot/RRS and
//! dense learned rotations for the SpinQuant baseline.
//!
//! `X @ H` with H the normalized Sylvester-Hadamard is applied via FWHT in
//! O(K log K); learned rotations are dense [K,K] matmuls.  Pairing
//! `(X R)(R^T W^T)^T` keeps the layer output exact (Fig. 2a).

use crate::linalg::fwht::fwht_rows;
use crate::linalg::gemm::{gemm_f32, Mat};

/// Rotation operator applied to activation/weight rows along K.
#[derive(Clone, Debug)]
pub enum Rotation {
    /// Normalized Sylvester-Hadamard (K must be a power of two).
    Hadamard,
    /// Dense learned rotation (SpinQuant): row-major [K,K].
    Dense(Mat),
}

impl Rotation {
    /// `X <- X @ R`, rotating every row in place (Hadamard) or via a
    /// dense GEMM (learned).
    pub fn apply(&self, x: &Mat) -> Mat {
        match self {
            Rotation::Hadamard => {
                // dispatched FWHT kernel, rows in parallel
                let mut out = x.clone();
                let k = out.cols;
                fwht_rows(&mut out.data, k);
                out
            }
            Rotation::Dense(r) => {
                assert_eq!(x.cols, r.rows);
                gemm_f32(x, r)
            }
        }
    }

    /// Orthogonality residual `max |R R^T - I|` (0 for Hadamard).
    pub fn orthogonality_error(&self, k: usize) -> f32 {
        match self {
            Rotation::Hadamard => 0.0,
            Rotation::Dense(r) => {
                assert_eq!(r.rows, k);
                let mut worst = 0.0f32;
                for i in 0..k {
                    for j in 0..k {
                        let mut s = 0.0;
                        for t in 0..k {
                            s += r.at(i, t) * r.at(j, t);
                        }
                        let want = if i == j { 1.0 } else { 0.0 };
                        worst = worst.max((s - want).abs());
                    }
                }
                worst
            }
        }
    }
}

/// Rotate a weight matrix's input dimension: `W' = W @ R` row-wise over K
/// (same operation as activations since both store K contiguously).
pub fn rotate_weight(w: &Mat, rot: &Rotation) -> Mat {
    rot.apply(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_f32_bt;
    use crate::util::rng::Pcg;

    fn randmat(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(n, k, rng.normal_vec(n * k))
    }

    #[test]
    fn pairing_preserves_output() {
        // (X H)(W H)^T == X W^T (Fig. 2a)
        let x = randmat(6, 64, 1);
        let w = randmat(10, 64, 2);
        let rot = Rotation::Hadamard;
        let y0 = gemm_f32_bt(&x, &w);
        let y1 = gemm_f32_bt(&rot.apply(&x), &rot.apply(&w));
        assert!(y0.max_abs_diff(&y1) < 1e-3);
    }

    #[test]
    fn dense_pairing_preserves_output() {
        // build an orthogonal matrix via Hadamard-as-dense
        let k = 32;
        let h = crate::linalg::fwht::hadamard_dense(k);
        let rot = Rotation::Dense(Mat::from_vec(k, k, h));
        assert!(rot.orthogonality_error(k) < 1e-4);
        let x = randmat(4, k, 3);
        let w = randmat(5, k, 4);
        let y0 = gemm_f32_bt(&x, &w);
        let y1 = gemm_f32_bt(&rot.apply(&x), &rot.apply(&w));
        assert!(y0.max_abs_diff(&y1) < 1e-3);
    }

    #[test]
    fn hadamard_apply_matches_dense_apply() {
        let k = 64;
        let x = randmat(3, k, 5);
        let hd = Rotation::Dense(Mat::from_vec(
            k,
            k,
            crate::linalg::fwht::hadamard_dense(k),
        ));
        let a = Rotation::Hadamard.apply(&x);
        let b = hd.apply(&x);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }
}
