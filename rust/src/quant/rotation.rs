//! Rotation utilities (paper 2.3): Hadamard rotation for QuaRot/RRS and
//! dense learned rotations for the SpinQuant baseline.
//!
//! `X @ H` with H the normalized Sylvester-Hadamard is applied via FWHT in
//! O(K log K); learned rotations are dense [K,K] matmuls.  Pairing
//! `(X R)(R^T W^T)^T` keeps the layer output exact (Fig. 2a).
//!
//! Widths that are not a power of two cannot take the plain FWHT (its
//! butterfly network assumes one) — [`Rotation::hadamard_for`] falls back
//! to an orthogonal block-diagonal Hadamard over the width's binary
//! decomposition, and [`Rotation::validate`] turns what used to be a
//! mid-inference assert into a prepare-time error.

use anyhow::{bail, Result};

use crate::linalg::fwht::{fwht_inplace, fwht_rows, hadamard_dense};
use crate::linalg::gemm::{gemm_f32, Mat};

/// Rotation operator applied to activation/weight rows along K.
#[derive(Clone, Debug)]
pub enum Rotation {
    /// Normalized Sylvester-Hadamard (K must be a power of two).
    Hadamard,
    /// Block-diagonal Hadamard over power-of-two segments that tile K —
    /// the non-power-of-two fallback.  Orthogonal (each block is), so
    /// pairing still cancels exactly.
    BlockHadamard(Vec<usize>),
    /// Dense learned rotation (SpinQuant): row-major [K,K].
    Dense(Mat),
}

/// Binary decomposition of `k` into descending power-of-two segments
/// (e.g. `12 -> [8, 4]`), the tiling [`Rotation::BlockHadamard`] uses.
pub fn block_decomposition(k: usize) -> Vec<usize> {
    assert!(k > 0, "cannot decompose width 0");
    (0..usize::BITS)
        .rev()
        .filter(|b| k & (1usize << b) != 0)
        .map(|b| 1usize << b)
        .collect()
}

impl Rotation {
    /// The FWHT-based rotation for width `k`: plain Hadamard when `k` is
    /// a power of two, the block-diagonal fallback otherwise.
    pub fn hadamard_for(k: usize) -> Rotation {
        if k.is_power_of_two() {
            Rotation::Hadamard
        } else {
            Rotation::BlockHadamard(block_decomposition(k))
        }
    }

    /// QuaRot-style closed-form dense rotation for width `k`: the
    /// (block-)Hadamard with rows sign-randomized by a seeded ±1
    /// diagonal.  Orthogonal by construction, needs no training — the
    /// recipe layer's `RotationKind::Dense` default when no learned
    /// SpinQuant matrices are supplied.
    pub fn closed_form_dense(k: usize, seed: u64) -> Rotation {
        let mut h = vec![0.0f32; k * k];
        let mut off = 0;
        for len in block_decomposition(k) {
            let hb = hadamard_dense(len);
            for i in 0..len {
                for j in 0..len {
                    h[(off + i) * k + (off + j)] = hb[i * len + j];
                }
            }
            off += len;
        }
        let mut rng = crate::util::rng::Pcg::new(seed);
        for i in 0..k {
            if rng.below(2) == 1 {
                for v in h[i * k..(i + 1) * k].iter_mut() {
                    *v = -*v;
                }
            }
        }
        Rotation::Dense(Mat::from_vec(k, k, h))
    }

    /// Check this rotation can be applied along width `k`, returning a
    /// clear error instead of letting `apply` hit a runtime assert.
    pub fn validate(&self, k: usize) -> Result<()> {
        match self {
            Rotation::Hadamard => {
                if !k.is_power_of_two() {
                    bail!(
                        "Hadamard rotation needs a power-of-two width, got {k} \
                         (use Rotation::hadamard_for for the block-diagonal \
                         fallback)"
                    );
                }
            }
            Rotation::BlockHadamard(segs) => {
                if segs.iter().sum::<usize>() != k
                    || !segs.iter().all(|s| s.is_power_of_two())
                {
                    bail!(
                        "block-Hadamard segments {segs:?} do not tile width {k} \
                         with powers of two"
                    );
                }
            }
            Rotation::Dense(r) => {
                if r.rows != k || r.cols != k {
                    bail!(
                        "dense rotation is [{}x{}], want [{k}x{k}]",
                        r.rows,
                        r.cols
                    );
                }
            }
        }
        Ok(())
    }

    /// `X <- X @ R`, rotating every row in place (Hadamard) or via a
    /// dense GEMM (learned).
    pub fn apply(&self, x: &Mat) -> Mat {
        match self {
            Rotation::Hadamard => {
                // dispatched FWHT kernel, rows in parallel
                let mut out = x.clone();
                let k = out.cols;
                fwht_rows(&mut out.data, k);
                out
            }
            Rotation::BlockHadamard(segs) => {
                let mut out = x.clone();
                let k = out.cols;
                debug_assert_eq!(segs.iter().sum::<usize>(), k);
                for r in 0..out.rows {
                    let row = &mut out.data[r * k..(r + 1) * k];
                    let mut off = 0;
                    for &len in segs {
                        fwht_inplace(&mut row[off..off + len]);
                        off += len;
                    }
                }
                out
            }
            Rotation::Dense(r) => {
                assert_eq!(x.cols, r.rows);
                gemm_f32(x, r)
            }
        }
    }

    /// Orthogonality residual `max |R R^T - I|` (0 for Hadamard).
    pub fn orthogonality_error(&self, k: usize) -> f32 {
        match self {
            Rotation::Hadamard | Rotation::BlockHadamard(_) => 0.0,
            Rotation::Dense(r) => {
                assert_eq!(r.rows, k);
                let mut worst = 0.0f32;
                for i in 0..k {
                    for j in 0..k {
                        let mut s = 0.0;
                        for t in 0..k {
                            s += r.at(i, t) * r.at(j, t);
                        }
                        let want = if i == j { 1.0 } else { 0.0 };
                        worst = worst.max((s - want).abs());
                    }
                }
                worst
            }
        }
    }
}

/// Rotate a weight matrix's input dimension: `W' = W @ R` row-wise over K
/// (same operation as activations since both store K contiguously).
pub fn rotate_weight(w: &Mat, rot: &Rotation) -> Mat {
    rot.apply(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_f32_bt;
    use crate::util::rng::Pcg;

    fn randmat(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        Mat::from_vec(n, k, rng.normal_vec(n * k))
    }

    #[test]
    fn pairing_preserves_output() {
        // (X H)(W H)^T == X W^T (Fig. 2a)
        let x = randmat(6, 64, 1);
        let w = randmat(10, 64, 2);
        let rot = Rotation::Hadamard;
        let y0 = gemm_f32_bt(&x, &w);
        let y1 = gemm_f32_bt(&rot.apply(&x), &rot.apply(&w));
        assert!(y0.max_abs_diff(&y1) < 1e-3);
    }

    #[test]
    fn dense_pairing_preserves_output() {
        // build an orthogonal matrix via Hadamard-as-dense
        let k = 32;
        let h = crate::linalg::fwht::hadamard_dense(k);
        let rot = Rotation::Dense(Mat::from_vec(k, k, h));
        assert!(rot.orthogonality_error(k) < 1e-4);
        let x = randmat(4, k, 3);
        let w = randmat(5, k, 4);
        let y0 = gemm_f32_bt(&x, &w);
        let y1 = gemm_f32_bt(&rot.apply(&x), &rot.apply(&w));
        assert!(y0.max_abs_diff(&y1) < 1e-3);
    }

    #[test]
    fn hadamard_apply_matches_dense_apply() {
        let k = 64;
        let x = randmat(3, k, 5);
        let hd = Rotation::Dense(Mat::from_vec(
            k,
            k,
            crate::linalg::fwht::hadamard_dense(k),
        ));
        let a = Rotation::Hadamard.apply(&x);
        let b = hd.apply(&x);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn block_decomposition_tiles_width() {
        assert_eq!(block_decomposition(12), vec![8, 4]);
        assert_eq!(block_decomposition(64), vec![64]);
        assert_eq!(block_decomposition(1), vec![1]);
        for k in [3usize, 7, 12, 100, 257] {
            let segs = block_decomposition(k);
            assert_eq!(segs.iter().sum::<usize>(), k);
            assert!(segs.iter().all(|s| s.is_power_of_two()));
        }
    }

    #[test]
    fn non_pow2_falls_back_without_panicking() {
        // width 12 used to hit the fwht assert mid-inference; the
        // fallback must rotate, stay orthogonal, and keep pairing exact
        let k = 12;
        let rot = Rotation::hadamard_for(k);
        assert!(matches!(rot, Rotation::BlockHadamard(_)));
        rot.validate(k).unwrap();
        let x = randmat(5, k, 6);
        let w = randmat(7, k, 7);
        let y0 = gemm_f32_bt(&x, &w);
        let y1 = gemm_f32_bt(&rot.apply(&x), &rot.apply(&w));
        assert!(y0.max_abs_diff(&y1) < 1e-3);
        // involution: block Hadamard is symmetric orthogonal like H
        let twice = rot.apply(&rot.apply(&x));
        assert!(twice.max_abs_diff(&x) < 1e-4);
        // and it actually mixes channels (not the identity)
        assert!(rot.apply(&x).max_abs_diff(&x) > 1e-3);
    }

    #[test]
    fn validate_catches_mismatches_instead_of_panicking() {
        assert!(Rotation::Hadamard.validate(64).is_ok());
        assert!(Rotation::Hadamard.validate(12).is_err());
        assert!(Rotation::hadamard_for(12).validate(12).is_ok());
        assert!(Rotation::BlockHadamard(vec![8, 2]).validate(12).is_err());
        let d = Rotation::Dense(Mat::zeros(8, 8));
        assert!(d.validate(8).is_ok());
        assert!(d.validate(12).is_err());
    }

    #[test]
    fn closed_form_dense_is_orthogonal_and_pairs() {
        for k in [32usize, 12, 96] {
            let rot = Rotation::closed_form_dense(k, 0xDECAF + k as u64);
            rot.validate(k).unwrap();
            assert!(rot.orthogonality_error(k) < 1e-4, "k={k}");
            let x = randmat(4, k, 8);
            let w = randmat(6, k, 9);
            let y0 = gemm_f32_bt(&x, &w);
            let y1 = gemm_f32_bt(&rot.apply(&x), &rot.apply(&w));
            assert!(y0.max_abs_diff(&y1) < 1e-3, "k={k}");
        }
        // seeded: same seed, same matrix; different seed, different signs
        let a = Rotation::closed_form_dense(64, 1);
        let b = Rotation::closed_form_dense(64, 1);
        let c = Rotation::closed_form_dense(64, 2);
        let (Rotation::Dense(ma), Rotation::Dense(mb), Rotation::Dense(mc)) =
            (&a, &b, &c)
        else {
            unreachable!()
        };
        assert_eq!(ma.data, mb.data);
        assert!(ma.max_abs_diff(mc) > 1e-3);
    }
}
