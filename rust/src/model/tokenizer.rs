//! Byte-level tokenizer (vocab = 256): the corpus is ASCII so ids are
//! bytes; decoding is lossy only on invalid UTF-8 (never for our corpus).

/// Encode a string to token ids.
pub fn encode(text: &str) -> Vec<u32> {
    text.as_bytes().iter().map(|&b| b as u32).collect()
}

/// Decode token ids to a string (invalid sequences -> U+FFFD).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xff) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "arlo is red. count: 1 2 3.";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn ids_are_bytes() {
        assert_eq!(encode("ab"), vec![97, 98]);
    }
}
