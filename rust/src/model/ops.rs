//! Transformer primitive ops shared by the engine: RMSNorm, rotate-half
//! RoPE, SiLU, causal attention.  All match python/compile/model.py.

use crate::linalg::softmax_inplace;

/// RMSNorm: `x * rsqrt(mean(x^2) + eps) * g`, row-wise.
pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32], eps: f32) {
    debug_assert_eq!(x.len(), g.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &gv) in out.iter_mut().zip(x).zip(g) {
        *o = v * r * gv;
    }
}

/// Precomputed RoPE tables for positions `[0, max_seq)`.
#[derive(Clone, Debug)]
pub struct RopeTable {
    pub cos: Vec<f32>, // [max_seq, head_dim/2]
    pub sin: Vec<f32>,
    pub half: usize,
}

impl RopeTable {
    pub fn new(max_seq: usize, head_dim: usize, theta: f32) -> RopeTable {
        let half = head_dim / 2;
        let mut cos = vec![0.0f32; max_seq * half];
        let mut sin = vec![0.0f32; max_seq * half];
        for p in 0..max_seq {
            for i in 0..half {
                let inv = 1.0 / theta.powf((2 * i) as f32 / head_dim as f32);
                let ang = p as f32 * inv;
                cos[p * half + i] = ang.cos();
                sin[p * half + i] = ang.sin();
            }
        }
        RopeTable { cos, sin, half }
    }

    /// Apply rotate-half RoPE to one head vector at position `pos`:
    /// `[x1, x2] -> [x1 c - x2 s, x1 s + x2 c]` (matches python
    /// `apply_rope`).
    pub fn apply(&self, head: &mut [f32], pos: usize) {
        let h = self.half;
        debug_assert_eq!(head.len(), 2 * h);
        let cos = &self.cos[pos * h..(pos + 1) * h];
        let sin = &self.sin[pos * h..(pos + 1) * h];
        for i in 0..h {
            let x1 = head[i];
            let x2 = head[i + h];
            head[i] = x1 * cos[i] - x2 * sin[i];
            head[i + h] = x1 * sin[i] + x2 * cos[i];
        }
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Single-query attention against cached K/V rows (decode step).
/// `q` is `[n_heads * hd]`; `keys`/`vals` are per-position `[kv_dim]`
/// slices (len = seq_len); GQA maps head h -> kv head h / (n_heads/n_kv).
/// Scores go through the dispatched [`crate::kernels::dot_f32`], which
/// every backend implements bit-identically to `linalg::gemm::dot` — so
/// attention stays deterministic under `RRS_KERNEL`.
#[allow(clippy::too_many_arguments)]
pub fn attend_single(
    q: &[f32],
    keys: &[Vec<f32>],
    vals: &[Vec<f32>],
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    out: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let t = keys.len();
    let rep = n_heads / n_kv_heads;
    let scale = 1.0 / (head_dim as f32).sqrt();
    scratch.resize(t, 0.0);
    for h in 0..n_heads {
        let kvh = h / rep;
        let qh = &q[h * head_dim..(h + 1) * head_dim];
        for (p, krow) in keys.iter().enumerate() {
            let kh = &krow[kvh * head_dim..(kvh + 1) * head_dim];
            scratch[p] = crate::kernels::dot_f32(qh, kh) * scale;
        }
        softmax_inplace(&mut scratch[..t]);
        let oh = &mut out[h * head_dim..(h + 1) * head_dim];
        oh.fill(0.0);
        for (p, vrow) in vals.iter().enumerate() {
            let w = scratch[p];
            if w < 1e-12 {
                continue;
            }
            let vh = &vrow[kvh * head_dim..(kvh + 1) * head_dim];
            for (o, &v) in oh.iter_mut().zip(vh) {
                *o += w * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let x = vec![3.0f32; 16];
        let g = vec![1.0f32; 16];
        let mut out = vec![0.0; 16];
        rmsnorm(&x, &g, &mut out, 1e-5);
        for &v in &out {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let table = RopeTable::new(32, 8, 10_000.0);
        let mut v: Vec<f32> = (0..8).map(|i| (i as f32 + 1.0) * 0.3).collect();
        let n0: f32 = v.iter().map(|x| x * x).sum();
        table.apply(&mut v, 17);
        let n1: f32 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
    }

    #[test]
    fn rope_pos0_identity() {
        let table = RopeTable::new(4, 8, 10_000.0);
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let orig = v.clone();
        table.apply(&mut v, 0);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn attention_single_key_is_value() {
        // with one cached position, attention output == its value
        let q = vec![0.5f32; 8]; // 2 heads x hd 4
        let keys = vec![vec![0.1f32; 4]]; // 1 kv head
        let vals = vec![vec![7.0f32, 8.0, 9.0, 10.0]];
        let mut out = vec![0.0f32; 8];
        let mut scratch = Vec::new();
        attend_single(&q, &keys, &vals, 2, 1, 4, &mut out, &mut scratch);
        assert_eq!(&out[..4], &[7.0, 8.0, 9.0, 10.0]);
        assert_eq!(&out[4..], &[7.0, 8.0, 9.0, 10.0]);
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0) - 0.0).abs() < 1e-6);
        assert!(silu(10.0) > 9.9);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
