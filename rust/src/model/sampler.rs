//! Token sampling: greedy, temperature, and top-k over a logits row.
//!
//! This is the minimal three-mode sampler the model layer exposes; the
//! serving front-end's full per-request suite (penalties, top-p, stop
//! sequences, seeds) lives in [`crate::coordinator::sampling`] and maps
//! [`Sampling`] onto it.

use crate::linalg::{argmax, softmax_inplace};
use crate::util::rng::Pcg;

#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    Greedy,
    Temperature(f32),
    TopK { k: usize, temperature: f32 },
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], mode: Sampling, rng: &mut Pcg) -> u32 {
    match mode {
        Sampling::Greedy => argmax(logits) as u32,
        Sampling::Temperature(t) => {
            let mut p: Vec<f32> = logits.iter().map(|&l| l / t.max(1e-4)).collect();
            softmax_inplace(&mut p);
            pick(&p, rng)
        }
        Sampling::TopK { k, temperature } => {
            // partial selection, not a full sort: O(V) expected instead
            // of O(V log V) per token.  NaN logits are filtered first —
            // they must never win the selection or be sampled
            let mut idx: Vec<usize> =
                (0..logits.len()).filter(|&i| !logits[i].is_nan()).collect();
            if idx.is_empty() {
                return 0;
            }
            let k = k.max(1).min(idx.len());
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    logits[b].total_cmp(&logits[a])
                });
                idx.truncate(k);
            }
            let mut p: Vec<f32> =
                idx.iter().map(|&i| logits[i] / temperature.max(1e-4)).collect();
            softmax_inplace(&mut p);
            idx[pick(&p, rng) as usize] as u32
        }
    }
}

/// Weighted draw over `probs`.  Robust to mass summing below 1.0 (the
/// draw is scaled by the actual mass, so the tail never soaks up the
/// rounding deficit); a degenerate all-zero row falls back to its
/// largest entry.
fn pick(probs: &[f32], rng: &mut Pcg) -> u32 {
    let total: f32 = probs.iter().sum();
    if !(total > 0.0) || !total.is_finite() {
        return argmax(probs) as u32;
    }
    let r = rng.uniform() * total;
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc {
            return i as u32;
        }
    }
    (probs.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Pcg::new(1);
        let logits = vec![0.0, 5.0, 1.0];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Pcg::new(2);
        let logits = vec![0.0, 10.0, 1.0];
        for _ in 0..50 {
            assert_eq!(
                sample(&logits, Sampling::Temperature(0.1), &mut rng),
                1
            );
        }
    }

    #[test]
    fn topk_restricts_support() {
        let mut rng = Pcg::new(3);
        let logits = vec![1.0, 0.9, -50.0, -50.0];
        for _ in 0..50 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 2, temperature: 1.0 },
                &mut rng,
            );
            assert!(t < 2);
        }
    }

    #[test]
    fn topk_ignores_nan_logits() {
        let mut rng = Pcg::new(4);
        let logits = vec![f32::NAN, 1.0, f32::NAN, 0.5, f32::NAN];
        for _ in 0..100 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 3, temperature: 1.0 },
                &mut rng,
            );
            assert!(t == 1 || t == 3, "sampled NaN index {t}");
        }
    }

    #[test]
    fn topk_matches_full_sort_selection() {
        // the partial selection must keep exactly the k largest logits
        let mut rng = Pcg::new(5);
        let logits: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut sorted: Vec<usize> = (0..logits.len()).collect();
        sorted.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let top8: std::collections::HashSet<usize> =
            sorted[..8].iter().copied().collect();
        for _ in 0..200 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 8, temperature: 1.0 },
                &mut rng,
            );
            assert!(top8.contains(&(t as usize)), "token {t} outside top-8");
        }
    }

    #[test]
    fn pick_handles_undermass_and_zero_mass() {
        let mut rng = Pcg::new(6);
        // mass 0.5: every draw must stay in-distribution, and index 2
        // (probability 0) must never be the rounding fallback
        for _ in 0..500 {
            let t = pick(&[0.3, 0.2, 0.0], &mut rng);
            assert!(t < 2, "picked zero-probability index {t}");
        }
        // all-zero mass: largest entry (index 0 by tie) not the last
        assert_eq!(pick(&[0.0, 0.0, 0.0], &mut rng), 0);
    }
}
