//! Token sampling: greedy, temperature, and top-k over a logits row.

use crate::linalg::{argmax, softmax_inplace};
use crate::util::rng::Pcg;

#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    Greedy,
    Temperature(f32),
    TopK { k: usize, temperature: f32 },
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], mode: Sampling, rng: &mut Pcg) -> u32 {
    match mode {
        Sampling::Greedy => argmax(logits) as u32,
        Sampling::Temperature(t) => {
            let mut p: Vec<f32> = logits.iter().map(|&l| l / t.max(1e-4)).collect();
            softmax_inplace(&mut p);
            pick(&p, rng)
        }
        Sampling::TopK { k, temperature } => {
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_by(|&a, &b| {
                logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(k.max(1));
            let mut p: Vec<f32> =
                idx.iter().map(|&i| logits[i] / temperature.max(1e-4)).collect();
            softmax_inplace(&mut p);
            idx[pick(&p, rng) as usize] as u32
        }
    }
}

fn pick(probs: &[f32], rng: &mut Pcg) -> u32 {
    let r = rng.uniform();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc {
            return i as u32;
        }
    }
    (probs.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Pcg::new(1);
        let logits = vec![0.0, 5.0, 1.0];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Pcg::new(2);
        let logits = vec![0.0, 10.0, 1.0];
        for _ in 0..50 {
            assert_eq!(
                sample(&logits, Sampling::Temperature(0.1), &mut rng),
                1
            );
        }
    }

    #[test]
    fn topk_restricts_support() {
        let mut rng = Pcg::new(3);
        let logits = vec![1.0, 0.9, -50.0, -50.0];
        for _ in 0..50 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 2, temperature: 1.0 },
                &mut rng,
            );
            assert!(t < 2);
        }
    }
}
