//! The quantized inference engine: offline preparation (calibration,
//! GPTQ, rotation, smoothing-scale computation) + the two runtime paths
//! (full-sequence forward for evaluation / prefill, batched single-token
//! decode against INT4 KV caches for serving).

use anyhow::{bail, Result};

use crate::linalg::gemm::{gemm_f32_bt, Mat};
use crate::quant::kv::{QuantVec, QuantVec8};
use crate::quant::qlinear::{PrepareAux, QLinear};
use crate::quant::rotation::Rotation;
use crate::quant::smoothquant::Calibration;
use crate::quant::{Method, QuantRecipe, RotationKind, Smoothing};

use super::config::{EngineConfig, ModelConfig};
use super::ops::{attend_single, rmsnorm, silu, RopeTable};
use super::weights::Weights;

/// Activations captured from an fp32 forward pass, grouped by projector
/// kind (the paper's Fig. 7/9 categories and the calibration source).
#[derive(Clone, Debug, Default)]
pub struct CapturedActs {
    /// Input of wq/wk/wv per layer: [T, dim]
    pub qkv: Vec<Mat>,
    /// Input of wo per layer: [T, dim]
    pub o: Vec<Mat>,
    /// Input of w_gate/w_up per layer: [T, dim]
    pub gate_up: Vec<Mat>,
    /// Input of w_down per layer: [T, ffn]  (SwiGLU output -> spikes!)
    pub down: Vec<Mat>,
}

impl CapturedActs {
    fn empty(n_layers: usize) -> CapturedActs {
        CapturedActs {
            qkv: Vec::with_capacity(n_layers),
            o: Vec::with_capacity(n_layers),
            gate_up: Vec::with_capacity(n_layers),
            down: Vec::with_capacity(n_layers),
        }
    }

    /// Merge captures from several sequences (row-wise concat per layer).
    pub fn merge(mut runs: Vec<CapturedActs>) -> CapturedActs {
        if runs.len() == 1 {
            return runs.pop().unwrap();
        }
        let mut out = runs.pop().unwrap();
        let cat = |dst: &mut Vec<Mat>, src: &[Mat]| {
            for (d, s) in dst.iter_mut().zip(src) {
                let mut data = std::mem::take(&mut d.data);
                data.extend_from_slice(&s.data);
                *d = Mat::from_vec(d.rows + s.rows, d.cols, data);
            }
        };
        for run in runs.iter() {
            cat(&mut out.qkv, &run.qkv);
            cat(&mut out.o, &run.o);
            cat(&mut out.gate_up, &run.gate_up);
            cat(&mut out.down, &run.down);
        }
        out
    }
}

/// fp32 forward that records every linear's input (mirror of python
/// `capture_activations`); used for SmoothQuant/GPTQ calibration and the
/// outlier-statistics harnesses.
pub fn capture_activations(
    w: &Weights,
    cfg: &ModelConfig,
    tokens: &[u32],
) -> CapturedActs {
    let t = tokens.len();
    let rope = RopeTable::new(cfg.max_seq.max(t), cfg.head_dim(), cfg.rope_theta);
    let mut acts = CapturedActs::empty(cfg.n_layers);
    // residual stream [T, dim]
    let mut x = Mat::zeros(t, cfg.dim);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(w.embed.row(tok as usize));
    }
    let mut h = Mat::zeros(t, cfg.dim);
    for layer in &w.layers {
        for i in 0..t {
            rmsnorm(x.row(i), &layer.attn_norm, h.row_mut(i), 1e-5);
        }
        acts.qkv.push(h.clone());
        let mut q = gemm_f32_bt(&h, &layer.wq);
        let mut k = gemm_f32_bt(&h, &layer.wk);
        let v = gemm_f32_bt(&h, &layer.wv);
        apply_rope_rows(&mut q, &rope, cfg.n_heads, cfg.head_dim(), 0);
        apply_rope_rows(&mut k, &rope, cfg.n_kv_heads, cfg.head_dim(), 0);
        let att = causal_attention(&q, &k, &v, cfg);
        acts.o.push(att.clone());
        let o = gemm_f32_bt(&att, &layer.wo);
        for i in 0..t {
            for (xv, ov) in x.row_mut(i).iter_mut().zip(o.row(i)) {
                *xv += ov;
            }
        }
        for i in 0..t {
            rmsnorm(x.row(i), &layer.mlp_norm, h.row_mut(i), 1e-5);
        }
        acts.gate_up.push(h.clone());
        let gate = gemm_f32_bt(&h, &layer.w_gate);
        let up = gemm_f32_bt(&h, &layer.w_up);
        let mut act = Mat::zeros(t, cfg.ffn);
        for i in 0..t * cfg.ffn {
            act.data[i] = silu(gate.data[i]) * up.data[i];
        }
        acts.down.push(act.clone());
        let down = gemm_f32_bt(&act, &layer.w_down);
        for i in 0..t {
            for (xv, dv) in x.row_mut(i).iter_mut().zip(down.row(i)) {
                *xv += dv;
            }
        }
    }
    acts
}

fn apply_rope_rows(
    m: &mut Mat,
    rope: &RopeTable,
    n_heads: usize,
    head_dim: usize,
    start_pos: usize,
) {
    for i in 0..m.rows {
        let pos = start_pos + i;
        let row = m.row_mut(i);
        for hd in 0..n_heads {
            rope.apply(&mut row[hd * head_dim..(hd + 1) * head_dim], pos);
        }
    }
}

/// Full causal GQA attention over [T, ...] projections (fp path).
fn causal_attention(q: &Mat, k: &Mat, v: &Mat, cfg: &ModelConfig) -> Mat {
    let t = q.rows;
    let hd = cfg.head_dim();
    let rep = cfg.n_heads / cfg.n_kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Mat::zeros(t, cfg.n_heads * hd);
    let mut att = vec![0.0f32; t];
    for h in 0..cfg.n_heads {
        let kvh = h / rep;
        for i in 0..t {
            let qh = &q.row(i)[h * hd..(h + 1) * hd];
            for j in 0..=i {
                let kh = &k.row(j)[kvh * hd..(kvh + 1) * hd];
                att[j] = crate::kernels::dot_f32(qh, kh) * scale;
            }
            crate::linalg::softmax_inplace(&mut att[..=i]);
            let orow = out.row_mut(i);
            let oh = &mut orow[h * hd..(h + 1) * hd];
            for j in 0..=i {
                let w = att[j];
                if w < 1e-12 {
                    continue;
                }
                let vh = &v.row(j)[kvh * hd..(kvh + 1) * hd];
                for (o, &vv) in oh.iter_mut().zip(vh) {
                    *o += w * vv;
                }
            }
        }
    }
    out
}

/// One prepared transformer block.
pub struct QLayer {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub wq: QLinear,
    pub wk: QLinear,
    pub wv: QLinear,
    pub wo: QLinear,
    pub w_gate: QLinear,
    pub w_up: QLinear,
    pub w_down: QLinear,
}

/// KV-cache storage: fp32 rows, nibble-packed INT4 (paper 4.1), or
/// byte-wide INT8 (the KV ablation's middle point).  Used both by the
/// flat per-sequence [`KvCache`] and, per block, by the paged
/// [`crate::kvpool`] allocator.
#[derive(Clone)]
pub enum KvStore {
    F32(Vec<Vec<f32>>),
    Int4 { rows: Vec<QuantVec>, group: usize },
    Int8 { rows: Vec<QuantVec8>, group: usize },
}

impl KvStore {
    pub fn new(kv_bits: u8, group: usize) -> KvStore {
        match kv_bits {
            4 => KvStore::Int4 { rows: Vec::new(), group },
            8 => KvStore::Int8 { rows: Vec::new(), group },
            _ => KvStore::F32(Vec::new()),
        }
    }

    /// Append a row; returns the bytes it occupies (for the running
    /// memory counters — summing rows on every metrics poll is O(T)).
    pub fn push(&mut self, row: &[f32]) -> usize {
        match self {
            KvStore::F32(rows) => {
                rows.push(row.to_vec());
                row.len() * 4
            }
            KvStore::Int4 { rows, group } => {
                let q = QuantVec::quantize(row, *group);
                let b = q.bytes();
                rows.push(q);
                b
            }
            KvStore::Int8 { rows, group } => {
                let q = QuantVec8::quantize(row, *group);
                let b = q.bytes();
                rows.push(q);
                b
            }
        }
    }

    pub fn len(&self) -> usize {
        match self {
            KvStore::F32(rows) => rows.len(),
            KvStore::Int4 { rows, .. } => rows.len(),
            KvStore::Int8 { rows, .. } => rows.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize all rows as fp32 (quantized rows dequantize on read).
    pub fn dequantize_all(&self) -> Vec<Vec<f32>> {
        match self {
            KvStore::F32(rows) => rows.clone(),
            KvStore::Int4 { rows, .. } => {
                rows.iter().map(|q| q.dequantize()).collect()
            }
            KvStore::Int8 { rows, .. } => {
                rows.iter().map(|q| q.dequantize()).collect()
            }
        }
    }

    /// Copy of the first `n` rows (clamped to the stored count).  Rows
    /// are independently quantized, so a row-boundary cut is exact —
    /// this is what the paged pool's partial-block tail sharing relies
    /// on.
    pub fn truncated(&self, n: usize) -> KvStore {
        match self {
            KvStore::F32(rows) => KvStore::F32(rows[..n.min(rows.len())].to_vec()),
            KvStore::Int4 { rows, group } => KvStore::Int4 {
                rows: rows[..n.min(rows.len())].to_vec(),
                group: *group,
            },
            KvStore::Int8 { rows, group } => KvStore::Int8 {
                rows: rows[..n.min(rows.len())].to_vec(),
                group: *group,
            },
        }
    }

    /// Dequantize (or copy) row `i` into `out`.
    pub fn row_into(&self, i: usize, out: &mut Vec<f32>) {
        match self {
            KvStore::F32(rows) => {
                out.resize(rows[i].len(), 0.0);
                out.copy_from_slice(&rows[i]);
            }
            KvStore::Int4 { rows, .. } => {
                out.resize(rows[i].len, 0.0);
                rows[i].dequantize_into(out);
            }
            KvStore::Int8 { rows, .. } => {
                out.resize(rows[i].len(), 0.0);
                rows[i].dequantize_into(out);
            }
        }
    }

    /// Borrow fp32 rows directly, or dequantize quantized rows into
    /// reusable scratch (the decode hot path: no per-step allocation).
    pub fn view<'a>(&'a self, scratch: &'a mut Vec<Vec<f32>>) -> &'a [Vec<f32>] {
        match self {
            KvStore::F32(rows) => rows,
            KvStore::Int4 { rows, .. } => {
                while scratch.len() < rows.len() {
                    scratch.push(Vec::new());
                }
                for (s, q) in scratch.iter_mut().zip(rows) {
                    s.resize(q.len, 0.0);
                    q.dequantize_into(s);
                }
                &scratch[..rows.len()]
            }
            KvStore::Int8 { rows, .. } => {
                while scratch.len() < rows.len() {
                    scratch.push(Vec::new());
                }
                for (s, q) in scratch.iter_mut().zip(rows) {
                    s.resize(q.len(), 0.0);
                    q.dequantize_into(s);
                }
                &scratch[..rows.len()]
            }
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            KvStore::F32(rows) => rows.iter().map(|r| r.len() * 4).sum(),
            KvStore::Int4 { rows, .. } => rows.iter().map(|q| q.bytes()).sum(),
            KvStore::Int8 { rows, .. } => rows.iter().map(|q| q.bytes()).sum(),
        }
    }
}

/// Per-sequence KV cache across layers (the flat, non-paged backend).
pub struct KvCache {
    pub layers: Vec<(KvStore, KvStore)>,
    pub pos: usize,
    /// Running byte counter, updated on append (metrics polls are O(1)
    /// instead of re-summing every row).
    bytes: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, ecfg: &EngineConfig) -> KvCache {
        let recipe = ecfg.resolved();
        let group = recipe.kv_group.min(cfg.head_dim().max(1));
        KvCache {
            layers: (0..cfg.n_layers)
                .map(|_| {
                    (
                        KvStore::new(recipe.kv_bits, group),
                        KvStore::new(recipe.kv_bits, group),
                    )
                })
                .collect(),
            pos: 0,
            bytes: 0,
        }
    }

    /// Append one K/V row pair for `layer`, maintaining the byte counter.
    pub fn push_row(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let (ks, vs) = &mut self.layers[layer];
        self.bytes += ks.push(k) + vs.push(v);
    }

    pub fn len(&self) -> usize {
        self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// Batched K/V access the transformer forwards read and write through:
/// implemented by the flat [`KvCache`] and by the paged block-table pool
/// ([`crate::kvpool`]).  Rows are pushed position-addressed so paged
/// backends can map them onto fixed-size blocks; `pos` is the cached
/// length before the current forward and only changes via [`advance`].
///
/// [`advance`]: KvSeqBatch::advance
pub trait KvSeqBatch {
    /// Number of sequences in the batch.
    fn batch_len(&self) -> usize;

    /// Current cached length of sequence `i`.
    fn pos(&self, i: usize) -> usize;

    /// Append one K/V row pair for `layer` of sequence `i` at absolute
    /// position `pos` (positions arrive in ascending order per layer).
    fn push_row(&mut self, i: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// Materialize every cached row of sequence `i` for `layer` as fp32
    /// (INT4 dequantizes into the reusable scratch buffers).
    fn view_rows<'a>(
        &'a self,
        i: usize,
        layer: usize,
        k_scratch: &'a mut Vec<Vec<f32>>,
        v_scratch: &'a mut Vec<Vec<f32>>,
    ) -> (&'a [Vec<f32>], &'a [Vec<f32>]);

    /// Advance sequence `i` by `n` positions (rows were pushed for every
    /// layer).
    fn advance(&mut self, i: usize, n: usize);
}

/// Flat per-sequence caches adapted to the batched KV interface.
struct FlatKvBatch<'a, 'b> {
    items: &'a mut [(&'b mut KvCache, u32)],
}

impl KvSeqBatch for FlatKvBatch<'_, '_> {
    fn batch_len(&self) -> usize {
        self.items.len()
    }

    fn pos(&self, i: usize) -> usize {
        self.items[i].0.pos
    }

    fn push_row(&mut self, i: usize, layer: usize, _pos: usize, k: &[f32], v: &[f32]) {
        self.items[i].0.push_row(layer, k, v);
    }

    fn view_rows<'a>(
        &'a self,
        i: usize,
        layer: usize,
        k_scratch: &'a mut Vec<Vec<f32>>,
        v_scratch: &'a mut Vec<Vec<f32>>,
    ) -> (&'a [Vec<f32>], &'a [Vec<f32>]) {
        let (ks, vs) = &self.items[i].0.layers[layer];
        (ks.view(k_scratch), vs.view(v_scratch))
    }

    fn advance(&mut self, i: usize, n: usize) {
        self.items[i].0.pos += n;
    }
}

/// No-cache sink for evaluation forwards: attention stays in-register
/// (`pos` is always 0) and pushed rows are discarded.
struct DiscardKv;

impl KvSeqBatch for DiscardKv {
    fn batch_len(&self) -> usize {
        1
    }

    fn pos(&self, _i: usize) -> usize {
        0
    }

    fn push_row(&mut self, _i: usize, _layer: usize, _pos: usize, _k: &[f32], _v: &[f32]) {}

    fn view_rows<'a>(
        &'a self,
        _i: usize,
        _layer: usize,
        _k_scratch: &'a mut Vec<Vec<f32>>,
        _v_scratch: &'a mut Vec<Vec<f32>>,
    ) -> (&'a [Vec<f32>], &'a [Vec<f32>]) {
        unreachable!("DiscardKv holds no rows (pos is always 0)")
    }

    fn advance(&mut self, _i: usize, _n: usize) {}
}

/// The prepared quantized model.
pub struct QuantModel {
    pub mcfg: ModelConfig,
    pub ecfg: EngineConfig,
    /// The resolved strategy every layer was prepared under
    /// (`ecfg.resolved()`, frozen at prepare time).
    pub recipe: QuantRecipe,
    pub embed: Mat,
    pub head: Mat,
    pub final_norm: Vec<f32>,
    pub layers: Vec<QLayer>,
    rope: RopeTable,
}

impl QuantModel {
    /// Offline preparation.  `calib_tokens` drives SmoothQuant scales and
    /// GPTQ (required for calibrated smoothing and whenever the recipe
    /// says `gptq`); `spin_rotations` supplies (R_dim, R_ffn) for dense
    /// rotations.  With an explicit `ecfg.recipe` a missing dense
    /// rotation is synthesized closed-form (QuaRot-style); the legacy
    /// SpinQuant method keeps requiring learned rotations.
    pub fn prepare(
        w: &Weights,
        mcfg: &ModelConfig,
        ecfg: &EngineConfig,
        calib_tokens: Option<&[u32]>,
        spin_rotations: Option<(Mat, Mat)>,
    ) -> Result<QuantModel> {
        // resolve the kernel registry up front: backend selection + the
        // one-shot tile autotuner run at model-prep time, never inside a
        // serving request
        let _kernels = crate::kernels::registry();
        let recipe = ecfg.resolved();
        recipe.validate()?;
        let need_calib = recipe.smoothing == Smoothing::Calibrated
            || (recipe.gptq && recipe.w_bits == 4);
        let acts = match (need_calib, calib_tokens) {
            (true, Some(toks)) => {
                // match the python calibration protocol: independent
                // 64-token windows (attention does not cross windows)
                let win = 64.min(toks.len().max(1));
                let runs: Vec<CapturedActs> = toks
                    .chunks(win)
                    .filter(|c| c.len() == win)
                    .map(|c| capture_activations(w, mcfg, c))
                    .collect();
                if runs.is_empty() {
                    bail!("calibration tokens too short");
                }
                Some(CapturedActs::merge(runs))
            }
            (true, None) => {
                bail!("{:?} requires calibration tokens", ecfg.method)
            }
            _ => None,
        };
        // rotations are resolved once per width, never per layer, so
        // gptq calibration and weight preparation agree exactly; every
        // width is validated here — non-power-of-two dims get the
        // block-Hadamard fallback instead of the historical fwht panic
        let (rot_dim, rot_ffn): (Option<Rotation>, Option<Rotation>) =
            match recipe.rotation {
                RotationKind::None => (None, None),
                RotationKind::Hadamard => (
                    Some(Rotation::hadamard_for(mcfg.dim)),
                    Some(Rotation::hadamard_for(mcfg.ffn)),
                ),
                RotationKind::Dense => match spin_rotations {
                    Some((rd, rf)) => {
                        (Some(Rotation::Dense(rd)), Some(Rotation::Dense(rf)))
                    }
                    None if ecfg.recipe.is_some() => (
                        Some(Rotation::closed_form_dense(mcfg.dim, 11)),
                        Some(Rotation::closed_form_dense(mcfg.ffn, 13)),
                    ),
                    None => bail!("SpinQuant needs rotations"),
                },
            };
        if let Some(r) = &rot_dim {
            r.validate(mcfg.dim)?;
        }
        if let Some(r) = &rot_ffn {
            r.validate(mcfg.ffn)?;
        }

        let mut layers = Vec::with_capacity(mcfg.n_layers);
        for (i, lw) in w.layers.iter().enumerate() {
            let act_for = |kind: usize| -> Option<&Mat> {
                acts.as_ref().map(|a| match kind {
                    0 => &a.qkv[i],
                    1 => &a.o[i],
                    2 => &a.gate_up[i],
                    _ => &a.down[i],
                })
            };
            let prep = |wmat: &Mat,
                        name: &str,
                        kind: usize,
                        rot: Option<&Rotation>|
             -> Result<QLinear> {
                let x = act_for(kind);
                // calibration for calibrated (SmoothQuant-style) scales
                let calib = x.map(|xm| {
                    Calibration::from_batches([xm].into_iter(), xm.cols)
                });
                // GPTQ calibration in the recipe's space (capped at 256
                // rows, matching python aot.py's `x_calib[:256]`)
                let cap_rows = |m: Mat| -> Mat {
                    if m.rows <= 256 {
                        m
                    } else {
                        let cols = m.cols;
                        Mat::from_vec(256, cols, m.data[..256 * cols].to_vec())
                    }
                };
                let gptq_x: Option<Mat> = if recipe.gptq && recipe.w_bits == 4
                {
                    x.map(|xm| {
                        // mirror the forward pipeline: divide by the
                        // calibrated scales, then rotate
                        let mut m = xm.clone();
                        if recipe.smoothing == Smoothing::Calibrated {
                            let c = calib.as_ref().unwrap();
                            let s = crate::quant::smoothquant::smoothing_scales(
                                c,
                                wmat,
                                recipe.alpha,
                            );
                            m = crate::quant::smoothquant::smooth_activation(
                                &m, &s,
                            );
                        }
                        if let Some(r) = rot {
                            m = r.apply(&m);
                        }
                        m
                    })
                    .map(cap_rows)
                } else {
                    None
                };
                let mut lin = QLinear::prepare_recipe(
                    wmat,
                    &recipe,
                    PrepareAux {
                        calib: calib.as_ref(),
                        gptq_calib: gptq_x.as_ref(),
                        rotation: rot.cloned(),
                    },
                )?;
                // per-layer quant-health label (sampled probes key on it)
                lin.probe = Some(format!("l{i}.{name}"));
                Ok(lin)
            };
            layers.push(QLayer {
                attn_norm: lw.attn_norm.clone(),
                mlp_norm: lw.mlp_norm.clone(),
                wq: prep(&lw.wq, "wq", 0, rot_dim.as_ref())?,
                wk: prep(&lw.wk, "wk", 0, rot_dim.as_ref())?,
                wv: prep(&lw.wv, "wv", 0, rot_dim.as_ref())?,
                wo: prep(&lw.wo, "wo", 1, rot_dim.as_ref())?,
                w_gate: prep(&lw.w_gate, "w_gate", 2, rot_dim.as_ref())?,
                w_up: prep(&lw.w_up, "w_up", 2, rot_dim.as_ref())?,
                w_down: prep(&lw.w_down, "w_down", 3, rot_ffn.as_ref())?,
            });
        }
        Ok(QuantModel {
            mcfg: *mcfg,
            ecfg: *ecfg,
            recipe,
            embed: w.embed.clone(),
            head: w.head.clone(),
            final_norm: w.final_norm.clone(),
            layers,
            rope: RopeTable::new(mcfg.max_seq, mcfg.head_dim(), mcfg.rope_theta),
        })
    }

    pub fn kv_group(&self) -> usize {
        self.recipe.kv_group.min(self.mcfg.head_dim().max(1))
    }

    /// Full-sequence forward (prefill / evaluation path).  Returns logits
    /// [T, vocab]; if `cache` is given, K/V rows are appended per layer
    /// so decode can continue from `T` (a non-empty cache is treated as
    /// an already-cached prefix, as after a kvpool prefix hit).
    pub fn forward_full(&self, tokens: &[u32], cache: Option<&mut KvCache>) -> Mat {
        match cache {
            Some(c) => {
                let mut items = [(c, 0u32)];
                let mut flat = FlatKvBatch { items: &mut items };
                self.forward_seq(tokens, &mut flat, 0)
            }
            None => self.forward_seq(tokens, &mut DiscardKv, 0),
        }
    }

    /// Batched single-token decode: each (cache, token) advances by one
    /// position.  Returns logits [B, vocab].
    pub fn decode_batch(&self, batch: &mut [(&mut KvCache, u32)]) -> Mat {
        let tokens: Vec<u32> = batch.iter().map(|(_, t)| *t).collect();
        let mut flat = FlatKvBatch { items: batch };
        self.decode_step(&mut flat, &tokens)
    }

    /// Forward `tokens` for sequence `slot` of `kv`, starting at its
    /// current position (0 = fresh prefill, where attention runs entirely
    /// in-register exactly like the flat path; >0 continues a cached
    /// prefix, attending over dequantized cached rows + the new rows).
    /// Returns logits [T, vocab] for the new positions and advances the
    /// sequence by T.
    pub fn forward_seq<B: KvSeqBatch>(
        &self,
        tokens: &[u32],
        kv: &mut B,
        slot: usize,
    ) -> Mat {
        let t = tokens.len();
        let cfg = &self.mcfg;
        let p0 = kv.pos(slot);
        let mut x = Mat::zeros(t, cfg.dim);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        let mut h = Mat::zeros(t, cfg.dim);
        let mut att_scratch: Vec<f32> = Vec::new();
        let mut k_scratch: Vec<Vec<f32>> = Vec::new();
        let mut v_scratch: Vec<Vec<f32>> = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            for i in 0..t {
                rmsnorm(x.row(i), &layer.attn_norm, h.row_mut(i), 1e-5);
            }
            let mut q = layer.wq.forward(&h);
            let mut k = layer.wk.forward(&h);
            let mut v = layer.wv.forward(&h);
            apply_rope_rows(&mut q, &self.rope, cfg.n_heads, cfg.head_dim(), p0);
            apply_rope_rows(&mut k, &self.rope, cfg.n_kv_heads, cfg.head_dim(), p0);
            if self.recipe.kv_bits < 16 {
                let g = self.kv_group();
                let bits = self.recipe.kv_bits;
                for i in 0..t {
                    crate::quant::kv::fake_quant_bits_inplace(k.row_mut(i), g, bits);
                    crate::quant::kv::fake_quant_bits_inplace(v.row_mut(i), g, bits);
                }
            }
            for i in 0..t {
                kv.push_row(slot, li, p0 + i, k.row(i), v.row(i));
            }
            let att = if p0 == 0 {
                causal_attention(&q, &k, &v, cfg)
            } else {
                // suffix attention: cached prefix rows + the rows just
                // pushed (view covers both)
                let mut att = Mat::zeros(t, cfg.n_heads * cfg.head_dim());
                let (keys, vals) =
                    kv.view_rows(slot, li, &mut k_scratch, &mut v_scratch);
                for i in 0..t {
                    attend_single(
                        q.row(i),
                        &keys[..p0 + i + 1],
                        &vals[..p0 + i + 1],
                        cfg.n_heads,
                        cfg.n_kv_heads,
                        cfg.head_dim(),
                        att.row_mut(i),
                        &mut att_scratch,
                    );
                }
                att
            };
            let o = layer.wo.forward(&att);
            for i in 0..t {
                for (xv, ov) in x.row_mut(i).iter_mut().zip(o.row(i)) {
                    *xv += ov;
                }
            }
            for i in 0..t {
                rmsnorm(x.row(i), &layer.mlp_norm, h.row_mut(i), 1e-5);
            }
            let gate = layer.w_gate.forward(&h);
            let up = layer.w_up.forward(&h);
            let mut act = Mat::zeros(t, cfg.ffn);
            for i in 0..t * cfg.ffn {
                act.data[i] = silu(gate.data[i]) * up.data[i];
            }
            let down = layer.w_down.forward(&act);
            for i in 0..t {
                for (xv, dv) in x.row_mut(i).iter_mut().zip(down.row(i)) {
                    *xv += dv;
                }
            }
        }
        for i in 0..t {
            let row = x.row(i).to_vec();
            rmsnorm(&row, &self.final_norm, x.row_mut(i), 1e-5);
        }
        kv.advance(slot, t);
        gemm_f32_bt(&x, &self.head)
    }

    /// One batched decode step over any KV backend: sequence `i` consumes
    /// `tokens[i]` at its current position.  Returns logits [B, vocab].
    pub fn decode_step<B: KvSeqBatch>(&self, kv: &mut B, tokens: &[u32]) -> Mat {
        let b = tokens.len();
        debug_assert_eq!(b, kv.batch_len());
        let cfg = &self.mcfg;
        let mut x = Mat::zeros(b, cfg.dim);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        let mut h = Mat::zeros(b, cfg.dim);
        let mut scratch = Vec::new();
        let mut k_scratch: Vec<Vec<f32>> = Vec::new();
        let mut v_scratch: Vec<Vec<f32>> = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            for i in 0..b {
                rmsnorm(x.row(i), &layer.attn_norm, h.row_mut(i), 1e-5);
            }
            let mut q = layer.wq.forward(&h);
            let mut k = layer.wk.forward(&h);
            let mut v = layer.wv.forward(&h);
            for i in 0..b {
                let pos = kv.pos(i);
                let qrow = q.row_mut(i);
                for hd in 0..cfg.n_heads {
                    self.rope.apply(
                        &mut qrow
                            [hd * cfg.head_dim()..(hd + 1) * cfg.head_dim()],
                        pos,
                    );
                }
                let krow = k.row_mut(i);
                for hd in 0..cfg.n_kv_heads {
                    self.rope.apply(
                        &mut krow
                            [hd * cfg.head_dim()..(hd + 1) * cfg.head_dim()],
                        pos,
                    );
                }
            }
            if self.recipe.kv_bits < 16 {
                let g = self.kv_group();
                let bits = self.recipe.kv_bits;
                for i in 0..b {
                    crate::quant::kv::fake_quant_bits_inplace(k.row_mut(i), g, bits);
                    crate::quant::kv::fake_quant_bits_inplace(v.row_mut(i), g, bits);
                }
            }
            let mut att_out = Mat::zeros(b, cfg.dim);
            for i in 0..b {
                let pos = kv.pos(i);
                kv.push_row(i, li, pos, k.row(i), v.row(i));
                // view this sequence's keys/values (INT4 dequantizes into
                // reusable scratch; fp32 borrows with no copy)
                let (keys, vals) =
                    kv.view_rows(i, li, &mut k_scratch, &mut v_scratch);
                attend_single(
                    q.row(i),
                    keys,
                    vals,
                    cfg.n_heads,
                    cfg.n_kv_heads,
                    cfg.head_dim(),
                    att_out.row_mut(i),
                    &mut scratch,
                );
            }
            let o = layer.wo.forward(&att_out);
            for i in 0..b {
                for (xv, ov) in x.row_mut(i).iter_mut().zip(o.row(i)) {
                    *xv += ov;
                }
            }
            for i in 0..b {
                rmsnorm(x.row(i), &layer.mlp_norm, h.row_mut(i), 1e-5);
            }
            let gate = layer.w_gate.forward(&h);
            let up = layer.w_up.forward(&h);
            let mut act = Mat::zeros(b, cfg.ffn);
            for i in 0..b * cfg.ffn {
                act.data[i] = silu(gate.data[i]) * up.data[i];
            }
            let down = layer.w_down.forward(&act);
            for i in 0..b {
                for (xv, dv) in x.row_mut(i).iter_mut().zip(down.row(i)) {
                    *xv += dv;
                }
            }
        }
        for i in 0..b {
            kv.advance(i, 1);
        }
        for i in 0..b {
            let row = x.row(i).to_vec();
            rmsnorm(&row, &self.final_norm, x.row_mut(i), 1e-5);
        }
        gemm_f32_bt(&x, &self.head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Scheme;

    fn tiny() -> (Weights, ModelConfig) {
        let cfg = ModelConfig { n_layers: 2, max_seq: 64, ..Default::default() };
        (Weights::random(&cfg, 7), cfg)
    }

    fn calib_tokens() -> Vec<u32> {
        (0..48u32).map(|i| (i * 37 + 11) % 256).collect()
    }

    #[test]
    fn fp_forward_shapes() {
        let (w, cfg) = tiny();
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        let m = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
        let logits = m.forward_full(&[1, 2, 3, 4], None);
        assert_eq!((logits.rows, logits.cols), (4, cfg.vocab));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_full_forward_fp() {
        let (w, cfg) = tiny();
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        let m = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
        let toks: Vec<u32> = vec![5, 9, 200, 31, 77];
        let full = m.forward_full(&toks, None);
        let mut cache = KvCache::new(&cfg, &ecfg);
        let mut rows = Vec::new();
        for &t in &toks {
            let mut batch = [(&mut cache, t)];
            let lg = m.decode_batch(&mut batch);
            rows.push(lg.row(0).to_vec());
        }
        for (i, row) in rows.iter().enumerate() {
            for (a, b) in row.iter().zip(full.row(i)) {
                assert!((a - b).abs() < 1e-3, "pos {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn prefill_then_decode_consistent() {
        let (w, cfg) = tiny();
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        let m = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
        let toks: Vec<u32> = vec![5, 9, 200, 31];
        // full forward over 5 tokens
        let mut all = toks.clone();
        all.push(42);
        let full = m.forward_full(&all, None);
        // prefill 4 then decode 1
        let mut cache = KvCache::new(&cfg, &ecfg);
        m.forward_full(&toks, Some(&mut cache));
        assert_eq!(cache.len(), 4);
        let mut batch = [(&mut cache, 42u32)];
        let lg = m.decode_batch(&mut batch);
        for (a, b) in lg.row(0).iter().zip(full.row(4)) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn all_methods_prepare_and_run() {
        let (w, cfg) = tiny();
        let toks = calib_tokens();
        for method in Method::ALL {
            if method == Method::SpinQuant {
                continue; // needs learned rotations (separate test)
            }
            let ecfg = EngineConfig {
                method,
                scheme: if method == Method::Fp {
                    Scheme::FP
                } else {
                    Scheme::A4W4KV4
                },
                group: 32,
                gptq: method != Method::Rtn && method != Method::Fp,
                ..Default::default()
            };
            let m = QuantModel::prepare(&w, &cfg, &ecfg, Some(&toks), None)
                .unwrap_or_else(|e| panic!("{method:?}: {e}"));
            let lg = m.forward_full(&[1, 2, 3], None);
            assert!(
                lg.data.iter().all(|v| v.is_finite()),
                "{method:?} produced non-finite logits"
            );
        }
    }

    #[test]
    fn int4_kv_cache_is_small() {
        let (w, cfg) = tiny();
        let e4 = EngineConfig {
            method: Method::Rtn,
            scheme: Scheme::A4W4KV4,
            gptq: false,
            kv_group: 32,
            ..Default::default()
        };
        let e16 = EngineConfig { scheme: Scheme::A4W4KV16, ..e4 };
        let m4 = QuantModel::prepare(&w, &cfg, &e4, None, None).unwrap();
        let m16 = QuantModel::prepare(&w, &cfg, &e16, None, None).unwrap();
        let toks: Vec<u32> = (0..32).collect();
        let mut c4 = KvCache::new(&cfg, &e4);
        let mut c16 = KvCache::new(&cfg, &e16);
        m4.forward_full(&toks, Some(&mut c4));
        m16.forward_full(&toks, Some(&mut c16));
        assert!(
            (c4.bytes() as f32) < 0.3 * c16.bytes() as f32,
            "int4 {} vs fp32 {}",
            c4.bytes(),
            c16.bytes()
        );
    }

    #[test]
    fn recipe_config_matches_legacy_config_bitwise() {
        // an explicit recipe equal to the legacy knobs' mapping must
        // produce identical logits (the tentpole equivalence guarantee)
        let (w, cfg) = tiny();
        let legacy = EngineConfig {
            method: Method::Rrs,
            scheme: Scheme::A4W4KV4,
            group: 32,
            gptq: false,
            ..Default::default()
        };
        let via_recipe = EngineConfig::from_recipe(legacy.resolved());
        let m1 = QuantModel::prepare(&w, &cfg, &legacy, None, None).unwrap();
        let m2 = QuantModel::prepare(&w, &cfg, &via_recipe, None, None).unwrap();
        let toks: Vec<u32> = vec![3, 1, 4, 1, 5];
        let l1 = m1.forward_full(&toks, None);
        let l2 = m2.forward_full(&toks, None);
        assert_eq!(l1.data, l2.data);
    }

    #[test]
    fn kv8_cache_sits_between_int4_and_fp32() {
        let (w, cfg) = tiny();
        let base = EngineConfig {
            method: Method::Rtn,
            scheme: Scheme::A4W4KV4,
            gptq: false,
            kv_group: 32,
            ..Default::default()
        };
        let mk = |spec: &str| {
            EngineConfig::from_recipe(
                crate::quant::QuantRecipe::parse(spec).unwrap(),
            )
        };
        let e8 = mk("rtn:a4w4kv8:g128:kvg32:nogptq");
        let e16 = EngineConfig { scheme: Scheme::A4W4KV16, ..base };
        let m4 = QuantModel::prepare(&w, &cfg, &base, None, None).unwrap();
        let m8 = QuantModel::prepare(&w, &cfg, &e8, None, None).unwrap();
        let m16 = QuantModel::prepare(&w, &cfg, &e16, None, None).unwrap();
        let toks: Vec<u32> = (0..32).collect();
        let mut c4 = KvCache::new(&cfg, &base);
        let mut c8 = KvCache::new(&cfg, &e8);
        let mut c16 = KvCache::new(&cfg, &e16);
        m4.forward_full(&toks, Some(&mut c4));
        m8.forward_full(&toks, Some(&mut c8));
        m16.forward_full(&toks, Some(&mut c16));
        assert!(c4.bytes() < c8.bytes(), "{} vs {}", c4.bytes(), c8.bytes());
        assert!(c8.bytes() < c16.bytes(), "{} vs {}", c8.bytes(), c16.bytes());
        // int8 KV decode still produces finite logits
        let mut batch = [(&mut c8, 7u32)];
        let lg = m8.decode_batch(&mut batch);
        assert!(lg.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_decode_stays_close_to_its_prefill() {
        // rtn decode vs rtn full-forward: row-local quant => identical
        let (w, cfg) = tiny();
        let ecfg = EngineConfig {
            method: Method::Rtn,
            scheme: Scheme::A4W4KV16,
            gptq: false,
            ..Default::default()
        };
        let m = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
        let toks: Vec<u32> = vec![10, 20, 30];
        let full = m.forward_full(&toks, None);
        let mut cache = KvCache::new(&cfg, &ecfg);
        let mut last = Mat::zeros(1, 1);
        for &t in &toks {
            let mut batch = [(&mut cache, t)];
            last = m.decode_batch(&mut batch);
        }
        // final-position logits agree (per-token quant is row-local)
        for (a, b) in last.row(0).iter().zip(full.row(toks.len() - 1)) {
            assert!((a - b).abs() < 2e-2, "{a} vs {b}");
        }
    }
}
