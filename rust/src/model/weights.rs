//! Trained weights: loading from `artifacts/weights.rrsw` and the
//! outlier-profile injection used by the Table-1/2 model-family sweep.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::linalg::gemm::Mat;
use crate::util::io::{read_rrsw, Tensor};
use crate::util::rng::Pcg;

use super::config::ModelConfig;

/// Per-layer fp32 weights (names mirror the python param dict).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub w_gate: Mat,
    pub w_up: Mat,
    pub w_down: Mat,
}

/// Full fp32 model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub embed: Mat,
    pub head: Mat,
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

fn mat_of(t: &Tensor) -> Result<Mat> {
    let (r, c) = t.dims2()?;
    Ok(Mat::from_vec(r, c, t.as_f32()?.to_vec()))
}

fn vec_of(t: &Tensor) -> Result<Vec<f32>> {
    Ok(t.as_f32()?.to_vec())
}

impl Weights {
    /// Load from a `.rrsw` written by python's `io_rrsw.write_rrsw`.
    pub fn load(path: impl AsRef<Path>, cfg: &ModelConfig) -> Result<Weights> {
        let raw = read_rrsw(path)?;
        Weights::from_tensors(&raw, cfg)
    }

    pub fn from_tensors(
        raw: &BTreeMap<String, Tensor>,
        cfg: &ModelConfig,
    ) -> Result<Weights> {
        let get = |name: &str| -> Result<&Tensor> {
            raw.get(name).with_context(|| format!("weights missing '{name}'"))
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            layers.push(LayerWeights {
                attn_norm: vec_of(get(&format!("{p}attn_norm"))?)?,
                mlp_norm: vec_of(get(&format!("{p}mlp_norm"))?)?,
                wq: mat_of(get(&format!("{p}wq"))?)?,
                wk: mat_of(get(&format!("{p}wk"))?)?,
                wv: mat_of(get(&format!("{p}wv"))?)?,
                wo: mat_of(get(&format!("{p}wo"))?)?,
                w_gate: mat_of(get(&format!("{p}w_gate"))?)?,
                w_up: mat_of(get(&format!("{p}w_up"))?)?,
                w_down: mat_of(get(&format!("{p}w_down"))?)?,
            });
        }
        Ok(Weights {
            embed: mat_of(get("embed")?)?,
            head: mat_of(get("head")?)?,
            final_norm: vec_of(get("final_norm")?)?,
            layers,
        })
    }

    /// Random weights for tests/benches (He-style, matches python scale).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Pcg::new(seed);
        let mut mat = |rows: usize, cols: usize| {
            let std = 1.0 / (cols as f32).sqrt();
            let data: Vec<f32> =
                (0..rows * cols).map(|_| rng.normal() * std).collect();
            Mat::from_vec(rows, cols, data)
        };
        let kd = cfg.kv_dim();
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: vec![1.0; cfg.dim],
                mlp_norm: vec![1.0; cfg.dim],
                wq: mat(cfg.dim, cfg.dim),
                wk: mat(kd, cfg.dim),
                wv: mat(kd, cfg.dim),
                wo: mat(cfg.dim, cfg.dim),
                w_gate: mat(cfg.ffn, cfg.dim),
                w_up: mat(cfg.ffn, cfg.dim),
                w_down: mat(cfg.dim, cfg.ffn),
            })
            .collect();
        Weights {
            embed: mat(cfg.vocab, cfg.dim),
            head: mat(cfg.vocab, cfg.dim),
            final_norm: vec![1.0; cfg.dim],
            layers,
        }
    }
}

/// Outlier-injection profile (mirror of python compile/outliers.py; the
/// Table-1 "model family" columns).  Channel outliers come from amplified
/// norm gains; spike outliers from amplified SwiGLU gate rows.
#[derive(Clone, Debug)]
pub struct OutlierProfile {
    pub name: String,
    pub n_channel: usize,
    pub channel_gain: f32,
    pub n_spike_rows: usize,
    pub spike_gain: f32,
}

impl OutlierProfile {
    pub fn base() -> OutlierProfile {
        OutlierProfile {
            name: "base".into(),
            n_channel: 0,
            channel_gain: 1.0,
            n_spike_rows: 0,
            spike_gain: 1.0,
        }
    }

    /// The paper-column stand-ins (kept in sync with profiles.json).
    pub fn builtin(name: &str) -> Option<OutlierProfile> {
        let p = |nc, cg, ns, sg| OutlierProfile {
            name: name.into(),
            n_channel: nc,
            channel_gain: cg,
            n_spike_rows: ns,
            spike_gain: sg,
        };
        Some(match name {
            "base" => OutlierProfile::base(),
            "llama2-like" => p(4, 30.0, 1, 8.0),
            "llama3-like" => p(6, 80.0, 2, 25.0),
            "llama3-70b-like" => p(6, 80.0, 4, 120.0),
            "qwen-like" => p(12, 40.0, 1, 12.0),
            _ => return None,
        })
    }

    pub const NAMES: [&'static str; 5] = [
        "base",
        "llama2-like",
        "llama3-like",
        "llama3-70b-like",
        "qwen-like",
    ];

    /// Inject into a copy of the weights (deterministic in `seed`).
    ///
    /// **Function-preserving**: the fp32 model computes the *same*
    /// function after injection — outliers appear only in the activations
    /// that quantizers see:
    ///
    /// * channel outliers: norm gain channel x`g`, and the consuming
    ///   linears' input columns /`g` (exact compensation through the
    ///   linear);
    /// * spike outliers: `w_up` row x`s` and the `w_down` input column
    ///   /`s` — exactly linear through SwiGLU (`silu(gate) * (up*s)`),
    ///   so the down-projector input spikes on tokens where that gate
    ///   fires, the paper's Fig. 7 mechanism.
    ///
    /// This matches how real LLMs carry outliers: the fp model is fine,
    /// INT4 is not.
    pub fn inject(&self, w: &Weights, seed: u64) -> Weights {
        let mut out = w.clone();
        if self.n_channel == 0 && self.n_spike_rows == 0 {
            return out;
        }
        let mut rng = Pcg::new(seed);
        let dim = w.final_norm.len();
        let channels = rng.choose_distinct(dim, self.n_channel.min(dim));
        for layer in out.layers.iter_mut() {
            for &c in &channels {
                layer.attn_norm[c] *= self.channel_gain;
                layer.mlp_norm[c] *= self.channel_gain;
                // consumers of attn_norm output
                for wm in [&mut layer.wq, &mut layer.wk, &mut layer.wv] {
                    scale_col(wm, c, 1.0 / self.channel_gain);
                }
                // consumers of mlp_norm output
                for wm in [&mut layer.w_gate, &mut layer.w_up] {
                    scale_col(wm, c, 1.0 / self.channel_gain);
                }
            }
            if self.n_spike_rows > 0 {
                let rows = rng.choose_distinct(layer.w_up.rows, self.n_spike_rows);
                for &r in &rows {
                    for v in layer.w_up.row_mut(r) {
                        *v *= self.spike_gain;
                    }
                    scale_col(&mut layer.w_down, r, 1.0 / self.spike_gain);
                }
            }
        }
        out
    }
}

fn scale_col(m: &mut Mat, col: usize, factor: f32) {
    for r in 0..m.rows {
        m.data[r * m.cols + col] *= factor;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_shapes() {
        let cfg = ModelConfig::default();
        let w = Weights::random(&cfg, 1);
        assert_eq!(w.embed.rows, cfg.vocab);
        assert_eq!(w.layers.len(), cfg.n_layers);
        assert_eq!(w.layers[0].wk.rows, cfg.kv_dim());
        assert_eq!(w.layers[0].w_down.cols, cfg.ffn);
    }

    #[test]
    fn base_profile_is_identity() {
        let cfg = ModelConfig { n_layers: 1, ..Default::default() };
        let w = Weights::random(&cfg, 2);
        let inj = OutlierProfile::base().inject(&w, 17);
        assert_eq!(w.layers[0].attn_norm, inj.layers[0].attn_norm);
        assert_eq!(w.layers[0].w_gate, inj.layers[0].w_gate);
    }

    #[test]
    fn injection_scales_channels() {
        let cfg = ModelConfig { n_layers: 2, ..Default::default() };
        let w = Weights::random(&cfg, 3);
        let p = OutlierProfile::builtin("llama3-like").unwrap();
        let inj = p.inject(&w, 17);
        let boosted: usize = inj.layers[0]
            .attn_norm
            .iter()
            .zip(&w.layers[0].attn_norm)
            .filter(|(a, b)| (*a / *b - p.channel_gain).abs() < 1e-3)
            .count();
        assert_eq!(boosted, p.n_channel);
        // same channels in every layer (residual-stream consistency)
        let ch0: Vec<usize> = (0..cfg.dim)
            .filter(|&c| inj.layers[0].attn_norm[c] != w.layers[0].attn_norm[c])
            .collect();
        let ch1: Vec<usize> = (0..cfg.dim)
            .filter(|&c| inj.layers[1].attn_norm[c] != w.layers[1].attn_norm[c])
            .collect();
        assert_eq!(ch0, ch1);
    }

    #[test]
    fn injection_preserves_fp_function() {
        use crate::model::config::EngineConfig;
        use crate::model::engine::QuantModel;
        use crate::quant::{Method, Scheme};
        let cfg = ModelConfig { n_layers: 2, ..Default::default() };
        let w = Weights::random(&cfg, 11);
        let p = OutlierProfile::builtin("llama3-70b-like").unwrap();
        let wi = p.inject(&w, 17);
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        let m0 = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
        let m1 = QuantModel::prepare(&wi, &cfg, &ecfg, None, None).unwrap();
        let toks: Vec<u32> = (0..24).map(|i| (i * 31 + 5) % 256).collect();
        let a = m0.forward_full(&toks, None);
        let b = m1.forward_full(&toks, None);
        let worst = a.max_abs_diff(&b);
        assert!(worst < 1e-2, "fp function changed by injection: {worst}");
    }

    #[test]
    fn all_builtin_profiles_resolve() {
        for n in OutlierProfile::NAMES {
            assert!(OutlierProfile::builtin(n).is_some(), "{n}");
        }
        assert!(OutlierProfile::builtin("nope").is_none());
    }
}
