//! Pure-rust LLaMA-architecture inference engine with quantized linears.
//!
//! Mirrors python/compile/model.py exactly (RMSNorm -> GQA attention with
//! rotate-half RoPE -> SwiGLU MLP, weights `[out, in]`), with every linear
//! layer routed through [`crate::quant::qlinear::QLinear`] so all of the
//! paper's methods (RTN / SmoothQuant / GPTQ / RS / QuaRot / RRS /
//! SpinQuant) run natively on the serving path.  The KV cache is
//! optionally INT4 (sub-channel, nibble-packed) via [`crate::quant::kv`].
//!
//! Numerics are validated against the PJRT-executed JAX graphs through
//! the golden vectors (rust/tests/golden.rs).

pub mod config;
pub mod engine;
pub mod ops;
pub mod sampler;
pub mod tokenizer;
pub mod weights;

pub use config::{EngineConfig, ModelConfig};
pub use engine::{KvCache, QuantModel};
pub use weights::Weights;
