//! Model + engine configuration (parsed from artifacts/manifest.json).

use anyhow::{Context, Result};

use crate::quant::{Method, Scheme};
use crate::util::json::Json;

/// Transformer architecture hyper-parameters (mirror of the python
/// `ModelConfig`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            vocab: 256,
            dim: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            ffn: 256,
            max_seq: 256,
            rope_theta: 10_000.0,
        }
    }
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Parse the `model` object of artifacts/manifest.json.
    pub fn from_manifest(manifest: &Json) -> Result<ModelConfig> {
        let m = manifest.get("model").context("manifest missing 'model'")?;
        let grab = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest model.{k}"))
        };
        Ok(ModelConfig {
            vocab: grab("vocab")?,
            dim: grab("dim")?,
            n_layers: grab("n_layers")?,
            n_heads: grab("n_heads")?,
            n_kv_heads: grab("n_kv_heads")?,
            ffn: grab("ffn")?,
            max_seq: grab("max_seq")?,
            rope_theta: m
                .get("rope_theta")
                .and_then(Json::as_f64)
                .unwrap_or(10_000.0) as f32,
        })
    }
}

/// Quantization configuration of an engine instance — one cell of the
/// paper's (method x scheme) matrix.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub method: Method,
    pub scheme: Scheme,
    /// Runtime-Smooth group size (Table 4 ablation knob).
    pub group: usize,
    /// KV-cache quant group (paper: 128, clamped to head_dim).
    pub kv_group: usize,
    /// SmoothQuant alpha.
    pub alpha: f32,
    /// Use GPTQ (vs RTN) for INT4 weights.
    pub gptq: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            method: Method::Rrs,
            scheme: Scheme::A4W4KV4,
            group: 128,
            kv_group: 128,
            alpha: 0.5,
            gptq: true,
        }
    }
}

impl EngineConfig {
    pub fn label(&self) -> String {
        format!("{}-{}", self.method.name(), self.scheme.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_manifest_parses() {
        let j = Json::parse(
            r#"{"model":{"vocab":256,"dim":128,"n_layers":4,"n_heads":4,
                 "n_kv_heads":2,"ffn":256,"max_seq":256,"rope_theta":10000.0}}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(c, ModelConfig::default());
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.kv_dim(), 64);
    }

    #[test]
    fn missing_field_errors() {
        let j = Json::parse(r#"{"model":{"vocab":256}}"#).unwrap();
        assert!(ModelConfig::from_manifest(&j).is_err());
    }
}
