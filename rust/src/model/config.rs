//! Model + engine configuration (parsed from artifacts/manifest.json).

use anyhow::{Context, Result};

use crate::quant::{Method, QuantRecipe, Scheme};
use crate::util::json::Json;

/// Transformer architecture hyper-parameters (mirror of the python
/// `ModelConfig`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            vocab: 256,
            dim: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            ffn: 256,
            max_seq: 256,
            rope_theta: 10_000.0,
        }
    }
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Parse the `model` object of artifacts/manifest.json.
    pub fn from_manifest(manifest: &Json) -> Result<ModelConfig> {
        let m = manifest.get("model").context("manifest missing 'model'")?;
        let grab = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest model.{k}"))
        };
        Ok(ModelConfig {
            vocab: grab("vocab")?,
            dim: grab("dim")?,
            n_layers: grab("n_layers")?,
            n_heads: grab("n_heads")?,
            n_kv_heads: grab("n_kv_heads")?,
            ffn: grab("ffn")?,
            max_seq: grab("max_seq")?,
            rope_theta: m
                .get("rope_theta")
                .and_then(Json::as_f64)
                .unwrap_or(10_000.0) as f32,
        })
    }
}

/// Quantization configuration of an engine instance — one cell of the
/// paper's (method x scheme) matrix.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub method: Method,
    pub scheme: Scheme,
    /// Runtime-Smooth group size (Table 4 ablation knob).
    pub group: usize,
    /// KV-cache quant group (paper: 128, clamped to head_dim).
    pub kv_group: usize,
    /// SmoothQuant alpha.
    pub alpha: f32,
    /// Use GPTQ (vs RTN) for INT4 weights.
    pub gptq: bool,
    /// Explicit composed strategy override (`--recipe` / `RRS_RECIPE`);
    /// `None` derives the recipe from the legacy method/scheme knobs, so
    /// every historical config keeps its exact behavior.
    pub recipe: Option<QuantRecipe>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            method: Method::Rrs,
            scheme: Scheme::A4W4KV4,
            group: 128,
            kv_group: 128,
            alpha: 0.5,
            gptq: true,
            recipe: None,
        }
    }
}

impl EngineConfig {
    /// Config driven entirely by a composed [`QuantRecipe`]; the legacy
    /// knobs are back-filled from the recipe for display and for code
    /// that still reads them.
    pub fn from_recipe(recipe: QuantRecipe) -> EngineConfig {
        EngineConfig {
            method: recipe.method(),
            scheme: recipe.scheme(),
            group: recipe.group,
            kv_group: recipe.kv_group,
            alpha: recipe.alpha,
            gptq: recipe.gptq,
            recipe: Some(recipe),
        }
    }

    /// The recipe this engine runs: the explicit override when present,
    /// otherwise the one the legacy method/scheme knobs map to
    /// (bit-identical routes either way).
    pub fn resolved(&self) -> QuantRecipe {
        self.recipe.unwrap_or_else(|| {
            QuantRecipe::from_method(
                self.method,
                self.scheme,
                self.group,
                self.kv_group,
                self.alpha,
                self.gptq,
            )
        })
    }

    pub fn label(&self) -> String {
        match &self.recipe {
            Some(r) => r.label(),
            None => format!("{}-{}", self.method.name(), self.scheme.label()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_manifest_parses() {
        let j = Json::parse(
            r#"{"model":{"vocab":256,"dim":128,"n_layers":4,"n_heads":4,
                 "n_kv_heads":2,"ffn":256,"max_seq":256,"rope_theta":10000.0}}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(c, ModelConfig::default());
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.kv_dim(), 64);
    }

    #[test]
    fn missing_field_errors() {
        let j = Json::parse(r#"{"model":{"vocab":256}}"#).unwrap();
        assert!(ModelConfig::from_manifest(&j).is_err());
    }

    #[test]
    fn recipe_resolution_round_trips() {
        let e = EngineConfig::default();
        let r = e.resolved();
        assert_eq!(r.method(), Method::Rrs);
        // legacy configs keep the historical label format
        assert_eq!(e.label(), "RRS-A4W4KV4");
        let e2 = EngineConfig::from_recipe(r);
        assert_eq!(e2.resolved(), r);
        assert_eq!(e2.label(), r.label());
    }
}
