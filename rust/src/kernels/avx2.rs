//! The AVX2 backend: explicit `std::arch` intrinsics for the packed
//! INT4 GEMM, the RRS prologue reductions, and the FWHT butterflies.
//!
//! The GEMM microkernel consumes nibble-packed weight rows directly:
//! each 16-byte chunk is masked into its low/high nibbles, sign-extended
//! with the `(n ^ 8) - 8` trick, widened to i16 and multiply-accumulated
//! with `pmaddwd` against the activation row split into even/odd lanes
//! (one deinterleave per row block, amortized over every output
//! channel).  All integer accumulation is exact and the f32 epilogue
//! follows the fixed order of the [`super::KernelBackend`] contract, so
//! this backend is bit-identical to the scalar reference — asserted by
//! `rust/tests/kernel_diff.rs`.
//!
//! Only compiled on x86-64; [`super::registry`] selects it when
//! `is_x86_feature_detected!("avx2")` holds (or `RRS_KERNEL=avx2`).

#[cfg(target_arch = "x86_64")]
mod imp {
    use std::arch::x86_64::*;

    use crate::quant::pack4::PackedI4;

    use super::super::{scalar, KernelBackend, TileConfig};

    /// See the module docs.
    pub struct Avx2Backend;

    impl KernelBackend for Avx2Backend {
        fn name(&self) -> &'static str {
            "avx2"
        }

        fn igemm_block(
            &self,
            a: &[i8],
            n: usize,
            k: usize,
            b: &PackedI4,
            j0: usize,
            j1: usize,
            tiles: TileConfig,
            acc: &mut [i32],
        ) {
            // SAFETY: this backend is only registered after runtime AVX2
            // detection (see kernels::select_backend), satisfying the
            // target-feature contract of every callee below.
            unsafe { igemm_block_avx2(a, n, k, b, j0, j1, tiles, acc) }
        }

        #[allow(clippy::too_many_arguments)]
        fn gemm_scaled_block(
            &self,
            a: &[i8],
            n: usize,
            k: usize,
            group: usize,
            sg: &[f32],
            sx: &[f32],
            b: &PackedI4,
            sw: &[f32],
            j0: usize,
            j1: usize,
            tiles: TileConfig,
            out: &mut [f32],
        ) {
            // SAFETY: AVX2 presence checked at backend registration.
            unsafe { gemm_scaled_block_avx2(a, n, k, group, sg, sx, b, sw, j0, j1, tiles, out) }
        }

        fn colmax_abs(&self, x: &[f32], rows: usize, k: usize, s: &mut [f32]) {
            // SAFETY: AVX2 presence checked at backend registration.
            unsafe { colmax_abs_avx2(x, rows, k, s) }
        }

        fn smooth_row(
            &self,
            row: &[f32],
            perm: &[usize],
            group: usize,
            sg: &[f32],
            out: &mut [f32],
        ) -> f32 {
            // SAFETY: AVX2 presence checked at backend registration.
            unsafe { smooth_row_avx2(row, perm, group, sg, out) }
        }

        fn fwht(&self, x: &mut [f32]) {
            // SAFETY: AVX2 presence checked at backend registration.
            unsafe { fwht_avx2(x) }
        }

        fn dot_f32(&self, a: &[f32], b: &[f32]) -> f32 {
            // SAFETY: AVX2 presence checked at backend registration.
            unsafe { dot4_sse(a, b) }
        }
    }

    /// Split `rows` activation rows starting at `ib` into even/odd
    /// element planes (`ae[t] = a[2t]`, `ao[t] = a[2t+1]`), zero-padding
    /// to `stride` so the SIMD loop can read whole chunks.
    fn deinterleave(
        a: &[i8],
        k: usize,
        ib: usize,
        rows: usize,
        stride: usize,
        ae: &mut [i8],
        ao: &mut [i8],
    ) {
        let half = k / 2;
        let used = k.div_ceil(2);
        for r in 0..rows {
            let arow = &a[(ib + r) * k..(ib + r + 1) * k];
            let e = &mut ae[r * stride..(r + 1) * stride];
            let o = &mut ao[r * stride..(r + 1) * stride];
            for t in 0..half {
                e[t] = arow[2 * t];
                o[t] = arow[2 * t + 1];
            }
            if k % 2 == 1 {
                e[half] = arow[k - 1];
                o[half] = 0;
            }
            // the scratch is reused across row blocks: re-zero the tail
            for v in e[used..].iter_mut() {
                *v = 0;
            }
            for v in o[used..].iter_mut() {
                *v = 0;
            }
        }
    }

    /// Exact i32 dot over one packed byte range (`bp.len() % 16 == 0`):
    /// nibble mask + sign-extend + widen + `pmaddwd` per 16-byte chunk.
    // SAFETY: unsafe only for the target-feature contract — the caller
    // must have verified AVX2; all loads stay inside the slices (the
    // debug_asserts state the length preconditions the callers uphold).
    #[target_feature(enable = "avx2")]
    unsafe fn dot_chunks(ae: &[i8], ao: &[i8], bp: &[u8]) -> i32 {
        debug_assert_eq!(bp.len() % 16, 0);
        debug_assert!(ae.len() >= bp.len() && ao.len() >= bp.len());
        let mask = _mm_set1_epi8(0x0f);
        let eight = _mm_set1_epi8(8);
        let mut acc = _mm256_setzero_si256();
        let mut t = 0;
        while t < bp.len() {
            let bv = _mm_loadu_si128(bp.as_ptr().add(t) as *const __m128i);
            let lo = _mm_and_si128(bv, mask);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(bv), mask);
            // sign-extend 4-bit two's complement: (n ^ 8) - 8
            let lo = _mm_sub_epi8(_mm_xor_si128(lo, eight), eight);
            let hi = _mm_sub_epi8(_mm_xor_si128(hi, eight), eight);
            let ae16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                ae.as_ptr().add(t) as *const __m128i,
            ));
            let ao16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                ao.as_ptr().add(t) as *const __m128i,
            ));
            let lo16 = _mm256_cvtepi8_epi16(lo);
            let hi16 = _mm256_cvtepi8_epi16(hi);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(lo16, ae16));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(hi16, ao16));
            t += 16;
        }
        hsum_epi32(acc)
    }

    // SAFETY: unsafe only for the target-feature contract (register-only
    // lane shuffles, no memory access).
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b0100_1110>(s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b1011_0001>(s));
        _mm_cvtsi128_si32(s)
    }

    // SAFETY: unsafe only for the target-feature contract; every access
    // is through checked slice ops, and the `dot_chunks` ranges end at
    // `stride`, the deinterleave scratch row length.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn igemm_block_avx2(
        a: &[i8],
        n: usize,
        k: usize,
        b: &PackedI4,
        j0: usize,
        j1: usize,
        tiles: TileConfig,
        acc: &mut [i32],
    ) {
        let w = j1 - j0;
        let stride = b.stride;
        let mr = tiles.mr.max(1);
        let nr = tiles.nr.max(1);
        let kc_bytes = (tiles.kc.max(32) / 2).next_multiple_of(16).min(stride);
        let mut ae = vec![0i8; mr * stride];
        let mut ao = vec![0i8; mr * stride];
        for ib in (0..n).step_by(mr) {
            let ih = (ib + mr).min(n);
            let rows = ih - ib;
            deinterleave(a, k, ib, rows, stride, &mut ae, &mut ao);
            for jt in (j0..j1).step_by(nr) {
                let jh = (jt + nr).min(j1);
                let mut kb = 0;
                while kb < stride {
                    let ke = (kb + kc_bytes).min(stride);
                    for j in jt..jh {
                        let brow = b.row(j);
                        for r in 0..rows {
                            let d = dot_chunks(
                                &ae[r * stride + kb..r * stride + ke],
                                &ao[r * stride + kb..r * stride + ke],
                                &brow[kb..ke],
                            );
                            acc[(ib + r) * w + (j - j0)] += d;
                        }
                    }
                    kb = ke;
                }
            }
        }
    }

    // SAFETY: unsafe only for the target-feature contract; all accesses
    // are checked slice ops over the same ranges the scalar reference
    // uses.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn gemm_scaled_block_avx2(
        a: &[i8],
        n: usize,
        k: usize,
        group: usize,
        sg: &[f32],
        sx: &[f32],
        b: &PackedI4,
        sw: &[f32],
        j0: usize,
        j1: usize,
        tiles: TileConfig,
        out: &mut [f32],
    ) {
        let w = j1 - j0;
        let stride = b.stride;
        let ng = sg.len();
        let mr = tiles.mr.max(1);
        let nr = tiles.nr.max(1);
        // group spans whole 16-byte packed chunks => per-group SIMD dots
        let chunky = group % 32 == 0;
        let mut ae = vec![0i8; mr * stride];
        let mut ao = vec![0i8; mr * stride];
        for ib in (0..n).step_by(mr) {
            let ih = (ib + mr).min(n);
            let rows = ih - ib;
            deinterleave(a, k, ib, rows, stride, &mut ae, &mut ao);
            for jt in (j0..j1).step_by(nr) {
                let jh = (jt + nr).min(j1);
                for j in jt..jh {
                    let brow = b.row(j);
                    let swj = sw[j];
                    for r in 0..rows {
                        let i = ib + r;
                        let fsum = if ng == 1 {
                            // single group: whole-row i32 dot (padding
                            // nibbles are zero), one scale at the end
                            let d = dot_chunks(
                                &ae[r * stride..(r + 1) * stride],
                                &ao[r * stride..(r + 1) * stride],
                                brow,
                            );
                            d as f32 * sg[0]
                        } else if chunky {
                            let gb = group / 2; // bytes per group, %16==0
                            let mut fs = 0.0f32;
                            for (g, &sgv) in sg.iter().enumerate() {
                                let lo = g * gb;
                                let d = dot_chunks(
                                    &ae[r * stride + lo..r * stride + lo + gb],
                                    &ao[r * stride + lo..r * stride + lo + gb],
                                    &brow[lo..lo + gb],
                                );
                                fs += d as f32 * sgv;
                            }
                            fs
                        } else {
                            // small/odd groups: the reference nibble loop
                            // (the integer dot is exact either way)
                            let arow = &a[i * k..(i + 1) * k];
                            let mut fs = 0.0f32;
                            for (g, &sgv) in sg.iter().enumerate() {
                                let lo = g * group;
                                let d = scalar::dot_seg(arow, brow, lo, lo + group);
                                fs += d as f32 * sgv;
                            }
                            fs
                        };
                        out[i * w + (j - j0)] = fsum * sx[i] * swj;
                    }
                }
            }
        }
    }

    // SAFETY: unsafe only for the target-feature contract; the vector
    // loop reads/writes `[j, j+8)` only while `j + 8 <= k`, within the
    // row and `s` slices (callers pass `s.len() == k`).
    #[target_feature(enable = "avx2")]
    unsafe fn colmax_abs_avx2(x: &[f32], rows: usize, k: usize, s: &mut [f32]) {
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        for i in 0..rows {
            let row = &x[i * k..(i + 1) * k];
            let mut j = 0;
            while j + 8 <= k {
                let v = _mm256_and_ps(_mm256_loadu_ps(row.as_ptr().add(j)), absmask);
                let cur = _mm256_loadu_ps(s.as_ptr().add(j));
                _mm256_storeu_ps(s.as_mut_ptr().add(j), _mm256_max_ps(cur, v));
                j += 8;
            }
            while j < k {
                s[j] = s[j].max(row[j].abs());
                j += 1;
            }
        }
    }

    // SAFETY: unsafe only for the target-feature contract; the vector
    // loop touches `[j, j+8)` only while `j + 8 <= hi <= k == perm.len()
    // <= out.len()` (the prologue writes `out[..k]`).
    #[target_feature(enable = "avx2")]
    unsafe fn smooth_row_avx2(
        row: &[f32],
        perm: &[usize],
        group: usize,
        sg: &[f32],
        out: &mut [f32],
    ) -> f32 {
        let k = perm.len();
        // gather by the runtime permutation (random access stays scalar)
        for (o, &p) in out[..k].iter_mut().zip(perm) {
            *o = row[p];
        }
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let mut vmax = _mm256_setzero_ps();
        let mut smax = 0.0f32;
        for (g, &sgv) in sg.iter().enumerate() {
            let lo = g * group;
            let hi = (lo + group).min(k);
            let d = _mm256_set1_ps(sgv);
            let mut j = lo;
            while j + 8 <= hi {
                let q = _mm256_div_ps(_mm256_loadu_ps(out.as_ptr().add(j)), d);
                _mm256_storeu_ps(out.as_mut_ptr().add(j), q);
                vmax = _mm256_max_ps(vmax, _mm256_and_ps(q, absmask));
                j += 8;
            }
            while j < hi {
                out[j] /= sgv;
                smax = smax.max(out[j].abs());
                j += 1;
            }
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vmax);
        for l in lanes {
            smax = smax.max(l); // f32 max is exact: any reduce order works
        }
        smax
    }

    // SAFETY: unsafe only for the target-feature contract; butterfly
    // loads/stores at `i` and `i + h` stay below `base + step <= k`
    // (power-of-two length asserted on entry).
    #[target_feature(enable = "avx2")]
    unsafe fn fwht_avx2(x: &mut [f32]) {
        let k = x.len();
        debug_assert!(k.is_power_of_two());
        if k < 16 {
            crate::linalg::fwht::fwht_inplace_scalar(x);
            return;
        }
        let norm = 1.0 / (k as f32).sqrt();
        let nv = _mm256_set1_ps(norm);
        let mut h = 1;
        while h < k {
            let step = h * 2;
            // fuse the normalization into the final stage: (a±b)*norm is
            // the same value the staged butterfly+scale pair produces
            let last = step == k;
            let mut base = 0;
            while base < k {
                if h >= 8 {
                    let mut i = base;
                    while i < base + h {
                        let a = _mm256_loadu_ps(x.as_ptr().add(i));
                        let b = _mm256_loadu_ps(x.as_ptr().add(i + h));
                        let mut s = _mm256_add_ps(a, b);
                        let mut d = _mm256_sub_ps(a, b);
                        if last {
                            s = _mm256_mul_ps(s, nv);
                            d = _mm256_mul_ps(d, nv);
                        }
                        _mm256_storeu_ps(x.as_mut_ptr().add(i), s);
                        _mm256_storeu_ps(x.as_mut_ptr().add(i + h), d);
                        i += 8;
                    }
                } else {
                    for i in base..base + h {
                        let a = x[i];
                        let b = x[i + h];
                        x[i] = a + b;
                        x[i + h] = a - b;
                    }
                }
                base += step;
            }
            h = step;
        }
        // k >= 16: the final stage (h = k/2 >= 8) ran vectorized with the
        // normalization fused, so there is nothing left to scale
    }

    /// f32 dot with the exact 4-lane pattern of
    /// [`crate::linalg::gemm::dot`]: lane `l` accumulates elements
    /// `4c + l`, lanes reduce left-to-right — bit-identical to scalar.
    // SAFETY: unsafe only for the target-feature contract; 4-lane loads
    // stop at `chunks * 4 <= a.len() == b.len()`, the tail is scalar.
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_sse(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 4;
        let mut accv = _mm_setzero_ps();
        for c in 0..chunks {
            let i = c * 4;
            let av = _mm_loadu_ps(a.as_ptr().add(i));
            let bv = _mm_loadu_ps(b.as_ptr().add(i));
            accv = _mm_add_ps(accv, _mm_mul_ps(av, bv));
        }
        let mut lanes = [0.0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), accv);
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for i in chunks * 4..a.len() {
            s += a[i] * b[i];
        }
        s
    }
}

#[cfg(target_arch = "x86_64")]
pub use imp::Avx2Backend;
