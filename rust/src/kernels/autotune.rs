//! One-shot startup autotuner: pick the (MR, NR, KC) tile shape for the
//! dispatched GEMM microkernel by timing a decode-shaped workload.
//!
//! Decode is the shape that matters — a handful of token rows against
//! thousands of packed weight channels — so the probe GEMM is small-`n`,
//! wide-`m`.  The whole sweep budgets a few milliseconds (it runs once
//! per process, warmed by `QuantModel::prepare`); the chosen shape and
//! the time spent are recorded in the registry and exported through the
//! metrics `stats` snapshot.  `RRS_TILE=MRxNRxKC` overrides the sweep,
//! `RRS_AUTOTUNE=0` (or the scalar backend) skips it.

use std::time::Instant;

use crate::linalg::igemm::MatI8;
use crate::quant::pack4::PackedI4;
use crate::util::rng::Pcg;

use super::{igemm_packed_with, KernelBackend, TileConfig};

/// Decode-shaped probe: token rows × K × output channels.
const PROBE_N: usize = 8;
const PROBE_K: usize = 512;
const PROBE_M: usize = 128;
/// Timed repetitions per candidate (best-of, after one warmup).
const REPS: usize = 2;

fn probe_operands() -> (MatI8, PackedI4) {
    let mut rng = Pcg::new(0xA070);
    let a = MatI8::from_vec(
        PROBE_N,
        PROBE_K,
        (0..PROBE_N * PROBE_K).map(|_| rng.below(15) as i8 - 7).collect(),
    );
    let b = MatI8::from_vec(
        PROBE_M,
        PROBE_K,
        (0..PROBE_M * PROBE_K).map(|_| rng.below(15) as i8 - 7).collect(),
    );
    (a, PackedI4::pack(&b))
}

/// Sweep the candidate grid on `backend`; returns the fastest tile shape
/// and the total microseconds spent tuning.
pub fn autotune(backend: &dyn KernelBackend) -> (TileConfig, u64) {
    let t0 = Instant::now();
    let (a, bp) = probe_operands();
    let mut best = TileConfig::DEFAULT;
    let mut best_ns = u128::MAX;
    for &nr in &[16usize, 32, 64] {
        for &kc in &[128usize, 256, 512] {
            let cand = TileConfig { mr: 8, nr, kc };
            // warmup pass (page in scratch, settle the branch predictor)
            let _ = igemm_packed_with(backend, cand, &a, &bp);
            let mut cand_ns = u128::MAX;
            for _ in 0..REPS {
                let s = Instant::now();
                let out = igemm_packed_with(backend, cand, &a, &bp);
                let dt = s.elapsed().as_nanos();
                std::hint::black_box(out);
                cand_ns = cand_ns.min(dt);
            }
            if cand_ns < best_ns {
                best_ns = cand_ns;
                best = cand;
            }
        }
    }
    (best, t0.elapsed().as_micros() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autotune_returns_a_candidate() {
        // sweep the portable backend explicitly (cheap and always built)
        let (tiles, us) = autotune(&super::super::portable::PortableBackend);
        assert!(tiles.mr > 0 && tiles.nr > 0 && tiles.kc > 0);
        assert!([16, 32, 64].contains(&tiles.nr));
        assert!(us > 0);
    }
}
