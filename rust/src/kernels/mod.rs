//! Runtime-dispatched SIMD microkernel layer for the fused
//! RRS → INT4 GEMM hot path.
//!
//! The paper's pipeline — smooth → quantize → nibble-unpack → igemm →
//! group-scale epilogue — *is* the serving hot loop (Runtime Smooth
//! happens per batch, at inference time), so this module gives it a real
//! kernel layer instead of the naive scalar loops it grew up with:
//!
//! * [`KernelBackend`] — the microkernel contract: a cache-blocked
//!   INT4×INT4→i32 GEMM that consumes [`PackedI4`] nibble-packed weights
//!   **directly** (no unpack-to-i8 materialization), the fused
//!   channel-max + smooth + per-token-quantize activation prologue, the
//!   FWHT rotation butterflies, and the f32 attention dot.
//! * Three backends: `scalar` (the pre-existing reference loops, kept
//!   bit-for-bit), `portable` (blocked safe-Rust loops shaped for the
//!   autovectorizer), and `avx2` (explicit `std::arch` intrinsics, built
//!   on x86-64 and selected via `is_x86_feature_detected!`).
//! * A process-wide [`Registry`] selecting the backend once at startup
//!   (override with `RRS_KERNEL=scalar|portable|avx2`), running the
//!   one-shot tile-size [`autotune`](autotune::autotune) (override with
//!   `RRS_TILE=MRxNRxKC`, disable with `RRS_AUTOTUNE=0`), and exposing
//!   call/row counters that [`crate::coordinator::Metrics`] publishes in
//!   the TCP `stats` snapshot.
//!
//! # The bit-identity contract
//!
//! Every backend must produce **bit-identical** results for the INT4
//! paths: i32 accumulators are exact integer sums (associativity is
//! free), and the fused epilogue applies its f32 scales in one fixed
//! order — per output element, group partials ascending, then
//! `(Σ_g sg[g]·dot_g) * sx[i] * sw[j]` — so scalar, portable and avx2
//! agree to the last bit with the staged reference path
//! ([`crate::quant::qlinear::forward_rs_fused_prepermuted`] over
//! [`crate::quant::runtime_smooth::prepare_staged`]).  The differential
//! suite (`rust/tests/kernel_diff.rs`) locks this in for every compiled
//! backend; CI re-runs it with `RRS_KERNEL=scalar` forced so the
//! reference stays exercised on AVX2 runners.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::linalg::gemm::Mat;
use crate::linalg::igemm::MatI8;
use crate::quant::pack4::PackedI4;
use crate::quant::runtime_smooth::{self, SmoothedAct};
use crate::quant::rtn;
use crate::util::threadpool;

pub mod autotune;
pub mod avx2;
pub mod portable;
pub mod scalar;

/// Cache-blocking tile sizes, in elements of the unpacked K dimension.
///
/// `mr` = activation rows per inner block, `nr` = output channels per
/// tile, `kc` = K-block depth.  Chosen once at startup by the autotuner
/// (or `RRS_TILE`); backends are free to clamp them to their lane
/// widths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    pub mr: usize,
    pub nr: usize,
    pub kc: usize,
}

impl TileConfig {
    /// Safe default when autotuning is disabled or not worthwhile.
    pub const DEFAULT: TileConfig = TileConfig { mr: 8, nr: 32, kc: 256 };

    /// `"MRxNRxKC"` — the form `RRS_TILE` accepts and metrics export.
    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.mr, self.nr, self.kc)
    }

    fn parse(s: &str) -> Option<TileConfig> {
        let mut it = s.split('x');
        let mr = it.next()?.trim().parse().ok()?;
        let nr = it.next()?.trim().parse().ok()?;
        let kc = it.next()?.trim().parse().ok()?;
        if it.next().is_some() || mr == 0 || nr == 0 || kc == 0 {
            return None;
        }
        Some(TileConfig { mr, nr, kc })
    }
}

/// The microkernel contract one CPU backend implements.
///
/// All slices are row-major; `acc`/`out` tiles are `[n, j1-j0]`.  See
/// the module docs for the cross-backend bit-identity contract.
pub trait KernelBackend: Send + Sync {
    /// Backend name as reported by metrics (`"scalar"`, `"avx2"`, ...).
    fn name(&self) -> &'static str;

    /// Accumulate `acc[i][j-j0] += Σ_t a[i·k+t] · unpack(b)[j][t]` for
    /// `j ∈ [j0, j1)`, consuming the packed weight rows directly.
    /// `acc` arrives zeroed from the driver; integer sums are exact, so
    /// blocking order is unconstrained.
    #[allow(clippy::too_many_arguments)]
    fn igemm_block(
        &self,
        a: &[i8],
        n: usize,
        k: usize,
        b: &PackedI4,
        j0: usize,
        j1: usize,
        tiles: TileConfig,
        acc: &mut [i32],
    );

    /// Fused scaled GEMM tile:
    /// `out[i][j-j0] = (Σ_g sg[g] · dot_g(i, j)) · sx[i] · sw[j]` with
    /// the group sum taken ascending in `g` (the staged-epilogue order).
    /// `group · sg.len() == k`; `sg == [1.0]` with `group == k` is the
    /// per-channel (non-grouped) epilogue.
    #[allow(clippy::too_many_arguments)]
    fn gemm_scaled_block(
        &self,
        a: &[i8],
        n: usize,
        k: usize,
        group: usize,
        sg: &[f32],
        sx: &[f32],
        b: &PackedI4,
        sw: &[f32],
        j0: usize,
        j1: usize,
        tiles: TileConfig,
        out: &mut [f32],
    );

    /// Column-wise absolute maxima: `s[j] = max(s[j], |x[i·k + j]|)`
    /// over all `rows` rows (the Runtime-Smooth channel-max reduction;
    /// f32 max is exact, so vectorization order is free).
    fn colmax_abs(&self, x: &[f32], rows: usize, k: usize, s: &mut [f32]);

    /// Fused gather + smooth + absmax over one activation row:
    /// `out[j] = row[perm[j]] / sg[j / group]`; returns `max_j |out[j]|`.
    fn smooth_row(
        &self,
        row: &[f32],
        perm: &[usize],
        group: usize,
        sg: &[f32],
        out: &mut [f32],
    ) -> f32;

    /// Normalized FWHT in place (`x.len()` a power of two) — the
    /// rotation butterfly kernel.  Must match the scalar reference
    /// ([`crate::linalg::fwht::fwht_inplace_scalar`]) bit-for-bit.
    fn fwht(&self, x: &mut [f32]);

    /// f32 dot with the exact 4-lane accumulation pattern of
    /// [`crate::linalg::gemm::dot`] — bit-identical across backends (the
    /// attention score path stays deterministic under `RRS_KERNEL`).
    fn dot_f32(&self, a: &[f32], b: &[f32]) -> f32;
}

// ───────────────────────────── registry ─────────────────────────────

/// The process-wide kernel selection: one backend + one tile config,
/// resolved once on first use.
pub struct Registry {
    pub backend: &'static dyn KernelBackend,
    pub tiles: TileConfig,
    /// `true` when `tiles` came from the startup autotuner (as opposed
    /// to `RRS_TILE` or the static default).
    pub autotuned: bool,
    /// Wall time the autotuner spent, in microseconds (0 if skipped).
    pub autotune_us: u64,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

static SCALAR: scalar::ScalarBackend = scalar::ScalarBackend;
static PORTABLE: portable::PortableBackend = portable::PortableBackend;
#[cfg(target_arch = "x86_64")]
static AVX2: avx2::Avx2Backend = avx2::Avx2Backend;

fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_backend() -> Option<&'static dyn KernelBackend> {
    if avx2_available() {
        Some(&AVX2)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_backend() -> Option<&'static dyn KernelBackend> {
    None
}

fn select_backend() -> &'static dyn KernelBackend {
    match std::env::var("RRS_KERNEL").ok().as_deref() {
        Some("scalar") => &SCALAR,
        Some("portable") => &PORTABLE,
        Some("avx2") => avx2_backend().unwrap_or_else(|| {
            eprintln!("RRS_KERNEL=avx2 requested but AVX2 is unavailable; \
                       falling back to portable");
            &PORTABLE
        }),
        Some("") | Some("auto") | None => avx2_backend().unwrap_or(&PORTABLE),
        Some(other) => {
            eprintln!("unknown RRS_KERNEL={other:?}; using auto selection");
            avx2_backend().unwrap_or(&PORTABLE)
        }
    }
}

/// The process-wide kernel registry (backend select + autotune happen on
/// the first call; [`crate::model::engine::QuantModel::prepare`] warms it
/// so serving never pays the one-shot cost mid-request).
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| {
        let backend = select_backend();
        let env_tile =
            std::env::var("RRS_TILE").ok().and_then(|s| TileConfig::parse(&s));
        if let Some(t) = env_tile {
            return Registry { backend, tiles: t, autotuned: false, autotune_us: 0 };
        }
        let skip = std::env::var("RRS_AUTOTUNE").ok().as_deref() == Some("0")
            || backend.name() == "scalar";
        if skip {
            return Registry {
                backend,
                tiles: TileConfig::DEFAULT,
                autotuned: false,
                autotune_us: 0,
            };
        }
        let (tiles, us) = autotune::autotune(backend);
        Registry { backend, tiles, autotuned: true, autotune_us: us }
    })
}

/// Every backend compiled into this binary *and usable on this CPU* —
/// the set the differential tests sweep.
pub fn all_backends() -> Vec<&'static dyn KernelBackend> {
    let mut v: Vec<&'static dyn KernelBackend> = vec![&SCALAR, &PORTABLE];
    if let Some(b) = avx2_backend() {
        v.push(b);
    }
    v
}

// ───────────────────────────── counters ─────────────────────────────

static FUSED_GEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static FUSED_GEMM_ROWS: AtomicU64 = AtomicU64::new(0);
static PER_CHANNEL_CALLS: AtomicU64 = AtomicU64::new(0);
static W4A8_CALLS: AtomicU64 = AtomicU64::new(0);
static IGEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static PROLOGUE_ROWS: AtomicU64 = AtomicU64::new(0);
static FWHT_ROWS: AtomicU64 = AtomicU64::new(0);

/// A point-in-time snapshot of the kernel layer: which backend is live,
/// the autotuned tile shape, and cumulative dispatch counters.
#[derive(Clone, Debug)]
pub struct KernelStats {
    pub backend: &'static str,
    pub tiles: TileConfig,
    pub autotuned: bool,
    pub autotune_us: u64,
    /// Fused (grouped-epilogue) GEMM dispatches / activation rows.
    pub fused_gemm_calls: u64,
    pub fused_gemm_rows: u64,
    /// Per-channel-epilogue GEMM dispatches.
    pub per_channel_calls: u64,
    /// W4A8 (INT8 activation × packed INT4 weight) GEMM dispatches.
    pub w4a8_calls: u64,
    /// Raw packed-igemm dispatches (i32 accumulator output).
    pub igemm_calls: u64,
    /// Activation rows through the fused RRS prologue.
    pub prologue_rows: u64,
    /// Rows rotated by the FWHT kernel.
    pub fwht_rows: u64,
}

/// Snapshot the registry + counters (forces registry init, autotune
/// included — use [`stats_peek`] on paths that must not pay for it).
pub fn stats() -> KernelStats {
    snapshot(registry())
}

/// Snapshot without forcing initialization: `None` until the first
/// kernel dispatch (or [`registry`] call) resolves the backend.  This is
/// what the metrics endpoint reads, so a `stats` poll on a server that
/// never touched the interpreted hot path (e.g. a pure PJRT deployment)
/// does not run the autotune sweep inside a monitoring request.
pub fn stats_peek() -> Option<KernelStats> {
    REGISTRY.get().map(snapshot)
}

fn snapshot(r: &Registry) -> KernelStats {
    // ORDERING: each cell is an independent monotonic call/row counter
    // bumped by fetch_add; a stats poll tolerates a torn view across
    // cells, so Relaxed loads.
    KernelStats {
        backend: r.backend.name(),
        tiles: r.tiles,
        autotuned: r.autotuned,
        autotune_us: r.autotune_us,
        fused_gemm_calls: FUSED_GEMM_CALLS.load(Ordering::Relaxed),
        fused_gemm_rows: FUSED_GEMM_ROWS.load(Ordering::Relaxed),
        per_channel_calls: PER_CHANNEL_CALLS.load(Ordering::Relaxed),
        w4a8_calls: W4A8_CALLS.load(Ordering::Relaxed),
        igemm_calls: IGEMM_CALLS.load(Ordering::Relaxed),
        prologue_rows: PROLOGUE_ROWS.load(Ordering::Relaxed),
        fwht_rows: FWHT_ROWS.load(Ordering::Relaxed),
    }
}

// ─────────────────────── threaded tile drivers ───────────────────────

/// Raw output pointer smuggled across the scoped-thread boundary; every
/// task writes a disjoint column range `[j0, j1)` of the `[n, m]`
/// buffer, so the pointer writes never alias.
struct SendPtr<T>(*mut T);
// SAFETY: the pointer is only dereferenced inside `parallel_col_blocks`,
// where every scoped task writes the disjoint column range `[j0, j1)` it
// was handed — no two tasks touch the same element, and the scope joins
// before `out` is used again.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Split the `m` output columns into per-thread blocks (aligned to the
/// tile width) and run `body(j0, j1, tile)` for each; `tile` is a zeroed
/// `[n, j1-j0]` scratch the body fills, copied into `out` afterwards.
///
/// Threading over *columns* (not rows, as the legacy GEMMs did) is what
/// makes batch-1 decode GEMMs parallel: the output row is one token, but
/// its thousands of output channels split across cores.
fn parallel_col_blocks<T, F>(out: &mut [T], n: usize, m: usize, nr: usize, zero: T, body: F)
where
    T: Copy + Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    debug_assert_eq!(out.len(), n * m);
    if n == 0 || m == 0 {
        return;
    }
    let threads = threadpool::default_threads();
    let chunk = m.div_ceil(threads).max(1).next_multiple_of(nr.max(1));
    let n_chunks = m.div_ceil(chunk);
    let ptr = SendPtr(out.as_mut_ptr());
    let ptr = &ptr;
    threadpool::parallel_for(n_chunks, threads, |range| {
        for c in range {
            let j0 = c * chunk;
            let j1 = (j0 + chunk).min(m);
            let w = j1 - j0;
            let mut tile = vec![zero; n * w];
            body(j0, j1, &mut tile);
            for i in 0..n {
                // SAFETY: src is row i of the `[n, w]` tile (in bounds by
                // construction); dst is columns `[j0, j1)` of row i of the
                // `[n, m]` out buffer with `j1 <= m`, and tasks own
                // disjoint column ranges, so the regions never overlap.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        tile.as_ptr().add(i * w),
                        ptr.0.add(i * m + j0),
                        w,
                    );
                }
            }
        }
    });
}

/// `C_i32 = A_i8 @ unpack(B)^T` through an explicit backend + tiles
/// (test / autotune entry; serving uses [`igemm_packed`]).
pub fn igemm_packed_with(
    bk: &dyn KernelBackend,
    tiles: TileConfig,
    a: &MatI8,
    b: &PackedI4,
) -> Vec<i32> {
    assert_eq!(a.cols, b.cols, "igemm_packed: inner dims");
    let (n, k, m) = (a.rows, a.cols, b.rows);
    let mut out = vec![0i32; n * m];
    parallel_col_blocks(&mut out, n, m, tiles.nr, 0i32, |j0, j1, tile| {
        bk.igemm_block(&a.data, n, k, b, j0, j1, tiles, tile);
    });
    out
}

/// `C_i32 = A_i8 @ unpack(B)^T` on the dispatched backend — the packed
/// counterpart of [`crate::linalg::igemm::igemm_i8_bt`], bit-identical
/// to it by the backend contract.
pub fn igemm_packed(a: &MatI8, b: &PackedI4) -> Vec<i32> {
    let _phase = crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::Gemm);
    IGEMM_CALLS.fetch_add(1, Ordering::Relaxed);
    let r = registry();
    igemm_packed_with(r.backend, r.tiles, a, b)
}

/// Fused Runtime-Smooth GEMM over a packed, pre-permuted weight, through
/// an explicit backend + tiles.  `q`/`sx`/`group`/`sg` come from the
/// prologue ([`rrs_prologue`]); `sw` is the per-output-channel weight
/// scale.  Output matches the staged
/// [`crate::quant::qlinear::forward_rs_fused_prepermuted`] bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn gemm_rs_fused_packed_with(
    bk: &dyn KernelBackend,
    tiles: TileConfig,
    q: &MatI8,
    sx: &[f32],
    group: usize,
    sg: &[f32],
    b: &PackedI4,
    sw: &[f32],
) -> Mat {
    assert_eq!(q.cols, b.cols, "rs_fused: inner dims");
    assert_eq!(q.rows, sx.len(), "rs_fused: token scales");
    assert_eq!(b.rows, sw.len(), "rs_fused: weight scales");
    assert!(group >= 1 && q.cols % group == 0, "rs_fused: group | k");
    assert_eq!(sg.len(), q.cols / group, "rs_fused: group scales");
    let (n, k, m) = (q.rows, q.cols, b.rows);
    let mut out = Mat::zeros(n, m);
    parallel_col_blocks(&mut out.data, n, m, tiles.nr, 0.0f32, |j0, j1, tile| {
        bk.gemm_scaled_block(&q.data, n, k, group, sg, sx, b, sw, j0, j1, tiles, tile);
    });
    out
}

/// Fused Runtime-Smooth GEMM on the dispatched backend (the serving hot
/// path behind [`crate::quant::qlinear::QLinear`]).
pub fn gemm_rs_fused_packed(
    q: &MatI8,
    sx: &[f32],
    group: usize,
    sg: &[f32],
    b: &PackedI4,
    sw: &[f32],
) -> Mat {
    let _phase = crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::Gemm);
    FUSED_GEMM_CALLS.fetch_add(1, Ordering::Relaxed);
    FUSED_GEMM_ROWS.fetch_add(q.rows as u64, Ordering::Relaxed);
    let r = registry();
    gemm_rs_fused_packed_with(r.backend, r.tiles, q, sx, group, sg, b, sw)
}

/// Per-channel A4W4 GEMM (per-token activation scale × per-channel
/// weight scale) over a packed weight — the degenerate one-group case of
/// the fused kernel, bit-identical to the staged
/// [`crate::quant::qlinear::forward_per_channel_a4w4`] epilogue.
pub fn gemm_per_channel_packed(xq: &MatI8, sx: &[f32], b: &PackedI4, sw: &[f32]) -> Mat {
    let _phase = crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::Gemm);
    PER_CHANNEL_CALLS.fetch_add(1, Ordering::Relaxed);
    let r = registry();
    gemm_per_channel_packed_with(r.backend, r.tiles, xq, sx, b, sw)
}

/// Explicit-backend form of [`gemm_per_channel_packed`].
pub fn gemm_per_channel_packed_with(
    bk: &dyn KernelBackend,
    tiles: TileConfig,
    xq: &MatI8,
    sx: &[f32],
    b: &PackedI4,
    sw: &[f32],
) -> Mat {
    gemm_rs_fused_packed_with(bk, tiles, xq, sx, xq.cols.max(1), &[1.0], b, sw)
}

/// W4A8 mixed-precision GEMM: full-range INT8 activation codes × packed
/// INT4 weights, per-token × per-channel scale epilogue.  The i32
/// accumulator is exact for i8·i4 products at any K that fits memory
/// (|a·w| ≤ 127·7, ~2^41 headroom at K = 2^31), and the avx2 `pmaddwd`
/// path widens both operands to i16 before multiplying, so every
/// backend serves INT8 codes unchanged — the entry point exists so the
/// recipe layer dispatches it explicitly and metrics can count the
/// W4A8 hot path separately.  Bit-identity vs the staged INT8 reference
/// is locked by `rust/tests/kernel_diff.rs`.
pub fn gemm_w4a8_packed(xq: &MatI8, sx: &[f32], b: &PackedI4, sw: &[f32]) -> Mat {
    let _phase = crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::Gemm);
    W4A8_CALLS.fetch_add(1, Ordering::Relaxed);
    let r = registry();
    gemm_w4a8_packed_with(r.backend, r.tiles, xq, sx, b, sw)
}

/// Explicit-backend form of [`gemm_w4a8_packed`].
pub fn gemm_w4a8_packed_with(
    bk: &dyn KernelBackend,
    tiles: TileConfig,
    xq: &MatI8,
    sx: &[f32],
    b: &PackedI4,
    sw: &[f32],
) -> Mat {
    gemm_rs_fused_packed_with(bk, tiles, xq, sx, xq.cols.max(1), &[1.0], b, sw)
}

/// Fused RRS activation prologue on an explicit backend: channel-max
/// reduction, reorder permutation, group scales, then a fused gather +
/// smooth + per-token RTN quantize pass per row.  Bit-identical to the
/// staged [`crate::quant::runtime_smooth::prepare_staged`].
pub fn rrs_prologue_with(bk: &dyn KernelBackend, x: &Mat, group: usize) -> SmoothedAct {
    rrs_prologue_with_q(bk, x, group, crate::quant::QMAX)
}

/// [`rrs_prologue_with`] at an arbitrary symmetric max code (7 = the
/// INT4 golden path, 127 = the W4A8 activation recipe).
pub fn rrs_prologue_with_q(
    bk: &dyn KernelBackend,
    x: &Mat,
    group: usize,
    qmax: f32,
) -> SmoothedAct {
    let mut s = vec![0.0f32; x.cols];
    bk.colmax_abs(&x.data, x.rows, x.cols, &mut s);
    for v in s.iter_mut() {
        *v = v.max(1e-8);
    }
    let perm = runtime_smooth::reorder_perm(&s);
    let sg = runtime_smooth::group_scales(&s, &perm, group);
    let mut q = MatI8::zeros(x.rows, x.cols);
    let mut token_scales = vec![0.0f32; x.rows];
    let mut smooth = vec![0.0f32; x.cols];
    for i in 0..x.rows {
        let absmax = bk.smooth_row(x.row(i), &perm, group, &sg, &mut smooth);
        let sxi = rtn::scale_for_q(absmax, qmax);
        token_scales[i] = sxi;
        rtn::quantize_row_q(
            &smooth,
            sxi,
            qmax,
            &mut q.data[i * x.cols..(i + 1) * x.cols],
        );
    }
    SmoothedAct { q, token_scales, perm, group_scales: sg, group }
}

/// Fused RRS activation prologue on the dispatched backend (what
/// [`crate::quant::runtime_smooth::prepare`] runs).  Sampled
/// quant-health probes ([`crate::obs::health`]) hang off this entry
/// point: the pre-smoothing activation and its INT4 codes are both in
/// hand here, so the probe costs one extra pass only on sampled calls.
pub fn rrs_prologue(x: &Mat, group: usize) -> SmoothedAct {
    rrs_prologue_q(x, group, crate::quant::QMAX)
}

/// [`rrs_prologue`] at an arbitrary max code (the recipe layer's entry;
/// the health probe clips against the same code range it quantized to).
pub fn rrs_prologue_q(x: &Mat, group: usize, qmax: f32) -> SmoothedAct {
    let _phase = crate::obs::attrib::phase_scope(crate::obs::attrib::Phase::Gemm);
    PROLOGUE_ROWS.fetch_add(x.rows as u64, Ordering::Relaxed);
    let r = registry();
    let sa = rrs_prologue_with_q(r.backend, x, group, qmax);
    if crate::obs::health::sampled() {
        let layer = crate::obs::current_layer_or("rrs_prologue");
        crate::obs::health::probe_quant_q(&layer, x, &sa.q, qmax);
    }
    sa
}

/// Dispatched in-place normalized FWHT over one row.
pub fn fwht_dispatch(x: &mut [f32]) {
    FWHT_ROWS.fetch_add(1, Ordering::Relaxed);
    registry().backend.fwht(x);
}

/// Apply the dispatched FWHT to every `k`-length row, rows in parallel
/// (the rotation path of QuaRot/RRS linears).
pub fn fwht_rows_par(data: &mut [f32], k: usize) {
    assert!(k.is_power_of_two(), "fwht length {k} not a power of two");
    assert_eq!(data.len() % k, 0);
    let rows = data.len() / k;
    FWHT_ROWS.fetch_add(rows as u64, Ordering::Relaxed);
    let bk = registry().backend;
    let threads = threadpool::default_threads();
    threadpool::parallel_rows(data, k, threads, |_i, row| bk.fwht(row));
}

/// Dispatched f32 dot product (attention scores); bit-identical to
/// [`crate::linalg::gemm::dot`] on every backend.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    registry().backend.dot_f32(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn tile_parse_roundtrip() {
        let t = TileConfig::parse("8x32x256").unwrap();
        assert_eq!(t, TileConfig { mr: 8, nr: 32, kc: 256 });
        assert_eq!(t.label(), "8x32x256");
        assert!(TileConfig::parse("8x32").is_none());
        assert!(TileConfig::parse("0x32x256").is_none());
        assert!(TileConfig::parse("axbxc").is_none());
    }

    #[test]
    fn registry_resolves_and_counts() {
        let before = stats();
        let mut rng = Pcg::new(3);
        let a = MatI8::from_vec(
            2,
            40,
            (0..80).map(|_| rng.below(15) as i8 - 7).collect(),
        );
        let b = MatI8::from_vec(
            3,
            40,
            (0..120).map(|_| rng.below(15) as i8 - 7).collect(),
        );
        let bp = PackedI4::pack(&b);
        let got = igemm_packed(&a, &bp);
        let want = crate::linalg::igemm::igemm_i8_bt(&a, &b);
        assert_eq!(got, want);
        let after = stats();
        assert!(!after.backend.is_empty());
        assert_eq!(after.igemm_calls, before.igemm_calls + 1);
    }

    #[test]
    fn per_channel_equals_one_group_fused() {
        let mut rng = Pcg::new(4);
        let xq = MatI8::from_vec(
            3,
            32,
            (0..96).map(|_| rng.below(15) as i8 - 7).collect(),
        );
        let wq = MatI8::from_vec(
            5,
            32,
            (0..160).map(|_| rng.below(15) as i8 - 7).collect(),
        );
        let sx: Vec<f32> = (0..3).map(|i| 0.1 + i as f32 * 0.03).collect();
        let sw: Vec<f32> = (0..5).map(|j| 0.2 + j as f32 * 0.01).collect();
        let bp = PackedI4::pack(&wq);
        let y = gemm_per_channel_packed(&xq, &sx, &bp, &sw);
        // staged reference epilogue
        for i in 0..3 {
            for j in 0..5 {
                let acc = crate::linalg::igemm::idot(xq.row(i), wq.row(j));
                let want = acc as f32 * sx[i] * sw[j];
                assert_eq!(y.at(i, j).to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn w4a8_full_range_codes_match_staged_reference() {
        // INT8 activation codes span the full [-127, 127] range; the
        // packed-weight igemm must stay exact (no i16 overflow) and the
        // epilogue bit-identical to the staged form
        let mut rng = Pcg::new(5);
        let xq = MatI8::from_vec(
            4,
            48,
            (0..192).map(|_| (rng.below(255) as i32 - 127) as i8).collect(),
        );
        let wq = MatI8::from_vec(
            6,
            48,
            (0..288).map(|_| rng.below(15) as i8 - 7).collect(),
        );
        let sx: Vec<f32> = (0..4).map(|i| 0.01 + i as f32 * 0.002).collect();
        let sw: Vec<f32> = (0..6).map(|j| 0.05 + j as f32 * 0.003).collect();
        let bp = PackedI4::pack(&wq);
        let before = stats();
        let y = gemm_w4a8_packed(&xq, &sx, &bp, &sw);
        assert_eq!(stats().w4a8_calls, before.w4a8_calls + 1);
        for i in 0..4 {
            for j in 0..6 {
                let acc = crate::linalg::igemm::idot(xq.row(i), wq.row(j));
                let want = acc as f32 * sx[i] * sw[j];
                assert_eq!(y.at(i, j).to_bits(), want.to_bits());
            }
        }
    }
}
