//! The portable fallback backend: cache-blocked safe-Rust loops shaped
//! for the autovectorizer (byte-pair nibble unpacking with independent
//! even/odd accumulators, split-slice FWHT butterflies).  This is the
//! default on CPUs without AVX2 and the floor every platform gets;
//! results are bit-identical to the scalar reference (integer
//! accumulation is exact, f32 scale order follows the contract).

use crate::quant::pack4::PackedI4;

use super::{scalar, KernelBackend, TileConfig};

/// See the module docs.
pub struct PortableBackend;

/// Exact i32 dot over elements `[lo, hi)` (`lo` even): unpack each
/// packed byte into its two nibbles and accumulate even/odd lanes
/// independently — the shape LLVM turns into `pmaddwd`-style vectors.
#[inline]
fn dot_span(arow: &[i8], brow: &[u8], lo: usize, hi: usize) -> i32 {
    debug_assert_eq!(lo % 2, 0, "span must start on a byte boundary");
    let full = hi / 2;
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    for t in lo / 2..full {
        let byte = brow[t];
        let ln = (((byte & 0x0f) << 4) as i8) >> 4;
        let hn = ((byte & 0xf0) as i8) >> 4;
        acc0 += arow[2 * t] as i32 * ln as i32;
        acc1 += arow[2 * t + 1] as i32 * hn as i32;
    }
    let mut acc = acc0 + acc1;
    if hi % 2 == 1 {
        acc += arow[hi - 1] as i32 * scalar::nib(brow, hi - 1);
    }
    acc
}

/// Bit-exact FWHT with split-slice butterflies (vectorizable form of
/// the reference loop: identical pairs, identical op order).
pub(crate) fn fwht_portable(x: &mut [f32]) {
    let k = x.len();
    debug_assert!(k.is_power_of_two());
    let mut h = 1;
    while h < k {
        let step = h * 2;
        let mut base = 0;
        while base < k {
            let (lhs, rhs) = x[base..base + step].split_at_mut(h);
            for (a, b) in lhs.iter_mut().zip(rhs.iter_mut()) {
                let t = *a;
                *a = t + *b;
                *b = t - *b;
            }
            base += step;
        }
        h = step;
    }
    let norm = 1.0 / (k as f32).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

impl KernelBackend for PortableBackend {
    fn name(&self) -> &'static str {
        "portable"
    }

    fn igemm_block(
        &self,
        a: &[i8],
        n: usize,
        k: usize,
        b: &PackedI4,
        j0: usize,
        j1: usize,
        tiles: TileConfig,
        acc: &mut [i32],
    ) {
        let w = j1 - j0;
        let mr = tiles.mr.max(1);
        let nr = tiles.nr.max(1);
        let kc = (tiles.kc.max(32) / 2) * 2; // even K blocks
        for ib in (0..n).step_by(mr) {
            let ih = (ib + mr).min(n);
            for jt in (j0..j1).step_by(nr) {
                let jh = (jt + nr).min(j1);
                let mut klo = 0;
                while klo < k {
                    let khi = (klo + kc).min(k);
                    for j in jt..jh {
                        let brow = b.row(j);
                        for i in ib..ih {
                            let arow = &a[i * k..(i + 1) * k];
                            acc[i * w + (j - j0)] += dot_span(arow, brow, klo, khi);
                        }
                    }
                    klo = khi;
                }
            }
        }
    }

    fn gemm_scaled_block(
        &self,
        a: &[i8],
        n: usize,
        k: usize,
        group: usize,
        sg: &[f32],
        sx: &[f32],
        b: &PackedI4,
        sw: &[f32],
        j0: usize,
        j1: usize,
        tiles: TileConfig,
        out: &mut [f32],
    ) {
        let w = j1 - j0;
        let mr = tiles.mr.max(1);
        let nr = tiles.nr.max(1);
        // the group structure already blocks K; odd groups fall back to
        // the nibble-at-a-time reference (identical integer result)
        let even = group % 2 == 0;
        for ib in (0..n).step_by(mr) {
            let ih = (ib + mr).min(n);
            for jt in (j0..j1).step_by(nr) {
                let jh = (jt + nr).min(j1);
                for j in jt..jh {
                    let brow = b.row(j);
                    let swj = sw[j];
                    for i in ib..ih {
                        let arow = &a[i * k..(i + 1) * k];
                        let mut fsum = 0.0f32;
                        for (g, &sgv) in sg.iter().enumerate() {
                            let lo = g * group;
                            let d = if even {
                                dot_span(arow, brow, lo, lo + group)
                            } else {
                                scalar::dot_seg(arow, brow, lo, lo + group)
                            };
                            fsum += d as f32 * sgv;
                        }
                        out[i * w + (j - j0)] = fsum * sx[i] * swj;
                    }
                }
            }
        }
    }

    fn colmax_abs(&self, x: &[f32], rows: usize, k: usize, s: &mut [f32]) {
        for i in 0..rows {
            for (sj, &v) in s.iter_mut().zip(&x[i * k..(i + 1) * k]) {
                *sj = sj.max(v.abs());
            }
        }
    }

    fn smooth_row(
        &self,
        row: &[f32],
        perm: &[usize],
        group: usize,
        sg: &[f32],
        out: &mut [f32],
    ) -> f32 {
        // gather, then divide per group segment with a hoisted divisor:
        // the same elementwise divisions as the reference, vectorizable
        let k = perm.len();
        for (o, &p) in out[..k].iter_mut().zip(perm) {
            *o = row[p];
        }
        let mut absmax = 0.0f32;
        for (g, &sgv) in sg.iter().enumerate() {
            let lo = g * group;
            let hi = (lo + group).min(k);
            for v in out[lo..hi].iter_mut() {
                *v /= sgv;
                absmax = absmax.max(v.abs());
            }
        }
        absmax
    }

    fn fwht(&self, x: &mut [f32]) {
        fwht_portable(x);
    }

    fn dot_f32(&self, a: &[f32], b: &[f32]) -> f32 {
        crate::linalg::gemm::dot(a, b)
    }
}
