//! The scalar reference backend: the pre-existing staged loops, kept
//! verbatim in spirit so every other backend has a bit-exact oracle.
//!
//! It still consumes the packed weight nibble-by-nibble (no i8
//! materialization) — the *semantics* of the packed microkernel with
//! none of the blocking or SIMD.  Selected with `RRS_KERNEL=scalar`; CI
//! forces it once per run so the oracle itself stays exercised on AVX2
//! runners.

use crate::quant::pack4::PackedI4;

use super::{KernelBackend, TileConfig};

/// See the module docs.
pub struct ScalarBackend;

/// Sign-extended nibble `t` of a packed row (low nibble = even `t`).
#[inline]
pub(crate) fn nib(brow: &[u8], t: usize) -> i32 {
    let byte = brow[t >> 1];
    let n = if t & 1 == 0 { byte & 0x0f } else { byte >> 4 };
    (((n << 4) as i8) >> 4) as i32
}

/// Exact i32 dot of an i8 row segment against packed nibbles `[lo, hi)`.
#[inline]
pub(crate) fn dot_seg(arow: &[i8], brow: &[u8], lo: usize, hi: usize) -> i32 {
    let mut acc = 0i32;
    for t in lo..hi {
        acc += arow[t] as i32 * nib(brow, t);
    }
    acc
}

impl KernelBackend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn igemm_block(
        &self,
        a: &[i8],
        n: usize,
        k: usize,
        b: &PackedI4,
        j0: usize,
        j1: usize,
        _tiles: TileConfig,
        acc: &mut [i32],
    ) {
        let w = j1 - j0;
        for (jj, j) in (j0..j1).enumerate() {
            let brow = b.row(j);
            for i in 0..n {
                let arow = &a[i * k..(i + 1) * k];
                acc[i * w + jj] += dot_seg(arow, brow, 0, k);
            }
        }
    }

    fn gemm_scaled_block(
        &self,
        a: &[i8],
        n: usize,
        k: usize,
        group: usize,
        sg: &[f32],
        sx: &[f32],
        b: &PackedI4,
        sw: &[f32],
        j0: usize,
        j1: usize,
        _tiles: TileConfig,
        out: &mut [f32],
    ) {
        let w = j1 - j0;
        for (jj, j) in (j0..j1).enumerate() {
            let brow = b.row(j);
            let swj = sw[j];
            for i in 0..n {
                let arow = &a[i * k..(i + 1) * k];
                // group partials ascending — the contract's f32 order
                let mut fsum = 0.0f32;
                for (g, &sgv) in sg.iter().enumerate() {
                    let lo = g * group;
                    let d = dot_seg(arow, brow, lo, lo + group);
                    fsum += d as f32 * sgv;
                }
                out[i * w + jj] = fsum * sx[i] * swj;
            }
        }
    }

    fn colmax_abs(&self, x: &[f32], rows: usize, k: usize, s: &mut [f32]) {
        for i in 0..rows {
            for (sj, &v) in s.iter_mut().zip(&x[i * k..(i + 1) * k]) {
                *sj = sj.max(v.abs());
            }
        }
    }

    fn smooth_row(
        &self,
        row: &[f32],
        perm: &[usize],
        group: usize,
        sg: &[f32],
        out: &mut [f32],
    ) -> f32 {
        let mut absmax = 0.0f32;
        for (j, &p) in perm.iter().enumerate() {
            let v = row[p] / sg[j / group];
            out[j] = v;
            absmax = absmax.max(v.abs());
        }
        absmax
    }

    fn fwht(&self, x: &mut [f32]) {
        crate::linalg::fwht::fwht_inplace_scalar(x);
    }

    fn dot_f32(&self, a: &[f32], b: &[f32]) -> f32 {
        crate::linalg::gemm::dot(a, b)
    }
}
