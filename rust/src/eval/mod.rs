//! Evaluation: perplexity (WikiText-2 stand-in), zero-shot QA scoring
//! (Common Sense QA stand-in), and the activation smoothness statistics
//! behind Figures 2b / 7 / 8 / 9.

pub mod perplexity;
pub mod qa;
pub mod smoothness;

pub use perplexity::perplexity;
pub use qa::{load_tasks, score_tasks, QaItem};
pub use smoothness::{collect_mu, outlier_histogram, SmoothMode};
