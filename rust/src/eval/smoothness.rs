//! Activation smoothness statistics: the measurement machinery behind
//! Fig. 2b (P(less smooth after rotation)), Fig. 7 (spike-outlier
//! histogram at the down-projector), Fig. 8 (victim-effect Monte Carlo)
//! and Fig. 9 (mu per projector under X / R / RS / RRS).

use crate::linalg::gemm::Mat;
use crate::quant::rotation::Rotation;
use crate::quant::runtime_smooth;
use crate::util::stats;

/// Which smoothing view of the activation to measure (Fig. 9 legend).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SmoothMode {
    /// Raw activation ("X").
    X,
    /// Rotated ("R").
    R,
    /// Runtime Smooth ("RS"): X / channel-max.
    Rs,
    /// Rotated Runtime Smooth ("RRS").
    Rrs,
}

impl SmoothMode {
    pub const ALL: [SmoothMode; 4] =
        [SmoothMode::X, SmoothMode::R, SmoothMode::Rs, SmoothMode::Rrs];

    pub fn name(&self) -> &'static str {
        match self {
            SmoothMode::X => "X",
            SmoothMode::R => "R",
            SmoothMode::Rs => "RS",
            SmoothMode::Rrs => "RRS",
        }
    }
}

/// Transform an activation per the mode (rotation requires pow-2 K).
pub fn apply_mode(x: &Mat, mode: SmoothMode) -> Mat {
    match mode {
        SmoothMode::X => x.clone(),
        SmoothMode::R => Rotation::Hadamard.apply(x),
        SmoothMode::Rs => smooth_by_channel_max(x),
        SmoothMode::Rrs => smooth_by_channel_max(&Rotation::Hadamard.apply(x)),
    }
}

fn smooth_by_channel_max(x: &Mat) -> Mat {
    let s = runtime_smooth::channel_scales(x);
    let mut out = x.clone();
    for i in 0..out.rows {
        for (v, &sj) in out.row_mut(i).iter_mut().zip(&s) {
            *v /= sj;
        }
    }
    out
}

/// Per-token mu = absmax/RMS after the mode transform (Fig. 2b / 9).
pub fn collect_mu(x: &Mat, mode: SmoothMode) -> Vec<f32> {
    let t = apply_mode(x, mode);
    (0..t.rows).map(|i| stats::smoothness_mu(t.row(i))).collect()
}

/// Fraction of tokens that got LESS smooth after rotation (Fig. 2b):
/// mu(rotated) > mu(raw).
pub fn prob_less_smooth_after_rotation(x: &Mat) -> f32 {
    let before = collect_mu(x, SmoothMode::X);
    let after = collect_mu(x, SmoothMode::R);
    let worse = before.iter().zip(&after).filter(|(b, a)| a > b).count();
    worse as f32 / before.len().max(1) as f32
}

/// Spike-outlier histogram (Fig. 7): per token, magnitudes x/median(|t|),
/// counted into the paper's intervals.  Returns (edges, counts) where
/// `counts[i]` = #elements with ratio in `[edges[i-1], edges[i])`.
pub fn outlier_histogram(x: &Mat, edges: &[f32]) -> Vec<usize> {
    let mut ratios = Vec::new();
    for i in 0..x.rows {
        let row = x.row(i);
        let mut mags: Vec<f32> = row.iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = mags[mags.len() / 2].max(1e-8);
        for &v in row {
            ratios.push(v.abs() / med);
        }
    }
    stats::log_histogram(&ratios, edges)
}

/// Victim-effect statistic (Fig. 8 / appendix A.1, eq. 8-10): build an
/// activation of `n_spike` spike tokens (magnitude `spike`) over Gaussian
/// noise, compute smoothing scales under RS or RRS, and return
/// u = mu(1/scale) — the smoothness of a normal token after smoothing.
pub fn victim_u(
    k: usize,
    n_tokens: usize,
    n_spikes: usize,
    spike: f32,
    rotated: bool,
    rng: &mut crate::util::rng::Pcg,
) -> f32 {
    let mut x = Mat::from_vec(n_tokens, k, rng.normal_vec(n_tokens * k));
    let chans = rng.choose_distinct(k, n_spikes.min(k));
    for (t, &c) in chans.iter().enumerate() {
        x.data[(t % n_tokens) * k + c] = spike;
    }
    let xt = if rotated { Rotation::Hadamard.apply(&x) } else { x };
    let s = runtime_smooth::channel_scales(&xt);
    let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
    stats::smoothness_mu(&inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn act_with_channel_outliers(seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        let mut x = Mat::from_vec(64, 128, rng.normal_vec(64 * 128));
        for i in 0..64 {
            x.data[i * 128 + 9] = 50.0 * (1.0 + 0.1 * rng.normal());
        }
        x
    }

    #[test]
    fn rotation_smooths_structured_activations() {
        let x = act_with_channel_outliers(1);
        let mu_x = stats::mean(&collect_mu(&x, SmoothMode::X));
        let mu_r = stats::mean(&collect_mu(&x, SmoothMode::R));
        assert!(mu_r < mu_x, "{mu_r} vs {mu_x}");
    }

    #[test]
    fn all_smoothers_improve_on_raw() {
        // With a dominant consistent channel, rotation yields near-constant
        // rows (very low mu) while RS yields Gaussian-ish rows; both beat
        // the raw activation, and RRS at least matches RS.
        let x = act_with_channel_outliers(2);
        let mu_x = stats::mean(&collect_mu(&x, SmoothMode::X));
        let mu_r = stats::mean(&collect_mu(&x, SmoothMode::R));
        let mu_rs = stats::mean(&collect_mu(&x, SmoothMode::Rs));
        let mu_rrs = stats::mean(&collect_mu(&x, SmoothMode::Rrs));
        assert!(mu_r < mu_x, "{mu_r} vs {mu_x}");
        assert!(mu_rs < mu_x, "{mu_rs} vs {mu_x}");
        assert!(mu_rrs <= mu_rs * 1.05, "{mu_rrs} vs {mu_rs}");
    }

    #[test]
    fn llm_like_rarely_less_smooth_but_random_often() {
        // Fig. 2b: structured activations rotate smoother; pure Gaussian
        // ("random matrix") gets less smooth about half the time.
        let x = act_with_channel_outliers(3);
        let p_llm = prob_less_smooth_after_rotation(&x);
        let mut rng = Pcg::new(4);
        let g = Mat::from_vec(64, 128, rng.normal_vec(64 * 128));
        let p_rand = prob_less_smooth_after_rotation(&g);
        assert!(p_llm < 0.2, "p_llm {p_llm}");
        assert!(p_rand > 0.3, "p_rand {p_rand}");
    }

    #[test]
    fn histogram_finds_spikes() {
        let mut rng = Pcg::new(5);
        let mut x = Mat::from_vec(16, 128, rng.normal_vec(16 * 128));
        x.data[7 * 128 + 3] = 5000.0;
        let counts = outlier_histogram(&x, &[10.0, 100.0, 1000.0]);
        assert_eq!(counts.len(), 4);
        assert!(counts[3] >= 1); // the >=1000x bucket caught the spike
    }

    #[test]
    fn victims_grow_with_spikes_without_rotation() {
        let mut rng = Pcg::new(6);
        let u_rs_1 = victim_u(128, 64, 1, 1000.0, false, &mut rng);
        let mut rng = Pcg::new(6);
        let u_rs_16 = victim_u(128, 64, 16, 1000.0, false, &mut rng);
        let mut rng = Pcg::new(6);
        let u_rrs_16 = victim_u(128, 64, 16, 1000.0, true, &mut rng);
        assert!(u_rs_16 > u_rs_1, "{u_rs_16} vs {u_rs_1}");
        assert!(u_rrs_16 < u_rs_16, "{u_rrs_16} vs {u_rs_16}");
    }
}
