//! Teacher-forced perplexity over held-out text (the WikiText-2 stand-in).

use crate::model::engine::QuantModel;
use crate::model::tokenizer;

/// Mean NLL (nats/token) over fixed windows of `seq` tokens; perplexity =
/// exp(NLL).  Window starts stride disjointly, matching python
/// `train.eval_nll`'s protocol (teacher forcing, next-byte targets).
pub fn mean_nll(model: &QuantModel, text: &str, seq: usize, max_windows: usize) -> f32 {
    let toks = tokenizer::encode(text);
    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut start = 0usize;
    let mut windows = 0usize;
    while start + seq + 1 < toks.len() && windows < max_windows {
        let window = &toks[start..start + seq + 1];
        let logits = model.forward_full(&window[..seq], None);
        for i in 0..seq {
            let row = logits.row(i);
            let target = window[i + 1] as usize;
            // stable log-softmax
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
            total += (lse - row[target]) as f64;
            count += 1;
        }
        start += seq;
        windows += 1;
    }
    if count == 0 {
        return f32::NAN;
    }
    (total / count as f64) as f32
}

/// Perplexity = exp(mean NLL).  Values above `cap` are clamped (the paper
/// prints divergent results as "5e3"-style magnitudes; we keep the raw
/// number but callers may format with [`format_ppl`]).
pub fn perplexity(model: &QuantModel, text: &str, seq: usize, max_windows: usize) -> f32 {
    mean_nll(model, text, seq, max_windows).exp()
}

/// Paper-style formatting: small values to 2 decimals, divergent ones in
/// scientific magnitude form ("5e3"), NaN as "Nan".
pub fn format_ppl(ppl: f32) -> String {
    if ppl.is_nan() {
        "Nan".to_string()
    } else if ppl < 100.0 {
        format!("{ppl:.2}")
    } else if ppl < 1000.0 {
        format!("{ppl:.1}")
    } else {
        let exp = ppl.log10().floor() as i32;
        let mant = ppl / 10f32.powi(exp);
        format!("{}e{}", mant.round() as i32, exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{EngineConfig, ModelConfig, QuantModel, Weights};
    use crate::quant::{Method, Scheme};

    #[test]
    fn random_model_ppl_near_uniform() {
        // an untrained model's byte-level perplexity is ~vocab
        let cfg = ModelConfig { n_layers: 1, ..Default::default() };
        let w = Weights::random(&cfg, 3);
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        let m = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
        let text = "abcdefgh. the quick brown fox jumps over the lazy dog. "
            .repeat(4);
        let ppl = perplexity(&m, &text, 32, 2);
        assert!(ppl > 50.0 && ppl < 2000.0, "ppl {ppl}");
    }

    #[test]
    fn formatting() {
        assert_eq!(format_ppl(6.6632), "6.66");
        assert_eq!(format_ppl(57.333), "57.33");
        assert_eq!(format_ppl(f32::NAN), "Nan");
        assert_eq!(format_ppl(5_200.0), "5e3");
        assert_eq!(format_ppl(214.88), "214.9");
    }
}
