//! Prometheus text exposition (format 0.0.4) for the coordinator
//! metrics: every counter/gauge the JSON snapshot carries (requests,
//! KV pool, prefix cache, resident lanes, kernel registry) plus the
//! log-scale latency histograms as native `_bucket{le=...}` families
//! and the per-layer quant-health gauges.  Served by the coordinator's
//! `metrics_prom` TCP command; scrape-side the body is plain
//! `text/plain; version=0.0.4`.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::Metrics;
use crate::kernels;

use super::attrib;
use super::health;
use super::hist::LogHistogram;
use super::watchdog;

/// Render the full exposition document for one metrics snapshot.
pub fn render(m: &Metrics) -> String {
    let mut out = String::with_capacity(16 * 1024);
    // ORDERING: metrics cells are independent counters/gauges; one
    // scrape tolerates a view torn across cells, so Relaxed loads.
    let c = |a: &AtomicU64| a.load(Ordering::Relaxed);

    // request lifecycle counters
    let request_counters: [(&str, &str, u64); 11] = [
        (
            "rrs_requests_submitted_total",
            "Requests accepted by the coordinator.",
            c(&m.submitted),
        ),
        (
            "rrs_requests_rejected_total",
            "Requests rejected on queue backpressure.",
            c(&m.rejected),
        ),
        (
            "rrs_requests_completed_total",
            "Requests retired with a response.",
            c(&m.completed),
        ),
        (
            "rrs_requests_aborted_total",
            "Requests aborted (can never fit the pool).",
            c(&m.aborted),
        ),
        (
            "rrs_requests_cancelled_total",
            "Requests cancelled by client disconnect or abort flag.",
            c(&m.cancelled),
        ),
        (
            "rrs_requests_deadline_missed_total",
            "Requests finished past their deadline.",
            c(&m.deadline_missed),
        ),
        (
            "rrs_tokens_streamed_total",
            "Token frames delivered to live stream receivers.",
            c(&m.tokens_streamed),
        ),
        (
            "rrs_preemptions_total",
            "Sequences preempted back to the queue on pool exhaustion.",
            c(&m.preemptions),
        ),
        (
            "rrs_tokens_generated_total",
            "Tokens generated across completed requests.",
            c(&m.tokens_generated),
        ),
        (
            "rrs_prefill_tokens_total",
            "Prompt tokens prefilled (re-prefills included).",
            c(&m.prefill_tokens),
        ),
        (
            "rrs_decode_steps_total",
            "Batched decode steps executed.",
            c(&m.decode_steps),
        ),
    ];
    for (name, help, v) in request_counters {
        counter(&mut out, name, help, v);
    }

    // KV-pool occupancy gauges
    let pool_gauges: [(&str, &str, u64); 4] = [
        (
            "rrs_pool_blocks_total",
            "KV pool capacity in blocks.",
            c(&m.pool_blocks_total),
        ),
        (
            "rrs_pool_blocks_used",
            "KV pool blocks held by active sequences.",
            c(&m.pool_blocks_used),
        ),
        (
            "rrs_pool_blocks_cached",
            "KV pool blocks held only by the prefix cache.",
            c(&m.pool_blocks_cached),
        ),
        (
            "rrs_pool_blocks_peak",
            "High-water mark of used blocks.",
            c(&m.pool_blocks_peak),
        ),
    ];
    for (name, help, v) in pool_gauges {
        gauge(&mut out, name, help, v as f64);
    }

    // KV-pool + prefix-cache counters
    let pool_counters: [(&str, &str, u64); 8] = [
        (
            "rrs_pool_evictions_total",
            "Prefix-cache blocks evicted (LRU).",
            c(&m.pool_evictions),
        ),
        (
            "rrs_pool_cow_copies_total",
            "Copy-on-write block copies.",
            c(&m.pool_cow_copies),
        ),
        (
            "rrs_pool_lazy_tail_shares_total",
            "Partial tail blocks shared lazily on prefix hit.",
            c(&m.pool_lazy_tail_shares),
        ),
        (
            "rrs_pool_lazy_tail_copies_total",
            "Lazily shared tail blocks copied on divergence.",
            c(&m.pool_lazy_tail_copies),
        ),
        (
            "rrs_prefix_queries_total",
            "Prefix-cache lookups.",
            c(&m.prefix_queries),
        ),
        (
            "rrs_prefix_query_tokens_total",
            "Prompt tokens probed against the prefix cache.",
            c(&m.prefix_query_tokens),
        ),
        (
            "rrs_prefix_hit_tokens_total",
            "Prompt tokens served from the prefix cache.",
            c(&m.prefix_hit_tokens),
        ),
        (
            "rrs_prefix_hit_blocks_total",
            "Whole blocks served from the prefix cache.",
            c(&m.prefix_hit_blocks),
        ),
    ];
    for (name, help, v) in pool_counters {
        counter(&mut out, name, help, v);
    }
    counter(
        &mut out,
        "rrs_prefix_partial_hits_total",
        "Prefix hits ending inside a partial tail block.",
        c(&m.prefix_partial_hits),
    );
    gauge(
        &mut out,
        "rrs_prefix_hit_rate",
        "Fraction of probed prompt tokens served from the prefix cache.",
        m.prefix_hit_rate(),
    );

    // resident-lane counters (paged PJRT backend)
    let lane_counters: [(&str, &str, u64); 5] = [
        (
            "rrs_kv_gathers_total",
            "Full KV gathers into dense decode lanes.",
            c(&m.kv_gather_total),
        ),
        (
            "rrs_kv_scatter_rows_total",
            "KV rows scattered back to the paged pool.",
            c(&m.kv_scatter_rows_total),
        ),
        (
            "rrs_lane_refreshes_total",
            "Resident-lane refreshes (gather on lane miss).",
            c(&m.lane_refresh_total),
        ),
        (
            "rrs_resident_hits_total",
            "Decode steps served from resident lanes (no gather).",
            c(&m.resident_hits),
        ),
        (
            "rrs_decode_graph_calls_total",
            "PJRT decode graph invocations.",
            c(&m.decode_graph_calls),
        ),
    ];
    for (name, help, v) in lane_counters {
        counter(&mut out, name, help, v);
    }

    // kernel registry (non-forcing peek: a metrics scrape never runs
    // the autotune sweep itself)
    if let Some(ks) = kernels::stats_peek() {
        head(
            &mut out,
            "rrs_kernel_info",
            "gauge",
            "Live kernel backend and tile (value is always 1).",
        );
        let tile = ks.tiles.label();
        sample(
            &mut out,
            "rrs_kernel_info",
            &[("backend", ks.backend), ("tile", &tile)],
            1.0,
        );
        gauge(
            &mut out,
            "rrs_kernel_autotune_us",
            "Startup autotune sweep duration in microseconds.",
            ks.autotune_us as f64,
        );
        let kernel_counters: [(&str, &str, u64); 6] = [
            (
                "rrs_kernel_fused_gemm_calls_total",
                "Fused RRS GEMM dispatches.",
                ks.fused_gemm_calls,
            ),
            (
                "rrs_kernel_fused_gemm_rows_total",
                "Activation rows through the fused RRS GEMM.",
                ks.fused_gemm_rows,
            ),
            (
                "rrs_kernel_per_channel_calls_total",
                "Per-channel packed GEMM dispatches.",
                ks.per_channel_calls,
            ),
            (
                "rrs_kernel_igemm_calls_total",
                "Raw INT8 GEMM dispatches.",
                ks.igemm_calls,
            ),
            (
                "rrs_kernel_prologue_rows_total",
                "Activation rows through the fused RRS prologue.",
                ks.prologue_rows,
            ),
            (
                "rrs_kernel_fwht_rows_total",
                "Rows rotated by the dispatched FWHT.",
                ks.fwht_rows,
            ),
        ];
        for (name, help, v) in kernel_counters {
            counter(&mut out, name, help, v);
        }
    }

    // latency histograms
    for (name, help, h) in m.histograms() {
        histogram(&mut out, name, help, h);
    }

    // per-phase attribution histograms (present once scopes have fired)
    render_attrib(&mut out);

    // per-layer quant health (present once sampling has fired)
    render_health(&mut out);

    // watchdog alerts + SLO burn rates
    render_watchdog(&mut out);

    // trace ring
    counter(
        &mut out,
        "rrs_trace_events_total",
        "Lifecycle trace events recorded (including overwritten).",
        m.trace.total(),
    );
    counter(
        &mut out,
        "rrs_trace_events_dropped_total",
        "Trace events lost to ring wraparound.",
        m.trace.dropped(),
    );
    gauge(
        &mut out,
        "rrs_trace_ring_capacity",
        "Trace ring capacity in events.",
        m.trace.capacity() as f64,
    );
    out
}

/// The per-phase attribution histogram family: one `rrs_phase_ms`
/// histogram per phase that has fired, `phase`-labeled; the GEMM series
/// additionally carries the live kernel backend (one backend per
/// process), giving the gemm-per-backend decomposition.
fn render_attrib(out: &mut String) {
    let backend =
        kernels::stats_peek().map(|k| k.backend).unwrap_or("unresolved");
    let name = "rrs_phase_ms";
    let mut wrote_head = false;
    for (phase, h) in attrib::histograms() {
        if h.count() == 0 {
            continue;
        }
        if !wrote_head {
            head(
                out,
                name,
                "histogram",
                "Attributed per-scope self time by phase (ms).",
            );
            wrote_head = true;
        }
        let mut labels: Vec<(&str, &str)> = vec![("phase", phase.name())];
        if phase == attrib::Phase::Gemm {
            labels.push(("backend", backend));
        }
        histogram_series(out, name, &labels, h);
    }
    if attrib::finished_len() > 0 {
        gauge(
            out,
            "rrs_attrib_window",
            "Finished requests held in the attribution window.",
            attrib::finished_len() as f64,
        );
    }
}

/// Watchdog families: burn-rate gauges plus per-alert state/counters.
fn render_watchdog(out: &mut String) {
    let (ttft_burn, itl_burn) = watchdog::burn_rates();
    head(
        out,
        "rrs_slo_burn_rate",
        "gauge",
        "SLO error-budget burn rate over the rolling window (1 = at budget).",
    );
    sample(out, "rrs_slo_burn_rate", &[("slo", "ttft")], ttft_burn);
    sample(out, "rrs_slo_burn_rate", &[("slo", "itl")], itl_burn);
    let alerts = watchdog::alerts();
    if alerts.is_empty() {
        return;
    }
    head(
        out,
        "rrs_alerts_active",
        "gauge",
        "Watchdog alert state (1 = firing).",
    );
    for (k, a) in &alerts {
        let v = if a.active { 1.0 } else { 0.0 };
        sample(out, "rrs_alerts_active", &[("alert", k.as_str())], v);
    }
    head(
        out,
        "rrs_alerts_raised_total",
        "counter",
        "Raise edges per watchdog alert since process start.",
    );
    for (k, a) in &alerts {
        let v = a.raised_total as f64;
        sample(out, "rrs_alerts_raised_total", &[("alert", k.as_str())], v);
    }
}

/// The per-layer quant-health gauge families.
fn render_health(out: &mut String) {
    let layers = health::snapshot();
    if layers.is_empty() {
        return;
    }
    head(
        out,
        "rrs_quant_probes_total",
        "counter",
        "Quant-health probes recorded per layer.",
    );
    for (l, h) in &layers {
        sample(out, "rrs_quant_probes_total", &[("layer", l)], h.probes as f64);
    }
    head(
        out,
        "rrs_quant_channel_max",
        "gauge",
        "Peak channel-wise |activation| maximum (pre-smoothing).",
    );
    for (l, h) in &layers {
        let v = h.channel_max as f64;
        sample(out, "rrs_quant_channel_max", &[("layer", l)], v);
    }
    head(
        out,
        "rrs_quant_spike_ratio",
        "gauge",
        "Mean max/p99 ratio of the channel maxima (1 = flat).",
    );
    for (l, h) in &layers {
        let v = h.spike_ratio as f64;
        sample(out, "rrs_quant_spike_ratio", &[("layer", l)], v);
    }
    head(
        out,
        "rrs_quant_kurtosis",
        "gauge",
        "Mean activation kurtosis proxy m4/m2^2 (3 = Gaussian).",
    );
    for (l, h) in &layers {
        let v = h.kurtosis as f64;
        sample(out, "rrs_quant_kurtosis", &[("layer", l)], v);
    }
    head(
        out,
        "rrs_quant_clip_rate",
        "gauge",
        "Mean fraction of INT4 codes at saturation (|code| = 7).",
    );
    for (l, h) in &layers {
        let v = h.clip_rate as f64;
        sample(out, "rrs_quant_clip_rate", &[("layer", l)], v);
    }
}

fn head(out: &mut String, name: &str, ty: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {ty}");
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    head(out, name, "counter", help);
    sample(out, name, &[], v as f64);
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    head(out, name, "gauge", help);
    sample(out, name, &[], v);
}

/// One sample line, labels escaped per the exposition format.
fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], v: f64) {
    if labels.is_empty() {
        let _ = writeln!(out, "{name} {}", fmt_value(v));
        return;
    }
    let labs: Vec<String> = labels
        .iter()
        .map(|(k, val)| format!("{k}=\"{}\"", escape_label(val)))
        .collect();
    let _ = writeln!(out, "{name}{{{}}} {}", labs.join(","), fmt_value(v));
}

/// Integer-valued samples render without a fraction (counter idiom).
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Escape a label value per the text exposition format: backslash,
/// double-quote, and newline.
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            ch => out.push(ch),
        }
    }
    out
}

/// Render one histogram family: cumulative `_bucket{le}` lines (every
/// 4th native bucket edge), `+Inf`, `_sum`, `_count`.
fn histogram(out: &mut String, name: &str, help: &str, h: &LogHistogram) {
    head(out, name, "histogram", help);
    histogram_series(out, name, &[], h);
}

/// One labeled series of a histogram family (the head is the caller's:
/// multi-series families write it once, then one series per label set).
fn histogram_series(
    out: &mut String,
    name: &str,
    labels: &[(&str, &str)],
    h: &LogHistogram,
) {
    let bucket = format!("{name}_bucket");
    let mut with_le = |le: &str, v: f64| {
        let mut labs: Vec<(&str, &str)> = labels.to_vec();
        labs.push(("le", le));
        sample(out, &bucket, &labs, v);
    };
    for (edge, cum) in h.cumulative(4) {
        // round the geometric edge so the le label stays compact
        let le = (edge * 1e6).round() / 1e6;
        with_le(&fmt_value(le), cum as f64);
    }
    with_le("+Inf", h.count() as f64);
    sample(out, &format!("{name}_sum"), labels, h.sum_ms());
    sample(out, &format!("{name}_count"), labels, h.count() as f64);
}

/// Parse an exposition body into `(samples, malformed)`.
///
/// Each sample is `(series, value)` where `series` is the metric name
/// with its label set attached verbatim.  Comment (`#`) and blank lines
/// are skipped; lines that do not parse — missing value, non-numeric
/// value, empty or invalid metric name, unterminated label set — are
/// **counted** rather than panicking the consumer, so a scrape-side
/// check survives one corrupt line with an accurate tally instead of
/// dying on it.
pub fn parse_exposition(text: &str) -> (Vec<(String, f64)>, usize) {
    let mut samples = Vec::new();
    let mut malformed = 0usize;
    for line in text.lines() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((series, value)) = line.rsplit_once(' ') else {
            malformed += 1;
            continue;
        };
        let Ok(v) = value.parse::<f64>() else {
            malformed += 1;
            continue;
        };
        let name = series.split('{').next().unwrap_or("");
        let name_ok = !name.is_empty()
            && name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':');
        let labels_ok = !series.contains('{') || series.ends_with('}');
        if !name_ok || !labels_ok {
            malformed += 1;
            continue;
        }
        samples.push((series.to_string(), v));
    }
    (samples, malformed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
    }

    #[test]
    fn value_formatting() {
        assert_eq!(fmt_value(42.0), "42");
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(1.5), "1.5");
        assert_eq!(fmt_value(0.001585), "0.001585");
    }

    #[test]
    fn render_covers_core_families() {
        let m = Metrics::new();
        m.observe_completion(12.0, 2.0, 6);
        m.observe_ttft(3.5);
        m.observe_itl(0.8);
        let text = render(&m);
        for family in [
            "rrs_requests_completed_total",
            "rrs_requests_cancelled_total",
            "rrs_requests_deadline_missed_total",
            "rrs_tokens_streamed_total",
            "rrs_pool_blocks_total",
            "rrs_prefix_hit_rate",
            "rrs_request_latency_ms_bucket",
            "rrs_ttft_ms_sum",
            "rrs_itl_ms_count",
            "rrs_trace_ring_capacity",
        ] {
            assert!(text.contains(family), "missing {family}:\n{text}");
        }
        assert!(text.contains("le=\"+Inf\""));
        // every non-comment line is `name[{labels}] value`
        let (samples, malformed) = parse_exposition(&text);
        assert_eq!(malformed, 0, "renderer emitted malformed lines:\n{text}");
        assert!(!samples.is_empty());
        assert!(samples
            .iter()
            .any(|(s, _)| s.starts_with("rrs_slo_burn_rate{slo=\"ttft\"}")));
    }

    #[test]
    fn parse_exposition_skips_and_counts_malformed() {
        let body = "# HELP x y\n\
                    # TYPE x counter\n\
                    x 3\n\
                    x{a=\"b\"} 4.5\n\
                    \n\
                    garbage-line\n\
                    bad name 1\n\
                    x{unterminated=\"b\" 2\n\
                    x notanumber\n";
        let (samples, malformed) = parse_exposition(body);
        assert_eq!(
            samples,
            vec![("x".to_string(), 3.0), ("x{a=\"b\"}".to_string(), 4.5)]
        );
        // garbage-line (no space-separated value), "bad name" (space in
        // the metric name), unterminated labels, non-numeric value:
        // counted, not fatal
        assert_eq!(malformed, 4);
    }
}
