//! Unified observability layer: log-scale latency histograms, request
//! lifecycle tracing, Prometheus exposition, and sampled quant-health
//! probes.
//!
//! * [`hist`] — fixed-memory lock-free log-scale histograms (replace the
//!   coordinator's unbounded latency reservoirs);
//! * [`trace`] — bounded per-request span ring, Chrome `trace_event`
//!   export (`trace` TCP command);
//! * [`prom`] — Prometheus text exposition 0.0.4 renderer
//!   (`metrics_prom` TCP command);
//! * [`health`] — sampled per-layer quantization-health probes
//!   (channel-max, spike ratio, kurtosis, INT4 clip rate);
//! * [`attrib`] — per-request phase attribution: thread-local phase
//!   scopes decompose each request's wall time into
//!   queue / prefill / kv-gather / gemm / sampling / stream-write
//!   (`attrib` TCP command);
//! * [`profile`] — continuous sampling profiler over the live phase
//!   stacks, folded-stack export (`RRS_PROF_HZ`, `profile` TCP
//!   command);
//! * [`watchdog`] — SLO burn-rate alerts over TTFT/ITL plus EWMA drift
//!   detection on the per-layer quant-health probes (`rrs_alerts_*`
//!   Prometheus families, `alerts` snapshot section).
//!
//! # Sampling (`RRS_OBS_SAMPLE`)
//!
//! Probes and per-decode-step trace spans ride the serving hot path, so
//! they are **sampled**: `RRS_OBS_SAMPLE` is a rate in `[0, 1]` (`0` /
//! unset = off, `1` = every call, `0.0625` = every 16th call).  The rate
//! is resolved to an integer period once and shared process-wide; each
//! call site then pays one relaxed atomic increment when sampling is
//! active and a single atomic load when it is off — the measured
//! obs-off overhead budget (`rust/benches/obs_overhead.rs` →
//! `BENCH_obs.json`) is "within run-to-run noise".
//!
//! Lifecycle events (enqueue/admit/prefill/finish/preempt/abort) and
//! histogram observations are per-request, not per-step, and are always
//! on.

pub mod attrib;
pub mod health;
pub mod hist;
pub mod profile;
pub mod prom;
pub mod trace;
pub mod watchdog;

use std::cell::RefCell;
// ORDERING: the process-wide sampling PERIOD is a config cell, not a
// synchronization point — readers only need *some* recent value (a
// stale period mis-samples a handful of calls, nothing more), so every
// access in this module is intentionally Relaxed.
use std::sync::atomic::{AtomicU64, Ordering};

// Poison-recovering lock helper; lives in `util::sync` so it follows
// the std/loom primitive switch, re-exported here because obs was its
// historical home and every serving module already imports it from obs.
pub use crate::util::sync::lock_recover;

/// Sentinel: `RRS_OBS_SAMPLE` not parsed yet.
const UNRESOLVED: u64 = u64::MAX;

/// Process-wide sampling period: 0 = off, n = every nth call.
static PERIOD: AtomicU64 = AtomicU64::new(UNRESOLVED);

fn rate_to_period(rate: f64) -> u64 {
    if !rate.is_finite() || rate <= 0.0 {
        0
    } else if rate >= 1.0 {
        1
    } else {
        (1.0 / rate).round() as u64
    }
}

fn period() -> u64 {
    let p = PERIOD.load(Ordering::Relaxed);
    if p != UNRESOLVED {
        return p;
    }
    let parsed = std::env::var("RRS_OBS_SAMPLE")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok())
        .map(rate_to_period)
        .unwrap_or(0);
    // first resolver wins; a racing set_sample_* call is preserved
    let _ = PERIOD.compare_exchange(
        UNRESOLVED,
        parsed,
        Ordering::Relaxed,
        Ordering::Relaxed,
    );
    PERIOD.load(Ordering::Relaxed)
}

/// Set the sampling rate programmatically (overrides `RRS_OBS_SAMPLE`;
/// tests and benches use this instead of racing on the environment).
pub fn set_sample_rate(rate: f64) {
    PERIOD.store(rate_to_period(rate), Ordering::Relaxed);
}

/// Set the sampling period directly: 0 = off, n = every nth call.
pub fn set_sample_every(n: u64) {
    PERIOD.store(n.min(UNRESOLVED - 1), Ordering::Relaxed);
}

/// The resolved sampling period (0 = off).
pub fn sample_period() -> u64 {
    period()
}

/// A call-site sampling counter over the process-wide period: `hit()`
/// is true on every `period()`th call, false always when sampling is
/// off.  Each hot call site owns one so interleaved sites keep their
/// own cadence.
pub struct Sampler {
    counter: AtomicU64,
}

impl Sampler {
    pub const fn new() -> Sampler {
        Sampler { counter: AtomicU64::new(0) }
    }

    /// Should this call pay for observability work?
    #[inline]
    pub fn hit(&self) -> bool {
        let p = period();
        if p == 0 {
            return false;
        }
        self.counter.fetch_add(1, Ordering::Relaxed) % p == 0
    }
}

impl Default for Sampler {
    fn default() -> Self {
        Sampler::new()
    }
}

thread_local! {
    /// Layer label the current thread is executing under (probe keying).
    static LAYER: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// RAII guard restoring the previous thread-local layer label on drop.
pub struct LayerScope {
    prev: Option<String>,
}

/// Install `label` as the current thread's layer label for the duration
/// of the returned guard ([`crate::quant::qlinear::QLinear::forward`]
/// wraps itself in one, so probes fired from nested kernel code land on
/// the right per-layer bucket).  `None` leaves the outer label intact.
pub fn layer_scope(label: Option<&str>) -> LayerScope {
    let prev = match label {
        Some(l) => LAYER.with(|s| {
            s.borrow_mut().replace(l.to_string())
        }),
        None => LAYER.with(|s| s.borrow().clone()),
    };
    LayerScope { prev }
}

impl Drop for LayerScope {
    fn drop(&mut self) {
        let prev = self.prev.take();
        LAYER.with(|s| *s.borrow_mut() = prev);
    }
}

/// The current thread's layer label, or `fallback` if none is set.
pub fn current_layer_or(fallback: &str) -> String {
    LAYER.with(|s| s.borrow().clone()).unwrap_or_else(|| fallback.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_resolves_to_period() {
        assert_eq!(rate_to_period(0.0), 0);
        assert_eq!(rate_to_period(-1.0), 0);
        assert_eq!(rate_to_period(f64::NAN), 0);
        assert_eq!(rate_to_period(1.0), 1);
        assert_eq!(rate_to_period(2.0), 1);
        assert_eq!(rate_to_period(0.5), 2);
        assert_eq!(rate_to_period(0.0625), 16);
    }

    #[test]
    fn layer_scope_nests_and_restores() {
        let _outer = layer_scope(Some("outer"));
        assert_eq!(current_layer_or("x"), "outer");
        {
            let _inner = layer_scope(Some("inner"));
            assert_eq!(current_layer_or("x"), "inner");
            {
                // None keeps the enclosing label
                let _keep = layer_scope(None);
                assert_eq!(current_layer_or("x"), "inner");
            }
            assert_eq!(current_layer_or("x"), "inner");
        }
        assert_eq!(current_layer_or("x"), "outer");
        drop(_outer);
        assert_eq!(current_layer_or("fallback"), "fallback");
    }

    #[test]
    fn sampler_period_cadence() {
        // programmatic override: global, so this test owns period 4
        // briefly; other tests in this binary never assert on cadence
        set_sample_every(4);
        let s = Sampler::new();
        let hits: Vec<bool> = (0..8).map(|_| s.hit()).collect();
        assert_eq!(hits, vec![true, false, false, false, true, false, false, false]);
        set_sample_every(0);
        assert!(!s.hit());
        assert_eq!(sample_period(), 0);
    }
}
