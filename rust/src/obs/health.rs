//! Sampled quantization-health probes: the runtime activation statistics
//! the paper's whole argument rests on, measured in production instead of
//! offline.  Each probe of a layer's pre-quantization activation `x` and
//! its INT4 codes `q` records:
//!
//! * **channel_max** — `max_j max_i |X_ij|`, the magnitude of the worst
//!   channel outlier (eq. 1's `s_j` peak; what Runtime Smooth divides by);
//! * **spike_ratio** — `max(s) / p99(s)` over the channel maxima: ≈1 for
//!   flat channels, large when a few channels spike (Fig. 2's outlier
//!   taxonomy — this is the statistic rotation alone cannot fix);
//! * **kurtosis** — excess-free kurtosis proxy `m4/m2²` over all of `x`:
//!   ≈3 for Gaussian (well-rotated) activations, large for heavy tails —
//!   the post-rotation flatness check;
//! * **clip_rate** — fraction of INT4 codes at saturation (|code| = 7):
//!   direct evidence of quantizer overload.
//!
//! Probes are gated by the process-wide [`crate::obs`] sampler
//! (`RRS_OBS_SAMPLE`), keyed by the layer label installed via
//! [`crate::obs::layer_scope`] (the model assembler tags each
//! [`crate::quant::qlinear::QLinear`] as `l{i}.wq` etc.), and aggregated
//! into a bounded per-layer registry exported through the metrics
//! snapshot and Prometheus exposition.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::linalg::gemm::Mat;
use crate::linalg::igemm::MatI8;
use crate::quant::runtime_smooth;
use crate::util::json::{obj, Json};
use crate::util::stats;

use super::{lock_recover, Sampler};

/// Cap on distinct layer labels (a runaway label source must not turn
/// the registry into the unbounded-memory bug this PR removes).
const MAX_LAYERS: usize = 512;

static SAMPLER: Sampler = Sampler::new();

/// True when this call site should pay for a probe (sampled; false when
/// `RRS_OBS_SAMPLE` is unset or 0).
#[inline]
pub fn sampled() -> bool {
    SAMPLER.hit()
}

#[derive(Clone, Debug, Default)]
struct Agg {
    probes: u64,
    channel_max_peak: f32,
    spike_sum: f64,
    kurt_sum: f64,
    clip_sum: f64,
}

fn registry() -> &'static Mutex<BTreeMap<String, Agg>> {
    static REG: OnceLock<Mutex<BTreeMap<String, Agg>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Aggregated health of one layer label (peak channel-max, mean of the
/// other statistics over all probes).
#[derive(Clone, Copy, Debug)]
pub struct LayerHealth {
    pub probes: u64,
    pub channel_max: f32,
    pub spike_ratio: f32,
    pub kurtosis: f32,
    pub clip_rate: f32,
}

/// Probe one (activation, INT4 codes) pair under `layer`.  The caller
/// decides *whether* to pay for this via [`sampled`]; the probe itself
/// is two passes over `x` plus one over `q` (O(rows·cols), no
/// allocation beyond the channel-scale vector).
pub fn probe_quant(layer: &str, x: &Mat, q: &MatI8) {
    probe_quant_q(layer, x, q, crate::quant::QMAX);
}

/// [`probe_quant`] with the quantizer's symmetric max code made explicit
/// (7 for INT4, 127 for W4A8 activations), so the clip-rate statistic
/// counts saturation against the range the codes were actually clamped
/// to instead of assuming INT4.
pub fn probe_quant_q(layer: &str, x: &Mat, q: &MatI8, qmax: f32) {
    if x.data.is_empty() || q.data.is_empty() {
        return;
    }
    let s = runtime_smooth::channel_scales(x);
    let channel_max = s.iter().fold(0.0f32, |a, &v| a.max(v));
    let p99 = stats::percentile(&s, 99.0).max(1e-8);
    let spike_ratio = (channel_max / p99).max(1.0);
    let n = x.data.len() as f64;
    let mean = x.data.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mut m2 = 0.0f64;
    let mut m4 = 0.0f64;
    for &v in &x.data {
        let d = v as f64 - mean;
        let d2 = d * d;
        m2 += d2;
        m4 += d2 * d2;
    }
    m2 /= n;
    m4 /= n;
    let kurtosis = if m2 > 1e-24 { (m4 / (m2 * m2)) as f32 } else { 0.0 };
    let qmax_code = qmax as u32;
    let clipped =
        q.data.iter().filter(|c| c.unsigned_abs() as u32 >= qmax_code).count();
    let clip_rate = clipped as f32 / q.data.len() as f32;
    record(layer, channel_max, spike_ratio, kurtosis, clip_rate);
}

fn record(layer: &str, channel_max: f32, spike: f32, kurt: f32, clip: f32) {
    // every sampled probe also feeds the drift watchdog's EWMAs
    super::watchdog::observe_quant(layer, spike, kurt, clip);
    let mut map = lock_recover(registry());
    if !map.contains_key(layer) && map.len() >= MAX_LAYERS {
        return;
    }
    let a = map.entry(layer.to_string()).or_default();
    a.probes += 1;
    a.channel_max_peak = a.channel_max_peak.max(channel_max);
    a.spike_sum += spike as f64;
    a.kurt_sum += kurt as f64;
    a.clip_sum += clip as f64;
}

/// Per-layer aggregates, sorted by label.
pub fn snapshot() -> Vec<(String, LayerHealth)> {
    let map = lock_recover(registry());
    map.iter()
        .map(|(k, a)| {
            let n = a.probes.max(1) as f64;
            (
                k.clone(),
                LayerHealth {
                    probes: a.probes,
                    channel_max: a.channel_max_peak,
                    spike_ratio: (a.spike_sum / n) as f32,
                    kurtosis: (a.kurt_sum / n) as f32,
                    clip_rate: (a.clip_sum / n) as f32,
                },
            )
        })
        .collect()
}

/// JSON object keyed by layer label (the `quant_health` section of the
/// metrics snapshot; empty object when sampling is off).
pub fn snapshot_json() -> Json {
    Json::Obj(
        snapshot()
            .into_iter()
            .map(|(name, h)| {
                (
                    name,
                    obj(vec![
                        ("probes", (h.probes as usize).into()),
                        ("channel_max", (h.channel_max as f64).into()),
                        ("spike_ratio", (h.spike_ratio as f64).into()),
                        ("kurtosis", (h.kurtosis as f64).into()),
                        ("clip_rate", (h.clip_rate as f64).into()),
                    ]),
                )
            })
            .collect(),
    )
}

/// Clear all per-layer aggregates (tests / benches).
pub fn reset() {
    lock_recover(registry()).clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn;
    use crate::util::rng::Pcg;

    fn probe_mat(label: &str, x: &Mat) {
        let (q, _s) = rtn::quant_per_token(x);
        probe_quant(label, x, &q);
    }

    #[test]
    fn spike_and_clip_detected() {
        let mut rng = Pcg::new(77);
        // 256 channels so p99 of the channel maxima excludes the single
        // spiking channel (1/256 < 1%)
        let mut x = Mat::from_vec(8, 256, rng.normal_vec(8 * 256));
        for i in 0..8 {
            x.data[i * 256 + 5] = 300.0; // one spiking channel
        }
        probe_mat("obs-health-spiky", &x);
        let snap = snapshot();
        let (_, h) = snap
            .iter()
            .find(|(k, _)| k == "obs-health-spiky")
            .expect("layer recorded");
        assert_eq!(h.probes, 1);
        assert!(h.channel_max >= 300.0, "channel_max {}", h.channel_max);
        assert!(h.spike_ratio > 5.0, "spike_ratio {}", h.spike_ratio);
        // per-token RTN against a 300x spike clips the spike channel only:
        // a low but nonzero saturation rate
        assert!(h.clip_rate > 0.0 && h.clip_rate < 0.5, "clip {}", h.clip_rate);
        assert!(h.kurtosis > 3.0, "spiky input must be heavy-tailed");
    }

    #[test]
    fn gaussian_input_is_flat() {
        let mut rng = Pcg::new(78);
        let x = Mat::from_vec(16, 128, rng.normal_vec(16 * 128));
        probe_mat("obs-health-flat", &x);
        let snap = snapshot();
        let (_, h) = snap
            .iter()
            .find(|(k, _)| k == "obs-health-flat")
            .expect("layer recorded");
        assert!(h.kurtosis > 2.0 && h.kurtosis < 4.5, "kurt {}", h.kurtosis);
        assert!(h.spike_ratio < 2.0, "spike_ratio {}", h.spike_ratio);
    }

    #[test]
    fn aggregates_average_over_probes() {
        let mut rng = Pcg::new(79);
        let x = Mat::from_vec(4, 32, rng.normal_vec(4 * 32));
        probe_mat("obs-health-agg", &x);
        probe_mat("obs-health-agg", &x);
        let snap = snapshot();
        let (_, h) = snap
            .iter()
            .find(|(k, _)| k == "obs-health-agg")
            .expect("layer recorded");
        assert_eq!(h.probes, 2);
        let j = snapshot_json();
        let lj = j.get("obs-health-agg").unwrap();
        assert_eq!(lj.get("probes").unwrap().as_usize(), Some(2));
        assert!(lj.get("clip_rate").unwrap().as_f64().is_some());
    }

    #[test]
    fn clip_rate_respects_qmax() {
        // codes pinned at ±7 are saturated for an INT4 quantizer but
        // mid-range for INT8 — the probe must use the caller's range
        let x = Mat::from_vec(1, 8, vec![1.0; 8]);
        let q = MatI8::from_vec(1, 8, vec![7i8; 8]);
        probe_quant_q("obs-health-clip4", &x, &q, 7.0);
        probe_quant_q("obs-health-clip8", &x, &q, 127.0);
        let snap = snapshot();
        let h4 = &snap.iter().find(|(k, _)| k == "obs-health-clip4").unwrap().1;
        let h8 = &snap.iter().find(|(k, _)| k == "obs-health-clip8").unwrap().1;
        assert_eq!(h4.clip_rate, 1.0);
        assert_eq!(h8.clip_rate, 0.0);
    }

    #[test]
    fn empty_inputs_are_ignored() {
        let before = snapshot().len();
        probe_quant("obs-health-empty", &Mat::zeros(0, 0), &MatI8::zeros(0, 0));
        assert_eq!(snapshot().len(), before);
    }
}
