//! Per-request span tracing: a bounded ring of lifecycle events
//! (`enqueue → admit → prefill → decode-step* → finish/preempt/abort`)
//! with monotonic timestamps, exportable in Chrome `trace_event` format
//! (load the JSON in `chrome://tracing` / Perfetto; one track per
//! request id).
//!
//! The ring is fixed-capacity: when full, the oldest event is
//! overwritten and `dropped` is incremented, so a long-running server
//! keeps the most recent window at O(1) memory.  Lifecycle events
//! (enqueue/admit/prefill/finish/preempt/abort) are always recorded;
//! per-decode-step spans go through the coordinator's sampler so the
//! decode hot loop stays within the observability overhead budget
//! (`RRS_OBS_SAMPLE`, see [`crate::obs`]).

use std::time::Instant;

use crate::util::sync::Mutex;

use crate::util::json::{obj, Json};

use super::lock_recover;

/// Default ring capacity (events).
pub const DEFAULT_CAPACITY: usize = 4096;

/// Request-lifecycle event kinds, in span order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Request accepted into the public queue.
    Enqueue,
    /// Popped from the queue into the active set (duration = queue wait).
    Admit,
    /// Prompt prefill (duration = prefill compute this admission).
    Prefill,
    /// One batched decode step this request took part in (sampled).
    DecodeStep,
    /// Response sent (tokens = generated length).
    Finish,
    /// Preempted back to the queue on pool exhaustion.
    Preempt,
    /// Aborted (capacity can never fit the request).
    Abort,
    /// Per-request attributed phase time ([`crate::obs::attrib`]); the
    /// scheduler emits one per nonzero phase at request finish.
    Phase(super::attrib::Phase),
    /// A watchdog alert raised (`req` = the alert's stable trace id).
    AlertRaise,
    /// A watchdog alert cleared (`req` = the alert's stable trace id).
    AlertClear,
}

impl SpanKind {
    /// Chrome trace event name.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::Admit => "admit",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::Finish => "finish",
            SpanKind::Preempt => "preempt",
            SpanKind::Abort => "abort",
            SpanKind::Phase(p) => p.span_name(),
            SpanKind::AlertRaise => "alert_raise",
            SpanKind::AlertClear => "alert_clear",
        }
    }
}

/// One recorded span: timestamps are µs since the ring's epoch.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub req: u64,
    pub kind: SpanKind,
    /// Span start, µs since ring creation (monotonic clock).
    pub ts_us: u64,
    /// Span duration in µs (0 for instant events).
    pub dur_us: u64,
    /// Tokens involved (prompt len, generated len, or step size).
    pub tokens: u64,
}

struct RingInner {
    buf: Vec<TraceEvent>,
    /// Next overwrite position once the buffer is full.
    head: usize,
    total: u64,
}

/// Bounded ring buffer of [`TraceEvent`]s (thread-safe).
///
/// # Examples
///
/// ```
/// use rrs::obs::trace::{SpanKind, TraceRing};
///
/// let ring = TraceRing::new(8);
/// ring.instant(1, SpanKind::Enqueue, 5);
/// ring.span(1, SpanKind::Prefill, 1200, 5);
/// assert_eq!(ring.len(), 2);
/// let jsonl = ring.chrome_trace_jsonl();
/// assert_eq!(jsonl.lines().count(), 2);
/// ```
pub struct TraceRing {
    epoch: Instant,
    cap: usize,
    inner: Mutex<RingInner>,
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new(DEFAULT_CAPACITY)
    }
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        let cap = capacity.max(1);
        TraceRing {
            epoch: Instant::now(),
            cap,
            inner: Mutex::new(RingInner {
                buf: Vec::with_capacity(cap.min(1024)),
                head: 0,
                total: 0,
            }),
        }
    }

    /// µs since the ring's epoch (the trace timebase).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record a span that just ended, lasting `dur_us`.
    pub fn span(&self, req: u64, kind: SpanKind, dur_us: u64, tokens: u64) {
        let ts_us = self.now_us().saturating_sub(dur_us);
        self.push(TraceEvent { req, kind, ts_us, dur_us, tokens });
    }

    /// Record an instantaneous event happening now.
    pub fn instant(&self, req: u64, kind: SpanKind, tokens: u64) {
        self.span(req, kind, 0, tokens);
    }

    fn push(&self, e: TraceEvent) {
        let mut g = lock_recover(&self.inner);
        if g.buf.len() < self.cap {
            g.buf.push(e);
        } else {
            let h = g.head;
            g.buf[h] = e;
            g.head = (h + 1) % self.cap;
        }
        g.total += 1;
    }

    /// Events currently held, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let g = lock_recover(&self.inner);
        let mut out = Vec::with_capacity(g.buf.len());
        out.extend_from_slice(&g.buf[g.head..]);
        out.extend_from_slice(&g.buf[..g.head]);
        out
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        lock_recover(&self.inner).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events ever recorded (including overwritten ones).
    pub fn total(&self) -> u64 {
        lock_recover(&self.inner).total
    }

    /// Events lost to ring wraparound.
    pub fn dropped(&self) -> u64 {
        let g = lock_recover(&self.inner);
        g.total - g.buf.len() as u64
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Full Chrome `trace_event` document: `{"traceEvents": [...]}`.
    pub fn chrome_trace_json(&self) -> Json {
        let events: Vec<Json> =
            self.events().iter().map(chrome_event_json).collect();
        obj(vec![("traceEvents", Json::Arr(events))])
    }

    /// Chrome trace events as JSONL (one complete event per line) — the
    /// shape the coordinator's `trace` TCP command streams.
    pub fn chrome_trace_jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.events() {
            out.push_str(&chrome_event_json(&e).dump());
            out.push('\n');
        }
        out
    }
}

/// One event as a Chrome "complete" (`ph: "X"`) trace record: `ts`/`dur`
/// in µs, one `tid` track per request id.
fn chrome_event_json(e: &TraceEvent) -> Json {
    obj(vec![
        ("name", e.kind.name().into()),
        ("cat", "rrs".into()),
        ("ph", "X".into()),
        ("ts", (e.ts_us as usize).into()),
        ("dur", (e.dur_us as usize).into()),
        ("pid", 1usize.into()),
        ("tid", (e.req as usize).into()),
        ("args", obj(vec![("tokens", (e.tokens as usize).into())])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_holds_and_orders_events() {
        let r = TraceRing::new(16);
        for i in 0..5u64 {
            r.instant(i, SpanKind::Enqueue, i);
        }
        let ev = r.events();
        assert_eq!(ev.len(), 5);
        assert_eq!(r.total(), 5);
        assert_eq!(r.dropped(), 0);
        for (i, e) in ev.iter().enumerate() {
            assert_eq!(e.req, i as u64);
        }
        // timestamps monotonic
        for w in ev.windows(2) {
            assert!(w[1].ts_us >= w[0].ts_us);
        }
    }

    #[test]
    fn wraparound_keeps_most_recent() {
        let r = TraceRing::new(8);
        for i in 0..20u64 {
            r.instant(i, SpanKind::Finish, 0);
        }
        assert_eq!(r.len(), 8);
        assert_eq!(r.capacity(), 8);
        assert_eq!(r.total(), 20);
        assert_eq!(r.dropped(), 12);
        let ev = r.events();
        // oldest surviving event is #12, newest is #19, in order
        let ids: Vec<u64> = ev.iter().map(|e| e.req).collect();
        assert_eq!(ids, (12..20).collect::<Vec<u64>>());
    }

    #[test]
    fn chrome_export_shape() {
        let r = TraceRing::new(8);
        r.instant(3, SpanKind::Enqueue, 4);
        r.span(3, SpanKind::Prefill, 250, 4);
        let doc = r.chrome_trace_json();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let e = &events[1];
        assert_eq!(e.get("name").unwrap().as_str(), Some("prefill"));
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("dur").unwrap().as_usize(), Some(250));
        assert_eq!(e.get("tid").unwrap().as_usize(), Some(3));
        assert_eq!(
            e.get("args").unwrap().get("tokens").unwrap().as_usize(),
            Some(4)
        );
        // JSONL round-trips line by line
        for line in r.chrome_trace_jsonl().lines() {
            let v = Json::parse(line).unwrap();
            assert!(v.get("ts").is_some());
        }
    }

    #[test]
    fn span_start_precedes_now() {
        let r = TraceRing::new(4);
        r.span(1, SpanKind::Admit, 1_000_000, 0); // 1 s span
        let e = r.events()[0];
        assert!(e.ts_us + e.dur_us <= r.now_us() + 1_000);
    }
}

/// Loom model: concurrent pushes into a full ring must keep the
/// `total`/`len`/`dropped` accounting coherent in every interleaving —
/// the `dropped()` subtraction must never underflow and the buffer must
/// never exceed capacity.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::{SpanKind, TraceRing};
    use loom::thread;
    use std::sync::Arc;

    #[test]
    fn concurrent_push_accounting_is_coherent() {
        loom::model(|| {
            let r = Arc::new(TraceRing::new(2));
            let a = Arc::clone(&r);
            let b = Arc::clone(&r);
            let t1 = thread::spawn(move || {
                a.instant(1, SpanKind::Enqueue, 0);
                a.instant(1, SpanKind::Finish, 0);
            });
            let t2 = thread::spawn(move || b.instant(2, SpanKind::Enqueue, 0));
            t1.join().unwrap();
            t2.join().unwrap();
            assert_eq!(r.total(), 3);
            assert_eq!(r.len(), 2);
            assert_eq!(r.dropped(), 1);
            assert_eq!(r.events().len(), 2);
        });
    }
}
