//! Fixed-bucket log-scale latency histograms (lock-free).
//!
//! Replaces the coordinator's unbounded `Mutex<Vec<f32>>` latency
//! reservoirs: a [`LogHistogram`] is a fixed 160-slot array of atomic
//! counters covering `[1 µs, 100 s)` in geometric buckets (20 per
//! decade, ratio `10^(1/20) ≈ 1.122`), so memory is O(1) regardless of
//! how many requests a server retires and `observe` is a handful of
//! relaxed atomic adds — safe to call from the scheduler hot loop with
//! no lock on the snapshot path (a poisoned-mutex cannot take the stats
//! endpoint down because there is no mutex).
//!
//! Quantile queries interpolate geometrically inside the landing bucket
//! and clamp to the exact observed `[min, max]`, which bounds the
//! relative error of any percentile by one bucket ratio (~12%); the
//! error bound is locked in by `rust/tests/obs.rs` against the exact
//! sort-based [`crate::util::stats::percentile`].

use crate::util::stats::Summary;
use crate::util::sync::{
    fetch_max_u32, fetch_min_u32, AtomicU32, AtomicU64, Ordering,
};

/// Lower edge of bucket 0 in milliseconds (1 µs).
pub const LO_MS: f64 = 1e-3;
/// Geometric buckets per decade.
pub const PER_DECADE: usize = 20;
/// Decades covered: `[1 µs, 100 s)`; out-of-range values clamp to the
/// end buckets (and the min/max clamp keeps their quantiles honest).
pub const DECADES: usize = 8;
/// Total bucket count.
pub const NBUCKETS: usize = PER_DECADE * DECADES;

/// Lock-free fixed-memory log-scale histogram of millisecond latencies.
///
/// # Examples
///
/// ```
/// use rrs::obs::hist::LogHistogram;
///
/// let h = LogHistogram::new();
/// for _ in 0..100 {
///     h.observe(5.0);
/// }
/// assert_eq!(h.count(), 100);
/// assert_eq!(h.quantile(0.5), 5.0); // clamped to observed min == max
/// ```
pub struct LogHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum in integer microseconds (atomic f32 adds don't exist; µs
    /// resolution keeps the mean honest for any realistic latency).
    sum_us: AtomicU64,
    /// Observed min/max as f32 bit patterns: for non-negative floats the
    /// IEEE-754 bit order matches the numeric order, so atomic integer
    /// `fetch_min`/`fetch_max` maintain them without a lock.
    min_bits: AtomicU32,
    max_bits: AtomicU32,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

// ORDERING: every cell in a histogram is an independent monotone
// statistic (bucket counters, count, sum, min/max bits); snapshot
// readers tolerate a view torn across cells (quantiles are already
// bucket-approximate), so all accesses are Relaxed — there is no
// cross-cell invariant to publish.
impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            min_bits: AtomicU32::new(f32::INFINITY.to_bits()),
            max_bits: AtomicU32::new(0.0f32.to_bits()),
        }
    }

    /// Bucket index for a value in ms (clamped to the covered range).
    pub fn bucket_index(ms: f32) -> usize {
        let v = ms as f64;
        if v.is_nan() || v <= LO_MS {
            return 0;
        }
        let idx = ((v / LO_MS).log10() * PER_DECADE as f64).floor() as isize;
        idx.clamp(0, NBUCKETS as isize - 1) as usize
    }

    /// Lower edge of bucket `i` in ms.
    pub fn lower_edge(i: usize) -> f64 {
        LO_MS * 10f64.powf(i as f64 / PER_DECADE as f64)
    }

    /// Upper edge of bucket `i` in ms.
    pub fn upper_edge(i: usize) -> f64 {
        LO_MS * 10f64.powf((i + 1) as f64 / PER_DECADE as f64)
    }

    /// Record one latency in ms.  Negative / non-finite values count as 0.
    #[inline]
    pub fn observe(&self, ms: f32) {
        let v = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us
            .fetch_add((v as f64 * 1000.0).round() as u64, Ordering::Relaxed);
        fetch_min_u32(&self.min_bits, v.to_bits());
        fetch_max_u32(&self.max_bits, v.to_bits());
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations in ms.
    pub fn sum_ms(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Smallest observed value (0 when empty).
    pub fn min(&self) -> f32 {
        if self.count() == 0 {
            return 0.0;
        }
        f32::from_bits(self.min_bits.load(Ordering::Relaxed))
    }

    /// Largest observed value (0 when empty).
    pub fn max(&self) -> f32 {
        if self.count() == 0 {
            return 0.0;
        }
        f32::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Interpolated quantile (`q` in [0,1]); geometric within the landing
    /// bucket, clamped to the exact observed `[min, max]`.
    pub fn quantile(&self, q: f64) -> f32 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        // 0-based rank, matching util::stats::percentile's convention
        let rank = q.clamp(0.0, 1.0) * (n as f64 - 1.0);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 > rank {
                let frac =
                    ((rank - cum as f64 + 0.5) / c as f64).clamp(0.0, 1.0);
                let lo = Self::lower_edge(i);
                let hi = Self::upper_edge(i);
                let est = (lo * (hi / lo).powf(frac)) as f32;
                return est.clamp(self.min(), self.max());
            }
            cum += c;
        }
        self.max()
    }

    /// Percentile (`p` in [0,100]) — convenience mirror of
    /// [`crate::util::stats::percentile`].
    pub fn percentile(&self, p: f32) -> f32 {
        self.quantile(p as f64 / 100.0)
    }

    /// [`Summary`]-shaped snapshot: the drop-in replacement for
    /// `Summary::of(&reservoir)` on the old unbounded Vec reservoirs.
    pub fn summary(&self) -> Summary {
        let n = self.count();
        if n == 0 {
            return Summary::default();
        }
        Summary {
            n: n as usize,
            mean: (self.sum_ms() / n as f64) as f32,
            p10: self.quantile(0.10),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            min: self.min(),
            max: self.max(),
        }
    }

    /// Cumulative `(upper_edge_ms, count_at_or_below)` pairs for
    /// Prometheus exposition, merging `stride` native buckets per
    /// exported `le` bucket (stride 4 → 40 exported buckets).
    pub fn cumulative(&self, stride: usize) -> Vec<(f64, u64)> {
        let stride = stride.max(1);
        let mut out = Vec::with_capacity(NBUCKETS / stride + 1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if (i + 1) % stride == 0 || i + 1 == NBUCKETS {
                out.push((Self::upper_edge(i), cum));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_are_monotone_and_cover_range() {
        for i in 0..NBUCKETS {
            assert!(LogHistogram::upper_edge(i) > LogHistogram::lower_edge(i));
            if i > 0 {
                let prev = LogHistogram::upper_edge(i - 1);
                let lo = LogHistogram::lower_edge(i);
                assert!((prev - lo).abs() / lo < 1e-9, "bucket {i} gap");
            }
        }
        assert!((LogHistogram::lower_edge(0) - LO_MS).abs() < 1e-12);
        assert!(LogHistogram::upper_edge(NBUCKETS - 1) > 1e4); // > 10 s
    }

    #[test]
    fn bucket_index_respects_edges() {
        for i in 0..NBUCKETS {
            // geometric midpoint is safely inside bucket i
            let mid = (LogHistogram::lower_edge(i)
                * LogHistogram::upper_edge(i))
            .sqrt();
            assert_eq!(LogHistogram::bucket_index(mid as f32), i, "bucket {i}");
        }
        assert_eq!(LogHistogram::bucket_index(0.0), 0);
        assert_eq!(LogHistogram::bucket_index(-5.0), 0);
        assert_eq!(LogHistogram::bucket_index(1e9), NBUCKETS - 1);
    }

    #[test]
    fn single_value_quantiles_are_exact() {
        let h = LogHistogram::new();
        for _ in 0..1000 {
            h.observe(3.7);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.quantile(0.0), 3.7);
        assert_eq!(h.quantile(0.5), 3.7);
        assert_eq!(h.quantile(0.99), 3.7);
        assert_eq!(h.min(), 3.7);
        assert_eq!(h.max(), 3.7);
        assert!((h.summary().mean - 3.7).abs() < 1e-3);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.summary().n, 0);
    }

    #[test]
    fn cumulative_reaches_count() {
        let h = LogHistogram::new();
        for i in 0..500 {
            h.observe(0.1 + i as f32);
        }
        let cum = h.cumulative(4);
        assert_eq!(cum.len(), NBUCKETS / 4);
        assert_eq!(cum.last().unwrap().1, 500);
        // cumulative counts are non-decreasing
        for w in cum.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
    }

    #[test]
    fn nonpositive_and_nan_observations_are_safe() {
        let h = LogHistogram::new();
        h.observe(f32::NAN);
        h.observe(-1.0);
        h.observe(0.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }
}

/// Loom model: two concurrent observers must lose no update, and the
/// min/max bit cells — maintained by the CAS loops in
/// [`crate::util::sync::fetch_min_u32`]/[`fetch_max_u32`] under loom —
/// must converge to the true extrema in every interleaving.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::LogHistogram;
    use loom::thread;
    use std::sync::Arc;

    #[test]
    fn concurrent_observe_loses_nothing() {
        loom::model(|| {
            let h = Arc::new(LogHistogram::new());
            let a = Arc::clone(&h);
            let b = Arc::clone(&h);
            let t1 = thread::spawn(move || a.observe(1.0));
            let t2 = thread::spawn(move || b.observe(100.0));
            t1.join().unwrap();
            t2.join().unwrap();
            assert_eq!(h.count(), 2);
            assert_eq!(h.min(), 1.0);
            assert_eq!(h.max(), 100.0);
            let cum = h.cumulative(1);
            let total = cum.last().map(|&(_, c)| c).unwrap_or(0);
            assert_eq!(total, 2);
        });
    }
}
