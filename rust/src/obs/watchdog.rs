//! SLO + quant-health watchdog: the layer that turns passive metrics
//! into actionable alerts.
//!
//! Two detector families share one alert registry:
//!
//! * **SLO burn rate** — every TTFT / ITL observation is classified
//!   good/bad against a configurable threshold (`RRS_SLO_TTFT_MS`,
//!   `RRS_SLO_ITL_MS`) into a rolling window of one-second buckets
//!   (`RRS_SLO_WINDOW_S`).  The burn rate is the windowed bad fraction
//!   divided by the error budget (`1 - RRS_SLO_TARGET`): `1.0` means
//!   the budget burns exactly as fast as the SLO allows, above it the
//!   service is failing its objective.  Alerts raise at burn ≥ 1 and
//!   clear at burn ≤ 0.5 (hysteresis), with a minimum sample floor so
//!   an idle server never alarms off one slow request.
//! * **Quant-health drift** — every sampled per-layer probe
//!   ([`crate::obs::health`]) feeds a fast EWMA (α = 0.2) and a slow
//!   EWMA (α = 0.02) per statistic (clip rate, spike ratio, kurtosis).
//!   After a warmup of [`QUANT_WARMUP`] probes, a layer alerts when its
//!   fast average exceeds the slow one by **both** a relative factor
//!   and an absolute floor — the paper's failure mode (activation
//!   spikes blowing INT4 clip rates) shows up as exactly this fast/slow
//!   divergence, while the double margin keeps quiet layers (slow ≈ 0)
//!   and noisy-but-stationary layers from flapping.  Alerts clear at
//!   half margin.
//!
//! Alert state surfaces three ways: `rrs_alerts_*` Prometheus families
//! ([`crate::obs::prom`]), an `alerts` section in the metrics snapshot
//! ([`alerts_json`]), and instant trace events — the scheduler drains
//! [`drain_transitions`] into the trace ring each round, so raise/clear
//! edges land on the same timeline as the requests they affected.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{obj, Json};

use super::lock_recover;

/// Probes before a layer's EWMAs are trusted for drift detection.
pub const QUANT_WARMUP: u64 = 8;

/// Fast EWMA coefficient (reacts within ~5 probes).
const ALPHA_FAST: f64 = 0.2;
/// Slow EWMA coefficient (the ~50-probe baseline).
const ALPHA_SLOW: f64 = 0.02;

/// Relative factor the fast EWMA must exceed the slow one by.
const QUANT_REL: f64 = 3.0;
/// Absolute floors per statistic: (clip_rate, spike_ratio, kurtosis).
const QUANT_ABS: [f64; 3] = [0.05, 4.0, 5.0];

/// Quant statistics the drift detector tracks, in [`QUANT_ABS`] order.
pub const QUANT_STATS: [&str; 3] = ["clip_rate", "spike_ratio", "kurtosis"];

/// SLO thresholds and window, resolved once from the environment (or
/// injected by tests via [`configure`]).
#[derive(Clone, Copy, Debug)]
pub struct WatchdogConfig {
    /// TTFT above this is an SLO violation (ms).
    pub ttft_ms: f64,
    /// ITL above this is an SLO violation (ms).
    pub itl_ms: f64,
    /// Good-fraction objective in `(0, 1)` (0.99 = 1% error budget).
    pub target: f64,
    /// Rolling window length in seconds.
    pub window_s: usize,
    /// Minimum windowed samples before a burn-rate alert can raise.
    pub min_samples: u64,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            ttft_ms: 2_000.0,
            itl_ms: 500.0,
            target: 0.99,
            window_s: 60,
            min_samples: 20,
        }
    }
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|v| v.is_finite() && *v > 0.0)
        .unwrap_or(default)
}

impl WatchdogConfig {
    /// Resolve from `RRS_SLO_TTFT_MS` / `RRS_SLO_ITL_MS` /
    /// `RRS_SLO_TARGET` / `RRS_SLO_WINDOW_S`, defaults where unset.
    pub fn from_env() -> WatchdogConfig {
        let d = WatchdogConfig::default();
        WatchdogConfig {
            ttft_ms: env_f64("RRS_SLO_TTFT_MS", d.ttft_ms),
            itl_ms: env_f64("RRS_SLO_ITL_MS", d.itl_ms),
            target: env_f64("RRS_SLO_TARGET", d.target).clamp(0.5, 0.9999),
            window_s: env_f64("RRS_SLO_WINDOW_S", d.window_s as f64) as usize,
            min_samples: d.min_samples,
        }
    }
}

/// Rolling good/bad window over one-second buckets.  Time is an
/// explicit bucket index (seconds) so tests drive it deterministically;
/// production feeds it seconds since process start.
pub struct BurnWindow {
    buckets: Vec<(u64, u64)>,
    /// Bucket timestamp (seconds) each slot currently holds.
    stamps: Vec<u64>,
}

impl BurnWindow {
    /// A window of `window_s` one-second buckets.
    pub fn new(window_s: usize) -> BurnWindow {
        let n = window_s.max(1);
        BurnWindow { buckets: vec![(0, 0); n], stamps: vec![u64::MAX; n] }
    }

    /// Record one observation at second `now_s`: `good` iff the latency
    /// met the SLO threshold.
    pub fn observe_at(&mut self, now_s: u64, good: bool) {
        let i = (now_s as usize) % self.buckets.len();
        if self.stamps[i] != now_s {
            self.stamps[i] = now_s;
            self.buckets[i] = (0, 0);
        }
        if good {
            self.buckets[i].0 += 1;
        } else {
            self.buckets[i].1 += 1;
        }
    }

    /// `(good, bad)` totals over buckets no older than the window as of
    /// second `now_s`.
    pub fn totals_at(&self, now_s: u64) -> (u64, u64) {
        let horizon = now_s.saturating_sub(self.buckets.len() as u64 - 1);
        let mut good = 0;
        let mut bad = 0;
        for (i, &(g, b)) in self.buckets.iter().enumerate() {
            let s = self.stamps[i];
            if s != u64::MAX && s >= horizon && s <= now_s {
                good += g;
                bad += b;
            }
        }
        (good, bad)
    }

    /// Burn rate at second `now_s`: windowed bad fraction over the
    /// error budget `1 - target` (0 when the window is empty).
    pub fn burn_rate_at(&self, now_s: u64, target: f64) -> f64 {
        let (good, bad) = self.totals_at(now_s);
        let n = good + bad;
        if n == 0 {
            return 0.0;
        }
        let budget = (1.0 - target).max(1e-9);
        (bad as f64 / n as f64) / budget
    }
}

/// Per-layer, per-statistic EWMA pair.
#[derive(Clone, Copy, Debug, Default)]
struct Ewma {
    fast: f64,
    slow: f64,
}

impl Ewma {
    fn update(&mut self, v: f64, first: bool) {
        if first {
            self.fast = v;
            self.slow = v;
        } else {
            self.fast += ALPHA_FAST * (v - self.fast);
            self.slow += ALPHA_SLOW * (v - self.slow);
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct LayerDrift {
    probes: u64,
    stats: [Ewma; 3],
}

/// One alert's registry entry.
#[derive(Clone, Debug)]
pub struct AlertState {
    /// Currently firing.
    pub active: bool,
    /// Raise edges since process start.
    pub raised_total: u64,
    /// Small stable id used as the `req` field of the alert's instant
    /// trace events (trace events carry no strings).
    pub trace_id: u64,
    /// Last observed detector value (burn rate or fast EWMA).
    pub value: f64,
    /// The threshold the value is compared against when raising.
    pub threshold: f64,
}

struct Watchdog {
    cfg: WatchdogConfig,
    epoch: Instant,
    ttft: BurnWindow,
    itl: BurnWindow,
    layers: BTreeMap<String, LayerDrift>,
    alerts: BTreeMap<String, AlertState>,
    next_trace_id: u64,
    /// Raise/clear edges not yet exported as trace events:
    /// `(trace_id, raised)`.
    transitions: Vec<(u64, bool)>,
}

/// Cap on tracked layers (mirrors the health registry bound).
const MAX_LAYERS: usize = 512;
/// Cap on queued, un-drained transitions.
const MAX_TRANSITIONS: usize = 1024;

impl Watchdog {
    fn new(cfg: WatchdogConfig) -> Watchdog {
        Watchdog {
            cfg,
            epoch: Instant::now(),
            ttft: BurnWindow::new(cfg.window_s),
            itl: BurnWindow::new(cfg.window_s),
            layers: BTreeMap::new(),
            alerts: BTreeMap::new(),
            next_trace_id: 1,
            transitions: Vec::new(),
        }
    }

    fn now_s(&self) -> u64 {
        self.epoch.elapsed().as_secs()
    }

    /// Flip alert `key` to `active`, recording the edge.
    fn set_alert(&mut self, key: &str, active: bool, value: f64, threshold: f64) {
        if !self.alerts.contains_key(key) && self.alerts.len() >= 4 * MAX_LAYERS {
            return;
        }
        let next_id = &mut self.next_trace_id;
        let a = self.alerts.entry(key.to_string()).or_insert_with(|| {
            let id = *next_id;
            *next_id += 1;
            AlertState {
                active: false,
                raised_total: 0,
                trace_id: id,
                value,
                threshold,
            }
        });
        a.value = value;
        a.threshold = threshold;
        if active != a.active {
            a.active = active;
            if active {
                a.raised_total += 1;
            }
            if self.transitions.len() < MAX_TRANSITIONS {
                self.transitions.push((a.trace_id, active));
            }
        }
    }

    fn slo_check(&mut self, which: &str) {
        let now = self.now_s();
        let (w, threshold) = match which {
            "ttft" => (&self.ttft, self.cfg.ttft_ms),
            _ => (&self.itl, self.cfg.itl_ms),
        };
        let (good, bad) = w.totals_at(now);
        let burn = w.burn_rate_at(now, self.cfg.target);
        let key = format!("slo.{which}");
        let was = self.alerts.get(&key).map(|a| a.active).unwrap_or(false);
        let active = if was {
            burn > 0.5 // clear below half budget-burn (hysteresis)
        } else {
            good + bad >= self.cfg.min_samples && burn >= 1.0
        };
        self.set_alert(&key, active, burn, threshold);
    }

    fn quant_observe(&mut self, layer: &str, spike: f64, kurt: f64, clip: f64) {
        if !self.layers.contains_key(layer) && self.layers.len() >= MAX_LAYERS {
            return;
        }
        let d = self.layers.entry(layer.to_string()).or_default();
        let first = d.probes == 0;
        d.probes += 1;
        let values = [clip, spike, kurt];
        for (e, v) in d.stats.iter_mut().zip(values) {
            e.update(v, first);
        }
        if d.probes < QUANT_WARMUP {
            return;
        }
        let snapshot = *d;
        for (i, stat) in QUANT_STATS.iter().enumerate() {
            let e = snapshot.stats[i];
            let abs = QUANT_ABS[i];
            let key = format!("quant.{layer}.{stat}");
            let was = self.alerts.get(&key).map(|a| a.active).unwrap_or(false);
            // raise on both margins; clear at half margin (hysteresis)
            let (rel, floor) = if was {
                (1.0 + (QUANT_REL - 1.0) * 0.5, abs * 0.5)
            } else {
                (QUANT_REL, abs)
            };
            let threshold = e.slow * rel + floor;
            let active = e.fast > threshold;
            if active || was || self.alerts.contains_key(&key) {
                self.set_alert(&key, active, e.fast, threshold);
            }
        }
    }
}

fn watchdog() -> &'static Mutex<Watchdog> {
    static W: OnceLock<Mutex<Watchdog>> = OnceLock::new();
    W.get_or_init(|| Mutex::new(Watchdog::new(WatchdogConfig::from_env())))
}

/// Replace the live configuration and reset all windows and alert
/// state (tests / benches; windows restart empty).
pub fn configure(cfg: WatchdogConfig) {
    *lock_recover(watchdog()) = Watchdog::new(cfg);
}

/// The live configuration.
pub fn config() -> WatchdogConfig {
    lock_recover(watchdog()).cfg
}

/// Record one TTFT observation (fed by
/// [`crate::coordinator::Metrics::observe_ttft`]).
pub fn observe_ttft(ms: f32) {
    let mut w = lock_recover(watchdog());
    let now = w.now_s();
    let good = (ms as f64) <= w.cfg.ttft_ms;
    w.ttft.observe_at(now, good);
    w.slo_check("ttft");
}

/// Record one ITL observation (fed by
/// [`crate::coordinator::Metrics::observe_itl`]).
pub fn observe_itl(ms: f32) {
    let mut w = lock_recover(watchdog());
    let now = w.now_s();
    let good = (ms as f64) <= w.cfg.itl_ms;
    w.itl.observe_at(now, good);
    w.slo_check("itl");
}

/// Record one per-layer quant-health probe (fed by
/// [`crate::obs::health`] on every sampled probe).
pub fn observe_quant(layer: &str, spike: f32, kurt: f32, clip: f32) {
    lock_recover(watchdog()).quant_observe(layer, spike as f64, kurt as f64, clip as f64);
}

/// Current burn rates `(ttft, itl)` against the live windows.
pub fn burn_rates() -> (f64, f64) {
    let w = lock_recover(watchdog());
    let now = w.now_s();
    (
        w.ttft.burn_rate_at(now, w.cfg.target),
        w.itl.burn_rate_at(now, w.cfg.target),
    )
}

/// All alerts ever registered, keyed by alert name.
pub fn alerts() -> Vec<(String, AlertState)> {
    let w = lock_recover(watchdog());
    w.alerts.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
}

/// Names of currently-firing alerts.
pub fn active_alerts() -> Vec<String> {
    alerts().into_iter().filter(|(_, a)| a.active).map(|(k, _)| k).collect()
}

/// Drain raise/clear edges recorded since the last drain:
/// `(trace_id, raised)`.  The scheduler turns these into instant trace
/// events each round.
pub fn drain_transitions() -> Vec<(u64, bool)> {
    std::mem::take(&mut lock_recover(watchdog()).transitions)
}

/// The `alerts` section of the metrics snapshot.
pub fn alerts_json() -> Json {
    let w = lock_recover(watchdog());
    let now = w.now_s();
    let active: Vec<Json> = w
        .alerts
        .iter()
        .filter(|(_, a)| a.active)
        .map(|(k, _)| Json::Str(k.clone()))
        .collect();
    let all: Vec<(String, Json)> = w
        .alerts
        .iter()
        .map(|(k, a)| {
            (
                k.clone(),
                obj(vec![
                    ("active", a.active.into()),
                    ("raised_total", (a.raised_total as usize).into()),
                    ("trace_id", (a.trace_id as usize).into()),
                    ("value", a.value.into()),
                    ("threshold", a.threshold.into()),
                ]),
            )
        })
        .collect();
    let slo = |name: &str, win: &BurnWindow, th: f64| {
        let (good, bad) = win.totals_at(now);
        (
            name.to_string(),
            obj(vec![
                ("threshold_ms", th.into()),
                ("target", w.cfg.target.into()),
                ("window_s", w.cfg.window_s.into()),
                ("good", (good as usize).into()),
                ("bad", (bad as usize).into()),
                ("burn_rate", win.burn_rate_at(now, w.cfg.target).into()),
            ]),
        )
    };
    obj(vec![
        ("active", Json::Arr(active)),
        (
            "slo",
            Json::Obj(vec![
                slo("ttft", &w.ttft, w.cfg.ttft_ms),
                slo("itl", &w.itl, w.cfg.itl_ms),
            ]),
        ),
        ("alerts", Json::Obj(all)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burn_window_rolls_and_rates() {
        let mut w = BurnWindow::new(10);
        for s in 0..10u64 {
            w.observe_at(s, true);
        }
        assert_eq!(w.totals_at(9), (10, 0));
        assert_eq!(w.burn_rate_at(9, 0.99), 0.0);
        // 5 bad seconds push the bad fraction to 5/15; with a 1% budget
        // the burn rate is ~33x
        for s in 10..15u64 {
            w.observe_at(s, false);
        }
        let (good, bad) = w.totals_at(14);
        assert_eq!(bad, 5);
        assert!(good < 10, "old buckets must roll out, good={good}");
        assert!(w.burn_rate_at(14, 0.99) > 10.0);
        // 20 quiet seconds later the window is empty again
        assert_eq!(w.totals_at(40), (0, 0));
        assert_eq!(w.burn_rate_at(40, 0.99), 0.0);
    }

    #[test]
    fn bucket_reuse_resets_stale_counts() {
        let mut w = BurnWindow::new(4);
        w.observe_at(0, false);
        w.observe_at(0, false);
        // second 4 maps to the same slot as second 0: stale counts gone
        w.observe_at(4, true);
        assert_eq!(w.totals_at(4), (1, 0));
    }

    #[test]
    fn quant_drift_raises_and_clears_per_layer() {
        let mut wd = Watchdog::new(WatchdogConfig::default());
        // clean baseline: Gaussian-ish stats, enough to exit warmup
        for _ in 0..20 {
            wd.quant_observe("wd-l0", 1.2, 3.0, 0.001);
        }
        assert!(
            wd.alerts.values().all(|a| !a.active),
            "clean workload must not alert"
        );
        // outlier-spike regime: clip rate and spike ratio jump
        for _ in 0..20 {
            wd.quant_observe("wd-l0", 30.0, 40.0, 0.4);
        }
        let fired: Vec<&String> = wd
            .alerts
            .iter()
            .filter(|(_, a)| a.active)
            .map(|(k, _)| k)
            .collect();
        assert!(
            fired.iter().any(|k| k.as_str() == "quant.wd-l0.clip_rate"),
            "clip alert missing, fired: {fired:?}"
        );
        assert!(
            fired.iter().any(|k| k.as_str() == "quant.wd-l0.spike_ratio"),
            "spike alert missing, fired: {fired:?}"
        );
        let edges = wd.transitions.len();
        assert!(edges >= 2, "raise edges queued");
        // recovery: long clean run pulls the fast EWMA back under the
        // clear threshold
        for _ in 0..60 {
            wd.quant_observe("wd-l0", 1.2, 3.0, 0.001);
        }
        assert!(
            wd.alerts.values().all(|a| !a.active),
            "alerts must clear after recovery"
        );
        assert!(wd.transitions.len() > edges, "clear edges queued");
        // raised_total survives the clear
        let clip = &wd.alerts["quant.wd-l0.clip_rate"];
        assert!(clip.raised_total >= 1);
    }

    #[test]
    fn stationary_noisy_layer_does_not_flap() {
        let mut wd = Watchdog::new(WatchdogConfig::default());
        // alternating but stationary stats: fast tracks slow closely
        for i in 0..200 {
            let jitter = if i % 2 == 0 { 1.0 } else { 1.5 };
            wd.quant_observe("wd-noisy", jitter, 3.0 + jitter, 0.01 * jitter);
        }
        assert!(wd.alerts.values().all(|a| !a.active), "stationary layer alerted");
    }

    #[test]
    fn slo_burn_raises_with_min_samples() {
        let mut wd = Watchdog::new(WatchdogConfig {
            min_samples: 10,
            ..WatchdogConfig::default()
        });
        // 5 bad observations: under the sample floor, no alert
        for _ in 0..5 {
            wd.itl.observe_at(0, false);
            wd.slo_check("itl");
        }
        assert!(!wd.alerts.get("slo.itl").map(|a| a.active).unwrap_or(false));
        for _ in 0..10 {
            wd.itl.observe_at(0, false);
            wd.slo_check("itl");
        }
        assert!(wd.alerts["slo.itl"].active, "burn alert must raise");
        assert_eq!(wd.alerts["slo.itl"].raised_total, 1);
    }

    #[test]
    fn alerts_json_shape() {
        let mut wd = Watchdog::new(WatchdogConfig::default());
        for _ in 0..QUANT_WARMUP + 4 {
            wd.quant_observe("wd-json", 1.0, 3.0, 0.0);
        }
        for _ in 0..12 {
            wd.quant_observe("wd-json", 50.0, 60.0, 0.9);
        }
        // move the global-free state into a JSON shape via the same
        // code path the snapshot uses
        let w = wd;
        let json = {
            // inline mirror of alerts_json over a local instance
            let active: Vec<Json> = w
                .alerts
                .iter()
                .filter(|(_, a)| a.active)
                .map(|(k, _)| Json::Str(k.clone()))
                .collect();
            Json::Arr(active)
        };
        match json {
            Json::Arr(a) => assert!(!a.is_empty(), "active alert list empty"),
            _ => unreachable!(),
        }
    }
}
