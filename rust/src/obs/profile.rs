//! Continuous sampling profiler over the live phase stacks.
//!
//! A background thread wakes at `RRS_PROF_HZ` (default off), snapshots
//! every registered [`crate::obs::attrib::ThreadStack`], and appends
//! the frames to a bounded sample ring.  [`folded`] folds the ring into
//! inferno / `flamegraph.pl`-compatible text — one
//! `rrs;phase;phase count` line per distinct stack — served by the
//! coordinator's `profile` TCP command.
//!
//! The ring wraps: when full, the oldest sample is overwritten and the
//! dropped count grows, so a long-lived server keeps a recent window at
//! O(1) memory (same discipline as [`crate::obs::trace::TraceRing`]).
//! Overhead is bounded in `rust/benches/obs_overhead.rs`: at 99 Hz the
//! sweep costs one registry lock plus a handful of relaxed loads per
//! thread per tick, asserted < 3% of decode throughput in CI.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

use crate::util::sync::Mutex;

use crate::util::json::{obj, Json};

use super::attrib::{self, Phase, MAX_DEPTH};
use super::lock_recover;

/// Sample ring capacity (at 99 Hz this holds ~11 minutes of samples
/// from one thread; the window shrinks proportionally with threads).
pub const RING_CAPACITY: usize = 65_536;

/// One profiler sample: the phase discriminants of one thread's live
/// stack at the sweep instant.
#[derive(Clone, Copy, Debug)]
struct Sample {
    frames: [u8; MAX_DEPTH],
    depth: u8,
}

struct RingInner {
    buf: Vec<Sample>,
    /// Next overwrite position once the buffer is full.
    head: usize,
    total: u64,
}

/// Bounded overwrite-oldest sample ring.  Factored out of the process
/// global so the wraparound accounting is loom-checkable on an owned
/// instance (the global stays the only one in production).
struct SampleRing {
    cap: usize,
    inner: Mutex<RingInner>,
}

impl SampleRing {
    fn new(cap: usize) -> SampleRing {
        SampleRing {
            cap: cap.max(1),
            inner: Mutex::new(RingInner { buf: Vec::new(), head: 0, total: 0 }),
        }
    }

    fn push(&self, s: Sample) {
        let mut g = lock_recover(&self.inner);
        if g.buf.len() < self.cap {
            g.buf.push(s);
        } else {
            let h = g.head;
            g.buf[h] = s;
            g.head = (h + 1) % self.cap;
        }
        g.total += 1;
    }

    fn total(&self) -> u64 {
        lock_recover(&self.inner).total
    }

    fn len(&self) -> usize {
        lock_recover(&self.inner).buf.len()
    }

    fn dropped(&self) -> u64 {
        let g = lock_recover(&self.inner);
        g.total - g.buf.len() as u64
    }

    fn clear(&self) {
        let mut g = lock_recover(&self.inner);
        g.buf.clear();
        g.head = 0;
        g.total = 0;
    }

    /// Fold the held samples into `stack → count` collapse counts.
    fn fold_counts(&self) -> BTreeMap<String, u64> {
        let g = lock_recover(&self.inner);
        let mut m = BTreeMap::new();
        for s in &g.buf {
            *m.entry(fold_key(s)).or_insert(0u64) += 1;
        }
        m
    }
}

fn ring() -> &'static SampleRing {
    static R: OnceLock<SampleRing> = OnceLock::new();
    R.get_or_init(|| SampleRing::new(RING_CAPACITY))
}

// ORDERING: RATE_MHZ is a lone config cell (sampling rate in mHz) with
// no other state published alongside it — a torn-free u64 load is all a
// reader needs, so its accesses are Relaxed.  STARTED elects the single
// sweep-thread spawner via SeqCst swap.
/// Sampling rate in millihertz (atomic f64 substitute: 99 Hz = 99_000).
static RATE_MHZ: AtomicU64 = AtomicU64::new(0);
static STARTED: AtomicBool = AtomicBool::new(false);

/// Parse `RRS_PROF_HZ` and start the sweep thread when positive.
/// Called once from `Coordinator::start`; repeated calls are no-ops.
pub fn ensure_env_started() {
    let hz = std::env::var("RRS_PROF_HZ")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok())
        .unwrap_or(0.0);
    if hz > 0.0 {
        start_at(hz);
    }
}

/// Start (or retune) the profiler at `hz` samples/second, clamped to
/// `[0, 1000]`.  `0` pauses the sweep without killing the thread.
pub fn start_at(hz: f64) {
    let hz = if hz.is_finite() { hz.clamp(0.0, 1000.0) } else { 0.0 };
    RATE_MHZ.store((hz * 1e3) as u64, Ordering::Relaxed);
    if hz <= 0.0 || STARTED.swap(true, Ordering::SeqCst) {
        return;
    }
    let _ = std::thread::Builder::new()
        .name("rrs-profiler".into())
        .spawn(sweep_loop);
}

/// Pause the sweep (benches measure the profiler-off baseline after a
/// profiled phase without restarting the process).
pub fn pause() {
    RATE_MHZ.store(0, Ordering::Relaxed);
}

/// The live sampling rate in Hz (0 = off / paused).
pub fn rate_hz() -> f64 {
    RATE_MHZ.load(Ordering::Relaxed) as f64 / 1e3
}

fn sweep_loop() {
    loop {
        let mhz = RATE_MHZ.load(Ordering::Relaxed);
        if mhz == 0 {
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }
        let period = Duration::from_secs_f64(1e3 / mhz as f64);
        std::thread::sleep(period);
        sweep_once();
    }
}

/// One sweep: sample every live registered thread stack.
fn sweep_once() {
    for stack in attrib::live_stacks() {
        let (frames, depth) = stack.snapshot();
        record_sample(frames, depth);
    }
}

/// Append one sample to the ring (the sweep path; exposed so the
/// wraparound behaviour is testable without timing dependence).
pub fn record_sample(frames: [u8; MAX_DEPTH], depth: usize) {
    ring().push(Sample { frames, depth: depth.min(MAX_DEPTH) as u8 });
}

/// Samples ever recorded (including overwritten ones).
pub fn samples_total() -> u64 {
    ring().total()
}

/// Samples currently held in the ring.
pub fn samples_len() -> usize {
    ring().len()
}

/// Samples lost to ring wraparound.
pub fn samples_dropped() -> u64 {
    ring().dropped()
}

/// Clear the sample ring (tests / benches).
pub fn reset() {
    ring().clear();
}

fn fold_key(s: &Sample) -> String {
    if s.depth == 0 {
        return "rrs;idle".to_string();
    }
    let mut key = String::from("rrs");
    for &f in s.frames.iter().take(s.depth as usize) {
        key.push(';');
        key.push_str(Phase::from_u8(f).map(Phase::name).unwrap_or("unknown"));
    }
    key
}

/// The ring folded into flamegraph collapse format: one
/// `stack count\n` line per distinct stack, lexicographically sorted
/// (`rrs` is the synthetic root; idle threads fold to `rrs;idle`).
/// Feed straight to `inferno-flamegraph` / `flamegraph.pl`.
pub fn folded() -> String {
    let counts = ring().fold_counts();
    let mut out = String::new();
    for (k, n) in counts {
        out.push_str(&k);
        out.push(' ');
        out.push_str(&n.to_string());
        out.push('\n');
    }
    out
}

/// The `profile` TCP command body: sweep state plus the folded stacks.
pub fn profile_json() -> Json {
    obj(vec![
        ("hz", rate_hz().into()),
        ("samples", (samples_total() as usize).into()),
        ("held", samples_len().into()),
        ("dropped", (samples_dropped() as usize).into()),
        ("capacity", RING_CAPACITY.into()),
        ("folded", Json::Str(folded())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The ring is process-global; serialize the tests that reset it.
    fn ring_lock() -> std::sync::MutexGuard<'static, ()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        lock_recover(L.get_or_init(|| Mutex::new(())))
    }

    fn sample_of(phases: &[Phase]) -> ([u8; MAX_DEPTH], usize) {
        let mut f = [0u8; MAX_DEPTH];
        for (i, p) in phases.iter().enumerate() {
            f[i] = *p as u8;
        }
        (f, phases.len())
    }

    #[test]
    fn folds_stacks_and_idle() {
        let _g = ring_lock();
        reset();
        let (f, d) = sample_of(&[Phase::Prefill, Phase::Gemm]);
        record_sample(f, d);
        record_sample(f, d);
        let (f2, d2) = sample_of(&[Phase::Sampling]);
        record_sample(f2, d2);
        record_sample([0u8; MAX_DEPTH], 0);
        let text = folded();
        assert!(text.contains("rrs;prefill;gemm 2"), "folded:\n{text}");
        assert!(text.contains("rrs;sampling 1"), "folded:\n{text}");
        assert!(text.contains("rrs;idle 1"), "folded:\n{text}");
        let j = profile_json();
        assert_eq!(j.get("held").unwrap().as_usize(), Some(4));
        assert!(j.get("folded").unwrap().as_str().unwrap().contains("rrs;"));
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let _g = ring_lock();
        reset();
        let (gemm, d) = sample_of(&[Phase::Gemm]);
        // fill the ring exactly, then push one more wave of a different
        // stack: the oldest samples must be the ones displaced
        for _ in 0..RING_CAPACITY {
            record_sample(gemm, d);
        }
        assert_eq!(samples_len(), RING_CAPACITY);
        assert_eq!(samples_dropped(), 0);
        let (samp, ds) = sample_of(&[Phase::Sampling]);
        let extra = 1000usize;
        for _ in 0..extra {
            record_sample(samp, ds);
        }
        assert_eq!(samples_len(), RING_CAPACITY);
        assert_eq!(samples_total(), (RING_CAPACITY + extra) as u64);
        assert_eq!(samples_dropped(), extra as u64);
        let text = folded();
        // the displaced window: gemm lost exactly `extra`, sampling
        // holds exactly `extra`
        let expect_gemm = format!("rrs;gemm {}", RING_CAPACITY - extra);
        let expect_samp = format!("rrs;sampling {extra}");
        assert!(text.contains(&expect_gemm), "folded:\n{text}");
        assert!(text.contains(&expect_samp), "folded:\n{text}");
        reset();
        assert_eq!(samples_len(), 0);
        assert_eq!(samples_total(), 0);
    }

    #[test]
    fn rate_clamps() {
        assert_eq!(rate_hz(), 0.0);
        RATE_MHZ.store((99.0f64 * 1e3) as u64, Ordering::Relaxed);
        assert!((rate_hz() - 99.0).abs() < 1e-9);
        RATE_MHZ.store(0, Ordering::Relaxed);
    }
}

/// Loom model: concurrent sweeps pushing into a full ring must keep the
/// `total`/`len`/`dropped` accounting coherent and never grow the
/// buffer past capacity, in every interleaving.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::{Sample, SampleRing, MAX_DEPTH};
    use loom::thread;
    use std::sync::Arc;

    fn sample(phase: u8) -> Sample {
        let mut frames = [0u8; MAX_DEPTH];
        frames[0] = phase;
        Sample { frames, depth: 1 }
    }

    #[test]
    fn concurrent_record_accounting_is_coherent() {
        loom::model(|| {
            let r = Arc::new(SampleRing::new(2));
            let a = Arc::clone(&r);
            let b = Arc::clone(&r);
            let t1 = thread::spawn(move || {
                a.push(sample(1));
                a.push(sample(2));
            });
            let t2 = thread::spawn(move || b.push(sample(3)));
            t1.join().unwrap();
            t2.join().unwrap();
            assert_eq!(r.total(), 3);
            assert_eq!(r.len(), 2);
            assert_eq!(r.dropped(), 1);
            let held: u64 = r.fold_counts().values().sum();
            assert_eq!(held, 2);
        });
    }
}
