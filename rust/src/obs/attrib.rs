//! Per-request phase attribution: decompose every request's wall time
//! into the serving phases that actually consumed it.
//!
//! The passive layer ([`super::hist`], [`super::trace`]) can say *that*
//! a request was slow; this module says *where the time went*.  Hot
//! paths install RAII [`phase_scope`] guards (the same pattern as
//! [`crate::obs::layer_scope`]): the scheduler step loop, the engine
//! KV gather/scatter, the kernel-registry GEMM dispatch, the vectorized
//! sampling pass, and the server stream-write path.  Each guard, on
//! drop, records its **self time** (elapsed minus time spent in nested
//! scopes, so phases never double-count) three ways:
//!
//! * a per-phase process-wide [`LogHistogram`] family, rendered by the
//!   Prometheus exposition as `rrs_phase_ms{phase=...}` (the GEMM phase
//!   additionally carries the live kernel backend label);
//! * the calling thread's **step accumulator**, which the scheduler
//!   drains once per decode round ([`step_take`]) and spreads onto every
//!   lane that took part in the step — per-request attribution;
//! * the thread's live **phase stack** (lock-free, fixed depth),
//!   readable cross-thread by the sampling profiler
//!   ([`super::profile`]).
//!
//! Completed requests land in a bounded registry with their full
//! [`Breakdown`]; the coordinator's `attrib` TCP command returns the
//! top-N slowest with their decompositions ([`slowest_json`]).

use std::cell::RefCell;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Arc, OnceLock, Weak};
use std::time::Instant;

use crate::util::sync::{AtomicU8, AtomicUsize, Mutex, Ordering};

use crate::util::json::{obj, Json};

use super::hist::LogHistogram;
use super::lock_recover;

/// Serving phases a request's wall time decomposes into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    /// Waiting in the public queue before first admission.
    Queue = 0,
    /// Prompt prefill compute (all admission rounds).
    Prefill = 1,
    /// Paged-pool KV rows gathered into dense lanes / attention reads.
    KvGather = 2,
    /// New KV rows scattered back into the paged pool.
    KvScatter = 3,
    /// Quantized GEMM dispatch (fused RRS / per-channel / W4A8 / INT8),
    /// including the fused activation prologue.
    Gemm = 4,
    /// Vectorized per-lane sampling pass over the batch's logit rows.
    Sampling = 5,
    /// Token frames written to the client socket.
    StreamWrite = 6,
    /// Decode-step wall time not covered by an instrumented phase
    /// (attention bookkeeping, scheduler overhead, ...).
    DecodeOther = 7,
}

/// Number of phases (array-index bound; phase discriminants are dense).
pub const NPHASES: usize = 8;

/// Every phase, in discriminant order.
pub const ALL_PHASES: [Phase; NPHASES] = [
    Phase::Queue,
    Phase::Prefill,
    Phase::KvGather,
    Phase::KvScatter,
    Phase::Gemm,
    Phase::Sampling,
    Phase::StreamWrite,
    Phase::DecodeOther,
];

impl Phase {
    /// Stable snake_case name (JSON keys, Prometheus labels, folded
    /// profiler stacks).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Queue => "queue",
            Phase::Prefill => "prefill",
            Phase::KvGather => "kv_gather",
            Phase::KvScatter => "kv_scatter",
            Phase::Gemm => "gemm",
            Phase::Sampling => "sampling",
            Phase::StreamWrite => "stream_write",
            Phase::DecodeOther => "decode_other",
        }
    }

    /// Trace-span name (`phase_*` so lifecycle and phase spans stay
    /// distinguishable on one request track).
    pub fn span_name(self) -> &'static str {
        match self {
            Phase::Queue => "phase_queue",
            Phase::Prefill => "phase_prefill",
            Phase::KvGather => "phase_kv_gather",
            Phase::KvScatter => "phase_kv_scatter",
            Phase::Gemm => "phase_gemm",
            Phase::Sampling => "phase_sampling",
            Phase::StreamWrite => "phase_stream_write",
            Phase::DecodeOther => "phase_decode_other",
        }
    }

    /// Inverse of the discriminant (profiler samples store raw `u8`s).
    pub fn from_u8(v: u8) -> Option<Phase> {
        ALL_PHASES.get(v as usize).copied()
    }
}

/// Max nesting depth of live phase scopes per thread (deeper scopes
/// still time correctly; they just vanish from profiler samples).
pub const MAX_DEPTH: usize = 8;

/// One thread's live phase stack, readable cross-thread.  The depth is
/// the publication point: frames below the published depth are always
/// fully written (Release/Acquire pairing on `depth`), so the profiler
/// reads a snapshot that is *torn in time* at worst (a frame from a
/// neighbouring instant), never an unwritten byte.
pub struct ThreadStack {
    depth: AtomicUsize,
    frames: [AtomicU8; MAX_DEPTH],
}

impl ThreadStack {
    #[cfg(not(loom))]
    fn new() -> ThreadStack {
        ThreadStack {
            depth: AtomicUsize::new(0),
            frames: [const { AtomicU8::new(0) }; MAX_DEPTH],
        }
    }

    // loom's atomics are not const-constructible; the models build their
    // stacks at runtime inside the model closure
    #[cfg(loom)]
    fn new() -> ThreadStack {
        ThreadStack {
            depth: AtomicUsize::new(0),
            frames: std::array::from_fn(|_| AtomicU8::new(0)),
        }
    }

    /// Publish `phase` as the new innermost frame at `depth`.
    ///
    /// ORDERING: the frame byte must be visible before the deeper depth
    /// is: depth is stored Release here and loaded Acquire in
    /// [`snapshot`], so a sweep that observes `depth + 1` also observes
    /// this frame.  (A Relaxed pair let the profiler read a stale frame
    /// byte under the new depth — the mis-attribution the
    /// `snapshot_never_sees_unpublished_frame` loom model locks out.)
    fn push(&self, depth: usize, phase: u8) {
        self.frames[depth].store(phase, Ordering::Relaxed);
        self.depth.store(depth + 1, Ordering::Release);
    }

    /// Retract the stack to `depth` live frames (scope exit).
    ///
    /// ORDERING: shrinking publishes no new frame, but Release keeps
    /// this store ordered after the dying scope's writes so a sweep
    /// never resurrects them under a later push.
    fn set_depth(&self, depth: usize) {
        self.depth.store(depth, Ordering::Release);
    }

    /// Snapshot the live frames (phase discriminants, outermost first).
    pub fn snapshot(&self) -> ([u8; MAX_DEPTH], usize) {
        // ORDERING: Acquire pairs with the Release in `push`: every
        // frame below the loaded depth was fully written before that
        // depth was published, so the Relaxed frame reads below are
        // covered by this edge.
        let depth = self.depth.load(Ordering::Acquire).min(MAX_DEPTH);
        let mut out = [0u8; MAX_DEPTH];
        for (i, f) in self.frames.iter().take(depth).enumerate() {
            out[i] = f.load(Ordering::Relaxed);
        }
        (out, depth)
    }
}

/// Cap on registered thread stacks (server spawns a thread per
/// connection; dead threads are pruned on registration and by the
/// profiler sweep, the cap bounds the worst case in between).
const MAX_STACKS: usize = 4096;

fn stack_registry() -> &'static Mutex<Vec<Weak<ThreadStack>>> {
    static REG: OnceLock<Mutex<Vec<Weak<ThreadStack>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

/// Snapshot every live registered thread stack (profiler sweep).
pub fn live_stacks() -> Vec<Arc<ThreadStack>> {
    let mut reg = lock_recover(stack_registry());
    reg.retain(|w| w.strong_count() > 0);
    reg.iter().filter_map(Weak::upgrade).collect()
}

struct LocalFrame {
    phase: Phase,
    start: Instant,
    /// Time consumed by nested scopes (subtracted for self time).
    child_us: u64,
}

struct ThreadState {
    stack: Arc<ThreadStack>,
    frames: Vec<LocalFrame>,
    /// Per-phase self-time since the last [`step_take`], microseconds.
    step_us: [u64; NPHASES],
}

impl ThreadState {
    fn new() -> ThreadState {
        let stack = Arc::new(ThreadStack::new());
        let mut reg = lock_recover(stack_registry());
        reg.retain(|w| w.strong_count() > 0);
        if reg.len() < MAX_STACKS {
            reg.push(Arc::downgrade(&stack));
        }
        ThreadState { stack, frames: Vec::with_capacity(MAX_DEPTH), step_us: [0; NPHASES] }
    }
}

thread_local! {
    static STATE: RefCell<ThreadState> = RefCell::new(ThreadState::new());
}

fn phase_hists() -> &'static [LogHistogram; NPHASES] {
    static H: OnceLock<[LogHistogram; NPHASES]> = OnceLock::new();
    H.get_or_init(|| std::array::from_fn(|_| LogHistogram::new()))
}

/// The process-wide per-phase self-time histograms (milliseconds), in
/// [`ALL_PHASES`] order — the Prometheus renderer iterates this.
pub fn histograms() -> impl Iterator<Item = (Phase, &'static LogHistogram)> {
    ALL_PHASES.iter().copied().zip(phase_hists().iter())
}

/// RAII guard: the calling thread is in `phase` until drop.  On drop
/// the scope's *self time* (elapsed minus nested scopes) feeds the
/// phase histogram and the thread's step accumulator; while live, the
/// phase is visible to the sampling profiler.
pub struct PhaseScope {
    phase: Phase,
}

/// Enter `phase` on the current thread.  Scopes nest; each level
/// accounts only its self time, so a GEMM inside a decode step never
/// counts twice.
pub fn phase_scope(phase: Phase) -> PhaseScope {
    STATE.with(|s| {
        let mut st = s.borrow_mut();
        let depth = st.frames.len();
        if depth < MAX_DEPTH {
            st.stack.push(depth, phase as u8);
        }
        st.frames.push(LocalFrame { phase, start: Instant::now(), child_us: 0 });
    });
    PhaseScope { phase }
}

impl Drop for PhaseScope {
    fn drop(&mut self) {
        STATE.with(|s| {
            let mut st = s.borrow_mut();
            let Some(f) = st.frames.pop() else { return };
            debug_assert_eq!(f.phase, self.phase);
            let depth = st.frames.len();
            if depth < MAX_DEPTH {
                st.stack.set_depth(depth);
            }
            let total_us = f.start.elapsed().as_micros() as u64;
            let self_us = total_us.saturating_sub(f.child_us);
            if let Some(parent) = st.frames.last_mut() {
                parent.child_us += total_us;
            }
            st.step_us[f.phase as usize] += self_us;
            phase_hists()[f.phase as usize].observe(self_us as f32 / 1e3);
        });
    }
}

/// Drain the calling thread's per-phase step accumulator (microseconds,
/// [`ALL_PHASES`] order).  The scheduler calls this once per decode
/// round and spreads the totals over every participating lane.
pub fn step_take() -> [u64; NPHASES] {
    STATE.with(|s| std::mem::replace(&mut s.borrow_mut().step_us, [0; NPHASES]))
}

/// One request's wall-time decomposition, microseconds per phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown(pub [u64; NPHASES]);

impl Breakdown {
    /// Add `us` microseconds to `phase`.
    pub fn add(&mut self, phase: Phase, us: u64) {
        self.0[phase as usize] = self.0[phase as usize].saturating_add(us);
    }

    /// Overwrite `phase` with `us` microseconds.
    pub fn set(&mut self, phase: Phase, us: u64) {
        self.0[phase as usize] = us;
    }

    /// Microseconds attributed to `phase`.
    pub fn get(&self, phase: Phase) -> u64 {
        self.0[phase as usize]
    }

    /// Sum over all phases, microseconds.
    pub fn total_us(&self) -> u64 {
        self.0.iter().sum()
    }

    /// JSON object keyed by phase name, values in milliseconds.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            ALL_PHASES
                .iter()
                .map(|p| (p.name().to_string(), Json::Num(self.get(*p) as f64 / 1e3)))
                .collect(),
        )
    }
}

/// A completed request with its attribution (the `attrib` command's
/// row shape).
#[derive(Clone, Debug)]
pub struct RequestAttrib {
    /// Request id (the trace `tid`).
    pub id: u64,
    /// End-to-end wall time, microseconds.
    pub total_us: u64,
    /// Generated tokens.
    pub tokens: u64,
    /// Terminal finish reason (`stop`, `length`, `cancelled`, ...).
    pub finish: &'static str,
    /// Per-phase decomposition.
    pub breakdown: Breakdown,
}

/// Completed-request ring capacity (top-N queries scan this window).
const MAX_FINISHED: usize = 512;

fn finished() -> &'static Mutex<VecDeque<RequestAttrib>> {
    static F: OnceLock<Mutex<VecDeque<RequestAttrib>>> = OnceLock::new();
    F.get_or_init(|| Mutex::new(VecDeque::with_capacity(64)))
}

/// Record a finished request's attribution (scheduler retire path).
pub fn finish_request(r: RequestAttrib) {
    let mut f = lock_recover(finished());
    if f.len() >= MAX_FINISHED {
        f.pop_front();
    }
    f.push_back(r);
}

/// The `n` slowest requests in the completed window, slowest first.
pub fn slowest(n: usize) -> Vec<RequestAttrib> {
    let f = lock_recover(finished());
    let mut v: Vec<RequestAttrib> = f.iter().cloned().collect();
    v.sort_by(|a, b| b.total_us.cmp(&a.total_us));
    v.truncate(n);
    v
}

/// Completed requests currently held in the attribution window.
pub fn finished_len() -> usize {
    lock_recover(finished()).len()
}

/// Clear the completed-request window (tests / benches).
pub fn reset() {
    lock_recover(finished()).clear();
}

/// The `attrib` TCP command body: window counters plus the top-`n`
/// slowest requests with per-phase decompositions (milliseconds).
pub fn slowest_json(n: usize) -> Json {
    let rows: Vec<Json> = slowest(n)
        .into_iter()
        .map(|r| {
            obj(vec![
                ("id", (r.id as usize).into()),
                ("total_ms", (r.total_us as f64 / 1e3).into()),
                ("tokens", (r.tokens as usize).into()),
                ("finish", r.finish.into()),
                ("attributed_ms", (r.breakdown.total_us() as f64 / 1e3).into()),
                ("phases_ms", r.breakdown.to_json()),
            ])
        })
        .collect();
    obj(vec![
        ("window", finished_len().into()),
        ("window_capacity", MAX_FINISHED.into()),
        ("requests", Json::Arr(rows)),
    ])
}

/// Cap on concurrently tracked stream-write accumulators.
const MAX_STREAMING: usize = 1024;

struct StreamWrites {
    us: HashMap<u64, u64>,
    order: VecDeque<u64>,
}

fn stream_writes() -> &'static Mutex<StreamWrites> {
    static S: OnceLock<Mutex<StreamWrites>> = OnceLock::new();
    S.get_or_init(|| {
        Mutex::new(StreamWrites { us: HashMap::new(), order: VecDeque::new() })
    })
}

/// Credit `us` microseconds of socket write time to request `id`
/// (server stream path; drained by the scheduler at retire).
pub fn add_stream_write(id: u64, us: u64) {
    let mut s = lock_recover(stream_writes());
    if let Some(v) = s.us.get_mut(&id) {
        *v += us;
        return;
    }
    while s.us.len() >= MAX_STREAMING {
        // evict the oldest live accumulator (stale ids already taken
        // are skipped); bounded by the order queue length
        match s.order.pop_front() {
            Some(old) => {
                if s.us.remove(&old).is_some() {
                    break;
                }
            }
            None => break,
        }
    }
    s.us.insert(id, us);
    s.order.push_back(id);
}

/// Take (and clear) the accumulated stream-write time for `id`.
pub fn take_stream_write(id: u64) -> u64 {
    lock_recover(stream_writes()).us.remove(&id).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_scopes_account_self_time() {
        let _ = step_take(); // drain anything a prior test left behind
        {
            let _outer = phase_scope(Phase::DecodeOther);
            std::thread::sleep(std::time::Duration::from_millis(4));
            {
                let _inner = phase_scope(Phase::Gemm);
                std::thread::sleep(std::time::Duration::from_millis(4));
            }
        }
        let us = step_take();
        let gemm = us[Phase::Gemm as usize];
        let other = us[Phase::DecodeOther as usize];
        assert!(gemm >= 3_000, "gemm self {gemm}us");
        assert!(other >= 3_000, "other self {other}us");
        // self-time: the outer scope must not re-count the inner 4ms
        assert!(other < 20_000, "outer did not subtract child: {other}us");
        // drained: a second take is empty
        assert_eq!(step_take(), [0u64; NPHASES]);
    }

    #[test]
    fn live_stack_visible_while_scoped() {
        let _g = phase_scope(Phase::Sampling);
        let found = live_stacks().iter().any(|s| {
            let (frames, depth) = s.snapshot();
            depth >= 1 && frames[..depth].contains(&(Phase::Sampling as u8))
        });
        assert!(found, "live scope not visible in any registered stack");
    }

    #[test]
    fn breakdown_json_and_ranking() {
        reset();
        for i in 0..5u64 {
            let mut b = Breakdown::default();
            b.add(Phase::Queue, 100 * (i + 1));
            b.add(Phase::Gemm, 50);
            finish_request(RequestAttrib {
                id: i,
                total_us: 1_000 * (i + 1),
                tokens: i,
                finish: "stop",
                breakdown: b,
            });
        }
        let top = slowest(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].id, 4);
        assert_eq!(top[1].id, 3);
        let j = slowest_json(2);
        assert!(j.get("window").unwrap().as_usize().unwrap() >= 5);
        let rows = j.get("requests").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        let r0 = &rows[0];
        assert_eq!(r0.get("id").unwrap().as_usize(), Some(4));
        let ph = r0.get("phases_ms").unwrap();
        assert!(ph.get("queue").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(ph.get("kv_gather").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn stream_write_accumulates_and_drains() {
        add_stream_write(900_001, 10);
        add_stream_write(900_001, 5);
        assert_eq!(take_stream_write(900_001), 15);
        assert_eq!(take_stream_write(900_001), 0);
    }

    #[test]
    fn phase_names_round_trip() {
        for (i, p) in ALL_PHASES.iter().enumerate() {
            assert_eq!(*p as usize, i);
            assert_eq!(Phase::from_u8(i as u8), Some(*p));
            assert!(p.span_name().starts_with("phase_"));
        }
        assert_eq!(Phase::from_u8(NPHASES as u8), None);
    }
}

/// Loom regression model for the frame-publish race fixed in
/// [`ThreadStack::push`]: with Relaxed/Relaxed the profiler sweep could
/// observe the incremented depth *before* the frame byte, attributing
/// the sample to whatever stale phase the slot last held.  Run with
/// `RUSTFLAGS="--cfg loom" cargo test -p rrs --lib -- loom_ --nocapture`.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::{Phase, ThreadStack};
    use loom::thread;
    use std::sync::Arc;

    #[test]
    fn snapshot_never_sees_unpublished_frame() {
        loom::model(|| {
            let st = Arc::new(ThreadStack::new());
            let w = Arc::clone(&st);
            let writer = thread::spawn(move || {
                // Gemm (4) is distinguishable from the zero-initialised
                // slot, which decodes as Queue (0).
                w.push(0, Phase::Gemm as u8);
            });
            let (frames, depth) = st.snapshot();
            if depth >= 1 {
                assert_eq!(
                    frames[0],
                    Phase::Gemm as u8,
                    "depth published before its frame byte"
                );
            }
            writer.join().unwrap();
        });
    }

    #[test]
    fn pop_never_resurrects_deeper_frame() {
        loom::model(|| {
            let st = Arc::new(ThreadStack::new());
            st.push(0, Phase::DecodeOther as u8);
            let w = Arc::clone(&st);
            let writer = thread::spawn(move || {
                // nested scope enters and exits
                w.push(1, Phase::Gemm as u8);
                w.set_depth(1);
            });
            let (frames, depth) = st.snapshot();
            assert!(depth <= 2);
            if depth >= 1 {
                assert_eq!(frames[0], Phase::DecodeOther as u8);
            }
            if depth == 2 {
                assert_eq!(frames[1], Phase::Gemm as u8);
            }
            writer.join().unwrap();
        });
    }
}
