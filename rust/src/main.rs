//! `rrs` — CLI for the Rotated Runtime Smooth serving stack.
//!
//! Commands:
//!   rrs info                         artifact + platform summary
//!   rrs generate --prompt "arlo is"  one-shot generation (rust engine);
//!       sampling: --temperature --top-k --top-p --repetition-penalty --seed
//!   rrs serve [--port 0]             TCP serving coordinator
//!   rrs eval-ppl [--method rrs] ...  perplexity of one config cell
//!   rrs harness <exp|all>            regenerate paper tables/figures
//!   rrs pjrt-demo                    run the AOT demo graph via PJRT
//!
//! Common flags: --artifacts DIR (default ./artifacts), --method,
//! --scheme {a4w4kv4,a4w4kv16,a4w16kv16,fp}, --group N, --profile NAME,
//! --fast.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use rrs::coordinator::{
    server, Coordinator, RequestOptions, RustServeEngine, SamplingParams,
    SchedulerConfig,
};
use rrs::eval::perplexity::format_ppl;
use rrs::harness::{self, Ctx};
use rrs::model::weights::OutlierProfile;
use rrs::model::{tokenizer, EngineConfig, QuantModel, Weights};
use rrs::quant::{Method, QuantRecipe, Scheme};
use rrs::runtime::PjrtEngine;
use rrs::util::cli::Args;

fn parse_scheme(s: &str) -> Result<Scheme> {
    Ok(match s.to_lowercase().as_str() {
        "a4w4kv4" | "4-4-4" => Scheme::A4W4KV4,
        "a4w4kv16" | "4-4-16" => Scheme::A4W4KV16,
        "a4w16kv16" | "16-4-16" => Scheme::A4W16KV16,
        "fp" | "fp16" | "16-16-16" => Scheme::FP,
        other => bail!("unknown scheme '{other}'"),
    })
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    // a recipe spec (--recipe or RRS_RECIPE) overrides the legacy
    // method/scheme knobs entirely: every quant axis comes from the spec
    if let Some(spec) = args.get("recipe") {
        let recipe = QuantRecipe::parse(spec).context("bad --recipe")?;
        return Ok(EngineConfig::from_recipe(recipe));
    }
    if let Some(parsed) = QuantRecipe::from_env() {
        let recipe = parsed.context("bad RRS_RECIPE")?;
        return Ok(EngineConfig::from_recipe(recipe));
    }
    let method = Method::parse(&args.get_or("method", "rrs"))
        .context("unknown --method")?;
    let scheme = parse_scheme(&args.get_or(
        "scheme",
        if method == Method::Fp { "fp" } else { "a4w4kv4" },
    ))?;
    Ok(EngineConfig {
        method,
        scheme,
        group: args.get_usize("group", 128),
        kv_group: args.get_usize("kv-group", 128),
        alpha: args.get_f32("alpha", 0.5),
        gptq: method != Method::Rtn
            && method != Method::Fp
            && !args.has_flag("no-gptq"),
        recipe: None,
    })
}

/// Build a rust-engine model from artifacts per CLI flags.
fn build_model(args: &Args) -> Result<QuantModel> {
    let root = args.get_or("artifacts", "artifacts");
    let artifacts = rrs::runtime::Artifacts::load(&root)?;
    let mcfg = artifacts.model;
    let profile = OutlierProfile::builtin(&args.get_or("profile", "base"))
        .context("unknown --profile")?;
    // prefer the finetuned per-profile checkpoint (see aot.py)
    let ppath = artifacts.root.join(format!("weights_{}.rrsw", profile.name));
    let weights = if profile.name != "base" && ppath.exists() {
        Weights::load(&ppath, &mcfg)?
    } else {
        let base = Weights::load(artifacts.weights_path(), &mcfg)?;
        profile.inject(&base, 17)
    };
    let ecfg = engine_config(args)?;
    let val = artifacts.val_text()?;
    let toks = tokenizer::encode(&val);
    let calib: Vec<u32> =
        (0..8).flat_map(|i| toks[i * 64..i * 64 + 64].to_vec()).collect();
    let spin = rrs::util::io::read_rrsw(artifacts.spinquant_path())
        .ok()
        .and_then(|m| {
            let rd = m.get("r_dim")?;
            let rf = m.get("r_ffn")?;
            Some((
                rrs::linalg::gemm::Mat::from_vec(
                    rd.shape[0], rd.shape[1], rd.as_f32().ok()?.to_vec()),
                rrs::linalg::gemm::Mat::from_vec(
                    rf.shape[0], rf.shape[1], rf.as_f32().ok()?.to_vec()),
            ))
        });
    QuantModel::prepare(&weights, &mcfg, &ecfg, Some(&calib), spin)
}

fn cmd_info(args: &Args) -> Result<()> {
    let root = args.get_or("artifacts", "artifacts");
    let artifacts = rrs::runtime::Artifacts::load(&root)?;
    println!("model: dim={} layers={} heads={} kv_heads={} ffn={} vocab={}",
             artifacts.model.dim, artifacts.model.n_layers,
             artifacts.model.n_heads, artifacts.model.n_kv_heads,
             artifacts.model.ffn, artifacts.model.vocab);
    println!("graphs:");
    for g in &artifacts.graphs {
        println!("  {} <- {}", g.name, g.file.display());
    }
    let engine = PjrtEngine::new(&root)?;
    println!("pjrt platform: {}", engine.platform());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = args.get_or("prompt", "arlo is");
    let max_tokens = args.get_usize("max-tokens", 32);
    let model = build_model(args)?;
    let ecfg = model.ecfg;
    let engine = RustServeEngine::new(model);
    let coord = Coordinator::start(engine, SchedulerConfig::default())?;
    let seed = args.get_usize("seed", 0);
    let params = SamplingParams {
        temperature: args.get_f32("temperature", 0.0),
        top_k: args.get_usize("top-k", 0),
        top_p: args.get_f32("top-p", 1.0),
        repetition_penalty: args.get_f32("repetition-penalty", 1.0),
        seed: if seed == 0 { None } else { Some(seed as u64) },
        ..Default::default()
    };
    let opts = RequestOptions {
        max_new_tokens: max_tokens,
        params,
        ..Default::default()
    };
    let resp = coord
        .generate_opts(tokenizer::encode(&prompt), opts)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("[{}] {}{}", ecfg.label(), prompt, tokenizer::decode(&resp.tokens));
    println!(
        "tokens={} queue={:.1}ms prefill={:.1}ms decode={:.1}ms total={:.1}ms",
        resp.tokens.len(), resp.queue_ms, resp.prefill_ms, resp.decode_ms,
        resp.total_ms
    );
    coord.shutdown();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = build_model(args)?;
    println!("serving {}", model.ecfg.label());
    let engine = RustServeEngine::new(model);
    let cfg = SchedulerConfig {
        max_batch: args.get_usize("max-batch", 8),
        queue_capacity: args.get_usize("queue", 64),
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start(engine, cfg)?);
    let port = args.get_usize("port", 0);
    server::serve(coord, &format!("127.0.0.1:{port}"))?;
    Ok(())
}

fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let model = build_model(args)?;
    let root = args.get_or("artifacts", "artifacts");
    let artifacts = rrs::runtime::Artifacts::load(&root)?;
    let val = artifacts.val_text()?;
    let windows = args.get_usize("windows", 8);
    let ppl = rrs::eval::perplexity(&model, &val, 96, windows);
    println!(
        "{} profile={} ppl={}",
        model.ecfg.label(),
        args.get_or("profile", "base"),
        format_ppl(ppl)
    );
    Ok(())
}

fn cmd_harness(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let ctx = Ctx::load(
        &args.get_or("artifacts", "artifacts"),
        &args.get_or("out", "reports"),
        args.has_flag("fast"),
    )?;
    match which {
        "all" => harness::run_all(&ctx)?,
        "table1" => harness::table1::run(&ctx)?,
        "table2" => harness::table2::run(&ctx)?,
        "table3" => harness::table3::run(&ctx)?,
        "table4" => harness::table4::run(&ctx)?,
        "fig2b" => harness::figures::fig2b(&ctx)?,
        "fig3" => harness::figures::fig3(&ctx)?,
        "fig6" => harness::fig6::run(&ctx)?,
        "fig7" => harness::figures::fig7(&ctx)?,
        "fig8" => harness::figures::fig8(&ctx)?,
        "fig9" => harness::figures::fig9(&ctx)?,
        "matrix" => harness::matrix::run(&ctx)?,
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn cmd_pjrt_demo(args: &Args) -> Result<()> {
    let root = args.get_or("artifacts", "artifacts");
    let engine = PjrtEngine::new(&root)?;
    println!("platform: {}", engine.platform());
    let goldens = rrs::util::io::read_rrsw(engine.artifacts.goldens_path())?;
    let x = goldens["demo_x"].as_f32()?.to_vec();
    let runner = engine.runner("demo_rrs_gemm")?;
    let out = runner.run(&[rrs::runtime::executor::HostTensor::f32(
        vec![16, 128],
        x,
    )])?;
    let y = out[0].as_f32()?;
    let want = goldens["demo_y"].as_f32()?;
    let worst = y
        .iter()
        .zip(want)
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("demo_rrs_gemm: {} outputs, max |err| vs golden = {worst:.2e}", y.len());
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "eval-ppl" => cmd_eval_ppl(&args),
        "harness" => cmd_harness(&args),
        "pjrt-demo" => cmd_pjrt_demo(&args),
        _ => {
            println!(
                "rrs — Rotated Runtime Smooth INT4 serving stack\n\n\
                 usage: rrs <info|generate|serve|eval-ppl|harness|pjrt-demo> [flags]\n\
                 harness experiments: all table1 table2 table3 table4 fig2b fig3 fig6 fig7 fig8 fig9 matrix\n\
                 common flags: --artifacts DIR --method M --scheme S --group N --profile P --fast\n\
                 quant recipe: --recipe SPEC (or RRS_RECIPE), e.g. 'sq:a8w4kv8:had:g64' —\n\
                 axis tokens: method presets (rrs rs sq quarot dense rtn fp), aXwYkvZ,\n\
                 nosmooth|norot|had|dense|rot, gptq|nogptq, gN kvgN alphaF migrate"
            );
            Ok(())
        }
    }
}
