//! Summary statistics + histogram helpers (metrics, figures, benches).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Root mean square.
pub fn rms(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x * x).sum::<f32>() / xs.len() as f32).sqrt()
}

/// Maximum absolute value.
pub fn absmax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
}

/// `mu = absmax / RMS` — the paper's token smoothness statistic (Fig. 2b).
pub fn smoothness_mu(token: &[f32]) -> f32 {
    let r = rms(token);
    if r < 1e-12 {
        return 0.0;
    }
    absmax(token) / r
}

/// `absmax / l2` — the appendix A.2 variant (Fig. 9).
pub fn smoothness_l2(token: &[f32]) -> f32 {
    let l2 = xs_l2(token);
    if l2 < 1e-12 {
        return 0.0;
    }
    absmax(token) / l2
}

fn xs_l2(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum::<f32>().sqrt()
}

/// Interpolated percentile (`p` in [0,100]) of an unsorted slice.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    // total_cmp: a total order even with NaNs present (NaNs sort to the
    // ends) — the old partial_cmp-or-Equal comparator was not transitive
    // on NaN inputs, which sort_by is allowed to punish.
    v.sort_by(f32::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f32;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f32;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Latency/throughput summary for metrics and bench output.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f32,
    pub p10: f32,
    pub p50: f32,
    pub p90: f32,
    pub p99: f32,
    pub min: f32,
    pub max: f32,
}

impl Summary {
    pub fn of(xs: &[f32]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            p10: percentile(xs, 10.0),
            p50: percentile(xs, 50.0),
            p90: percentile(xs, 90.0),
            p99: percentile(xs, 99.0),
            min: xs.iter().cloned().fold(f32::INFINITY, f32::min),
            max: xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        }
    }
}

/// Fixed-bin log-scale histogram (Fig. 7 magnitude intervals).
pub fn log_histogram(xs: &[f32], edges: &[f32]) -> Vec<usize> {
    let mut counts = vec![0usize; edges.len() + 1];
    for &x in xs {
        let mut b = edges.len();
        for (i, &e) in edges.iter().enumerate() {
            if x < e {
                b = i;
                break;
            }
        }
        counts[b] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rms() {
        assert_eq!(mean(&[1.0, 3.0]), 2.0);
        assert!((rms(&[3.0, 4.0]) - (12.5f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn mu_of_constant_token_is_one() {
        let t = vec![2.0f32; 64];
        assert!((smoothness_mu(&t) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mu_of_spike_is_large() {
        let mut t = vec![0.01f32; 64];
        t[5] = 100.0;
        assert!(smoothness_mu(&t) > 7.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-3);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn histogram_bins() {
        let xs = vec![0.5, 5.0, 50.0, 500.0];
        let counts = log_histogram(&xs, &[1.0, 10.0, 100.0]);
        assert_eq!(counts, vec![1, 1, 1, 1]);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 1000);
        assert!(s.p50 > 490.0 && s.p50 < 510.0);
        assert!(s.p99 > 985.0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 999.0);
    }
}
